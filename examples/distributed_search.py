"""Distributed similarity-search serving (the paper's engine as a service):
shard a fingerprint DB over a device mesh, fan queries out, merge top-k
hierarchically — run with multiple host devices to see real sharding:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_search.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import make_sharded_search, shard_database
from repro.data.molecules import (SyntheticConfig, queries_from_db,
                                  synthetic_fingerprints)
from repro.kernels import ref
from repro.launch.mesh import make_local_mesh


def main():
    n_dev = len(jax.devices())
    mesh = make_local_mesh()
    print(f"devices: {n_dev}, mesh axes: {mesh.axis_names}, "
          f"shape: {dict(mesh.shape)}")

    db = synthetic_fingerprints(SyntheticConfig(n=40_000, seed=0))
    queries = jnp.asarray(queries_from_db(db, 32))

    with mesh:
        db_s, cnt_s, n_valid = shard_database(mesh, db)
        print(f"DB sharded: {db_s.shape[0]} rows over {n_dev} devices "
              f"({db_s.sharding.spec})")
        search, _, _ = make_sharded_search(mesh, db_s.shape[0], k=20,
                                           n_valid=n_valid)
        vals, ids = search(queries, db_s, cnt_s)

    _, expect = ref.tanimoto_topk_ref(queries, jnp.asarray(db), 20)
    ok = np.allclose(np.asarray(vals), np.asarray(expect), rtol=1e-6)
    print(f"hierarchical merge == single-device oracle: {ok}")
    print(f"sample result (query 0): ids {np.asarray(ids)[0, :5]} "
          f"sims {np.round(np.asarray(vals)[0, :5], 3)}")


if __name__ == "__main__":
    main()
