"""Batched token serving across the architecture zoo (prefill + decode with
per-family caches: KV, Mamba state, xLSTM state, cross-attention).

    PYTHONPATH=src python examples/serve_generate.py --arch jamba-v0.1-52b
"""
import argparse

from repro.launch.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="jamba-v0.1-52b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()
    gen = generate(args.arch, prompt_len=12, gen_len=args.gen_len,
                   batch=args.batch)
    print("generated ids (row 0):", gen[0].tolist())


if __name__ == "__main__":
    main()
