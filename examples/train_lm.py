"""End-to-end driver (deliverable b): train a ~100M-param granite-family
model for a few hundred steps with checkpointing + fault recovery.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses a width-reduced granite config (~100M params) on the local mesh. The
same `repro.launch.train` path drives the full configs on a production mesh.
"""
import argparse

from repro.configs import ARCHS, get_arch
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()

    # ~100M-param variant of the granite family: 12L x 768 wide
    base = get_arch(args.arch)
    cfg100m = base.with_(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                         d_ff=2048, vocab=32_000, max_seq=512)
    ARCHS["granite-100m"] = cfg100m

    losses = train("granite-100m", steps=args.steps, global_batch=8,
                   seq_len=256, ckpt_dir="/tmp/repro_100m_ckpt",
                   ckpt_every=50, fail_at=args.fail_at, reduced=False,
                   n_microbatches=2)
    print(f"\nfirst-10 mean loss {sum(losses[:10]) / 10:.3f} -> "
          f"last-10 mean loss {sum(losses[-10:]) / 10:.3f}")


if __name__ == "__main__":
    main()
