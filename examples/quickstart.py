"""Quickstart: build a ChEMBL-like fingerprint DB and run all three of the
paper's search engines on it.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import CHEMBL_LIKE
from repro.core import (BitBoundFoldingEngine, BruteForceEngine, HNSWEngine,
                        recall_at_k)
from repro.data.molecules import (SyntheticConfig, queries_from_db,
                                  synthetic_fingerprints)


def main():
    print("== building synthetic ChEMBL-like DB (20k molecules, 1024-bit) ==")
    db = synthetic_fingerprints(SyntheticConfig(n=20_000, seed=0))
    queries = queries_from_db(db, 16)
    k = CHEMBL_LIKE.k

    print("== exhaustive brute force (fused scan+top-k engine) ==")
    brute = BruteForceEngine(db, use_kernel=True)
    true_ids, true_sims = brute.search(queries, k)
    print(f"   top hit similarities: {np.round(true_sims[:4, 0], 3)}")

    # Sc=0.5 here: the paper runs Sc=0.8 on ChEMBL where top-20 neighbours
    # are mostly >=0.8-similar; synthetic neighbourhoods sit lower, so the
    # equivalent recall-preserving operating point is a lower cutoff.
    print(f"== BitBound & folding (Sc=0.5, m={CHEMBL_LIKE.folding_m}) ==")
    bbf = BitBoundFoldingEngine(db, cutoff=0.5, m=CHEMBL_LIKE.folding_m)
    ids, _ = bbf.search(queries, k)
    frac = bbf.scanned(len(queries)) / (len(queries) * len(db))
    print(f"   recall vs brute force: {recall_at_k(ids, true_ids):.3f}; "
          f"scanned {100 * frac:.1f}% of DB "
          f"(pruning speedup ~{1 / max(frac, 1e-9):.1f}x)")

    print("== HNSW approximate search (build on 8k subset) ==")
    hnsw = HNSWEngine(db[:8_000], m=CHEMBL_LIKE.hnsw_m,
                      ef_construction=CHEMBL_LIKE.hnsw_ef_construction,
                      ef_search=CHEMBL_LIKE.hnsw_ef_search)
    sub_truth, _ = BruteForceEngine(db[:8_000]).search(queries, k)
    ids, _ = hnsw.search(queries, k)
    print(f"   recall vs brute force: {recall_at_k(ids, sub_truth):.3f}; "
          f"~{hnsw.scanned(len(queries)) // len(queries)} distance evals/query "
          f"vs {8_000} for exhaustive")


if __name__ == "__main__":
    main()
