"""MutableFingerprintStore invariants: segment layout, LSM compaction,
folded-array consistency, capacity padding (ISSUE 3 tentpole)."""
import numpy as np
import pytest

from repro.core import folding as fl
from repro.serve.store import MutableFingerprintStore, PAD_COUNT, next_pow2
from repro.data.molecules import SyntheticConfig, synthetic_fingerprints


@pytest.fixture(scope="module")
def rows():
    return synthetic_fingerprints(SyntheticConfig(n=300, seed=0))


@pytest.fixture(scope="module")
def extra():
    return synthetic_fingerprints(SyntheticConfig(n=90, seed=8))


def _cnt(a):
    return np.bitwise_count(a).sum(-1).astype(np.int64)


def test_next_pow2():
    assert [next_pow2(n) for n in (0, 1, 2, 3, 4, 5, 1000)] == \
        [1, 1, 2, 4, 4, 8, 1024]


def test_sorted_main_invariants(rows):
    st = MutableFingerprintStore(rows, sorted_main=True, fold_m=2)
    seg = st.main
    n = seg.n
    assert n == 300 and seg.capacity == 512 == seg.db.shape[0]
    # valid counts ascending, pad counts sentinel (Eq.2 windows never reach)
    assert (np.diff(seg.counts[:n]) >= 0).all()
    assert (seg.counts[n:] == PAD_COUNT).all()
    assert (seg.db[n:] == 0).all() and (seg.order[n:] == -1).all()
    # order is a permutation reproducing the input rows
    assert sorted(seg.order[:n].tolist()) == list(range(n))
    np.testing.assert_array_equal(st.rows_in_gid_order(), rows)
    # stable sort: equal popcounts stay in ascending gid order
    eq = seg.counts[:n - 1] == seg.counts[1:n]
    assert (seg.order[:n - 1][eq] < seg.order[1:n][eq]).all()
    # folded arrays match a fold of the sorted rows
    np.testing.assert_array_equal(seg.folded[:n], fl.fold(seg.db[:n], 2, 1))
    np.testing.assert_array_equal(seg.folded_counts[:n],
                                  _cnt(seg.folded[:n]))


def test_unsorted_main_identity_order(rows):
    st = MutableFingerprintStore(rows, sorted_main=False, fold_m=1)
    n = st.main.n
    np.testing.assert_array_equal(st.main.db[:n], rows)
    np.testing.assert_array_equal(st.main.order[:n], np.arange(n))
    assert (st.main.counts[n:] == 0).all()     # brute pads score 0, lose ties


def test_insert_assigns_monotone_gids(rows, extra):
    st = MutableFingerprintStore(rows, compact_threshold=1000)
    g1 = st.insert(extra[:10])
    g2 = st.insert(extra[10:25])
    np.testing.assert_array_equal(g1, np.arange(300, 310))
    np.testing.assert_array_equal(g2, np.arange(310, 325))
    assert st.n_total == 325 and st.n_delta == 25 and st.n_main == 300
    np.testing.assert_array_equal(st.delta_db, extra[:25])
    np.testing.assert_array_equal(st.delta_counts, _cnt(extra[:25]))
    # folded delta maintained eagerly for stage-1 scans
    np.testing.assert_array_equal(st.delta_folded,
                                  fl.fold(extra[:25], st.fold_m, 1))


def test_threshold_triggers_compaction(rows, extra):
    st = MutableFingerprintStore(rows, fold_m=2, compact_threshold=40)
    st.insert(extra[:30])
    assert st.compactions == 0 and st.n_delta == 30 and st.generation == 0
    st.insert(extra[30:50])                     # 50 >= 40 -> compact
    assert st.compactions == 1 and st.generation == 1
    assert st.n_delta == 0 and st.n_main == 350 == st.n_total
    # the fresh main is exactly a from-scratch build on the concatenation
    ref = MutableFingerprintStore(np.concatenate([rows, extra[:50]]),
                                  fold_m=2)
    for f in ("db", "counts", "order", "folded", "folded_counts"):
        np.testing.assert_array_equal(getattr(st.main, f),
                                      getattr(ref.main, f), err_msg=f)
    # gids keep continuing after the compaction
    g = st.insert(extra[50:55])
    np.testing.assert_array_equal(g, np.arange(350, 355))
    np.testing.assert_array_equal(st.rows_in_gid_order(),
                                  np.concatenate([rows, extra[:55]]))


def test_capacity_padding_is_stable_across_compaction(rows, extra):
    """Compactions below the capacity keep array shapes — the property that
    lets device pipelines (keyed on shapes) survive compaction."""
    st = MutableFingerprintStore(rows, compact_threshold=10)
    shape0 = st.main.db.shape
    st.insert(extra[:10])                       # compacts at threshold
    assert st.compactions == 1
    assert st.main.db.shape == shape0 == (512, rows.shape[1])
    # ... and grows by doubling once the capacity is crossed
    st2 = MutableFingerprintStore(extra[:60], compact_threshold=8)
    assert st2.main.capacity == 64
    st2.insert(rows[:8])
    assert st2.main.capacity == 128


def test_delta_version_counters(rows, extra):
    st = MutableFingerprintStore(rows, compact_threshold=40)
    v0 = st.delta_version
    st.insert(extra[:5])
    assert st.delta_version == v0 + 1
    st.compact()
    assert st.generation == 1 and st.n_delta == 0


def test_width_mismatch_rejected(rows):
    st = MutableFingerprintStore(rows)
    with pytest.raises(ValueError, match="width"):
        st.insert(np.zeros((2, rows.shape[1] + 1), np.uint32))


# -- insert validation (ISSUE 7 satellite) ----------------------------------

def test_insert_rejects_wrong_width(rows):
    st = MutableFingerprintStore(rows)
    with pytest.raises(ValueError, match="width"):
        st.insert(np.ones((2, rows.shape[1] + 1), dtype=np.uint32))


def test_insert_rejects_float_rows(rows):
    st = MutableFingerprintStore(rows)
    with pytest.raises(ValueError, match="uint32"):
        st.insert(np.ones((2, rows.shape[1]), dtype=np.float32))


def test_insert_rejects_signed_and_python_ints(rows):
    st = MutableFingerprintStore(rows)
    with pytest.raises(ValueError, match="uint32"):
        st.insert(np.ones((1, rows.shape[1]), dtype=np.int64))
    with pytest.raises(ValueError, match="uint32"):
        st.insert([[1] * rows.shape[1]])       # python ints -> int64


def test_insert_rejects_bad_ndim(rows):
    st = MutableFingerprintStore(rows)
    with pytest.raises(ValueError, match="packed words"):
        st.insert(np.zeros((2, 2, rows.shape[1]), dtype=np.uint32))


def test_insert_accepts_narrower_unsigned(rows):
    # uint8/uint16 rows are losslessly castable packed words
    st = MutableFingerprintStore(rows)
    gids = st.insert(np.ones((2, rows.shape[1]), dtype=np.uint16))
    assert gids.tolist() == [len(rows), len(rows) + 1]
    assert st.delta_db.dtype == np.uint32
