"""Strong correctness checks: the decode (recurrent / cached) path must
reproduce the training (parallel) path token by token."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_arch


def _f32(cfg):
    return cfg.with_(dtype="float32")


def _teacher_force(cfg, params, tokens, enc_kv=None):
    b, s = tokens.shape
    caches = models.init_caches(cfg, b, s + 1)
    step = jax.jit(models.decode_step(cfg))
    outs = []
    for t in range(s):
        args = (params, caches, tokens[:, t:t + 1])
        logits, caches = step(*args, enc_kv) if enc_kv is not None else step(*args)
        outs.append(logits)
    return jnp.stack(outs, axis=1)    # (B, S, V)


def _train_logits(cfg, params, tokens, extra=None):
    """Forward pass logits via the training path."""
    from repro.models.transformer import (_run_stack, _norm, _mask_pad_vocab,
                                          _encode)
    from repro.models.layers import embed, unembed
    b, s = tokens.shape
    x = embed(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    enc_out = None
    if cfg.enc_dec:
        enc_out = _encode(params, cfg, extra["audio_embed"])
        x = x + params["dec_pos"]["pos"][:s]
    x, _ = _run_stack(params["units"], cfg, x, positions,
                      window=cfg.attn_window, enc_out=enc_out,
                      use_rope=cfg.family != "audio")
    x = _norm(cfg, params["final_norm"], x)
    return _mask_pad_vocab(cfg, unembed(params["embed"], x).astype(jnp.float32))


@pytest.mark.parametrize("arch", ["granite-3-2b", "qwen1.5-4b", "xlstm-350m",
                                  "jamba-v0.1-52b", "olmoe-1b-7b"])
def test_decode_matches_train_forward(arch):
    cfg = _f32(get_arch(arch).reduced())
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)
    params, _ = models.split(models.init_params(cfg, jax.random.key(0)))
    full = _train_logits(cfg, params, tokens)
    step = _teacher_force(cfg, params, tokens)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=2e-2, atol=2e-2)


def test_whisper_decode_matches_train():
    cfg = _f32(get_arch("whisper-medium").reduced())
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    audio = jnp.asarray(rng.normal(size=(2, cfg.n_audio_frames, cfg.d_model)),
                        jnp.float32)
    params, _ = models.split(models.init_params(cfg, jax.random.key(0)))
    from repro.models.transformer import _encode, build_enc_kv
    enc_out = _encode(params, cfg, audio)
    enc_kv = build_enc_kv(cfg, params, enc_out)
    full = _train_logits(cfg, params, tokens, {"audio_embed": audio})
    step = _teacher_force(cfg, params, tokens, enc_kv)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=2e-2, atol=2e-2)


def test_windowed_attention_matches_full_when_window_large():
    cfg = _f32(get_arch("granite-3-2b").reduced())
    cfg_win = cfg.with_(attn_window=64)     # window > seq: identical
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)
    params, _ = models.split(models.init_params(cfg, jax.random.key(0)))
    a = _train_logits(cfg, params, tokens)
    b = _train_logits(cfg_win, params, tokens)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
