"""Collection-safe fallback for ``hypothesis`` (given/settings/strategies).

The tier-1 property tests are written against hypothesis, but the suite must
*collect and run* in environments where hypothesis is not installed (this
container, the no-hypothesis CI leg). This module is a tiny stand-in with the
same decorator surface: strategies draw deterministic pseudo-random examples
from a per-test seeded RNG, so a failing example reproduces across runs.

It is intentionally NOT a shrinking property-based framework — it is a seeded
example sampler that keeps the same test bodies executable either way:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _propcheck import given, settings, strategies as st

Supported: ``st.integers``, ``st.floats``, ``st.lists``, ``st.tuples``,
``st.sampled_from``, ``st.booleans``, ``st.just``, ``st.data()``, plus
``.map`` / ``.flatmap`` / ``.filter`` on any strategy.
"""
from __future__ import annotations

import os
import random
import zlib

# Number of examples per test defaults to the test's @settings(max_examples=N)
# capped at PROPCHECK_MAX_EXAMPLES (the shim has no shrinker, so huge example
# counts buy little; keep the no-hypothesis leg fast).
_MAX_EXAMPLES_CAP = int(os.environ.get("PROPCHECK_MAX_EXAMPLES", "12"))
_DEFAULT_EXAMPLES = 10


class SearchStrategy:
    """A deterministic sampler: ``_draw(rng) -> value``."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)

    def map(self, fn):
        return SearchStrategy(lambda rng: fn(self._draw(rng)))

    def flatmap(self, fn):
        return SearchStrategy(lambda rng: fn(self._draw(rng)).example(rng))

    def filter(self, pred, max_tries: int = 100):
        def draw(rng):
            for _ in range(max_tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("propcheck filter: no satisfying example found")
        return SearchStrategy(draw)


class DataObject:
    """Interactive draw handle for ``st.data()`` tests."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: SearchStrategy, label: str | None = None):
        return strategy.example(self._rng)


class _DataStrategy(SearchStrategy):
    def __init__(self):
        super().__init__(lambda rng: DataObject(rng))


class strategies:
    """Namespace mirror of ``hypothesis.strategies``."""

    SearchStrategy = SearchStrategy

    @staticmethod
    def integers(min_value=None, max_value=None):
        lo = -(2**31) if min_value is None else min_value
        hi = 2**31 - 1 if max_value is None else max_value

        def draw(rng):
            # bias towards boundaries, where off-by-ones live
            r = rng.random()
            if r < 0.08:
                return lo
            if r < 0.16:
                return hi
            return rng.randint(lo, hi)
        return SearchStrategy(draw)

    @staticmethod
    def floats(min_value=None, max_value=None, allow_nan=True,
               allow_infinity=None, width=64):
        lo = -1e9 if min_value is None else float(min_value)
        hi = 1e9 if max_value is None else float(max_value)

        def draw(rng):
            r = rng.random()
            if r < 0.06:
                v = lo
            elif r < 0.12:
                v = hi
            elif r < 0.2:
                v = 0.0 if lo <= 0.0 <= hi else lo
            else:
                v = rng.uniform(lo, hi)
            if width == 32:
                import numpy as np
                v = float(np.float32(v))
            return v
        return SearchStrategy(draw)

    @staticmethod
    def booleans():
        return SearchStrategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def just(value):
        return SearchStrategy(lambda rng: value)

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return SearchStrategy(lambda rng: elements[rng.randrange(len(elements))])

    @staticmethod
    def lists(elements: SearchStrategy, min_size=0, max_size=None):
        hi = (min_size + 20) if max_size is None else max_size

        def draw(rng):
            n = rng.randint(min_size, hi)
            return [elements.example(rng) for _ in range(n)]
        return SearchStrategy(draw)

    @staticmethod
    def tuples(*strats: SearchStrategy):
        return SearchStrategy(lambda rng: tuple(s.example(rng) for s in strats))

    @staticmethod
    def data():
        return _DataStrategy()


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    """Attach example-count settings; other hypothesis knobs are ignored."""
    def deco(fn):
        fn._propcheck_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*strats: SearchStrategy):
    """Run the test body over deterministically sampled examples.

    The wrapper takes no parameters (drawn values fill the test's signature),
    so pytest does not mistake strategy-bound argument names for fixtures.
    """
    def deco(fn):
        def wrapper():
            cfg = (getattr(wrapper, "_propcheck_settings", None)
                   or getattr(fn, "_propcheck_settings", {}))
            n = min(cfg.get("max_examples", _DEFAULT_EXAMPLES), _MAX_EXAMPLES_CAP)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                example = [s.example(rng) for s in strats]
                try:
                    fn(*example)
                except Exception as e:  # surface the failing example
                    shown = [x if not isinstance(x, DataObject) else "<data>"
                             for x in example]
                    raise AssertionError(
                        f"propcheck example {i}/{n} failed for {fn.__name__}: "
                        f"args={shown!r}") from e

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
