"""Folding (modulo-OR compression) properties + two-stage search accuracy."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # collection-safe fallback (see tests/_propcheck.py)
    from _propcheck import given, settings, strategies as st

from repro.core import folding as fl
from repro.core import pack_bits, unpack_bits


@given(st.integers(0, 2**32 - 1), st.sampled_from([1, 2, 4, 8]),
       st.sampled_from([1, 2]))
@settings(max_examples=40, deadline=None)
def test_fold_is_or_of_sections(seed, m, scheme):
    rng = np.random.default_rng(seed)
    bits = (rng.random((8, 1024)) < 0.1).astype(np.uint8)
    packed = pack_bits(bits)
    folded = fl.fold(packed, m, scheme)
    fb = unpack_bits(folded)
    L = 1024
    if scheme == 1:
        expect = bits.reshape(8, m, L // m).max(axis=1)
    else:
        expect = bits.reshape(8, L // m, m).max(axis=2)
    np.testing.assert_array_equal(fb, expect)


@given(st.integers(0, 2**32 - 1), st.sampled_from([2, 4, 8]))
@settings(max_examples=20, deadline=None)
def test_folded_popcount_never_increases(seed, m):
    rng = np.random.default_rng(seed)
    bits = (rng.random((16, 1024)) < 0.08).astype(np.uint8)
    packed = pack_bits(bits)
    for scheme in (1, 2):
        folded = fl.fold(packed, m, scheme)
        assert (np.bitwise_count(folded).sum(-1)
                <= np.bitwise_count(packed).sum(-1)).all()


def test_scheme1_jax_matches_numpy(small_db):
    for m in (2, 4, 8):
        a = fl.fold_scheme1(small_db, m)
        b = np.asarray(fl.fold_scheme1_jax(jnp.asarray(small_db), m))
        np.testing.assert_array_equal(a, b)


def test_kr1_formula():
    # paper: k_r1 = k*m*log2(2m) — Table I column
    assert fl.kr1_for(20, 1) == 20
    assert fl.kr1_for(20, 2) == 20 * 4
    assert fl.kr1_for(20, 4) == 20 * 12
    assert fl.kr1_for(20, 8) == 20 * 32
    assert fl.kr1_for(20, 16) == 20 * 80


def test_folding_schemes_equivalent_on_uniform_bits(small_db, queries,
                                                     brute_truth):
    """On hash-uniform bits the two OR-folding schemes are statistically
    equivalent (the paper's scheme-1 > scheme-2 gap needs RDKit's real bit
    layout — documented data-fidelity gap, EXPERIMENTS.md §Table I). Both
    must stay accurate at m=8 thanks to the two-stage k_r1 rescore."""
    from repro.core import BitBoundFoldingEngine, recall_at_k
    _, true_ids = brute_truth
    rec = {}
    for scheme in (1, 2):
        eng = BitBoundFoldingEngine(small_db, cutoff=0.0, m=8, scheme=scheme)
        ids, _ = eng.search(queries, 20)
        rec[scheme] = recall_at_k(ids, true_ids)
    assert abs(rec[1] - rec[2]) < 0.08, rec
    assert rec[1] > 0.9 and rec[2] > 0.9, rec
