"""Concurrent serving tier (ISSUE 9): deterministic-core parity, admission
control + shedding, deadlines, graceful degradation, replica fan-out and
failover, and warm restart of a front-end-owned durable directory."""
import time

import numpy as np
import pytest

from repro.data.molecules import (SyntheticConfig, queries_from_db,
                                  synthetic_fingerprints)
from repro.serve import (DeadlineExceeded, FrontendConfig, Overloaded,
                         SearchFrontend, SearchService, ServiceConfig,
                         Unavailable)

ENGINES = ("brute", "bitbound-folding", "hnsw")
SVC_KW = dict(compact_threshold=64, hnsw_m=4, hnsw_ef_construction=12,
              hnsw_ef_search=16, cutoff=0.4, fold_m=2)

#: no shedding, no deadline pressure — the parity configuration
CALM = dict(high_water=10_000, default_deadline_ms=None,
            flush_interval_ms=0.5)


@pytest.fixture(scope="module")
def data():
    db = synthetic_fingerprints(SyntheticConfig(n=400, seed=0))
    extra = synthetic_fingerprints(SyntheticConfig(n=90, seed=5))
    q = queries_from_db(db, 10, seed=2)
    return db, extra, q


def _wait(cond, timeout=20.0, msg="condition"):
    t0 = time.time()
    while not cond():
        if time.time() - t0 > timeout:
            raise AssertionError(f"timed out waiting for {msg}")
        time.sleep(0.02)


# -- deterministic-core parity (the ISSUE 9 correctness anchor) --------------

def test_single_replica_parity_all_engines(data):
    """Front end with 1 replica, shedding disabled, no deadlines: ids AND
    sims bit-identical to the direct synchronous SearchService path, across
    all three engines, interleaved with inserts."""
    db, extra, q = data
    ref = SearchService(db, engines=ENGINES, **SVC_KW)
    fe = SearchFrontend(db, engines=ENGINES,
                        frontend=FrontendConfig(replicas=1, **CALM),
                        **SVC_KW)
    try:
        for e in ENGINES:
            got = fe.search(q, 6, engine=e)
            want = ref.search(q, 6, engine=e)
            np.testing.assert_array_equal(got[0], want[0], err_msg=e)
            np.testing.assert_array_equal(got[1], want[1], err_msg=e)
        # interleave inserts and re-check (delta path + HNSW graph inserts)
        for lo in range(0, len(extra), 30):
            batch = extra[lo:lo + 30]
            np.testing.assert_array_equal(fe.insert(batch),
                                          ref.insert(batch))
            for e in ENGINES:
                got = fe.search(q, 6, engine=e)
                want = ref.search(q, 6, engine=e)
                np.testing.assert_array_equal(got[0], want[0], err_msg=e)
                np.testing.assert_array_equal(got[1], want[1], err_msg=e)
        assert fe.shed_count == 0 and fe.expired_count == 0
        assert fe.degradation_level == 0
    finally:
        fe.close()


def test_replicas_serve_identical_results(data):
    """Every replica answers any query identically — load balancing is
    invisible to results."""
    db, extra, q = data
    fe = SearchFrontend(db, engines=("bitbound-folding",),
                        frontend=FrontendConfig(replicas=3, **CALM),
                        **SVC_KW)
    try:
        fe.insert(extra[:40])
        ref = fe.search(q, 6)
        # many rounds so the balancer exercises different replicas
        for _ in range(12):
            got = fe.search(q, 6)
            np.testing.assert_array_equal(got[0], ref[0])
            np.testing.assert_array_equal(got[1], ref[1])
    finally:
        fe.close()


# -- admission control, deadlines, degradation -------------------------------

def test_overload_sheds_typed_and_bounded(data):
    db, _, q = data
    fe = SearchFrontend(db, engines=("brute",),
                        frontend=FrontendConfig(
                            replicas=1, high_water=4,
                            default_deadline_ms=None,
                            flush_interval_ms=1.0),
                        **SVC_KW)
    try:
        futs, shed = [], 0
        for i in range(200):
            try:
                futs.append(fe.submit(q[i % len(q)], 4))
            except Overloaded:
                shed += 1
        assert shed > 0, "200 instant submits never hit high_water=4"
        # bounded admission: never more in flight than the high-water mark
        assert len(futs) <= 4 or fe.summary()["shed"] == shed
        for f in futs:
            f.result(timeout=30)
        fe.drain(30)
        s = fe.summary()
        assert s["shed"] == shed
        assert s["n_completed"] == len(futs)
    finally:
        fe.close()


def test_expired_requests_dropped_before_scoring(data):
    db, _, q = data
    fe = SearchFrontend(db, engines=("brute",),
                        frontend=FrontendConfig(
                            replicas=1, high_water=1000,
                            flush_interval_ms=30.0),
                        **SVC_KW)
    try:
        fe.search(q[:1], 4, deadline_ms=None)     # warm the compile cache
        queries_before = fe.replicas[0].svc.n_queries
        f = fe.submit(q[0], 4, deadline_ms=0.001)  # expires immediately
        with pytest.raises(DeadlineExceeded):
            f.result(timeout=30)
        fe.drain(30)
        assert fe.expired_count == 1
        # dropped pre-dispatch: the engine never scored it
        assert fe.replicas[0].svc.n_queries == queries_before
        fam = fe.metrics.family("frontend_deadline_expired_total")
        assert fam.total() == 1
    finally:
        fe.close()


def test_degradation_ladder_engages_and_recovers(data):
    db, _, q = data
    fe = SearchFrontend(db, engines=("hnsw",),
                        frontend=FrontendConfig(
                            replicas=1, high_water=4,
                            default_deadline_ms=None,
                            flush_interval_ms=1.0,
                            degrade_ticks=2, degrade_high=0.5,
                            degrade_low=0.1),
                        **SVC_KW)
    try:
        eng = fe.replicas[0].svc.engines["hnsw"]
        ef0, beam0 = int(eng.ef_search), int(eng.beam)
        futs = []
        t0 = time.time()
        while fe.max_level_engaged == 0 and time.time() - t0 < 20:
            try:
                futs.append(fe.submit(q[0], 4))
            except Overloaded:
                pass
        assert fe.max_level_engaged >= 1, "sustained shedding never stepped"
        assert fe.metrics.family(
            "frontend_degradation_shifts_total").value(direction="down") >= 1
        for f in futs:
            f.result(timeout=60)
        fe.drain(60)
        # recovery: idle load steps the ladder back to full quality
        _wait(lambda: fe.degradation_level == 0, timeout=20,
              msg="ladder step-up")
        # level 0 restores the exact configured knobs (scales, not deltas)
        fe.search(q[:1], 4)
        fe.drain(30)
        assert (int(eng.ef_search), int(eng.beam)) == (ef0, beam0)
        assert fe.metrics.family(
            "frontend_degradation_shifts_total").value(direction="up") >= 1
    finally:
        fe.close()


def test_ladder_level_zero_must_be_identity():
    from repro.serve.frontend import DegradeLevel
    with pytest.raises(ValueError, match="identity"):
        FrontendConfig(ladder=(DegradeLevel("broken", k_scale=0.5),))


# -- replicas + failover ------------------------------------------------------

@pytest.mark.parametrize("durable", [False, True])
def test_replica_failover_rehydrates_byte_identical(data, tmp_path, durable):
    """Kill one of two replicas mid-stream: no acked insert lost, queries
    keep serving, and the re-hydrated replica is byte-identical to the
    survivor (post-compaction normalization)."""
    db, extra, q = data
    cfg = dict(SVC_KW)
    if durable:
        cfg["durable_dir"] = str(tmp_path / "fe")
    fe = SearchFrontend(db, engines=("bitbound-folding", "hnsw"),
                        frontend=FrontendConfig(replicas=2, **CALM), **cfg)
    try:
        gids = fe.insert(extra[:30])
        assert list(gids) == list(range(len(db), len(db) + 30))
        ref = fe.search(q, 6)
        fe.kill_replica(0)
        # still serving from the survivor while slot 0 rehydrates
        got = fe.search(q, 6)
        np.testing.assert_array_equal(got[0], ref[0])
        _wait(lambda: fe.live_replicas() == 2, msg="rehydration")
        assert fe.replicas[0].generation == 1
        # acked inserts fan to the rebuilt replica too
        fe.insert(extra[30:60])
        a0, m0 = fe.replica_state(0)
        a1, m1 = fe.replica_state(1)
        assert m0 == m1
        assert sorted(a0) == sorted(a1)
        for k in a0:
            assert a0[k].tobytes() == a1[k].tobytes(), \
                f"{k}: rehydrated replica diverged from survivor"
        assert fe.summary()["failovers"] == 1
        assert fe.metrics.family("frontend_replica_live").value(
            replica=0) == 1
    finally:
        fe.close()


def test_wedged_replica_detected_and_failed_over(data):
    """A worker stuck inside a task past health_timeout_s is marked dead by
    the monitor and its queued work re-dispatched to the survivor."""
    db, _, q = data
    fe = SearchFrontend(db, engines=("brute",),
                        frontend=FrontendConfig(
                            replicas=2, health_timeout_s=1.0,
                            rehydrate=False, **CALM),
                        **SVC_KW)
    gate = {"blocked": True}   # bound before try: the finally reads it
    try:
        # warm the compile caches so a first-call compile on the healthy
        # replica cannot trip the wedge detector
        for _ in range(4):
            fe.search(q, 6, timeout=60)

        def wedge(svc):
            while gate["blocked"]:
                time.sleep(0.01)

        fe.replicas[0].call(wedge, label="wedge")
        _wait(lambda: fe.live_replicas() == 1, msg="wedge detection")
        got = fe.search(q, 6, timeout=30)     # survivor still serves
        assert got[0].shape == (len(q), 6)
        assert fe.summary()["failovers"] == 1
        gate["blocked"] = False
    finally:
        gate["blocked"] = False
        fe.close()


def test_insert_unavailable_when_all_dead(data):
    db, extra, _ = data
    fe = SearchFrontend(db, engines=("brute",),
                        frontend=FrontendConfig(replicas=1, rehydrate=False,
                                                **CALM), **SVC_KW)
    try:
        fe.kill_replica(0)
        with pytest.raises(Unavailable):
            fe.insert(extra[:5])
    finally:
        fe.close()


# -- durable warm restart -----------------------------------------------------

def test_frontend_open_round_trip(data, tmp_path):
    """Front-end durable dir round-trips through SearchFrontend.open AND
    plain SearchService.open (one on-disk format)."""
    db, extra, q = data
    d = tmp_path / "fe"
    fe = SearchFrontend(db, engines=("bitbound-folding",),
                        frontend=FrontendConfig(replicas=2, **CALM),
                        durable_dir=str(d), **SVC_KW)
    fe.insert(extra[:40])
    fe.snapshot()
    fe.insert(extra[40:70])                   # WAL tail past the snapshot
    ref = fe.search(q, 6)
    fe.close()

    fe2 = SearchFrontend.open(d, frontend=FrontendConfig(replicas=2, **CALM))
    try:
        assert fe2.n_total == len(db) + 70
        got = fe2.search(q, 6)
        np.testing.assert_array_equal(got[0], ref[0])
        np.testing.assert_array_equal(got[1], ref[1])
        a0, _ = fe2.replica_state(0)
        a1, _ = fe2.replica_state(1)
        for k in a0:
            assert a0[k].tobytes() == a1[k].tobytes(), k
    finally:
        fe2.close()

    svc = SearchService.open(d)
    try:
        assert svc.n_total == len(db) + 70
        got = svc.search(q, 6)
        np.testing.assert_array_equal(got[0], ref[0])
    finally:
        svc.close()


def test_frontend_refuses_existing_dir_without_open(data, tmp_path):
    db, _, _ = data
    d = tmp_path / "fe"
    fe = SearchFrontend(db, engines=("brute",), durable_dir=str(d), **SVC_KW)
    fe.close()
    with pytest.raises(ValueError, match="open"):
        SearchFrontend(db, engines=("brute",), durable_dir=str(d), **SVC_KW)


# -- lifecycle ----------------------------------------------------------------

def test_frontend_close_idempotent_and_rejects_after(data):
    db, _, q = data
    fe = SearchFrontend(db, engines=("brute",), **SVC_KW)
    fe.search(q[:1], 4)
    fe.close()
    fe.close()                                # second close is a no-op
    with pytest.raises(RuntimeError, match="closed"):
        fe.submit(q[0], 4)
    with pytest.raises(RuntimeError, match="closed"):
        fe.insert(db[:1])


def test_export_metrics_merges_frontend_and_replicas(data, tmp_path):
    import json
    db, _, q = data
    fe = SearchFrontend(db, engines=("brute",),
                        frontend=FrontendConfig(replicas=2, **CALM),
                        **SVC_KW)
    try:
        fe.search(q, 4)
        p = tmp_path / "m.jsonl"
        n = fe.export_metrics(p, ts=1.0)
        rows = [json.loads(line) for line in open(p)]
        assert len(rows) == n
        names = {r["name"] for r in rows}
        assert {"frontend_queue_depth", "frontend_inflight",
                "frontend_request_latency_ms",
                "service_queries_total"} <= names
        # replica rows carry the replica label; frontend rows don't
        svc_rows = [r for r in rows if r["name"].startswith("service_")]
        assert svc_rows and all("replica" in r["labels"] for r in svc_rows)
        assert (tmp_path / "m.jsonl.prom").exists()
    finally:
        fe.close()
