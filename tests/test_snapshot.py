"""Snapshot serialization round-trips (ISSUE 6 tentpole, snapshot half).

The core contract: for any engine × backend × layout × shards and any
interleaving of inserts/compactions, serializing the search state and
restoring it yields **byte-equal** extracted state — and *continuing* to
insert into the restored replica tracks the live engine exactly (the HNSW
level-stream rng and the store counters survive the round-trip).
"""
import tempfile

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _propcheck import given, settings, strategies as st

from repro.checkpoint.manager import (load_array_snapshot,
                                      load_latest_intact,
                                      save_array_snapshot, snapshot_steps)
from repro.core import BitBoundFoldingEngine, BruteForceEngine, HNSWEngine
from repro.data.molecules import (SyntheticConfig, queries_from_db,
                                  synthetic_fingerprints)
from repro.serve import SearchService, snapshot as snap

POOL = synthetic_fingerprints(SyntheticConfig(n=420, seed=0))
BASE = POOL[:140]
EXTRA = POOL[140:]
QUERIES = queries_from_db(POOL, 6, seed=4)

# engine-kind × backend × layout × shards grid the property sweep samples
# from ("tpu" rides the interpret-mode Pallas path — covered by the
# service-level tests below to keep the sweep's compile count at zero)
CASES = [
    ("brute", "jnp", None, None),
    ("bitbound", "numpy", None, None),
    ("bitbound", "jnp", None, None),
    ("hnsw", "numpy", "rows", None),
    ("hnsw", "jnp", "rows", None),
    ("hnsw", "jnp", "blocked", None),
    ("hnsw", "numpy", "rows", 2),
    ("hnsw", "jnp", "blocked", 2),
]


def _mk_engine(kind, backend, layout, shards, db):
    if kind == "brute":
        return BruteForceEngine(db, backend=backend, compact_threshold=24)
    if kind == "bitbound":
        return BitBoundFoldingEngine(db, cutoff=0.3, m=2, backend=backend,
                                     compact_threshold=24)
    return HNSWEngine(db, m=4, ef_construction=12, ef_search=16, seed=3,
                      backend=backend, layout=layout, shards=shards)


def _restore_kwargs(kind, backend, layout):
    if kind == "brute":
        return dict(backend=backend, compact_threshold=24)
    if kind == "bitbound":
        return dict(cutoff=0.3, m=2, backend=backend, compact_threshold=24)
    return dict(m=4, ef_construction=12, ef_search=16, seed=3,
                backend=backend, layout=layout)


def _assert_state_equal(e_live, e_restored, label=""):
    a1, m1 = snap.engine_state(e_live)
    a2, m2 = snap.engine_state(e_restored)
    assert m1 == m2, f"{label}: meta diverged"
    assert sorted(a1) == sorted(a2), f"{label}: array names diverged"
    for k in a1:
        assert a1[k].dtype == a2[k].dtype, f"{label}/{k}: dtype"
        assert a1[k].shape == a2[k].shape, f"{label}/{k}: shape"
        assert a1[k].tobytes() == a2[k].tobytes(), f"{label}/{k}: bytes"


def _roundtrip_via_disk(engine):
    arrays, meta = snap.engine_state(engine)
    with tempfile.TemporaryDirectory() as d:
        save_array_snapshot(d, 0, arrays, {"engine": meta})
        loaded, lmeta = load_array_snapshot(d, 0)
    return loaded, lmeta["engine"]


@settings(max_examples=12, deadline=None)
@given(st.sampled_from(CASES),
       st.lists(st.tuples(st.sampled_from(["insert", "compact"]),
                          st.integers(min_value=1, max_value=9)),
                min_size=0, max_size=6),
       st.integers(min_value=0, max_value=200))
def test_snapshot_roundtrip_interleavings(case, ops, off):
    """Random insert/compact/snapshot interleavings: restored state is
    byte-equal to live state, and inserting *after* the restore tracks the
    live engine exactly (rng-stream + counter continuation)."""
    kind, backend, layout, shards = case
    eng = _mk_engine(kind, backend, layout, shards, BASE)
    pos = off % (len(EXTRA) - 64)
    for op, size in ops:
        if op == "insert":
            eng.insert(EXTRA[pos:pos + size])
            pos += size
        elif getattr(eng, "store", None) is not None and eng.store.n_delta:
            eng.store.compact()
    arrays, meta = _roundtrip_via_disk(eng)
    restored = snap.engine_from_state(arrays, meta,
                                      **_restore_kwargs(kind, backend,
                                                        layout))
    label = f"{kind}/{backend}/{layout}/shards={shards}"
    _assert_state_equal(eng, restored, label)
    # continuation: both sides take the same two extra batches
    for a, b in ((pos, pos + 5), (pos + 5, pos + 12)):
        eng.insert(EXTRA[a:b])
        restored.insert(EXTRA[a:b])
    _assert_state_equal(eng, restored, label + " after continuation")
    if backend == "numpy":        # host path: search parity is compile-free
        ids1, sims1 = eng.search(QUERIES, 8)
        ids2, sims2 = restored.search(QUERIES, 8)
        np.testing.assert_array_equal(ids1, ids2, err_msg=label)
        np.testing.assert_array_equal(sims1, sims2, err_msg=label)


@pytest.mark.parametrize("engines,backend,shards", [
    (("brute", "bitbound-folding", "hnsw"), None, None),
    (("bitbound-folding",), "tpu", None),
    (("hnsw",), "jnp", 2),
])
def test_service_snapshot_restore_search_parity(tmp_path, engines, backend,
                                                shards):
    """SearchService.open hydrates a replica whose results are bit-identical
    to the live service and to a never-crashed rebuild — including sharded
    HNSW graphs re-committed to their devices and the tpu kernel path."""
    d = tmp_path / "svc"
    svc = SearchService(BASE, engines=engines, durable_dir=str(d),
                        backend=backend, compact_threshold=20,
                        hnsw_m=4, hnsw_ef_construction=12, hnsw_ef_search=16,
                        hnsw_shards=shards)
    for i in range(0, 42, 6):
        svc.insert(EXTRA[i:i + 6])
    svc.snapshot()
    svc.insert(EXTRA[42:50])                    # WAL tail past the snapshot
    live = {e: svc.search(QUERIES, 8, engine=e) for e in engines}
    svc.close()

    svc2 = SearchService.open(d)
    reb = SearchService(np.concatenate([BASE, EXTRA[:50]]), engines=engines,
                        backend=backend, compact_threshold=20, hnsw_m=4,
                        hnsw_ef_construction=12, hnsw_ef_search=16,
                        hnsw_shards=shards)
    for e in engines:
        got = svc2.search(QUERIES, 8, engine=e)
        ref = reb.search(QUERIES, 8, engine=e)
        np.testing.assert_array_equal(live[e][0], got[0], err_msg=e)
        np.testing.assert_array_equal(live[e][1], got[1], err_msg=e)
        np.testing.assert_array_equal(ref[0], got[0], err_msg=e)
        np.testing.assert_array_equal(ref[1], got[1], err_msg=e)
    # restored replica keeps inserting in lockstep with the rebuild
    svc2.insert(EXTRA[50:58])
    reb.insert(EXTRA[50:58])
    for e in engines:
        got = svc2.search(QUERIES, 8, engine=e)
        ref = reb.search(QUERIES, 8, engine=e)
        np.testing.assert_array_equal(ref[0], got[0], err_msg=e)
        np.testing.assert_array_equal(ref[1], got[1], err_msg=e)
    svc2.close()


def test_snapshot_retention_and_walkback(tmp_path):
    svc = SearchService(BASE, engines=("brute",), durable_dir=str(tmp_path),
                        compact_threshold=1000, snapshot_keep=2)
    for i in range(4):
        svc.insert(EXTRA[i * 4:(i + 1) * 4])
        svc.snapshot()
    svc.close()
    steps = snapshot_steps(tmp_path / "snapshots")
    assert len(steps) == 2                       # retention honoured
    # corrupt the newest generation: open() must walk back to the previous
    newest = tmp_path / "snapshots" / f"snap_{steps[-1]:08d}"
    victim = sorted(newest.glob("arr_*.npy"))[0]
    victim.write_bytes(victim.read_bytes()[:-7])
    svc2 = SearchService.open(tmp_path)
    # the walk-back snapshot plus the WAL tail still recovers everything
    assert svc2.engines["brute"].n_total == len(BASE) + 16
    svc2.close()


def test_fresh_service_refuses_existing_durable_dir(tmp_path):
    svc = SearchService(BASE[:16], engines=("brute",),
                        durable_dir=str(tmp_path))
    svc.close()
    with pytest.raises(ValueError, match="open"):
        SearchService(BASE[:16], engines=("brute",),
                      durable_dir=str(tmp_path))


def test_open_missing_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        SearchService.open(tmp_path / "void")


def test_load_latest_intact_skips_partial(tmp_path):
    save_array_snapshot(tmp_path, 0, {"x": np.arange(5)}, {"v": 1})
    save_array_snapshot(tmp_path, 1, {"x": np.arange(9)}, {"v": 2})
    (tmp_path / "snap_00000001" / "manifest.json").unlink()
    step, arrays, meta = load_latest_intact(tmp_path)
    assert step == 0 and meta == {"v": 1}
    np.testing.assert_array_equal(arrays["x"], np.arange(5))


# -- background snapshots (ISSUE 7 satellite) -------------------------------

def test_background_snapshot_inserts_keep_acking(tmp_path):
    """Inserts must keep acking while a snapshot writer is parked mid-write:
    the state is handed off as copy-on-write arrays on the serving thread
    and the serialization + fsync runs on a daemon thread."""
    import threading

    from repro.checkpoint.fs import Fs

    gate = threading.Event()
    entered = threading.Event()

    class GateFs(Fs):
        armed = False

        def open(self, path, mode="wb"):
            if self.armed and "snap_" in str(path):
                entered.set()
                assert gate.wait(timeout=30), "test gate never released"
            return super().open(path, mode)

    fs = GateFs()
    svc = SearchService(BASE, engines=("brute",), durable_dir=str(tmp_path),
                        compact_threshold=10_000, fs=fs)
    fs.armed = True
    svc.snapshot(background=True)
    assert entered.wait(timeout=30), "background writer never started"
    # writer is blocked inside the gated open(); the serving thread acks
    gids = []
    for i in range(6):
        gids.extend(svc.insert(EXTRA[i * 3:(i + 1) * 3]).tolist())
    assert svc._snap_thread is not None and svc._snap_thread.is_alive()
    assert gids == list(range(len(BASE), len(BASE) + 18))
    gate.set()
    svc.snapshot_join()
    svc.close()
    # every insert acked during the in-flight snapshot is recoverable
    svc2 = SearchService.open(tmp_path)
    assert svc2.engines["brute"].n_total == len(BASE) + 18
    svc2.close()


def test_background_snapshot_error_surfaces_at_join(tmp_path):
    from repro.checkpoint.fs import Fs

    class BoomFs(Fs):
        armed = False

        def open(self, path, mode="wb"):
            if self.armed and "snap_" in str(path):
                raise IOError("boom")
            return super().open(path, mode)

    fs = BoomFs()
    svc = SearchService(BASE[:32], engines=("brute",),
                        durable_dir=str(tmp_path), fs=fs)
    fs.armed = True
    svc.snapshot(background=True)
    with pytest.raises(IOError, match="boom"):
        svc.snapshot_join()
    svc.close()                               # error already consumed


def test_snapshot_carries_metric_and_refuses_mismatch(tmp_path):
    """Snapshot meta records the similarity metric and fingerprint width;
    a reopen inherits them, and a reopen *overriding* the metric must be
    refused — scores, BitBound windows and HNSW graphs are metric-specific.
    """
    from repro.core.fingerprints import resolve_metric

    svc = SearchService(BASE, engines=("brute", "bitbound-folding", "hnsw"),
                        durable_dir=str(tmp_path), metric="dice",
                        fp_bits=1024, hnsw_m=4, hnsw_ef_construction=12,
                        hnsw_ef_search=16)
    svc.insert(EXTRA[:8])
    svc.snapshot()
    live = svc.search(QUERIES, 8, engine="bitbound-folding")
    svc.close()

    meta = load_latest_intact(str(tmp_path / "snapshots"))[2]
    assert resolve_metric(meta["config"]["metric"]).name == "dice"
    assert int(meta["config"]["fp_bits"]) == 1024

    svc2 = SearchService.open(tmp_path)        # inherits dice from the meta
    assert resolve_metric(svc2.config.metric).name == "dice"
    got = svc2.search(QUERIES, 8, engine="bitbound-folding")
    np.testing.assert_array_equal(np.asarray(live[0]), np.asarray(got[0]))
    np.testing.assert_array_equal(np.asarray(live[1]), np.asarray(got[1]))
    svc2.close()

    with pytest.raises(ValueError, match="metric"):
        SearchService.open(tmp_path, metric="cosine")
    # explicitly restating the persisted metric is fine
    svc3 = SearchService.open(tmp_path, metric="dice")
    svc3.close()


def test_hnsw_extraction_never_aliases_live_arrays():
    """COW contract behind background snapshots: extracted arrays must be
    private copies, never views of the live (still-mutating) state."""
    eng = HNSWEngine(POOL[:80])
    arrays, _ = snap.hnsw_index_state(eng.index)
    for name, a in arrays.items():
        assert not np.shares_memory(a, eng.index.db), name
        assert not np.shares_memory(a, eng.index.base_adj), name
