"""Service-level observability (ISSUE 8): the SearchService registry is
populated consistently with the legacy telemetry (summary() keys stable),
write-only runs report explicit null percentiles, the recent-window views
are bounded under sustained load, tiered residency populates the per-stage
stream gauges/spans (and device residency does not), and a captured tiered
trace shows the double-buffer overlap — a chunk's host->HBM transfer span
concurrent with the previous chunk's compute span."""
import numpy as np
import pytest

from repro.data.molecules import (SyntheticConfig, queries_from_db,
                                  synthetic_fingerprints)
from repro.obs.schema import validate_trace
from repro.obs.trace import TRACER
from repro.serve import SearchService

K = 8


@pytest.fixture(scope="module")
def data():
    db = synthetic_fingerprints(SyntheticConfig(n=2000, seed=0))
    extra = synthetic_fingerprints(SyntheticConfig(n=64, seed=5))
    q = queries_from_db(db, 16, seed=2)
    return db, extra, q


@pytest.fixture(autouse=True)
def _tracer_off():
    """Every test starts and ends with the process-wide tracer disabled and
    empty (it is a module-level singleton)."""
    TRACER.configure(enabled=False)
    TRACER.clear()
    yield
    TRACER.configure(enabled=False)
    TRACER.clear()


def test_registry_matches_legacy_telemetry(data):
    db, extra, q = data
    svc = SearchService(db, engines=("brute",), backend="jnp", k=K)
    svc.insert(extra[:8])
    for i in range(6):
        svc.submit(q[i], engine="brute")
    svc.flush()
    svc.submit(q[6:10], engine="brute")
    svc.flush()
    m = svc.metrics
    assert m.family("service_queries_total").value(engine="brute") \
        == svc.n_queries == 10
    assert m.family("service_inserts_total").value() == svc.n_inserts == 8
    # scanned attribution: registry counter == Counter view == engine
    # contract (scanned-per-batch summed over the flush buckets)
    assert m.family("service_scanned_total").value(engine="brute") \
        == svc.scanned_total["brute"]
    assert m.family("service_request_latency_ms").count() \
        == len(svc.latencies_ms) == 7      # 7 requests, 10 query rows
    # batch buckets: one 8-bucket flush + one 4-bucket flush
    assert m.family("service_batches_total").value(engine="brute",
                                                   bucket="8") == 1
    assert m.family("service_batches_total").value(engine="brute",
                                                   bucket="4") == 1
    s = svc.summary()
    assert s["batch_buckets"] == {8: 1, 4: 1}
    assert s["p50_ms"] > 0 and s["p99_ms"] >= s["p50_ms"]
    assert s["mean_ms"] > 0
    # reset_telemetry zeroes values but keeps the family declarations
    svc.reset_telemetry()
    assert m.family("service_queries_total").total() == 0
    assert svc.summary()["p50_ms"] is None


def test_write_only_run_reports_null_percentiles(data):
    db, extra, _ = data
    svc = SearchService(db, engines=("brute",), backend="jnp")
    svc.insert(extra[:4])
    s = svc.summary()
    assert s["n_queries"] == 0 and s["n_inserts"] == 4
    # keys present with explicit nulls — not missing, not 0.0
    assert s["p50_ms"] is None and s["p99_ms"] is None \
        and s["mean_ms"] is None
    assert s["qps"] == 0.0


def test_metrics_disabled_service_falls_back(data):
    db, _, q = data
    svc = SearchService(db, engines=("brute",), backend="jnp", k=K,
                        metrics=False)
    assert svc.metrics.enabled is False
    for i in range(4):
        svc.submit(q[i], engine="brute")
    svc.flush()
    s = svc.summary()                     # percentiles from the deque window
    assert s["n_queries"] == 4 and s["p50_ms"] > 0
    assert svc.metrics.collect() == []


def test_telemetry_windows_bounded(data, monkeypatch):
    db, _, q = data
    monkeypatch.setattr(SearchService, "TELEMETRY_WINDOW", 8)
    svc = SearchService(db, engines=("brute",), backend="jnp", k=K)
    for i in range(24):                   # 3x the window, one batch each
        svc.submit(q[i % len(q)], engine="brute")
        svc.flush()
    # recent-window views are bounded; full-run aggregates are not
    assert len(svc.latencies_ms) == 8
    assert len(svc.batches) == 8
    assert svc.n_queries == 24
    assert svc.metrics.family("service_request_latency_ms").count() == 24
    s = svc.summary()
    assert s["batch_buckets"] == {1: 24}  # full-run histogram, not windowed
    assert s["n_queries"] == 24


def _tiered_service(db, **kw):
    # 2000 rows -> 2048-capacity main segment; 256-row chunks -> 8 streamed
    # chunks through the double buffer on every brute tiered search
    return SearchService(db, engines=("brute",), backend="jnp", k=K,
                         residency="tiered", tier_chunk_rows=256, **kw)


def test_tiered_stage_gauges_populated(data):
    db, _, q = data
    svc = _tiered_service(db)
    svc.submit(q[:4], engine="brute")
    svc.flush()
    m = svc.metrics
    assert m.family("service_tiered_chunks").value(engine="brute") == 8
    assert m.family("service_tiered_stall_seconds").value(engine="brute") >= 0
    frac = m.family("service_tiered_stall_fraction").value(engine="brute")
    assert 0.0 <= frac <= 1.0
    # scanned attribution still matches the engine contract under tiering
    assert m.family("service_scanned_total").value(engine="brute") \
        == svc.scanned_total["brute"] > 0


def test_device_residency_leaves_tier_gauges_empty(data):
    db, _, q = data
    svc = SearchService(db, engines=("brute",), backend="jnp", k=K)
    svc.submit(q[:4], engine="brute")
    svc.flush()
    m = svc.metrics
    # no tiered child was ever materialized on the device-resident path
    assert m.family("service_tiered_chunks").value(engine="brute") == 0
    assert all(r["name"] != "service_tiered_chunks" for r in m.collect())


def test_tiered_trace_shows_double_buffer_overlap(data):
    db, _, q = data
    TRACER.configure(enabled=True)
    svc = _tiered_service(db)
    svc.submit(q[:4], engine="brute")
    svc.flush()
    events = [e for e in TRACER.events if e["ph"] == "X"]
    assert validate_trace(TRACER.to_chrome()) == []
    puts = [e for e in events if e["name"] == "tier.device_put"]
    scans = [e for e in events if e["name"] == "tier.scan_chunk"]
    assert len(puts) == 8 and len(scans) == 8
    # acceptance: chunk i+1's host->HBM transfer span overlaps chunk i's
    # compute span on the timeline (the double buffer actually pipelines)
    def overlaps(a, b):
        return (a["ts"] < b["ts"] + b["dur"]
                and b["ts"] < a["ts"] + a["dur"])
    scan_by_chunk = {e["args"]["chunk"]: e for e in scans}
    put_by_chunk = {e["args"]["chunk"]: e for e in puts}
    overlapped = [c for c in range(7)
                  if overlaps(put_by_chunk[c + 1], scan_by_chunk[c])]
    assert overlapped, "no transfer span overlapped the previous compute span"
    # the service-level request path is present and linked
    names = {e["name"] for e in events}
    assert {"service.batch", "service.engine_search",
            "service.queue_wait"} <= names
    search_spans = [e for e in events if e["name"] == "service.engine_search"]
    assert all(e["args"].get("parent") == "service.batch"
               for e in search_spans)


def test_disabled_tracer_records_nothing_through_service(data):
    db, extra, q = data
    assert TRACER.enabled is False
    svc = _tiered_service(db)
    svc.insert(extra[:4])
    svc.submit(q[:4], engine="brute")
    svc.flush()
    assert TRACER.events == [] and TRACER.dropped_events == 0


def test_wal_and_snapshot_spans(data, tmp_path):
    db, extra, q = data
    TRACER.configure(enabled=True)
    svc = SearchService(db, engines=("brute",), backend="jnp", k=K,
                        durable_dir=str(tmp_path))
    svc.insert(extra[:4])
    svc.submit(q[:2], engine="brute")
    svc.flush()
    svc.snapshot()
    svc.close()
    names = {e["name"] for e in TRACER.events if e["ph"] == "X"}
    assert {"wal.append", "wal.fsync", "service.insert",
            "snapshot.extract", "snapshot.write"} <= names
    # WAL append is recorded inside the insert span
    appends = [e for e in TRACER.events if e["name"] == "wal.append"]
    assert all(e["args"]["parent"] == "service.insert" for e in appends)
