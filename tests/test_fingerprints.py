"""Property tests for packed fingerprints and Tanimoto similarity."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # collection-safe fallback (see tests/_propcheck.py)
    from _propcheck import given, settings, strategies as st

from repro.core import (pack_bits, unpack_bits, popcount, tanimoto,
                        batched_tanimoto_scores)

bits_arrays = st.integers(1, 8).flatmap(
    lambda words: st.lists(
        st.lists(st.integers(0, 1), min_size=words * 32, max_size=words * 32),
        min_size=1, max_size=6))


@given(bits_arrays)
@settings(max_examples=50, deadline=None)
def test_pack_unpack_roundtrip(rows):
    bits = np.asarray(rows, dtype=np.uint8)
    packed = pack_bits(bits)
    assert packed.dtype == np.uint32
    np.testing.assert_array_equal(unpack_bits(packed), bits)


@given(bits_arrays)
@settings(max_examples=50, deadline=None)
def test_popcount_matches_bit_sum(rows):
    bits = np.asarray(rows, dtype=np.uint8)
    packed = jnp.asarray(pack_bits(bits))
    np.testing.assert_array_equal(np.asarray(popcount(packed)),
                                  bits.sum(axis=1))


@given(st.integers(1, 4), st.data())
@settings(max_examples=50, deadline=None)
def test_tanimoto_matches_set_formula(words, data):
    n = words * 32
    a = np.asarray(data.draw(st.lists(st.integers(0, 1), min_size=n, max_size=n)), np.uint8)
    b = np.asarray(data.draw(st.lists(st.integers(0, 1), min_size=n, max_size=n)), np.uint8)
    inter = int(np.sum(a & b))
    union = int(np.sum(a | b))
    expect = inter / union if union else 0.0
    got = float(tanimoto(jnp.asarray(pack_bits(a)), jnp.asarray(pack_bits(b))))
    assert abs(got - expect) < 1e-6


def test_tanimoto_properties(small_db):
    db = jnp.asarray(small_db[:100])
    s = np.asarray(batched_tanimoto_scores(db, db))
    assert (s >= 0).all() and (s <= 1.0 + 1e-6).all()
    np.testing.assert_allclose(s, s.T, rtol=1e-6)          # symmetry
    np.testing.assert_allclose(np.diag(s), 1.0, rtol=1e-6)  # self-similarity
