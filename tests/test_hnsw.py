"""HNSW: build invariants, accelerated search recall, backend parity."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # collection-safe fallback (see tests/_propcheck.py)
    from _propcheck import given, settings, strategies as st

from repro.core import hnsw as hn
from repro.core import HNSWEngine, recall_at_k
from repro.data.molecules import SyntheticConfig, synthetic_fingerprints, queries_from_db


@pytest.fixture(scope="module")
def tiny_index():
    db = synthetic_fingerprints(SyntheticConfig(n=800, seed=3))
    return db, hn.build_hnsw(db, m=8, ef_construction=40, seed=0)


def test_degree_bounds(tiny_index):
    db, idx = tiny_index
    assert idx.base_adj.shape == (800, 16)           # 2M at base
    # no self-loops, ids in range
    for i in range(800):
        row = idx.base_adj[i]
        valid = row[row >= 0]
        assert (valid != i).all()
        assert (valid < 800).all()
    for l, adj in enumerate(idx.level_adj, start=1):
        assert adj.shape[1] == idx.m


def test_layers_nested(tiny_index):
    """Every node at level l also exists at all lower levels (hierarchy)."""
    db, idx = tiny_index
    prev = None
    for l in range(len(idx.level_nodes), 0, -1):
        nodes = set(idx.level_nodes[l - 1].tolist())
        if prev is not None:
            assert prev <= nodes
        prev = nodes


def test_entry_point_at_top(tiny_index):
    db, idx = tiny_index
    if idx.max_level > 0:
        assert idx.entry_point in set(idx.level_nodes[-1].tolist())


def test_base_layer_connected_enough(tiny_index):
    """BFS from the entry point reaches nearly every node (the paper's
    long-range links keep the graph navigable)."""
    db, idx = tiny_index
    seen = {int(idx.entry_point)}
    frontier = [int(idx.entry_point)]
    while frontier:
        nxt = []
        for u in frontier:
            for v in idx.base_adj[u]:
                v = int(v)
                if v >= 0 and v not in seen:
                    seen.add(v)
                    nxt.append(v)
        frontier = nxt
    assert len(seen) >= 0.95 * idx.n


def test_search_recall_vs_bruteforce(tiny_index):
    db, idx = tiny_index
    q = queries_from_db(db, 16, seed=5)
    eng = HNSWEngine(db, index=idx, ef_search=64)
    ids, sims = eng.search(q, 10)
    # oracle
    import jax.numpy as jnp
    from repro.core import batched_tanimoto_scores
    s = np.asarray(batched_tanimoto_scores(jnp.asarray(q), jnp.asarray(db)))
    true = np.argsort(-s, axis=1, kind="stable")[:, :10]
    rec = recall_at_k(ids, true)
    assert rec >= 0.8, rec
    # self-query must find itself (similarity 1)
    assert (sims[:, 0] >= 1.0 - 1e-6).all()


def _truth(db, q, k=10):
    import jax.numpy as jnp
    from repro.core import batched_tanimoto_scores
    s = np.asarray(batched_tanimoto_scores(jnp.asarray(q), jnp.asarray(db)))
    return np.argsort(-s, axis=1, kind="stable")[:, :k]


@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 48]),
       st.sampled_from([1, 2, 4]))
@settings(max_examples=4, deadline=None)
def test_backend_parity_jnp_vs_tpu(seed, ef, beam):
    """The jnp and tpu (Pallas gather kernel) backends run the same traversal
    with the same arithmetic — recall must match within 0.01 and sims must
    agree on small random databases (satellite of ISSUE 2)."""
    db = synthetic_fingerprints(SyntheticConfig(n=300, seed=seed % 1000))
    idx = hn.build_hnsw(np.asarray(db), m=4, ef_construction=20, seed=0)
    q = queries_from_db(db, 4, seed=seed % 977)
    true = _truth(db, q, 5)
    recalls = {}
    sims_by_backend = {}
    for backend in ("jnp", "tpu"):
        eng = HNSWEngine(db, index=idx, backend=backend, beam=beam)
        ids, sims = eng.search(q, 5, ef=ef)
        recalls[backend] = recall_at_k(ids, true)
        sims_by_backend[backend] = sims
    assert abs(recalls["jnp"] - recalls["tpu"]) <= 0.01, recalls
    np.testing.assert_allclose(sims_by_backend["jnp"],
                               sims_by_backend["tpu"], rtol=1e-6)


def test_numpy_backend_reference_recall(tiny_index):
    """Host reference traversal reaches the same recall ballpark as the
    device path on the same index."""
    db, idx = tiny_index
    q = queries_from_db(db, 8, seed=9)
    true = _truth(db, q, 10)
    recs = {}
    for backend in ("numpy", "jnp"):
        eng = HNSWEngine(db, index=idx, backend=backend, ef_search=64)
        ids, _ = eng.search(q, 10)
        recs[backend] = recall_at_k(ids, true)
    assert recs["numpy"] >= 0.9, recs
    assert abs(recs["numpy"] - recs["jnp"]) <= 0.05, recs


def test_traversal_stats_surface(tiny_index):
    """Telemetry contract: iterations / expansions / termination reasons come
    through HNSWEngine.stats (no private back-channel)."""
    db, idx = tiny_index
    q = queries_from_db(db, 8, seed=11)
    eng = HNSWEngine(db, index=idx, ef_search=32, backend="jnp")
    assert eng.stats == {}                      # nothing before a search
    eng.search(q, 5)
    st_ = eng.stats
    assert st_["iters"] > 0 and st_["expansions"] > 0
    assert st_["neighbour_evals"] == st_["expansions"] * idx.base_adj.shape[1]
    assert st_["converged"] + st_["max_iters_hit"] == len(q)
    assert st_["iters_per_query"].shape == (len(q),)
    assert not hasattr(eng, "_last_iters")      # back-channel removed
    # a tiny budget must terminate queries with the budget reason
    tight = HNSWEngine(db, index=idx, ef_search=64, backend="jnp", max_iters=2)
    tight.search(q, 5)
    assert tight.stats["max_iters_hit"] == len(q)


def test_beam_expansion_cuts_iterations(tiny_index):
    """Multi-candidate beam expansion amortises traversal: ~B fewer
    lock-step iterations at equivalent recall."""
    db, idx = tiny_index
    q = queries_from_db(db, 8, seed=12)
    true = _truth(db, q, 10)
    stats = {}
    recs = {}
    for beam in (1, 4):
        eng = HNSWEngine(db, index=idx, ef_search=64, backend="jnp", beam=beam)
        ids, _ = eng.search(q, 10)
        stats[beam] = eng.stats["iters"]
        recs[beam] = recall_at_k(ids, true)
    assert stats[4] < stats[1] / 2, stats
    assert recs[4] >= recs[1] - 0.05, recs


def test_beam_auto_tune_default(tiny_index):
    """ISSUE 3 satellite: ``HNSWEngine(beam=None)`` picks the beam from
    ``ef_search`` (ROADMAP telemetry rule), at equal recall vs ``beam=1``
    with fewer lock-step iterations."""
    db, idx = tiny_index
    # the rule itself
    assert hn.auto_beam(64) == 4 and hn.auto_beam(16) == 1
    assert hn.auto_beam(128) == 8 and hn.auto_beam(1024) == 8  # clamped
    assert HNSWEngine(db, index=idx, ef_search=64).beam == 4
    assert HNSWEngine(db, index=idx, ef_search=16).beam == 1
    # equal-recall pin vs beam=1 on the tiny grid
    q = queries_from_db(db, 16, seed=13)
    true = _truth(db, q, 10)
    auto = HNSWEngine(db, index=idx, ef_search=64, backend="jnp")
    ids_a, _ = auto.search(q, 10)
    iters_auto = auto.stats["iters"]
    one = HNSWEngine(db, index=idx, ef_search=64, backend="jnp", beam=1)
    ids_1, _ = one.search(q, 10)
    assert recall_at_k(ids_a, true) == recall_at_k(ids_1, true), \
        (recall_at_k(ids_a, true), recall_at_k(ids_1, true))
    assert iters_auto < one.stats["iters"]


def test_recall_increases_with_ef(tiny_index):
    db, idx = tiny_index
    q = queries_from_db(db, 16, seed=6)
    import jax.numpy as jnp
    from repro.core import batched_tanimoto_scores
    s = np.asarray(batched_tanimoto_scores(jnp.asarray(q), jnp.asarray(db)))
    true = np.argsort(-s, axis=1, kind="stable")[:, :10]
    eng = HNSWEngine(db, index=idx)
    recs = []
    for ef in (10, 40, 120):
        ids, _ = eng.search(q, 10, ef=ef)
        recs.append(recall_at_k(ids, true))
    assert recs[-1] >= recs[0] - 0.02, recs
    assert recs[-1] >= 0.85, recs
