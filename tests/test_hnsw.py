"""HNSW: build invariants + accelerated search recall."""
import numpy as np
import pytest

from repro.core import hnsw as hn
from repro.core import HNSWEngine, recall_at_k
from repro.data.molecules import SyntheticConfig, synthetic_fingerprints, queries_from_db


@pytest.fixture(scope="module")
def tiny_index():
    db = synthetic_fingerprints(SyntheticConfig(n=800, seed=3))
    return db, hn.build_hnsw(db, m=8, ef_construction=40, seed=0)


def test_degree_bounds(tiny_index):
    db, idx = tiny_index
    assert idx.base_adj.shape == (800, 16)           # 2M at base
    # no self-loops, ids in range
    for i in range(800):
        row = idx.base_adj[i]
        valid = row[row >= 0]
        assert (valid != i).all()
        assert (valid < 800).all()
    for l, adj in enumerate(idx.level_adj, start=1):
        assert adj.shape[1] == idx.m


def test_layers_nested(tiny_index):
    """Every node at level l also exists at all lower levels (hierarchy)."""
    db, idx = tiny_index
    prev = None
    for l in range(len(idx.level_nodes), 0, -1):
        nodes = set(idx.level_nodes[l - 1].tolist())
        if prev is not None:
            assert prev <= nodes
        prev = nodes


def test_entry_point_at_top(tiny_index):
    db, idx = tiny_index
    if idx.max_level > 0:
        assert idx.entry_point in set(idx.level_nodes[-1].tolist())


def test_base_layer_connected_enough(tiny_index):
    """BFS from the entry point reaches nearly every node (the paper's
    long-range links keep the graph navigable)."""
    db, idx = tiny_index
    seen = {int(idx.entry_point)}
    frontier = [int(idx.entry_point)]
    while frontier:
        nxt = []
        for u in frontier:
            for v in idx.base_adj[u]:
                v = int(v)
                if v >= 0 and v not in seen:
                    seen.add(v)
                    nxt.append(v)
        frontier = nxt
    assert len(seen) >= 0.95 * idx.n


def test_search_recall_vs_bruteforce(tiny_index):
    db, idx = tiny_index
    q = queries_from_db(db, 16, seed=5)
    eng = HNSWEngine(db, index=idx, ef_search=64)
    ids, sims = eng.search(q, 10)
    # oracle
    import jax.numpy as jnp
    from repro.core import batched_tanimoto_scores
    s = np.asarray(batched_tanimoto_scores(jnp.asarray(q), jnp.asarray(db)))
    true = np.argsort(-s, axis=1, kind="stable")[:, :10]
    rec = recall_at_k(ids, true)
    assert rec >= 0.8, rec
    # self-query must find itself (similarity 1)
    assert (sims[:, 0] >= 1.0 - 1e-6).all()


def test_recall_increases_with_ef(tiny_index):
    db, idx = tiny_index
    q = queries_from_db(db, 16, seed=6)
    import jax.numpy as jnp
    from repro.core import batched_tanimoto_scores
    s = np.asarray(batched_tanimoto_scores(jnp.asarray(q), jnp.asarray(db)))
    true = np.argsort(-s, axis=1, kind="stable")[:, :10]
    eng = HNSWEngine(db, index=idx)
    recs = []
    for ef in (10, 40, 120):
        ids, _ = eng.search(q, 10, ef=ef)
        recs.append(recall_at_k(ids, true))
    assert recs[-1] >= recs[0] - 0.02, recs
    assert recs[-1] >= 0.85, recs
