"""Crash-fault-injection harness (ISSUE 6 acceptance).

Fault models:

* **Injected crashes** — the service runs on a :class:`CrashPointFs` that
  tears the write exhausting a byte budget (torn WAL records, truncated
  snapshot leaves) or raises between metadata ops (fsync / rename / mkdir /
  remove) on an op budget. Budgets are swept three ways: a mixed
  ingest+snapshot workload, an ingest-only workload (every crash lands in a
  WAL append / compaction rotation), and a snapshot-only workload (every
  crash lands in the snapshot write/publish/GC sequence). After every crash
  the directory is reopened with the real filesystem and the recovered
  service must (a) contain every acknowledged insert and (b) have state
  byte-equal to a never-crashed service driven with the same prefix of the
  workload — across all three engines at once.
* **SIGKILL** — a subprocess ingests with fsync-per-ack and prints each
  acked batch; the parent SIGKILLs it mid-ingest and reopens the directory,
  asserting acked-implies-recovered and search parity against a
  never-crashed rebuild.
"""
import os
import signal
import subprocess
import sys
import tempfile
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.checkpoint.fs import CrashPointFs, InjectedCrash
from repro.data.molecules import (SyntheticConfig, queries_from_db,
                                  synthetic_fingerprints)
from repro.serve import SearchService, snapshot as snap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ENGINES = ("brute", "bitbound-folding", "hnsw")
SVC_KW = dict(compact_threshold=12, hnsw_m=4, hnsw_ef_construction=12,
              hnsw_ef_search=16)

POOL = synthetic_fingerprints(SyntheticConfig(n=260, seed=0))
BASE = POOL[:150]
BATCH = 5
BATCHES = [POOL[150 + i * BATCH:150 + (i + 1) * BATCH] for i in range(10)]
QUERIES = queries_from_db(POOL, 5, seed=4)

_ref_cache: dict = {}


def _reference_state(n_batches: int):
    """State of a never-crashed service after the same workload prefix
    (snapshots never mutate engine state, so one cache serves every test)."""
    if n_batches not in _ref_cache:
        svc = SearchService(BASE, engines=ENGINES, **SVC_KW)
        for b in BATCHES[:n_batches]:
            svc.insert(b)
        _ref_cache[n_batches] = snap.service_state(svc)
    return _ref_cache[n_batches]


def _crash_run(tmp: Path, fs: CrashPointFs, workload):
    """One swept run: real-fs service creation, faulty-fs workload, real-fs
    recovery. ``workload(svc, stage)`` drives the service and keeps
    ``stage[0]`` naming the op in flight. Returns
    ``(acked_batches, crashed_stage_or_None, recovered_service)``."""
    svc = SearchService(BASE, engines=ENGINES, durable_dir=str(tmp),
                        **SVC_KW)
    stage = ["setup"]
    crashed = None
    try:
        svc._set_fs(fs)                # even the swap rotation may crash
        workload(svc, stage)
        stage[0] = "done"
    except InjectedCrash:
        crashed = stage[0]
        try:                           # drop the torn WAL buffer quietly
            svc._wal._f.close()
        except Exception:
            pass
    acked = svc.n_inserts // BATCH     # insert() returned for exactly these
    recovered = SearchService.open(tmp)
    return acked, crashed, recovered


def _workload_mixed(svc, stage):
    for i, batch in enumerate(BATCHES):
        stage[0] = "insert"            # WAL append + apply (+ rotation when
        svc.insert(batch)              # the batch trips a compaction)
        if (i + 1) % 3 == 0:
            stage[0] = "snapshot"
            svc.snapshot()


def _workload_ingest(svc, stage):
    for batch in BATCHES:
        stage[0] = "insert"
        svc.insert(batch)


def _workload_snapshot(svc, stage):
    for batch in BATCHES[:4]:          # a WAL tail for the snapshot to cover
        stage[0] = "insert"
        svc.insert(batch)
    stage[0] = "snapshot"
    svc.snapshot()
    stage[0] = "snapshot"              # second generation: retention prune +
    svc.snapshot()                     # WAL GC crash windows


def _assert_recovered(acked: int, recovered: SearchService, label: str):
    n_rec = recovered.engines["brute"].n_total - len(BASE)
    assert n_rec % BATCH == 0, f"{label}: partial batch recovered"
    n_batches = n_rec // BATCH
    # acked-implies-recovered (an fsync'd-but-unapplied batch may add one)
    assert n_batches >= acked, f"{label}: lost acked batches"
    arrays, meta = snap.service_state(recovered)
    ref_arrays, ref_meta = _reference_state(n_batches)
    assert meta == ref_meta, f"{label}: meta diverged from never-crashed run"
    assert sorted(arrays) == sorted(ref_arrays), f"{label}: array set"
    for k in arrays:
        assert arrays[k].dtype == ref_arrays[k].dtype, f"{label}/{k}"
        assert arrays[k].tobytes() == ref_arrays[k].tobytes(), \
            f"{label}/{k}: state diverged from never-crashed run"
    return n_batches


def _search_parity(recovered: SearchService, n_batches: int, label: str):
    reb = SearchService(
        np.concatenate([BASE] + list(BATCHES[:n_batches])) if n_batches
        else BASE, engines=ENGINES, **SVC_KW)
    for e in ENGINES:
        got = recovered.search(QUERIES, 6, engine=e)
        ref = reb.search(QUERIES, 6, engine=e)
        np.testing.assert_array_equal(got[0], ref[0],
                                      err_msg=f"{label}/{e}")
        np.testing.assert_array_equal(got[1], ref[1],
                                      err_msg=f"{label}/{e}")


def _probe_totals(tmp: Path, workload):
    """Fault-free instrumented run: returns the byte/op totals the budget
    sweeps are placed across (and sanity-checks the fault-free roundtrip)."""
    probe = CrashPointFs()             # unlimited budgets: counts only
    acked, crashed, recovered = _crash_run(tmp, probe, workload)
    assert crashed is None
    _assert_recovered(acked, recovered, "fault-free")
    recovered.close()
    assert probe.bytes_written > 0 and probe.ops > 0
    return probe.bytes_written, probe.ops


def _sweep(tmp_path, workload, budgets, expect_stages):
    """Run ``workload`` once per budget; assert every recovery is lossless
    and bit-identical, and that the sweep crossed ``expect_stages``."""
    stages_hit = set()
    parity_checked = set()
    for kind, budget in budgets:
        fs = (CrashPointFs(byte_budget=budget) if kind == "bytes"
              else CrashPointFs(op_budget=budget))
        with tempfile.TemporaryDirectory(dir=tmp_path) as d:
            acked, crashed, recovered = _crash_run(Path(d), fs, workload)
            label = f"{kind}={budget} crash@{crashed}"
            n_batches = _assert_recovered(acked, recovered, label)
            if crashed is not None:
                stages_hit.add(crashed)
                # full search parity once per distinct crash stage (the
                # extra compiles make per-budget checks too slow; state
                # byte-equality already covers the rest)
                if crashed not in parity_checked:
                    parity_checked.add(crashed)
                    _search_parity(recovered, n_batches, label)
            recovered.close()
    missing = expect_stages - stages_hit
    assert not missing, f"sweep never crashed in {missing} (hit {stages_hit})"
    return stages_hit


def test_fault_injection_sweep_mixed(tmp_path):
    """Byte and op budgets swept across the full ingest/compaction/snapshot
    write sequence of a mixed workload."""
    total_bytes, total_ops = _probe_totals(tmp_path / "probe",
                                           _workload_mixed)
    budgets = ([("bytes", max(1, total_bytes * i // 7)) for i in range(7)]
               + [("ops", max(1, total_ops * i // 4)) for i in range(4)])
    # snapshot leaves dominate the byte stream, so the mixed sweep is
    # guaranteed to land there; the ingest-only sweep below pins the rest
    _sweep(tmp_path, _workload_mixed, budgets, {"snapshot"})


def test_fault_injection_sweep_ingest(tmp_path):
    """Ingest-only workload: every budget exhausts inside a WAL append,
    fsync, or compaction rotation — the acked-implies-recovered hot path."""
    total_bytes, total_ops = _probe_totals(tmp_path / "probe",
                                           _workload_ingest)
    budgets = ([("bytes", max(1, total_bytes * i // 6)) for i in range(6)]
               + [("ops", max(1, total_ops * i // 6)) for i in range(6)])
    stages = _sweep(tmp_path, _workload_ingest, budgets, {"insert"})
    assert stages <= {"setup", "insert"}   # nothing else runs here


def test_fault_injection_sweep_snapshot(tmp_path):
    """Snapshot-targeted workload: budgets land in the leaf writes, the
    manifest, the atomic publish, the retention prune and the WAL GC of a
    snapshot generation (including the second-generation windows)."""
    total_bytes, total_ops = _probe_totals(tmp_path / "probe",
                                           _workload_snapshot)
    budgets = ([("bytes", max(1, total_bytes * (i + 3) // 8))
                for i in range(5)]      # skip the ingest prefix: crash late
               + [("ops", max(1, total_ops * (i + 2) // 6))
                  for i in range(4)])
    _sweep(tmp_path, _workload_snapshot, budgets, {"snapshot"})


def test_crash_between_tempwrite_and_rename(tmp_path):
    """Pin the classic window explicitly: the snapshot temp dir is fully
    written but the atomic rename never happens — recovery must use the
    previous generation + WAL, losing nothing."""
    svc = SearchService(BASE, engines=("brute",), durable_dir=str(tmp_path),
                        compact_threshold=1000)
    svc.insert(BATCHES[0])

    class NoRenameFs(CrashPointFs):
        def replace(self, src, dst):
            raise InjectedCrash("crash before atomic rename")

    svc._set_fs(NoRenameFs())
    with pytest.raises(InjectedCrash):
        svc.snapshot()
    recovered = SearchService.open(tmp_path)
    assert recovered.engines["brute"].n_total == len(BASE) + BATCH
    tmps = list((tmp_path / "snapshots").glob(".tmp_*"))
    assert tmps, "expected an orphaned temp dir from the crashed publish"
    recovered.close()


@pytest.mark.parametrize("fsync_every", [1, 4])
def test_sigkill_mid_ingest_recovers_acked(tmp_path, fsync_every):
    """Subprocess driver: SIGKILL the serving process mid-ingest; every
    batch it acked before dying must be searchable after reopen, and the
    results bit-identical to a never-crashed rebuild (group commit is
    allowed to lose only its documented unsynced window)."""
    d = tmp_path / "svc"
    code = textwrap.dedent(f"""
        import numpy as np
        from repro.data.molecules import SyntheticConfig, synthetic_fingerprints
        from repro.serve import SearchService

        pool = synthetic_fingerprints(SyntheticConfig(n=260, seed=0))
        svc = SearchService(pool[:150], engines=("brute", "bitbound-folding",
                                                 "hnsw"),
                            durable_dir={str(d)!r}, compact_threshold=12,
                            hnsw_m=4, hnsw_ef_construction=12,
                            hnsw_ef_search=16,
                            wal_fsync_every={fsync_every})
        rng = np.random.default_rng(7)
        for i in range(4000):
            svc.insert(rng.integers(0, 2**32, size=(2, pool.shape[1]),
                                    dtype=np.uint32))
            print(f"ACK {{i}}", flush=True)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.Popen([sys.executable, "-c", code], env=env,
                            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                            text=True)
    acked = -1
    try:
        for line in proc.stdout:
            if line.startswith("ACK "):
                acked = int(line.split()[1])
            if acked >= 8:              # mid-ingest, well before batch 4000
                proc.send_signal(signal.SIGKILL)
                break
    finally:
        proc.kill()
        proc.wait(timeout=60)
    assert acked >= 8, proc.stderr.read()

    recovered = SearchService.open(d)
    n_rec = recovered.engines["brute"].n_total
    n_acked_rows = 150 + 2 * (acked + 1)
    if fsync_every == 1:
        assert n_rec >= n_acked_rows, "lost an acked, fsync'd insert"
    else:                               # documented group-commit window
        assert n_rec >= n_acked_rows - 2 * (fsync_every - 1)
    assert (n_rec - 150) % 2 == 0, "partial batch recovered"

    # bit-identical to a never-crashed rebuild on the recovered database
    rng = np.random.default_rng(7)
    pool = synthetic_fingerprints(SyntheticConfig(n=260, seed=0))
    inserted = [rng.integers(0, 2**32, size=(2, pool.shape[1]),
                             dtype=np.uint32)
                for _ in range((n_rec - 150) // 2)]
    reb = SearchService(np.concatenate([pool[:150]] + inserted),
                        engines=ENGINES, compact_threshold=12, hnsw_m=4,
                        hnsw_ef_construction=12, hnsw_ef_search=16)
    q = queries_from_db(pool, 5, seed=4)
    for e in ENGINES:
        got = recovered.search(q, 6, engine=e)
        ref = reb.search(q, 6, engine=e)
        np.testing.assert_array_equal(got[0], ref[0], err_msg=e)
        np.testing.assert_array_equal(got[1], ref[1], err_msg=e)
    recovered.close()


def test_sigkill_with_concurrent_clients_through_frontend(tmp_path):
    """ISSUE 9: acked-implies-recovered must survive the concurrent tier.
    A subprocess serves query clients through a 2-replica SearchFrontend
    while the main thread ingests (each ACK printed only after the durable
    insert call returned); the parent SIGKILLs it mid-stream and reopens
    the front-end-owned directory with plain SearchService.open — every
    acked batch must be searchable, bit-identical to a never-crashed
    rebuild."""
    d = tmp_path / "fe"
    code = textwrap.dedent(f"""
        import threading
        import numpy as np
        from repro.data.molecules import (SyntheticConfig,
                                          synthetic_fingerprints)
        from repro.serve import FrontendConfig, SearchFrontend

        pool = synthetic_fingerprints(SyntheticConfig(n=260, seed=0))
        fe = SearchFrontend(pool[:150], engines=("bitbound-folding",),
                            durable_dir={str(d)!r}, compact_threshold=64,
                            cutoff=0.4, fold_m=2,
                            frontend=FrontendConfig(
                                replicas=2, high_water=64,
                                default_deadline_ms=None,
                                flush_interval_ms=1.0))

        def client(seed):
            rng = np.random.default_rng(seed)
            while True:
                q = pool[int(rng.integers(0, 150))]
                try:
                    fe.search(q, 5, timeout=60)
                except Exception:
                    return
        for s in range(3):
            threading.Thread(target=client, args=(s,), daemon=True).start()

        rng = np.random.default_rng(7)
        for i in range(4000):
            fe.insert(rng.integers(0, 2**32, size=(2, pool.shape[1]),
                                   dtype=np.uint32))
            print(f"ACK {{i}}", flush=True)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.Popen([sys.executable, "-c", code], env=env,
                            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                            text=True)
    acked = -1
    try:
        for line in proc.stdout:
            if line.startswith("ACK "):
                acked = int(line.split()[1])
            if acked >= 8:              # mid-stream, clients in flight
                proc.send_signal(signal.SIGKILL)
                break
    finally:
        proc.kill()
        proc.wait(timeout=60)
    assert acked >= 8, proc.stderr.read()

    recovered = SearchService.open(d)
    n_rec = recovered.n_total
    assert n_rec >= 150 + 2 * (acked + 1), \
        "lost an insert the front end acked before SIGKILL"
    assert (n_rec - 150) % 2 == 0, "partial batch recovered"

    rng = np.random.default_rng(7)
    pool = synthetic_fingerprints(SyntheticConfig(n=260, seed=0))
    inserted = [rng.integers(0, 2**32, size=(2, pool.shape[1]),
                             dtype=np.uint32)
                for _ in range((n_rec - 150) // 2)]
    reb = SearchService(np.concatenate([pool[:150]] + inserted),
                        engines=("bitbound-folding",), compact_threshold=64,
                        cutoff=0.4, fold_m=2)
    q = queries_from_db(pool, 5, seed=4)
    got = recovered.search(q, 6)
    ref = reb.search(q, 6)
    np.testing.assert_array_equal(got[0], ref[0])
    np.testing.assert_array_equal(got[1], ref[1])
    recovered.close()
