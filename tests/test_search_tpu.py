"""Cross-engine parity of the device-resident two-stage path (`search_tpu`)
against the host-side numpy reference (`search_numpy`).

The reference breaks ties deterministically (stable sort, ascending
sorted-row index) — the same order the device kernel's position-stable
``top_k`` produces — so the m=1 pure-BitBound case must match bit-for-bit.
With folding (m>1) stage-1 float ordering may legitimately differ between the
float32 kernel and the float64 host loop, so the contract is recall parity
against brute-force ground truth.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BitBoundFoldingEngine, BruteForceEngine, recall_at_k


@pytest.mark.parametrize("backend", ["tpu", "jnp"])
@pytest.mark.parametrize("cutoff", [0.2, 0.6, 0.8])
def test_m1_exact_parity(small_db, queries, backend, cutoff):
    """m=1 (pure BitBound): ids AND sims match the numpy reference exactly."""
    ref = BitBoundFoldingEngine(small_db, cutoff=cutoff, m=1)
    dev = BitBoundFoldingEngine(small_db, cutoff=cutoff, m=1, backend=backend)
    rids, rsims = ref.search(queries, 20)
    dids, dsims = dev.search(queries, 20)
    np.testing.assert_array_equal(rids, dids)
    np.testing.assert_array_equal(rsims, dsims)
    assert ref.scanned(len(queries)) == dev.scanned(len(queries))


@pytest.mark.parametrize("m", [2, 4])
@pytest.mark.parametrize("cutoff", [0.6, 0.8])
def test_folded_recall_at_least_reference(small_db, queries, brute_truth,
                                          m, cutoff):
    """m>1: the device path's recall vs brute-force ground truth must be at
    least the numpy reference's (same candidate windows, same k_r1)."""
    _, true_ids = brute_truth
    ref = BitBoundFoldingEngine(small_db, cutoff=cutoff, m=m)
    dev = BitBoundFoldingEngine(small_db, cutoff=cutoff, m=m, backend="tpu")
    rids, _ = ref.search(queries, 20)
    dids, _ = dev.search(queries, 20)
    # one-hit tolerance: fp32 stage-1 ordering on a real TPU may legitimately
    # swap a candidate exactly at the k_r1 boundary vs the fp64 host loop
    assert recall_at_k(dids, true_ids) >= (recall_at_k(rids, true_ids)
                                           - 1.0 / true_ids.size)


def test_search_tpu_returns_device_arrays(small_db, queries):
    """The device path returns jax arrays (no forced host round-trip) and
    reports the scanned-candidate count as the reference does."""
    eng = BitBoundFoldingEngine(small_db, cutoff=0.6, m=2, backend="tpu")
    ids, sims, scanned = eng.search_tpu(queries, 10)
    assert isinstance(ids, jax.Array)
    assert isinstance(sims, jax.Array)
    assert isinstance(scanned, jax.Array)
    assert ids.shape == (len(queries), 10) and sims.shape == ids.shape
    ref = BitBoundFoldingEngine(small_db, cutoff=0.6, m=2)
    ref.search(queries, 10)
    assert int(scanned) == ref.scanned(len(queries))


def test_search_tpu_compilation_is_bucketed(small_db, queries):
    """Repeated searches reuse one compiled pipeline per (bucket, k): no
    per-query or per-batch recompilation."""
    eng = BitBoundFoldingEngine(small_db, cutoff=0.6, m=2, backend="tpu")
    eng.search(queries, 10)
    eng.search(queries, 10)
    eng.search(queries[:8], 10)   # same bucket, different batch shape
    assert len(eng._stage1_cache) == 1
    eng.search(queries, 5)        # new k -> one more pipeline
    assert len(eng._stage1_cache) == 2


def test_scheme2_device_path(small_db, queries):
    """Adjacent-OR folding also runs on device (jax scheme-2 query fold)."""
    eng = BitBoundFoldingEngine(small_db, cutoff=0.0, m=8, scheme=2,
                                backend="tpu")
    ids, sims = eng.search(queries, 5)
    assert (sims[:, 0] >= 1.0 - 1e-6).all()   # self-queries always found


def test_backend_selector_validation(small_db):
    with pytest.raises(ValueError):
        BitBoundFoldingEngine(small_db, backend="fpga")
    with pytest.raises(ValueError):
        BruteForceEngine(small_db, backend="numpy")
    # legacy flag maps onto the selector
    assert BruteForceEngine(small_db, use_kernel=True).backend == "tpu"
    assert BitBoundFoldingEngine(small_db).backend == "numpy"


def test_high_cutoff_empty_windows(small_db):
    """Queries whose Eq.2 window is empty come back id -1 / sim 0 on both
    paths (the all-zero query is the extreme case)."""
    q = np.zeros((2, small_db.shape[1]), dtype=np.uint32)
    q[1] = small_db[0]
    ref = BitBoundFoldingEngine(small_db, cutoff=0.95, m=1)
    dev = BitBoundFoldingEngine(small_db, cutoff=0.95, m=1, backend="tpu")
    rids, rsims = ref.search(q, 10)
    dids, dsims = dev.search(q, 10)
    np.testing.assert_array_equal(rids, dids)
    np.testing.assert_array_equal(rsims, dsims)
