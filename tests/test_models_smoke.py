"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs (harness requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import ARCHS, SHAPES


def _batch(cfg, b=2, s=16):
    batch = {"tokens": jnp.ones((b, s), jnp.int32),
             "labels": jnp.ones((b, s), jnp.int32)}
    if cfg.family == "audio":
        batch["audio_embed"] = jnp.ones((b, cfg.n_audio_frames, cfg.d_model),
                                        jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        batch["patch_embed"] = jnp.ones((b, cfg.n_patches, cfg.d_model),
                                        jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch):
    cfg = ARCHS[arch].reduced()
    params, _ = models.split(models.init_params(cfg, jax.random.key(0)))
    loss = jax.jit(models.train_loss(cfg))(params, _batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_grad_step_smoke(arch):
    cfg = ARCHS[arch].reduced()
    params, _ = models.split(models.init_params(cfg, jax.random.key(1)))
    g = jax.jit(jax.grad(models.train_loss(cfg)))(params, _batch(cfg))
    finite = all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))
    assert finite, f"{arch}: non-finite grads"
    # at least one grad leaf is non-zero
    assert any(float(jnp.abs(x).max()) > 0 for x in jax.tree.leaves(g))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_step_smoke(arch):
    cfg = ARCHS[arch].reduced()
    b = 2
    params, _ = models.split(models.init_params(cfg, jax.random.key(0)))
    caches = models.init_caches(cfg, b, 32)
    enc_kv = None
    if cfg.enc_dec:
        from repro.models.transformer import _encode, build_enc_kv
        batch = _batch(cfg, b)
        enc_out = _encode(params, cfg, batch["audio_embed"])
        enc_kv = build_enc_kv(cfg, params, enc_out)
    step = jax.jit(models.decode_step(cfg))
    toks = jnp.ones((b, 1), jnp.int32)
    logits, caches = step(params, caches, toks, enc_kv) if enc_kv is not None \
        else step(params, caches, toks)
    assert logits.shape == (b, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"
    # padded vocab tail is suppressed
    if cfg.padded_vocab != cfg.vocab:
        assert float(logits[:, cfg.vocab:].max()) <= -1e29


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_step_smoke(arch):
    cfg = ARCHS[arch].reduced()
    batch = _batch(cfg)
    params, _ = models.split(models.init_params(cfg, jax.random.key(0)))
    logits, caches = jax.jit(models.prefill_step(cfg))(params, batch)
    assert logits.shape == (2, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_all_archs_registered():
    assert len(ARCHS) == 10
    fams = {c.family for c in ARCHS.values()}
    assert fams == {"dense", "moe", "hybrid", "ssm", "audio", "vlm"}
    assert len(SHAPES) == 4
