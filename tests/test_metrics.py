"""Cross-engine metric conformance suite (ISSUE 10 tentpole).

Every similarity metric is a rational function of the popcount triple
``(a=|A|, b=|B|, c=|A∩B|)``; the ``Metric`` descriptor maps the shared
triple to a score at trace time. This suite pins:

* the numpy oracle (``metric_from_counts_np``) against the closed-form
  float64 formulas and the device map (``metric_from_counts``) against the
  oracle **bit-exactly** — jitted and eager, so XLA fast-math rewrites
  (rsqrt, FMA contraction) cannot split the backends;
* engine × backend × layout × metric parity: brute vs bitbound vs
  HNSW-rescore agree with the oracle on scores everywhere, and on ids
  modulo permutation within equal-f32-score tie groups (non-Tanimoto
  metrics compress score resolution, so ties are common and the numpy
  heap vs device ``top_k`` tie orders legitimately differ);
* Tanimoto-default identity: ``metric=None`` and explicit
  ``Metric("tanimoto")`` trace the same programs and return identical
  results (the bit-identity-with-pre-metric-code contract);
* BitBound window soundness per metric: nothing scoring ``>= cutoff``
  ever has a popcount outside the metric's window, m=1 engines never
  drop a qualifying true top-k value, and unbounded metrics
  (``tversky(0,0)``) fall back to a full scan with ``scanned``
  reflecting it;
* Tversky asymmetry (α≠β ⇒ sim(q,d) ≠ sim(d,q)) and the degenerate
  cases: empty fingerprint, all-ones, q==d ⇒ score exactly 1.0;
* variable widths: odd word counts (fp_bits off the 128-lane grid)
  through every engine, and ``fp_bits`` mismatches raising up front.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # collection-safe fallback (see tests/_propcheck.py)
    from _propcheck import given, settings, strategies as st

from repro.core import BitBoundFoldingEngine, BruteForceEngine, HNSWEngine
from repro.core import hnsw as hn
from repro.core.fingerprints import (Metric, TANIMOTO, TVERSKY_SCALE,
                                     metric_from_counts,
                                     metric_from_counts_np, pack_bits,
                                     resolve_metric)
from repro.data.molecules import (SyntheticConfig, queries_from_db,
                                  synthetic_fingerprints)

METRICS = [
    Metric("tanimoto"),
    Metric("dice"),
    Metric("cosine"),
    resolve_metric("tversky(0.3,0.7)"),
]
UNBOUNDED = resolve_metric("tversky(0,0)")
M_IDS = [m.spec for m in METRICS]

DB = np.asarray(synthetic_fingerprints(SyntheticConfig(n=500, seed=0)))
QUERIES = np.asarray(queries_from_db(DB, 6, seed=1))
K = 8


def _triples(queries, db):
    """Independent popcount-triple computation (the conformance ground
    truth shares no code with the engines)."""
    a = np.bitwise_count(queries).sum(axis=1).astype(np.int64)
    b = np.bitwise_count(db).sum(axis=1).astype(np.int64)
    c = np.bitwise_count(queries[:, None, :] & db[None, :, :]) \
        .sum(axis=2).astype(np.int64)
    return a, b, c


def _oracle(metric, queries, db):
    """(Q, N) float32 oracle score matrix."""
    a, b, c = _triples(queries, db)
    return metric_from_counts_np(metric, c, a[:, None], b[None, :])


def _closed_form(metric, a, b, c):
    """Float64 closed form straight from the paper definitions."""
    a, b, c = (x.astype(np.float64) for x in (a, b, c))
    if metric.name == "tanimoto":
        den = a + b - c
    elif metric.name == "dice":
        c, den = 2.0 * c, a + b
    elif metric.name == "cosine":
        with np.errstate(invalid="ignore", divide="ignore"):
            return np.where(a * b > 0, c / np.sqrt(a * b), 0.0)
    else:
        den = c + metric.alpha * (a - c) + metric.beta * (b - c)
    with np.errstate(invalid="ignore", divide="ignore"):
        return np.where(den > 0, c / den, 0.0)


def _assert_results_match_oracle(metric, ids, vals, oracle, k, cutoff=None):
    """Tie-tolerant conformance: per query, the returned value vector must
    equal the oracle's sorted top-k values exactly (restricted to values
    >= cutoff when the engine prunes), and every returned id's true score
    must equal its returned value — together these pin the result set up
    to permutation within equal-score groups, the strongest property that
    survives f32 ties."""
    ids, vals = np.asarray(ids), np.asarray(vals)
    for qi in range(oracle.shape[0]):
        row = oracle[qi]
        want = np.sort(row)[::-1][:k]
        got = vals[qi]
        if cutoff is None:
            np.testing.assert_array_equal(got, want, err_msg=f"q{qi} vals")
        else:
            w = want[want >= cutoff]
            np.testing.assert_array_equal(
                got[:len(w)], w, err_msg=f"q{qi} vals >= cutoff")
        for slot, (i, v) in enumerate(zip(ids[qi], vals[qi])):
            if i < 0:
                continue
            if cutoff is not None and v < cutoff:
                continue
            assert row[i] == v, (
                f"{metric.spec} q{qi} slot{slot}: id {i} true score "
                f"{row[i]!r} != returned {v!r}")


def _assert_tie_equivalent(ids_a, vals_a, ids_b, vals_b, label="",
                           oracle=None):
    """Cross-backend parity modulo tie order: value vectors bit-equal, id
    sets equal within every maximal equal-value run.  The final run may
    straddle the rank-k cut (more equal-score items exist than slots), so
    when ``oracle`` (full [nq, n_db] score matrix) is given, a divergent
    final group is accepted iff every id on both sides truly scores the
    tie value."""
    ids_a, ids_b = np.asarray(ids_a), np.asarray(ids_b)
    vals_a, vals_b = np.asarray(vals_a), np.asarray(vals_b)
    np.testing.assert_array_equal(vals_a, vals_b, err_msg=f"{label}: vals")
    for qi in range(ids_a.shape[0]):
        row = vals_a[qi]
        start = 0
        for end in range(1, len(row) + 1):
            if end == len(row) or row[end] != row[start]:
                ga = np.sort(ids_a[qi, start:end])
                gb = np.sort(ids_b[qi, start:end])
                if (end == len(row) and oracle is not None
                        and not np.array_equal(ga, gb)):
                    for i in np.concatenate([ga, gb]):
                        assert oracle[qi, i] == row[start], (
                            f"{label}: q{qi} boundary tie id {i} does not "
                            f"score {row[start]!r}")
                else:
                    np.testing.assert_array_equal(
                        ga, gb, err_msg=f"{label}: q{qi} tie group "
                                        f"[{start}:{end}] val={row[start]!r}")
                start = end


# -- score map ---------------------------------------------------------------

@pytest.mark.parametrize("metric", METRICS + [UNBOUNDED],
                         ids=M_IDS + [UNBOUNDED.spec])
def test_np_oracle_matches_closed_form(metric):
    rng = np.random.default_rng(0)
    a = rng.integers(0, 1025, 4000)
    b = rng.integers(0, 1025, 4000)
    c = rng.integers(0, np.minimum(a, b) + 1)
    got = metric_from_counts_np(metric, c, a, b)
    want = _closed_form(metric, a, b, c)
    np.testing.assert_allclose(got, want.astype(np.float32), rtol=2e-6,
                               atol=0)
    # exact corners: no overlap -> 0, identical sets -> 1
    assert metric_from_counts_np(metric, np.int64(0), np.int64(0),
                                 np.int64(0)) == 0.0
    nz = a[a > 0]
    ones = metric_from_counts_np(metric, nz, nz, nz)
    np.testing.assert_array_equal(ones, np.float32(1.0))


@pytest.mark.parametrize("metric", METRICS + [UNBOUNDED],
                         ids=M_IDS + [UNBOUNDED.spec])
def test_device_map_matches_np_oracle_bitwise(metric):
    """The jitted device map must equal the numpy oracle bit-for-bit — the
    property the per-metric op sequences (exact-int divides, explicit
    rsqrt, 1/256-quantized Tversky weights) were chosen to guarantee."""
    import jax
    rng = np.random.default_rng(1)
    a = rng.integers(0, 1025, 2048).astype(np.int32)
    b = rng.integers(0, 1025, 2048).astype(np.int32)
    c = rng.integers(0, np.minimum(a, b) + 1).astype(np.int32)
    want = metric_from_counts_np(metric, c.astype(np.int64),
                                 a.astype(np.int64), b.astype(np.int64))
    eager = np.asarray(metric_from_counts(metric, jnp.asarray(c),
                                          jnp.asarray(a), jnp.asarray(b)))
    jitted = np.asarray(jax.jit(
        lambda cc, aa, bb: metric_from_counts(metric, cc, aa, bb)
    )(jnp.asarray(c), jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(eager, want)
    np.testing.assert_array_equal(jitted, want)


def test_tversky_weights_quantized():
    m = resolve_metric("tversky(0.3,0.7)")
    assert m.alpha == round(0.3 * TVERSKY_SCALE) / TVERSKY_SCALE
    assert m.beta == round(0.7 * TVERSKY_SCALE) / TVERSKY_SCALE
    assert resolve_metric(m.spec) == m      # spec round-trips


def test_tversky_asymmetry():
    """α≠β weights the two set differences differently: for q ⊂ d the two
    directions must disagree, and each must hit the closed form exactly."""
    met = resolve_metric("tversky(0.3,0.7)")
    q = pack_bits(np.arange(1024) < 4)[None]      # |q| = 4
    d = pack_bits(np.arange(1024) < 8)[None]      # |d| = 8, q ⊂ d
    s_qd = float(_oracle(met, q, d)[0, 0])
    s_dq = float(_oracle(met, d, q)[0, 0])
    assert s_qd == pytest.approx(4 / (4 + met.beta * 4), abs=1e-6)
    assert s_dq == pytest.approx(4 / (4 + met.alpha * 4), abs=1e-6)
    assert s_qd != s_dq
    # symmetric metrics stay symmetric on the same pair
    for sym in METRICS[:3] + [resolve_metric("tversky")]:
        assert _oracle(sym, q, d)[0, 0] == _oracle(sym, d, q)[0, 0]


@pytest.mark.parametrize("metric", METRICS + [UNBOUNDED],
                         ids=M_IDS + [UNBOUNDED.spec])
def test_degenerate_fingerprints(metric):
    empty = np.zeros((1, 32), dtype=np.uint32)
    ones = np.full((1, 32), 0xFFFFFFFF, dtype=np.uint32)
    some = DB[:3]
    # empty vs anything (and vs itself) scores 0
    for other in (empty, ones, some):
        assert np.all(_oracle(metric, empty, other) == 0.0)
        assert np.all(_oracle(metric, other, empty) == 0.0)
    # q == d scores exactly 1 (all-ones included)
    for row in (ones, some):
        np.testing.assert_array_equal(
            np.diagonal(_oracle(metric, row, row)), np.float32(1.0))


# -- engine conformance ------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "tpu"])
@pytest.mark.parametrize("metric", METRICS, ids=M_IDS)
def test_brute_engine_matches_oracle(metric, backend):
    eng = BruteForceEngine(DB, backend=backend, metric=metric)
    ids, vals = eng.search(QUERIES, K)
    _assert_results_match_oracle(metric, ids, vals,
                                 _oracle(metric, QUERIES, DB), K)


@pytest.mark.parametrize("metric", METRICS, ids=M_IDS)
def test_bitbound_m1_soundness_and_parity(metric):
    """m=1 two-stage scan: the only candidate filter is the metric's
    popcount window, so no qualifying (>= cutoff) true top-k value may go
    missing — and the three backends must agree exactly."""
    oracle = _oracle(metric, QUERIES, DB)
    for cutoff in (0.3, 0.5):
        results = {}
        for backend in ("numpy", "jnp", "tpu"):
            eng = BitBoundFoldingEngine(DB, cutoff=cutoff, m=1,
                                        backend=backend, metric=metric)
            results[backend] = eng.search(QUERIES, K)
        for backend in ("jnp", "tpu"):
            _assert_tie_equivalent(*results["numpy"], *results[backend],
                                   label=f"{metric.spec} m=1 numpy vs "
                                         f"{backend} Sc={cutoff}",
                                   oracle=oracle)
        _assert_results_match_oracle(metric, *results["numpy"], oracle, K,
                                     cutoff=cutoff)


@pytest.mark.parametrize("m", [2, 4])
@pytest.mark.parametrize("metric", METRICS, ids=M_IDS)
def test_bitbound_folded_backend_parity(metric, m):
    """m>1 adds the stage-1 fold truncation heuristic (a recall knob shared
    by all metrics, Tanimoto included) — the conformance property is exact
    backend parity against the numpy fold-aware reference."""
    results = {}
    for backend in ("numpy", "jnp", "tpu"):
        eng = BitBoundFoldingEngine(DB, cutoff=0.4, m=m, backend=backend,
                                    metric=metric)
        results[backend] = eng.search(QUERIES, K)
    oracle = _oracle(metric, QUERIES, DB)
    for backend in ("jnp", "tpu"):
        _assert_tie_equivalent(*results["numpy"], *results[backend],
                               label=f"{metric.spec} m={m} numpy vs "
                                     f"{backend}", oracle=oracle)
    # every returned candidate's score is the true metric score
    ids, vals = (np.asarray(x) for x in results["numpy"])
    for qi in range(ids.shape[0]):
        for i, v in zip(ids[qi], vals[qi]):
            if i >= 0 and np.isfinite(v):
                assert oracle[qi, i] == v


def test_bitbound_unbounded_metric_full_scans():
    """tversky(0,0) has no sound popcount window in either direction: the
    engine must widen to a full scan and report it through ``scanned``."""
    assert not UNBOUNDED.bounded
    for backend in ("numpy", "jnp"):
        eng = BitBoundFoldingEngine(DB, cutoff=0.5, m=1, backend=backend,
                                    metric=UNBOUNDED)
        ids, vals = eng.search(QUERIES, K)
        # full scan: nothing pruned (the window may also sweep the store's
        # power-of-two capacity pad rows, so >= rather than ==)
        assert eng.scanned(len(QUERIES)) >= len(QUERIES) * eng.n_total, \
            backend
        # everything overlapping scores 1.0 under tversky(0,0)
        assert np.all(np.asarray(vals) == 1.0)


@pytest.mark.parametrize("backend,layout",
                         [("numpy", "rows"), ("jnp", "rows"),
                          ("jnp", "blocked"), ("tpu", "rows")])
@pytest.mark.parametrize("metric", METRICS, ids=M_IDS)
def test_hnsw_backend_parity_and_rescore(metric, backend, layout):
    """One graph (built under the metric on the host) searched through
    every traversal path: score vectors bit-equal to the numpy reference,
    ids equal within tie groups, every id rescored at its true score."""
    index = hn.build_hnsw(DB, m=6, ef_construction=20, seed=3, metric=metric)
    ref_eng = HNSWEngine(DB, index=index, backend="numpy", ef_search=24)
    ref_ids, ref_vals = ref_eng.search(QUERIES, K)
    eng = HNSWEngine(DB, index=index, backend=backend, layout=layout,
                     ef_search=24)
    ids, vals = eng.search(QUERIES, K)
    oracle = _oracle(metric, QUERIES, DB)
    _assert_tie_equivalent(ref_ids, ref_vals, ids, vals,
                           label=f"{metric.spec} hnsw numpy vs "
                                 f"{backend}/{layout}", oracle=oracle)
    ids, vals = np.asarray(ids), np.asarray(vals)
    for qi in range(ids.shape[0]):
        for i, v in zip(ids[qi], vals[qi]):
            if i >= 0 and np.isfinite(v):
                assert oracle[qi, i] == v, f"q{qi} id {i}"


def test_hnsw_engine_refuses_metric_mismatch():
    index = hn.build_hnsw(DB[:200], m=4, ef_construction=10, seed=0,
                          metric=Metric("dice"))
    with pytest.raises(ValueError, match="metric"):
        HNSWEngine(DB[:200], index=index, metric="cosine")
    # matching (or inherited) metric is fine
    eng = HNSWEngine(DB[:200], index=index)
    assert eng.metric == Metric("dice")


# -- Tanimoto-default identity ----------------------------------------------

def test_tanimoto_default_identity():
    """metric=None, metric="tanimoto" and metric=TANIMOTO must be the same
    engine configuration — same scores, same ids, same everything."""
    assert resolve_metric(None) == TANIMOTO
    base = BruteForceEngine(DB, backend="jnp").search(QUERIES, K)
    for spec in ("tanimoto", TANIMOTO):
        got = BruteForceEngine(DB, backend="jnp", metric=spec) \
            .search(QUERIES, K)
        np.testing.assert_array_equal(np.asarray(base[0]),
                                      np.asarray(got[0]))
        np.testing.assert_array_equal(np.asarray(base[1]),
                                      np.asarray(got[1]))
    b1 = BitBoundFoldingEngine(DB, cutoff=0.4, m=2, backend="jnp")
    b2 = BitBoundFoldingEngine(DB, cutoff=0.4, m=2, backend="jnp",
                               metric=TANIMOTO)
    r1, r2 = b1.search(QUERIES, K), b2.search(QUERIES, K)
    np.testing.assert_array_equal(np.asarray(r1[0]), np.asarray(r2[0]))
    np.testing.assert_array_equal(np.asarray(r1[1]), np.asarray(r2[1]))


# -- BitBound window soundness (property) ------------------------------------

@settings(max_examples=24, deadline=None)
@given(st.sampled_from(METRICS),
       st.floats(min_value=0.05, max_value=0.95, width=32),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_bound_window_soundness(metric, cutoff, seed):
    """The defining BitBound property, per metric: any candidate scoring
    >= cutoff has its popcount inside [ceil(a*lo), floor(a*hi)] — checked
    against random fingerprints at several densities and widths."""
    rng = np.random.default_rng(seed)
    words = int(rng.choice([4, 7, 32]))
    density = float(rng.uniform(0.05, 0.6))
    db = pack_bits(rng.random((64, words * 32)) < density)
    q = pack_bits(rng.random((4, words * 32)) < density)
    a, b, c = _triples(q, db)
    scores = metric_from_counts_np(metric, c, a[:, None], b[None, :])
    lo_r, hi_r = metric.bound_ratios(cutoff)
    for qi in range(q.shape[0]):
        qual = scores[qi] >= cutoff
        if not qual.any():
            continue
        bq = b[qual]
        if metric.bounded_below:
            assert np.all(bq >= np.ceil(a[qi] * lo_r)), \
                f"{metric.spec} Sc={cutoff}: qualifying count below window"
        if metric.bounded_above:
            assert np.all(bq <= np.floor(a[qi] * hi_r)), \
                f"{metric.spec} Sc={cutoff}: qualifying count above window"


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(METRICS),
       st.sampled_from([0.35, 0.55]),
       st.integers(min_value=0, max_value=10_000))
def test_bitbound_m1_never_drops_qualifying_topk(metric, cutoff, seed):
    """Engine-level soundness sweep: at m=1 (pure window pruning, no fold
    truncation) every true top-k member scoring >= cutoff is returned, for
    arbitrary databases."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(60, 200))
    db = np.asarray(synthetic_fingerprints(
        SyntheticConfig(n=n, seed=int(rng.integers(0, 1000)))))
    qs = np.asarray(queries_from_db(db, 3, seed=int(rng.integers(0, 1000))))
    k = 5
    eng = BitBoundFoldingEngine(db, cutoff=cutoff, m=1, backend="numpy",
                                metric=metric)
    ids, vals = eng.search(qs, k)
    _assert_results_match_oracle(metric, ids, vals, _oracle(metric, qs, db),
                                 k, cutoff=cutoff)


# -- variable widths ---------------------------------------------------------

ODD_DB = np.asarray(synthetic_fingerprints(
    SyntheticConfig(n=300, length=224, seed=5)))       # 7 words: off-lane
ODD_QS = np.asarray(queries_from_db(ODD_DB, 4, seed=6))


@pytest.mark.parametrize("metric", [METRICS[0], METRICS[2]],
                         ids=[METRICS[0].spec, METRICS[2].spec])
def test_odd_width_brute_and_bitbound(metric):
    assert ODD_DB.shape[1] == 7
    oracle = _oracle(metric, ODD_QS, ODD_DB)
    for backend in ("jnp", "tpu"):
        ids, vals = BruteForceEngine(ODD_DB, backend=backend,
                                     metric=metric).search(ODD_QS, K)
        _assert_results_match_oracle(metric, ids, vals, oracle, K)
    # folded stage-1 at m=2 pads ceil(7/2)=4 words; backends stay in parity
    results = {}
    for backend in ("numpy", "jnp"):
        eng = BitBoundFoldingEngine(ODD_DB, cutoff=0.4, m=2,
                                    backend=backend, metric=metric)
        results[backend] = eng.search(ODD_QS, K)
    _assert_tie_equivalent(*results["numpy"], *results["jnp"],
                           label=f"{metric.spec} odd-width m=2")


def test_odd_width_hnsw():
    index = hn.build_hnsw(ODD_DB, m=4, ef_construction=12, seed=1,
                          metric=Metric("dice"))
    ref_eng = HNSWEngine(ODD_DB, index=index, backend="numpy", ef_search=16)
    dev_eng = HNSWEngine(ODD_DB, index=index, backend="jnp", ef_search=16)
    _assert_tie_equivalent(*ref_eng.search(ODD_QS, K),
                           *dev_eng.search(ODD_QS, K),
                           label="dice odd-width hnsw")


def test_fp_bits_validation():
    # declared width must match the data
    with pytest.raises(ValueError, match="fp_bits"):
        BruteForceEngine(ODD_DB, fp_bits=1024)
    with pytest.raises(ValueError, match="fp_bits"):
        BitBoundFoldingEngine(DB, fp_bits=224)
    # matching declaration is accepted and echoed back resolved
    eng = BruteForceEngine(ODD_DB, fp_bits=224)
    assert eng.fp_bits == 224
    eng = HNSWEngine(DB[:100], m=4, ef_construction=8, fp_bits=1024)
    assert eng.fp_bits == 1024
