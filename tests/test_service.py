"""SearchService behaviour: dynamic micro-batching, engine routing, insert
broadcast + compaction, telemetry, and the service-level insert-then-search
parity acceptance (ISSUE 3)."""
import numpy as np
import pytest

from repro.core import BruteForceEngine, BitBoundFoldingEngine, HNSWEngine
from repro.data.molecules import (SyntheticConfig, queries_from_db,
                                  synthetic_fingerprints)
from repro.serve import SearchService


@pytest.fixture(scope="module")
def data():
    db = synthetic_fingerprints(SyntheticConfig(n=600, seed=0))
    extra = synthetic_fingerprints(SyntheticConfig(n=80, seed=5))
    q = queries_from_db(db, 12, seed=2)
    return db, extra, q


def test_micro_batching_matches_direct_engine(data):
    db, extra, q = data
    svc = SearchService(db, engines=("brute", "bitbound-folding"),
                        backend="jnp", k=8, cutoff=0.4, fold_m=2)
    # mixed request sizes -> one (engine, k) batch padded to a pow2 bucket
    bb = "bitbound-folding"
    r1 = svc.submit(q[0], engine=bb)            # single-row request
    r2 = svc.submit(q[1:4], engine=bb)
    r3 = svc.submit(q[4:9], engine=bb)
    r4 = svc.submit(q[:2], engine="brute")
    done = svc.flush()
    assert set(done) == {r1, r2, r3, r4}
    # per-request slices equal a direct engine call on the same batch
    eng = svc.engines["bitbound-folding"]
    ids, sims = eng.search(q[:9], 8)
    for rid, sl in ((r1, slice(0, 1)), (r2, slice(1, 4)), (r3, slice(4, 9))):
        np.testing.assert_array_equal(done[rid][0], ids[sl])
        np.testing.assert_array_equal(done[rid][1], sims[sl])
    bids, bsims = svc.engines["brute"].search(q[:2], 8)
    np.testing.assert_array_equal(done[r4][0], bids)
    np.testing.assert_array_equal(done[r4][1], bsims)
    # batches padded to power-of-two buckets; zero-pad queries are dropped
    buckets = [b["bucket"] for b in svc.batches]
    assert all(b & (b - 1) == 0 for b in buckets)
    assert sorted(buckets) == [2, 16]          # 9 queries -> 16, 2 -> 2
    assert len(svc.latencies_ms) == 4


def test_router_rejects_unknown_engine(data):
    db, _, q = data
    svc = SearchService(db, engines=("brute",))
    with pytest.raises(ValueError, match="engine"):
        svc.submit(q[0], engine="hnsw")
    with pytest.raises(ValueError, match="engine"):
        SearchService(db, engines=("fpga",))


def test_insert_broadcast_and_compaction_counts(data):
    db, extra, q = data
    svc = SearchService(db, engines=("brute", "bitbound-folding"),
                        backend="jnp", compact_threshold=50)
    g = svc.insert(extra[:30])
    np.testing.assert_array_equal(g, np.arange(600, 630))
    assert svc.compactions == 0
    svc.insert(extra[30:60])                   # both stores cross threshold
    assert svc.compactions == 2                # one per store-backed engine
    for eng in svc.engines.values():
        assert eng.n_total == 660
    s = svc.summary()
    assert s["n_inserts"] == 60 and s["compactions"] == 2


def test_service_parity_with_rebuilt_engines(data):
    """Acceptance: a service interleaving inserts and queries (across a
    compaction) returns bit-identical results to from-scratch engines on the
    concatenated database — for all three engines behind one service."""
    db, extra, q = data
    svc = SearchService(db, engines=("brute", "bitbound-folding", "hnsw"),
                        backend="jnp", k=10, cutoff=0.4, fold_m=2,
                        compact_threshold=48, hnsw_m=6,
                        hnsw_ef_construction=24, hnsw_ef_search=24, seed=3)
    svc.search(q[:4], 10)                      # pre-insert traffic
    svc.insert(extra[:20])
    mids = {n: svc.search(q, 10, engine=n) for n in svc.engines}
    svc.insert(extra[20:60])                   # crosses the threshold
    assert svc.compactions == 2
    finals = {n: svc.search(q, 10, engine=n) for n in svc.engines}

    mid_db = np.concatenate([db, extra[:20]])
    full_db = np.concatenate([db, extra[:60]])
    rebuilds = {
        "brute": lambda d: BruteForceEngine(d, backend="jnp"),
        "bitbound-folding": lambda d: BitBoundFoldingEngine(
            d, cutoff=0.4, m=2, backend="jnp"),
        "hnsw": lambda d: HNSWEngine(d, m=6, ef_construction=24,
                                     ef_search=24, seed=3, backend="jnp"),
    }
    for name, make in rebuilds.items():
        for stage, d, got in (("mid", mid_db, mids[name]),
                              ("final", full_db, finals[name])):
            rids, rsims = make(d).search(q, 10)
            np.testing.assert_array_equal(got[0], rids,
                                          err_msg=f"{name} {stage}")
            np.testing.assert_array_equal(got[1], rsims,
                                          err_msg=f"{name} {stage}")


def test_telemetry_summary_fields(data):
    db, _, q = data
    fake_t = [0.0]

    def clock():
        fake_t[0] += 0.001                     # deterministic 1ms steps
        return fake_t[0]

    svc = SearchService(db, engines=("brute",), backend="jnp", k=5,
                        clock=clock)
    for i in range(6):
        svc.submit(q[i])
    svc.flush()
    svc.search(q[:3], 5)
    s = svc.summary()
    assert s["n_queries"] == 9
    assert s["qps"] > 0 and s["search_time_s"] > 0
    assert s["p50_ms"] > 0 and s["p99_ms"] >= s["p50_ms"]
    assert s["batch_buckets"] == {8: 1, 4: 1}  # 6 -> 8, 3 -> 4
    assert s["scanned"]["brute"] > 0
    assert s["engines"] == {"brute": "jnp"}
    assert svc.compiled_pipelines() > 0


def test_flush_chunks_oversized_batches(data):
    db, _, q = data
    svc = SearchService(db, engines=("brute",), backend="jnp", k=5,
                        max_batch=4)
    rid = svc.submit(q[:10])                   # > max_batch -> 3 chunks
    done = svc.flush()
    ids, sims = done[rid]
    assert ids.shape == (10, 5)
    rids, rsims = svc.engines["brute"].search(q[:10], 5)
    np.testing.assert_array_equal(ids, rids)
    np.testing.assert_array_equal(sims, rsims)
    assert [b["bucket"] for b in svc.batches] == [4, 4, 2]


def test_compact_all_pins_delta_phase(data):
    db, extra, _ = data
    svc = SearchService(db, engines=("brute",), compact_threshold=1000)
    svc.insert(extra[:7])
    assert svc.engines["brute"].store.n_delta == 7
    svc.compact_all()
    assert svc.engines["brute"].store.n_delta == 0
    assert svc.compactions == 1


# -- lifecycle under concurrency (ISSUE 9 satellite) --------------------------

def test_close_idempotent(data, tmp_path):
    db, extra, _ = data
    svc = SearchService(db, engines=("brute",), durable_dir=str(tmp_path))
    svc.insert(extra[:5])
    svc.close()
    svc.close()                          # second close: no-op, no raise
    with pytest.raises(RuntimeError, match="closed"):
        svc.snapshot()
    reopened = SearchService.open(tmp_path)
    assert reopened.n_total == len(db) + 5
    reopened.close()


def test_close_during_background_snapshot_from_other_thread(data, tmp_path):
    """close() racing a background snapshot writer from another thread must
    wait the writer out (the snapshot publishes; the WAL closes after its
    final unpin) instead of closing the WAL underneath it."""
    import threading

    from repro.checkpoint.fs import Fs

    class GatedFs(Fs):
        def __init__(self):
            self.armed = False
            self.entered = threading.Event()
            self.gate = threading.Event()

        def replace(self, src, dst):
            if self.armed:
                self.entered.set()
                assert self.gate.wait(30), "test gate never released"
            super().replace(src, dst)

    db, extra, _ = data
    fs = GatedFs()
    svc = SearchService(db, engines=("brute",), durable_dir=str(tmp_path),
                        fs=fs)
    svc.insert(extra[:10])
    fs.armed = True
    sid = svc.snapshot(background=True)
    assert fs.entered.wait(30), "background writer never started publishing"
    closer = threading.Thread(target=svc.close)
    closer.start()
    closer.join(timeout=0.5)
    assert closer.is_alive(), "close() returned while the writer was gated"
    fs.gate.set()
    closer.join(timeout=30)
    assert not closer.is_alive(), "close() never finished after the writer"
    assert svc._wal is None
    svc.close()                          # still idempotent afterwards
    reopened = SearchService.open(tmp_path)
    assert reopened._snap_id == sid      # the raced snapshot did publish
    assert reopened.n_total == len(db) + 10
    reopened.close()
