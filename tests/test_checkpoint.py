"""Checkpoint manager: atomic publish, integrity, retention, restore."""
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, save_checkpoint, restore_checkpoint
from repro.checkpoint.fs import CrashPointFs, InjectedCrash
from repro.checkpoint.manager import (latest_step, load_array_snapshot,
                                      load_latest_intact,
                                      save_array_snapshot)


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (8, 4)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 3, t)
    restored = restore_checkpoint(tmp_path, 3, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_integrity_check_detects_corruption(tmp_path):
    t = _tree()
    path = save_checkpoint(tmp_path, 1, t)
    victim = sorted(path.glob("leaf_*.npy"))[0]
    raw = bytearray(victim.read_bytes())
    raw[-1] ^= 0xFF
    victim.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="integrity"):
        restore_checkpoint(tmp_path, 1, t)


def test_atomic_publish_no_tmp_left(tmp_path):
    save_checkpoint(tmp_path, 2, _tree())
    assert not any(p.name.startswith(".tmp") for p in tmp_path.iterdir())


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s), blocking=True)
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]
    assert mgr.latest() == 4


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(7, _tree(7))
    mgr.wait()
    assert latest_step(tmp_path) == 7
    step, restored = mgr.restore_latest(_tree(7))
    assert step == 7


def test_structure_mismatch_rejected(tmp_path):
    save_checkpoint(tmp_path, 5, _tree())
    with pytest.raises(AssertionError):
        restore_checkpoint(tmp_path, 5, {"only": jnp.zeros(3)})


# -- named-array snapshot corruption paths (ISSUE 6 satellite) ---------------

def _arrays(seed=0):
    rng = np.random.default_rng(seed)
    return {"x": rng.integers(0, 9, size=(6, 3), dtype=np.int64),
            "nested/y": rng.random(5).astype(np.float32)}


def test_snapshot_truncated_npy_detected(tmp_path):
    save_array_snapshot(tmp_path, 0, _arrays(), {"gen": 0})
    victim = sorted((tmp_path / "snap_00000000").glob("arr_*.npy"))[0]
    victim.write_bytes(victim.read_bytes()[:-9])
    with pytest.raises(IOError):
        load_array_snapshot(tmp_path, 0)


def test_snapshot_sha_mismatch_detected(tmp_path):
    save_array_snapshot(tmp_path, 0, _arrays(), {"gen": 0})
    mpath = tmp_path / "snap_00000000" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["arrays"][0]["sha256"] = "0" * 64
    mpath.write_text(json.dumps(manifest))
    with pytest.raises(IOError, match="integrity"):
        load_array_snapshot(tmp_path, 0)
    # without verification the (undamaged) arrays still load
    arrays, _ = load_array_snapshot(tmp_path, 0, verify=False)
    np.testing.assert_array_equal(arrays["x"], _arrays()["x"])


def test_crash_between_tempwrite_and_rename_keeps_latest(tmp_path):
    """A write that dies after the temp dir is complete but before the
    atomic rename must leave the previous generation as latest-intact."""
    save_array_snapshot(tmp_path, 0, _arrays(0), {"gen": 0})

    class NoRenameFs(CrashPointFs):
        def replace(self, src, dst):
            raise InjectedCrash("before rename")

    with pytest.raises(InjectedCrash):
        save_array_snapshot(tmp_path, 1, _arrays(1), {"gen": 1},
                            fs=NoRenameFs())
    assert (tmp_path / ".tmp_snap_00000001").exists()   # orphan, not a snap
    step, arrays, meta = load_latest_intact(tmp_path)
    assert step == 0 and meta == {"gen": 0}
    np.testing.assert_array_equal(arrays["x"], _arrays(0)["x"])
    # the next successful save reclaims the orphan temp dir
    save_array_snapshot(tmp_path, 1, _arrays(1), {"gen": 1})
    assert not (tmp_path / ".tmp_snap_00000001").exists()
    step, _, meta = load_latest_intact(tmp_path)
    assert step == 1 and meta == {"gen": 1}


def test_torn_snapshot_write_walks_back(tmp_path):
    """Tear the second generation's write at several depths (first leaf,
    mid-leaves, inside the manifest): walk-back must always restore the
    intact first generation."""
    save_array_snapshot(tmp_path, 0, _arrays(0), {"gen": 0})
    probe = CrashPointFs()             # measure the fault-free write size
    save_array_snapshot(tmp_path / "probe", 1, _arrays(1), {"gen": 1},
                        fs=probe)
    total = probe.bytes_written
    for frac in (0.01, 0.35, 0.75, 0.98):
        with pytest.raises(InjectedCrash):
            save_array_snapshot(tmp_path, 1, _arrays(1), {"gen": 1},
                                fs=CrashPointFs(
                                    byte_budget=max(1, int(total * frac))))
        step, arrays, meta = load_latest_intact(tmp_path)
        assert step == 0 and meta == {"gen": 0}, f"frac={frac}"
        np.testing.assert_array_equal(arrays["nested/y"],
                                      _arrays(0)["nested/y"])
