"""Checkpoint manager: atomic publish, integrity, retention, restore."""
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, save_checkpoint, restore_checkpoint
from repro.checkpoint.manager import latest_step


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (8, 4)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 3, t)
    restored = restore_checkpoint(tmp_path, 3, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_integrity_check_detects_corruption(tmp_path):
    t = _tree()
    path = save_checkpoint(tmp_path, 1, t)
    victim = sorted(path.glob("leaf_*.npy"))[0]
    raw = bytearray(victim.read_bytes())
    raw[-1] ^= 0xFF
    victim.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="integrity"):
        restore_checkpoint(tmp_path, 1, t)


def test_atomic_publish_no_tmp_left(tmp_path):
    save_checkpoint(tmp_path, 2, _tree())
    assert not any(p.name.startswith(".tmp") for p in tmp_path.iterdir())


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s), blocking=True)
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]
    assert mgr.latest() == 4


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(7, _tree(7))
    mgr.wait()
    assert latest_step(tmp_path) == 7
    step, restored = mgr.restore_latest(_tree(7))
    assert step == 7


def test_structure_mismatch_rejected(tmp_path):
    save_checkpoint(tmp_path, 5, _tree())
    with pytest.raises(AssertionError):
        restore_checkpoint(tmp_path, 5, {"only": jnp.zeros(3)})
