"""Dry-run machinery: HLO loop-aware analysis + one real (reduced-size)
multi-device lowering through the exact dryrun code path, in a subprocess."""
import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_hlo_loop_aware_flops_exact():
    import jax, jax.numpy as jnp
    from repro.launch.hlo_analysis import analyse_hlo

    def f(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=9)
        return y

    txt = jax.jit(f).lower(jnp.ones((64, 64))).compile().as_text()
    r = analyse_hlo(txt)
    assert r["dot_flops"] == 9 * 2 * 64 ** 3


def test_hlo_nested_loops():
    import jax
    import jax.numpy as jnp
    from repro.launch.hlo_analysis import analyse_hlo

    def inner(c):
        y, _ = jax.lax.scan(lambda a, _: (a @ a, None), c, None, length=3)
        return y

    def f(x):
        y, _ = jax.lax.scan(lambda c, _: (inner(c), None), x, None, length=5)
        return y

    txt = jax.jit(f).lower(jnp.ones((32, 32))).compile().as_text()
    r = analyse_hlo(txt)
    assert r["dot_flops"] == 5 * 3 * 2 * 32 ** 3


def test_collective_bytes_parsing():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ar = bf16[16,128]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = f32[4,256]{1,0} all-gather(%y), dimensions={0}
  %done = bf16[16,128]{1,0} all-reduce-done(%ar)
"""
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 16 * 128 * 2
    assert got["all-gather"] == 4 * 256 * 4
    assert got["counts"]["all-reduce"] == 1  # -done not double-counted


def test_dryrun_cell_reduced_subprocess():
    """Exercise lower_cell end-to-end with 16 placeholder devices and a
    shrunken mesh (monkeypatched) — proves the plumbing without the cost of
    a 512-way compile inside the test suite."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = """
        import jax
        import repro.launch.mesh as mesh_mod
        def small_mesh(*, multi_pod=False):
            shape = (2, 2, 4) if multi_pod else (4, 4)
            axes = ("pod", "data", "model") if multi_pod else ("data", "model")
            return mesh_mod.compat_make_mesh(shape, axes)
        mesh_mod.make_production_mesh = small_mesh
        import repro.launch.dryrun as dr
        dr.make_production_mesh = small_mesh
        import repro.configs as C
        cfg = C.get_arch("granite-3-2b").reduced().with_(n_layers=4)
        C.ARCHS["tiny-test"] = cfg
        for mp in (False, True):
            res = dr.lower_cell("tiny-test", "train_4k", multi_pod=mp)
            assert res["flops"] > 0, res
            assert res["loop_aware"]["dot_flops"] > res["flops"] * 0.5
        res = dr.lower_cell("tiny-test", "decode_32k")
        assert "error" not in res
        print("DRYRUN_OK")
    """
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "DRYRUN_OK" in out.stdout
