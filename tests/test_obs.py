"""Observability primitives (ISSUE 8): metrics registry semantics
(counters/gauges/log-bucketed histograms, quantile error bound, thread
safety, Prometheus + JSONL exposition round-tripping through the schema
validators) and trace spans (nesting/parent linkage, Chrome export shape,
bounded buffer, and the allocation-free disabled fast path)."""
import json
import threading

import pytest

from repro.obs.metrics import (GROWTH, MetricsRegistry, NULL_METRICS,
                               NullMetrics)
from repro.obs.schema import (validate_metrics_jsonl, validate_trace,
                              validate_trace_file)
from repro.obs.trace import NULL_HANDLE, NULL_SPAN, Tracer


# -- registry ---------------------------------------------------------------

def test_counter_gauge_labels():
    m = MetricsRegistry()
    c = m.counter("q_total", "queries", labels=("engine",))
    c.inc(engine="brute")
    c.inc(3, engine="hnsw")
    assert c.value(engine="brute") == 1
    assert c.value(engine="hnsw") == 3
    assert c.value(engine="never-touched") == 0
    assert c.total() == 4
    g = m.gauge("depth")
    g.set(7)
    g.set(2)                      # last write wins
    assert g.value() == 2
    # same name re-registration must return the same family ...
    assert m.counter("q_total", labels=("engine",)) is c
    # ... and a kind/label mismatch is a hard error, not silent aliasing
    with pytest.raises(ValueError, match="re-registered"):
        m.gauge("q_total")
    with pytest.raises(ValueError, match="labels"):
        c.inc(backend="jnp")


def test_histogram_quantile_error_bound():
    m = MetricsRegistry()
    h = m.histogram("lat_ms")
    for v in range(1, 1001):
        h.observe(float(v))
    assert h.count() == 1000
    assert h.mean() == pytest.approx(500.5)     # sum/count is exact
    for q, truth in ((0.5, 500.0), (0.99, 990.0)):
        est = h.quantile(q)
        # log-bucketed with 8 buckets/doubling: ~9% max relative error
        assert abs(est - truth) / truth < GROWTH - 1 + 0.02, (q, est)


def test_histogram_single_value_exact():
    m = MetricsRegistry()
    h = m.histogram("lat_ms")
    for _ in range(3):
        h.observe(7.3)
    # quantiles clamp to the observed [min, max] -> exact here
    assert h.quantile(0.5) == 7.3
    assert h.quantile(0.99) == 7.3
    assert m.histogram("empty").quantile(0.5) is None
    assert m.histogram("empty").mean() is None


def test_registry_thread_safety():
    m = MetricsRegistry()
    c = m.counter("n", labels=("t",))
    h = m.histogram("h")

    def work(tid):
        for i in range(5000):
            c.inc(t=str(tid % 2))
            h.observe(float(i % 17) + 0.5)

    threads = [threading.Thread(target=work, args=(t,)) for t in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.total() == 8 * 5000
    assert h.count() == 8 * 5000


def test_prometheus_render_shape():
    m = MetricsRegistry()
    m.counter("req_total", "requests", labels=("engine",)).inc(5,
                                                               engine="brute")
    h = m.histogram("lat_ms", "latency")
    h.observe(1.0)
    h.observe(100.0)
    text = m.render_prometheus()
    assert "# TYPE req_total counter" in text
    assert 'req_total{engine="brute"} 5' in text
    assert "# TYPE lat_ms histogram" in text
    assert "lat_ms_count 2" in text
    assert "lat_ms_sum 101" in text
    # cumulative bucket exposition ends at the +Inf edge with the count
    bucket_lines = [l for l in text.splitlines() if "lat_ms_bucket" in l]
    assert bucket_lines and bucket_lines[-1].endswith(" 2")


def test_jsonl_export_round_trips_schema(tmp_path):
    m = MetricsRegistry()
    m.counter("service_queries_total", labels=("engine",)).inc(4,
                                                               engine="brute")
    m.counter("service_scanned_total", labels=("engine",)).inc(1024,
                                                               engine="brute")
    h = m.histogram("service_request_latency_ms", labels=("engine",))
    for v in (0.5, 1.5, 2.5, 200.0):
        h.observe(v, engine="brute")
    m.gauge("service_compactions").set(2)
    path = tmp_path / "metrics.jsonl"
    n = m.export_jsonl(path, ts=123.0)
    assert n == 4
    assert validate_metrics_jsonl(path) == []     # serving-family floor met
    rows = {r["name"]: r for r in map(json.loads, path.read_text().splitlines())}
    lat = rows["service_request_latency_ms"]
    assert lat["count"] == 4 and lat["min"] == 0.5 and lat["max"] == 200.0
    assert sum(lat["buckets"].values()) == 4
    # reset zeroes children but keeps family declarations
    m.reset()
    assert m.family("service_queries_total").total() == 0
    assert m.collect() == []


def test_metrics_schema_catches_corruption(tmp_path):
    bad = tmp_path / "bad.jsonl"
    bad.write_text(json.dumps({"name": "x", "type": "histogram",
                               "labels": {}, "count": 5, "sum": 1.0,
                               "buckets": {"1": 3}}) + "\n")
    errs = validate_metrics_jsonl(bad, require_families=())
    assert any("bucket counts sum" in e for e in errs)


def test_null_metrics_surface():
    n = NULL_METRICS
    assert isinstance(n, NullMetrics) and n.enabled is False
    fam = n.counter("anything", labels=("x",))
    fam.inc(5, x="y")
    fam.observe(1.0)
    fam.set(2.0)
    assert fam.total() == 0 and fam.quantile(0.5) is None
    assert n.collect() == [] and n.render_prometheus() == ""


# -- tracer -----------------------------------------------------------------

def test_disabled_span_fast_path():
    tr = Tracer(enabled=False)
    # acceptance: no span object is allocated when tracing is off — every
    # call returns the module-level singletons and records nothing
    assert tr.span("a") is NULL_SPAN
    assert tr.span("b", key="val") is tr.span("c")
    assert tr.begin("d", track="t") is NULL_HANDLE
    with tr.span("e") as s:
        s.set(answer=42)
    tr.begin("f").end(done=True)
    tr.emit("g", 0.0, 1.0)
    assert tr.events == [] and tr.dropped_events == 0


def test_span_nesting_and_parent_linkage():
    tr = Tracer(enabled=True)
    with tr.span("outer", engine="brute"):
        with tr.span("inner") as s:
            s.set(rows=8)
    by_name = {e["name"]: e for e in tr.events}
    assert by_name["inner"]["args"]["parent"] == "outer"
    assert by_name["inner"]["args"]["rows"] == 8
    assert "parent" not in by_name["outer"]["args"]
    # inner is contained in outer on the timeline
    o, i = by_name["outer"], by_name["inner"]
    assert o["ts"] <= i["ts"]
    assert i["ts"] + i["dur"] <= o["ts"] + o["dur"] + 1e-6
    assert validate_trace(tr.to_chrome()) == []


def test_flow_handles_and_tracks():
    tr = Tracer(enabled=True)
    h = tr.begin("transfer", track="h2d-stream", chunk=0)
    h.end(bytes=4096)
    tr.emit("stall", 0.0, 0.001, track="h2d-stream", chunk=0)
    names = [e["name"] for e in tr.events]
    assert "thread_name" in names            # track metadata emitted once
    meta = [e for e in tr.events if e["ph"] == "M"]
    assert len(meta) == 1 and meta[0]["args"]["name"] == "h2d-stream"
    tids = {e["tid"] for e in tr.events if e["ph"] == "X"}
    assert tids == {meta[0]["tid"]}          # both spans on the named track
    assert validate_trace(tr.to_chrome()) == []


def test_event_buffer_bounded():
    tr = Tracer(enabled=True, max_events=4)
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    assert len(tr.events) == 4
    assert tr.dropped_events == 6


def test_chrome_export_file(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("service.batch", engine="brute"):
        pass
    path = tmp_path / "trace.json"
    n = tr.export_chrome(path)
    assert n == 1
    assert validate_trace_file(path, require_spans=("service.batch",)) == []
    assert validate_trace_file(path, require_spans=("missing.span",)) \
        == ["required span 'missing.span' not present in trace"]
    obj = json.loads(path.read_text())
    assert obj["displayTimeUnit"] == "ms"
