"""Per-kernel allclose sweeps against the pure-jnp oracle (ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitbound as bb
from repro.data.molecules import SyntheticConfig, synthetic_fingerprints, queries_from_db
from repro.kernels import ops, ref


def _db(n, seed=0, length=1024):
    return synthetic_fingerprints(SyntheticConfig(n=n, seed=seed, length=length))


@pytest.mark.parametrize("n,q,k,tile", [
    (1000, 3, 5, 128),
    (2048, 2, 20, 512),
    (5000, 4, 100, 2048),   # k > tile-boundary interactions
    (300, 2, 10, 128),      # padded final tile
    (130, 1, 64, 128),      # k close to n, single tile + pad
])
def test_fused_topk_matches_oracle(n, q, k, tile):
    db = jnp.asarray(_db(n))
    qs = jnp.asarray(queries_from_db(np.asarray(db), q))
    ids, vals = ops.tanimoto_topk(qs, db, k=k, tile_n=tile)
    rids, rvals = ref.tanimoto_topk_ref(qs, db, k=k)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-6)
    # ids may differ between tied scores; returned ids must realise the values
    s = np.asarray(ref.tanimoto_scores_ref(qs, db))
    got = s[np.arange(q)[:, None], np.asarray(ids)]
    np.testing.assert_allclose(got, np.asarray(rvals), rtol=1e-6)


@pytest.mark.parametrize("length", [256, 512, 1024])
def test_fused_topk_fp_lengths(length):
    """Folded databases have shorter word counts — sweep W."""
    db = jnp.asarray(_db(1500, length=length))
    qs = jnp.asarray(queries_from_db(np.asarray(db), 3))
    ids, vals = ops.tanimoto_topk(qs, db, k=10, tile_n=256)
    _, rvals = ref.tanimoto_topk_ref(qs, db, k=10)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-6)


@pytest.mark.parametrize("cutoff,tile", [(0.2, 128), (0.4, 512), (0.8, 256),
                                         (0.95, 128)])
def test_bitbound_kernel_matches_oracle(cutoff, tile):
    db = _db(3000, seed=1)
    qs = jnp.asarray(queries_from_db(db, 4))
    idx = bb.build_index(jnp.asarray(db))
    ids, vals = ops.bitbound_topk(qs, idx.db, idx.counts, k=15, cutoff=cutoff,
                                  tile_n=tile)
    rids, rvals = ref.bitbound_topk_ref(qs, idx.db, idx.counts, k=15,
                                        cutoff=cutoff)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-6)
    # invalid entries agree
    np.testing.assert_array_equal(np.asarray(ids) < 0, np.asarray(rids) < 0)


def test_bitbound_kernel_restricted_window_grid():
    """max_tiles below the full DB: still exact when windows fit."""
    db = _db(4096, seed=2)
    qs = jnp.asarray(queries_from_db(db, 3))
    idx = bb.build_index(jnp.asarray(db))
    ids, vals = ops.bitbound_topk(qs, idx.db, idx.counts, k=10, cutoff=0.8,
                                  tile_n=256, max_tiles=8)
    _, rvals = ref.bitbound_topk_ref(qs, idx.db, idx.counts, k=10, cutoff=0.8)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-6)


# -- BitBound kernel edge cases (interpret mode vs ref.py) -------------------

def test_bitbound_kernel_empty_window():
    """A sparse query at a high cutoff has an empty Eq.2 window: every slot
    must come back id -1 / val -inf, exactly like the oracle."""
    db = _db(1000, seed=5)
    idx = bb.build_index(jnp.asarray(db))
    q = np.zeros((1, db.shape[1]), dtype=np.uint32)
    q[0, 0] = 0b11    # popcount 2 -> window is popcount {2} only
    assert not (np.asarray(idx.counts) == 2).any()
    qs = jnp.asarray(q)
    ids, vals = ops.bitbound_topk(qs, idx.db, idx.counts, k=8, cutoff=0.9,
                                  tile_n=128)
    rids, rvals = ref.bitbound_topk_ref(qs, idx.db, idx.counts, k=8,
                                        cutoff=0.9)
    assert (np.asarray(ids) == -1).all()
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(rids))
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(rvals))


def test_bitbound_kernel_window_smaller_than_k():
    """Eq.2 window holds fewer candidates than k: the valid prefix matches
    the oracle and the tail is -1 / -inf on both."""
    db = _db(2000, seed=6)
    idx = bb.build_index(jnp.asarray(db))
    counts = np.asarray(idx.counts)
    # query popcount = rarest count value -> tiny window at cutoff ~1
    vals_u, freq = np.unique(counts, return_counts=True)
    rare = int(vals_u[np.argmin(freq)])
    q_bits = np.zeros((1, db.shape[1] * 32), dtype=np.uint8)
    q_bits[0, :rare] = 1
    from repro.core import pack_bits
    qs = jnp.asarray(pack_bits(q_bits))
    k = int(freq.min()) + 10
    ids, vals = ops.bitbound_topk(qs, idx.db, idx.counts, k=k, cutoff=0.999,
                                  tile_n=256)
    rids, rvals = ref.bitbound_topk_ref(qs, idx.db, idx.counts, k=k,
                                        cutoff=0.999)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(ids) < 0, np.asarray(rids) < 0)
    assert (np.asarray(ids)[0, -1] == -1)   # tail really is unfilled


def test_bitbound_kernel_all_zero_query():
    """Popcount-0 query: window is the popcount-0 rows; Tanimoto with an
    empty print is defined as 0, never NaN/inf."""
    db = _db(1500, seed=7)
    db[:3] = 0    # make the zero-count window non-empty
    idx = bb.build_index(jnp.asarray(db))
    qs = jnp.zeros((2, db.shape[1]), dtype=jnp.uint32)
    ids, vals = ops.bitbound_topk(qs, idx.db, idx.counts, k=5, cutoff=0.8,
                                  tile_n=128)
    rids, rvals = ref.bitbound_topk_ref(qs, idx.db, idx.counts, k=5,
                                        cutoff=0.8)
    assert not np.isnan(np.asarray(vals)).any()
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(rvals))
    np.testing.assert_array_equal(np.asarray(ids) < 0, np.asarray(rids) < 0)
    # the zero rows are legitimate window members with similarity 0
    assert (np.asarray(ids)[:, :3] >= 0).all()


def test_bitbound_kernel_non_tile_aligned_n():
    """N not a multiple of the tile: padded tail rows must never appear."""
    db = _db(3001, seed=8)
    idx = bb.build_index(jnp.asarray(db))
    qs = jnp.asarray(queries_from_db(db, 4))
    ids, vals = ops.bitbound_topk(qs, idx.db, idx.counts, k=12, cutoff=0.5,
                                  tile_n=256)
    rids, rvals = ref.bitbound_topk_ref(qs, idx.db, idx.counts, k=12,
                                        cutoff=0.5)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-6)
    assert (np.asarray(ids) < 3001).all()


# -- row-window kernel (stage 1 of the device two-stage engine) --------------

def test_window_kernel_matches_oracle():
    """Per-query row windows incl. empty, tiny, full-DB and tail windows."""
    db = _db(3000, seed=3)
    qs = jnp.asarray(queries_from_db(db, 5))
    idx = bb.build_index(jnp.asarray(db))
    lo = jnp.asarray([100, 500, 700, 0, 2999], jnp.int32)
    hi = jnp.asarray([2500, 500, 705, 3000, 3000], jnp.int32)
    ids, vals = ops.window_topk(qs, idx.db, idx.counts, lo, hi, k=10,
                                tile_n=256)
    rids, rvals = ref.window_topk_ref(qs, idx.db, idx.counts, lo, hi, k=10)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(ids) < 0, np.asarray(rids) < 0)


def test_window_kernel_restricted_max_tiles():
    """Static grid smaller than the full DB stays exact when windows fit."""
    db = _db(4096, seed=9)
    qs = jnp.asarray(queries_from_db(db, 3))
    idx = bb.build_index(jnp.asarray(db))
    lo = jnp.asarray([0, 1000, 3000], jnp.int32)
    hi = jnp.asarray([900, 2000, 4096], jnp.int32)
    ids, vals = ops.window_topk(qs, idx.db, idx.counts, lo, hi, k=7,
                                tile_n=256, max_tiles=5)
    _, rvals = ref.window_topk_ref(qs, idx.db, idx.counts, lo, hi, k=7)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-6)


def test_window_kernel_folded_db_full_bounds():
    """The two-stage wiring: bounds from full-resolution popcounts, scores on
    the folded DB — the kernel must not consult folded popcounts for the
    window."""
    from repro.core import folding as fl
    db = _db(2000, seed=10)
    idx = bb.build_index(jnp.asarray(db))
    folded = jnp.asarray(fl.fold(np.asarray(idx.db), 4, 1))
    qs = jnp.asarray(fl.fold(queries_from_db(db, 3), 4, 1))
    lo = jnp.asarray([0, 600, 1990], jnp.int32)
    hi = jnp.asarray([500, 1400, 2000], jnp.int32)
    from repro.core.fingerprints import popcount
    fcnt = popcount(folded)
    ids, vals = ops.window_topk(qs, folded, fcnt, lo, hi, k=9, tile_n=128)
    rids, rvals = ref.window_topk_ref(qs, folded, fcnt, lo, hi, k=9)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-6)
    got = np.asarray(ids)
    for qi in range(3):
        ok = got[qi] >= 0
        assert (got[qi][ok] >= int(lo[qi])).all()
        assert (got[qi][ok] < int(hi[qi])).all()


def test_bitcount_kernel_sweep():
    for n, w in [(100, 8), (4096, 32), (5000, 16)]:
        rng = np.random.default_rng(n)
        words = jnp.asarray(rng.integers(0, 2**32, (n, w), dtype=np.uint32))
        got = np.asarray(ops.bitcount(words))
        np.testing.assert_array_equal(got, np.asarray(ref.bitcount_ref(words)))


def test_kernel_engine_integration(small_db, queries, brute_truth):
    """BruteForceEngine(use_kernel=True) == oracle top-k."""
    from repro.core import BruteForceEngine
    s, true_ids = brute_truth
    eng = BruteForceEngine(jnp.asarray(small_db), use_kernel=True)
    ids, vals = eng.search(queries, 20)
    expect = np.take_along_axis(s, true_ids, axis=1)
    np.testing.assert_allclose(vals, expect, rtol=1e-6)


@pytest.mark.parametrize("n,q,k,qb,tile", [
    (2000, 16, 10, 8, 256),
    (1500, 5, 20, 4, 512),     # Q padded up to qb multiple
    (4096, 32, 5, 16, 1024),
])
def test_blocked_topk_matches_oracle(n, q, k, qb, tile):
    """Query-blocked engine (one DB sweep per qb queries) stays exact."""
    db = jnp.asarray(_db(n, seed=4))
    qs = jnp.asarray(queries_from_db(np.asarray(db), q))
    ids, vals = ops.tanimoto_topk_blocked(qs, db, k=k, qb=qb, tile_n=tile)
    _, rvals = ref.tanimoto_topk_ref(qs, db, k=k)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-6)
    s = np.asarray(ref.tanimoto_scores_ref(qs, db))
    got = s[np.arange(q)[:, None], np.asarray(ids)]
    np.testing.assert_allclose(got, np.asarray(rvals), rtol=1e-6)
