"""Per-kernel allclose sweeps against the pure-jnp oracle (ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitbound as bb
from repro.data.molecules import SyntheticConfig, synthetic_fingerprints, queries_from_db
from repro.kernels import ops, ref


def _db(n, seed=0, length=1024):
    return synthetic_fingerprints(SyntheticConfig(n=n, seed=seed, length=length))


@pytest.mark.parametrize("n,q,k,tile", [
    (1000, 3, 5, 128),
    (2048, 2, 20, 512),
    (5000, 4, 100, 2048),   # k > tile-boundary interactions
    (300, 2, 10, 128),      # padded final tile
    (130, 1, 64, 128),      # k close to n, single tile + pad
])
def test_fused_topk_matches_oracle(n, q, k, tile):
    db = jnp.asarray(_db(n))
    qs = jnp.asarray(queries_from_db(np.asarray(db), q))
    ids, vals = ops.tanimoto_topk(qs, db, k=k, tile_n=tile)
    rids, rvals = ref.tanimoto_topk_ref(qs, db, k=k)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-6)
    # ids may differ between tied scores; returned ids must realise the values
    s = np.asarray(ref.tanimoto_scores_ref(qs, db))
    got = s[np.arange(q)[:, None], np.asarray(ids)]
    np.testing.assert_allclose(got, np.asarray(rvals), rtol=1e-6)


@pytest.mark.parametrize("length", [256, 512, 1024])
def test_fused_topk_fp_lengths(length):
    """Folded databases have shorter word counts — sweep W."""
    db = jnp.asarray(_db(1500, length=length))
    qs = jnp.asarray(queries_from_db(np.asarray(db), 3))
    ids, vals = ops.tanimoto_topk(qs, db, k=10, tile_n=256)
    _, rvals = ref.tanimoto_topk_ref(qs, db, k=10)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-6)


@pytest.mark.parametrize("cutoff,tile", [(0.2, 128), (0.4, 512), (0.8, 256),
                                         (0.95, 128)])
def test_bitbound_kernel_matches_oracle(cutoff, tile):
    db = _db(3000, seed=1)
    qs = jnp.asarray(queries_from_db(db, 4))
    idx = bb.build_index(jnp.asarray(db))
    ids, vals = ops.bitbound_topk(qs, idx.db, idx.counts, k=15, cutoff=cutoff,
                                  tile_n=tile)
    rids, rvals = ref.bitbound_topk_ref(qs, idx.db, idx.counts, k=15,
                                        cutoff=cutoff)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-6)
    # invalid entries agree
    np.testing.assert_array_equal(np.asarray(ids) < 0, np.asarray(rids) < 0)


def test_bitbound_kernel_restricted_window_grid():
    """max_tiles below the full DB: still exact when windows fit."""
    db = _db(4096, seed=2)
    qs = jnp.asarray(queries_from_db(db, 3))
    idx = bb.build_index(jnp.asarray(db))
    ids, vals = ops.bitbound_topk(qs, idx.db, idx.counts, k=10, cutoff=0.8,
                                  tile_n=256, max_tiles=8)
    _, rvals = ref.bitbound_topk_ref(qs, idx.db, idx.counts, k=10, cutoff=0.8)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-6)


def test_bitcount_kernel_sweep():
    for n, w in [(100, 8), (4096, 32), (5000, 16)]:
        rng = np.random.default_rng(n)
        words = jnp.asarray(rng.integers(0, 2**32, (n, w), dtype=np.uint32))
        got = np.asarray(ops.bitcount(words))
        np.testing.assert_array_equal(got, np.asarray(ref.bitcount_ref(words)))


def test_kernel_engine_integration(small_db, queries, brute_truth):
    """BruteForceEngine(use_kernel=True) == oracle top-k."""
    from repro.core import BruteForceEngine
    s, true_ids = brute_truth
    eng = BruteForceEngine(jnp.asarray(small_db), use_kernel=True)
    ids, vals = eng.search(queries, 20)
    expect = np.take_along_axis(s, true_ids, axis=1)
    np.testing.assert_allclose(vals, expect, rtol=1e-6)


@pytest.mark.parametrize("n,q,k,qb,tile", [
    (2000, 16, 10, 8, 256),
    (1500, 5, 20, 4, 512),     # Q padded up to qb multiple
    (4096, 32, 5, 16, 1024),
])
def test_blocked_topk_matches_oracle(n, q, k, qb, tile):
    """Query-blocked engine (one DB sweep per qb queries) stays exact."""
    db = jnp.asarray(_db(n, seed=4))
    qs = jnp.asarray(queries_from_db(np.asarray(db), q))
    ids, vals = ops.tanimoto_topk_blocked(qs, db, k=k, qb=qb, tile_n=tile)
    _, rvals = ref.tanimoto_topk_ref(qs, db, k=k)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-6)
    s = np.asarray(ref.tanimoto_scores_ref(qs, db))
    got = s[np.arange(q)[:, None], np.asarray(ids)]
    np.testing.assert_allclose(got, np.asarray(rvals), rtol=1e-6)
