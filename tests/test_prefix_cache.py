"""KNN prefix cache: the paper's engine applied to LM serving."""
import numpy as np

from repro.serve import KNNPrefixCache, simhash_sketch


def test_sketch_similarity_tracks_overlap():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 1000, 64)
    b = a.copy()
    b[48:] = rng.integers(0, 1000, 16)        # 75% shared prefix
    c = rng.integers(0, 1000, 64)             # unrelated
    from repro.core import tanimoto, pack_bits
    import jax.numpy as jnp
    sa, sb, sc = (jnp.asarray(simhash_sketch(x)) for x in (a, b, c))
    sim_ab = float(tanimoto(sa, sb))
    sim_ac = float(tanimoto(sa, sc))
    assert sim_ab > 0.5 > sim_ac
    assert float(tanimoto(sa, sa)) == 1.0


def test_cache_hit_on_shared_prefix():
    rng = np.random.default_rng(1)
    cache = KNNPrefixCache(sim_threshold=0.5, min_prefix=8)
    base = rng.integers(0, 1000, 100)
    cache.insert(base, payload="kv_base")
    # same conversation, longer continuation
    query = np.concatenate([base[:80], rng.integers(0, 1000, 30)])
    payload, reuse = cache.lookup(query)
    assert payload == "kv_base"
    assert reuse == 80
    assert cache.hits == 1


def test_cache_miss_on_unrelated_prompt():
    rng = np.random.default_rng(2)
    cache = KNNPrefixCache(sim_threshold=0.5, min_prefix=8)
    cache.insert(rng.integers(0, 1000, 100), payload="kv")
    payload, reuse = cache.lookup(rng.integers(0, 1000, 100))
    assert payload is None and reuse == 0


def test_capacity_eviction():
    rng = np.random.default_rng(3)
    cache = KNNPrefixCache(capacity=4)
    for i in range(8):
        cache.insert(rng.integers(0, 1000, 32), payload=i)
    assert len(cache._sketches) == 4
    assert cache._payloads == [4, 5, 6, 7]


# -- determinism + oracle (ISSUE 7 satellite) -------------------------------

def test_simhash_sketch_determinism_pinned():
    """Exact packed-word pin: any change to the n-gram hash, seed handling
    or bit packing invalidates every stored sketch, so the sketch function
    is part of the on-disk contract."""
    s = simhash_sketch(np.arange(20))
    assert s.dtype == np.uint32 and s.shape == (32,)
    expected = {0: 0x80, 1: 0x4000000, 3: 0x2000, 5: 0x1, 6: 0x80000,
                8: 0x40, 9: 0x2000000, 11: 0x1000, 12: 0x80000000,
                14: 0x40000, 16: 0x20, 17: 0x1000000, 19: 0x800,
                20: 0x40000000, 22: 0x20000, 24: 0x10, 29: 0x2,
                30: 0x100000}
    assert {i: int(v) for i, v in enumerate(s) if v} == expected
    # parameters flow through (length/ngram/seed change the mapping)
    s2 = simhash_sketch(np.arange(20), length=256, ngram=2, seed=7)
    assert [int(v) for v in s2] == [1048593, 1048592, 16777488, 16777472,
                                    268439808, 268439552, 65537, 65537]
    # pure function: repeated calls byte-equal
    np.testing.assert_array_equal(s, simhash_sketch(np.arange(20)))


def test_lookup_matches_brute_force_oracle():
    """Cache hit/miss decisions must match an oracle that scores every
    cached prompt exhaustively with the same sketch + threshold + exact
    prefix-verification rule."""
    from repro.core import tanimoto
    import jax.numpy as jnp

    rng = np.random.default_rng(7)
    cache = KNNPrefixCache(sim_threshold=0.6, min_prefix=8, capacity=64)
    prompts = []
    for i in range(12):
        if i % 3 == 0 or not prompts:
            p = rng.integers(0, 500, 64)       # fresh conversation
        else:                                  # fork an earlier prompt
            base = prompts[rng.integers(len(prompts))]
            cut = int(rng.integers(8, 56))
            p = np.concatenate([base[:cut], rng.integers(0, 500, 64 - cut)])
        prompts.append(p)
        cache.insert(p, payload=i)

    def oracle(query):
        qs = jnp.asarray(simhash_sketch(query))
        best_payload, best_len = None, 0
        for j, p in enumerate(prompts):
            sim = float(tanimoto(qs, jnp.asarray(simhash_sketch(p))))
            if sim < cache.sim_threshold:
                continue
            n = min(len(query), len(p))
            neq = np.nonzero(query[:n] != p[:n])[0]
            plen = int(neq[0]) if len(neq) else n
            if plen > best_len:
                best_payload, best_len = j, plen
        if best_len >= cache.min_prefix:
            return best_payload, best_len
        return None, 0

    hits = misses = 0
    for t in range(20):
        if t % 2:
            base = prompts[rng.integers(len(prompts))]
            cut = int(rng.integers(4, 60))
            q = np.concatenate([base[:cut], rng.integers(0, 500, 20)])
        else:
            q = rng.integers(0, 500, 64)
        want = oracle(q)
        got = cache.lookup(q)
        assert got == want, (t, got, want)
        hits += want[0] is not None
        misses += want[0] is None
    assert cache.hits == hits and cache.misses == misses
    assert hits > 0 and misses > 0             # both branches exercised
