"""KNN prefix cache: the paper's engine applied to LM serving."""
import numpy as np

from repro.serve import KNNPrefixCache, simhash_sketch


def test_sketch_similarity_tracks_overlap():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 1000, 64)
    b = a.copy()
    b[48:] = rng.integers(0, 1000, 16)        # 75% shared prefix
    c = rng.integers(0, 1000, 64)             # unrelated
    from repro.core import tanimoto, pack_bits
    import jax.numpy as jnp
    sa, sb, sc = (jnp.asarray(simhash_sketch(x)) for x in (a, b, c))
    sim_ab = float(tanimoto(sa, sb))
    sim_ac = float(tanimoto(sa, sc))
    assert sim_ab > 0.5 > sim_ac
    assert float(tanimoto(sa, sa)) == 1.0


def test_cache_hit_on_shared_prefix():
    rng = np.random.default_rng(1)
    cache = KNNPrefixCache(sim_threshold=0.5, min_prefix=8)
    base = rng.integers(0, 1000, 100)
    cache.insert(base, payload="kv_base")
    # same conversation, longer continuation
    query = np.concatenate([base[:80], rng.integers(0, 1000, 30)])
    payload, reuse = cache.lookup(query)
    assert payload == "kv_base"
    assert reuse == 80
    assert cache.hits == 1


def test_cache_miss_on_unrelated_prompt():
    rng = np.random.default_rng(2)
    cache = KNNPrefixCache(sim_threshold=0.5, min_prefix=8)
    cache.insert(rng.integers(0, 1000, 100), payload="kv")
    payload, reuse = cache.lookup(rng.integers(0, 1000, 100))
    assert payload is None and reuse == 0


def test_capacity_eviction():
    rng = np.random.default_rng(3)
    cache = KNNPrefixCache(capacity=4)
    for i in range(8):
        cache.insert(rng.integers(0, 1000, 32), payload=i)
    assert len(cache._sketches) == 4
    assert cache._payloads == [4, 5, 6, 7]
