import numpy as np
import pytest


@pytest.fixture(scope="session")
def small_db():
    from repro.data.molecules import SyntheticConfig, synthetic_fingerprints
    return synthetic_fingerprints(SyntheticConfig(n=2000, seed=0))


@pytest.fixture(scope="session")
def queries(small_db):
    from repro.data.molecules import queries_from_db
    return queries_from_db(small_db, 16)


@pytest.fixture(scope="session")
def brute_truth(small_db, queries):
    """Oracle top-20 ids for the shared query set."""
    import jax.numpy as jnp
    from repro.core import batched_tanimoto_scores
    s = np.asarray(batched_tanimoto_scores(jnp.asarray(queries), jnp.asarray(small_db)))
    ids = np.argsort(-s, axis=1, kind="stable")[:, :20]
    return s, ids
