"""ISSUE 2 acceptance: HNSW backend recall parity at >=10k scale.

``HNSWEngine(backend="tpu")`` (Pallas gather-distance kernel, interpret mode
off-TPU) must match the ``jnp`` backend's recall within 0.01 on a 10k-
fingerprint random database.
"""
import numpy as np
import pytest

from repro.core import BruteForceEngine, HNSWEngine, recall_at_k
from repro.core import hnsw as hn
from repro.data.molecules import (SyntheticConfig, queries_from_db,
                                  synthetic_fingerprints)


@pytest.fixture(scope="module")
def big_index():
    db = synthetic_fingerprints(SyntheticConfig(n=10_000, seed=42))
    idx = hn.build_hnsw(np.asarray(db), m=8, ef_construction=40, seed=0)
    return db, idx


def test_tpu_matches_jnp_recall_at_10k(big_index):
    db, idx = big_index
    q = queries_from_db(db, 8, seed=43)
    true, _ = BruteForceEngine(db).search(q, 10)
    recalls = {}
    stats = {}
    for backend in ("jnp", "tpu"):
        eng = HNSWEngine(db, index=idx, backend=backend, ef_search=32)
        ids, sims = eng.search(q, 10)
        recalls[backend] = recall_at_k(ids, true)
        stats[backend] = eng.stats
        # self-queries must find themselves at full similarity
        assert (sims[:, 0] >= 1.0 - 1e-6).all(), backend
    assert abs(recalls["jnp"] - recalls["tpu"]) <= 0.01, recalls
    assert recalls["jnp"] >= 0.6, recalls   # the graph navigates at scale
    # both backends walked the same graph the same way
    assert stats["jnp"]["expansions"] == stats["tpu"]["expansions"], stats
