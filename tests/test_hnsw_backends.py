"""ISSUE 2 / ISSUE 4 acceptance: HNSW backend and layout parity at >=10k.

``HNSWEngine(backend="tpu")`` (Pallas kernels, interpret mode off-TPU) must
match the ``jnp`` backend's recall within 0.01 on a 10k-fingerprint random
database, and the ``blocked`` neighbour-packed layout must be **bit-exact**
with the ``rows`` layout on every device backend.
"""
import numpy as np
import pytest

from repro.core import BruteForceEngine, HNSWEngine, recall_at_k
from repro.core import hnsw as hn
from repro.data.molecules import (SyntheticConfig, queries_from_db,
                                  synthetic_fingerprints)


@pytest.fixture(scope="module")
def big_index():
    db = synthetic_fingerprints(SyntheticConfig(n=10_000, seed=42))
    idx = hn.build_hnsw(np.asarray(db), m=8, ef_construction=40, seed=0)
    return db, idx


def test_tpu_matches_jnp_recall_at_10k(big_index):
    db, idx = big_index
    q = queries_from_db(db, 8, seed=43)
    true, _ = BruteForceEngine(db).search(q, 10)
    recalls = {}
    stats = {}
    results = {}
    for backend in ("jnp", "tpu"):
        for layout in ("rows", "blocked"):
            eng = HNSWEngine(db, index=idx, backend=backend, ef_search=32,
                             layout=layout)
            ids, sims = eng.search(q, 10)
            results[(backend, layout)] = (ids, sims)
            recalls[(backend, layout)] = recall_at_k(ids, true)
            stats[(backend, layout)] = eng.stats
            # self-queries must find themselves at full similarity
            assert (sims[:, 0] >= 1.0 - 1e-6).all(), (backend, layout)
    assert abs(recalls[("jnp", "rows")] - recalls[("tpu", "rows")]) <= 0.01, \
        recalls
    assert recalls[("jnp", "rows")] >= 0.6, recalls  # navigates at scale
    # ISSUE 4 acceptance: the blocked layout is bit-exact with the row path
    # on every backend (same graph walk, same arithmetic, same sort)
    base_ids, base_sims = results[("jnp", "rows")]
    for key, (ids, sims) in results.items():
        if key == ("jnp", "rows"):
            continue
        np.testing.assert_array_equal(ids, base_ids, err_msg=str(key))
        np.testing.assert_array_equal(sims, base_sims, err_msg=str(key))
    # all four paths walked the same graph the same way
    expans = {k: s["expansions"] for k, s in stats.items()}
    assert len(set(expans.values())) == 1, expans


def test_blocked_device_graph_carries_neighbour_blocks(big_index):
    """The blocked device graph's nbr_fps/nbr_cnt really are the packed
    adjacency fingerprints (nbr_fps[v, j] == db[base_adj[v, j]], zero rows
    for -1 slots) — the layout the expand kernel streams."""
    db, idx = big_index
    g = hn.to_device_graph(idx, layout="blocked")
    base = np.asarray(g.base_adj)
    nbr = np.asarray(g.nbr_fps)
    dbv = np.asarray(g.db)
    rng = np.random.default_rng(0)
    for v in rng.integers(0, idx.n, 32):
        for j in range(base.shape[1]):
            e = base[v, j]
            want = dbv[e] if e >= 0 else np.zeros(dbv.shape[1], dbv.dtype)
            np.testing.assert_array_equal(nbr[v, j], want)
    assert g.nbr_cnt.shape == base.shape
    # rows layout ships no blocks (no 2M*W HBM copy unless asked for)
    assert hn.to_device_graph(idx, layout="rows").nbr_fps is None
