"""Fused beam-expansion kernel (kernels/expand.py) vs the pure-jnp oracle.

ISSUE 4 satellite: edge cases of the gather/expand stage — expansion widths
and 2M off the 128-lane grid, duplicate neighbour ids inside one expansion,
fully masked (all ``-1``) expansion rows, and odd word counts (W padding) —
each checked bit-for-bit against ``ref.expand_sorted_ref``, plus the jnp
twin (``core.hnsw.expand_scores_jnp``) against both.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fingerprints import popcount
from repro.core.hnsw import expand_scores_jnp
from repro.data.molecules import SyntheticConfig, synthetic_fingerprints
from repro.kernels import ops, ref


def _case(n, q_n, m2, beam, w_words=None, seed=0, masked_frac=0.3):
    rng = np.random.default_rng(seed)
    db = np.asarray(synthetic_fingerprints(SyntheticConfig(n=n, seed=seed)))
    if w_words is not None:                   # W padding: truncate words
        db = np.ascontiguousarray(db[:, :w_words])
    adj = rng.integers(0, n, (n, m2)).astype(np.int32)
    adj[rng.random(adj.shape) < 0.15] = -1
    nbr = db[np.maximum(adj, 0)].copy()
    nbr[adj < 0] = 0
    cnt = np.bitwise_count(nbr).sum(-1).astype(np.int32)
    pop = rng.integers(0, n, (q_n, beam)).astype(np.int32)
    flat = adj[np.maximum(pop, 0)].reshape(q_n, beam * m2).copy()
    flat[rng.random(flat.shape) < masked_frac] = -1
    worst = np.full((q_n,), -np.inf, dtype=np.float32)
    return db, nbr, cnt, pop, flat, worst


def _run_all(db, nbr, cnt, pop, flat, worst, kk):
    args = (jnp.asarray(db[:pop.shape[0]]), jnp.asarray(nbr),
            jnp.asarray(cnt), jnp.asarray(pop), jnp.asarray(flat),
            jnp.asarray(worst))
    ks, ki = ops.expand_tanimoto_sorted(*args, kk)
    rs, ri = ref.expand_sorted_ref(*args, kk)
    q = args[0]
    ts, ti = expand_scores_jnp(q, popcount(q), *args[1:], kk)
    return (np.asarray(ks), np.asarray(ki)), (np.asarray(rs), np.asarray(ri)), \
        (np.asarray(ts), np.asarray(ti))


@pytest.mark.parametrize("n,q_n,m2,beam,kk,seed", [
    (400, 3, 16, 4, 32, 0),     # E=64, lane-aligned-ish
    (500, 2, 10, 3, 20, 1),     # 2M=10 and E=30: neither a lane multiple
    (257, 1, 5, 1, 5, 2),       # single-slot beam, odd everything
    (300, 4, 12, 5, 60, 3),     # kk == n_exp (full sorted expansion)
])
def test_expand_matches_oracle_and_twin(n, q_n, m2, beam, kk, seed):
    case = _case(n, q_n, m2, beam, seed=seed)
    (ks, ki), (rs, ri), (ts, ti) = _run_all(*case, kk)
    np.testing.assert_array_equal(ks, rs)
    np.testing.assert_array_equal(ki, ri)
    np.testing.assert_array_equal(ts, rs)
    np.testing.assert_array_equal(ti, ri)


def test_expand_odd_word_count():
    """W padding: fingerprints whose word count is off the lane grid (W=7)
    must still score exactly (the kernel recurs over whatever W it's given)."""
    case = _case(300, 3, 8, 2, w_words=7, seed=4)
    (ks, ki), (rs, ri), (ts, ti) = _run_all(*case, kk=10)
    np.testing.assert_array_equal(ks, rs)
    np.testing.assert_array_equal(ki, ri)
    np.testing.assert_array_equal(ts, rs)


def test_expand_duplicate_neighbour_ids():
    """Duplicate ids inside one expansion (a repeated id within one
    adjacency row, or two popped nodes sharing a neighbour) must each score
    identically and survive the sort as distinct slots — dedup is the
    traversal's visited-mask job, not the kernel's."""
    rng = np.random.default_rng(5)
    n, m2, beam, q_n = 200, 6, 2, 2
    db = np.asarray(synthetic_fingerprints(SyntheticConfig(n=n, seed=5)))
    adj = rng.integers(0, n, (n, m2)).astype(np.int32)
    adj[3, 4] = adj[3, 1]                        # duplicate within one row
    adj[7, 0] = adj[9, 2]                        # shared across two rows
    nbr = db[np.maximum(adj, 0)].copy()
    nbr[adj < 0] = 0
    cnt = np.bitwise_count(nbr).sum(-1).astype(np.int32)
    pop = np.array([[3, 5], [7, 9]], dtype=np.int32)
    flat = adj[pop].reshape(q_n, beam * m2)      # traversal-invariant flat
    worst = np.full((q_n,), -np.inf, dtype=np.float32)
    kk = beam * m2
    (ks, ki), (rs, ri), _ = _run_all(db, nbr, cnt, pop, flat, worst, kk)
    np.testing.assert_array_equal(ks, rs)
    np.testing.assert_array_equal(ki, ri)
    # both copies of each duplicate survive, with identical scores
    dup = adj[3, 1]
    slots = np.where(ki[0] == dup)[0]
    assert len(slots) >= 2, (ki[0], dup)
    assert len(set(np.round(ks[0][slots], 7))) == 1
    shared = adj[7, 0]
    slots = np.where(ki[1] == shared)[0]
    assert len(slots) >= 2, (ki[1], shared)
    assert len(set(np.round(ks[1][slots], 7))) == 1


def test_expand_all_invalid_row():
    """A fully masked expansion row (all -1 — e.g. every neighbour already
    visited) must come back all -inf / -1, and must not disturb other rows."""
    db, nbr, cnt, pop, flat, worst = _case(250, 3, 8, 2, seed=6)
    flat[1, :] = -1
    (ks, ki), (rs, ri), (ts, ti) = _run_all(db, nbr, cnt, pop, flat, worst,
                                            kk=8)
    np.testing.assert_array_equal(ks, rs)
    np.testing.assert_array_equal(ki, ri)
    assert not np.isfinite(ks[1]).any()
    assert (ki[1] == -1).all()


def test_expand_invalid_pop_ids():
    """-1 popped slots (queue underflow) are clamped for addressability and
    fully masked via their flat ids."""
    db, nbr, cnt, pop, flat, worst = _case(220, 2, 6, 3, seed=7)
    pop[0, 1] = -1
    flat[0, 6:12] = -1                           # the slot's ids masked too
    (ks, ki), (rs, ri), _ = _run_all(db, nbr, cnt, pop, flat, worst, kk=9)
    np.testing.assert_array_equal(ks, rs)
    np.testing.assert_array_equal(ki, ri)


def test_expand_worst_threshold_filters():
    """Scores <= worst[q] are dropped (score -inf, id -1): the result-queue
    eviction bound applied inside the kernel."""
    db, nbr, cnt, pop, flat, worst = _case(300, 2, 8, 2, seed=8,
                                           masked_frac=0.0)
    worst[0] = 1.1                               # nothing can beat it
    (ks, ki), (rs, ri), _ = _run_all(db, nbr, cnt, pop, flat, worst, kk=10)
    np.testing.assert_array_equal(ks, rs)
    np.testing.assert_array_equal(ki, ri)
    assert not np.isfinite(ks[0]).any() and (ki[0] == -1).all()
    assert np.isfinite(ks[1]).any()


def test_expand_inside_jitted_loop():
    """The traversal launches the kernel from inside lax.while_loop — it
    must trace there with loop-carried pop/flat ids."""
    db, nbr, cnt, pop, flat, worst = _case(150, 2, 6, 2, seed=9)
    q = jnp.asarray(db[:2])
    nbr_j, cnt_j = jnp.asarray(nbr), jnp.asarray(cnt)
    worst_j = jnp.asarray(worst)

    def f(pop0, flat0):
        def body(carry):
            i, p, fl, acc = carry
            s, _ = ops.expand_tanimoto_sorted(q, nbr_j, cnt_j, p, fl,
                                              worst_j, 6)
            acc = acc + jnp.where(jnp.isfinite(s), s, 0.0).sum()
            return i + 1, (p + 1) % 150, fl, acc

        return jax.lax.while_loop(lambda c: c[0] < 3, body,
                                  (0, pop0, flat0, jnp.float32(0)))[3]

    out = jax.jit(f)(jnp.asarray(pop), jnp.asarray(flat))
    assert np.isfinite(float(out)) and float(out) > 0
