"""ISSUE 5 acceptance: sharded HNSW fan-out traversal + rank-merge.

Contracts pinned here:

* ``HNSWEngine(shards=1)`` is **bit-identical** to the unsharded engine
  (same build seed, identity merge) on every device backend and layout.
* Multi-shard recall at the fig8 operating point is within 0.01 of the
  unsharded engine (partition-then-merge covers the global top-k as long as
  each shard covers its local share).
* Backends and layouts stay bit-exact with each other *through the
  fan-out* (same per-shard graph walks, same merge).
* Online inserts route round-robin and stay rebuild-identical.
* On a forced multi-device host platform the per-shard graphs land on
  distinct devices and results don't change (subprocess, like
  ``tests/test_distributed.py``).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import BruteForceEngine, HNSWEngine, recall_at_k
from repro.core import hnsw as hn
from repro.data.molecules import (SyntheticConfig, queries_from_db,
                                  synthetic_fingerprints)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
K = 10


@pytest.fixture(scope="module")
def corpus():
    db = synthetic_fingerprints(SyntheticConfig(n=2_000, seed=42))
    q = queries_from_db(db, 8, seed=43)
    true_ids, _ = BruteForceEngine(db).search(q, K)
    return db, q, true_ids


@pytest.fixture(scope="module")
def unsharded(corpus):
    db, q, _ = corpus
    eng = HNSWEngine(db, m=8, ef_construction=40, ef_search=32,
                     backend="jnp")
    ids, sims = eng.search(q, K)
    return ids, sims


def test_one_shard_bit_parity(corpus, unsharded):
    """shards=1 == unsharded, bit for bit (ids and sims), on both layouts."""
    db, q, _ = corpus
    ids0, sims0 = unsharded
    for layout in ("rows", "blocked"):
        eng = HNSWEngine(db, m=8, ef_construction=40, ef_search=32,
                         backend="jnp", layout=layout, shards=1)
        ids, sims = eng.search(q, K)
        np.testing.assert_array_equal(ids, ids0, err_msg=layout)
        np.testing.assert_array_equal(sims, sims0, err_msg=layout)


def test_multi_shard_recall_pin(corpus, unsharded):
    """>= 2 shards: recall within 0.01 of unsharded at the fig8 point."""
    db, q, true_ids = corpus
    ids0, _ = unsharded
    r0 = recall_at_k(ids0, true_ids)
    for shards in (2, 4):
        eng = HNSWEngine(db, m=8, ef_construction=40, ef_search=32,
                         backend="jnp", shards=shards)
        ids, sims = eng.search(q, K)
        r = recall_at_k(ids, true_ids)
        assert r >= r0 - 0.01, (shards, r, r0)
        # self-queries still find themselves at full similarity
        assert (sims[:, 0] >= 1.0 - 1e-6).all(), shards
        assert eng.stats["shards"] == shards
        assert len(eng.stats["per_shard"]) == shards


def test_sharded_backend_layout_parity(corpus):
    """Every device backend x layout is bit-exact through the fan-out."""
    db, q, _ = corpus
    db, q = db[:1200], q[:4]
    base = None
    for backend, layout in [("jnp", "rows"), ("jnp", "blocked"),
                            ("tpu", "rows"), ("tpu", "blocked")]:
        eng = HNSWEngine(db, m=8, ef_construction=40, ef_search=32,
                         backend=backend, layout=layout, shards=2)
        ids, sims = eng.search(q, K)
        if base is None:
            base = (ids, sims)
        else:
            np.testing.assert_array_equal(ids, base[0],
                                          err_msg=f"{backend}/{layout}")
            np.testing.assert_array_equal(sims, base[1],
                                          err_msg=f"{backend}/{layout}")


def test_sharded_numpy_backend(corpus, unsharded):
    """Host-reference fan-out: same merge semantics, recall-pinned."""
    db, q, true_ids = corpus
    ids0, _ = unsharded
    eng = HNSWEngine(db, m=8, ef_construction=40, ef_search=32,
                     backend="numpy", shards=2)
    ids, sims = eng.search(q, K)
    assert recall_at_k(ids, true_ids) >= \
        recall_at_k(ids0, true_ids) - 0.01
    assert (sims[ids < 0] == 0.0).all()
    assert eng.scanned(len(q)) > 0


def test_sharded_insert_matches_rebuild(corpus):
    """Round-robin insert routing: an engine grown online is identical to
    one built on the concatenated database (per-shard insert parity)."""
    db, q, _ = corpus
    grown = HNSWEngine(db[:1990], m=8, ef_construction=40, ef_search=32,
                       backend="jnp", shards=2)
    gids = grown.insert(db[1990:])
    np.testing.assert_array_equal(gids, np.arange(1990, 2000))
    assert grown.n_total == 2000
    rebuilt = HNSWEngine(db, m=8, ef_construction=40, ef_search=32,
                         backend="jnp", shards=2)
    ids_g, sims_g = grown.search(q, K)
    ids_r, sims_r = rebuilt.search(q, K)
    np.testing.assert_array_equal(ids_g, ids_r)
    np.testing.assert_array_equal(sims_g, sims_r)


def test_shards_validation(corpus):
    db, _, _ = corpus
    with pytest.raises(ValueError, match="either index= or shards="):
        HNSWEngine(db[:64], m=4, index=hn.build_hnsw(db[:64], m=4),
                   shards=2)
    with pytest.raises(ValueError, match="cannot split"):
        HNSWEngine(db[:4], m=4, shards=8)


def test_round_robin_invariant_guard(corpus):
    """insert_hnsw_sharded refuses shard lists that break round-robin."""
    db, _, _ = corpus
    idxs = hn.build_hnsw_sharded(db[:100], 2, m=4, ef_construction=10)
    bad = [idxs[0], hn.build_hnsw(db[:30], m=4, ef_construction=10)]
    with pytest.raises(ValueError, match="round-robin"):
        hn.insert_hnsw_sharded(bad, db[100:104])


def test_sharded_search_hnsw_module_api(corpus, unsharded):
    """The core-module fan-out (build_hnsw_sharded -> to_device_graph_sharded
    -> search_hnsw_sharded) matches the engine path."""
    db, q, _ = corpus
    idxs = hn.build_hnsw_sharded(np.asarray(db), 2, m=8, ef_construction=40,
                                 seed=0)
    graphs = hn.to_device_graph_sharded(idxs)
    gids, sims, stats = hn.search_hnsw_sharded(graphs, q, K, ef=32,
                                               beam=hn.auto_beam(32))
    eng = HNSWEngine(db, m=8, ef_construction=40, ef_search=32,
                     backend="jnp", shards=2)
    ids_e, sims_e = eng.search(q, K)
    np.testing.assert_array_equal(np.asarray(gids), ids_e)
    np.testing.assert_array_equal(np.asarray(sims), sims_e)
    assert len(stats) == 2


def test_forced_multi_device_placement():
    """On an 8-device host platform the shard graphs land on distinct
    devices; parity and the recall pin hold (the EXPERIMENTS.md recipe)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = textwrap.dedent("""
        import jax, numpy as np
        assert len(jax.devices()) == 8
        from repro.core import HNSWEngine, BruteForceEngine, recall_at_k
        from repro.data.molecules import (SyntheticConfig, queries_from_db,
                                          synthetic_fingerprints)
        db = synthetic_fingerprints(SyntheticConfig(n=1200, seed=42))
        q = queries_from_db(db, 4, seed=43)
        true_ids, _ = BruteForceEngine(db).search(q, 10)
        base = HNSWEngine(db, m=8, ef_construction=40, ef_search=32,
                          backend="jnp")
        ids0, sims0 = base.search(q, 10)
        one = HNSWEngine(db, m=8, ef_construction=40, ef_search=32,
                         backend="jnp", shards=1)
        ids1, sims1 = one.search(q, 10)
        np.testing.assert_array_equal(ids0, ids1)
        np.testing.assert_array_equal(sims0, sims1)
        sh = HNSWEngine(db, m=8, ef_construction=40, ef_search=32,
                        backend="jnp", shards=4)
        devs = {next(iter(g.db.devices())) for g in sh._shard_graphs}
        assert len(devs) == 4, devs
        ids, _ = sh.search(q, 10)
        r0, r = recall_at_k(ids0, true_ids), recall_at_k(ids, true_ids)
        assert r >= r0 - 0.01, (r, r0)
        print("SHARDED_8DEV_OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "SHARDED_8DEV_OK" in out.stdout
