"""Gather-distance kernel (kernels/gather.py) vs the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.molecules import SyntheticConfig, synthetic_fingerprints
from repro.kernels import ops, ref


def _db(n, seed=0):
    return jnp.asarray(synthetic_fingerprints(SyntheticConfig(n=n, seed=seed)))


@pytest.mark.parametrize("n,q,e,seed", [
    (400, 3, 8, 0),
    (1000, 2, 32, 1),     # beam-sized expansion (B*2M)
    (257, 1, 5, 2),       # odd shapes
])
def test_gather_matches_oracle(n, q, e, seed):
    db = _db(n, seed)
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, n, size=(q, e)).astype(np.int32)
    # sprinkle invalid ids (masked/visited/padded neighbours)
    ids[rng.random(ids.shape) < 0.3] = -1
    got = ops.gather_tanimoto(db[:q], db, jnp.asarray(ids))
    want = ref.gather_tanimoto_ref(db[:q], db, jnp.asarray(ids))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_gather_all_invalid_row():
    """A fully masked query row (all -1) must come back all -inf."""
    db = _db(300)
    ids = np.full((2, 6), -1, np.int32)
    ids[1, 0] = 7
    got = np.asarray(ops.gather_tanimoto(db[:2], db, jnp.asarray(ids)))
    assert not np.isfinite(got[0]).any()
    assert np.isfinite(got[1, 0]) and not np.isfinite(got[1, 1:]).any()


def test_gather_self_id_scores_one():
    db = _db(300)
    ids = np.arange(4, dtype=np.int32)[:, None]
    got = np.asarray(ops.gather_tanimoto(db[:4], db, jnp.asarray(ids)))
    np.testing.assert_allclose(got[:, 0], 1.0, rtol=1e-6)


def test_gather_inside_jitted_loop():
    """The traversal launches the kernel from inside lax.while_loop — the
    kernel must trace there (ids are loop-carried traced values)."""
    db = _db(200)
    q = db[:3]

    def f(ids0):
        def body(carry):
            i, ids, acc = carry
            s = ops.gather_tanimoto(q, db, ids)
            acc = acc + jnp.where(jnp.isfinite(s), s, 0.0).sum()
            return i + 1, (ids + 1) % 200, acc

        return jax.lax.while_loop(lambda c: c[0] < 3, body,
                                  (0, ids0, jnp.float32(0)))[2]

    ids0 = jnp.arange(6, dtype=jnp.int32).reshape(3, 2)
    out = jax.jit(f)(ids0)
    assert np.isfinite(float(out)) and float(out) > 0
