"""Distributed behaviour — runs in a subprocess with 8 host devices so the
main pytest process keeps its single-device backend."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_multi_device(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_sharded_search_matches_oracle():
    out = _run_multi_device("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import make_sharded_search, shard_database
        from repro.kernels import ref
        from repro.data.molecules import SyntheticConfig, synthetic_fingerprints, queries_from_db
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((4, 2), ("data", "model"))
        db = synthetic_fingerprints(SyntheticConfig(n=4000, seed=0))
        q = jnp.asarray(queries_from_db(db, 8))
        with mesh:
            db_s, cnt_s, n = shard_database(mesh, db)
            search, _, _ = make_sharded_search(mesh, db_s.shape[0], 10)
            vals, ids = search(q, db_s, cnt_s)
        rids, rvals = ref.tanimoto_topk_ref(q, jnp.asarray(db), 10)
        np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-6)
        s = np.asarray(ref.tanimoto_scores_ref(q, jnp.asarray(db)))
        got = s[np.arange(8)[:, None], np.asarray(ids)]
        np.testing.assert_allclose(got, np.asarray(rvals), rtol=1e-6)
        print("SHARDED_OK")
    """)
    assert "SHARDED_OK" in out


def test_sharded_search_multipod_hierarchical_merge():
    out = _run_multi_device("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import make_sharded_search, shard_database
        from repro.kernels import ref
        from repro.data.molecules import SyntheticConfig, synthetic_fingerprints, queries_from_db
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((2, 2, 2), ("pod", "data", "model"))
        db = synthetic_fingerprints(SyntheticConfig(n=2048, seed=1))
        q = jnp.asarray(queries_from_db(db, 4))
        with mesh:
            db_s, cnt_s, n = shard_database(mesh, db)
            search, _, _ = make_sharded_search(mesh, db_s.shape[0], 5)
            vals, ids = search(q, db_s, cnt_s)
        _, rvals = ref.tanimoto_topk_ref(q, jnp.asarray(db), 5)
        np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-6)
        print("MULTIPOD_OK")
    """)
    assert "MULTIPOD_OK" in out


def test_sharded_search_masks_pad_rows():
    """Regression (ISSUE 3 satellite): `shard_database` pads the DB with
    zero rows to the shard multiple; without masking their 0-score entries
    surface in the merged top-k once k approaches the shard size. With
    ``n_valid`` threaded through, pad ids come back as -1 / sim 0."""
    out = _run_multi_device("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import make_sharded_search, shard_database
        from repro.launch.mesh import compat_make_mesh
        from repro.data.molecules import SyntheticConfig, synthetic_fingerprints
        mesh = compat_make_mesh((4, 2), ("data", "model"))
        db = synthetic_fingerprints(SyntheticConfig(n=10, seed=0))  # pads to 12
        with mesh:
            db_s, cnt_s, n_valid = shard_database(mesh, db)
            assert db_s.shape[0] == 12 and n_valid == 10
            # k == padded total: every row (incl. both pads) is a candidate
            search, _, _ = make_sharded_search(mesh, db_s.shape[0], 12,
                                               n_valid=n_valid)
            q = jnp.asarray(db[:3])
            vals, ids = search(q, db_s, cnt_s)
        ids, vals = np.asarray(ids), np.asarray(vals)
        assert (ids < n_valid).all(), ids          # no pad id ever surfaces
        assert ((ids >= 0).sum(axis=1) == n_valid).all(), ids
        assert (vals[ids < 0] == 0.0).all()
        # the valid entries are exactly the 10 real rows
        for row in ids:
            assert set(int(i) for i in row if i >= 0) == set(range(10))
        print("PAD_MASK_OK")
    """)
    assert "PAD_MASK_OK" in out


def test_quantized_psum_close_to_exact():
    out = _run_multi_device("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import quantized_psum
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((8,), ("data",))
        x = jax.random.normal(jax.random.key(0), (8, 512))
        f_q = shard_map(lambda v: quantized_psum(v[0], "data"), mesh=mesh,
                        in_specs=P("data"), out_specs=P(), check_rep=False)
        f_e = shard_map(lambda v: jax.lax.psum(v[0], "data"), mesh=mesh,
                        in_specs=P("data"), out_specs=P(), check_rep=False)
        got, exact = f_q(x), f_e(x)
        err = float(jnp.abs(got - exact).max())
        scale = float(jnp.abs(x).max())
        assert err < scale * 8 / 127 + 1e-5, (err, scale)
        print("PSUM_OK", err)
    """)
    assert "PSUM_OK" in out


def test_production_mesh_shapes():
    out = _run_multi_device("""
        import os
        import jax
        from repro.launch.mesh import make_production_mesh, data_axes
        m = make_production_mesh()
        assert m.devices.shape == (16, 16) and m.axis_names == ("data", "model")
        mp = make_production_mesh(multi_pod=True)
        assert mp.devices.shape == (2, 16, 16)
        assert mp.axis_names == ("pod", "data", "model")
        assert data_axes(mp) == ("pod", "data")
        print("MESH_OK")
    """, n_devices=512)
    assert "MESH_OK" in out


def test_train_step_runs_on_local_mesh():
    out = _run_multi_device("""
        from repro.launch.train import train
        losses = train("granite-3-2b", steps=3, global_batch=8, seq_len=32,
                       ckpt_dir="/tmp/repro_test_dist_ckpt", ckpt_every=0,
                       log=lambda *a: None)
        assert len(losses) == 3
        print("TRAIN_OK", losses[-1])
    """)
    assert "TRAIN_OK" in out
