"""BitBound (Eq. 2) pruning: the bound must be *sound* — no fingerprint
outside the popcount window can reach the similarity cutoff."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # collection-safe fallback (see tests/_propcheck.py)
    from _propcheck import given, settings, strategies as st

from repro.core import bitbound as bb
from repro.core import pack_bits
from repro.core.fingerprints import popcount


@given(st.integers(0, 2**32 - 1), st.sampled_from([0.3, 0.5, 0.7, 0.9]))
@settings(max_examples=30, deadline=None)
def test_eq2_bound_is_sound(seed, cutoff):
    rng = np.random.default_rng(seed)
    bits = (rng.random((64, 128)) < rng.uniform(0.02, 0.3)).astype(np.uint8)
    db = jnp.asarray(pack_bits(bits))
    q = db[0]
    a = int(popcount(q))
    cnt = np.asarray(popcount(db))
    # similarity of q against all
    inter = np.bitwise_count(np.asarray(q)[None] & np.asarray(db)).sum(-1)
    union = a + cnt - inter
    sim = np.where(union > 0, inter / np.maximum(union, 1), 0.0)
    outside = (cnt < np.ceil(a * cutoff)) | (cnt > np.floor(a / cutoff))
    assert (sim[outside] < cutoff).all(), "Eq.2 pruned a true neighbour"


def test_index_sorted_and_complete(small_db):
    idx = bb.build_index(jnp.asarray(small_db))
    counts = np.asarray(idx.counts)
    assert (np.diff(counts) >= 0).all()
    # order is a permutation
    assert len(np.unique(np.asarray(idx.order))) == small_db.shape[0]
    # sorted db rows match original rows through the permutation
    np.testing.assert_array_equal(np.asarray(idx.db),
                                  small_db[np.asarray(idx.order)])


def test_bound_range_contains_all_hits(small_db, queries):
    idx = bb.build_index(jnp.asarray(small_db))
    cutoff = 0.6
    for q in jnp.asarray(queries)[:4]:
        lo, hi = bb.bound_range(idx, popcount(q), cutoff)
        lo, hi = int(lo), int(hi)
        inter = np.bitwise_count(np.asarray(q)[None] & np.asarray(idx.db)).sum(-1)
        union = int(popcount(q)) + np.asarray(idx.counts) - inter
        sim = np.where(union > 0, inter / np.maximum(union, 1), 0.0)
        hits = np.where(sim >= cutoff)[0]
        assert (hits >= lo).all() and (hits < hi).all()


def test_expected_speedup_monotonic():
    mu, sigma = 62.0, 22.0
    speedups = [bb.expected_speedup(mu, sigma, c) for c in (0.3, 0.5, 0.7, 0.9)]
    assert all(s2 >= s1 for s1, s2 in zip(speedups, speedups[1:]))
    assert speedups[0] >= 1.0


def test_gaussian_model_normalises():
    xs = np.linspace(0, 1024, 8192)
    dens = bb.gaussian_model(xs, 62.0, 22.0)
    assert abs(np.trapezoid(dens, xs) - 1.0) < 1e-2
