"""ISSUE 3 acceptance: insert-then-search parity.

After any interleaving of online inserts and searches — including across an
LSM compaction boundary — every engine returns **bit-identical** (ids, sims)
to an engine rebuilt from scratch on the concatenated database, on every
backend. This pins the whole write path: store segment layout, the merged
main+delta candidate ordering (stable (popcount, gid) ties), the device
merge ranks, and HNSW's rng-continuation incremental construction.
"""
import numpy as np
import pytest

from repro.core import BruteForceEngine, BitBoundFoldingEngine, HNSWEngine
from repro.core import hnsw as hn
from repro.data.molecules import (SyntheticConfig, queries_from_db,
                                  synthetic_fingerprints)


@pytest.fixture(scope="module")
def data():
    base = synthetic_fingerprints(SyntheticConfig(n=700, seed=0))
    extra = synthetic_fingerprints(SyntheticConfig(n=100, seed=9))
    full = np.concatenate([base, extra])
    q = queries_from_db(full, 10, seed=4)
    return base, extra, full, q


def _assert_equal(eng, reb, q, k, label):
    ids, sims = eng.search(q, k)
    rids, rsims = reb.search(q, k)
    np.testing.assert_array_equal(ids, rids, err_msg=label)
    np.testing.assert_array_equal(sims, rsims, err_msg=label)


@pytest.mark.parametrize("backend", ["jnp", "tpu"])
def test_brute_insert_parity(data, backend):
    base, extra, full, q = data
    eng = BruteForceEngine(base, backend=backend, compact_threshold=64)
    eng.insert(extra[:30])                     # delta only
    assert eng.store.compactions == 0
    _assert_equal(eng, BruteForceEngine(np.concatenate([base, extra[:30]]),
                                        backend=backend), q, 15,
                  f"brute/{backend} pre-compaction")
    eng.insert(extra[30:])                     # 100 >= 64 -> compaction
    assert eng.store.compactions == 1
    _assert_equal(eng, BruteForceEngine(full, backend=backend), q, 15,
                  f"brute/{backend} post-compaction")
    assert eng.n_total == len(full)


@pytest.mark.parametrize("backend,m,cutoff", [
    ("numpy", 1, 0.6), ("numpy", 4, 0.2),
    ("jnp", 2, 0.4), ("jnp", 1, 0.2),
    ("tpu", 1, 0.6), ("tpu", 4, 0.2),
])
def test_bitbound_insert_parity(data, backend, m, cutoff):
    base, extra, full, q = data
    label = f"bitbound/{backend} m={m} Sc={cutoff}"
    eng = BitBoundFoldingEngine(base, cutoff=cutoff, m=m, backend=backend,
                                compact_threshold=64)
    eng.insert(extra[:30])
    mid = BitBoundFoldingEngine(np.concatenate([base, extra[:30]]),
                                cutoff=cutoff, m=m, backend=backend)
    _assert_equal(eng, mid, q, 15, label + " pre-compaction")
    # scanned-work accounting matches the rebuild too (Eq.2 windows + delta)
    assert eng.scanned(len(q)) == mid.scanned(len(q)), label
    eng.insert(extra[30:])
    assert eng.store.compactions == 1
    reb = BitBoundFoldingEngine(full, cutoff=cutoff, m=m, backend=backend)
    _assert_equal(eng, reb, q, 15, label + " post-compaction")
    assert eng.scanned(len(q)) == reb.scanned(len(q)), label


@pytest.mark.parametrize("backend", ["numpy", "jnp", "tpu"])
def test_hnsw_insert_parity(data, backend):
    base, extra, full, q = data
    eng = HNSWEngine(base[:600], m=6, ef_construction=24, ef_search=24,
                     seed=3, backend=backend)
    eng.insert(extra[:20])
    eng.insert(extra[20:40])
    reb_db = np.concatenate([base[:600], extra[:40]])
    reb = HNSWEngine(reb_db, m=6, ef_construction=24, ef_search=24, seed=3,
                     backend=backend)
    _assert_equal(eng, reb, q, 10, f"hnsw/{backend}")
    assert eng.n_total == 640


def test_hnsw_incremental_graph_identical(data):
    """The graph itself (not just search results) matches a from-scratch
    build: same adjacency, entry point, levels — the rng-continuation +
    shared _insert_node contract."""
    base, extra, full, q = data
    idx = hn.build_hnsw(base[:600], m=6, ef_construction=24, seed=3)
    hn.insert_hnsw(idx, extra[:20])
    hn.insert_hnsw(idx, extra[20:40])
    ref = hn.build_hnsw(np.concatenate([base[:600], extra[:40]]),
                        m=6, ef_construction=24, seed=3)
    np.testing.assert_array_equal(idx.base_adj, ref.base_adj)
    np.testing.assert_array_equal(idx.level_of, ref.level_of)
    assert idx.entry_point == ref.entry_point
    assert idx.max_level == ref.max_level
    assert len(idx.level_nodes) == len(ref.level_nodes)
    for a, b in zip(idx.level_nodes, ref.level_nodes):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(idx.level_adj, ref.level_adj):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("backend", ["jnp", "tpu"])
def test_brute_delta_parity_when_k_spans_padding(backend):
    """Regression (code-review find): with k > n_main the main scan's
    capacity-pad rows (sim 0, raw row ids) used to win cross-run score-0
    ties against real delta rows in the merge — ids must instead match the
    rebuild exactly up to k = n_total."""
    rows = synthetic_fingerprints(SyntheticConfig(n=8, seed=1))
    eng = BruteForceEngine(rows[:5], backend=backend,   # capacity pads 5->8
                           compact_threshold=100)
    eng.insert(rows[5:])
    reb = BruteForceEngine(rows, backend=backend)
    q = rows[:2]
    _assert_equal(eng, reb, q, 8, f"brute/{backend} k==n_total")
    ids, _ = eng.search(q, 8)
    assert (ids >= 0).all() and (ids < 8).all(), ids


def test_insert_returns_global_ids(data):
    base, extra, _, _ = data
    eng = BruteForceEngine(base, compact_threshold=10_000)
    g1 = eng.insert(extra[0])                  # single row broadcastable
    g2 = eng.insert(extra[1:4])
    np.testing.assert_array_equal(g1, [len(base)])
    np.testing.assert_array_equal(g2, np.arange(len(base) + 1, len(base) + 4))
    assert eng.insert(np.empty((0, base.shape[1]), np.uint32)).size == 0
