"""ISSUE 3 acceptance: insert-then-search parity.

After any interleaving of online inserts and searches — including across an
LSM compaction boundary — every engine returns **bit-identical** (ids, sims)
to an engine rebuilt from scratch on the concatenated database, on every
backend. This pins the whole write path: store segment layout, the merged
main+delta candidate ordering (stable (popcount, gid) ties), the device
merge ranks, and HNSW's rng-continuation incremental construction.
"""
import numpy as np
import pytest

from repro.core import BruteForceEngine, BitBoundFoldingEngine, HNSWEngine
from repro.core import hnsw as hn
from repro.data.molecules import (SyntheticConfig, queries_from_db,
                                  synthetic_fingerprints)


@pytest.fixture(scope="module")
def data():
    base = synthetic_fingerprints(SyntheticConfig(n=700, seed=0))
    extra = synthetic_fingerprints(SyntheticConfig(n=100, seed=9))
    full = np.concatenate([base, extra])
    q = queries_from_db(full, 10, seed=4)
    return base, extra, full, q


def _assert_equal(eng, reb, q, k, label):
    ids, sims = eng.search(q, k)
    rids, rsims = reb.search(q, k)
    np.testing.assert_array_equal(ids, rids, err_msg=label)
    np.testing.assert_array_equal(sims, rsims, err_msg=label)


@pytest.mark.parametrize("backend", ["jnp", "tpu"])
def test_brute_insert_parity(data, backend):
    base, extra, full, q = data
    eng = BruteForceEngine(base, backend=backend, compact_threshold=64)
    eng.insert(extra[:30])                     # delta only
    assert eng.store.compactions == 0
    _assert_equal(eng, BruteForceEngine(np.concatenate([base, extra[:30]]),
                                        backend=backend), q, 15,
                  f"brute/{backend} pre-compaction")
    eng.insert(extra[30:])                     # 100 >= 64 -> compaction
    assert eng.store.compactions == 1
    _assert_equal(eng, BruteForceEngine(full, backend=backend), q, 15,
                  f"brute/{backend} post-compaction")
    assert eng.n_total == len(full)


@pytest.mark.parametrize("backend,m,cutoff", [
    ("numpy", 1, 0.6), ("numpy", 4, 0.2),
    ("jnp", 2, 0.4), ("jnp", 1, 0.2),
    ("tpu", 1, 0.6), ("tpu", 4, 0.2),
])
def test_bitbound_insert_parity(data, backend, m, cutoff):
    base, extra, full, q = data
    label = f"bitbound/{backend} m={m} Sc={cutoff}"
    eng = BitBoundFoldingEngine(base, cutoff=cutoff, m=m, backend=backend,
                                compact_threshold=64)
    eng.insert(extra[:30])
    mid = BitBoundFoldingEngine(np.concatenate([base, extra[:30]]),
                                cutoff=cutoff, m=m, backend=backend)
    _assert_equal(eng, mid, q, 15, label + " pre-compaction")
    # scanned-work accounting matches the rebuild too (Eq.2 windows + delta)
    assert eng.scanned(len(q)) == mid.scanned(len(q)), label
    eng.insert(extra[30:])
    assert eng.store.compactions == 1
    reb = BitBoundFoldingEngine(full, cutoff=cutoff, m=m, backend=backend)
    _assert_equal(eng, reb, q, 15, label + " post-compaction")
    assert eng.scanned(len(q)) == reb.scanned(len(q)), label


@pytest.mark.parametrize("backend,layout", [
    ("numpy", "rows"), ("jnp", "rows"), ("jnp", "blocked"),
    ("tpu", "rows"), ("tpu", "blocked"),
])
def test_hnsw_insert_parity(data, backend, layout):
    base, extra, full, q = data
    eng = HNSWEngine(base[:600], m=6, ef_construction=24, ef_search=24,
                     seed=3, backend=backend, layout=layout)
    eng.search(q, 10)       # build the device graph at n=600 so the insert
    eng.insert(extra[:20])  # refresh below exercises the incremental path
    eng.insert(extra[20:40])
    reb_db = np.concatenate([base[:600], extra[:40]])
    reb = HNSWEngine(reb_db, m=6, ef_construction=24, ef_search=24, seed=3,
                     backend=backend, layout=layout)
    _assert_equal(eng, reb, q, 10, f"hnsw/{backend}/{layout}")
    assert eng.n_total == 640
    if backend != "numpy":
        # the incrementally-refreshed device graph (dirty_log scatter) is
        # identical to a from-scratch to_device_graph densify+upload
        g_inc = eng._graph
        g_new = hn.to_device_graph(eng.index, capacity=g_inc.db.shape[0],
                                   layout=layout)
        for field in ("db", "db_popcount", "base_adj", "upper_adj",
                      "nbr_fps", "nbr_cnt"):
            a, b = getattr(g_inc, field), getattr(g_new, field)
            if a is None:
                assert b is None, field
                continue
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{backend}/{layout}/"
                                                  f"{field}")


def test_hnsw_cross_layout_results_identical(data):
    """ISSUE 4 acceptance: after online inserts, the blocked layout returns
    bit-identical results to the rows layout (jnp backend)."""
    base, extra, full, q = data
    res = {}
    for layout in ("rows", "blocked"):
        eng = HNSWEngine(base[:600], m=6, ef_construction=24, ef_search=24,
                         seed=3, backend="jnp", layout=layout)
        eng.insert(extra[:40])
        res[layout] = eng.search(q, 10)
    np.testing.assert_array_equal(res["rows"][0], res["blocked"][0])
    np.testing.assert_array_equal(res["rows"][1], res["blocked"][1])


def test_hnsw_incremental_graph_identical(data):
    """The graph itself (not just search results) matches a from-scratch
    build: same adjacency, entry point, levels — the rng-continuation +
    shared _insert_node contract."""
    base, extra, full, q = data
    idx = hn.build_hnsw(base[:600], m=6, ef_construction=24, seed=3)
    hn.insert_hnsw(idx, extra[:20])
    hn.insert_hnsw(idx, extra[20:40])
    ref = hn.build_hnsw(np.concatenate([base[:600], extra[:40]]),
                        m=6, ef_construction=24, seed=3)
    np.testing.assert_array_equal(idx.base_adj, ref.base_adj)
    np.testing.assert_array_equal(idx.level_of, ref.level_of)
    assert idx.entry_point == ref.entry_point
    assert idx.max_level == ref.max_level
    assert len(idx.level_nodes) == len(ref.level_nodes)
    for a, b in zip(idx.level_nodes, ref.level_nodes):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(idx.level_adj, ref.level_adj):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("backend", ["jnp", "tpu"])
def test_brute_delta_parity_when_k_spans_padding(backend):
    """Regression (code-review find): with k > n_main the main scan's
    capacity-pad rows (sim 0, raw row ids) used to win cross-run score-0
    ties against real delta rows in the merge — ids must instead match the
    rebuild exactly up to k = n_total."""
    rows = synthetic_fingerprints(SyntheticConfig(n=8, seed=1))
    eng = BruteForceEngine(rows[:5], backend=backend,   # capacity pads 5->8
                           compact_threshold=100)
    eng.insert(rows[5:])
    reb = BruteForceEngine(rows, backend=backend)
    q = rows[:2]
    _assert_equal(eng, reb, q, 8, f"brute/{backend} k==n_total")
    ids, _ = eng.search(q, 8)
    assert (ids >= 0).all() and (ids < 8).all(), ids


def test_hnsw_amortized_growth_and_persisted_rng(data):
    """ISSUE 4 satellites: insert_hnsw grows through doubling backing arrays
    (views, no O(n_total) concatenate per batch) and continues a persisted
    rng Generator instead of re-drawing the whole level stream."""
    base, extra, _, _ = data
    idx = hn.build_hnsw(base[:200], m=4, ef_construction=12, seed=3)
    assert idx.rng is not None                    # persisted at build time
    hn.insert_hnsw(idx, extra[:8])
    cap_arr = idx._db_cap
    assert cap_arr is not None and cap_arr.shape[0] >= 208
    assert idx.db.base is cap_arr                 # view, not a copy
    assert idx.base_adj.base is idx._adj_cap
    hn.insert_hnsw(idx, extra[8:16])
    assert idx._db_cap is cap_arr                 # no reallocation below cap
    assert idx.n == 216
    # the dirty log accumulated the touched base rows, incl. all new nodes
    assert set(range(200, 216)) <= set(idx.dirty_log)
    # a legacy index (no persisted rng) fast-forwards the seed stream and
    # still matches the rebuild exactly
    legacy = hn.build_hnsw(base[:200], m=4, ef_construction=12, seed=3)
    legacy.rng = None
    hn.insert_hnsw(legacy, extra[:16])
    np.testing.assert_array_equal(legacy.level_of, idx.level_of)
    np.testing.assert_array_equal(legacy.base_adj, idx.base_adj)


def test_hnsw_dirty_log_bounded(data):
    """The dirty log is bounded: once it outgrows ~2n entries it is cleared
    and the epoch bumps, and engines holding a stale epoch full-rebuild
    instead of consuming lost entries — no unbounded host growth under
    sustained insert streams, no stale device graphs."""
    base, extra, _, q = data
    eng = HNSWEngine(base[:300], m=4, ef_construction=12, ef_search=16,
                     seed=3, backend="jnp", layout="blocked")
    eng.search(q, 5)
    idx = eng.index
    idx.dirty_log = [0] * (2 * idx.n + 1025)   # long-consumed service log
    eng.insert(extra[:8])
    assert idx.dirty_epoch == 1
    assert len(idx.dirty_log) <= 2 * idx.n + 1024
    reb = HNSWEngine(np.concatenate([base[:300], extra[:8]]), m=4,
                     ef_construction=12, ef_search=16, seed=3,
                     backend="jnp", layout="blocked")
    _assert_equal(eng, reb, q, 5, "hnsw dirty-log epoch rebuild")
    assert eng._dirty_epoch == idx.dirty_epoch


def test_hnsw_tpu_insert_scorer_db_cache(data):
    """ISSUE 4 satellite: the tpu insert-frontier scorer appends new rows
    into a cached capacity-padded device db instead of re-uploading the
    full database every insert batch."""
    base, extra, _, q = data
    eng = HNSWEngine(base[:200], m=4, ef_construction=12, ef_search=16,
                     seed=3, backend="tpu")
    assert eng._insert_db_cache is None
    eng.insert(extra[:4])
    dev, filled = eng._insert_db_cache
    assert filled == 204 and dev.shape[0] >= 204
    cap0 = dev.shape[0]
    eng.insert(extra[4:8])
    dev2, filled2 = eng._insert_db_cache
    assert filled2 == 208 and dev2.shape[0] == cap0   # appended in place
    # cached rows are exactly the index's fingerprints
    np.testing.assert_array_equal(np.asarray(dev2[:208]),
                                  np.asarray(eng.index.db))
    reb = HNSWEngine(np.concatenate([base[:200], extra[:8]]), m=4,
                     ef_construction=12, ef_search=16, seed=3, backend="tpu")
    _assert_equal(eng, reb, q, 5, "hnsw/tpu scorer cache")


def test_insert_returns_global_ids(data):
    base, extra, _, _ = data
    eng = BruteForceEngine(base, compact_threshold=10_000)
    g1 = eng.insert(extra[0])                  # single row broadcastable
    g2 = eng.insert(extra[1:4])
    np.testing.assert_array_equal(g1, [len(base)])
    np.testing.assert_array_equal(g2, np.arange(len(base) + 1, len(base) + 4))
    assert eng.insert(np.empty((0, base.shape[1]), np.uint32)).size == 0
