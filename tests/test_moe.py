"""MoE dispatch: capacity path must equal the dense oracle when capacity is
ample; load-balance aux behaves; capped capacity drops gracefully."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import moe as moe_mod
from repro.models.param import split


def _setup(seed=0, t=32):
    cfg = get_arch("olmoe-1b-7b").reduced().with_(dtype="float32")
    p_sp = moe_mod.init_moe(jax.random.key(seed), cfg, cfg.d_model)
    p, _ = split(p_sp)
    x = jax.random.normal(jax.random.key(seed + 1), (2, t // 2, cfg.d_model),
                          jnp.float32)
    return cfg, p, x


def test_capacity_matches_dense_when_ample():
    cfg, p, x = _setup()
    y_cap, aux_cap = moe_mod.moe_ffn(p, cfg, x, capacity_factor=8.0)
    y_dense, aux_dense = moe_mod.moe_ffn_dense(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_dense),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(aux_cap), float(aux_dense), rtol=1e-5)


def test_tight_capacity_drops_but_stays_finite():
    cfg, p, x = _setup(seed=2)
    y, aux = moe_mod.moe_ffn(p, cfg, x, capacity_factor=0.25)
    assert bool(jnp.all(jnp.isfinite(y)))
    # dropped tokens pass through as zero contribution (residual handles them)
    y_full, _ = moe_mod.moe_ffn(p, cfg, x, capacity_factor=8.0)
    assert float(jnp.abs(y).sum()) <= float(jnp.abs(y_full).sum()) + 1e-3


def test_aux_loss_uniform_router_is_one():
    """Perfectly uniform routing gives aux ~= 1 (Switch normalisation)."""
    cfg, p, x = _setup(seed=3)
    t = 64
    e = cfg.moe.n_experts
    probs = jnp.full((t, e), 1.0 / e)
    top_e = jnp.tile(jnp.arange(cfg.moe.top_k), (t, 1)) + \
        (jnp.arange(t) % (e - cfg.moe.top_k + 1))[:, None]
    aux = moe_mod._aux_loss(cfg.moe, probs, top_e)
    assert 0.5 < float(aux) < 2.0


def test_router_grads_flow():
    cfg, p, x = _setup(seed=4)

    def loss(p):
        y, aux = moe_mod.moe_ffn(p, cfg, x, capacity_factor=8.0)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).max()) > 0
    assert float(jnp.abs(g["gate"]).max()) > 0
