"""Write-ahead log: record round-trip, torn-tail truncation, corruption
detection, rotation/GC, group commit (ISSUE 6 tentpole, WAL half)."""
import numpy as np
import pytest

from repro.checkpoint.fs import DEFAULT_FS
from repro.serve import wal as wal_mod
from repro.serve.wal import WalCorruption, WriteAheadLog, replay


def _rows(n, w=4, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2**32, size=(n, w), dtype=np.uint32)


def test_append_replay_roundtrip(tmp_path):
    w = WriteAheadLog(tmp_path, words=4)
    batches = [(0, _rows(3, seed=1)), (3, _rows(1, seed=2)),
               (4, _rows(5, seed=3))]
    for gid, rows in batches:
        w.append(gid, rows)
    w.close()
    records, stats = replay(tmp_path, words=4)
    assert stats["records"] == 3 and stats["truncated"] == 0
    for (g0, r0), (g1, r1) in zip(batches, records):
        assert g0 == g1
        np.testing.assert_array_equal(r0, r1)


def test_reopen_never_appends_to_old_segment(tmp_path):
    w1 = WriteAheadLog(tmp_path, words=4)
    w1.append(0, _rows(2))
    w1.close()
    w2 = WriteAheadLog(tmp_path, words=4)
    w2.append(2, _rows(2, seed=5))
    w2.close()
    assert wal_mod.segment_seqs(tmp_path) == [0, 1]
    records, _ = replay(tmp_path, words=4)
    assert [g for g, _ in records] == [0, 2]


@pytest.mark.parametrize("cut", [1, 7, 20])
def test_torn_tail_truncated_on_replay(tmp_path, cut):
    """A crash mid-append leaves a partial record; replay must truncate it
    (those bytes were never fsync'd => never acked) and keep the rest."""
    w = WriteAheadLog(tmp_path, words=4)
    w.append(0, _rows(3, seed=1))
    w.append(3, _rows(2, seed=2))
    w.close()
    path = tmp_path / "wal_00000000.log"
    data = path.read_bytes()
    path.write_bytes(data[:len(data) - cut])       # tear the last record
    records, stats = replay(tmp_path, words=4)
    assert stats["truncated"] == 1
    assert [g for g, _ in records] == [0]
    np.testing.assert_array_equal(records[0][1], _rows(3, seed=1))
    # truncation is durable: a second replay sees a clean segment
    records2, stats2 = replay(tmp_path, words=4)
    assert stats2["truncated"] == 0
    assert [g for g, _ in records2] == [0]


def test_torn_tail_in_non_final_segment_still_truncates(tmp_path):
    """Crash mid-append to segment K, recover (rotates to K+1), crash again:
    segment K's torn tail is no longer last but must still truncate."""
    w = WriteAheadLog(tmp_path, words=4)
    w.append(0, _rows(2, seed=1))
    w.close()
    path = tmp_path / "wal_00000000.log"
    path.write_bytes(path.read_bytes() + b"\x01\x02\x03")   # torn garbage
    w2 = WriteAheadLog(tmp_path, words=4)                   # seq 1
    w2.append(2, _rows(1, seed=2))
    w2.close()
    records, stats = replay(tmp_path, words=4)
    assert stats["truncated"] == 1
    assert [g for g, _ in records] == [0, 2]


def test_midstream_corruption_raises_without_truncate(tmp_path):
    w = WriteAheadLog(tmp_path, words=4)
    w.append(0, _rows(2, seed=1))
    w.append(2, _rows(2, seed=2))
    w.close()
    path = tmp_path / "wal_00000000.log"
    raw = bytearray(path.read_bytes())
    raw[20] ^= 0xFF                                # inside the first record
    path.write_bytes(bytes(raw))
    with pytest.raises(WalCorruption):
        replay(tmp_path, words=4, truncate=False)


def test_words_mismatch_rejected(tmp_path):
    w = WriteAheadLog(tmp_path, words=4)
    w.append(0, _rows(1))
    with pytest.raises(ValueError, match="width"):
        w.append(1, _rows(1, w=8))
    w.close()
    with pytest.raises(WalCorruption, match="words"):
        replay(tmp_path, words=8)


def test_rotate_and_gc(tmp_path):
    w = WriteAheadLog(tmp_path, words=4)
    w.append(0, _rows(2, seed=1))
    new_seq = w.rotate()
    assert new_seq == 1
    w.append(2, _rows(2, seed=2))
    w.gc_below(new_seq)
    assert wal_mod.segment_seqs(tmp_path) == [1]
    w.close()
    records, _ = replay(tmp_path, from_seq=new_seq, words=4)
    assert [g for g, _ in records] == [2]


def test_group_commit_batches_fsyncs(tmp_path):
    class CountingFs(type(DEFAULT_FS)):
        def __init__(self):
            self.fsyncs = 0

        def fsync(self, f):
            self.fsyncs += 1
            super().fsync(f)

    fs1, fsN = CountingFs(), CountingFs()
    w1 = WriteAheadLog(tmp_path / "a", words=4, fs=fs1, fsync_every=1)
    wN = WriteAheadLog(tmp_path / "b", words=4, fs=fsN, fsync_every=8)
    for i in range(16):
        w1.append(i, _rows(1, seed=i))
        wN.append(i, _rows(1, seed=i))
    w1.close()
    wN.close()
    assert fs1.fsyncs - fsN.fsyncs >= 12     # 16+1 header vs 2+1 header
    ra, _ = replay(tmp_path / "a", words=4)
    rb, _ = replay(tmp_path / "b", words=4)
    assert len(ra) == len(rb) == 16


def test_empty_directory_replay(tmp_path):
    records, stats = replay(tmp_path / "nothing", words=4)
    assert records == [] and stats["segments"] == 0


# -- GC pinning (ISSUE 9 satellite) ------------------------------------------

def test_gc_below_clamped_by_pins(tmp_path):
    """While pins are held, gc_below floors at the minimum pinned sequence
    regardless of the caller's (possibly mid-write) floor."""
    w = WriteAheadLog(tmp_path, words=4)
    for i in range(4):
        w.append(i * 2, _rows(2, seed=i))
        w.rotate()
    assert wal_mod.segment_seqs(tmp_path) == [0, 1, 2, 3, 4]
    t1 = w.pin(1)
    t2 = w.pin(3)
    w.gc_below(10)                       # caller floor above every pin
    assert wal_mod.segment_seqs(tmp_path) == [1, 2, 3, 4]
    w.unpin(t1)
    w.gc_below(10)
    assert wal_mod.segment_seqs(tmp_path) == [3, 4]
    w.unpin(t2)
    w.gc_below(4)                        # unpinned: caller floor applies
    assert wal_mod.segment_seqs(tmp_path) == [4]
    w.unpin(99)                          # unknown token is a no-op
    w.close()


def test_gc_interleaved_with_gated_inflight_snapshot(tmp_path):
    """ISSUE 9 regression: a background snapshot is mid-write (gated just
    before its atomic publish) when a concurrent GC pass runs with a floor
    at the snapshot's *mid-write* rotate point. The WAL pin taken by
    ``snapshot()`` must clamp that GC to the published recovery floor —
    crash-before-publish recovery replays from there, and deleting its
    segments would lose acked inserts."""
    import threading

    from repro.checkpoint.fs import Fs
    from repro.serve import SearchService

    class GatedFs(Fs):
        def __init__(self):
            self.armed = False
            self.entered = threading.Event()
            self.gate = threading.Event()

        def replace(self, src, dst):     # the snapshot's atomic publish
            if self.armed:
                self.entered.set()
                assert self.gate.wait(30), "test gate never released"
            super().replace(src, dst)

    fs = GatedFs()
    svc = SearchService(_rows(60, seed=9), engines=("brute",),
                        durable_dir=str(tmp_path), fs=fs,
                        compact_threshold=10_000)
    try:
        for i in range(3):               # acked inserts the WAL must keep
            svc.insert(_rows(2, seed=20 + i))
        recovery_floor = 1               # gen-0 (constructor) snapshot's
        #   wal_from_seq: acked-but-unsnapshotted inserts live at seq >= 1
        fs.armed = True
        svc.snapshot(background=True)
        assert fs.entered.wait(30), "background writer never reached publish"
        # concurrent housekeeping GC using the mid-write rotate point as its
        # floor — without the pin this deletes the acked inserts' segments
        svc._wal.gc_below(svc._wal.seq)
        segs = wal_mod.segment_seqs(tmp_path / "wal")
        assert recovery_floor in segs, (
            f"GC deleted segment {recovery_floor} out from under the "
            f"in-flight snapshot (have {segs})")
        # the crash-before-publish recovery window is intact: replaying from
        # the published floor still yields every acked insert
        records, _ = replay(tmp_path / "wal", from_seq=recovery_floor,
                            words=4, truncate=False)
        assert sum(r.shape[0] for _, r in records) == 6
    finally:
        fs.gate.set()
    svc.snapshot_join()
    svc.close()
