"""Synthetic data helpers (ISSUE 3 satellite: queries_from_db must not crash
when asked for more queries than the database holds)."""
import numpy as np
import pytest

from repro.data.molecules import (SyntheticConfig, queries_from_db,
                                  synthetic_fingerprints)


def test_queries_within_db_are_unique_members():
    db = synthetic_fingerprints(SyntheticConfig(n=50, seed=0))
    q = queries_from_db(db, 20, seed=1)
    assert q.shape == (20, db.shape[1])
    # without replacement below n: all rows distinct
    assert len(np.unique(q, axis=0)) == 20


def test_oversampling_falls_back_to_replacement():
    db = synthetic_fingerprints(SyntheticConfig(n=10, seed=0))
    with pytest.warns(UserWarning, match="replacement"):
        q = queries_from_db(db, 25, seed=1)
    assert q.shape == (25, db.shape[1])
    # every sample is still a database member
    dbset = {r.tobytes() for r in np.asarray(db)}
    assert all(r.tobytes() in dbset for r in q)
