"""Register-array priority queue invariants + streaming top-k properties."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # collection-safe fallback (see tests/_propcheck.py)
    from _propcheck import given, settings, strategies as st

from repro.core.topk import (NEG_INF, PQ, merge_sorted, merge_sorted_many,
                             pq_insert, pq_insert_batch, pq_make, pq_pop,
                             pq_pop_many, pq_worst, streaming_topk)

floats = st.floats(-1e6, 1e6, allow_nan=False, width=32)


@given(st.lists(floats, min_size=1, max_size=300), st.integers(1, 16),
       st.sampled_from([4, 16, 64]))
@settings(max_examples=60, deadline=None)
def test_streaming_topk_matches_sort(xs, k, tile):
    scores = jnp.asarray(np.asarray(xs, np.float32))
    vals, idxs = streaming_topk(scores, k, tile=tile)
    vals, idxs = np.asarray(vals), np.asarray(idxs)
    expect = np.sort(np.asarray(xs, np.float32))[::-1][:k]
    got = vals[:min(k, len(xs))]
    np.testing.assert_allclose(got, expect[:len(got)], rtol=1e-6)
    # returned indices actually point at the returned values
    for v, i in zip(vals, idxs):
        if i >= 0 and np.isfinite(v):
            assert abs(xs[i] - v) < 1e-3


@given(st.lists(st.tuples(floats, st.integers(0, 10_000)), min_size=1,
                max_size=60), st.integers(1, 12))
@settings(max_examples=60, deadline=None)
def test_pq_invariants(items, cap):
    """Sorted order, fixed capacity, evict-worst: the queue always holds
    exactly the best <= cap entries seen so far, descending."""
    pq = pq_make(cap)
    for n_seen, (s, pay) in enumerate(items, start=1):
        pq = pq_insert(pq, jnp.float32(s), jnp.int32(pay))
        scores = np.asarray(pq.scores)
        assert scores.shape == (cap,)                       # fixed shape
        valid = scores[np.isfinite(scores)]
        assert (np.diff(valid) <= 1e-6).all()               # sorted desc
        seen = np.sort(np.asarray([x for x, _ in items[:n_seen]],
                                  np.float32))[::-1][:cap]
        np.testing.assert_allclose(valid, seen[:len(valid)],
                                   rtol=1e-5, atol=1e-5)    # best-k retained
        # empty lanes are a suffix of sentinels
        n_valid = len(valid)
        assert not np.isfinite(scores[n_valid:]).any()
        assert (np.asarray(pq.payload)[n_valid:] == -1).all()


@given(st.lists(st.tuples(floats, st.integers(0, 10_000)), min_size=1,
                max_size=80), st.integers(1, 12))
@settings(max_examples=40, deadline=None)
def test_pq_batch_insert_matches_sequential(items, cap):
    """pq_insert_batch (sort + rank-merge) == repeated compare-and-shift."""
    seq = pq_make(cap)
    for s, pay in items:
        seq = pq_insert(seq, jnp.float32(s), jnp.int32(pay))
    scores = jnp.asarray(np.asarray([s for s, _ in items], np.float32))
    pays = jnp.asarray(np.asarray([p for _, p in items], np.int32))
    batch = pq_insert_batch(pq_make(cap), scores, pays)
    np.testing.assert_allclose(np.asarray(seq.scores),
                               np.asarray(batch.scores), rtol=1e-6)


@given(st.lists(floats, min_size=1, max_size=40),
       st.lists(floats, min_size=1, max_size=40))
@settings(max_examples=40, deadline=None)
def test_merge_sorted_matches_full_sort(xs, ys):
    a = np.sort(np.asarray(xs, np.float32))[::-1].copy()
    b = np.sort(np.asarray(ys, np.float32))[::-1].copy()
    ia = np.arange(len(a), dtype=np.int32)
    ib = 1000 + np.arange(len(b), dtype=np.int32)
    ms, mi = merge_sorted(jnp.asarray(a), jnp.asarray(ia),
                          jnp.asarray(b), jnp.asarray(ib))
    expect = np.sort(np.concatenate([a, b]))[::-1][:len(a)]
    np.testing.assert_allclose(np.asarray(ms), expect, rtol=1e-6)
    # payloads track their scores
    both_s = np.concatenate([a, b])
    both_i = np.concatenate([ia, ib])
    for s, i in zip(np.asarray(ms), np.asarray(mi)):
        assert s in both_s[both_i == i]


def _shard_runs(valid_counts, cap, seed=0, scores=None):
    """Stacked (S, cap) descending runs with ``-1``/``NEG_INF`` pad tails of
    per-run length ``cap - valid_counts[s]`` — the sharded fan-out's result
    shape. Ids encode (shard, slot) as ``shard * 1000 + slot``."""
    rng = np.random.default_rng(seed)
    S = len(valid_counts)
    s_out = np.full((S, cap), -np.inf, dtype=np.float32)
    i_out = np.full((S, cap), -1, dtype=np.int32)
    for s, n in enumerate(valid_counts):
        vals = (np.sort(rng.random(n).astype(np.float32))[::-1]
                if scores is None else np.asarray(scores[s], np.float32))
        s_out[s, :n] = vals[:n]
        i_out[s, :n] = s * 1000 + np.arange(n)
    return s_out, i_out


def _merge_oracle(s_runs, i_runs, cap):
    """Stable shard-major merge: ties keep lower shard, then run order."""
    flat_s = s_runs.reshape(-1)
    flat_i = i_runs.reshape(-1)
    order = np.argsort(-flat_s, kind="stable")[:cap]
    return flat_s[order], flat_i[order]


@given(st.lists(st.integers(0, 8), min_size=1, max_size=5),
       st.sampled_from([1, 3, 8]))
@settings(max_examples=30, deadline=None)
def test_merge_sorted_many_matches_stable_oracle(valid_counts, cap):
    """The rank-merge tree over S shard runs == a stable sort of the
    concatenation, for any mix of run lengths incl. empty/padded runs."""
    valid_counts = [min(v, cap) for v in valid_counts]
    s_runs, i_runs = _shard_runs(valid_counts, cap, seed=cap)
    ms, mi = merge_sorted_many(jnp.asarray(s_runs), jnp.asarray(i_runs))
    es, ei = _merge_oracle(s_runs, i_runs, cap)
    np.testing.assert_array_equal(np.asarray(ms), es)
    np.testing.assert_array_equal(np.asarray(mi), ei)


def test_merge_sorted_many_unequal_counts_and_pads():
    """Shards returning fewer than cap rows (id -1 / -inf pads): pads never
    displace real entries and only surface when valid entries run out."""
    s_runs, i_runs = _shard_runs([4, 0, 2, 1], cap=4, seed=3)
    ms, mi = merge_sorted_many(jnp.asarray(s_runs), jnp.asarray(i_runs))
    ms, mi = np.asarray(ms), np.asarray(mi)
    assert (mi >= 0).all()                      # 7 valid entries, cap 4
    es, ei = _merge_oracle(s_runs, i_runs, 4)
    np.testing.assert_array_equal(ms, es)
    np.testing.assert_array_equal(mi, ei)
    # fewer valid entries than cap: the tail is sentinel pads
    s_runs, i_runs = _shard_runs([1, 0, 1], cap=4, seed=4)
    ms, mi = merge_sorted_many(jnp.asarray(s_runs), jnp.asarray(i_runs))
    assert (np.asarray(mi)[2:] == -1).all()
    assert not np.isfinite(np.asarray(ms)[2:]).any()


def test_merge_sorted_many_duplicate_scores_stable_by_shard():
    """Equal scores come back ordered by shard index then slot (the
    left-leaning tree keeps run A first at every level) — the deterministic
    cross-shard tie order the sharded engines rely on."""
    dup = [[0.5, 0.5, 0.25], [0.5, 0.5, 0.25], [0.5, 0.25, 0.25]]
    s_runs, i_runs = _shard_runs([3, 3, 3], cap=3, scores=dup)
    ms, mi = merge_sorted_many(jnp.asarray(s_runs), jnp.asarray(i_runs))
    np.testing.assert_allclose(np.asarray(ms), [0.5] * 3)
    # all five 0.5-entries exist; the best 3 are shard 0's pair then shard 1
    np.testing.assert_array_equal(np.asarray(mi), [0, 1, 1000])


def test_merge_sorted_many_single_run_identity():
    """S == 1 is the identity — the sharded traversal's 1-shard bit-parity
    contract rests on this."""
    s_runs, i_runs = _shard_runs([3], cap=5, seed=9)
    ms, mi = merge_sorted_many(jnp.asarray(s_runs), jnp.asarray(i_runs))
    np.testing.assert_array_equal(np.asarray(ms), s_runs[0])
    np.testing.assert_array_equal(np.asarray(mi), i_runs[0])


def test_pq_pop_order():
    pq = pq_make(4)
    for s in [0.2, 0.9, 0.5, 0.7, 0.1]:
        pq = pq_insert(pq, jnp.float32(s), jnp.int32(int(s * 10)))
    out = []
    for _ in range(4):
        s, p, pq = pq_pop(pq)
        out.append(float(s))
    assert out == sorted(out, reverse=True)
    assert abs(out[0] - 0.9) < 1e-6
    # queue now empty: sentinel pops
    s, p, pq = pq_pop(pq)
    assert not np.isfinite(float(s)) and int(p) == -1


def test_pq_pop_many_beam():
    pq = pq_make(6)
    for s in [0.1, 0.4, 0.9, 0.3, 0.8]:
        pq = pq_insert(pq, jnp.float32(s), jnp.int32(int(s * 10)))
    top_s, top_p, rest = pq_pop_many(pq, 3)
    np.testing.assert_allclose(np.asarray(top_s), [0.9, 0.8, 0.4], rtol=1e-6)
    assert list(np.asarray(top_p)) == [9, 8, 4]
    # remaining entries shifted up, tail refilled with sentinels
    np.testing.assert_allclose(np.asarray(rest.scores)[:2], [0.3, 0.1],
                               rtol=1e-6)
    assert not np.isfinite(np.asarray(rest.scores)[2:]).any()


def test_pq_worst_tracks_eviction_threshold():
    pq = pq_make(3)
    assert not np.isfinite(float(pq_worst(pq)))     # not full: inserts free
    for s in [0.3, 0.6, 0.9]:
        pq = pq_insert(pq, jnp.float32(s), jnp.int32(0))
    assert abs(float(pq_worst(pq)) - 0.3) < 1e-6    # full: worst retained
    pq = pq_insert(pq, jnp.float32(0.5), jnp.int32(1))
    assert abs(float(pq_worst(pq)) - 0.5) < 1e-6    # 0.3 evicted
