"""Streaming top-k and register-array priority queue properties."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # collection-safe fallback (see tests/_propcheck.py)
    from _propcheck import given, settings, strategies as st

from repro.core.topk import (streaming_topk, pq_make, pq_insert_max,
                             pq_pop_max, pq_worst_max)

floats = st.floats(-1e6, 1e6, allow_nan=False, width=32)


@given(st.lists(floats, min_size=1, max_size=300), st.integers(1, 16),
       st.sampled_from([4, 16, 64]))
@settings(max_examples=60, deadline=None)
def test_streaming_topk_matches_sort(xs, k, tile):
    scores = jnp.asarray(np.asarray(xs, np.float32))
    vals, idxs = streaming_topk(scores, k, tile=tile)
    vals, idxs = np.asarray(vals), np.asarray(idxs)
    expect = np.sort(np.asarray(xs, np.float32))[::-1][:k]
    got = vals[:min(k, len(xs))]
    np.testing.assert_allclose(got, expect[:len(got)], rtol=1e-6)
    # returned indices actually point at the returned values
    for v, i in zip(vals, idxs):
        if i >= 0 and np.isfinite(v):
            assert abs(xs[i] - v) < 1e-3


@given(st.lists(st.tuples(floats, st.integers(0, 10_000)), min_size=1,
                max_size=60), st.integers(1, 12))
@settings(max_examples=60, deadline=None)
def test_pq_keeps_best_k(items, cap):
    pq = pq_make(cap, max_heap=True)
    for s, pay in items:
        pq = pq_insert_max(pq, jnp.float32(s), jnp.int32(pay))
    scores = np.asarray(pq.scores)
    # sorted descending
    valid = scores[np.isfinite(scores)]
    assert (np.diff(valid) <= 1e-6).all()
    expect = np.sort(np.asarray([s for s, _ in items], np.float32))[::-1][:cap]
    np.testing.assert_allclose(valid, expect[:len(valid)], rtol=1e-5, atol=1e-5)


def test_pq_pop_order():
    pq = pq_make(4, max_heap=True)
    for s in [0.2, 0.9, 0.5, 0.7, 0.1]:
        pq = pq_insert_max(pq, jnp.float32(s), jnp.int32(int(s * 10)))
    out = []
    for _ in range(4):
        s, p, pq = pq_pop_max(pq)
        out.append(float(s))
    assert out == sorted(out, reverse=True)
    assert abs(out[0] - 0.9) < 1e-6


def test_pq_worst_tracks_kth():
    pq = pq_make(3, max_heap=True)
    assert not np.isfinite(float(pq_worst_max(pq)))
    for s in [0.3, 0.6, 0.9]:
        pq = pq_insert_max(pq, jnp.float32(s), jnp.int32(0))
    assert abs(float(pq_worst_max(pq)) - 0.3) < 1e-6
