"""Recurrent blocks: training (parallel/chunked) path == decode (step) path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import ssm, xlstm
from repro.models.param import split


D = 32


def _cfg(name):
    return get_arch(name).reduced().with_(dtype="float32", n_heads=4,
                                          n_kv_heads=4, d_model=D)


def test_mamba_train_equals_decode_rollout():
    cfg = _cfg("jamba-v0.1-52b")
    p, _ = split(ssm.init_mamba(jax.random.key(0), cfg, D))
    x = jax.random.normal(jax.random.key(1), (2, 16, D), jnp.float32) * 0.5
    y_train = ssm.mamba_train(p, cfg, x, D, chunk=4)
    state = ssm.init_mamba_state(cfg, 2, D)
    outs = []
    for t in range(16):
        y, state = ssm.mamba_decode(p, cfg, x[:, t:t + 1], state, D)
        outs.append(y)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_train),
                               rtol=1e-3, atol=1e-4)


def test_mamba_chunk_size_invariance():
    cfg = _cfg("jamba-v0.1-52b")
    p, _ = split(ssm.init_mamba(jax.random.key(2), cfg, D))
    x = jax.random.normal(jax.random.key(3), (1, 24, D), jnp.float32)
    y8 = ssm.mamba_train(p, cfg, x, D, chunk=8)
    y24 = ssm.mamba_train(p, cfg, x, D, chunk=24)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y24), rtol=1e-4,
                               atol=1e-5)


def test_mlstm_train_equals_decode_rollout():
    cfg = _cfg("xlstm-350m")
    p, _ = split(xlstm.init_mlstm(jax.random.key(0), cfg, D))
    x = jax.random.normal(jax.random.key(1), (2, 12, D), jnp.float32) * 0.5
    y_train = xlstm.mlstm_train(p, cfg, x, D)
    state = xlstm.init_mlstm_state(cfg, 2, D)
    outs = []
    for t in range(12):
        y, state = xlstm.mlstm_decode(p, cfg, x[:, t:t + 1], state, D)
        outs.append(y)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_train),
                               rtol=1e-3, atol=1e-4)


def test_slstm_train_equals_decode_rollout():
    cfg = _cfg("xlstm-350m")
    p, _ = split(xlstm.init_slstm(jax.random.key(4), cfg, D))
    x = jax.random.normal(jax.random.key(5), (2, 10, D), jnp.float32) * 0.5
    y_train = xlstm.slstm_train(p, cfg, x, D)
    state = xlstm.init_slstm_state(cfg, 2, D)
    outs = []
    for t in range(10):
        y, state = xlstm.slstm_decode(p, cfg, x[:, t:t + 1], state, D)
        outs.append(y)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_train),
                               rtol=1e-3, atol=1e-4)


def test_mlstm_chunking_invariance():
    cfg = _cfg("xlstm-350m")
    p, _ = split(xlstm.init_mlstm(jax.random.key(6), cfg, D))
    x = jax.random.normal(jax.random.key(7), (1, 16, D), jnp.float32)
    y4 = xlstm.mlstm_train(p, cfg, x, D, chunk=4)
    y16 = xlstm.mlstm_train(p, cfg, x, D, chunk=16)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y16), rtol=1e-4,
                               atol=1e-5)


def test_slstm_custom_vjp_matches_autodiff():
    """The custom-VJP core (one weight-grad contraction per sequence) must
    produce the same gradients as naive autodiff through the step scan."""
    cfg = _cfg("xlstm-350m")
    p, _ = split(xlstm.init_slstm(jax.random.key(8), cfg, D))
    x = jax.random.normal(jax.random.key(9), (2, 9, D), jnp.float32) * 0.5

    def loss_custom(p):
        return jnp.sum(xlstm.slstm_train(p, cfg, x, D) ** 2)

    def loss_naive(p):
        xs = xlstm._slstm_inputs(p, x)
        rs = tuple(p[f"r_{g}"]["w"].astype(jnp.float32)
                   for g in ("i", "f", "z", "o"))
        st = xlstm.init_slstm_state(cfg, 2, D)

        def body(st, xt):
            pres = tuple(xi + st.h @ r for xi, r in zip(xt, rs))
            new = xlstm._gate_step(rs, pres, st)
            return new, new.h

        xs_t = tuple(jnp.moveaxis(v, 1, 0) for v in xs)
        _, hs = jax.lax.scan(body, st, xs_t)
        y = xlstm.apply_dense(p["out"], jnp.moveaxis(hs, 0, 1).astype(x.dtype))
        return jnp.sum(y ** 2)

    l1, g1 = jax.value_and_grad(loss_custom)(p)
    l2, g2 = jax.value_and_grad(loss_naive)(p)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    for k in ("r_i", "r_f", "r_z", "r_o", "w_i", "w_o", "out"):
        np.testing.assert_allclose(np.asarray(jax.tree.leaves(g1[k])[0]),
                                   np.asarray(jax.tree.leaves(g2[k])[0]),
                                   rtol=1e-4, atol=1e-5, err_msg=k)
