"""Training loop: loss decreases, fault-tolerant restart is exact,
microbatching is equivalent, gradient compression behaves."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data.tokens import DataConfig, batch_for_step
from repro.distributed.compression import (dequantize_int8, ef_compress_grads,
                                           ef_init, quantize_int8)
from repro.train.optimizer import AdamWConfig
from repro.train.step import TrainConfig, init_train_state, make_train_step


CFG = get_arch("granite-3-2b").reduced()

# The production schedule warms up over 100 steps — at 10 smoke steps the lr
# never leaves the noise floor and "loss decreases" is a coin flip. Pin a
# schedule shaped for the smoke horizon instead.
SMOKE_OPT = AdamWConfig(warmup_steps=2, total_steps=10)


def _run(tcfg, steps=8, seed=0):
    step_fn = jax.jit(make_train_step(CFG, tcfg))
    state = init_train_state(CFG, tcfg, jax.random.key(seed))
    dcfg = DataConfig(vocab=CFG.vocab, seq_len=32, global_batch=8)
    losses = []
    for s in range(steps):
        batch = {k: jnp.asarray(v) for k, v in batch_for_step(dcfg, s).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    return losses, state


def test_loss_decreases():
    losses, _ = _run(TrainConfig(opt=SMOKE_OPT), steps=10)
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) * 0.99, losses
    assert all(np.isfinite(losses))


def test_microbatch_equivalence():
    l1, _ = _run(TrainConfig(n_microbatches=1), steps=3)
    l4, _ = _run(TrainConfig(n_microbatches=4), steps=3)
    np.testing.assert_allclose(l1, l4, rtol=5e-2)


def test_grad_compression_trains():
    losses, _ = _run(TrainConfig(opt=SMOKE_OPT, grad_compression=True),
                     steps=10)
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) * 0.99, losses


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.key(0), (1000,)) * 3
    q, s, n = quantize_int8(x)
    deq = dequantize_int8(q, s, n, x.shape, jnp.float32)
    err = float(jnp.abs(deq - x).max())
    assert err <= float(jnp.abs(x).max()) / 127 + 1e-6


def test_error_feedback_accumulates_residual():
    g = {"w": jax.random.normal(jax.random.key(1), (64,)) * 1e-3}
    ef = ef_init(g)
    g1, ef1 = ef_compress_grads(g, ef)
    # compressed + residual reconstructs the input exactly
    np.testing.assert_allclose(np.asarray(g1["w"] + ef1.error["w"]),
                               np.asarray(g["w"]), rtol=1e-6, atol=1e-8)


def test_fault_injection_recovery(tmp_path):
    """Crash at step 6, recover from checkpoint at 5, end state must equal an
    uninterrupted run (deterministic data + exact restore)."""
    from repro.launch.train import train
    logs = []
    loss_fail = train("granite-3-2b", steps=10, global_batch=4, seq_len=32,
                      ckpt_dir=str(tmp_path / "a"), ckpt_every=1, fail_at=6,
                      log=logs.append)
    loss_clean = train("granite-3-2b", steps=10, global_batch=4, seq_len=32,
                       ckpt_dir=str(tmp_path / "b"), ckpt_every=1,
                       log=lambda *a: None)
    assert any("fault" in str(l) for l in logs)
    np.testing.assert_allclose(loss_fail[-3:], loss_clean[-3:], rtol=1e-4)
