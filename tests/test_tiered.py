"""Tiered residency (ISSUE 7): host-resident full-resolution rows +
double-buffered host->HBM streaming rescore must be **bit-identical** (ids
and scores) to the fully device-resident path, at every fold level, on both
device backends, with and without a delta segment, across compactions, and
through snapshot/restore."""
import tempfile

import numpy as np
import pytest

from repro.core import BitBoundFoldingEngine, BruteForceEngine
from repro.data.molecules import (SyntheticConfig, queries_from_db,
                                  synthetic_fingerprints)
from repro.serve.store import MutableFingerprintStore, TieredFingerprintStore

K = 10


@pytest.fixture(scope="module")
def db():
    return synthetic_fingerprints(SyntheticConfig(n=3000))


@pytest.fixture(scope="module")
def queries(db):
    return queries_from_db(db, 16)


@pytest.fixture(scope="module")
def extra():
    return synthetic_fingerprints(SyntheticConfig(n=120, seed=9))


def _assert_identical(a, b):
    ids_a, sims_a = a
    ids_b, sims_b = b
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))
    np.testing.assert_array_equal(np.asarray(sims_a), np.asarray(sims_b))


@pytest.mark.parametrize("backend", ["jnp", "tpu"])
def test_brute_tiered_parity(db, queries, extra, backend):
    dev = BruteForceEngine(db, backend=backend)
    # tiny chunk -> the 4096-capacity main segment streams in 8 chunks
    tie = BruteForceEngine(db, backend=backend, residency="tiered",
                           tier_chunk_rows=512)
    _assert_identical(dev.search(queries, K), tie.search(queries, K))
    dev.insert(extra)
    tie.insert(extra)                       # delta path on top of streaming
    _assert_identical(dev.search(queries, K), tie.search(queries, K))
    assert tie.stats["residency"] == "tiered"
    assert tie.stats["tiered_chunks"] == 8
    assert tie.stats["tiered_streamed_bytes"] > 0
    assert 0.0 <= tie.stats["tiered_stall_fraction"] <= 1.0


@pytest.mark.parametrize("backend", ["jnp", "tpu"])
@pytest.mark.parametrize("m", [1, 4])
def test_bitbound_tiered_parity(db, queries, extra, backend, m):
    kw = dict(cutoff=0.6, m=m, backend=backend, compact_threshold=100)
    dev = BitBoundFoldingEngine(db, **kw)
    tie = BitBoundFoldingEngine(db, residency="tiered", tier_chunk=32, **kw)
    _assert_identical(dev.search(queries, K), tie.search(queries, K))
    # delta phase, then past compact_threshold -> rebuilt main segment
    for lo, hi in ((0, 40), (40, 120)):
        dev.insert(extra[lo:hi])
        tie.insert(extra[lo:hi])
        _assert_identical(dev.search(queries, K), tie.search(queries, K))
    assert dev.store.compactions > 0
    if m > 1:   # m == 1 never streams (stage-1 folded scores are exact)
        assert tie.stats["residency"] == "tiered"
        assert tie.stats["tiered_chunks"] > 1


def test_bitbound_tiered_matches_numpy_reference(db, queries):
    """The streaming path sits behind the same oracle as the device path."""
    ref = BitBoundFoldingEngine(db, cutoff=0.6, m=4, backend="numpy")
    tie = BitBoundFoldingEngine(db, cutoff=0.6, m=4, backend="jnp",
                                residency="tiered", tier_chunk=64)
    ids_r, sims_r = ref.search(queries, K)
    ids_t, sims_t = tie.search(queries, K)
    np.testing.assert_array_equal(ids_r, np.asarray(ids_t, dtype=np.int64))
    np.testing.assert_allclose(sims_r, sims_t, rtol=0, atol=0)


def test_tiered_keeps_full_rows_off_device(db):
    eng = BitBoundFoldingEngine(db, cutoff=0.6, m=4, backend="jnp",
                                residency="tiered")
    assert eng.full is None                   # never uploaded
    assert eng._full_np is eng.store.main.db  # host view, no copy
    b = BruteForceEngine(db, backend="jnp", residency="tiered")
    assert b.db is None and b._db_np is b.store.main.db


def test_invalid_residency_rejected(db):
    with pytest.raises(ValueError, match="residency"):
        BruteForceEngine(db[:64], residency="floppy")
    with pytest.raises(ValueError, match="residency"):
        BitBoundFoldingEngine(db[:64], residency="hbm")


def test_tiered_store_mmap_byte_equal(db, extra):
    """The memmap-backed main segment build is byte-identical to the
    in-RAM build, including across an insert-triggered compaction."""
    with tempfile.TemporaryDirectory() as td:
        kw = dict(sorted_main=True, fold_m=4, compact_threshold=64)
        plain = MutableFingerprintStore(db, **kw)
        tiered = TieredFingerprintStore(db, mmap_dir=td, **kw)
        assert tiered.residency == "tiered"
        assert isinstance(tiered.main.db, np.memmap)
        for attr in ("db", "folded", "counts", "folded_counts", "order"):
            np.testing.assert_array_equal(
                np.asarray(getattr(plain.main, attr)),
                np.asarray(getattr(tiered.main, attr)), err_msg=attr)
        plain.insert(extra)
        tiered.insert(extra)                  # crosses compact_threshold
        assert tiered.compactions == plain.compactions > 0
        for attr in ("db", "folded", "counts", "folded_counts", "order"):
            np.testing.assert_array_equal(
                np.asarray(getattr(plain.main, attr)),
                np.asarray(getattr(tiered.main, attr)), err_msg=attr)


def test_tiered_store_engine_inherits_residency(db, queries):
    """An engine built on a TieredFingerprintStore (residency=None) serves
    tiered and stays bit-identical to a device-resident engine."""
    with tempfile.TemporaryDirectory() as td:
        st = TieredFingerprintStore(db, mmap_dir=td, sorted_main=True,
                                    fold_m=4, compact_threshold=4096)
        eng = BitBoundFoldingEngine(None, cutoff=0.6, m=4, backend="jnp",
                                    store=st)
        assert eng.residency == "tiered"
        dev = BitBoundFoldingEngine(db, cutoff=0.6, m=4, backend="jnp")
        _assert_identical(dev.search(queries, K), eng.search(queries, K))


def test_tiered_snapshot_roundtrip(db, queries, extra):
    """Snapshot/restore of a tiered engine: the hydrated engine stays
    tiered (full DB never materialized on device) and bit-identical."""
    from repro.serve import snapshot as snap
    eng = BitBoundFoldingEngine(db, cutoff=0.6, m=4, backend="jnp",
                                residency="tiered", tier_chunk=64)
    eng.insert(extra[:30])
    arrays, meta = snap.engine_state(eng)
    assert meta["store"]["residency"] == "device"  # plain store under a
    #   tiered *engine*: residency was an engine knob, carried by the config
    r1 = eng.search(queries, K)
    back = snap.engine_from_state(arrays, meta, cutoff=0.6, m=4,
                                  backend="jnp", residency="tiered",
                                  tier_chunk=64)
    assert back.residency == "tiered" and back.full is None
    _assert_identical(r1, back.search(queries, K))
    # tiered *store*: residency rides in the snapshot meta itself
    st = TieredFingerprintStore(db, sorted_main=True, fold_m=4)
    eng2 = BitBoundFoldingEngine(None, cutoff=0.6, m=4, backend="jnp",
                                 store=st)
    arrays2, meta2 = snap.engine_state(eng2)
    assert meta2["store"]["residency"] == "tiered"
    back2 = snap.engine_from_state(arrays2, meta2, cutoff=0.6, m=4,
                                   backend="jnp")
    assert back2.residency == "tiered" and back2.full is None
    _assert_identical(eng2.search(queries, K), back2.search(queries, K))


def test_service_residency_plumbs_through(db, queries):
    from repro.serve.service import SearchService
    svc = SearchService(db, engines=("brute", "bitbound-folding"),
                        backend="jnp", residency="tiered")
    for eng in svc.engines.values():
        assert eng.residency == "tiered"
    dev = SearchService(db, engines=("bitbound-folding",), backend="jnp")
    ids_t, sims_t = svc.search(queries, k=K, engine="bitbound-folding")
    ids_d, sims_d = dev.search(queries, k=K)
    np.testing.assert_array_equal(ids_t, ids_d)
    np.testing.assert_array_equal(sims_t, sims_d)
