"""End-to-end engine behaviour (paper §IV): brute force is exact; BitBound &
folding trade recall per Table I / Fig 2; work scales down with cutoff."""
import numpy as np
import pytest

from repro.core import (BruteForceEngine, BitBoundFoldingEngine, HNSWEngine,
                        recall_at_k)


def test_bruteforce_exact(small_db, queries, brute_truth):
    s, true_ids = brute_truth
    eng = BruteForceEngine(small_db)
    ids, vals = eng.search(queries, 20)
    expect = np.take_along_axis(s, true_ids, axis=1)
    np.testing.assert_allclose(vals, expect, rtol=1e-6)
    assert recall_at_k(ids, true_ids) == 1.0


def test_bitbound_pure_recall_high(small_db, queries, brute_truth):
    """m=1 (no folding): only the Eq.2 prune is active — misses are only
    true neighbours below the cutoff."""
    _, true_ids = brute_truth
    eng = BitBoundFoldingEngine(small_db, cutoff=0.2, m=1)
    ids, _ = eng.search(queries, 20)
    assert recall_at_k(ids, true_ids) >= 0.95


def test_scanned_work_decreases_with_cutoff(small_db, queries):
    scans = []
    for cutoff in (0.2, 0.5, 0.8):
        eng = BitBoundFoldingEngine(small_db, cutoff=cutoff, m=1)
        eng.search(queries, 10)
        scans.append(eng.scanned(len(queries)))
    assert scans[0] >= scans[1] >= scans[2]
    assert scans[2] < scans[0]


def test_two_stage_folding_recall(small_db, queries, brute_truth):
    """Paper Table I trend: scheme-1 folding with the k_r1 rescore keeps
    accuracy high through m=8, then degrades at m=32."""
    _, true_ids = brute_truth
    recalls = {}
    for m in (1, 4, 32):
        eng = BitBoundFoldingEngine(small_db, cutoff=0.0, m=m)
        ids, _ = eng.search(queries, 20)
        recalls[m] = recall_at_k(ids, true_ids)
    assert recalls[1] == 1.0
    assert recalls[4] >= 0.9
    assert recalls[32] <= recalls[4]


def test_self_query_always_found(small_db, queries):
    eng = BitBoundFoldingEngine(small_db, cutoff=0.8, m=2)
    ids, vals = eng.search(queries, 5)
    assert (vals[:, 0] >= 1.0 - 1e-6).all()


def test_scanned_counter_contract(small_db, queries):
    """Unified work-counter contract: ``scanned(n_queries)`` is the number of
    candidates scored for n_queries queries, extrapolated from the most
    recent search batch (closed-form for input-independent engines)."""
    n, nq = small_db.shape[0], len(queries)

    brute = BruteForceEngine(small_db)
    # input-independent: defined before any search, linear in n_queries
    assert brute.scanned(nq) == nq * n
    brute.search(queries, 5)
    assert brute.scanned(nq) == nq * n
    assert brute.scanned(2 * nq) == 2 * nq * n

    for backend in ("numpy", "tpu"):
        eng = BitBoundFoldingEngine(small_db, cutoff=0.6, m=2,
                                    backend=backend)
        # data-dependent: zero before any search...
        assert eng.scanned(nq) == 0
        eng.search(queries, 5)
        got = eng.scanned(nq)
        # ...equals the summed Eq.2 window sizes of the batch afterwards
        counts = np.sort(np.bitwise_count(np.asarray(small_db)).sum(-1))
        expect = 0
        for q in np.asarray(queries):
            a = int(np.bitwise_count(q).sum())
            lo = np.searchsorted(counts, int(np.ceil(a * 0.6)), side="left")
            hi = np.searchsorted(counts, int(np.floor(a / 0.6)), side="right")
            expect += max(hi - lo, 0)
        assert got == expect, backend
        # and scales linearly in the requested n_queries
        assert eng.scanned(2 * nq) == 2 * got
        assert eng.scanned(0) == 0


def test_scanned_contract_pins_all_engines(small_db, queries):
    """Regression (ISSUE 2 satellite): every data-dependent engine follows
    the SAME extrapolate-from-last-batch contract — ``scanned(n) =
    last_batch_total * n / last_batch_n_queries`` — including when the
    requested ``n`` differs from the batch size (the old HNSW counter
    double-counted there)."""
    db = np.asarray(small_db)[:500]
    qs = np.asarray(queries)[:4]      # batch of 4 ...
    ask = 10                          # ... but ask about 10 queries

    engines = [
        BitBoundFoldingEngine(db, cutoff=0.6, m=2, backend="numpy"),
        BitBoundFoldingEngine(db, cutoff=0.6, m=2, backend="tpu"),
        HNSWEngine(db, m=6, ef_construction=30, backend="numpy"),
        HNSWEngine(db, m=6, ef_construction=30, backend="jnp"),
    ]
    for eng in engines:
        label = f"{type(eng).__name__}[{eng.backend}]"
        assert eng.scanned(ask) == 0, label        # nothing before a search
        eng.search(qs, 5)
        batch_total = eng.scanned(len(qs))         # identity at batch size
        assert batch_total > 0, label
        assert eng.scanned(ask) == round(batch_total * ask / len(qs)), label
        assert eng.scanned(2 * len(qs)) == 2 * batch_total, label
        assert eng.scanned(0) == 0, label

    # HNSW specifically: the batch total is the traversal's own telemetry,
    # not an iteration count rescaled twice
    hnsw = engines[-1]
    hnsw.search(qs, 5)
    assert hnsw.scanned(len(qs)) == hnsw.stats["neighbour_evals"]

    # input-independent engine: closed form, defined before any search
    brute = BruteForceEngine(db)
    assert brute.scanned(ask) == ask * db.shape[0]


def test_engine_backend_validation():
    db = np.zeros((4, 8), np.uint32)
    with pytest.raises(ValueError, match="backend"):
        BruteForceEngine(db, backend="numpy")      # no host path for brute
    with pytest.raises(ValueError, match="backend"):
        BitBoundFoldingEngine(db, backend="cuda")
