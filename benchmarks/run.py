"""Benchmark driver — one section per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV (harness contract) and stores JSON
under experiments/bench/. ``--fast`` shrinks DB sizes for CI-style runs.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller DBs (used by the final tee run on CPU)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import (bitbound_speedup, engine_throughput, exhaustive_qps,
                   folding_accuracy, hnsw_grid, pareto)

    sections = [
        ("table1_folding_accuracy", lambda: folding_accuracy.run(
            n_db=6_000 if args.fast else 20_000, n_queries=32)),
        ("fig2_bitbound_speedup", lambda: bitbound_speedup.run(
            n_db=20_000 if args.fast else 60_000, n_queries=48)),
        ("fig7_exhaustive_qps", lambda: exhaustive_qps.run(
            n_db=20_000 if args.fast else 60_000, n_queries=16)),
        ("fig8_hnsw_grid", lambda: hnsw_grid.run(
            n_db=3_000 if args.fast else 8_000, n_queries=24,
            ms=(5, 10) if args.fast else (5, 10, 20),
            efs=(20, 60, 120) if args.fast else (20, 60, 120, 200))),
        ("fig10_pareto", lambda: pareto.run(
            n_db=3_000 if args.fast else 8_000, n_queries=24)),
        ("engine_throughput", lambda: engine_throughput.run(
            n_db=20_000 if args.fast else 60_000)),
    ]

    failures = 0
    for name, fn in sections:
        if args.only and args.only != name:
            continue
        print(f"### {name}")
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
        print()

    # roofline table (reads dry-run artifacts if present)
    print("### roofline")
    try:
        from . import roofline
        roofline.run()
    except Exception:
        traceback.print_exc()

    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
