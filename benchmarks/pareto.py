"""Paper Figs. 10/11: QPS-vs-recall Pareto frontiers across engines on one
platform.

Two QPS columns per point:
* ``host_qps`` — wall clock on this container. Reference only: the brute
  path is jit-compiled jnp while BitBound&folding runs the variable-range
  numpy reference, so cross-engine host numbers are not apples-to-apples.
* ``tpu_projected_qps`` — the roofline projection (bytes streamed per query
  / 819 GB/s HBM, + traversal cost for HNSW), the same accounting the paper
  uses for its engines. This column is the cross-engine Pareto.

BitBound cutoffs are swept (the paper fixes Sc=0.8 on ChEMBL where top-20
neighbours are mostly >=0.8-similar; our synthetic neighbourhoods sit lower,
so the equivalent operating points use lower cutoffs — recall vs cutoff is
the actual knob, as in the paper's Fig. 10).
"""
from __future__ import annotations

import numpy as np

from repro.core import (BitBoundFoldingEngine, BruteForceEngine, HNSWEngine,
                        recall_at_k)
from repro.core import hnsw as hn
from repro.core.folding import kr1_for
from .common import K, brute_truth, emit, get_db, get_queries, timeit

HBM_BW = 819e9
BYTES_PER_FP = 128  # 32 x u32


def run(n_db=8_000, n_queries=32):
    db = get_db(n_db, seed=9)
    queries = get_queries(db, n_queries, seed=10)
    true_ids, _ = brute_truth(db, queries, K)
    rows = []

    def tpu_qps(bytes_per_query):
        return HBM_BW / max(bytes_per_query, 1.0)

    eng = BruteForceEngine(db)
    dt = timeit(lambda: eng.search(queries, K))
    rows.append({"name": "pareto_bruteforce", "engine": "bruteforce",
                 "host_qps": round(n_queries / dt, 1), "recall": 1.0,
                 "tpu_projected_qps": round(tpu_qps(n_db * BYTES_PER_FP), 1)})

    for cutoff in (0.0, 0.3, 0.5):
        for m in (2, 4, 8):
            eng = BitBoundFoldingEngine(db, cutoff=cutoff, m=m)
            dt = timeit(lambda: eng.search(queries, K), repeats=2)
            ids, _ = eng.search(queries, K)
            frac = eng.scanned(n_queries) / (n_queries * n_db)
            bpq = n_db * frac * BYTES_PER_FP / m + kr1_for(K, m) * BYTES_PER_FP
            rows.append({
                "name": f"pareto_bbf_Sc{cutoff}_m{m}",
                "engine": "bitbound_folding", "m": m, "cutoff": cutoff,
                "host_qps": round(n_queries / dt, 1),
                "recall": round(recall_at_k(ids, true_ids), 4),
                "scan_fraction": round(frac, 4),
                "tpu_projected_qps": round(tpu_qps(bpq), 1)})

    engines = {}
    for m, ef in ((10, 40), (10, 120), (20, 60), (20, 200)):
        if m not in engines:
            index = hn.build_hnsw(np.asarray(db), m=m, ef_construction=100,
                                  seed=0)
            engines[m] = HNSWEngine(db, index=index)
        eng = engines[m]
        dt = timeit(lambda: eng.search(queries, K, ef=ef), repeats=2)
        ids, _ = eng.search(queries, K, ef=ef)
        evals = max(eng.scanned(n_queries) // n_queries, 1)
        # traversal reads: fingerprints of evaluated neighbours + adjacency
        bpq = evals * (BYTES_PER_FP + 4) + evals * 4
        rows.append({"name": f"pareto_hnsw_m{m}_ef{ef}", "engine": "hnsw",
                     "m": m, "ef": ef, "host_qps": round(n_queries / dt, 1),
                     "recall": round(recall_at_k(ids, true_ids), 4),
                     "avg_evals": int(evals),
                     "tpu_projected_qps": round(tpu_qps(bpq), 1)})
    emit("fig10_pareto", rows)
    return rows


if __name__ == "__main__":
    run()
