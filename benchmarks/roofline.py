"""Roofline analysis (deliverable g): three terms per (arch × shape × mesh)
from the dry-run artifacts in experiments/dryrun/.

Terms (seconds per step, per the harness formulas, v5e constants):
  compute    = HLO_FLOPs_per_device / 197e12            (bf16 MXU peak)
  memory     = HBM_bytes_per_device / 819e9
  collective = collective_bytes_per_device / 50e9       (per-link ICI)

Methodology notes (details in EXPERIMENTS.md §Roofline):
* HLO_FLOPs is the *loop-aware* dot-FLOP count (launch/hlo_analysis.py):
  XLA's cost_analysis counts while bodies once, so layer scans / microbatch
  scans / chunk scans are re-weighted by their trip counts. Elementwise
  FLOPs are excluded (≪1% for these shapes).
* HBM bytes uses max(cost_analysis bytes, analytic floor). The analytic
  floor is parameter + optimizer + KV-cache traffic: train ≈ 28 B/param
  (bf16 param read ×3 passes + f32 grad w + m/v rw + param rw), decode ≈
  2 B/param + cache r/w, prefill ≈ 2 B/param + cache write.
* MODEL_FLOPS = 6·N_active·tokens (train) / 2·N_active·tokens (fwd-only),
  N from the abstract param tree (exact), MoE active-expert adjusted.
"""
from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
# Per-DMA-descriptor issue cost for the gather-stage model (order of
# magnitude for the v5e DMA engine; what makes 128-byte row fetches
# latency-bound long before they are bandwidth-bound).
DMA_SETUP_S = 1e-6
# Host->device link for the tiered-residency model (PCIe-class, the v5e
# host interface). Benchmarks that measured the actual link on their host
# carry a link_gbps_measured column, which takes precedence.
HOST_LINK_BW = 32e9
HBM_BYTES = 16e9                      # v5e per-chip capacity

DRYRUN_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"
OUT = Path(__file__).resolve().parent.parent / "experiments" / "roofline.json"
BENCH_DIR = Path(__file__).resolve().parent.parent / "experiments" / "bench"
OUT_GATHER = (Path(__file__).resolve().parent.parent / "experiments"
              / "roofline_gather.json")


def param_counts(arch: str):
    """(N_total, N_active) from the abstract param tree (no allocation)."""
    import jax
    from repro import models
    from repro.configs import get_arch
    cfg = get_arch(arch)
    vals, _ = models.abstract_params(cfg)
    flat = jax.tree.flatten_with_path(vals)[0]
    total = active = 0
    for path, leaf in flat:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if cfg.moe and "ffn" in keys and any(k in ("gate", "up", "down") for k in keys):
            active += n * cfg.moe.top_k / cfg.moe.n_experts
        else:
            active += n
    return total, active


def model_flops(arch: str, shape: dict) -> float:
    from repro.configs import SHAPES_BY_NAME
    sh = SHAPES_BY_NAME[shape["shape"]]
    n_total, n_active = param_counts(arch)
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * n_active * tokens
    if sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * sh.global_batch      # decode: one token per seq


def analytic_hbm_floor(arch: str, rec: dict, chips: int) -> float:
    from repro.configs import SHAPES_BY_NAME
    n_total, _ = param_counts(arch)
    sh = SHAPES_BY_NAME[rec["shape"]]
    if sh.kind == "train":
        return 28.0 * n_total / chips
    cache = rec["memory"]["output_bytes"] + rec["memory"]["argument_bytes"]
    return 2.0 * n_total / chips + cache


def analyse_cell(rec: dict) -> dict:
    chips = rec["n_devices"]
    flops_dev = rec["loop_aware"]["dot_flops"]
    coll_dev = rec["loop_aware"]["collective_bytes_total"]
    hbm_dev = max(rec.get("bytes_accessed", 0.0),
                  analytic_hbm_floor(rec["arch"], rec, chips))
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = hbm_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    bound = max((t_compute, "compute"), (t_memory, "memory"),
                (t_coll, "collective"))[1]
    mf = model_flops(rec["arch"], rec)
    t_ideal = mf / chips / PEAK_FLOPS
    t_bound = max(t_compute, t_memory, t_coll)
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": "pod2" if rec.get("multi_pod") else "pod1",
        "chips": chips, "tag": rec.get("tag", ""),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "bound": bound,
        "model_flops": mf,
        "hlo_flops_total": flops_dev * chips,
        "useful_flops_ratio": mf / max(flops_dev * chips, 1.0),
        "roofline_fraction": t_ideal / max(t_bound, 1e-12),
        "hbm_fits": rec["memory"]["temp_bytes"] + rec["memory"]["argument_bytes"] < 16e9,
    }


def run(pattern: str = "*.json", tag: str = ""):
    rows = []
    for f in sorted(DRYRUN_DIR.glob(pattern)):
        rec = json.loads(f.read_text())
        if "skipped" in rec or "error" in rec:
            continue
        if (rec.get("tag") or "") != tag:
            continue
        rows.append(analyse_cell(rec))
    rows.sort(key=lambda r: r["roofline_fraction"])
    OUT.write_text(json.dumps(rows, indent=1))
    print(f"{'arch':22s} {'shape':12s} {'mesh':5s} {'bound':10s} "
          f"{'t_comp':>9s} {'t_mem':>9s} {'t_coll':>9s} {'roofline%':>9s} {'useful%':>8s} fits")
    for r in rows:
        print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:5s} {r['bound']:10s} "
              f"{r['t_compute_s']:9.4f} {r['t_memory_s']:9.4f} "
              f"{r['t_collective_s']:9.4f} {100*r['roofline_fraction']:8.1f}% "
              f"{100*min(r['useful_flops_ratio'],9.99):7.1f}% {r['hbm_fits']}")
    return rows


SHARD_COUNTS = (1, 2, 4, 8)        # per-shard HBM budget columns
REFERENCE_LIBRARY_NODES = 1_000_000


def gather_stage(bench_path: Path = BENCH_DIR / "BENCH_gather.json"):
    """Roofline terms for the HNSW fine-grained distance stage (ISSUE 4).

    Reads ``BENCH_gather.json`` (``benchmarks/gather_bench.py``) and models
    one beam-expansion query-iteration on v5e constants: both layouts move
    the same HBM bytes, so the streaming term ``t_stream = bytes / 819e9``
    is shared; the layouts differ in DMA *descriptor* count —
    ``beam * 2M`` 128-byte row fetches (row layout) vs ``beam`` contiguous
    ``2M*W*4``-byte streams (blocked). With ~1 us per descriptor the row
    layout is descriptor-issue-bound (effective bandwidth ~W*4 bytes/us ~=
    0.1 GB/s per engine), the blocked layout is stream-bound — the model
    behind the layout change, reported as effective-bandwidth fractions.

    The **sharded fan-out** (ISSUE 5) changes the HBM *capacity* budget,
    not the per-stream terms: each of S shards packs only its own N/S
    nodes' neighbour blocks, so the blocked layout's extra ``2M*W``-word
    per-node copy divides S ways. ``hbm_blocked_copy_bytes_per_node`` is
    that per-node cost (= one stream) and
    ``hbm_blocked_copy_gib_per_shard`` budgets it per device for a 1M-node
    library at S in {1, 2, 4, 8} — the number that decides whether the
    blocked layout fits a device's HBM at a given shard count.
    """
    rows = json.loads(Path(bench_path).read_text())
    out = []
    for r in rows:
        bytes_iter = r["bytes_hbm_per_query_iter"] * r["q"]
        t_stream = bytes_iter / HBM_BW
        t_row = r["q"] * r["dma_streams_row"] * DMA_SETUP_S + t_stream
        t_blk = r["q"] * r["dma_streams_blocked"] * DMA_SETUP_S + t_stream
        copy_per_node = r["stream_bytes_blocked"]      # 2M*W*4 bytes
        out.append({
            "name": r["name"], "q": r["q"], "m": r["m"], "beam": r["beam"],
            "bytes_per_iter": bytes_iter,
            "t_stream_s": t_stream,
            "t_row_model_s": t_row, "t_blocked_model_s": t_blk,
            "model_speedup": t_row / t_blk,
            "bw_frac_row": t_stream / t_row,
            "bw_frac_blocked": t_stream / t_blk,
            "hbm_blocked_copy_bytes_per_node": copy_per_node,
            "hbm_blocked_copy_gib_per_shard": {
                str(s): round(REFERENCE_LIBRARY_NODES / s * copy_per_node
                              / 2**30, 3)
                for s in SHARD_COUNTS},
            "measured_speedup_jnp": r.get("speedup_jnp"),
            "measured_speedup_vs_row_kernel": r.get("speedup_vs_row_kernel"),
        })
    OUT_GATHER.write_text(json.dumps(out, indent=1))
    print(f"{'name':18s} {'bytes/iter':>10s} {'t_row':>10s} {'t_blk':>10s} "
          f"{'model_x':>8s} {'bw%row':>7s} {'bw%blk':>7s} "
          f"{'GiB/shard@1M S=1/8':>18s}")
    for r in out:
        gib = r["hbm_blocked_copy_gib_per_shard"]
        print(f"{r['name']:18s} {r['bytes_per_iter']:10d} "
              f"{r['t_row_model_s']:10.2e} {r['t_blocked_model_s']:10.2e} "
              f"{r['model_speedup']:8.1f} {100*r['bw_frac_row']:6.1f}% "
              f"{100*r['bw_frac_blocked']:6.1f}% "
              f"{gib['1']:>8.2f}/{gib['8']:<8.2f}")
    return out


OUT_TIERED = (Path(__file__).resolve().parent.parent / "experiments"
              / "roofline_tiered.json")


def tiered_model(bench_path: Path = BENCH_DIR / "BENCH_tiered.json"):
    """Host-link roofline for the tiered-residency path (ISSUE 7).

    Per query batch the device path reads ``capacity * (W/m*4 + 4)`` bytes
    of folded rows + counts (stage 1) plus the rescore candidates' full
    rows from HBM; the tiered path reads the same stage-1 bytes but pulls
    the candidate rows over the *host link* instead. With the double buffer
    the link transfer overlaps the rescore kernel, so

        t_device = t_stage1 + t_rescore
        t_tiered = t_stage1 + max(t_rescore, t_link)

    The scan bandwidth is calibrated from each measured device-residency
    row (``bw_eff`` = bytes scanned / measured time — on a CPU container
    this folds every constant factor of the jnp path into one number), and
    the link bandwidth is the benchmark's measured ``link_gbps_measured``
    (falling back to the v5e PCIe-class constant). The model's predicted
    device/tiered slowdown is checked against the measured slowdown at
    every (n_db, fold_m) present in both residencies — the acceptance
    criterion is agreement within 2x. The v5e columns re-evaluate the same
    terms at HBM_BW / HOST_LINK_BW and report the capacity ceiling the
    tiered path breaks: a device-resident DB caps at
    ``HBM_BYTES / (4W(1+1/m) + 8)`` rows; tiered residency only needs
    ``4W/m + 8`` bytes/row device-side.
    """
    rows = json.loads(Path(bench_path).read_text())
    by_key = {(r["n_db"], r["fold_m"], r["residency"]): r for r in rows}
    out = []
    for r in rows:
        if r["residency"] != "tiered":
            continue
        dev = by_key.get((r["n_db"], r["fold_m"], "device"))
        w, m, cap = r["words"], r["fold_m"], r["capacity"]
        nq = r["n_queries"]
        stage1_bytes = cap * (4 * w // m + 4) * nq        # folded + counts
        resc_bytes = r.get("streamed_bytes_per_batch",
                           r["scanned_per_query"] * 4 * w * nq)
        link_bw = r.get("link_gbps_measured", HOST_LINK_BW / 1e9) * 1e9
        rec = {
            "name": r["name"], "n_db": r["n_db"], "fold_m": m,
            "stage1_bytes_per_batch": stage1_bytes,
            "streamed_bytes_per_batch": resc_bytes,
            "measured_stall_fraction": r.get("stall_fraction"),
            "device_bytes_per_row_tiered": 4 * w // m + 8,
            "device_bytes_per_row_resident": 4 * w * (1 + 1 / m) + 8,
            # v5e analytic terms: the capacity ceiling and the link margin
            "v5e_capacity_rows_resident": int(
                HBM_BYTES / (4 * w * (1 + 1 / m) + 8)),
            "v5e_capacity_rows_tiered": int(HBM_BYTES / (4 * w / m + 8)),
            "v5e_t_link_s": resc_bytes / HOST_LINK_BW,
            "v5e_t_stage1_s": stage1_bytes / HBM_BW,
            "v5e_slowdown_model": (
                (stage1_bytes / HBM_BW
                 + max(resc_bytes / HOST_LINK_BW, resc_bytes / HBM_BW))
                / (stage1_bytes / HBM_BW + resc_bytes / HBM_BW)),
        }
        if dev is not None:
            # calibrate the scan bandwidth from the measured device row,
            # then predict the tiered slowdown from the link term alone
            t_dev = dev["us_per_call"] / 1e6
            bw_eff = (stage1_bytes + resc_bytes) / t_dev
            t_link = resc_bytes / link_bw
            t_resc = resc_bytes / bw_eff
            t_tier_model = stage1_bytes / bw_eff + max(t_resc, t_link)
            slow_model = t_tier_model / t_dev
            slow_meas = dev["host_qps"] / r["host_qps"]
            ratio = slow_meas / slow_model
            rec.update(
                host_qps_device=dev["host_qps"],
                host_qps_tiered=r["host_qps"],
                bw_eff_gbps=round(bw_eff / 1e9, 3),
                link_gbps=round(link_bw / 1e9, 2),
                slowdown_model=round(slow_model, 3),
                slowdown_measured=round(slow_meas, 3),
                model_vs_measured=round(ratio, 3),
                within_2x=bool(0.5 <= ratio <= 2.0),
            )
        out.append(rec)
    OUT_TIERED.write_text(json.dumps(out, indent=1))
    print(f"{'name':28s} {'slow_meas':>9s} {'slow_model':>10s} {'ratio':>6s} "
          f"{'2x':>3s} {'v5e_slow':>8s} {'v5e_cap_dev':>12s} "
          f"{'v5e_cap_tier':>12s}")
    for r in out:
        print(f"{r['name']:28s} {r.get('slowdown_measured', '-'):>9} "
              f"{r.get('slowdown_model', '-'):>10} "
              f"{r.get('model_vs_measured', '-'):>6} "
              f"{'ok' if r.get('within_2x', True) else 'NO':>3} "
              f"{r['v5e_slowdown_model']:8.3f} "
              f"{r['v5e_capacity_rows_resident']:12d} "
              f"{r['v5e_capacity_rows_tiered']:12d}")
    bad = [r["name"] for r in out if r.get("within_2x") is False]
    if bad:
        print(f"[roofline] tiered model outside 2x for: {', '.join(bad)}")
    return out


if __name__ == "__main__":
    import sys
    if "--gather" in sys.argv:
        gather_stage()
    elif "--tiered" in sys.argv:
        tiered_model()
    else:
        run()
