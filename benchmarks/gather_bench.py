"""Beam-expansion stage microbenchmark (ISSUE 4): row-gather vs
neighbour-blocked layouts of the HNSW fine-grained distance engine.

For each (Q, M) grid point it builds a synthetic base-layer adjacency, a
neighbour-blocked copy (``nbr_fps (N, 2M, W)``), and one beam expansion per
query (``pop_ids (Q, beam)`` + flattened candidate ids with a visited-mask
fraction), then times the full gather -> score -> evict-filter -> sort chain
of one traversal iteration on both layouts:

* ``jnp`` paths — the plain-XLA stages the ``jnp`` backend runs
  (``score_ids``-style scattered row gather vs ``expand_scores_jnp``).
* ``kernel`` paths (optional, ``--pallas``) — the Pallas kernels
  (``ops.gather_tanimoto`` + top-k vs the fused ``ops.expand_tanimoto_sorted``;
  interpret mode off-TPU, where the grid itself is walked in Python — the
  row kernel walks ``Q*beam*2M`` steps, the blocked kernel ``Q*beam``).

The analytic columns are layout properties, independent of the timing host:
both layouts move the same HBM bytes per query-iteration
(``beam*2M*W*4``), but the row layout issues ``beam*2M`` scattered
``W*4``-byte DMAs while the blocked layout issues ``beam`` contiguous
``2M*W*4``-byte streams — the DMA-granularity gap flagged as ROADMAP #1.

Reading the wall-clocks on a CPU host (this container):

* ``speedup_jnp`` (row jnp vs blocked jnp) sits near 1x — off-TPU the chain
  is bound by ``lax.top_k`` (XLA CPU's fastest exact sort), which both
  layouts pay identically, and XLA lowers both gathers to the same memcpy
  loop. The layout's target is the *DMA descriptor count* on real hardware,
  which the ``dma_streams_*`` columns capture analytically.
* ``speedup_vs_row_kernel`` (the row Pallas kernel vs the blocked jnp
  stage) is the wall-clock improvement over what the ``tpu``-backend row
  path actually executes on this host — the headline ``>= 2x`` point at
  (Q=64, M=16).
* kernel-vs-kernel interpret timings (``--pallas``) carry an
  ``interpret_mode: true`` flag: the Pallas interpreter's per-step cost
  scales with *operand* size, not block size, so they do not model Mosaic.

Emits ``experiments/bench/BENCH_gather.json`` (see EXPERIMENTS.md for the
schema) and prints one CSV row per grid point. ``benchmarks/roofline.py
--gather`` turns the JSON into roofline terms for the blocked stage.
"""
from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hnsw import (NEG_INF, _blocked_rows, _np_popcount,
                             expand_scores_jnp)
from repro.core.fingerprints import popcount
from .common import emit, get_db, timeit


def make_case(n_db: int, q_n: int, m: int, beam: int, seed: int = 0,
              masked_frac: float = 0.3):
    """One synthetic beam expansion: adjacency, blocked copy, popped beam.
    The blocked copy is packed by the engine's own `_blocked_rows`, so the
    bench always measures (and bit-checks) the layout the engine ships."""
    rng = np.random.default_rng(seed)
    m2 = 2 * m
    db = np.asarray(get_db(n_db, seed=7))
    db_cnt = _np_popcount(db)
    adj = rng.integers(0, n_db, (n_db, m2)).astype(np.int32)
    adj[rng.random(adj.shape) < 0.05] = -1              # padded edge slots
    nbr, nbr_cnt = _blocked_rows(db, db_cnt, adj)
    pop = rng.integers(0, n_db, (q_n, beam)).astype(np.int32)
    flat = adj[pop].reshape(q_n, beam * m2).copy()
    flat[rng.random(flat.shape) < masked_frac] = -1     # "visited" slots
    worst = np.full((q_n,), -np.inf, dtype=np.float32)
    return dict(db=jnp.asarray(db), db_cnt=jnp.asarray(db_cnt),
                queries=jnp.asarray(db[:q_n]),
                nbr=jnp.asarray(nbr), nbr_cnt=jnp.asarray(nbr_cnt),
                pop=jnp.asarray(pop), flat=jnp.asarray(flat),
                worst=jnp.asarray(worst))


@functools.partial(jax.jit, static_argnames=("kk",))
def _row_expand_jnp(queries, db, db_cnt, flat, worst, kk):
    """The rows-layout expansion chain exactly as search_hnsw runs it on the
    jnp backend: scattered row gather + score + evict-filter + sort."""
    q_cnt = popcount(queries)
    safe = jnp.maximum(flat, 0)
    fps = db[safe]                                       # (Q, E, W) gather
    inter = jnp.sum(jax.lax.population_count(
        queries[:, None, :] & fps).astype(jnp.int32), axis=-1)
    union = q_cnt[:, None] + db_cnt[safe] - inter
    s = jnp.where(union > 0,
                  inter.astype(jnp.float32) / union.astype(jnp.float32), 0.0)
    s = jnp.where(flat >= 0, s, NEG_INF)
    keep = s > worst[:, None]
    s = jnp.where(keep, s, NEG_INF)
    fl = jnp.where(keep, flat, -1)
    s_srt, pos = jax.lax.top_k(s, kk)
    return s_srt, jnp.take_along_axis(fl, pos, axis=1)


@functools.partial(jax.jit, static_argnames=("kk",))
def _blocked_expand_jnp(queries, nbr, nbr_cnt, pop, flat, worst, kk):
    q_cnt = popcount(queries)
    return expand_scores_jnp(queries, q_cnt, nbr, nbr_cnt, pop, flat,
                             worst, kk)


def run(n_db=20_000, qs=(16, 64, 256), ms=(8, 16, 32), beam=4, ef=64,
        pallas_points=((64, 16),), repeats=3):
    from repro.kernels import ops

    rows = []
    for q_n in qs:
        for m in ms:
            c = make_case(n_db, q_n, m, beam)
            m2 = 2 * m
            n_exp = beam * m2
            kk = min(n_exp, ef)
            w = int(c["db"].shape[1])

            t_row = timeit(lambda: _row_expand_jnp(
                c["queries"], c["db"], c["db_cnt"], c["flat"], c["worst"],
                kk), repeats=repeats)
            t_blk = timeit(lambda: _blocked_expand_jnp(
                c["queries"], c["nbr"], c["nbr_cnt"], c["pop"], c["flat"],
                c["worst"], kk), repeats=repeats)
            # the two paths must agree bit-for-bit before we compare clocks
            s_r, i_r = _row_expand_jnp(c["queries"], c["db"], c["db_cnt"],
                                       c["flat"], c["worst"], kk)
            s_b, i_b = _blocked_expand_jnp(c["queries"], c["nbr"],
                                           c["nbr_cnt"], c["pop"], c["flat"],
                                           c["worst"], kk)
            np.testing.assert_array_equal(np.asarray(s_r), np.asarray(s_b))
            np.testing.assert_array_equal(np.asarray(i_r), np.asarray(i_b))

            row = {
                "name": f"gather_q{q_n}_m{m}", "q": q_n, "m": m,
                "beam": beam, "w": w, "n_db": n_db, "kk": kk,
                "n_exp": n_exp,
                # layout analytics (per query-iteration, host-independent)
                "bytes_hbm_per_query_iter": n_exp * w * 4,
                "dma_streams_row": n_exp,            # beam*2M scattered rows
                "dma_streams_blocked": beam,         # beam contiguous blocks
                "stream_bytes_row": w * 4,
                "stream_bytes_blocked": m2 * w * 4,
                # wall-clock of the full expansion chain, jnp backend
                "us_per_call": round(t_blk * 1e6, 1),
                "us_row_jnp": round(t_row * 1e6, 1),
                "us_blocked_jnp": round(t_blk * 1e6, 1),
                "speedup_jnp": round(t_row / t_blk, 2),
            }
            if (q_n, m) in set(map(tuple, pallas_points)):
                # jit-wrapped like the engine runs them (pallas_call retraces
                # per eager call otherwise; the traversal launches from
                # inside a jitted while_loop)
                @functools.partial(jax.jit, static_argnames=("kk",))
                def row_kernel(queries, db, flat, worst, kk):
                    s = ops.gather_tanimoto(queries, db, flat,
                                            q_cnt=popcount(queries))
                    s = jnp.where(s > worst[:, None], s, -jnp.inf)
                    return jax.lax.top_k(s, kk)

                @functools.partial(jax.jit, static_argnames=("kk",))
                def blocked_kernel(queries, nbr, nbr_cnt, pop, flat, worst,
                                   kk):
                    return ops.expand_tanimoto_sorted(
                        queries, nbr, nbr_cnt, pop, flat, worst, kk)

                t_rk = timeit(lambda: row_kernel(
                    c["queries"], c["db"], c["flat"], c["worst"], kk),
                    repeats=1, warmup=1)
                t_bk = timeit(lambda: blocked_kernel(
                    c["queries"], c["nbr"], c["nbr_cnt"], c["pop"],
                    c["flat"], c["worst"], kk), repeats=1, warmup=1)
                row.update(
                    us_row_kernel=round(t_rk * 1e6, 1),
                    us_blocked_kernel=round(t_bk * 1e6, 1),
                    # the headline point: the blocked stage vs what the row
                    # kernel costs on this host (the tpu-backend row path)
                    speedup_vs_row_kernel=round(t_rk / t_blk, 2),
                    speedup_kernel=round(t_rk / t_bk, 2),
                    interpret_mode=jax.default_backend() != "tpu")
            rows.append(row)
    emit("BENCH_gather", rows)
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-db", type=int, default=20_000)
    ap.add_argument("--qs", type=int, nargs="+", default=[16, 64, 256])
    ap.add_argument("--ms", type=int, nargs="+", default=[8, 16, 32])
    ap.add_argument("--beam", type=int, default=4)
    ap.add_argument("--ef", type=int, default=64)
    ap.add_argument("--no-pallas", action="store_true",
                    help="skip the Pallas kernel timings (interpret mode "
                         "walks the row kernel's Q*beam*2M grid in Python)")
    ap.add_argument("--pallas-points", type=int, nargs="+", default=None,
                    help="flat (q, m) pairs to time with the kernels, "
                         "e.g. --pallas-points 64 16 16 8")
    args = ap.parse_args()
    if args.no_pallas:
        points = ()
    elif args.pallas_points is not None:
        it = iter(args.pallas_points)
        points = tuple(zip(it, it))
    else:
        points = tuple((q, m) for q in args.qs for m in args.ms
                       if (q, m) == (64, 16)) or ((args.qs[0], args.ms[0]),)
    run(n_db=args.n_db, qs=tuple(args.qs), ms=tuple(args.ms), beam=args.beam,
        ef=args.ef, pallas_points=points)


if __name__ == "__main__":
    main()
