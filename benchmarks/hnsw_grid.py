"""Paper Figs. 8/9: HNSW QPS vs recall over the (M, ef) grid."""
from __future__ import annotations

import numpy as np

from repro.core import HNSWEngine, recall_at_k
from repro.core import hnsw as hn
from .common import K, brute_truth, emit, get_db, get_queries, timeit


def run(n_db=8_000, n_queries=32, ms=(5, 10, 20), efs=(20, 60, 120, 200)):
    db = get_db(n_db, seed=7)
    queries = get_queries(db, n_queries, seed=8)
    true_ids, _ = brute_truth(db, queries, K)
    rows = []
    for m in ms:
        index = hn.build_hnsw(np.asarray(db), m=m, ef_construction=100, seed=0)
        eng = HNSWEngine(db, index=index)
        for ef in efs:
            dt = timeit(lambda: eng.search(queries, K, ef=ef), repeats=2)
            ids, _ = eng.search(queries, K, ef=ef)
            rows.append({
                "name": f"hnsw_m{m}_ef{ef}", "m": m, "ef": ef,
                "us_per_call": round(dt / n_queries * 1e6, 1),
                "host_qps": round(n_queries / dt, 1),
                "recall": round(recall_at_k(ids, true_ids), 4),
                "avg_neighbour_evals": eng.scanned(n_queries) // n_queries,
            })
    emit("fig8_hnsw_grid", rows)
    return rows


if __name__ == "__main__":
    run()
