"""Paper Figs. 8/9/12: HNSW QPS vs recall over the (M, ef) grid.

``--backend`` sweeps the same grid through the engine's execution paths
(``numpy`` host reference / ``jnp`` device traversal / ``tpu`` device
traversal with the Pallas gather-distance kernel, interpret-mode off-TPU),
so the paper's Fig. 12 recall-vs-QPS operating point is directly trackable
per backend. Rows land in the ``experiments/bench`` JSON schema with
``backend``/``beam`` columns plus the traversal telemetry
(iterations, expansions, budget terminations) from ``HNSWEngine.stats``.

``--shards N`` sweeps the sharded fan-out engine instead
(``HNSWEngine(shards=N)``: per-shard traversals + rank-merge, §IV Fig. 8's
parallel pipelines) and lands in ``fig8_hnsw_grid..._sharded.json``; run it
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to give the
shards distinct host devices (EXPERIMENTS.md §Sharded HNSW).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import HNSWEngine, recall_at_k
from repro.core import hnsw as hn
from .common import K, brute_truth, emit, get_db, get_queries, timeit


def run(n_db=8_000, n_queries=32, ms=(5, 10, 20), efs=(20, 60, 120, 200),
        backend="jnp", beam=1, ef_construction=100, layout="rows",
        shards=None, metric=None, fp_bits=None):
    from repro.core.fingerprints import resolve_metric
    met = resolve_metric(metric)
    length = int(fp_bits) if fp_bits else 1024
    db = get_db(n_db, seed=7, length=length)
    queries = get_queries(db, n_queries, seed=8)
    true_ids, _ = brute_truth(db, queries, K, metric=met)
    rows = []
    lsuf = "" if layout == "rows" else f"_{layout}"
    ssuf = "" if shards is None else f"_s{shards}"
    msuf = "" if met.name == "tanimoto" else f"_{met.name}"
    for m in ms:
        if shards is None:
            index = hn.build_hnsw(np.asarray(db), m=m,
                                  ef_construction=ef_construction, seed=0,
                                  metric=met)
            eng = HNSWEngine(db, index=index, backend=backend, beam=beam,
                             layout=layout)
        else:
            eng = HNSWEngine(db, m=m, ef_construction=ef_construction,
                             seed=0, backend=backend, beam=beam,
                             layout=layout, shards=shards, metric=met)
        for ef in efs:
            dt = timeit(lambda: eng.search(queries, K, ef=ef), repeats=2)
            ids, _ = eng.search(queries, K, ef=ef)
            rows.append({
                "name": f"hnsw_m{m}_ef{ef}_{backend}{lsuf}{ssuf}{msuf}",
                "m": m, "ef": ef,
                "backend": backend, "beam": beam, "layout": layout,
                "shards": shards,
                "metric": met.spec, "fp_bits": length,
                "n_db": n_db, "n_queries": n_queries,
                "us_per_call": round(dt / n_queries * 1e6, 1),
                "host_qps": round(n_queries / dt, 1),
                "recall": round(recall_at_k(ids, true_ids), 4),
                "avg_neighbour_evals": eng.scanned(n_queries) // n_queries,
                "avg_iters": round(eng.stats.get("iters", 0) / n_queries, 1),
                "max_iters_hit": eng.stats.get("max_iters_hit", 0),
            })
    suffix = "" if backend == "jnp" else f"_{backend}"
    shard_suffix = "" if shards is None else "_sharded"
    emit(f"fig8_hnsw_grid{suffix}{lsuf}{shard_suffix}{msuf}", rows)
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="jnp",
                    choices=["numpy", "jnp", "tpu"])
    ap.add_argument("--n-db", type=int, default=None,
                    help="database size (default 8000, 2000 for tpu "
                         "interpret mode)")
    ap.add_argument("--n-queries", type=int, default=None)
    ap.add_argument("--ms", type=int, nargs="+", default=None,
                    help="HNSW M values to sweep")
    ap.add_argument("--efs", type=int, nargs="+", default=None,
                    help="ef_search values to sweep")
    ap.add_argument("--beam", type=int, default=1,
                    help="candidates expanded per traversal iteration")
    ap.add_argument("--layout", default="rows", choices=["rows", "blocked"],
                    help="fine-grained distance layout (row gather vs "
                         "neighbour-blocked streaming; bit-exact results)")
    ap.add_argument("--shards", type=int, default=None,
                    help="fan-out over N per-device database shards "
                         "(emits the _sharded artifact)")
    ap.add_argument("--ef-construction", type=int, default=None)
    ap.add_argument("--metric", default=None,
                    help="similarity metric: tanimoto (default), dice, "
                         "cosine, or tversky(a,b) — the graph is built and "
                         "searched under it (emits a _<metric> artifact)")
    ap.add_argument("--fp-bits", type=int, default=None,
                    help="fingerprint width in bits (default 1024)")
    args = ap.parse_args()
    # interpret-mode Pallas (off-TPU) walks the gather grid in python:
    # default to a tiny-mode sweep there so the smoke leg stays fast
    tiny = args.backend == "tpu"
    run(n_db=args.n_db or (2_000 if tiny else 8_000),
        n_queries=args.n_queries or (8 if tiny else 32),
        ms=tuple(args.ms) if args.ms else ((8,) if tiny else (5, 10, 20)),
        efs=tuple(args.efs) if args.efs else ((20, 60) if tiny
                                              else (20, 60, 120, 200)),
        backend=args.backend, beam=args.beam, layout=args.layout,
        shards=args.shards, metric=args.metric, fp_bits=args.fp_bits,
        ef_construction=args.ef_construction or (40 if tiny else 100))


if __name__ == "__main__":
    main()
