"""CI gate for the observability artifacts (ISSUE 8 satellite).

Thin CLI over :mod:`repro.obs.schema`: validates a Chrome trace-event JSON
export and/or a metrics-registry JSONL export, printing every schema error
and exiting non-zero if any artifact fails. CI runs it right after the
traced `search_serve` smoke so a silently-broken exporter (missing family,
malformed bucket counts, span that stopped firing) fails the build instead
of shipping an empty dashboard.

    PYTHONPATH=src python benchmarks/check_obs_schema.py \
        --trace /tmp/trace.json --require-span tier.device_put \
        --metrics /tmp/metrics.jsonl
"""
from __future__ import annotations

import argparse
import sys

from repro.obs.schema import (FRONTEND_METRIC_FAMILIES,
                              REQUIRED_METRIC_FAMILIES, validate_trace_file,
                              validate_metrics_jsonl)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default=None,
                    help="Chrome trace-event JSON to validate")
    ap.add_argument("--metrics", default=None,
                    help="metrics-registry JSONL export to validate")
    ap.add_argument("--require-span", action="append", default=[],
                    help="span name that must appear in the trace "
                         "(repeatable), e.g. tier.device_put")
    ap.add_argument("--require-family", action="append", default=None,
                    help="metric family that must appear in the JSONL "
                         "(repeatable; default: the serving floor "
                         f"{', '.join(REQUIRED_METRIC_FAMILIES)})")
    ap.add_argument("--require-frontend", action="store_true",
                    help="demand the concurrent-tier floor too: the service "
                         "families plus "
                         f"{', '.join(FRONTEND_METRIC_FAMILIES)}")
    args = ap.parse_args(argv)
    if not args.trace and not args.metrics:
        ap.error("nothing to validate: pass --trace and/or --metrics")

    errors: list[str] = []
    if args.trace:
        errs = validate_trace_file(args.trace,
                                   require_spans=tuple(args.require_span))
        errors += [f"[trace] {e}" for e in errs]
        print(f"[check-obs] trace {args.trace}: "
              f"{'OK' if not errs else f'{len(errs)} error(s)'}")
    if args.metrics:
        fams = (tuple(args.require_family)
                if args.require_family is not None else None)
        if args.require_frontend:
            fams = (REQUIRED_METRIC_FAMILIES + FRONTEND_METRIC_FAMILIES
                    + (fams or ()))
        errs = validate_metrics_jsonl(args.metrics, require_families=fams)
        errors += [f"[metrics] {e}" for e in errs]
        print(f"[check-obs] metrics {args.metrics}: "
              f"{'OK' if not errs else f'{len(errs)} error(s)'}")
    for e in errors:
        print(f"[check-obs] {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
