"""Paper Figs. 6/7 + §V-B: exhaustive engine throughput.

* wall-clock QPS of the fused engine on this host (CPU, interpret-mode
  Pallas → jnp streaming path) vs folding level and cutoff;
* *projected* TPU-v5e throughput from the roofline: the fused kernel is
  memory-bound (DESIGN.md §2), so QPS ≈ HBM_bw / bytes_per_query — the
  analogue of the paper's 57.6 GB/s → 450 Mcpd/s engine accounting.

``--backend`` runs the BitBound+folding sweep through either the numpy
reference loop or the device-resident ``search_tpu`` two-stage pipeline;
rows share one JSON schema with a ``backend`` field.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import BitBoundFoldingEngine, BruteForceEngine
from repro.core import bitbound as bb
from .common import K, emit, get_db, get_queries, timeit

HBM_BW = 819e9           # TPU v5e per chip
FPGA_ENGINE_BW = 57.6e9  # paper, per engine


def projected_qps(n_db: int, words: int, scan_fraction: float = 1.0,
                  bw: float = HBM_BW) -> float:
    bytes_per_query = n_db * scan_fraction * words * 4
    return bw / bytes_per_query


def run(n_db=60_000, n_queries=32, backend="numpy", metric=None,
        fp_bits=None):
    from repro.core.fingerprints import resolve_metric
    met = resolve_metric(metric)
    length = int(fp_bits) if fp_bits else 1024
    db = get_db(n_db, length=length)
    queries = get_queries(db, n_queries)
    words = db.shape[1]
    rows = []

    eng = BruteForceEngine(db, metric=met)
    dt = timeit(lambda: eng.search(queries, K))
    qps = n_queries / dt
    rows.append({
        "name": "bruteforce", "backend": "jnp",
        "metric": met.spec, "fp_bits": length,
        "n_db": n_db, "n_queries": n_queries,
        "us_per_call": round(dt / n_queries * 1e6, 1),
        "host_qps": round(qps, 1),
        "host_compounds_per_s": round(qps * n_db / 1e6, 1),
        "tpu_projected_qps_1chip": round(projected_qps(1_941_405, words), 1),
        "fpga_paper_qps": 1638 / 7,   # per engine
    })

    for m in (1, 2, 4, 8):
        for cutoff in (0.6, 0.8):
            eng = BitBoundFoldingEngine(db, cutoff=cutoff, m=m,
                                        backend=backend, metric=met)
            dt = timeit(lambda: eng.search(queries, K), repeats=2)
            frac = eng.scanned(n_queries) / (n_queries * n_db)
            qps = n_queries / dt
            rows.append({
                "name": f"bitbound_fold_m{m}_Sc{cutoff}",
                "backend": backend,
                "metric": met.spec, "fp_bits": length,
                "n_db": n_db, "n_queries": n_queries,
                "us_per_call": round(dt / n_queries * 1e6, 1),
                "host_qps": round(qps, 1),
                "scan_fraction": round(frac, 4),
                # folded scan reads W/m words over the pruned range + rescore
                "tpu_projected_qps_1chip": round(projected_qps(
                    1_941_405, words / m, frac), 1),
            })
    suffix = "" if backend == "numpy" else f"_{backend}"
    msuf = "" if met.name == "tanimoto" else f"_{met.name}"
    emit(f"fig7_exhaustive_qps{suffix}{msuf}", rows)
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="numpy",
                    choices=["numpy", "jnp", "tpu"])
    ap.add_argument("--n-db", type=int, default=None,
                    help="database size (default 60k numpy / 20k device)")
    ap.add_argument("--n-queries", type=int, default=None)
    ap.add_argument("--metric", default=None,
                    help="similarity metric: tanimoto (default), dice, "
                         "cosine, or tversky(a,b)")
    ap.add_argument("--fp-bits", type=int, default=None,
                    help="fingerprint width in bits (default 1024)")
    args = ap.parse_args()
    n_db = args.n_db or (60_000 if args.backend == "numpy" else 20_000)
    n_queries = args.n_queries or (32 if args.backend == "numpy" else 8)
    run(n_db=n_db, n_queries=n_queries, backend=args.backend,
        metric=args.metric, fp_bits=args.fp_bits)


if __name__ == "__main__":
    main()
