"""Paper Fig. 2: BitBound pruned search fraction & speedup vs similarity
cutoff — measured on the index AND predicted by the Gaussian model (Eq. 3).

``--backend`` selects the engine path: "numpy" (host reference loop) or
"tpu" (device-resident two-stage pipeline; interpret-mode Pallas off-TPU).
Both paths emit rows with the same JSON schema, distinguished by the
``backend`` field, so results are directly comparable.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import BitBoundFoldingEngine
from repro.core import bitbound as bb
from .common import K, emit, get_db, get_queries

CUTOFFS = (0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9)


def run(n_db=60_000, n_queries=64, backend="numpy"):
    db = get_db(n_db)
    queries = get_queries(db, n_queries)
    idx = bb.build_index(np.asarray(db))
    rows = []
    for cutoff in CUTOFFS:
        eng = BitBoundFoldingEngine(db, cutoff=cutoff, m=1, backend=backend)
        eng.search(queries, K)
        frac = eng.scanned(n_queries) / (n_queries * n_db)
        model_frac = bb.expected_search_fraction(idx.mu, idx.sigma, cutoff)
        rows.append({
            "name": f"bitbound_Sc{cutoff}", "cutoff": cutoff,
            "backend": backend,
            "measured_fraction": round(frac, 4),
            "measured_speedup": round(1.0 / max(frac, 1e-9), 2),
            "gaussian_model_fraction": round(model_frac, 4),
            "gaussian_model_speedup": round(1.0 / model_frac, 2),
        })
    suffix = "" if backend == "numpy" else f"_{backend}"
    emit(f"fig2_bitbound_speedup{suffix}", rows)
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="numpy",
                    choices=["numpy", "jnp", "tpu"])
    ap.add_argument("--n-db", type=int, default=None,
                    help="database size (default 60k numpy / 20k device)")
    ap.add_argument("--n-queries", type=int, default=None)
    args = ap.parse_args()
    # interpret-mode Pallas is functional, not fast: default to a smaller DB
    # on the device paths so the sweep finishes in CLI time off-TPU
    n_db = args.n_db or (60_000 if args.backend == "numpy" else 20_000)
    n_queries = args.n_queries or (64 if args.backend == "numpy" else 16)
    run(n_db=n_db, n_queries=n_queries, backend=args.backend)


if __name__ == "__main__":
    main()
