"""Paper Fig. 2: BitBound pruned search fraction & speedup vs similarity
cutoff — measured on the index AND predicted by the Gaussian model (Eq. 3)."""
from __future__ import annotations

import numpy as np

from repro.core import BitBoundFoldingEngine
from repro.core import bitbound as bb
from .common import K, emit, get_db, get_queries


def run(n_db=60_000, n_queries=64):
    db = get_db(n_db)
    queries = get_queries(db, n_queries)
    idx = bb.build_index(np.asarray(db))
    rows = []
    for cutoff in (0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9):
        eng = BitBoundFoldingEngine(db, cutoff=cutoff, m=1)
        eng.search(queries, K)
        frac = eng.scanned(n_queries) / (n_queries * n_db)
        model_frac = bb.expected_search_fraction(idx.mu, idx.sigma, cutoff)
        rows.append({
            "name": f"bitbound_Sc{cutoff}", "cutoff": cutoff,
            "measured_fraction": round(frac, 4),
            "measured_speedup": round(1.0 / max(frac, 1e-9), 2),
            "gaussian_model_fraction": round(model_frac, 4),
            "gaussian_model_speedup": round(1.0 / model_frac, 2),
        })
    emit("fig2_bitbound_speedup", rows)
    return rows


if __name__ == "__main__":
    run()
