"""Paper §IV-A/§V-B headline: single-engine compounds/s throughput of the
fused scan+top-k kernel, plus the distributed (sharded) engine scaling story
via the collective-cost model (wire bytes per query independent of DB size).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import BruteForceEngine
from repro.kernels import ops
from .common import emit, get_db, get_queries, timeit

HBM_BW = 819e9
PAPER_ENGINE_CPS = 450e6      # 450 M compounds/s per FPGA engine
PAPER_ENGINE_BW = 57.6e9


def run(n_db=60_000, n_queries=8):
    db = get_db(n_db)
    queries = get_queries(db, n_queries)
    rows = []

    # host wall-clock of the fused kernel (interpret mode — correctness path)
    qj, dj = jnp.asarray(queries), jnp.asarray(db)
    dt = timeit(lambda: ops.tanimoto_topk(qj, dj, k=20), repeats=2)
    cps_host = n_queries * n_db / dt
    # TPU projection: kernel streams 128 B/compound (32 u32 words) once
    cps_tpu = HBM_BW / 128
    rows.append({
        "name": "fused_engine_throughput",
        "us_per_call": round(dt / n_queries * 1e6, 1),
        "host_compounds_per_s": round(cps_host / 1e6, 2),
        "tpu_v5e_projected_compounds_per_s_1chip": round(cps_tpu / 1e6, 1),
        "paper_fpga_engine_compounds_per_s": round(PAPER_ENGINE_CPS / 1e6, 1),
        "projected_vs_paper_engine": round(cps_tpu / PAPER_ENGINE_CPS, 2),
        "bw_ratio_vs_paper_engine": round(HBM_BW / PAPER_ENGINE_BW, 2),
    })

    # distributed merge cost model: bytes on the wire per query for the
    # hierarchical top-k merge (k=20 entries x 8 B x gather width)
    for chips, axes in ((16, "data"), (256, "data"), (512, "pod x data")):
        wire = 20 * 8 * chips  # all_gather of per-shard top-k
        rows.append({
            "name": f"sharded_merge_{chips}chips",
            "axes": axes,
            "wire_bytes_per_query": wire,
            "merge_time_us_at_50GBps": round(wire / 50e9 * 1e6, 3),
            "scan_time_us_per_chip_1p9M_db": round(
                1_941_405 / chips * 128 / HBM_BW * 1e6, 1),
        })
    emit("engine_throughput", rows)
    return rows


if __name__ == "__main__":
    run()
