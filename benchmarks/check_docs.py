"""Docs-reference guard: fail CI when a path referenced from the top-level
docs does not exist in the working tree.

Scans the backtick-quoted spans of README.md, EXPERIMENTS.md and
docs/ARCHITECTURE.md for tokens that look like repository paths (contain a
``/`` or end in a known file suffix) and checks each resolves — either from
the repo root or from ``src/repro`` (module docs name ``core/engine.py``
style paths). Spans with globby/schematic characters (``*``, ``[``, ``{``,
``<``, ``...``) are skipped: they are patterns, not paths. Command lines
(``python -m benchmarks.foo``) are covered via their module files by the
``benchmarks.``/``repro.`` dotted forms.

    python -m benchmarks.check_docs
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = ("README.md", "EXPERIMENTS.md", "docs/ARCHITECTURE.md")
SUFFIXES = (".py", ".md", ".json", ".txt", ".yml")
SKIP_CHARS = ("*", "[", "{", "<", "...")
# bare dir-name references whose trailing slash docs often drop
ROOTS = ("src", "tests", "benchmarks", "examples", "experiments", "docs")


def candidate_paths(text: str):
    for span in re.findall(r"`([^`\n]+)`", text):
        tok = span.strip().rstrip("/").rstrip(":")
        if not tok or any(c in tok for c in SKIP_CHARS):
            continue
        if re.match(r"^(PYTHONPATH|XLA_FLAGS|JAX_PLATFORMS)?[=\S]*\s*"
                    r"(python|pip|pytest|git)\b", tok) and " " in tok:
            # command lines: check path-looking and dotted-module words
            for word in tok.split():
                if word.startswith("-"):
                    continue
                if re.fullmatch(r"(benchmarks|repro)(\.[\w]+)+", word):
                    yield word.replace(".", "/") + ".py"
                elif "/" in word and "=" not in word:
                    yield word.rstrip("/")
            continue
        if " " in tok or "=" in tok or tok.startswith("-"):
            continue
        if re.fullmatch(r"(benchmarks|repro)(\.[\w]+)+", tok):
            yield tok.replace(".", "/") + ".py"
            continue
        if "/" in tok or tok.endswith(SUFFIXES) or tok in ROOTS:
            # the first path segment must look like a directory/module name
            # (filters prose fractions like `W/m` or `Q/ef`)
            head = tok.split("/")[0]
            if "/" in tok and not re.fullmatch(r"[a-z_][a-z0-9_.-]+", head):
                continue
            # strip `path::symbol` / `path#anchor` decorations
            yield re.split(r"::|#", tok)[0]


def resolves(path: str) -> bool:
    bases = (REPO, REPO / "src" / "repro", REPO / "src",
             REPO / "experiments" / "bench", REPO / "experiments")
    for base in bases:
        if (base / path).exists():
            return True
        # module-path variants: `core/topk.merge_sorted` -> core/topk.py,
        # `repro/serve.py` (from dotted `repro.serve`) -> package dir
        head, _, tail = path.rpartition("/")
        if "." in tail:
            mod = f"{head}/{tail.split('.')[0]}" if head else \
                tail.split(".")[0]
            if (base / (mod + ".py")).exists() or (base / mod).is_dir():
                return True
    return False


def main() -> int:
    missing = []
    checked = 0
    for doc in DOC_FILES:
        f = REPO / doc
        if not f.exists():
            missing.append((doc, "(the doc file itself)"))
            continue
        for tok in set(candidate_paths(f.read_text())):
            checked += 1
            if not resolves(tok):
                missing.append((doc, tok))
    for doc, tok in sorted(missing):
        print(f"[docs-check] MISSING {doc}: `{tok}` does not resolve")
    print(f"[docs-check] {checked} path references checked across "
          f"{len(DOC_FILES)} docs, {len(missing)} missing")
    return 1 if missing else 0


if __name__ == "__main__":
    sys.exit(main())
