"""Paper Table I: Top-20 accuracy vs folding level m, schemes 1 and 2."""
from __future__ import annotations

import numpy as np

from repro.core import BitBoundFoldingEngine, recall_at_k
from .common import K, brute_truth, emit, get_db, get_queries


def run(n_db=20_000, n_queries=48):
    db = get_db(n_db)
    queries = get_queries(db, n_queries)
    _, true_vals = brute_truth(db, queries, K)
    true_ids, _ = brute_truth(db, queries, K)
    rows = []
    for m in (1, 2, 4, 8, 16, 32):
        row = {"name": f"folding_m{m}", "m": m}
        for scheme in (1, 2):
            eng = BitBoundFoldingEngine(db, cutoff=0.0, m=m, scheme=scheme)
            ids, _ = eng.search(queries, K)
            row[f"accuracy_scheme{scheme}"] = round(recall_at_k(ids, true_ids), 4)
        from repro.core.folding import kr1_for
        row["kr1_over_k"] = kr1_for(K, m) // K
        rows.append(row)
    emit("table1_folding_accuracy", rows)
    return rows


if __name__ == "__main__":
    run()
