"""Bench-regression guard: fail if committed HNSW-grid QPS regressed.

Compares every ``experiments/bench/fig8_hnsw_grid*.json`` in the working
tree against the most recent *git-committed version with different
content* (so on a clean checkout it compares HEAD's artifact with the last
commit that changed it). Rows are matched by ``name`` and only compared
when they came from the same measurement shape (``n_db`` / ``n_queries`` /
``beam`` match — a committed re-run at a different scale is a new baseline,
not a regression). A matched row fails when ``host_qps`` drops by more than
``--threshold`` (default 20%).

Run it from CI *before* the tiny-mode benchmark smoke legs overwrite the
artifacts:

    python -m benchmarks.check_bench_regression
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
BENCH_REL = "experiments/bench"
# rows are only comparable at the same measurement shape; "shards" guards
# the fig8_hnsw_grid_sharded.json artifact (a re-run at a different shard
# count is a new baseline, not a regression), "wal" the serve_load*.json
# durability axis (an in-memory row is no baseline for a fsync-per-ack row),
# "fold_m" / "residency" the BENCH_tiered.json capacity sweep (a device
# row guards nothing about the streaming path, and vice versa), and
# "loop" / "target_qps" the serve_slo.json SLO harness (closed-loop
# capacity and open-loop paced QPS are different measurements), and
# "replicas" / "degradation" the concurrent front-end rows (a 2-replica
# window or a different degradation ladder is a different serving shape),
# and "metric" / "fp_bits" the similarity sweep (a dice row at 512 bits is
# no baseline for a tanimoto row at 1024)
SHAPE_KEYS = ("n_db", "n_queries", "beam", "shards", "wal", "fold_m",
              "residency", "loop", "target_qps", "replicas", "degradation",
              "metric", "fp_bits")
# rows committed before the metric axis existed implicitly measured the
# defaults — they still guard a tanimoto/1024-bit re-run
SHAPE_DEFAULTS = {"metric": "tanimoto", "fp_bits": 1024}


def _git(*args: str) -> subprocess.CompletedProcess:
    return subprocess.run(["git", *args], cwd=REPO, capture_output=True,
                          text=True)


def previous_versions(rel_path: str, current_text: str):
    """Committed versions of ``rel_path`` with content differing from
    ``current_text``, newest first (a shape-changed re-baseline is skipped
    by the caller in favour of an older, comparable version)."""
    log = _git("log", "--format=%H", "--", rel_path)
    if log.returncode != 0:
        return
    for commit in log.stdout.split():
        show = _git("show", f"{commit}:{rel_path}")
        if show.returncode == 0 and show.stdout != current_text:
            try:
                yield json.loads(show.stdout)
            except json.JSONDecodeError:
                continue


def compare(old_rows: list, new_rows: list, threshold: float):
    """(regressions, n_compared): matched-by-name rows whose QPS dropped."""
    old_by_name = {r["name"]: r for r in old_rows if "name" in r}
    regressions, compared = [], 0
    for r in new_rows:
        o = old_by_name.get(r.get("name"))
        if o is None or "host_qps" not in o or "host_qps" not in r:
            continue
        if any(o.get(k, SHAPE_DEFAULTS.get(k))
               != r.get(k, SHAPE_DEFAULTS.get(k)) for k in SHAPE_KEYS):
            continue                       # re-measured at a different shape
        compared += 1
        if r["host_qps"] < (1.0 - threshold) * o["host_qps"]:
            regressions.append(
                (r["name"], o["host_qps"], r["host_qps"]))
    return regressions, compared


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="allowed fractional QPS drop (default 0.20)")
    ap.add_argument("--glob",
                    default="fig8_hnsw_grid*.json,BENCH_tiered.json,"
                            "serve_slo.json",
                    help="benchmark artifacts to guard (comma-separated "
                         "globs)")
    args = ap.parse_args(argv)

    bench_dir = REPO / BENCH_REL
    failed = False
    checked = 0
    paths = sorted({p for g in args.glob.split(",")
                    for p in bench_dir.glob(g.strip())})
    for path in paths:
        rel = f"{BENCH_REL}/{path.name}"
        text = path.read_text()
        new_rows = json.loads(text)
        regs = compared = None
        n_versions = 0
        # walk back to the most recent *comparable* baseline: a version
        # re-measured at a different shape (n_db/...) guards nothing, but an
        # older same-shape version still can
        for old in previous_versions(rel, text):
            n_versions += 1
            regs, compared = compare(old, new_rows, args.threshold)
            if compared:
                break
        if n_versions == 0:
            print(f"[bench-guard] {path.name}: no prior committed version "
                  f"with different content — skipped")
            continue
        if not compared:
            # loud: a guarded artifact with history but no comparable rows
            # is effectively unguarded (e.g. every prior version was a
            # different measurement shape)
            print(f"[bench-guard] WARNING {path.name}: {n_versions} prior "
                  f"version(s) but 0 comparable rows — artifact is "
                  f"UNGUARDED; commit a same-shape baseline")
            continue
        checked += compared
        if regs:
            failed = True
            for name, was, now in regs:
                print(f"[bench-guard] REGRESSION {path.name}:{name} "
                      f"host_qps {was} -> {now} "
                      f"(> {args.threshold:.0%} drop)")
        else:
            print(f"[bench-guard] {path.name}: {compared} comparable rows, "
                  f"no regression > {args.threshold:.0%}")
    print(f"[bench-guard] {checked} rows compared across artifacts")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
