"""Online-serving load benchmark: latency/QPS under mixed insert+query load.

Drives a :class:`repro.serve.service.SearchService` (dynamic micro-batching,
LSM-compacting mutable store) with deterministic workloads at configurable
write ratios and emits ``experiments/bench/serve_load[_backend].json``:
per-(engine, write_ratio) rows with p50/p99 request latency, QPS, scanned
candidates, compaction counts — and ``compiles_in_window``, the number of
pipeline compilations that happened inside the steady-state timed window.

The warmup phase replays enough of the workload to touch every pipeline
shape the steady state can need — all power-of-two batch buckets, every
delta-bucket size below the compaction threshold (one full delta
0 -> threshold cycle), and at least one compaction — so the timed window
measures pure serving: ``compiles_in_window`` must be 0 (asserted by the CI
smoke leg via the JSON).

``--wal`` adds the durability axis (ISSUE 6): each (engine, write_ratio)
cell is re-measured with the write-ahead log on — fsync-per-ack and
8-record group commit — against a temp directory, so the p99 rows quantify
what the acked-implies-recovered contract costs per insert.

``--slo`` switches to the SLO-tracking harness (ISSUE 8 tentpole): a
target-QPS load loop — ``--loop closed`` issues ops back-to-back (service
at capacity), ``--loop open`` paces submissions to ``--target-qps`` on a
wall-clock schedule so queueing delay shows up in the latency when the
service falls behind — with a per-phase latency breakdown read from the
service's metrics registry (queue wait / engine batch / insert / WAL
append) and a **hard p99 SLO verdict**: the run exits non-zero when
request p99 exceeds ``--slo-p99-ms``. ``--measure-overhead`` replays the
identical window against a ``metrics=False`` twin service and reports the
observability overhead as a QPS fraction (``--overhead-budget 0.05`` turns
the 5% acceptance bound into a hard failure).

``--frontend`` switches to the concurrent-tier harness (ISSUE 9): the same
workload is driven through :class:`repro.serve.frontend.SearchFrontend`
(bounded admission, deadlines, degradation ladder, N read replicas) in an
open-loop window paced past capacity, with a hard verdict on *zero
deadline misses among accepted requests* (admitted work either completes
inside its deadline or is dropped un-scored with a typed error — never
silently late). ``--failover`` kills a replica mid-window and asserts
availability plus post-rehydrate byte parity.
"""
from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np

from repro.data.molecules import SyntheticConfig, synthetic_fingerprints, queries_from_db
from repro.launch.search_serve import make_workload
from repro.serve.service import SearchService
from .common import emit

WRITE_RATIOS = (0.0, 0.01, 0.1)

# durability axis (ISSUE 6): "off" = in-memory service (the historical rows,
# names unchanged), "fsync" = WAL with fsync-per-ack, "group8" = WAL with
# 8-record group commit. The p99 delta between fsync and group8 is the price
# of the strict acked-implies-recovered contract per insert.
WAL_MODES = ("off", "fsync", "group8")
_WAL_FSYNC_EVERY = {"fsync": 1, "group8": 8}


def _capacities(svc):
    return {name: eng.store.main.capacity
            for name, eng in svc.engines.items() if hasattr(eng, "store")}


def _run_ops(svc, ops, engine, k, flush_every):
    since = 0
    for op, payload in ops:
        if op == "insert":
            svc.insert(payload)
        else:
            svc.submit(payload, k=k, engine=engine)
            since += 1
            if since >= flush_every:
                svc.flush()
                since = 0
    svc.flush()


def run(n_db=20_000, n_ops=256, k=10, backend="jnp",
        engines=("brute", "bitbound-folding"), write_ratios=WRITE_RATIOS,
        compact_threshold=None, flush_every=8, suffix=None,
        wal_modes=("off",)):
    db = synthetic_fingerprints(SyntheticConfig(n=n_db, seed=0))
    pool = synthetic_fingerprints(SyntheticConfig(n=max(4 * n_ops, 256),
                                                  seed=7))
    queries = queries_from_db(db, min(n_db, 256))
    rows = []
    for engine in engines:
        for wr, wal in ((wr, wal) for wr in write_ratios
                        for wal in wal_modes):
            # threshold low enough that the warmup pass crosses >= 1
            # compaction (and thereby visits every delta bucket) when the
            # workload writes at all
            expected_writes = max(int(n_ops * wr), 1)
            ct = compact_threshold or max(2, expected_writes // 2)
            tmpdir = (tempfile.TemporaryDirectory(prefix="serve_load_wal_")
                      if wal != "off" else None)
            durable = dict(durable_dir=tmpdir.name,
                           wal_fsync_every=_WAL_FSYNC_EVERY[wal]) \
                if tmpdir else {}
            svc = SearchService(db, engines=(engine,), backend=backend, k=k,
                                compact_threshold=ct, **durable)
            ops = make_workload(n_ops, wr, pool[:2 * n_ops], queries, seed=3)
            warm_pool = pool[2 * n_ops:]
            warm_ops = [("insert", warm_pool[i % len(warm_pool):][:1])
                        if op == "insert" else (op, payload)
                        for i, (op, payload) in enumerate(ops)]
            # warmup: same op mix, different insert rows — compiles every
            # (batch bucket, delta bucket, window bucket) shape and forces
            # the first compaction outside the timed window
            _run_ops(svc, warm_ops, engine, k, flush_every)
            # pin the delta phase: the timed window then replays exactly the
            # warmup's (batch bucket, delta bucket) shape trajectory
            svc.compact_all()
            warm_compactions = svc.compactions
            # reset telemetry; keep the engines (and their compile caches)
            svc.reset_telemetry()
            compiled_before = svc.compiled_pipelines()
            caps_before = _capacities(svc)
            _run_ops(svc, ops, engine, k, flush_every)
            compiled_after = svc.compiled_pipelines()
            capacity_crossed = _capacities(svc) != caps_before
            s = svc.summary()
            wal_sfx = "" if wal == "off" else f"_wal-{wal}"
            rows.append({
                "name": f"serve_{engine}_wr{wr}{wal_sfx}",
                "engine": engine, "backend": backend,
                "n_db": n_db, "k": k, "n_ops": n_ops,
                "write_ratio": wr, "wal": wal,
                "compact_threshold": ct,
                # summary() reports explicit None when no queries ran
                "p50_ms": s.get("p50_ms") or 0.0,
                "p99_ms": s.get("p99_ms") or 0.0,
                "qps": s["qps"],
                "n_queries": s["n_queries"],
                "n_inserts": s["n_inserts"],
                "compactions": int(svc.compactions - warm_compactions),
                "warmup_compactions": int(warm_compactions),
                "batch_buckets": s["batch_buckets"],
                "scanned": s["scanned"].get(engine, 0),
                "compiles_in_window": int(compiled_after - compiled_before),
                # a compaction crossing a main-capacity power-of-two inside
                # the window legitimately recompiles (new array shapes) —
                # reported so the hard no-recompile check can exempt it
                "capacity_crossed": bool(capacity_crossed),
            })
            svc.close()
            if tmpdir is not None:
                tmpdir.cleanup()
    sfx = suffix if suffix is not None else (
        "" if backend in (None, "jnp") else f"_{backend}")
    emit(f"serve_load{sfx}", rows)
    return rows


# -- SLO-tracking harness (ISSUE 8) -----------------------------------------

#: (report key, registry family) pairs for the per-phase latency breakdown
PHASE_FAMILIES = (
    ("queue_wait", "service_queue_wait_ms"),
    ("engine_batch", "service_engine_batch_ms"),
    ("insert", "service_insert_ms"),
    ("wal_append", "service_wal_append_ms"),
)


def _phase_breakdown(svc):
    """Read the per-phase latency families out of the service registry."""
    out = {}
    for phase, fam_name in PHASE_FAMILIES:
        fam = svc.metrics.family(fam_name)
        if fam is None:
            continue
        n = fam.count()
        if not n:
            continue
        out[phase] = {"count": int(n), "mean_ms": fam.mean(),
                      "p50_ms": fam.quantile(0.5),
                      "p99_ms": fam.quantile(0.99)}
    return out


def _run_window(svc, ops, engine, k, flush_every, loop, target_qps):
    """One timed load window; returns (wall seconds, missed deadlines).

    ``loop="closed"`` issues ops back-to-back — the service runs at
    capacity and the measured QPS *is* the capacity. ``loop="open"``
    schedules op i at ``t0 + i/target_qps`` and sleeps until its deadline:
    arrival rate is fixed, so when the service falls behind, the backlog
    shows up as queue-wait and request latency instead of silently slowing
    the generator (coordinated omission)."""
    interval = (1.0 / target_qps) if (loop == "open" and target_qps) else 0.0
    missed = 0
    since = 0
    t0 = time.perf_counter()
    for i, (op, payload) in enumerate(ops):
        if interval:
            deadline = t0 + i * interval
            now = time.perf_counter()
            if now < deadline:
                time.sleep(deadline - now)
            elif now > deadline + interval:
                missed += 1
        if op == "insert":
            svc.insert(payload)
        else:
            svc.submit(payload, k=k, engine=engine)
            since += 1
            if since >= flush_every:
                svc.flush()
                since = 0
    svc.flush()
    return time.perf_counter() - t0, missed


def _measured_service(db, pool, queries, *, engine, backend, k, n_ops,
                      write_ratio, flush_every, loop, target_qps,
                      metrics=True, **svc_kwargs):
    """Build + warm a service, run one timed window, return
    (service, wall seconds, missed deadlines). Caller closes."""
    expected_writes = max(int(n_ops * write_ratio), 1)
    ct = max(2, expected_writes // 2)
    svc = SearchService(db, engines=(engine,), backend=backend, k=k,
                        compact_threshold=ct, metrics=metrics, **svc_kwargs)
    ops = make_workload(n_ops, write_ratio, pool[:2 * n_ops], queries, seed=3)
    warm_pool = pool[2 * n_ops:]
    warm_ops = [("insert", warm_pool[i % len(warm_pool):][:1])
                if op == "insert" else (op, payload)
                for i, (op, payload) in enumerate(ops)]
    _run_ops(svc, warm_ops, engine, k, flush_every)   # compile everything
    svc.compact_all()
    svc.reset_telemetry()
    dt, missed = _run_window(svc, ops, engine, k, flush_every, loop,
                             target_qps)
    return svc, dt, missed


def run_slo(n_db=20_000, n_ops=256, k=10, backend="jnp",
            engines=("brute",), write_ratio=0.01, flush_every=8,
            loop="closed", target_qps=None, slo_p99_ms=50.0,
            measure_overhead=False, residency="device",
            tier_chunk_rows=None, tier_chunk=None, suffix=None):
    """SLO harness: per-engine load window + registry phase breakdown +
    hard p99 verdict. Emits ``experiments/bench/serve_slo*.json`` rows and
    returns them; the CLI exits non-zero when any ``slo_ok`` is false."""
    db = synthetic_fingerprints(SyntheticConfig(n=n_db, seed=0))
    pool = synthetic_fingerprints(SyntheticConfig(n=max(4 * n_ops, 256),
                                                  seed=7))
    queries = queries_from_db(db, min(n_db, 256))
    svc_kwargs = dict(residency=residency, tier_chunk_rows=tier_chunk_rows,
                      tier_chunk=tier_chunk)
    common = dict(backend=backend, k=k, n_ops=n_ops,
                  write_ratio=write_ratio, flush_every=flush_every,
                  loop=loop, target_qps=target_qps, **svc_kwargs)
    rows = []
    for engine in engines:
        svc, dt, missed = _measured_service(db, pool, queries, engine=engine,
                                            **common)
        s = svc.summary()
        phases = _phase_breakdown(svc)
        svc.close()
        p99 = s.get("p99_ms")
        achieved_qps = s["n_queries"] / dt if dt > 0 else 0.0
        row = {
            "name": f"slo_{engine}_{loop}"
                    + (f"_q{target_qps:g}" if target_qps else ""),
            "engine": engine, "backend": backend, "loop": loop,
            "n_db": n_db, "k": k, "n_ops": n_ops,
            "write_ratio": write_ratio, "residency": residency,
            "target_qps": target_qps, "achieved_qps": round(achieved_qps, 1),
            # alias for the bench-regression guard's QPS comparison key
            "host_qps": round(achieved_qps, 1),
            "missed_deadlines": missed,
            "p50_ms": s.get("p50_ms"), "p99_ms": p99,
            "mean_ms": s.get("mean_ms"),
            "slo_p99_ms": slo_p99_ms,
            "slo_ok": bool(p99 is not None and p99 <= slo_p99_ms),
            "phases": phases,
        }
        if measure_overhead:
            # identical window against a metrics-off twin: the QPS delta is
            # the whole observability bill (acceptance bound: <= 5%)
            svc2, dt2, _ = _measured_service(db, pool, queries,
                                             engine=engine, metrics=False,
                                             **common)
            n_q2 = len(svc2.latencies_ms) or s["n_queries"]
            svc2.close()
            qps_off = n_q2 / dt2 if dt2 > 0 else 0.0
            row["qps_metrics_off"] = round(qps_off, 1)
            row["overhead_frac"] = (
                round(max(0.0, 1.0 - achieved_qps / qps_off), 4)
                if qps_off > 0 else None)
        rows.append(row)
        print(f"[serve-slo] {row['name']}: p99={p99}ms "
              f"(SLO {slo_p99_ms}ms -> {'OK' if row['slo_ok'] else 'FAIL'}) "
              f"qps={row['achieved_qps']}"
              + (f" overhead={row.get('overhead_frac')}"
                 if measure_overhead else ""))
    sfx = suffix if suffix is not None else (
        "" if backend in (None, "jnp") else f"_{backend}")
    emit(f"serve_slo{sfx}", rows)
    return rows


# -- concurrent front-end harness (ISSUE 9) ----------------------------------


def _frontend_warm(fe, queries, pool, k, engine):
    """Compile every pipeline shape the overload window can touch, on every
    replica: each pow2 micro-batch bucket (the dispatcher submits
    per-request singles; the service's micro-batcher groups them) x each
    delta bucket the window's inserts can reach x each degradation level's
    effective k. Batch warming runs through each replica's worker queue so
    no compile can land inside the timed window on *any* replica; inserts
    go through the front end so the delta grows identically everywhere.
    Ends with an aligned compaction so the window starts at delta 0 with
    the (delta 1, 2, ...) shapes already cached. Returns rows consumed
    from ``pool``."""
    import math

    sizes, b = [], 1
    while b <= min(fe.fcfg.high_water, 256):
        sizes.append(b)
        b *= 2
    k_effs = sorted({max(1, int(math.floor(k * lvl.k_scale)))
                     for lvl in fe.fcfg.ladder})

    def _warm_buckets(svc):
        for k_eff in k_effs:
            for n in sizes:
                for j in range(n):
                    svc.submit(queries[j % len(queries)], k_eff, engine)
                svc.flush()

    def _wait_all(futs):
        for f in futs:
            f.result(timeout=600.0)

    inserted = 0
    for target in (0, 1, 2, 4):
        while inserted < target:
            fe.insert(pool[inserted:inserted + 1])
            inserted += 1
        _wait_all([rep.call(_warm_buckets, label="warm")
                   for rep in fe.replicas])
    _wait_all([rep.call(lambda svc: svc.compact_all(), label="warm")
               for rep in fe.replicas])
    return inserted


def run_frontend_slo(n_db=20_000, n_ops=256, k=10, backend="jnp",
                     engine="brute", replicas=1, write_ratio=0.01,
                     high_water=64, deadline_ms=1000.0, target_qps=None,
                     overload_factor=2.0, failover=False,
                     metrics_out=None, suffix=None):
    """Overload / failover harness for the concurrent serving tier.

    Builds a :class:`repro.serve.frontend.SearchFrontend` with ``replicas``
    read replicas, measures its closed-loop capacity, then runs an
    open-loop window paced to ``target_qps`` (default ``overload_factor``
    x capacity — overloaded *by construction*, so bounded admission must
    shed). The verdict (``slo_ok``) demands **zero deadline misses among
    accepted requests**: every admitted query either completes inside its
    deadline or is dropped un-scored with a typed ``DeadlineExceeded``.

    ``failover=True`` (requires ``replicas >= 2``) kills one replica at the
    window midpoint and additionally asserts availability (completions
    after the kill), rehydration (the slot comes back at a higher
    generation), and post-rehydrate byte parity between the rebuilt
    replica and a survivor.

    Emits one ``experiments/bench/serve_slo*.json`` row with the
    ``replicas`` / ``degradation`` measurement-shape keys; ``target_qps``
    is recorded as None when auto-derived (the actual pace lands in
    ``paced_qps``) so rows stay regression-comparable across machines.
    """
    from repro.serve.frontend import (DeadlineExceeded, FrontendConfig,
                                      Overloaded, SearchFrontend,
                                      Unavailable)
    if failover and replicas < 2:
        raise ValueError("failover run needs replicas >= 2 (one dies, one "
                         "keeps serving)")
    db = synthetic_fingerprints(SyntheticConfig(n=n_db, seed=0))
    pool = synthetic_fingerprints(SyntheticConfig(n=max(4 * n_ops, 256),
                                                  seed=7))
    queries = queries_from_db(db, min(n_db, 256))
    fcfg = FrontendConfig(replicas=replicas, high_water=high_water,
                          default_deadline_ms=deadline_ms,
                          flush_interval_ms=1.0,
                          # first-compile stalls are not wedges; failover is
                          # exercised via the explicit kill hook below
                          health_timeout_s=60.0)
    fe = SearchFrontend(db, engines=(engine,), backend=backend, k=k,
                        compact_threshold=2 ** 30, frontend=fcfg)
    try:
        used = _frontend_warm(fe, queries, pool, k, engine)
        # closed-loop capacity probe in concurrent waves: sequential
        # single-client search would measure the dispatcher-tick latency
        # floor, not the micro-batched throughput the admission bound is
        # sized against — waves of in-flight requests measure the latter
        n_probe = min(max(32, n_ops // 2), 128)
        wave = max(1, min(high_water // 2, 16))
        done = 0
        t0 = time.perf_counter()
        while done < n_probe:
            futs = [fe.submit(queries[(done + j) % len(queries)], k, engine,
                              deadline_ms=None)
                    for j in range(min(wave, n_probe - done))]
            for f in futs:
                f.result(timeout=60.0)
            done += len(futs)
        cap_qps = n_probe / max(time.perf_counter() - t0, 1e-9)

        paced = target_qps if target_qps else overload_factor * cap_qps
        if failover and not target_qps:
            # the failover leg measures sustained availability through a
            # kill + rehydrate, not shedding: pace the window to span ~2s
            # of wall time so "mid-run" leaves real traffic after the kill
            paced = min(paced, max(n_ops / 2.0, 1.0))
        interval = 1.0 / paced
        ops = make_workload(n_ops, write_ratio, pool[used:used + 2 * n_ops],
                            queries, seed=3)
        kill_at = n_ops // 2 if failover else None
        kill_idx = replicas - 1

        import queue as queue_mod
        import threading
        stats = {"expired": 0, "unavailable": 0, "lat_ms": [],
                 "after_kill": 0}
        pend: queue_mod.Queue = queue_mod.Queue()

        def _collect():
            # futures complete roughly FIFO (dispatch order), so a single
            # sequential collector measures completion latency with at most
            # scheduling-noise overestimate — conservative for miss counting
            while True:
                item = pend.get()
                if item is None:
                    return
                fut, t_sub, after_kill = item
                try:
                    fut.result(timeout=120.0)
                except DeadlineExceeded:
                    stats["expired"] += 1
                    continue
                except Unavailable:
                    stats["unavailable"] += 1
                    continue
                stats["lat_ms"].append((time.perf_counter() - t_sub) * 1e3)
                if after_kill:
                    stats["after_kill"] += 1

        collector = threading.Thread(target=_collect, daemon=True)
        collector.start()
        shed = 0
        killed = False
        t0 = time.perf_counter()
        for i, (op, payload) in enumerate(ops):
            slot = t0 + i * interval
            now = time.perf_counter()
            if now < slot:
                time.sleep(slot - now)
            if kill_at is not None and i == kill_at and not killed:
                fe.kill_replica(kill_idx)
                killed = True
            if op == "insert":
                try:
                    fe.insert(payload)
                except Unavailable:
                    stats["unavailable"] += 1
            else:
                try:
                    fut = fe.submit(payload, k=k, engine=engine)
                except Overloaded:
                    shed += 1
                    continue
                pend.put((fut, time.perf_counter(), killed))
        fe.drain(timeout=120.0)
        dt = time.perf_counter() - t0
        pend.put(None)
        collector.join(timeout=120.0)

        failover_ok = None
        if failover:
            wait_until = time.perf_counter() + 60.0
            while (fe.live_replicas() < replicas
                   and time.perf_counter() < wait_until):
                time.sleep(0.05)
            rehydrated = (fe.live_replicas() == replicas
                          and fe.replicas[kill_idx].generation > 0)
            parity = False
            if rehydrated:
                # a write after rehydration must land on the rebuilt slot
                # too, and both replicas must extract identical bytes
                fe.insert(pool[used + 2 * n_ops:used + 2 * n_ops + 2])
                a0, _ = fe.replica_state(0)
                a1, _ = fe.replica_state(kill_idx)
                parity = (set(a0) == set(a1)
                          and all(np.array_equal(a0[name], a1[name])
                                  for name in a0))
            availability = stats["after_kill"] > 0
            failover_ok = bool(rehydrated and parity and availability)

        lat = stats["lat_ms"]
        misses = (sum(1 for v in lat if v > deadline_ms)
                  if deadline_ms is not None else 0)
        s = fe.summary()
        if metrics_out:
            fe.export_metrics(metrics_out, ts=time.time())
        row = {
            "name": f"frontend_{engine}_r{replicas}"
                    + ("_failover" if failover else ""),
            "engine": engine, "backend": backend, "loop": "open",
            "n_db": n_db, "k": k, "n_ops": n_ops,
            "write_ratio": write_ratio,
            "replicas": replicas, "degradation": len(fe.fcfg.ladder),
            "high_water": high_water, "deadline_ms": deadline_ms,
            "target_qps": target_qps if target_qps else None,
            "paced_qps": round(paced, 1),
            "capacity_qps": round(cap_qps, 1),
            "achieved_qps": round(len(lat) / dt, 1) if dt > 0 else 0.0,
            "host_qps": round(len(lat) / dt, 1) if dt > 0 else 0.0,
            "completed": len(lat),
            "shed": int(s["shed"]), "expired": int(s["expired"]),
            "unavailable": int(stats["unavailable"]),
            "deadline_misses": int(misses),
            "failovers": int(s["failovers"]),
            "max_degradation_level": int(s["max_degradation_level"]),
            "p50_ms": (round(float(np.percentile(lat, 50)), 3)
                       if lat else None),
            "p99_ms": (round(float(np.percentile(lat, 99)), 3)
                       if lat else None),
            "slo_ok": bool(misses == 0 and failover_ok is not False),
        }
        if failover:
            row["failover_ok"] = failover_ok
            row["completed_after_kill"] = int(stats["after_kill"])
        print(f"[serve-frontend] {row['name']}: paced={row['paced_qps']}qps "
              f"(capacity {row['capacity_qps']}) completed={row['completed']}"
              f" shed={row['shed']} expired={row['expired']} "
              f"misses={row['deadline_misses']} p99={row['p99_ms']}ms "
              f"degrade<= {row['max_degradation_level']} "
              f"-> {'OK' if row['slo_ok'] else 'FAIL'}"
              + (f" failover_ok={failover_ok}" if failover else ""))
    finally:
        fe.close()
    rows = [row]
    sfx = suffix if suffix is not None else (
        "" if backend in (None, "jnp") else f"_{backend}")
    emit(f"serve_slo{sfx}", rows)
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="jnp",
                    choices=["numpy", "jnp", "tpu"])
    ap.add_argument("--n-db", type=int, default=20_000)
    ap.add_argument("--ops", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--engines", default="brute,bitbound-folding",
                    help="comma-separated: brute,bitbound-folding,hnsw")
    ap.add_argument("--write-ratio", type=float, default=None,
                    help="run a single write ratio instead of the sweep "
                         f"{WRITE_RATIOS}")
    ap.add_argument("--compact-threshold", type=int, default=None)
    ap.add_argument("--flush-every", type=int, default=8)
    ap.add_argument("--wal", action="store_true",
                    help=f"sweep the durability axis {WAL_MODES} (WAL into "
                         "a temp dir; p99 delta fsync-per-ack vs group "
                         "commit vs in-memory)")
    ap.add_argument("--slo", action="store_true",
                    help="SLO-tracking mode: load loop + per-phase registry "
                         "breakdown + hard p99 verdict (exit non-zero on "
                         "violation)")
    ap.add_argument("--loop", default="closed", choices=["closed", "open"],
                    help="SLO mode: closed = back-to-back (capacity), open "
                         "= wall-clock paced to --target-qps (queueing "
                         "visible when the service falls behind)")
    ap.add_argument("--target-qps", type=float, default=None,
                    help="SLO mode, open loop: arrival rate to pace to")
    ap.add_argument("--slo-p99-ms", type=float, default=50.0,
                    help="SLO mode: request-latency p99 bound (ms)")
    ap.add_argument("--measure-overhead", action="store_true",
                    help="SLO mode: replay the window against a "
                         "metrics=False twin and report overhead_frac")
    ap.add_argument("--overhead-budget", type=float, default=None,
                    help="SLO mode: fail when overhead_frac exceeds this "
                         "(the acceptance bound is 0.05)")
    ap.add_argument("--residency", default="device",
                    choices=["device", "tiered"])
    ap.add_argument("--tier-chunk-rows", type=int, default=None)
    ap.add_argument("--tier-chunk", type=int, default=None)
    ap.add_argument("--frontend", action="store_true",
                    help="concurrent-tier mode (ISSUE 9): open-loop "
                         "overload window through SearchFrontend; paced to "
                         "--target-qps or 2x measured capacity; exits "
                         "non-zero on any deadline miss among accepted "
                         "requests")
    ap.add_argument("--replicas", type=int, default=1,
                    help="frontend mode: read replicas behind the front end")
    ap.add_argument("--high-water", type=int, default=64,
                    help="frontend mode: admission bound (in-flight "
                         "requests before typed Overloaded shedding)")
    ap.add_argument("--deadline-ms", type=float, default=1000.0,
                    help="frontend mode: per-request deadline (<= 0 "
                         "disables deadlines)")
    ap.add_argument("--failover", action="store_true",
                    help="frontend mode: kill one replica mid-window and "
                         "assert availability + post-rehydrate byte parity "
                         "(needs --replicas >= 2)")
    ap.add_argument("--expect-shed", action="store_true",
                    help="frontend mode: fail unless the window actually "
                         "shed (guards the overload-by-construction smoke)")
    ap.add_argument("--metrics-out", default=None,
                    help="frontend mode: export the merged front-end + "
                         "replica metrics registries as JSONL here")
    ap.add_argument("--out-suffix", default=None,
                    help="override the emitted artifact suffix (e.g. "
                         "_smoke keeps CI runs off the committed rows)")
    args = ap.parse_args()
    if args.frontend:
        rows = run_frontend_slo(
            n_db=args.n_db, n_ops=args.ops, k=args.k, backend=args.backend,
            engine=args.engines.split(",")[0],
            replicas=args.replicas,
            write_ratio=(args.write_ratio
                         if args.write_ratio is not None else 0.01),
            high_water=args.high_water,
            deadline_ms=(args.deadline_ms if args.deadline_ms > 0 else None),
            target_qps=args.target_qps, failover=args.failover,
            metrics_out=args.metrics_out, suffix=args.out_suffix)
        bad = [r["name"] for r in rows if not r["slo_ok"]]
        if args.expect_shed:
            bad += [f"{r['name']} (no shedding at {r['paced_qps']} qps)"
                    for r in rows if not r["shed"]]
        if bad:
            raise SystemExit(f"frontend SLO violated: {bad}")
        return
    if args.slo:
        if args.loop == "open" and not args.target_qps:
            ap.error("--loop open requires --target-qps")
        rows = run_slo(n_db=args.n_db, n_ops=args.ops, k=args.k,
                       backend=args.backend,
                       engines=tuple(args.engines.split(",")),
                       write_ratio=(args.write_ratio
                                    if args.write_ratio is not None
                                    else 0.01),
                       flush_every=args.flush_every, loop=args.loop,
                       target_qps=args.target_qps,
                       slo_p99_ms=args.slo_p99_ms,
                       measure_overhead=(args.measure_overhead
                                         or args.overhead_budget is not None),
                       residency=args.residency,
                       tier_chunk_rows=args.tier_chunk_rows,
                       tier_chunk=args.tier_chunk, suffix=args.out_suffix)
        bad = [r["name"] for r in rows if not r["slo_ok"]]
        if args.overhead_budget is not None:
            bad += [f"{r['name']} (overhead {r['overhead_frac']} > "
                    f"{args.overhead_budget})" for r in rows
                    if (r.get("overhead_frac") or 0) > args.overhead_budget]
        if bad:
            raise SystemExit(f"SLO violated: {bad}")
        return
    ratios = (args.write_ratio,) if args.write_ratio is not None \
        else WRITE_RATIOS
    rows = run(n_db=args.n_db, n_ops=args.ops, k=args.k,
               backend=args.backend,
               engines=tuple(args.engines.split(",")),
               write_ratios=ratios,
               compact_threshold=args.compact_threshold,
               flush_every=args.flush_every,
               wal_modes=WAL_MODES if args.wal else ("off",),
               suffix=args.out_suffix)
    bad = [r for r in rows
           if r["compiles_in_window"] and not r["capacity_crossed"]]
    if bad:
        raise SystemExit(
            f"steady-state window recompiled: "
            f"{[(r['name'], r['compiles_in_window']) for r in bad]}")


if __name__ == "__main__":
    main()
