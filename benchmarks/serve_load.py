"""Online-serving load benchmark: latency/QPS under mixed insert+query load.

Drives a :class:`repro.serve.service.SearchService` (dynamic micro-batching,
LSM-compacting mutable store) with deterministic workloads at configurable
write ratios and emits ``experiments/bench/serve_load[_backend].json``:
per-(engine, write_ratio) rows with p50/p99 request latency, QPS, scanned
candidates, compaction counts — and ``compiles_in_window``, the number of
pipeline compilations that happened inside the steady-state timed window.

The warmup phase replays enough of the workload to touch every pipeline
shape the steady state can need — all power-of-two batch buckets, every
delta-bucket size below the compaction threshold (one full delta
0 -> threshold cycle), and at least one compaction — so the timed window
measures pure serving: ``compiles_in_window`` must be 0 (asserted by the CI
smoke leg via the JSON).

``--wal`` adds the durability axis (ISSUE 6): each (engine, write_ratio)
cell is re-measured with the write-ahead log on — fsync-per-ack and
8-record group commit — against a temp directory, so the p99 rows quantify
what the acked-implies-recovered contract costs per insert.
"""
from __future__ import annotations

import argparse
import tempfile

import numpy as np

from repro.data.molecules import SyntheticConfig, synthetic_fingerprints, queries_from_db
from repro.launch.search_serve import make_workload
from repro.serve.service import SearchService
from .common import emit

WRITE_RATIOS = (0.0, 0.01, 0.1)

# durability axis (ISSUE 6): "off" = in-memory service (the historical rows,
# names unchanged), "fsync" = WAL with fsync-per-ack, "group8" = WAL with
# 8-record group commit. The p99 delta between fsync and group8 is the price
# of the strict acked-implies-recovered contract per insert.
WAL_MODES = ("off", "fsync", "group8")
_WAL_FSYNC_EVERY = {"fsync": 1, "group8": 8}


def _capacities(svc):
    return {name: eng.store.main.capacity
            for name, eng in svc.engines.items() if hasattr(eng, "store")}


def _run_ops(svc, ops, engine, k, flush_every):
    since = 0
    for op, payload in ops:
        if op == "insert":
            svc.insert(payload)
        else:
            svc.submit(payload, k=k, engine=engine)
            since += 1
            if since >= flush_every:
                svc.flush()
                since = 0
    svc.flush()


def run(n_db=20_000, n_ops=256, k=10, backend="jnp",
        engines=("brute", "bitbound-folding"), write_ratios=WRITE_RATIOS,
        compact_threshold=None, flush_every=8, suffix=None,
        wal_modes=("off",)):
    db = synthetic_fingerprints(SyntheticConfig(n=n_db, seed=0))
    pool = synthetic_fingerprints(SyntheticConfig(n=max(4 * n_ops, 256),
                                                  seed=7))
    queries = queries_from_db(db, min(n_db, 256))
    rows = []
    for engine in engines:
        for wr, wal in ((wr, wal) for wr in write_ratios
                        for wal in wal_modes):
            # threshold low enough that the warmup pass crosses >= 1
            # compaction (and thereby visits every delta bucket) when the
            # workload writes at all
            expected_writes = max(int(n_ops * wr), 1)
            ct = compact_threshold or max(2, expected_writes // 2)
            tmpdir = (tempfile.TemporaryDirectory(prefix="serve_load_wal_")
                      if wal != "off" else None)
            durable = dict(durable_dir=tmpdir.name,
                           wal_fsync_every=_WAL_FSYNC_EVERY[wal]) \
                if tmpdir else {}
            svc = SearchService(db, engines=(engine,), backend=backend, k=k,
                                compact_threshold=ct, **durable)
            ops = make_workload(n_ops, wr, pool[:2 * n_ops], queries, seed=3)
            warm_pool = pool[2 * n_ops:]
            warm_ops = [("insert", warm_pool[i % len(warm_pool):][:1])
                        if op == "insert" else (op, payload)
                        for i, (op, payload) in enumerate(ops)]
            # warmup: same op mix, different insert rows — compiles every
            # (batch bucket, delta bucket, window bucket) shape and forces
            # the first compaction outside the timed window
            _run_ops(svc, warm_ops, engine, k, flush_every)
            # pin the delta phase: the timed window then replays exactly the
            # warmup's (batch bucket, delta bucket) shape trajectory
            svc.compact_all()
            warm_compactions = svc.compactions
            # reset telemetry; keep the engines (and their compile caches)
            svc.reset_telemetry()
            compiled_before = svc.compiled_pipelines()
            caps_before = _capacities(svc)
            _run_ops(svc, ops, engine, k, flush_every)
            compiled_after = svc.compiled_pipelines()
            capacity_crossed = _capacities(svc) != caps_before
            s = svc.summary()
            wal_sfx = "" if wal == "off" else f"_wal-{wal}"
            rows.append({
                "name": f"serve_{engine}_wr{wr}{wal_sfx}",
                "engine": engine, "backend": backend,
                "n_db": n_db, "k": k, "n_ops": n_ops,
                "write_ratio": wr, "wal": wal,
                "compact_threshold": ct,
                "p50_ms": s.get("p50_ms", 0.0),
                "p99_ms": s.get("p99_ms", 0.0),
                "qps": s["qps"],
                "n_queries": s["n_queries"],
                "n_inserts": s["n_inserts"],
                "compactions": int(svc.compactions - warm_compactions),
                "warmup_compactions": int(warm_compactions),
                "batch_buckets": s["batch_buckets"],
                "scanned": s["scanned"].get(engine, 0),
                "compiles_in_window": int(compiled_after - compiled_before),
                # a compaction crossing a main-capacity power-of-two inside
                # the window legitimately recompiles (new array shapes) —
                # reported so the hard no-recompile check can exempt it
                "capacity_crossed": bool(capacity_crossed),
            })
            svc.close()
            if tmpdir is not None:
                tmpdir.cleanup()
    sfx = suffix if suffix is not None else (
        "" if backend in (None, "jnp") else f"_{backend}")
    emit(f"serve_load{sfx}", rows)
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="jnp",
                    choices=["numpy", "jnp", "tpu"])
    ap.add_argument("--n-db", type=int, default=20_000)
    ap.add_argument("--ops", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--engines", default="brute,bitbound-folding",
                    help="comma-separated: brute,bitbound-folding,hnsw")
    ap.add_argument("--write-ratio", type=float, default=None,
                    help="run a single write ratio instead of the sweep "
                         f"{WRITE_RATIOS}")
    ap.add_argument("--compact-threshold", type=int, default=None)
    ap.add_argument("--flush-every", type=int, default=8)
    ap.add_argument("--wal", action="store_true",
                    help=f"sweep the durability axis {WAL_MODES} (WAL into "
                         "a temp dir; p99 delta fsync-per-ack vs group "
                         "commit vs in-memory)")
    args = ap.parse_args()
    ratios = (args.write_ratio,) if args.write_ratio is not None \
        else WRITE_RATIOS
    rows = run(n_db=args.n_db, n_ops=args.ops, k=args.k,
               backend=args.backend,
               engines=tuple(args.engines.split(",")),
               write_ratios=ratios,
               compact_threshold=args.compact_threshold,
               flush_every=args.flush_every,
               wal_modes=WAL_MODES if args.wal else ("off",))
    bad = [r for r in rows
           if r["compiles_in_window"] and not r["capacity_crossed"]]
    if bad:
        raise SystemExit(
            f"steady-state window recompiled: "
            f"{[(r['name'], r['compiles_in_window']) for r in bad]}")


if __name__ == "__main__":
    main()
