"""Online-serving load benchmark: latency/QPS under mixed insert+query load.

Drives a :class:`repro.serve.service.SearchService` (dynamic micro-batching,
LSM-compacting mutable store) with deterministic workloads at configurable
write ratios and emits ``experiments/bench/serve_load[_backend].json``:
per-(engine, write_ratio) rows with p50/p99 request latency, QPS, scanned
candidates, compaction counts — and ``compiles_in_window``, the number of
pipeline compilations that happened inside the steady-state timed window.

The warmup phase replays enough of the workload to touch every pipeline
shape the steady state can need — all power-of-two batch buckets, every
delta-bucket size below the compaction threshold (one full delta
0 -> threshold cycle), and at least one compaction — so the timed window
measures pure serving: ``compiles_in_window`` must be 0 (asserted by the CI
smoke leg via the JSON).

``--wal`` adds the durability axis (ISSUE 6): each (engine, write_ratio)
cell is re-measured with the write-ahead log on — fsync-per-ack and
8-record group commit — against a temp directory, so the p99 rows quantify
what the acked-implies-recovered contract costs per insert.

``--slo`` switches to the SLO-tracking harness (ISSUE 8 tentpole): a
target-QPS load loop — ``--loop closed`` issues ops back-to-back (service
at capacity), ``--loop open`` paces submissions to ``--target-qps`` on a
wall-clock schedule so queueing delay shows up in the latency when the
service falls behind — with a per-phase latency breakdown read from the
service's metrics registry (queue wait / engine batch / insert / WAL
append) and a **hard p99 SLO verdict**: the run exits non-zero when
request p99 exceeds ``--slo-p99-ms``. ``--measure-overhead`` replays the
identical window against a ``metrics=False`` twin service and reports the
observability overhead as a QPS fraction (``--overhead-budget 0.05`` turns
the 5% acceptance bound into a hard failure).
"""
from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np

from repro.data.molecules import SyntheticConfig, synthetic_fingerprints, queries_from_db
from repro.launch.search_serve import make_workload
from repro.serve.service import SearchService
from .common import emit

WRITE_RATIOS = (0.0, 0.01, 0.1)

# durability axis (ISSUE 6): "off" = in-memory service (the historical rows,
# names unchanged), "fsync" = WAL with fsync-per-ack, "group8" = WAL with
# 8-record group commit. The p99 delta between fsync and group8 is the price
# of the strict acked-implies-recovered contract per insert.
WAL_MODES = ("off", "fsync", "group8")
_WAL_FSYNC_EVERY = {"fsync": 1, "group8": 8}


def _capacities(svc):
    return {name: eng.store.main.capacity
            for name, eng in svc.engines.items() if hasattr(eng, "store")}


def _run_ops(svc, ops, engine, k, flush_every):
    since = 0
    for op, payload in ops:
        if op == "insert":
            svc.insert(payload)
        else:
            svc.submit(payload, k=k, engine=engine)
            since += 1
            if since >= flush_every:
                svc.flush()
                since = 0
    svc.flush()


def run(n_db=20_000, n_ops=256, k=10, backend="jnp",
        engines=("brute", "bitbound-folding"), write_ratios=WRITE_RATIOS,
        compact_threshold=None, flush_every=8, suffix=None,
        wal_modes=("off",)):
    db = synthetic_fingerprints(SyntheticConfig(n=n_db, seed=0))
    pool = synthetic_fingerprints(SyntheticConfig(n=max(4 * n_ops, 256),
                                                  seed=7))
    queries = queries_from_db(db, min(n_db, 256))
    rows = []
    for engine in engines:
        for wr, wal in ((wr, wal) for wr in write_ratios
                        for wal in wal_modes):
            # threshold low enough that the warmup pass crosses >= 1
            # compaction (and thereby visits every delta bucket) when the
            # workload writes at all
            expected_writes = max(int(n_ops * wr), 1)
            ct = compact_threshold or max(2, expected_writes // 2)
            tmpdir = (tempfile.TemporaryDirectory(prefix="serve_load_wal_")
                      if wal != "off" else None)
            durable = dict(durable_dir=tmpdir.name,
                           wal_fsync_every=_WAL_FSYNC_EVERY[wal]) \
                if tmpdir else {}
            svc = SearchService(db, engines=(engine,), backend=backend, k=k,
                                compact_threshold=ct, **durable)
            ops = make_workload(n_ops, wr, pool[:2 * n_ops], queries, seed=3)
            warm_pool = pool[2 * n_ops:]
            warm_ops = [("insert", warm_pool[i % len(warm_pool):][:1])
                        if op == "insert" else (op, payload)
                        for i, (op, payload) in enumerate(ops)]
            # warmup: same op mix, different insert rows — compiles every
            # (batch bucket, delta bucket, window bucket) shape and forces
            # the first compaction outside the timed window
            _run_ops(svc, warm_ops, engine, k, flush_every)
            # pin the delta phase: the timed window then replays exactly the
            # warmup's (batch bucket, delta bucket) shape trajectory
            svc.compact_all()
            warm_compactions = svc.compactions
            # reset telemetry; keep the engines (and their compile caches)
            svc.reset_telemetry()
            compiled_before = svc.compiled_pipelines()
            caps_before = _capacities(svc)
            _run_ops(svc, ops, engine, k, flush_every)
            compiled_after = svc.compiled_pipelines()
            capacity_crossed = _capacities(svc) != caps_before
            s = svc.summary()
            wal_sfx = "" if wal == "off" else f"_wal-{wal}"
            rows.append({
                "name": f"serve_{engine}_wr{wr}{wal_sfx}",
                "engine": engine, "backend": backend,
                "n_db": n_db, "k": k, "n_ops": n_ops,
                "write_ratio": wr, "wal": wal,
                "compact_threshold": ct,
                # summary() reports explicit None when no queries ran
                "p50_ms": s.get("p50_ms") or 0.0,
                "p99_ms": s.get("p99_ms") or 0.0,
                "qps": s["qps"],
                "n_queries": s["n_queries"],
                "n_inserts": s["n_inserts"],
                "compactions": int(svc.compactions - warm_compactions),
                "warmup_compactions": int(warm_compactions),
                "batch_buckets": s["batch_buckets"],
                "scanned": s["scanned"].get(engine, 0),
                "compiles_in_window": int(compiled_after - compiled_before),
                # a compaction crossing a main-capacity power-of-two inside
                # the window legitimately recompiles (new array shapes) —
                # reported so the hard no-recompile check can exempt it
                "capacity_crossed": bool(capacity_crossed),
            })
            svc.close()
            if tmpdir is not None:
                tmpdir.cleanup()
    sfx = suffix if suffix is not None else (
        "" if backend in (None, "jnp") else f"_{backend}")
    emit(f"serve_load{sfx}", rows)
    return rows


# -- SLO-tracking harness (ISSUE 8) -----------------------------------------

#: (report key, registry family) pairs for the per-phase latency breakdown
PHASE_FAMILIES = (
    ("queue_wait", "service_queue_wait_ms"),
    ("engine_batch", "service_engine_batch_ms"),
    ("insert", "service_insert_ms"),
    ("wal_append", "service_wal_append_ms"),
)


def _phase_breakdown(svc):
    """Read the per-phase latency families out of the service registry."""
    out = {}
    for phase, fam_name in PHASE_FAMILIES:
        fam = svc.metrics.family(fam_name)
        if fam is None:
            continue
        n = fam.count()
        if not n:
            continue
        out[phase] = {"count": int(n), "mean_ms": fam.mean(),
                      "p50_ms": fam.quantile(0.5),
                      "p99_ms": fam.quantile(0.99)}
    return out


def _run_window(svc, ops, engine, k, flush_every, loop, target_qps):
    """One timed load window; returns (wall seconds, missed deadlines).

    ``loop="closed"`` issues ops back-to-back — the service runs at
    capacity and the measured QPS *is* the capacity. ``loop="open"``
    schedules op i at ``t0 + i/target_qps`` and sleeps until its deadline:
    arrival rate is fixed, so when the service falls behind, the backlog
    shows up as queue-wait and request latency instead of silently slowing
    the generator (coordinated omission)."""
    interval = (1.0 / target_qps) if (loop == "open" and target_qps) else 0.0
    missed = 0
    since = 0
    t0 = time.perf_counter()
    for i, (op, payload) in enumerate(ops):
        if interval:
            deadline = t0 + i * interval
            now = time.perf_counter()
            if now < deadline:
                time.sleep(deadline - now)
            elif now > deadline + interval:
                missed += 1
        if op == "insert":
            svc.insert(payload)
        else:
            svc.submit(payload, k=k, engine=engine)
            since += 1
            if since >= flush_every:
                svc.flush()
                since = 0
    svc.flush()
    return time.perf_counter() - t0, missed


def _measured_service(db, pool, queries, *, engine, backend, k, n_ops,
                      write_ratio, flush_every, loop, target_qps,
                      metrics=True, **svc_kwargs):
    """Build + warm a service, run one timed window, return
    (service, wall seconds, missed deadlines). Caller closes."""
    expected_writes = max(int(n_ops * write_ratio), 1)
    ct = max(2, expected_writes // 2)
    svc = SearchService(db, engines=(engine,), backend=backend, k=k,
                        compact_threshold=ct, metrics=metrics, **svc_kwargs)
    ops = make_workload(n_ops, write_ratio, pool[:2 * n_ops], queries, seed=3)
    warm_pool = pool[2 * n_ops:]
    warm_ops = [("insert", warm_pool[i % len(warm_pool):][:1])
                if op == "insert" else (op, payload)
                for i, (op, payload) in enumerate(ops)]
    _run_ops(svc, warm_ops, engine, k, flush_every)   # compile everything
    svc.compact_all()
    svc.reset_telemetry()
    dt, missed = _run_window(svc, ops, engine, k, flush_every, loop,
                             target_qps)
    return svc, dt, missed


def run_slo(n_db=20_000, n_ops=256, k=10, backend="jnp",
            engines=("brute",), write_ratio=0.01, flush_every=8,
            loop="closed", target_qps=None, slo_p99_ms=50.0,
            measure_overhead=False, residency="device",
            tier_chunk_rows=None, tier_chunk=None, suffix=None):
    """SLO harness: per-engine load window + registry phase breakdown +
    hard p99 verdict. Emits ``experiments/bench/serve_slo*.json`` rows and
    returns them; the CLI exits non-zero when any ``slo_ok`` is false."""
    db = synthetic_fingerprints(SyntheticConfig(n=n_db, seed=0))
    pool = synthetic_fingerprints(SyntheticConfig(n=max(4 * n_ops, 256),
                                                  seed=7))
    queries = queries_from_db(db, min(n_db, 256))
    svc_kwargs = dict(residency=residency, tier_chunk_rows=tier_chunk_rows,
                      tier_chunk=tier_chunk)
    common = dict(backend=backend, k=k, n_ops=n_ops,
                  write_ratio=write_ratio, flush_every=flush_every,
                  loop=loop, target_qps=target_qps, **svc_kwargs)
    rows = []
    for engine in engines:
        svc, dt, missed = _measured_service(db, pool, queries, engine=engine,
                                            **common)
        s = svc.summary()
        phases = _phase_breakdown(svc)
        svc.close()
        p99 = s.get("p99_ms")
        achieved_qps = s["n_queries"] / dt if dt > 0 else 0.0
        row = {
            "name": f"slo_{engine}_{loop}"
                    + (f"_q{target_qps:g}" if target_qps else ""),
            "engine": engine, "backend": backend, "loop": loop,
            "n_db": n_db, "k": k, "n_ops": n_ops,
            "write_ratio": write_ratio, "residency": residency,
            "target_qps": target_qps, "achieved_qps": round(achieved_qps, 1),
            # alias for the bench-regression guard's QPS comparison key
            "host_qps": round(achieved_qps, 1),
            "missed_deadlines": missed,
            "p50_ms": s.get("p50_ms"), "p99_ms": p99,
            "mean_ms": s.get("mean_ms"),
            "slo_p99_ms": slo_p99_ms,
            "slo_ok": bool(p99 is not None and p99 <= slo_p99_ms),
            "phases": phases,
        }
        if measure_overhead:
            # identical window against a metrics-off twin: the QPS delta is
            # the whole observability bill (acceptance bound: <= 5%)
            svc2, dt2, _ = _measured_service(db, pool, queries,
                                             engine=engine, metrics=False,
                                             **common)
            n_q2 = len(svc2.latencies_ms) or s["n_queries"]
            svc2.close()
            qps_off = n_q2 / dt2 if dt2 > 0 else 0.0
            row["qps_metrics_off"] = round(qps_off, 1)
            row["overhead_frac"] = (
                round(max(0.0, 1.0 - achieved_qps / qps_off), 4)
                if qps_off > 0 else None)
        rows.append(row)
        print(f"[serve-slo] {row['name']}: p99={p99}ms "
              f"(SLO {slo_p99_ms}ms -> {'OK' if row['slo_ok'] else 'FAIL'}) "
              f"qps={row['achieved_qps']}"
              + (f" overhead={row.get('overhead_frac')}"
                 if measure_overhead else ""))
    sfx = suffix if suffix is not None else (
        "" if backend in (None, "jnp") else f"_{backend}")
    emit(f"serve_slo{sfx}", rows)
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="jnp",
                    choices=["numpy", "jnp", "tpu"])
    ap.add_argument("--n-db", type=int, default=20_000)
    ap.add_argument("--ops", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--engines", default="brute,bitbound-folding",
                    help="comma-separated: brute,bitbound-folding,hnsw")
    ap.add_argument("--write-ratio", type=float, default=None,
                    help="run a single write ratio instead of the sweep "
                         f"{WRITE_RATIOS}")
    ap.add_argument("--compact-threshold", type=int, default=None)
    ap.add_argument("--flush-every", type=int, default=8)
    ap.add_argument("--wal", action="store_true",
                    help=f"sweep the durability axis {WAL_MODES} (WAL into "
                         "a temp dir; p99 delta fsync-per-ack vs group "
                         "commit vs in-memory)")
    ap.add_argument("--slo", action="store_true",
                    help="SLO-tracking mode: load loop + per-phase registry "
                         "breakdown + hard p99 verdict (exit non-zero on "
                         "violation)")
    ap.add_argument("--loop", default="closed", choices=["closed", "open"],
                    help="SLO mode: closed = back-to-back (capacity), open "
                         "= wall-clock paced to --target-qps (queueing "
                         "visible when the service falls behind)")
    ap.add_argument("--target-qps", type=float, default=None,
                    help="SLO mode, open loop: arrival rate to pace to")
    ap.add_argument("--slo-p99-ms", type=float, default=50.0,
                    help="SLO mode: request-latency p99 bound (ms)")
    ap.add_argument("--measure-overhead", action="store_true",
                    help="SLO mode: replay the window against a "
                         "metrics=False twin and report overhead_frac")
    ap.add_argument("--overhead-budget", type=float, default=None,
                    help="SLO mode: fail when overhead_frac exceeds this "
                         "(the acceptance bound is 0.05)")
    ap.add_argument("--residency", default="device",
                    choices=["device", "tiered"])
    ap.add_argument("--tier-chunk-rows", type=int, default=None)
    ap.add_argument("--tier-chunk", type=int, default=None)
    args = ap.parse_args()
    if args.slo:
        if args.loop == "open" and not args.target_qps:
            ap.error("--loop open requires --target-qps")
        rows = run_slo(n_db=args.n_db, n_ops=args.ops, k=args.k,
                       backend=args.backend,
                       engines=tuple(args.engines.split(",")),
                       write_ratio=(args.write_ratio
                                    if args.write_ratio is not None
                                    else 0.01),
                       flush_every=args.flush_every, loop=args.loop,
                       target_qps=args.target_qps,
                       slo_p99_ms=args.slo_p99_ms,
                       measure_overhead=(args.measure_overhead
                                         or args.overhead_budget is not None),
                       residency=args.residency,
                       tier_chunk_rows=args.tier_chunk_rows,
                       tier_chunk=args.tier_chunk)
        bad = [r["name"] for r in rows if not r["slo_ok"]]
        if args.overhead_budget is not None:
            bad += [f"{r['name']} (overhead {r['overhead_frac']} > "
                    f"{args.overhead_budget})" for r in rows
                    if (r.get("overhead_frac") or 0) > args.overhead_budget]
        if bad:
            raise SystemExit(f"SLO violated: {bad}")
        return
    ratios = (args.write_ratio,) if args.write_ratio is not None \
        else WRITE_RATIOS
    rows = run(n_db=args.n_db, n_ops=args.ops, k=args.k,
               backend=args.backend,
               engines=tuple(args.engines.split(",")),
               write_ratios=ratios,
               compact_threshold=args.compact_threshold,
               flush_every=args.flush_every,
               wal_modes=WAL_MODES if args.wal else ("off",))
    bad = [r for r in rows
           if r["compiles_in_window"] and not r["capacity_crossed"]]
    if bad:
        raise SystemExit(
            f"steady-state window recompiled: "
            f"{[(r['name'], r['compiles_in_window']) for r in bad]}")


if __name__ == "__main__":
    main()
