"""Shared benchmark helpers: data, oracle, timing, CSV output."""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

RESULTS_DIR = Path(__file__).resolve().parent.parent / "experiments" / "bench"

# Bench scale: large enough for real trends, small enough for this container.
N_DB = 60_000
N_QUERIES = 64
K = 20


def get_db(n=N_DB, seed=0, length=1024):
    from repro.data.molecules import SyntheticConfig, synthetic_fingerprints
    return synthetic_fingerprints(SyntheticConfig(n=n, seed=seed,
                                                  length=length))


def get_queries(db, n=N_QUERIES, seed=1):
    from repro.data.molecules import queries_from_db
    return queries_from_db(db, n, seed=seed)


def brute_truth(db, queries, k=K, metric=None):
    """Exact top-k via the fused kernel engine (itself validated vs ref)."""
    from repro.core.fingerprints import resolve_metric
    from repro.kernels import ref
    met = resolve_metric(metric)
    q = jnp.asarray(queries)
    d = jnp.asarray(db)
    # chunk queries to bound memory
    ids_all, vals_all = [], []
    for i in range(0, q.shape[0], 16):
        ids, vals = ref.tanimoto_topk_ref(q[i:i + 16], d, k, metric=met)
        ids_all.append(np.asarray(ids))
        vals_all.append(np.asarray(vals))
    return np.concatenate(ids_all), np.concatenate(vals_all)


def timeit(fn, *args, repeats=3, warmup=1):
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or \
            isinstance(out, (jax.Array, tuple, list)) else None
        ts.append(time.perf_counter() - t0)
    return min(ts)


def emit(name: str, rows: list[dict]):
    """Print rows as `name,us_per_call,derived` CSV lines + save JSON."""
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    (RESULTS_DIR / f"{name}.json").write_text(json.dumps(rows, indent=1))
    for r in rows:
        us = r.get("us_per_call", "")
        derived = {k: v for k, v in r.items() if k not in ("name", "us_per_call")}
        print(f"{r.get('name', name)},{us},{json.dumps(derived, sort_keys=True)}")
