"""Tiered-residency capacity sweep (ISSUE 7): QPS vs DB size vs fold level
for the BitBound two-stage engine, device-resident vs tiered.

``residency="device"`` keeps the full-resolution packed DB in device memory
(the single-device ceiling this PR breaks); ``residency="tiered"`` keeps
only the folded stage-1 arrays plus the count/order vectors device-resident
and streams the BitBound-bounded rescore candidates host -> HBM through the
engine's double-buffered staging window. The sweep measures both paths on
shared DB sizes (the crossover axis) and pushes the tiered path an order of
magnitude past the largest device-resident point — on this container both
"device" and host memory are the same DRAM, so the wall-clocks bound the
*software* overhead of chunking + merging (the stall fraction and the
streamed-bytes column are what the roofline host-link model scales to real
host links; see ``benchmarks/roofline.py --tiered``).

The host link itself is measured once per run (``jax.device_put`` of a
64 MiB buffer, timed to readiness) and emitted as ``link_gbps_measured`` so
the roofline model can use the *observed* bandwidth on any host.

Emits ``experiments/bench/BENCH_tiered.json`` (schema in EXPERIMENTS.md
§Tiered residency) and one CSV line per row. ``--tiny`` is the CI smoke
leg: a small DB forced through the streaming path with multiple chunks and
a hard bit-identity assert against ``residency="device"`` (brute +
bitbound), emitting nothing.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.core import BitBoundFoldingEngine, BruteForceEngine
from repro.data.molecules import (SyntheticConfig, queries_from_db,
                                  synthetic_fingerprints)
from .common import emit, timeit

K = 10
N_QUERIES = 32


def measure_link_gbps(n_bytes: int = 64 << 20) -> float:
    """Observed host->device bandwidth: device_put of a fresh buffer, timed
    to block_until_ready (the same primitive the streaming path issues)."""
    buf = np.random.default_rng(0).integers(
        0, 2**32, size=(n_bytes // 4,), dtype=np.uint32)
    jax.block_until_ready(jax.device_put(buf[:1024]))     # warm the path
    t0 = time.perf_counter()
    jax.block_until_ready(jax.device_put(buf))
    dt = time.perf_counter() - t0
    return n_bytes / dt / 1e9


def bench_point(pool: np.ndarray, n_db: int, m: int, residency: str,
                backend: str, batches: int = 4, tier_chunk: int = 256,
                repeats: int = 3):
    db = pool[:n_db]
    queries = queries_from_db(db, N_QUERIES * batches)
    eng = BitBoundFoldingEngine(db, cutoff=0.6, m=m, backend=backend,
                                residency=residency, tier_chunk=tier_chunk)
    for b in range(batches):                               # compile/warm
        eng.search(queries[b * N_QUERIES:(b + 1) * N_QUERIES], K)
    # best-of-repeats over the whole batch loop: single-run wall-clocks on
    # a shared container are noisy at these (sub-second) windows
    dt, stats = None, {}
    for _ in range(repeats):
        t0 = time.perf_counter()
        for b in range(batches):
            eng.search(queries[b * N_QUERIES:(b + 1) * N_QUERIES], K)
        t = time.perf_counter() - t0
        if dt is None or t < dt:
            dt, stats = t, dict(eng.stats)
    qps = N_QUERIES * batches / dt
    row = {
        "name": f"tiered_{residency}_n{n_db}_m{m}",
        "n_db": n_db, "n_queries": N_QUERIES, "fold_m": m,
        "residency": residency, "backend": eng.backend, "k": K,
        "words": int(db.shape[1]),
        "capacity": int(eng.store.main.capacity),
        "scanned_per_query": int(eng.scanned(N_QUERIES) / N_QUERIES),
        "host_qps": round(qps, 1),
        "us_per_call": round(dt / batches * 1e6, 1),
    }
    if residency == "tiered":
        row.update(
            stall_fraction=round(stats.get("tiered_stall_fraction", 0.0), 4),
            tiered_chunks=int(stats.get("tiered_chunks", 0)),
            streamed_bytes_per_batch=int(
                stats.get("tiered_streamed_bytes", 0)))
    return row


def run(sizes_device=(50_000, 100_000),
        sizes_tiered=(50_000, 100_000, 1_000_000),
        fold_ms=(2, 4), backend: str = "jnp", batches: int = 4):
    n_max = max(max(sizes_device), max(sizes_tiered))
    print(f"[tiered-capacity] generating {n_max}-print synthetic pool...",
          flush=True)
    pool = synthetic_fingerprints(SyntheticConfig(n=n_max))
    link = measure_link_gbps()
    print(f"[tiered-capacity] measured host link: {link:.2f} GB/s")
    rows = []
    # fold-level axis at the shared crossover size, both residencies
    shared = max(s for s in sizes_device if s in set(sizes_tiered))
    for m in fold_ms:
        for residency in ("device", "tiered"):
            r = bench_point(pool, shared, m, residency, backend,
                            batches=batches)
            r["link_gbps_measured"] = round(link, 2)
            rows.append(r)
            print(f"[tiered-capacity] {r['name']}: {r['host_qps']} QPS "
                  f"(stall {r.get('stall_fraction', '-')})", flush=True)
    # DB-size axis at the headline fold level
    m = fold_ms[-1]
    done = {(r["n_db"], r["fold_m"], r["residency"]) for r in rows}
    for residency, sizes in (("device", sizes_device),
                             ("tiered", sizes_tiered)):
        for n in sizes:
            if (n, m, residency) in done:
                continue
            r = bench_point(pool, n, m, residency, backend, batches=batches)
            r["link_gbps_measured"] = round(link, 2)
            rows.append(r)
            print(f"[tiered-capacity] {r['name']}: {r['host_qps']} QPS "
                  f"(stall {r.get('stall_fraction', '-')})", flush=True)
    emit("BENCH_tiered", rows)
    return rows


def tiny() -> int:
    """CI smoke leg: force a small DB through the streaming path (multiple
    chunks) and require bit-identity with the device-resident path."""
    db = synthetic_fingerprints(SyntheticConfig(n=2048))
    queries = queries_from_db(db, 16)
    extra = synthetic_fingerprints(SyntheticConfig(n=40, seed=5))
    failures = 0
    for name, dev, tie in (
        ("bitbound",
         BitBoundFoldingEngine(db, cutoff=0.6, m=4, backend="jnp"),
         BitBoundFoldingEngine(db, cutoff=0.6, m=4, backend="jnp",
                               residency="tiered", tier_chunk=32)),
        ("brute",
         BruteForceEngine(db, backend="jnp"),
         BruteForceEngine(db, backend="jnp", residency="tiered",
                          tier_chunk_rows=512)),
    ):
        for phase in ("main", "delta"):
            if phase == "delta":
                dev.insert(extra)
                tie.insert(extra)
            ids_d, sims_d = dev.search(queries, K)
            ids_t, sims_t = tie.search(queries, K)
            same = (np.array_equal(np.asarray(ids_d), np.asarray(ids_t))
                    and np.array_equal(np.asarray(sims_d),
                                       np.asarray(sims_t)))
            chunks = tie.stats.get("tiered_chunks", 0)
            status = "OK" if same and chunks > 1 else "FAIL"
            failures += status == "FAIL"
            print(f"[tiered-capacity] tiny {name}/{phase}: parity "
                  f"{'bit-identical' if same else 'MISMATCH'}, "
                  f"{chunks} chunks streamed -> {status}")
    return 1 if failures else 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small DB, streaming forced, parity "
                         "asserted, nothing emitted")
    ap.add_argument("--sizes-device", type=int, nargs="+",
                    default=[50_000, 100_000])
    ap.add_argument("--sizes-tiered", type=int, nargs="+",
                    default=[50_000, 100_000, 1_000_000])
    ap.add_argument("--fold-ms", type=int, nargs="+", default=[2, 4])
    ap.add_argument("--backend", default="jnp", choices=["jnp", "tpu"])
    ap.add_argument("--batches", type=int, default=4)
    args = ap.parse_args()
    if args.tiny:
        sys.exit(tiny())
    run(sizes_device=tuple(args.sizes_device),
        sizes_tiered=tuple(args.sizes_tiered),
        fold_ms=tuple(args.fold_ms), backend=args.backend,
        batches=args.batches)


if __name__ == "__main__":
    main()
