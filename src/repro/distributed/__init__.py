from .compression import (  # noqa: F401
    quantize_int8, dequantize_int8, ef_compress_grads, EFState, ef_init,
    quantized_psum,
)
