"""Gradient compression for the DP all-reduce path.

Two pieces:

* ``quantized_psum`` — a shard_map collective: per-tensor int8 blockwise
  quantise -> all_gather of (q, scale) -> dequantise + sum. This is the
  transport-level primitive (4x fewer bytes on the wire than f32 psum; 2x
  vs bf16) and is what the sharded search merge and the explicit-DP training
  path use.
* ``ef_compress_grads`` — error-feedback quantisation of the gradient tree
  inside the pjit train step: g_hat = Q(g + e); e' = (g + e) - g_hat. The
  numerics of compressed communication (what affects convergence) are exact;
  the wire-byte saving is accounted analytically in the roofline because
  XLA owns the collective schedule under pjit (DESIGN.md §6, noted honestly
  in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jax.Array, block: int = 256):
    """Blockwise symmetric int8 quantisation. Returns (q int8, scales f32)."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127).astype(jnp.int8)
    return q, scale[:, 0], n


def dequantize_int8(q: jax.Array, scale: jax.Array, n: int, shape, dtype):
    deq = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    return deq.reshape(shape).astype(dtype)


class EFState(NamedTuple):
    error: dict   # residual tree, f32, sharded like params


def ef_init(params) -> EFState:
    return EFState(error=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def ef_compress_grads(grads, ef: EFState, block: int = 256):
    """Error-feedback int8 quantise/dequantise of a gradient tree."""
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s, n = quantize_int8(target, block)
        g_hat = dequantize_int8(q, s, n, g.shape, jnp.float32)
        return g_hat, target - g_hat

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef.error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_g, EFState(error=new_e)


def quantized_psum(x: jax.Array, axis_name: str, block: int = 256):
    """int8-compressed psum inside shard_map: quantise locally, all_gather
    the compact representation, dequantise + reduce. Wire bytes ≈ 1/4 of a
    f32 psum (+ scale overhead)."""
    q, s, n = quantize_int8(x, block)
    qs = jax.lax.all_gather(q, axis_name)          # (D, blocks, block) int8
    ss = jax.lax.all_gather(s, axis_name)          # (D, blocks)
    deq = qs.astype(jnp.float32) * ss[..., None]
    total = jnp.sum(deq, axis=0).reshape(-1)[:n]
    return total.reshape(x.shape).astype(x.dtype)
