"""Worker-thread replica: single-owner access to one deterministic
:class:`~repro.serve.service.SearchService` (ISSUE 9 tentpole).

The deterministic core is synchronous and single-caller by design — the
parity suites depend on it. The concurrent tier therefore never shares a
service between threads: each replica owns exactly one service and one
worker thread, and *every* access (query batches, insert fan-out, state
extraction for snapshots, compaction, degradation-knob changes) is a
:class:`_Task` enqueued on the replica's FIFO queue and executed by the
worker. FIFO ordering is the consistency model: inserts enqueued under the
front end's insert lock land in the same order on every replica, so replica
states never diverge; a query sees exactly the inserts enqueued before it
on *its* replica.

Failure model: a worker that raises marks the replica ``dead`` and exits; a
worker stuck inside an engine call past the health timeout is marked
``dead`` externally by the front end's monitor (``Replica.busy_for``). A
dead replica's queue is :meth:`drain`-ed by the front end and its tasks
re-dispatched to a surviving replica — task callables take the service as
their only argument precisely so they can be re-bound. The abandoned worker
thread (daemon) may still finish its in-flight task; result futures are
first-write-wins, so a late result from a wedged worker and the re-dispatch
cannot race each other into a double completion.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable

from .service import SearchService


class ReplicaDead(RuntimeError):
    """The task's replica died before (or while) executing it."""


class Future:
    """Minimal thread-safe one-shot result cell (first write wins)."""

    __slots__ = ("_ev", "_value", "_exc", "_lock")

    def __init__(self):
        self._ev = threading.Event()
        self._value = None
        self._exc = None
        self._lock = threading.Lock()

    def set_result(self, value) -> bool:
        with self._lock:
            if self._ev.is_set():
                return False
            self._value = value
            self._ev.set()
            return True

    def set_exception(self, exc: BaseException) -> bool:
        with self._lock:
            if self._ev.is_set():
                return False
            self._exc = exc
            self._ev.set()
            return True

    def done(self) -> bool:
        return self._ev.is_set()

    def result(self, timeout: float | None = None):
        if not self._ev.wait(timeout):
            raise TimeoutError("replica task did not complete in time")
        if self._exc is not None:
            raise self._exc
        return self._value


@dataclass
class _Task:
    """One unit of work: ``fn(service)`` run by the owning worker.

    ``abandon`` is called (with the terminal exception) when the task can
    never run anywhere — e.g. every replica is dead — so composite tasks
    like query batches can fail their inner request futures instead of
    leaving clients hanging.
    """
    fn: Callable[[SearchService], object]
    label: str = "task"
    future: Future = field(default_factory=Future)
    abandon: Callable[[BaseException], None] | None = None

    def fail(self, exc: BaseException) -> None:
        if self.abandon is not None:
            self.abandon(exc)
        self.future.set_exception(exc)


LIVE, DEAD, STOPPED = "live", "dead", "stopped"


class Replica:
    """One service + one worker thread + one FIFO task queue."""

    def __init__(self, index: int, service: SearchService, *,
                 generation: int = 0, clock=time.perf_counter):
        self.index = int(index)
        self.generation = int(generation)
        self.svc = service
        self.clock = clock
        self.state = LIVE
        self.error: BaseException | None = None
        self._q: queue.Queue[_Task | None] = queue.Queue()
        self._busy_since: float | None = None
        self._tasks_done = 0
        self._thread = threading.Thread(
            target=self._run, daemon=True,
            name=f"replica-{self.index}.{self.generation}")
        self._thread.start()

    # -- scheduling ----------------------------------------------------------
    def call(self, fn, label: str = "task", abandon=None) -> Future:
        """Enqueue ``fn(service)``; returns the result future."""
        task = _Task(fn, label=label, abandon=abandon)
        self.put(task)
        return task.future

    def put(self, task: _Task) -> None:
        if self.state != LIVE:
            task.fail(ReplicaDead(
                f"replica {self.index} is {self.state}"))
            return
        self._q.put(task)

    def queue_depth(self) -> int:
        """Pending tasks (+1 while the worker is inside one) — the load
        balancing key and the ``frontend_queue_depth`` gauge."""
        return self._q.qsize() + (1 if self._busy_since is not None else 0)

    def busy_for(self, now: float | None = None) -> float:
        """Seconds the worker has spent inside its current task (0 when
        idle) — the wedge-detection signal."""
        t0 = self._busy_since
        if t0 is None:
            return 0.0
        return (now if now is not None else self.clock()) - t0

    # -- lifecycle -----------------------------------------------------------
    def mark_dead(self, error: BaseException | None = None) -> None:
        """Externally declare this replica failed (wedge timeout, divergent
        insert, explicit kill). The worker thread is abandoned — it exits
        at its next queue pop; a task it is still inside may complete its
        future first-write-wins."""
        if self.state == LIVE:
            self.state = DEAD
            self.error = error

    def drain(self) -> list[_Task]:
        """Pull every not-yet-started task off a dead replica's queue so
        the front end can re-dispatch them to a survivor."""
        tasks = []
        while True:
            try:
                t = self._q.get_nowait()
            except queue.Empty:
                return tasks
            if t is not None:
                tasks.append(t)

    def stop(self, timeout: float | None = 5.0) -> None:
        """Graceful shutdown: the worker finishes queued tasks, then exits."""
        if self.state == LIVE:
            self.state = STOPPED
        self._q.put(None)                  # wake + terminate sentinel
        self._thread.join(timeout)

    # -- worker --------------------------------------------------------------
    def _run(self) -> None:
        while True:
            task = self._q.get()
            if task is None:
                return
            if self.state == DEAD:
                # drained concurrently with our pop: hand the task back so
                # the front end's failover can re-dispatch it
                task.fail(ReplicaDead(
                    f"replica {self.index} died before task "
                    f"{task.label!r} ran"))
                continue
            self._busy_since = self.clock()
            try:
                task.future.set_result(task.fn(self.svc))
            except BaseException as e:     # noqa: BLE001 — fault isolation
                # a failing task poisons the replica (the service may be in
                # a partially-applied state — divergence risk); the front
                # end's monitor sees DEAD and fails over
                self.state = DEAD
                self.error = e
                task.fail(e)
                self._busy_since = None
                return
            self._busy_since = None
            self._tasks_done += 1
