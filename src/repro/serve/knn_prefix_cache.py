"""KNN prefix cache: the paper's similarity engine applied to LM serving.

Prompts are sketched into 1024-bit binary fingerprints (SimHash over token
n-grams — the LM analogue of a Morgan fingerprint: local structure hashed
into bit positions). A Tanimoto KNN search over previously-served prompt
sketches finds the best cached KV prefix; if the Jaccard similarity clears a
threshold and the cached prompt shares a long-enough exact token prefix, the
decode skips prefill for that prefix.

This is the honest crossover promised in DESIGN.md §5: the search engine
(core/ + kernels/) is reused verbatim — the cache is just another
fingerprint database, searchable with the same fused kernel and shardable
with core/distributed.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.fingerprints import pack_bits
from ..core import BruteForceEngine


def simhash_sketch(tokens: np.ndarray, length: int = 1024, ngram: int = 3,
                   seed: int = 0x5EED) -> np.ndarray:
    """Sketch a token sequence into a packed `length`-bit fingerprint.

    Each token n-gram sets one bit (hash % length) — like a Morgan
    fingerprint's substructure->bit mapping. Jaccard over sketches then
    approximates n-gram overlap between prompts."""
    tokens = np.asarray(tokens, dtype=np.int64)
    bits = np.zeros(length, dtype=np.uint8)
    if len(tokens) < ngram:
        grams = [tuple(tokens.tolist())]
    else:
        grams = [tuple(tokens[i:i + ngram].tolist())
                 for i in range(len(tokens) - ngram + 1)]
    for g in grams:
        h = seed
        for t in g:
            h = (h * 1000003 + int(t)) & 0xFFFFFFFFFFFFFFFF
        bits[h % length] = 1
    return pack_bits(bits[None])[0]


def _common_prefix_len(a: np.ndarray, b: np.ndarray) -> int:
    n = min(len(a), len(b))
    neq = np.nonzero(a[:n] != b[:n])[0]
    return int(neq[0]) if len(neq) else n


@dataclass
class KNNPrefixCache:
    """Bounded store of (prompt sketch, prompt tokens, KV cache handle)."""
    capacity: int = 256
    sim_threshold: float = 0.7
    min_prefix: int = 8

    _sketches: list = field(default_factory=list)
    _prompts: list = field(default_factory=list)
    _payloads: list = field(default_factory=list)
    hits: int = 0
    misses: int = 0

    def insert(self, prompt_tokens: np.ndarray, payload) -> None:
        if len(self._sketches) >= self.capacity:   # FIFO eviction
            self._sketches.pop(0)
            self._prompts.pop(0)
            self._payloads.pop(0)
        self._sketches.append(simhash_sketch(prompt_tokens))
        self._prompts.append(np.asarray(prompt_tokens))
        self._payloads.append(payload)

    def lookup(self, prompt_tokens: np.ndarray):
        """Returns (payload, reuse_len) of the best reusable prefix, or
        (None, 0). Stage 1: Tanimoto KNN over sketches (the paper's engine);
        stage 2: exact token-prefix verification (like the paper's two-stage
        folding rescore, approximate filter -> exact check)."""
        if not self._sketches:
            self.misses += 1
            return None, 0
        q = simhash_sketch(prompt_tokens)[None]
        db = np.stack(self._sketches)
        eng = BruteForceEngine(db)
        ids, sims = eng.search(q, k=min(4, len(self._sketches)))
        best_payload, best_len = None, 0
        for idx, sim in zip(ids[0], sims[0]):
            if idx < 0 or sim < self.sim_threshold:
                continue
            plen = _common_prefix_len(np.asarray(prompt_tokens),
                                      self._prompts[int(idx)])
            if plen > best_len:
                best_payload, best_len = self._payloads[int(idx)], plen
        if best_len >= self.min_prefix:
            self.hits += 1
            return best_payload, best_len
        self.misses += 1
        return None, 0
