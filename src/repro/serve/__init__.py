from .knn_prefix_cache import KNNPrefixCache, simhash_sketch  # noqa: F401
