from .knn_prefix_cache import KNNPrefixCache, simhash_sketch  # noqa: F401
from .store import MutableFingerprintStore, next_pow2  # noqa: F401
from .service import SearchService, ServiceConfig  # noqa: F401
