from .knn_prefix_cache import KNNPrefixCache, simhash_sketch  # noqa: F401
from .store import (MutableFingerprintStore, TieredFingerprintStore,  # noqa: F401
                    next_pow2, validate_rows)
from .service import SearchService, ServiceConfig  # noqa: F401
from .wal import WriteAheadLog, WalCorruption, replay as wal_replay  # noqa: F401
from .replica import Future, Replica, ReplicaDead  # noqa: F401
from .frontend import (DeadlineExceeded, DegradeLevel,  # noqa: F401
                       FrontendConfig, Overloaded, SearchFrontend,
                       Unavailable)
from . import snapshot  # noqa: F401
