"""Concurrent serving tier: admission control, deadlines, load shedding,
degradation, and replica failover over the deterministic core (ISSUE 9).

:class:`SearchFrontend` is the thread-based production front end the
ROADMAP's "concurrent production front end" item calls for — the paper's
fixed-interval query pipelines (§IV) and FPScreen's pipeline-parallel
serving shape, layered *on top of* the synchronous
:class:`~repro.serve.service.SearchService` so every parity suite keeps
holding. Correctness anchor: with one replica, shedding disabled and
generous deadlines, results are **bit-identical** to the direct service
path (pinned by ``tests/test_frontend.py``).

Pieces (docs/ARCHITECTURE.md §Serving tier):

* **Bounded admission + deadlines** — :meth:`submit` is non-blocking and
  sheds with a typed :class:`Overloaded` once ``high_water`` requests are
  in flight (bounded memory under open-loop overload — the queue never
  grows without bound). Every request carries a deadline; expired requests
  are dropped *pre-dispatch* — never scored — and counted
  (``frontend_deadline_expired_total``).
* **Flush-interval micro-batching** — a dispatcher thread wakes every
  ``flush_interval_ms`` (or immediately when idle), drops expired
  requests, and hands the tick's batch to the least-loaded replica, which
  runs it through the service's pow2-bucketed micro-batcher.
* **Graceful degradation** — a declared :class:`DegradeLevel` ladder steps
  down under sustained overload (shedding observed, or in-flight depth
  above ``degrade_high`` for ``degrade_ticks`` consecutive ticks) and back
  up on recovery: smaller ``k``-rescore window (``k_scale`` — for
  BitBound, ``k`` *is* the Eq.2 window driver), smaller HNSW beam /
  ``ef_search``. The active level is exported as the
  ``frontend_degradation_level`` gauge.
* **Read replicas + failover** — N :class:`~repro.serve.replica.Replica`
  workers hydrated from one snapshot state, queries load-balanced by queue
  depth, inserts fanned to every live replica through the WAL under one
  insert lock (same order everywhere — states never diverge). A replica
  that raises, diverges on assigned gids, or wedges past
  ``health_timeout_s`` is marked dead, drained (query batches re-dispatch
  to a survivor), and re-hydrated from the latest published snapshot plus
  the WAL tail (``replica.failover`` span), with the replay window pinned
  against WAL GC.
* **Background maintenance** — snapshots (every ``snapshot_every_inserts``)
  and delta compaction (past ``compact_delta`` rows) run behind the
  dispatcher's scheduler, never on the insert/ack path; replica services
  are built with auto-compaction disabled so the deterministic core never
  compacts inside an ack.

Durability: the front end owns the WAL and snapshot directory itself
(replica services run in-memory) — the on-disk layout is exactly the
single-service one, so ``SearchService.open`` can always recover a front
end directory and vice versa.
"""
from __future__ import annotations

import math
import threading
import time
from copy import deepcopy
from dataclasses import dataclass, replace
from functools import partial
from pathlib import Path

import numpy as np

from ..checkpoint import manager as ckpt
from ..checkpoint.fs import DEFAULT_FS, Fs
from ..obs.metrics import MetricsRegistry, NULL_METRICS
from ..obs.trace import TRACER as _TR
from . import snapshot as snap
from . import wal as wal_mod
from .replica import DEAD, LIVE, Future, Replica, ReplicaDead
from .service import SearchService, ServiceConfig

#: replica services never auto-compact inside an insert ack — compaction is
#: scheduled off the hot path by the front end (FrontendConfig.compact_delta)
_NO_AUTO_COMPACT = 2 ** 31 - 1


class Overloaded(RuntimeError):
    """Typed admission rejection: ``high_water`` requests already in flight.
    Callers back off / retry; the queue never grows unboundedly."""


class DeadlineExceeded(RuntimeError):
    """The request's deadline expired before it was scored (dropped
    pre-dispatch) — the work was shed, not half-done."""


class Unavailable(RuntimeError):
    """No live replica can take the work right now."""


@dataclass(frozen=True)
class DegradeLevel:
    """One rung of the graceful-degradation ladder. Scales are applied to
    the *configured* (level-0) values, so stepping back up restores exact
    baseline quality; level 0 must be the identity."""
    name: str
    k_scale: float = 1.0       # per-request k (BitBound rescore-window driver)
    ef_scale: float = 1.0      # HNSW ef_search
    beam_scale: float = 1.0    # HNSW traversal beam

    @property
    def is_identity(self) -> bool:
        return self.k_scale == self.ef_scale == self.beam_scale == 1.0


DEFAULT_LADDER = (
    DegradeLevel("full"),
    DegradeLevel("beam-half", ef_scale=0.5, beam_scale=0.5),
    DegradeLevel("k-half", k_scale=0.5, ef_scale=0.25, beam_scale=0.25),
)


@dataclass
class FrontendConfig:
    """Concurrency knobs of the serving tier (engine knobs stay in
    :class:`~repro.serve.service.ServiceConfig`)."""
    replicas: int = 1
    high_water: int = 256            # admitted-but-uncompleted request bound
    default_deadline_ms: float | None = 1000.0   # None = no deadline
    flush_interval_ms: float = 2.0   # dispatcher micro-batch tick
    insert_timeout_s: float = 30.0   # per-replica apply ack before failover
    health_timeout_s: float = 10.0   # busy-this-long in one task == wedged
    rehydrate: bool = True           # auto-failover dead replicas
    ladder: tuple = DEFAULT_LADDER   # level 0 must be the identity
    degrade_high: float = 0.75       # depth fraction that arms step-down
    degrade_low: float = 0.25        # depth fraction that arms step-up
    degrade_ticks: int = 3           # consecutive armed ticks before a step
    snapshot_every_inserts: int = 0  # 0 = only explicit snapshot() calls
    compact_delta: int | None = None  # delta rows before scheduled
    #   compaction (None = the ServiceConfig.compact_threshold value)
    metrics: bool = True

    def __post_init__(self):
        if not self.ladder or not self.ladder[0].is_identity:
            raise ValueError("ladder[0] must be the identity (full-quality) "
                             "level")
        if self.replicas < 1:
            raise ValueError("need at least one replica")


@dataclass
class _FrontReq:
    rid: int
    queries: np.ndarray
    k: int
    engine: str
    deadline: float | None           # absolute clock() time; None = never
    t_submit: float
    future: Future = None            # type: ignore[assignment]


class SearchFrontend:
    """Admission -> micro-batch -> replica fan-out serving tier."""

    def __init__(self, db, engines=("bitbound-folding",),
                 config: ServiceConfig | None = None,
                 frontend: FrontendConfig | None = None,
                 fs: Fs | None = None, clock=time.perf_counter,
                 _services: list[SearchService] | None = None,
                 _wal_records=None, **overrides):
        cfg = config or ServiceConfig(**overrides)
        if overrides and config is not None:
            raise ValueError("pass either config= or keyword overrides")
        self.fcfg = frontend or FrontendConfig()
        self.clock = clock
        self._fs = fs or DEFAULT_FS
        # the front end owns durability; replica services run in-memory with
        # auto-compaction disabled (scheduled off the hot path instead)
        self._durable_dir = cfg.durable_dir
        self._compact_delta = (self.fcfg.compact_delta
                               if self.fcfg.compact_delta is not None
                               else cfg.compact_threshold)
        self.config = replace(cfg, durable_dir=None,
                              compact_threshold=_NO_AUTO_COMPACT)
        self.engines = tuple(engines)

        if _services is None:
            svc0 = SearchService(db, engines=self.engines,
                                 config=replace(self.config))
            services = [svc0]
            if self.fcfg.replicas > 1:
                arrays, meta = snap.service_state(svc0)
                meta = dict(meta, words=svc0.words)
                for _ in range(self.fcfg.replicas - 1):
                    services.append(SearchService.from_state(
                        {k: v.copy() for k, v in arrays.items()},
                        deepcopy(meta)))
        else:
            services = _services
        self.words = services[0].words
        self._n_total = int(services[0].n_total)

        self._init_metrics()
        self.replicas: list[Replica] = [
            self._make_replica(i, svc, generation=0)
            for i, svc in enumerate(services)]

        # request plumbing (before durability — the initial snapshot below
        # already goes through the locked snapshot path)
        self._admit_lock = threading.Lock()
        self._admit_q: list[_FrontReq] = []
        self._inflight = 0
        self._next_rid = 0
        self._insert_lock = threading.Lock()
        self._snap_lock = threading.Lock()
        self._rehydrating: set[int] = set()
        self._compact_futs: list = []
        # degradation controller state
        self._level = 0
        self._hot_ticks = 0
        self._cool_ticks = 0
        self._shed_seen = 0.0
        self.max_level_engaged = 0
        self._last_maintenance_error: BaseException | None = None
        self._closed = False
        self._wake = threading.Event()
        self._stop = threading.Event()

        # durability (frontend-owned; same on-disk layout as SearchService)
        self._wal = None
        self._snap_id = -1
        self._inserts_since_snap = 0
        if self._durable_dir is not None:
            base = Path(self._durable_dir)
            self._snap_dir = base / "snapshots"
            self._wal_dir = base / "wal"
            if _services is None and (
                    ckpt.snapshot_steps(self._snap_dir)
                    or wal_mod.segment_seqs(self._wal_dir)):
                raise ValueError(
                    f"{base} already holds durable state; use "
                    f"SearchFrontend.open() to warm-restart from it")
            self._wal = wal_mod.WriteAheadLog(
                self._wal_dir, self.words, fs=self._fs,
                fsync_every=self.config.wal_fsync_every)
            if _services is None:
                self.snapshot()        # base DB recoverable before any insert

        self._dispatcher = threading.Thread(target=self._dispatch_loop,
                                            daemon=True,
                                            name="frontend-dispatch")
        self._dispatcher.start()

    # -- construction helpers ------------------------------------------------
    def _make_replica(self, index: int, svc: SearchService,
                      generation: int) -> Replica:
        # remember the level-0 engine knobs so degradation scales from (and
        # recovery restores) the configured baseline
        svc._fe_level = 0
        svc._fe_base = {}
        for name, eng in svc.engines.items():
            if name == "hnsw":
                svc._fe_base[name] = (int(eng.ef_search), int(eng.beam))
        rep = Replica(index, svc, generation=generation, clock=self.clock)
        self._m_replica_live.set(1, replica=index)
        self._m_depth.touch(replica=index)
        return rep

    @classmethod
    def open(cls, directory, *, engines=None,
             frontend: FrontendConfig | None = None, fs: Fs | None = None,
             clock=time.perf_counter, **overrides) -> "SearchFrontend":
        """Warm-restart a front end from a durable directory: latest intact
        snapshot, one WAL-tail replay (torn tail truncated), every replica
        hydrated bit-identically, fresh WAL segment opened."""
        fs = fs or DEFAULT_FS
        base = Path(directory)
        step, arrays, meta = ckpt.load_latest_intact(base / "snapshots")
        if step is None:
            raise FileNotFoundError(f"no intact snapshot under {base}")
        fcfg = frontend or FrontendConfig()
        records, _ = wal_mod.replay(base / "wal",
                                    from_seq=int(meta["wal_from_seq"]),
                                    words=int(meta["words"]), truncate=True,
                                    fs=fs)
        services = []
        for _ in range(fcfg.replicas):
            svc = SearchService.from_state(
                {k: v.copy() for k, v in arrays.items()}, deepcopy(meta),
                **overrides)
            svc.apply_wal_records(records)
            services.append(svc)
        cfg = replace(services[0].config, durable_dir=str(base))
        fe = cls(None, engines=tuple(meta["engines"]), config=cfg,
                 frontend=fcfg, fs=fs, clock=clock, _services=services)
        fe._snap_id = step
        if fcfg.compact_delta is None and "frontend_compact_delta" in meta:
            # the user-facing scheduled-compaction cadence survives the
            # replica configs' disabled auto-compaction threshold
            fe._compact_delta = int(meta["frontend_compact_delta"])
        return fe

    # -- metrics -------------------------------------------------------------
    def _init_metrics(self) -> None:
        self.metrics = (MetricsRegistry() if self.fcfg.metrics
                        else NULL_METRICS)
        m = self.metrics
        self._m_admitted = m.counter(
            "frontend_admitted_total", "requests admitted",
            labels=("engine",))
        self._m_shed = m.counter(
            "frontend_shed_total",
            "requests rejected at admission", labels=("reason",))
        self._m_expired = m.counter(
            "frontend_deadline_expired_total",
            "admitted requests dropped un-scored at deadline",
            labels=("stage",))
        self._m_inserts = m.counter(
            "frontend_inserts_total", "fingerprint rows acked")
        self._m_depth = m.gauge(
            "frontend_queue_depth", "replica worker queue depth",
            labels=("replica",))
        self._m_inflight = m.gauge(
            "frontend_inflight", "admitted-but-uncompleted requests")
        self._m_level = m.gauge(
            "frontend_degradation_level", "active degradation-ladder level")
        self._m_shifts = m.counter(
            "frontend_degradation_shifts_total", "ladder steps taken",
            labels=("direction",))
        self._m_replica_live = m.gauge(
            "frontend_replica_live", "1 = replica live, 0 = dead/rehydrating",
            labels=("replica",))
        self._m_failovers = m.counter(
            "frontend_failovers_total", "replicas declared dead")
        self._m_lat = m.histogram(
            "frontend_request_latency_ms", "submit -> completion",
            labels=("engine",))
        # pre-seed the known label sets so every family exports (and the
        # CI required-family floor holds) even on runs where an event —
        # a shed, an expiry, a failover — never fires
        for reason in ("overload", "unavailable"):
            self._m_shed.touch(reason=reason)
        for stage in ("dispatch", "worker"):
            self._m_expired.touch(stage=stage)
        for direction in ("down", "up"):
            self._m_shifts.touch(direction=direction)
        for engine in self.engines:
            self._m_admitted.touch(engine=engine)
            self._m_lat.touch(engine=engine)
        self._m_inserts.inc(0)
        self._m_failovers.inc(0)
        self._m_inflight.set(0)
        self._m_level.set(0)

    # -- read path -----------------------------------------------------------
    def submit(self, queries, k: int | None = None, engine: str | None = None,
               deadline_ms: float | None = -1.0) -> Future:
        """Admit a search request; returns a :class:`Future` redeemed by
        ``.result(timeout)``. Non-blocking: raises :class:`Overloaded`
        instead of queueing past ``high_water``. ``deadline_ms`` overrides
        the configured default (``None`` = no deadline for this request)."""
        if self._closed:
            raise RuntimeError("frontend is closed")
        engine = engine or self.engines[0]
        if engine not in self.engines:
            raise ValueError(f"engine {engine!r} not served "
                             f"(have {self.engines})")
        queries = np.atleast_2d(np.asarray(queries, dtype=np.uint32))
        if deadline_ms is not None and deadline_ms < 0:
            deadline_ms = self.fcfg.default_deadline_ms
        now = self.clock()
        with _TR.span("frontend.admit", engine=engine):
            with self._admit_lock:
                if self._inflight >= self.fcfg.high_water:
                    self._m_shed.inc(reason="overload")
                    with _TR.span("frontend.shed", reason="overload"):
                        pass
                    raise Overloaded(
                        f"{self._inflight} requests in flight "
                        f"(high_water {self.fcfg.high_water})")
                req = _FrontReq(
                    rid=self._next_rid, queries=queries,
                    k=int(k or self.config.k), engine=engine,
                    deadline=(now + deadline_ms / 1e3
                              if deadline_ms is not None else None),
                    t_submit=now, future=Future())
                self._next_rid += 1
                self._admit_q.append(req)
                self._inflight += 1
            self._m_admitted.inc(engine=engine)
        self._wake.set()
        return req.future

    def search(self, queries, k: int | None = None,
               engine: str | None = None, deadline_ms: float | None = -1.0,
               timeout: float | None = 60.0):
        """Blocking convenience path: submit + wait. With one replica, no
        shedding and no deadline pressure this is bit-identical to
        ``SearchService.search`` (the deterministic-core parity anchor)."""
        return self.submit(queries, k, engine,
                           deadline_ms=deadline_ms).result(timeout)

    def _complete(self, req: _FrontReq, result=None,
                  exc: BaseException | None = None) -> None:
        first = (req.future.set_exception(exc) if exc is not None
                 else req.future.set_result(result))
        if first:
            with self._admit_lock:
                self._inflight -= 1
            if exc is None:
                self._m_lat.observe((self.clock() - req.t_submit) * 1e3,
                                    engine=req.engine)

    # -- dispatcher ----------------------------------------------------------
    def _dispatch_loop(self) -> None:
        interval = self.fcfg.flush_interval_ms / 1e3
        while not self._stop.is_set():
            self._wake.wait(timeout=interval)
            self._wake.clear()
            self._tick()

    def _tick(self) -> None:
        with self._admit_lock:
            reqs, self._admit_q = self._admit_q, []
        try:
            if reqs:
                self._dispatch(reqs)
        except Exception as e:             # noqa: BLE001 — fail, don't lose
            self._abandon_batch(reqs, e)
        # maintenance faults must never kill the dispatcher (the serving
        # loop); they surface through metrics/state on the next pass
        for step in (self._monitor_health, self._degradation_tick,
                     self._schedule_maintenance):
            try:
                step()
            except Exception as e:         # noqa: BLE001 — keep serving
                self._last_maintenance_error = e

    def _dispatch(self, reqs: list[_FrontReq]) -> None:
        now = self.clock()
        ready = []
        for r in reqs:
            if r.deadline is not None and now > r.deadline:
                # never scored: shed pre-dispatch
                self._m_expired.inc(stage="dispatch")
                with _TR.span("frontend.shed", reason="deadline",
                              rid=r.rid):
                    pass
                self._complete(r, exc=DeadlineExceeded(
                    f"request {r.rid} expired "
                    f"{(now - r.deadline) * 1e3:.1f}ms before dispatch"))
            else:
                ready.append(r)
        if not ready:
            return
        live = [rep for rep in self.replicas if rep.state == LIVE]
        if not live:
            for r in ready:
                self._m_shed.inc(reason="unavailable")
                self._complete(r, exc=Unavailable("no live replica"))
            return
        target = min(live, key=lambda rep: rep.queue_depth())
        level = self._level
        with _TR.span("frontend.dispatch", replica=target.index,
                      n_requests=len(ready), level=level):
            target.call(partial(self._run_batch, reqs=ready, level=level),
                        label="batch",
                        abandon=partial(self._abandon_batch, ready))
        for rep in self.replicas:
            self._m_depth.set(rep.queue_depth(), replica=rep.index)
        self._m_inflight.set(self._inflight)

    def _abandon_batch(self, reqs: list[_FrontReq],
                       exc: BaseException) -> None:
        for r in reqs:
            self._complete(r, exc=exc)

    def _run_batch(self, svc: SearchService, reqs: list[_FrontReq],
                   level: int):
        """Worker-side batch execution (re-bindable to any replica)."""
        self._apply_level(svc, level)
        lvl = self.fcfg.ladder[level]
        now = self.clock()
        rids = []
        for r in reqs:
            if r.future.done():            # completed elsewhere (re-dispatch)
                continue
            if r.deadline is not None and now > r.deadline:
                self._m_expired.inc(stage="worker")
                self._complete(r, exc=DeadlineExceeded(
                    f"request {r.rid} expired in queue"))
                continue
            k_eff = max(1, int(math.floor(r.k * lvl.k_scale)))
            rids.append((r, svc.submit(r.queries, k_eff, r.engine)))
        if not rids:
            return 0
        done = svc.flush()
        for r, rid in rids:
            self._complete(r, result=done[rid])
        return len(rids)

    def _apply_level(self, svc: SearchService, level: int) -> None:
        """Set the ladder level's engine knobs on a worker-owned service
        (level 0 restores the exact configured baseline)."""
        if svc._fe_level == level:
            return
        lvl = self.fcfg.ladder[level]
        for name, (ef0, beam0) in svc._fe_base.items():
            eng = svc.engines[name]
            eng.ef_search = max(1, int(math.floor(ef0 * lvl.ef_scale)))
            eng.beam = max(1, int(math.floor(beam0 * lvl.beam_scale)))
        svc._fe_level = level

    # -- write path ----------------------------------------------------------
    def insert(self, fps) -> np.ndarray:
        """Append fingerprints: WAL-fsync first (durable front end), then
        fan to every live replica's queue in one locked step — identical
        apply order everywhere. Acked once durable and applied by at least
        one live replica; a replica that misses ``insert_timeout_s`` is
        marked wedged and failed over, not waited on forever."""
        if self._closed:
            raise RuntimeError("frontend is closed")
        fps = np.atleast_2d(np.asarray(fps, dtype=np.uint32))
        if fps.shape[1] != self.words:
            raise ValueError(f"row width {fps.shape[1]} != {self.words}")
        with _TR.span("frontend.insert", rows=int(fps.shape[0])):
            with self._insert_lock:
                live = [r for r in self.replicas if r.state == LIVE]
                if not live:
                    raise Unavailable("no live replica to apply the insert")
                first_gid = self._n_total
                if self._wal is not None and fps.shape[0]:
                    self._wal.append(first_gid, fps)
                futs = [(rep, rep.call(
                    partial(self._replica_insert, rows=fps,
                            expect_gid=first_gid), label="insert"))
                    for rep in live]
                self._n_total += int(fps.shape[0])
            gids = np.arange(first_gid, first_gid + fps.shape[0],
                             dtype=np.int64)
            applied = 0
            for rep, fut in futs:
                try:
                    got = fut.result(timeout=self.fcfg.insert_timeout_s)
                    if not np.array_equal(np.asarray(got), gids):
                        raise RuntimeError(
                            f"replica {rep.index} assigned {got}, "
                            f"expected {gids}")
                    applied += 1
                except ReplicaDead:
                    continue               # already failed over
                except TimeoutError:
                    self._fail_replica(rep, RuntimeError(
                        f"insert ack missed {self.fcfg.insert_timeout_s}s"))
                except Exception as e:     # noqa: BLE001 — divergence
                    self._fail_replica(rep, e)
            if applied == 0 and self._wal is None:
                raise Unavailable("insert applied by no replica and the "
                                  "front end is not durable")
        self._inserts_since_snap += int(fps.shape[0])
        self._m_inserts.inc(fps.shape[0])
        return gids

    @staticmethod
    def _replica_insert(svc: SearchService, rows: np.ndarray,
                        expect_gid: int) -> np.ndarray:
        """Idempotent worker-side apply (safe under re-dispatch)."""
        n = svc.n_total
        if expect_gid + rows.shape[0] <= n:
            return np.arange(expect_gid, expect_gid + rows.shape[0],
                             dtype=np.int64)
        if expect_gid != n:
            raise RuntimeError(f"replica at {n} rows cannot apply insert "
                               f"at gid {expect_gid} (gap)")
        return svc.insert(rows)

    # -- health + failover ---------------------------------------------------
    def _monitor_health(self) -> None:
        now = self.clock()
        for rep in list(self.replicas):
            if (rep.state == LIVE
                    and rep.busy_for(now) > self.fcfg.health_timeout_s):
                self._fail_replica(rep, RuntimeError(
                    f"wedged for {rep.busy_for(now):.1f}s"))
            elif rep.state == DEAD:
                self._note_dead(rep)
                if (self.fcfg.rehydrate
                        and rep.index not in self._rehydrating):
                    self._rehydrating.add(rep.index)
                    threading.Thread(
                        target=self._rehydrate_slot, args=(rep.index,),
                        daemon=True,
                        name=f"rehydrate-{rep.index}").start()

    def kill_replica(self, index: int) -> None:
        """Operational / test hook: declare replica ``index`` failed now."""
        self._fail_replica(self.replicas[index],
                           RuntimeError("killed by operator"))

    def _fail_replica(self, rep: Replica, error: BaseException) -> None:
        if rep.state != LIVE:
            return
        rep.mark_dead(error)
        self._note_dead(rep)

    def _note_dead(self, rep: Replica) -> None:
        if getattr(rep, "_fe_noted", False):
            return
        rep._fe_noted = True
        self._m_failovers.inc()
        self._m_replica_live.set(0, replica=rep.index)
        survivors = [r for r in self.replicas
                     if r is not rep and r.state == LIVE]
        for task in rep.drain():
            if task.label == "batch" and survivors:
                min(survivors, key=lambda r: r.queue_depth()).put(task)
            else:
                # inserts already fan to every replica; extraction /
                # compaction are retried by their schedulers
                task.fail(ReplicaDead(
                    f"replica {rep.index} died ({rep.error})"))
        self._wake.set()

    def _rehydrate_slot(self, index: int) -> None:
        """Failover: rebuild a dead slot from the latest published snapshot
        + WAL tail (durable) or a survivor's extracted state, then atomically
        attach it under the insert lock so it has missed nothing."""
        try:
            with _TR.span("replica.failover", replica=index):
                old = self.replicas[index]
                generation = old.generation + 1
                if self._wal is not None:
                    pin = self._wal.pin(0)     # freeze GC during catch-up
                    try:
                        step, arrays, meta = ckpt.load_latest_intact(
                            self._snap_dir)
                        if step is None:
                            raise IOError("no intact snapshot to rehydrate "
                                          "from")
                        svc = SearchService.from_state(arrays, deepcopy(meta))
                        from_seq = int(meta["wal_from_seq"])
                        # bulk catch-up without blocking writers, then a
                        # short locked pass for the final tail
                        self._wal.flush()
                        records, _ = wal_mod.replay(
                            self._wal_dir, from_seq=from_seq,
                            words=self.words, truncate=False)
                        svc.apply_wal_records(records)
                        with self._insert_lock:
                            self._wal.flush()
                            records, _ = wal_mod.replay(
                                self._wal_dir, from_seq=from_seq,
                                words=self.words, truncate=False)
                            svc.apply_wal_records(records)
                            self.replicas[index] = self._make_replica(
                                index, svc, generation)
                    finally:
                        self._wal.unpin(pin)
                else:
                    with self._insert_lock:
                        donor = next((r for r in self.replicas
                                      if r.state == LIVE), None)
                        if donor is None:
                            raise Unavailable("no donor replica")
                        arrays, meta = donor.call(
                            snap.service_state,
                            label="extract").result(timeout=60.0)
                        meta = dict(meta, words=self.words)
                        svc = SearchService.from_state(
                            {k: v.copy() for k, v in arrays.items()},
                            deepcopy(meta))
                        self.replicas[index] = self._make_replica(
                            index, svc, generation)
        finally:
            self._rehydrating.discard(index)
            self._wake.set()

    # -- degradation controller ----------------------------------------------
    def _degradation_tick(self) -> None:
        fcfg = self.fcfg
        if len(fcfg.ladder) < 2:
            return
        with self._admit_lock:
            depth_frac = self._inflight / max(fcfg.high_water, 1)
        shed_now = self._m_shed.total()
        shed_delta = shed_now - self._shed_seen
        self._shed_seen = shed_now
        if shed_delta > 0 or depth_frac >= fcfg.degrade_high:
            self._hot_ticks += 1
            self._cool_ticks = 0
        elif depth_frac <= fcfg.degrade_low:
            self._cool_ticks += 1
            self._hot_ticks = 0
        else:
            self._hot_ticks = 0
            self._cool_ticks = 0
        if (self._hot_ticks >= fcfg.degrade_ticks
                and self._level < len(fcfg.ladder) - 1):
            self._level += 1
            self._hot_ticks = 0
            self.max_level_engaged = max(self.max_level_engaged, self._level)
            self._m_shifts.inc(direction="down")
        elif self._cool_ticks >= fcfg.degrade_ticks and self._level > 0:
            self._level -= 1
            self._cool_ticks = 0
            self._m_shifts.inc(direction="up")
        self._m_level.set(self._level)

    @property
    def degradation_level(self) -> int:
        return self._level

    # -- maintenance scheduler ----------------------------------------------
    def _schedule_maintenance(self) -> None:
        # background snapshot cadence
        if (self._wal is not None and self.fcfg.snapshot_every_inserts
                and self._inserts_since_snap
                >= self.fcfg.snapshot_every_inserts
                and self._snap_lock.acquire(blocking=False)):
            self._inserts_since_snap = 0
            threading.Thread(target=self._snapshot_locked, daemon=True,
                             name="frontend-snapshot").start()
        # scheduled compaction, off the insert/ack path
        if self._compact_futs:
            if any(not f.done() for f in self._compact_futs):
                return
            self._compact_futs = []
        rep0 = next((r for r in self.replicas if r.state == LIVE), None)
        if rep0 is None:
            return
        delta = max((eng.store.n_delta
                     for eng in rep0.svc.engines.values()
                     if hasattr(eng, "store")), default=0)
        if delta >= max(self._compact_delta, 1):
            # enqueued under the insert lock so compaction lands at the same
            # queue position (relative to inserts) on every replica — states
            # stay byte-aligned, not just logically equal
            with self._insert_lock:
                self._compact_futs = [
                    rep.call(lambda svc: svc.compact_all(), label="compact")
                    for rep in self.replicas if rep.state == LIVE]

    def _snapshot_locked(self) -> None:
        try:
            self._snapshot_once()
        finally:
            self._snap_lock.release()

    def snapshot(self) -> int:
        """Write one snapshot generation now (synchronous; the scheduler
        path runs the same body on a background thread)."""
        with self._snap_lock:
            return self._snapshot_once()

    def _snapshot_once(self) -> int:
        if self._wal is None:
            raise RuntimeError("snapshot() requires a durable front end")
        floors = self._published_floors()
        with self._insert_lock:
            donor = next((r for r in self.replicas if r.state == LIVE), None)
            if donor is None:
                raise Unavailable("no live replica to extract from")
            # pin the *recovery* floor (oldest published snapshot), not the
            # mid-write rotate point: crash-before-publish recovery replays
            # from there and a concurrent GC must not outrun it
            pin = self._wal.pin(min(floors) if floors else 0)
            from_seq = self._wal.rotate()
            fut = donor.call(snap.service_state, label="extract")
        try:
            arrays, meta = fut.result(timeout=600.0)
            meta = dict(meta, wal_from_seq=int(from_seq),
                        words=int(self.words),
                        frontend_compact_delta=int(self._compact_delta))
            sid = self._snap_id + 1
            with _TR.span("snapshot.write", sid=sid):
                ckpt.save_array_snapshot(self._snap_dir, sid, arrays, meta,
                                         fs=self._fs, durable=True)
            self._snap_id = sid
            steps = ckpt.snapshot_steps(self._snap_dir)
            keep = max(self.config.snapshot_keep, 1)
            for s in steps[:-keep]:
                self._fs.rmtree(self._snap_dir / f"snap_{s:08d}")
            floors = self._published_floors()
            if floors:
                self._wal.gc_below(min(floors))   # pin-clamped
            return sid
        finally:
            self._wal.unpin(pin)

    def _published_floors(self) -> list[int]:
        floors = []
        for s in ckpt.snapshot_steps(self._snap_dir):
            try:
                floors.append(int(ckpt.read_snapshot_meta(
                    self._snap_dir, s)["wal_from_seq"]))
            except (IOError, KeyError, ValueError):
                continue
        return floors

    # -- introspection -------------------------------------------------------
    @property
    def n_total(self) -> int:
        return self._n_total

    @property
    def shed_count(self) -> int:
        return int(self.metrics.family("frontend_shed_total").total()
                   if self.metrics.enabled else 0)

    @property
    def expired_count(self) -> int:
        fam = self.metrics.family("frontend_deadline_expired_total")
        return int(fam.total()) if fam is not None else 0

    def live_replicas(self) -> int:
        return sum(1 for r in self.replicas if r.state == LIVE)

    def drain(self, timeout: float = 60.0) -> None:
        """Wait until every admitted request has completed (test/benchmark
        barrier; does not block new submissions)."""
        t0 = self.clock()
        while True:
            with self._admit_lock:
                if self._inflight == 0:
                    return
            if self.clock() - t0 > timeout:
                raise TimeoutError(f"{self._inflight} requests still in "
                                   f"flight after {timeout}s")
            self._wake.set()
            time.sleep(0.001)

    def replica_state(self, index: int, *, compact: bool = True,
                      timeout: float = 120.0):
        """Extract one replica's full service state through its worker (the
        byte-parity probe). ``compact=True`` folds the delta first so two
        replicas with different *maintenance* schedules but the same
        logical database extract identical bytes."""
        rep = self.replicas[index]

        def _extract(svc):
            if compact:
                svc.compact_all()
            return snap.service_state(svc)

        return rep.call(_extract, label="extract").result(timeout=timeout)

    def summary(self) -> dict:
        fam = self.metrics.family("frontend_request_latency_ms")
        p50 = fam.quantile(0.5) if fam is not None else None
        p99 = fam.quantile(0.99) if fam is not None else None
        n_done = fam.count() if fam is not None else 0
        return {
            "replicas": len(self.replicas),
            "replicas_live": self.live_replicas(),
            "n_completed": int(n_done),
            "n_total_rows": int(self._n_total),
            "shed": self.shed_count,
            "expired": self.expired_count,
            "failovers": int(self.metrics.family(
                "frontend_failovers_total").total()
                if self.metrics.enabled else 0),
            "degradation_level": self._level,
            "max_degradation_level": self.max_level_engaged,
            "p50_ms": round(float(p50), 3) if p50 is not None else None,
            "p99_ms": round(float(p99), 3) if p99 is not None else None,
        }

    def export_metrics(self, path, ts: float | None = None) -> int:
        """One JSONL export covering the front-end registry plus every
        replica's service registry (rows labeled ``replica=<i>``), with a
        Prometheus text twin at ``<path>.prom``."""
        import json
        rows = self.metrics.collect()
        for rep in self.replicas:
            for row in rep.svc.metrics.collect():
                row["labels"]["replica"] = str(rep.index)
                rows.append(row)
        if ts is not None:
            for r in rows:
                r["ts"] = ts
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        with open(path, "w") as f:
            for r in rows:
                f.write(json.dumps(r) + "\n")
        with open(str(path) + ".prom", "w") as f:
            f.write(self.metrics.render_prometheus())
            for rep in self.replicas:
                f.write(rep.svc.metrics.render_prometheus())
        return len(rows)

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Stop the dispatcher, drain workers, close the WAL. Idempotent."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self._wake.set()
        self._dispatcher.join(timeout=10.0)
        with self._admit_lock:
            reqs, self._admit_q = self._admit_q, []
        for r in reqs:
            self._complete(r, exc=Unavailable("frontend closed"))
        for rep in self.replicas:
            rep.stop()
        with self._snap_lock:
            pass                           # wait out an in-flight snapshot
        if self._wal is not None:
            self._wal.close()
            self._wal = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
