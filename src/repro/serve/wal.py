"""Append-only write-ahead log for the serving delta segment.

``SearchService.insert`` acks a batch only after its WAL record is fsync'd
(group commit optionally batches the fsyncs — see ``fsync_every``), so the
durability contract is *acked implies recovered*: any insert whose call
returned is replayed into a reopened service even if the process is
SIGKILLed the next instruction.

On-disk format (everything little-endian, one file per segment):

    wal_<seq:08d>.log := header record*
    header            := magic "FPWAL001" | u32 words-per-row
    record            := u32 crc32(payload) | u32 len(payload) | payload
    payload           := u64 first_gid | u32 n_rows | rows (n_rows*words u32)

``first_gid`` makes replay idempotent against the snapshot it starts from:
records entirely at gids below the restored ``n_total`` are skipped, the
first new record must start exactly at ``n_total`` (a gap means segments
were lost — refuse to serve rather than silently drop acked data).

Segments **rotate** on compaction and before every snapshot; a snapshot's
manifest stores the first segment sequence number still needed
(``wal_from_seq``) and everything below it is garbage-collected after the
snapshot publishes. A crash between rotate and publish only leaves an
extra (fully replayable) segment behind.

A crash mid-append leaves a **torn tail**: a record whose length/crc check
fails at the end of a segment. Replay truncates it — those bytes were never
fsync'd, so the insert was never acked. A record that fails its crc midway
through a segment (actual corruption, not a crash) raises instead.

**GC pinning (ISSUE 9).** ``gc_below(seq)`` trusts its caller's floor —
but with ``snapshot(background=True)`` the floor computed from *published*
snapshot manifests can race an in-flight writer: the new snapshot rotated
the WAL (claiming ``wal_from_seq = s``) but has not published yet, so a
concurrent GC that floors at a *later* published snapshot would delete the
very segments the in-flight snapshot still depends on for its crash
window (crash before publish -> recovery = previous snapshot + full WAL
from *its* floor). :meth:`pin` registers a hard floor before the writer
starts; :meth:`gc_below` clamps every request to the minimum pinned
sequence until :meth:`unpin`. The replica-rehydration path pins the same
way so a catching-up replica's segments cannot vanish mid-replay.

The writer handle is also internally locked: the concurrent front end
(``serve/frontend.py``) appends from its insert path while housekeeping
threads rotate/sync/GC, and interleaved raw file writes would corrupt
records.
"""
from __future__ import annotations

import os
import struct
import threading
import zlib
from pathlib import Path

import numpy as np

from ..checkpoint.fs import DEFAULT_FS, Fs
from ..obs.trace import TRACER as _TR

MAGIC = b"FPWAL001"
_HEADER = struct.Struct("<8sI")            # magic, words-per-row
_REC = struct.Struct("<II")                # crc32(payload), len(payload)
_PAYLOAD_HEAD = struct.Struct("<QI")       # first_gid, n_rows


def _segment_name(seq: int) -> str:
    return f"wal_{seq:08d}.log"


def segment_seqs(directory: str | os.PathLike) -> list[int]:
    base = Path(directory)
    if not base.exists():
        return []
    return sorted(int(p.name[4:-4]) for p in base.glob("wal_*.log"))


def _encode_record(first_gid: int, rows: np.ndarray) -> bytes:
    rows = np.ascontiguousarray(rows, dtype="<u4")
    payload = _PAYLOAD_HEAD.pack(int(first_gid), rows.shape[0]) + rows.tobytes()
    return _REC.pack(zlib.crc32(payload), len(payload)) + payload


class WalCorruption(IOError):
    """A record failed its crc/length check somewhere other than the
    truncatable tail of the final segment."""


class WriteAheadLog:
    """Writer handle. Always opens a *new* segment (``rotate`` semantics on
    open) — recovery never appends to a file that may hold a torn tail.

    ``fsync_every=1`` fsyncs each append before returning (the default,
    full acked-implies-recovered). ``fsync_every=N`` group-commits: fsync
    every N appends, trading an N-1 record ack window for throughput —
    measured by ``benchmarks/serve_load.py --wal``.
    """

    def __init__(self, directory: str | os.PathLike, words: int, *,
                 fs: Fs = DEFAULT_FS, fsync_every: int = 1):
        self.dir = Path(directory)
        self.words = int(words)
        self.fsync_every = max(int(fsync_every), 1)
        self._fs = fs
        self._f = None
        self._unsynced = 0
        self._lock = threading.RLock()
        self._pins: dict[int, int] = {}    # token -> pinned floor seq
        self._next_pin = 0
        fs.mkdir(self.dir)
        existing = segment_seqs(self.dir)
        self.seq = (existing[-1] + 1) if existing else 0
        self._open_segment()

    # -- write path ----------------------------------------------------------
    def _open_segment(self) -> None:
        path = self.dir / _segment_name(self.seq)
        self._f = self._fs.open(path, "wb")
        self._f.write(_HEADER.pack(MAGIC, self.words))
        self._fs.fsync(self._f)
        self._fs.fsync_dir(self.dir)
        self._unsynced = 0

    def append(self, first_gid: int, rows: np.ndarray) -> None:
        """Log one insert batch; returns after the record is durable
        (modulo the group-commit window)."""
        rows = np.atleast_2d(np.asarray(rows, dtype=np.uint32))
        if rows.shape[1] != self.words:
            raise ValueError(f"row width {rows.shape[1]} != WAL width "
                             f"{self.words}")
        with self._lock, _TR.span("wal.append", rows=int(rows.shape[0]),
                                  seq=int(self.seq)):
            self._f.write(_encode_record(first_gid, rows))
            self._unsynced += 1
            if self._unsynced >= self.fsync_every:
                self.sync()

    def sync(self) -> None:
        with self._lock:
            if self._f is not None and self._unsynced:
                with _TR.span("wal.fsync", records=int(self._unsynced),
                              seq=int(self.seq)):
                    self._fs.fsync(self._f)
                self._unsynced = 0

    def flush(self) -> None:
        """Flush user-space buffers so the on-disk tail is record-complete
        (no durability promise — that's :meth:`sync`). Replica catch-up
        reads the live segment through the filesystem, so it must not see
        half a record still sitting in the writer's buffer."""
        with self._lock:
            if self._f is not None:
                self._f.flush()

    def rotate(self) -> int:
        """Close the current segment and start the next; returns the new
        sequence number (the first one a snapshot taken now depends on)."""
        with self._lock:
            self.sync()
            self._f.close()
            self.seq += 1
            self._open_segment()
            return self.seq

    # -- GC + pinning --------------------------------------------------------
    def pin(self, seq: int) -> int:
        """Register a hard GC floor (an in-flight snapshot's ``from_seq`` or
        a rehydrating replica's replay start); returns a token for
        :meth:`unpin`. While any pin is held, :meth:`gc_below` clamps to the
        minimum pinned sequence."""
        with self._lock:
            token = self._next_pin
            self._next_pin += 1
            self._pins[token] = int(seq)
            return token

    def unpin(self, token: int) -> None:
        with self._lock:
            self._pins.pop(token, None)

    def gc_below(self, seq: int) -> None:
        """Remove segments no snapshot needs anymore, clamped to the lowest
        pinned floor — a *published*-snapshot floor computed while another
        snapshot is mid-write must not delete the in-flight writer's tail."""
        with self._lock:
            if self._pins:
                seq = min(seq, min(self._pins.values()))
            for s in segment_seqs(self.dir):
                if s < seq:
                    self._fs.remove(self.dir / _segment_name(s))

    def set_fs(self, fs: Fs) -> None:
        """Swap the fs layer (fault-injection harness); rotates so the open
        file handle goes through the new layer too."""
        with self._lock:
            self.sync()
            self._f.close()
            self._fs = fs
            self.seq += 1
            self._open_segment()

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self.sync()
                self._f.close()
                self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def _read_segment(path: Path, *, words: int | None,
                  is_last: bool, truncate: bool, fs: Fs):
    """Yield ``(first_gid, rows)`` records; handle the torn tail."""
    data = path.read_bytes()
    if len(data) < _HEADER.size:
        if not (is_last or truncate):
            raise WalCorruption(f"{path}: truncated header")
        if truncate and len(data) > 0:
            fs.truncate(path, 0)
        return
    magic, seg_words = _HEADER.unpack_from(data, 0)
    if magic != MAGIC:
        raise WalCorruption(f"{path}: bad magic {magic!r}")
    if words is not None and seg_words != words:
        raise WalCorruption(f"{path}: words {seg_words} != expected {words}")
    off = _HEADER.size
    records = []
    while off < len(data):
        good = True
        if off + _REC.size > len(data):
            good = False
        else:
            crc, plen = _REC.unpack_from(data, off)
            payload = data[off + _REC.size: off + _REC.size + plen]
            if len(payload) != plen or zlib.crc32(payload) != crc:
                good = False
        if not good:
            # Torn tail: legal where a crash can leave one (the segment that
            # was being appended to). Truncate to the valid prefix.
            if truncate:
                fs.truncate(path, off)
                break
            raise WalCorruption(f"{path}: bad record at offset {off}")
        first_gid, n_rows = _PAYLOAD_HEAD.unpack_from(payload, 0)
        rows = np.frombuffer(payload, dtype="<u4",
                             offset=_PAYLOAD_HEAD.size).astype(np.uint32)
        if rows.size != n_rows * seg_words:
            raise WalCorruption(f"{path}: payload size mismatch at {off}")
        records.append((first_gid, rows.reshape(n_rows, seg_words)))
        off += _REC.size + plen
    return records


def replay(directory: str | os.PathLike, *, from_seq: int = 0,
           words: int | None = None, truncate: bool = True,
           fs: Fs = DEFAULT_FS):
    """Read every record in segments >= ``from_seq`` in order.

    Returns ``(records, stats)`` where records is a list of
    ``(first_gid, rows)`` and stats counts segments/records/truncations.
    With ``truncate=True`` (recovery) torn tails are cut back to the last
    valid record boundary; with ``truncate=False`` (read-only audit) a torn
    tail raises :class:`WalCorruption`.
    """
    seqs = [s for s in segment_seqs(directory) if s >= from_seq]
    base = Path(directory)
    records: list[tuple[int, np.ndarray]] = []
    stats = {"segments": len(seqs), "records": 0, "truncated": 0}
    for s in seqs:
        path = base / _segment_name(s)
        size_before = path.stat().st_size
        recs = _read_segment(path, words=words, is_last=(s == seqs[-1]),
                             truncate=truncate, fs=fs) or []
        if truncate and path.exists() and path.stat().st_size < size_before:
            stats["truncated"] += 1
        records.extend(recs)
        stats["records"] += len(recs)
    return records, stats
