"""Online molecular-similarity search service (paper §V deployment shape).

The paper's host streams queries into fixed-interval pipelines and appends
new compounds without stalling the scan engines. :class:`SearchService` is
that host for the TPU engines:

* **request queue + dynamic micro-batcher** — :meth:`submit` enqueues
  requests (any per-request ``k`` / engine); :meth:`flush` groups pending
  requests by ``(engine, k)``, concatenates their queries and pads each
  chunk to a **power-of-two batch bucket** (zero queries, results dropped)
  so every flush replays one of O(log max_batch) compiled pipeline shapes —
  steady-state serving never recompiles.
* **engine router** — one service fronts any subset of the three engines
  (``brute`` / ``bitbound-folding`` / ``hnsw``) over the same logical
  database; requests pick their engine per call.
* **online inserts** — :meth:`insert` broadcasts new fingerprints to every
  engine (delta append + threshold-triggered LSM compaction in the store;
  incremental graph inserts for HNSW) and checks the engines agree on the
  assigned global ids. Search results at any interleaving are bit-identical
  to engines rebuilt from scratch on the concatenated database
  (``tests/test_insert_parity.py`` / ``tests/test_service.py``).
* **telemetry** — per-request latency (submit -> flush completion),
  p50/p99/QPS, batch-bucket histogram, per-engine scanned counters and
  compaction counts (:meth:`summary`).

The service is synchronous and deterministic by design (no threads): a
driver loop decides when to flush, which keeps parity tests and benchmark
replays exact. ``launch/search_serve.py --engine service`` and
``benchmarks/serve_load.py`` drive it with mixed insert+query workloads.
The store -> service -> engine request path is documented in
docs/ARCHITECTURE.md.
"""
from __future__ import annotations

import time
from collections import Counter
from dataclasses import dataclass

import numpy as np

from ..core.engine import (BitBoundFoldingEngine, BruteForceEngine,
                           HNSWEngine)
from .store import next_pow2

ENGINE_NAMES = ("brute", "bitbound-folding", "hnsw")


@dataclass
class _Request:
    rid: int
    queries: np.ndarray          # (n, W) uint32
    k: int
    engine: str
    t_submit: float


@dataclass
class ServiceConfig:
    """Engine-construction knobs shared by the service entry points."""
    backend: str | None = None
    k: int = 10
    max_batch: int = 256
    compact_threshold: int = 4096
    cutoff: float = 0.6
    fold_m: int = 4
    fold_scheme: int = 1
    hnsw_m: int = 8
    hnsw_ef_construction: int = 40
    hnsw_ef_search: int = 32
    hnsw_layout: str = "rows"    # "blocked" = neighbour-blocked expand stage
    hnsw_shards: int | None = None  # fan-out HNSW over N per-device shards
    seed: int = 0


class SearchService:
    """Request-queue front end over the online-insertable search engines."""

    #: completed-but-unredeemed results kept before FIFO eviction — bounds
    #: memory for drivers that consume flush() returns and never result()
    RESULT_BUFFER = 1024

    def __init__(self, db, engines=("bitbound-folding",),
                 config: ServiceConfig | None = None,
                 clock=time.perf_counter, **overrides):
        cfg = config or ServiceConfig(**overrides)
        if overrides and config is not None:
            raise ValueError("pass either config= or keyword overrides")
        self.config = cfg
        self.clock = clock
        db = np.atleast_2d(np.asarray(db, dtype=np.uint32))
        self.engines = {name: self._build_engine(name, db) for name in engines}
        self.default_engine = engines[0]
        self._pending: list[_Request] = []
        self._results: dict[int, tuple] = {}
        self._next_rid = 0
        self.reset_telemetry()

    def reset_telemetry(self) -> None:
        """Zero the telemetry counters (engines and their compile caches are
        untouched). Benchmarks call this between warmup and timed windows."""
        self.latencies_ms: list[float] = []
        self.batches: list[dict] = []
        self.scanned_total: Counter = Counter()
        self.n_queries = 0
        self.n_inserts = 0
        self.search_time = 0.0
        self.insert_time = 0.0

    def _build_engine(self, name: str, db: np.ndarray):
        cfg = self.config
        if name == "brute":
            # brute has no host reference path; map "numpy" to the jnp path
            be = cfg.backend if cfg.backend in ("jnp", "tpu") else None
            return BruteForceEngine(db, backend=be,
                                    compact_threshold=cfg.compact_threshold)
        if name == "bitbound-folding":
            return BitBoundFoldingEngine(
                db, cutoff=cfg.cutoff, m=cfg.fold_m, scheme=cfg.fold_scheme,
                backend=cfg.backend,
                compact_threshold=cfg.compact_threshold)
        if name == "hnsw":
            return HNSWEngine(db, m=cfg.hnsw_m,
                              ef_construction=cfg.hnsw_ef_construction,
                              ef_search=cfg.hnsw_ef_search, seed=cfg.seed,
                              backend=cfg.backend, layout=cfg.hnsw_layout,
                              shards=cfg.hnsw_shards)
        raise ValueError(
            f"unknown engine {name!r}; expected one of {ENGINE_NAMES}")

    # -- write path ---------------------------------------------------------
    def insert(self, fps) -> np.ndarray:
        """Append fingerprints online to every engine; returns the global
        ids (engines must agree — one logical database)."""
        t0 = self.clock()
        fps = np.atleast_2d(np.asarray(fps, dtype=np.uint32))
        gids = None
        for name, eng in self.engines.items():
            g = eng.insert(fps)
            if gids is None:
                gids = g
            elif not np.array_equal(g, gids):
                raise RuntimeError(
                    f"engine {name} assigned ids {g}, expected {gids}")
        self.n_inserts += fps.shape[0]
        self.insert_time += self.clock() - t0
        return gids

    # -- read path ----------------------------------------------------------
    def submit(self, queries, k: int | None = None,
               engine: str | None = None) -> int:
        """Enqueue a search request (single query row or a (n, W) batch);
        returns a request id redeemed by :meth:`flush` / :meth:`result`."""
        engine = engine or self.default_engine
        if engine not in self.engines:
            raise ValueError(f"engine {engine!r} not served "
                             f"(have {tuple(self.engines)})")
        queries = np.atleast_2d(np.asarray(queries, dtype=np.uint32))
        req = _Request(self._next_rid, queries, int(k or self.config.k),
                       engine, self.clock())
        self._pending.append(req)
        self._next_rid += 1
        return req.rid

    def flush(self) -> dict[int, tuple]:
        """Run every pending request through its engine, micro-batched by
        (engine, k) and padded to power-of-two batch buckets. Returns
        {rid: (ids, sims)} for the requests completed by this flush."""
        pending, self._pending = self._pending, []
        done: dict[int, tuple] = {}
        groups: dict[tuple, list[_Request]] = {}
        for r in pending:
            groups.setdefault((r.engine, r.k), []).append(r)
        for (ename, k), reqs in groups.items():
            eng = self.engines[ename]
            qs = np.concatenate([r.queries for r in reqs])
            n, w = qs.shape
            ids_parts, sims_parts = [], []
            t0 = self.clock()
            off = 0
            while off < n:
                chunk = qs[off:off + self.config.max_batch]
                bucket = next_pow2(chunk.shape[0])
                padded = np.zeros((bucket, w), dtype=np.uint32)
                padded[:chunk.shape[0]] = chunk
                ids, sims = eng.search(padded, k)
                ids_parts.append(np.asarray(ids)[:chunk.shape[0]])
                sims_parts.append(np.asarray(sims)[:chunk.shape[0]])
                self.batches.append({"engine": ename, "k": k,
                                     "bucket": int(bucket),
                                     "n": int(chunk.shape[0])})
                self.scanned_total[ename] += eng.scanned(bucket)
                off += chunk.shape[0]
            self.search_time += self.clock() - t0
            ids = np.concatenate(ids_parts)
            sims = np.concatenate(sims_parts)
            t_done = self.clock()
            off = 0
            for r in reqs:
                nr = r.queries.shape[0]
                done[r.rid] = (ids[off:off + nr], sims[off:off + nr])
                off += nr
                self.latencies_ms.append((t_done - r.t_submit) * 1e3)
                self.n_queries += nr
        self._results.update(done)
        # FIFO-evict beyond the buffer bound: callers that consume flush()'s
        # return and never result() must not leak arrays forever
        while len(self._results) > self.RESULT_BUFFER:
            self._results.pop(next(iter(self._results)))
        return done

    def result(self, rid: int):
        """Redeem a completed request (pops it from the result buffer).
        Raises ``KeyError`` for unknown rids, including results evicted past
        :attr:`RESULT_BUFFER` unredeemed completions."""
        return self._results.pop(rid)

    def search(self, queries, k: int | None = None,
               engine: str | None = None):
        """Convenience synchronous path: submit + flush + redeem."""
        rid = self.submit(queries, k, engine)
        self.flush()
        return self._results.pop(rid)

    def compact_all(self) -> None:
        """Force-compact every store-backed engine's delta (operational
        hook: benchmarks use it to pin the delta phase before a measurement
        window; a deployment would call it off-peak)."""
        for eng in self.engines.values():
            store = getattr(eng, "store", None)
            if store is not None and store.n_delta:
                store.compact()

    # -- telemetry ----------------------------------------------------------
    @property
    def compactions(self) -> int:
        return sum(eng.store.compactions for eng in self.engines.values()
                   if hasattr(eng, "store"))

    def compiled_pipelines(self) -> int:
        """Total compiled-executable count across engine pipeline caches —
        flat in steady state (the no-recompile acceptance criterion)."""
        total = 0
        for eng in self.engines.values():
            for fn in eng._jit_cache.values():
                size = getattr(fn, "_cache_size", None)
                total += int(size()) if callable(size) else 1
        return total

    def summary(self) -> dict:
        lat = np.asarray(self.latencies_ms, dtype=np.float64)
        out = {
            "engines": {n: e.backend for n, e in self.engines.items()},
            "n_queries": int(self.n_queries),
            "n_inserts": int(self.n_inserts),
            "compactions": int(self.compactions),
            "search_time_s": round(self.search_time, 4),
            "insert_time_s": round(self.insert_time, 4),
            "qps": round(self.n_queries / self.search_time, 1)
            if self.search_time > 0 else 0.0,
            "batch_buckets": dict(Counter(b["bucket"] for b in self.batches)),
            "scanned": {k: int(v) for k, v in self.scanned_total.items()},
        }
        if lat.size:
            out.update(
                p50_ms=round(float(np.percentile(lat, 50)), 3),
                p99_ms=round(float(np.percentile(lat, 99)), 3),
                mean_ms=round(float(lat.mean()), 3))
        return out
