"""Online molecular-similarity search service (paper §V deployment shape).

The paper's host streams queries into fixed-interval pipelines and appends
new compounds without stalling the scan engines. :class:`SearchService` is
that host for the TPU engines:

* **request queue + dynamic micro-batcher** — :meth:`submit` enqueues
  requests (any per-request ``k`` / engine); :meth:`flush` groups pending
  requests by ``(engine, k)``, concatenates their queries and pads each
  chunk to a **power-of-two batch bucket** (zero queries, results dropped)
  so every flush replays one of O(log max_batch) compiled pipeline shapes —
  steady-state serving never recompiles.
* **engine router** — one service fronts any subset of the three engines
  (``brute`` / ``bitbound-folding`` / ``hnsw``) over the same logical
  database; requests pick their engine per call.
* **online inserts** — :meth:`insert` broadcasts new fingerprints to every
  engine (delta append + threshold-triggered LSM compaction in the store;
  incremental graph inserts for HNSW) and checks the engines agree on the
  assigned global ids. Search results at any interleaving are bit-identical
  to engines rebuilt from scratch on the concatenated database
  (``tests/test_insert_parity.py`` / ``tests/test_service.py``).
* **telemetry** — per-request latency (submit -> flush completion),
  p50/p99/QPS, batch-bucket histogram, per-engine scanned counters and
  compaction counts (:meth:`summary`). Since ISSUE 8 the backing store is a
  :class:`repro.obs.metrics.MetricsRegistry` (:attr:`metrics`) — bounded
  log-bucketed latency histograms and labeled counters/gauges with
  Prometheus/JSONL exposition — plus structured trace spans through
  ``repro.obs.trace.TRACER`` (queue wait, batch formation, per-engine
  search, WAL append, snapshot writes; Chrome trace-event export for
  Perfetto). ``latencies_ms`` / ``batches`` remain as *bounded* recent
  windows (``TELEMETRY_WINDOW``) so sustained load cannot grow host memory;
  ``summary()`` keys are unchanged and always present (``None`` percentiles
  on a write-only run).

The service is synchronous and deterministic by design (no threads): a
driver loop decides when to flush, which keeps parity tests and benchmark
replays exact. ``launch/search_serve.py --engine service`` and
``benchmarks/serve_load.py`` drive it with mixed insert+query workloads.
The store -> service -> engine request path is documented in
docs/ARCHITECTURE.md.
"""
from __future__ import annotations

import threading
import time
from collections import Counter, deque
from dataclasses import dataclass
from pathlib import Path

import numpy as np

from ..checkpoint import manager as ckpt
from ..checkpoint.fs import DEFAULT_FS, Fs
from ..core.engine import (BitBoundFoldingEngine, BruteForceEngine,
                           HNSWEngine)
from ..core.fingerprints import resolve_metric
from ..obs.metrics import MetricsRegistry, NULL_METRICS
from ..obs.trace import TRACER as _TR
from . import snapshot as snap
from . import wal as wal_mod
from .store import next_pow2

ENGINE_NAMES = ("brute", "bitbound-folding", "hnsw")


@dataclass
class _Request:
    rid: int
    queries: np.ndarray          # (n, W) uint32
    k: int
    engine: str
    t_submit: float


@dataclass
class ServiceConfig:
    """Engine-construction knobs shared by the service entry points."""
    backend: str | None = None
    metric: str = "tanimoto"     # similarity spec: "tanimoto" | "dice" |
    #   "cosine" | "tversky(a,b)" — every engine scores, prunes and builds
    #   graphs under this metric (core/fingerprints.Metric)
    fp_bits: int | None = None   # fingerprint width in bits; None = infer
    #   from the database rows (words * 32)
    k: int = 10
    max_batch: int = 256
    compact_threshold: int = 4096
    cutoff: float = 0.6
    fold_m: int = 4
    fold_scheme: int = 1
    hnsw_m: int = 8
    hnsw_ef_construction: int = 40
    hnsw_ef_search: int = 32
    hnsw_layout: str = "rows"    # "blocked" = neighbour-blocked expand stage
    hnsw_shards: int | None = None  # fan-out HNSW over N per-device shards
    residency: str = "device"    # "tiered" = host-resident full rows,
    #   double-buffered host->HBM streaming rescore (store-backed engines)
    tier_chunk_rows: int | None = None  # brute tiered: rows per streamed
    #   chunk (None = engine default); small values force multi-chunk
    #   streams for tests / trace captures
    tier_chunk: int | None = None       # bitbound tiered: candidate columns
    #   per streamed rescore chunk (None = engine default)
    seed: int = 0
    # --- observability (ISSUE 8; docs/ARCHITECTURE.md §Observability) ---
    metrics: bool = True         # False = NULL_METRICS no-op registry (the
    #   serve_load overhead A/B baseline); summary() falls back to the
    #   bounded recent-window deque for percentiles
    # --- durability (ISSUE 6; docs/ARCHITECTURE.md §On-disk format) ---
    durable_dir: str | None = None  # snapshots/ + wal/ live here; None = RAM
    wal_fsync_every: int = 1     # 1 = fsync per ack; N = group commit (the
    #   last N-1 acked inserts may be lost on crash — benchmark axis only)
    snapshot_keep: int = 2       # retained snapshot generations (walk-back)


class SearchService:
    """Request-queue front end over the online-insertable search engines."""

    #: completed-but-unredeemed results kept before FIFO eviction — bounds
    #: memory for drivers that consume flush() returns and never result()
    RESULT_BUFFER = 1024

    #: recent-window bound for the legacy ``latencies_ms`` / ``batches``
    #: telemetry views — under sustained load they are rolling windows, not
    #: append-only lists; full-run aggregates live in :attr:`metrics`
    TELEMETRY_WINDOW = 4096

    def __init__(self, db, engines=("bitbound-folding",),
                 config: ServiceConfig | None = None,
                 clock=time.perf_counter, fs: Fs | None = None, **overrides):
        cfg = config or ServiceConfig(**overrides)
        if overrides and config is not None:
            raise ValueError("pass either config= or keyword overrides")
        self.config = cfg
        self.clock = clock
        db = np.atleast_2d(np.asarray(db, dtype=np.uint32))
        self.words = int(db.shape[1])
        self.engines = {name: self._build_engine(name, db) for name in engines}
        self.default_engine = engines[0]
        self._pending: list[_Request] = []
        self._results: dict[int, tuple] = {}
        self._next_rid = 0
        self._fs = fs or DEFAULT_FS
        self._wal = None
        self._snap_id = -1
        self._snap_thread = None
        self._snap_error = None
        self._lifecycle_lock = threading.RLock()
        self._closed = False
        self.reset_telemetry()
        if cfg.durable_dir is not None:
            self._attach_durable_dir(fresh=True)

    def reset_telemetry(self) -> None:
        """Zero the telemetry counters and the metrics registry (engines and
        their compile caches are untouched). Benchmarks call this between
        warmup and timed windows."""
        if not hasattr(self, "metrics"):
            self._init_metrics()
        self.metrics.reset()
        # bounded recent windows (back-compat views; see TELEMETRY_WINDOW)
        self.latencies_ms: deque = deque(maxlen=self.TELEMETRY_WINDOW)
        self.batches: deque = deque(maxlen=self.TELEMETRY_WINDOW)
        self._batch_buckets: Counter = Counter()   # full-run, O(log batch)
        self.scanned_total: Counter = Counter()
        self.n_queries = 0
        self.n_inserts = 0
        self.search_time = 0.0
        self.insert_time = 0.0

    def _init_metrics(self) -> None:
        """Declare the service metric families (ISSUE 8). Families are
        stable across :meth:`reset_telemetry`; only the values reset.
        ``ServiceConfig.metrics=False`` swaps in the no-op registry."""
        self.metrics = (MetricsRegistry() if self.config.metrics
                        else NULL_METRICS)
        m = self.metrics
        self._m_queries = m.counter(
            "service_queries_total", "queries completed", labels=("engine",))
        self._m_inserts = m.counter(
            "service_inserts_total", "fingerprint rows inserted")
        self._m_scanned = m.counter(
            "service_scanned_total", "candidates scored", labels=("engine",))
        self._m_batches = m.counter(
            "service_batches_total", "engine flush batches",
            labels=("engine", "bucket"))
        self._m_req_lat = m.histogram(
            "service_request_latency_ms", "submit -> flush completion",
            labels=("engine",))
        self._m_queue_wait = m.histogram(
            "service_queue_wait_ms", "submit -> batch formation",
            labels=("engine",))
        self._m_batch_ms = m.histogram(
            "service_engine_batch_ms", "one (engine, k) flush group",
            labels=("engine",))
        self._m_insert_ms = m.histogram(
            "service_insert_ms", "insert broadcast incl. WAL")
        self._m_wal_ms = m.histogram(
            "service_wal_append_ms", "WAL append+fsync before ack")
        self._m_compactions = m.gauge(
            "service_compactions", "store compactions to date")
        self._m_tier_stall = m.gauge(
            "service_tiered_stall_seconds",
            "double-buffer stall in the last tiered search",
            labels=("engine",))
        self._m_tier_chunks = m.gauge(
            "service_tiered_chunks",
            "chunks streamed in the last tiered search", labels=("engine",))
        self._m_tier_stall_frac = m.gauge(
            "service_tiered_stall_fraction",
            "stall fraction of the last tiered search", labels=("engine",))

    def _engine_kwargs(self, name: str) -> dict:
        """ServiceConfig -> engine constructor knobs (shared by fresh builds
        and snapshot restores, which pass data separately)."""
        cfg = self.config
        if name == "brute":
            # brute has no host reference path; map "numpy" to the jnp path
            be = cfg.backend if cfg.backend in ("jnp", "tpu") else None
            kw = dict(backend=be, compact_threshold=cfg.compact_threshold,
                      residency=cfg.residency, metric=cfg.metric,
                      fp_bits=cfg.fp_bits)
            if cfg.tier_chunk_rows is not None:
                kw["tier_chunk_rows"] = cfg.tier_chunk_rows
            return kw
        if name == "bitbound-folding":
            kw = dict(cutoff=cfg.cutoff, m=cfg.fold_m,
                      scheme=cfg.fold_scheme, backend=cfg.backend,
                      compact_threshold=cfg.compact_threshold,
                      residency=cfg.residency, metric=cfg.metric,
                      fp_bits=cfg.fp_bits)
            if cfg.tier_chunk is not None:
                kw["tier_chunk"] = cfg.tier_chunk
            return kw
        if name == "hnsw":
            return dict(m=cfg.hnsw_m,
                        ef_construction=cfg.hnsw_ef_construction,
                        ef_search=cfg.hnsw_ef_search, seed=cfg.seed,
                        backend=cfg.backend, layout=cfg.hnsw_layout,
                        shards=cfg.hnsw_shards, metric=cfg.metric,
                        fp_bits=cfg.fp_bits)
        raise ValueError(
            f"unknown engine {name!r}; expected one of {ENGINE_NAMES}")

    def _build_engine(self, name: str, db: np.ndarray):
        kind = {"brute": BruteForceEngine,
                "bitbound-folding": BitBoundFoldingEngine,
                "hnsw": HNSWEngine}.get(name)
        if kind is None:
            raise ValueError(
                f"unknown engine {name!r}; expected one of {ENGINE_NAMES}")
        return kind(db, **self._engine_kwargs(name))

    # -- write path ---------------------------------------------------------
    def _apply_insert(self, fps: np.ndarray) -> np.ndarray:
        """Apply one insert batch to every engine (no WAL, no telemetry) —
        the shared path under :meth:`insert` and WAL replay."""
        gids = None
        for name, eng in self.engines.items():
            g = eng.insert(fps)
            if gids is None:
                gids = g
            elif not np.array_equal(g, gids):
                raise RuntimeError(
                    f"engine {name} assigned ids {g}, expected {gids}")
        return gids

    def insert(self, fps) -> np.ndarray:
        """Append fingerprints online to every engine; returns the global
        ids (engines must agree — one logical database). On a durable
        service the batch is WAL-logged and fsync'd **before** it is
        applied, so a return from this method means the insert survives any
        subsequent crash (modulo an explicit group-commit window)."""
        t0 = self.clock()
        fps = np.atleast_2d(np.asarray(fps, dtype=np.uint32))
        comp0 = self.compactions
        with _TR.span("service.insert", rows=int(fps.shape[0])):
            if self._wal is not None and fps.shape[0]:
                first_gid = next(iter(self.engines.values())).n_total
                tw = self.clock()
                self._wal.append(first_gid, fps)
                self._m_wal_ms.observe((self.clock() - tw) * 1e3)
            gids = self._apply_insert(fps)
            if self._wal is not None and self.compactions != comp0:
                self._wal.rotate()     # segment rotation on compaction
        self.n_inserts += fps.shape[0]
        self._m_inserts.inc(fps.shape[0])
        self._m_compactions.set(self.compactions)
        dt = self.clock() - t0
        self.insert_time += dt
        self._m_insert_ms.observe(dt * 1e3)
        return gids

    # -- read path ----------------------------------------------------------
    def submit(self, queries, k: int | None = None,
               engine: str | None = None) -> int:
        """Enqueue a search request (single query row or a (n, W) batch);
        returns a request id redeemed by :meth:`flush` / :meth:`result`."""
        engine = engine or self.default_engine
        if engine not in self.engines:
            raise ValueError(f"engine {engine!r} not served "
                             f"(have {tuple(self.engines)})")
        queries = np.atleast_2d(np.asarray(queries, dtype=np.uint32))
        req = _Request(self._next_rid, queries, int(k or self.config.k),
                       engine, self.clock())
        self._pending.append(req)
        self._next_rid += 1
        return req.rid

    def flush(self) -> dict[int, tuple]:
        """Run every pending request through its engine, micro-batched by
        (engine, k) and padded to power-of-two batch buckets. Returns
        {rid: (ids, sims)} for the requests completed by this flush."""
        pending, self._pending = self._pending, []
        done: dict[int, tuple] = {}
        groups: dict[tuple, list[_Request]] = {}
        for r in pending:
            groups.setdefault((r.engine, r.k), []).append(r)
        # queue-wait spans use the service clock; only a real wall clock
        # shares a timeline with the tracer's perf_counter epoch
        real_clock = self.clock is time.perf_counter
        for (ename, k), reqs in groups.items():
            eng = self.engines[ename]
            qs = np.concatenate([r.queries for r in reqs])
            n, w = qs.shape
            ids_parts, sims_parts = [], []
            t0 = self.clock()
            for r in reqs:
                self._m_queue_wait.observe((t0 - r.t_submit) * 1e3,
                                           engine=ename)
                if _TR.enabled and real_clock:
                    _TR.emit("service.queue_wait", r.t_submit, t0,
                             track="queue", rid=r.rid, engine=ename)
            with _TR.span("service.batch", engine=ename, k=int(k),
                          n_queries=int(n), n_requests=len(reqs)):
                off = 0
                while off < n:
                    chunk = qs[off:off + self.config.max_batch]
                    bucket = next_pow2(chunk.shape[0])
                    padded = np.zeros((bucket, w), dtype=np.uint32)
                    padded[:chunk.shape[0]] = chunk
                    with _TR.span("service.engine_search", engine=ename,
                                  bucket=int(bucket)):
                        ids, sims = eng.search(padded, k)
                    ids_parts.append(np.asarray(ids)[:chunk.shape[0]])
                    sims_parts.append(np.asarray(sims)[:chunk.shape[0]])
                    self.batches.append({"engine": ename, "k": k,
                                         "bucket": int(bucket),
                                         "n": int(chunk.shape[0])})
                    self._batch_buckets[int(bucket)] += 1
                    self._m_batches.inc(engine=ename, bucket=int(bucket))
                    sc = eng.scanned(bucket)
                    self.scanned_total[ename] += sc
                    self._m_scanned.inc(sc, engine=ename)
                    self._fold_engine_stats(ename, eng)
                    off += chunk.shape[0]
            dt = self.clock() - t0
            self.search_time += dt
            self._m_batch_ms.observe(dt * 1e3, engine=ename)
            ids = np.concatenate(ids_parts)
            sims = np.concatenate(sims_parts)
            t_done = self.clock()
            off = 0
            for r in reqs:
                nr = r.queries.shape[0]
                done[r.rid] = (ids[off:off + nr], sims[off:off + nr])
                off += nr
                self.latencies_ms.append((t_done - r.t_submit) * 1e3)
                self._m_req_lat.observe((t_done - r.t_submit) * 1e3,
                                        engine=ename)
                self.n_queries += nr
                self._m_queries.inc(nr, engine=ename)
        self._results.update(done)
        # FIFO-evict beyond the buffer bound: callers that consume flush()'s
        # return and never result() must not leak arrays forever
        while len(self._results) > self.RESULT_BUFFER:
            self._results.pop(next(iter(self._results)))
        return done

    def result(self, rid: int):
        """Redeem a completed request (pops it from the result buffer).
        Raises ``KeyError`` for unknown rids, including results evicted past
        :attr:`RESULT_BUFFER` unredeemed completions."""
        return self._results.pop(rid)

    def search(self, queries, k: int | None = None,
               engine: str | None = None):
        """Convenience synchronous path: submit + flush + redeem."""
        rid = self.submit(queries, k, engine)
        self.flush()
        return self._results.pop(rid)

    def compact_all(self) -> None:
        """Force-compact every store-backed engine's delta (operational
        hook: benchmarks use it to pin the delta phase before a measurement
        window; a deployment would call it off-peak)."""
        comp0 = self.compactions
        for eng in self.engines.values():
            store = getattr(eng, "store", None)
            if store is not None and store.n_delta:
                store.compact()
        if self._wal is not None and self.compactions != comp0:
            self._wal.rotate()

    # -- durability (ISSUE 6) ------------------------------------------------
    def _attach_durable_dir(self, fresh: bool) -> None:
        base = Path(self.config.durable_dir)
        self._snap_dir = base / "snapshots"
        self._wal_dir = base / "wal"
        if fresh and (ckpt.snapshot_steps(self._snap_dir)
                      or wal_mod.segment_seqs(self._wal_dir)):
            raise ValueError(
                f"{base} already holds durable state; use "
                f"SearchService.open() to warm-restart from it")
        self._wal = wal_mod.WriteAheadLog(
            self._wal_dir, self.words, fs=self._fs,
            fsync_every=self.config.wal_fsync_every)
        if fresh:
            self.snapshot()    # base DB is recoverable before any insert

    def snapshot(self, *, background: bool = False) -> int:
        """Write a full-state snapshot generation; rotates the WAL first so
        the snapshot's ``wal_from_seq`` covers exactly the records after it,
        then garbage-collects segments no retained snapshot needs. Crash
        windows: before the atomic publish the old snapshot + full WAL
        recover everything; after it the GC'd segments are redundant.

        ``background=True`` moves the serialization + fsync work off the
        serving thread: the state is **extracted synchronously** as
        copy-on-write numpy arrays (extraction is a copy — the writer never
        aliases live store/graph arrays, so inserts keep acking while the
        snapshot is in flight), then a daemon thread saves, prunes and
        WAL-GCs. At most one writer is in flight; a second ``snapshot()``
        (or :meth:`close`) joins the previous one first, and any writer
        exception is re-raised at the next :meth:`snapshot_join` /
        :meth:`snapshot` / :meth:`close`.

        The current **recovery floor** — the oldest *published* snapshot's
        ``wal_from_seq``, i.e. what a crash-before-publish recovery still
        replays from — is **pinned** in the WAL before the writer starts
        (ISSUE 9): any concurrent ``gc_below``, even one erroneously
        flooring at this snapshot's mid-write rotate point, is clamped
        above it until the writer publishes."""
        with self._lifecycle_lock:
            if self._closed:
                raise RuntimeError("snapshot() on a closed service")
            if self._wal is None:
                raise RuntimeError("snapshot() requires durable_dir")
            self.snapshot_join()
            sid = self._snap_id + 1
            pin = self._wal.pin(self._recovery_floor())
            from_seq = self._wal.rotate()
            arrays, meta = snap.service_state(self)
            meta["wal_from_seq"] = int(from_seq)
            meta["words"] = int(self.words)
            if background:
                t = threading.Thread(target=self._snapshot_worker,
                                     args=(sid, arrays, meta, pin),
                                     name=f"snapshot-{sid}", daemon=True)
                self._snap_thread = t
                t.start()
                return sid
        try:
            self._write_snapshot(sid, arrays, meta)
        finally:
            self._wal.unpin(pin)
        return sid

    def _recovery_floor(self) -> int:
        """Lowest ``wal_from_seq`` across published snapshot generations —
        the first WAL segment a walk-back recovery can still need. 0 when
        no generation has published yet (everything is needed)."""
        floors = []
        for s in ckpt.snapshot_steps(self._snap_dir):
            try:
                floors.append(int(ckpt.read_snapshot_meta(
                    self._snap_dir, s)["wal_from_seq"]))
            except (IOError, KeyError, ValueError):
                continue
        return min(floors) if floors else 0

    def _write_snapshot(self, sid: int, arrays, meta) -> None:
        """Persist one extracted snapshot + retention prune + WAL GC (the
        serialization half of :meth:`snapshot`; runs on the serving thread
        or the background writer — the trace span's tid shows which)."""
        with _TR.span("snapshot.write", sid=int(sid)):
            ckpt.save_array_snapshot(self._snap_dir, sid, arrays, meta,
                                     fs=self._fs, durable=True)
        self._snap_id = sid
        steps = ckpt.snapshot_steps(self._snap_dir)
        for s in steps[:-max(self.config.snapshot_keep, 1)]:
            self._fs.rmtree(self._snap_dir / f"snap_{s:08d}")
        # WAL GC floor: the oldest *retained* snapshot's from_seq (walk-back
        # restores must still find their records). A concurrent in-flight
        # snapshot's rotate point is protected by its WAL pin.
        floors = []
        for s in ckpt.snapshot_steps(self._snap_dir):
            try:
                floors.append(int(ckpt.read_snapshot_meta(
                    self._snap_dir, s)["wal_from_seq"]))
            except (IOError, KeyError, ValueError):
                continue
        if floors:
            self._wal.gc_below(min(floors))

    def _snapshot_worker(self, sid: int, arrays, meta, pin: int) -> None:
        try:
            self._write_snapshot(sid, arrays, meta)
        except BaseException as e:   # surfaced at the next join point
            self._snap_error = e
        finally:
            wal = self._wal
            if wal is not None:
                wal.unpin(pin)

    def snapshot_join(self) -> None:
        """Wait for an in-flight background snapshot (no-op otherwise) and
        re-raise any exception its writer hit."""
        t = self._snap_thread
        if t is not None:
            t.join()
            self._snap_thread = None
        if self._snap_error is not None:
            e, self._snap_error = self._snap_error, None
            raise e

    @classmethod
    def from_state(cls, arrays, meta, *, clock=time.perf_counter,
                   fs: Fs | None = None, **overrides) -> "SearchService":
        """Hydrate a service from an extracted ``(arrays, meta)`` snapshot
        state (no durable attachment — ``_wal`` stays None). This is the
        shared hydration body under :meth:`open` and the concurrent front
        end's replica construction/rehydration (``serve/replica.py``): a
        read replica is exactly a service built this way plus a replayed
        WAL tail it does not own."""
        cfg = ServiceConfig(**{**meta["config"], **overrides})
        snap_metric = resolve_metric(meta["config"].get("metric", "tanimoto"))
        want_metric = resolve_metric(cfg.metric)
        if want_metric.spec != snap_metric.spec:
            raise ValueError(
                f"snapshot was taken under metric {snap_metric.spec!r}; "
                f"refusing to serve it as {want_metric.spec!r} — scores, "
                f"BitBound windows and HNSW graphs are metric-specific; "
                f"rebuild the index under the new metric instead")
        svc = cls.__new__(cls)
        svc.config = cfg
        svc.clock = clock
        svc.words = int(meta["words"])
        svc._fs = fs or DEFAULT_FS
        svc.engines = {}
        for name in meta["engines"]:
            svc.engines[name] = snap.engine_from_state(
                snap.split_engine_arrays(arrays, name),
                meta["engine_state"][name], **svc._engine_kwargs(name))
        svc.default_engine = meta["default_engine"]
        svc._pending = []
        svc._results = {}
        svc._next_rid = 0
        svc._wal = None
        svc._snap_id = -1
        svc._snap_thread = None
        svc._snap_error = None
        svc._lifecycle_lock = threading.RLock()
        svc._closed = False
        svc.reset_telemetry()
        return svc

    def apply_wal_records(self, records) -> int:
        """Replay ``(first_gid, rows)`` WAL records into every engine,
        skipping those already folded in (idempotent); a gid gap means lost
        segments — refuse to serve rather than drop acked data. Returns the
        number of rows applied."""
        applied = 0
        for first_gid, rows in records:
            n_now = next(iter(self.engines.values())).n_total
            if first_gid + rows.shape[0] <= n_now:
                continue
            if first_gid != n_now:
                raise IOError(f"WAL gap: record at gid {first_gid}, "
                              f"index at {n_now}")
            self._apply_insert(rows)
            applied += int(rows.shape[0])
        return applied

    @classmethod
    def open(cls, directory, *, clock=time.perf_counter,
             fs: Fs | None = None, **overrides) -> "SearchService":
        """Warm-restart a replica from a durable directory: load the latest
        intact snapshot (walking back over corrupt/partial generations),
        hydrate every engine bit-identically — sharded HNSW graphs are
        re-committed to their devices — then replay the WAL tail and reopen
        the log. ``overrides`` patch the persisted ServiceConfig (serving
        knobs like backend; data-shape knobs must match the snapshot)."""
        fs = fs or DEFAULT_FS
        base = Path(directory)
        step, arrays, meta = ckpt.load_latest_intact(base / "snapshots")
        if step is None:
            raise FileNotFoundError(f"no intact snapshot under {base}")
        svc = cls.from_state(arrays, meta, clock=clock, fs=fs, **overrides)
        svc.config.durable_dir = str(base)
        svc._snap_id = step
        svc._snap_dir = base / "snapshots"
        svc._wal_dir = base / "wal"
        # replay acknowledged inserts logged after the snapshot
        records, _ = wal_mod.replay(svc._wal_dir,
                                    from_seq=int(meta["wal_from_seq"]),
                                    words=svc.words, truncate=True, fs=fs)
        svc.apply_wal_records(records)
        svc._wal = wal_mod.WriteAheadLog(
            svc._wal_dir, svc.words, fs=fs,
            fsync_every=svc.config.wal_fsync_every)
        return svc

    def close(self) -> None:
        """Flush and close the WAL (no final snapshot — reopen replays).
        Joins any in-flight background snapshot first. Idempotent and safe
        to call from a thread other than the one running a
        ``snapshot(background=True)`` — the lifecycle lock orders it after
        the snapshot's synchronous phase, and the join waits out the
        writer before the WAL handle goes away (pinned by
        ``tests/test_service.py::test_close_*``)."""
        with self._lifecycle_lock:
            already = self._closed
            self._closed = True
        if already:
            # second close still drains a writer the first one raced with,
            # but swallows nothing new and never double-closes the WAL
            t = self._snap_thread
            if t is not None:
                t.join()
            return
        self.snapshot_join()
        with self._lifecycle_lock:
            if self._wal is not None:
                self._wal.close()
                self._wal = None

    def _set_fs(self, fs: Fs) -> None:
        """Swap the filesystem layer (crash-fault harness hook)."""
        self._fs = fs
        if self._wal is not None:
            self._wal.set_fs(fs)

    # -- telemetry ----------------------------------------------------------
    def _fold_engine_stats(self, ename: str, eng) -> None:
        """Fold the engine's per-batch ``stats`` dict into the registry —
        tiered double-buffer telemetry becomes per-engine gauges so the
        stream-stall cost is visible without scraping engine objects."""
        st = getattr(eng, "stats", None)
        if not st:
            return
        if st.get("residency") == "tiered":
            self._m_tier_stall.set(st.get("tiered_stall_s", 0.0),
                                   engine=ename)
            self._m_tier_chunks.set(st.get("tiered_chunks", 0), engine=ename)
            self._m_tier_stall_frac.set(st.get("tiered_stall_fraction", 0.0),
                                        engine=ename)

    @property
    def n_total(self) -> int:
        """Rows in the logical database (engines agree by construction)."""
        return int(next(iter(self.engines.values())).n_total)

    @property
    def compactions(self) -> int:
        return sum(eng.store.compactions for eng in self.engines.values()
                   if hasattr(eng, "store"))

    def compiled_pipelines(self) -> int:
        """Total compiled-executable count across engine pipeline caches —
        flat in steady state (the no-recompile acceptance criterion)."""
        total = 0
        for eng in self.engines.values():
            for fn in eng._jit_cache.values():
                size = getattr(fn, "_cache_size", None)
                total += int(size()) if callable(size) else 1
        return total

    def summary(self) -> dict:
        out = {
            "engines": {n: e.backend for n, e in self.engines.items()},
            "n_queries": int(self.n_queries),
            "n_inserts": int(self.n_inserts),
            "compactions": int(self.compactions),
            "search_time_s": round(self.search_time, 4),
            "insert_time_s": round(self.insert_time, 4),
            "qps": round(self.n_queries / self.search_time, 1)
            if self.search_time > 0 else 0.0,
            "batch_buckets": dict(self._batch_buckets),
            "scanned": {k: int(v) for k, v in self.scanned_total.items()},
        }
        # percentiles from the full-run registry histogram (exact mean,
        # log-bucket quantile estimate); the no-op registry falls back to
        # the bounded recent window. Keys are always present — a write-only
        # run reports explicit nulls, never a KeyError downstream.
        p50 = p99 = mean = None
        if self.n_queries:
            if self.metrics.enabled:
                p50 = self._m_req_lat.quantile(0.5)
                p99 = self._m_req_lat.quantile(0.99)
                mean = self._m_req_lat.mean()
            elif self.latencies_ms:
                lat = np.asarray(self.latencies_ms, dtype=np.float64)
                p50, p99 = (float(np.percentile(lat, q)) for q in (50, 99))
                mean = float(lat.mean())
        out.update(
            p50_ms=round(p50, 3) if p50 is not None else None,
            p99_ms=round(p99, 3) if p99 is not None else None,
            mean_ms=round(mean, 3) if mean is not None else None)
        return out
