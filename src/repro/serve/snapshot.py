"""Serving-state serialization: full search state <-> flat named arrays.

This is the durability layer's data model (ISSUE 6 / ROADMAP "Durability and
warm restart"). A snapshot captures everything a replica needs to hydrate
**bit-identically** — the insert==rebuild parity suites define "identical":

* per store-backed engine (brute / bitbound-folding): the main segment's
  rows *in global-id order* plus the delta rows. The sorted/padded/folded
  main arrays are **not** stored — ``MutableFingerprintStore`` rebuilds
  them through the same deterministic ``_build_main`` (stable popcount
  argsort, power-of-two capacity, eager folding) that produced the live
  segment, so the restored arrays are byte-equal and the store's write
  counters (``generation`` / ``delta_version`` / ``compactions``) are
  carried in the meta blob.
* per HNSW index (and per shard of a sharded engine): fingerprints,
  base-layer adjacency, per-level upper adjacency, entry point, level
  assignments, and the **level-draw rng state** (``np.random.Generator``
  PCG64 state dict) — continuing inserts after a restore draws exactly the
  levels the live index would have drawn. Construction-time ``upper_dicts``
  are rebuilt from the dense arrays (the existing deserialized-index path);
  capacity backing arrays reallocate lazily on the first insert with values
  identical to the live ones (both sides share the same power-of-two
  bracket).

Arrays are a flat ``{name: ndarray}`` dict (names like
``"brute/main_rows"``, ``"hnsw/shard01/db"``) written by
``repro.checkpoint.manager.save_array_snapshot``; everything non-array
rides in the manifest's JSON ``meta``. ``service_state`` is the canonical
extraction — the property-based round-trip test compares the live and
restored extractions byte-for-byte.
"""
from __future__ import annotations

import numpy as np

from ..core import hnsw as hn
from ..core.fingerprints import TANIMOTO, resolve_metric
from ..obs.trace import TRACER as _TR
from ..core.engine import (BitBoundFoldingEngine, BruteForceEngine,
                           HNSWEngine)
from .store import (MutableFingerprintStore, TieredFingerprintStore,
                    _popcounts)

FORMAT_VERSION = 1


def _cow(a: np.ndarray) -> np.ndarray:
    """Copy-on-write extraction: always materialize a private C-contiguous
    copy (``np.ascontiguousarray`` would alias an already-contiguous live
    array, racing the background snapshot writer against inserts)."""
    return np.array(a, order="C")


# -- store ------------------------------------------------------------------

def store_state(store: MutableFingerprintStore):
    """Extract a store as ``(arrays, meta)``."""
    n = store.main.n
    main_rows = np.empty((n, store.words), dtype=np.uint32)
    main_rows[store.main.order[:n]] = store.main.db[:n]
    arrays = {"main_rows": main_rows, "delta_db": store.delta_db.copy()}
    meta = {
        "sorted_main": bool(store.sorted_main),
        "fold_m": int(store.fold_m),
        "fold_scheme": int(store.fold_scheme),
        "compact_threshold": int(store.compact_threshold),
        "generation": int(store.generation),
        "delta_version": int(store.delta_version),
        "compactions": int(store.compactions),
        "residency": getattr(store, "residency", "device"),
        "words": int(store.words),
    }
    return arrays, meta


def store_from_state(arrays, meta) -> MutableFingerprintStore:
    from ..core import folding as fl
    # tiered stores restore as tiered (host-RAM main segment; an mmap
    # backing directory is a deployment knob, not snapshot state) so the
    # hydrated engine never materializes the full DB on device
    kind = (TieredFingerprintStore
            if meta.get("residency", "device") == "tiered"
            else MutableFingerprintStore)
    rows = np.asarray(arrays["main_rows"], dtype=np.uint32)
    if "words" in meta and rows.shape[1] != int(meta["words"]):
        raise ValueError(
            f"snapshot rows are {rows.shape[1]} words wide but meta "
            f"records {meta['words']} — refusing a width-mismatched restore")
    st = kind(
        rows, sorted_main=meta["sorted_main"],
        fold_m=meta["fold_m"], fold_scheme=meta["fold_scheme"],
        compact_threshold=meta["compact_threshold"])
    delta = np.asarray(arrays["delta_db"], dtype=np.uint32)
    if delta.shape[0]:
        st.delta_db = delta
        st.delta_counts = _popcounts(delta)
        st.delta_folded = (fl.fold(delta, st.fold_m, st.fold_scheme)
                           if st.fold_m > 1 else delta)
        st.delta_folded_counts = _popcounts(st.delta_folded)
    st.generation = meta["generation"]
    st.delta_version = meta["delta_version"]
    st.compactions = meta["compactions"]
    return st


# -- HNSW index -------------------------------------------------------------

def hnsw_index_state(index: hn.HNSWIndex):
    """Extract one HNSW index as ``(arrays, meta)``."""
    arrays = {
        "db": _cow(index.db),
        "base_adj": _cow(index.base_adj),
        "level_of": _cow(index.level_of),
    }
    for l in range(1, index.max_level + 1):
        arrays[f"upper{l}_nodes"] = _cow(index.level_nodes[l - 1])
        arrays[f"upper{l}_adj"] = _cow(index.level_adj[l - 1])
    rng_state = None
    if index.rng is not None:
        rng_state = index.rng.bit_generator.state  # JSON-able nested dict
    meta = {
        "m": int(index.m),
        "ef_construction": int(index.ef_construction),
        "entry_point": int(index.entry_point),
        "max_level": int(index.max_level),
        "seed": int(index.seed),
        "max_level_cap": int(index.max_level_cap),
        "dirty_epoch": int(index.dirty_epoch),
        "upper_version": int(index.upper_version),
        "rng_state": rng_state,
        "metric": getattr(index, "metric", TANIMOTO).spec,
    }
    return arrays, meta


def hnsw_index_from_state(arrays, meta) -> hn.HNSWIndex:
    db = np.ascontiguousarray(arrays["db"], dtype=np.uint32)
    level_nodes, level_adj = [], []
    for l in range(1, meta["max_level"] + 1):
        level_nodes.append(
            np.asarray(arrays[f"upper{l}_nodes"], dtype=np.int32))
        level_adj.append(np.asarray(arrays[f"upper{l}_adj"], dtype=np.int32))
    index = hn.HNSWIndex(
        db=db, db_popcount=hn._np_popcount(db), m=meta["m"],
        ef_construction=meta["ef_construction"],
        entry_point=meta["entry_point"], max_level=meta["max_level"],
        base_adj=np.ascontiguousarray(arrays["base_adj"], dtype=np.int32),
        level_nodes=level_nodes, level_adj=level_adj,
        level_of=np.ascontiguousarray(arrays["level_of"], dtype=np.int8),
        seed=meta["seed"], max_level_cap=meta["max_level_cap"],
        metric=resolve_metric(meta.get("metric", "tanimoto")))
    index.dirty_epoch = meta["dirty_epoch"]
    index.upper_version = meta["upper_version"]
    if meta.get("rng_state") is not None:
        index.rng = np.random.default_rng(index.seed)
        index.rng.bit_generator.state = meta["rng_state"]
    # construction dicts: rebuilt through the existing deserialized-index
    # path (values identical to the live dicts; _densify sorts keys, so
    # iteration-order differences cannot leak into future graphs)
    index.upper_dicts = hn._upper_dicts_from_dense(index)
    return index


# -- engines ----------------------------------------------------------------

_STORE_KINDS = {"brute": BruteForceEngine, "bitbound": BitBoundFoldingEngine}


def engine_state(engine):
    """Extract any of the three engine types as ``(arrays, meta)``."""
    if isinstance(engine, BruteForceEngine):
        arrays, smeta = store_state(engine.store)
        return arrays, {"kind": "brute", "store": smeta}
    if isinstance(engine, BitBoundFoldingEngine):
        arrays, smeta = store_state(engine.store)
        return arrays, {"kind": "bitbound", "store": smeta}
    if isinstance(engine, HNSWEngine):
        if engine.shards is not None:
            arrays, shard_meta = {}, []
            for s, ix in enumerate(engine._shard_indexes):
                a, m_ = hnsw_index_state(ix)
                arrays.update({f"shard{s:02d}/{k}": v for k, v in a.items()})
                shard_meta.append(m_)
            return arrays, {"kind": "hnsw", "shards": engine.shards,
                            "shard_index": shard_meta}
        arrays, imeta = hnsw_index_state(engine.index)
        return arrays, {"kind": "hnsw", "shards": None, "index": imeta}
    raise TypeError(f"cannot snapshot engine type {type(engine).__name__}")


def engine_from_state(arrays, meta, **engine_kwargs):
    """Rebuild an engine from its extracted state. ``engine_kwargs`` are the
    construction knobs (backend, cutoff, ef_search, ...) that are serving
    config rather than data — the caller passes them from ServiceConfig."""
    kind = meta["kind"]
    engine_kwargs.pop("shards", None)   # sharding is data shape: meta decides
    if kind in _STORE_KINDS:
        store = store_from_state(arrays, meta["store"])
        return _STORE_KINDS[kind](None, store=store, **engine_kwargs)
    if kind == "hnsw":
        if meta["shards"] is not None:
            shards = int(meta["shards"])
            indexes = []
            for s, imeta in enumerate(meta["shard_index"]):
                pre = f"shard{s:02d}/"
                sub = {k[len(pre):]: v for k, v in arrays.items()
                       if k.startswith(pre)}
                indexes.append(hnsw_index_from_state(sub, imeta))
            return HNSWEngine(None, shards=shards, shard_indexes=indexes,
                              **engine_kwargs)
        index = hnsw_index_from_state(arrays, meta["index"])
        return HNSWEngine(None, index=index, **engine_kwargs)
    raise ValueError(f"unknown engine kind {kind!r}")


def service_state(svc):
    """Extract a whole :class:`repro.serve.service.SearchService` as
    ``(arrays, meta)`` — the canonical state the round-trip tests compare."""
    from dataclasses import asdict
    arrays, engines_meta = {}, {}
    # the COW extraction runs on the serving thread even for background
    # snapshots — its span is the synchronous cost the request path pays
    with _TR.span("snapshot.extract", engines=list(svc.engines)):
        for name, eng in svc.engines.items():
            a, m_ = engine_state(eng)
            arrays.update({f"{name}/{k}": v for k, v in a.items()})
            engines_meta[name] = m_
    cfg = asdict(svc.config)
    cfg.pop("durable_dir", None)       # bound at open(), not snapshot time
    meta = {
        "format": FORMAT_VERSION,
        "config": cfg,
        "engines": list(svc.engines.keys()),
        "default_engine": svc.default_engine,
        "engine_state": engines_meta,
        "n_total": int(next(iter(svc.engines.values())).n_total),
        "metric": resolve_metric(cfg.get("metric", "tanimoto")).spec,
        "fp_bits": int(cfg.get("fp_bits") or svc.words * 32),
    }
    return arrays, meta


def split_engine_arrays(arrays, name):
    """Select the ``name/``-prefixed subset of a service array dict."""
    pre = name + "/"
    return {k[len(pre):]: v for k, v in arrays.items() if k.startswith(pre)}
