"""Mutable LSM-style fingerprint store — the serving-time database layout.

The paper's host appends new compounds without stalling the scan engines;
BitBound (Eq. 2) needs the scanned segment popcount-sorted, and the folded
stage-1 arrays must stay consistent with the full-resolution rows. This
module reconciles the two with a two-segment LSM layout:

* **main segment** — immutable between compactions. For sorted stores
  (BitBound engines) the rows are popcount-sorted with ``order`` mapping
  sorted row -> global id; for unsorted stores (brute force) rows sit in
  global-id order and ``order`` is the identity. The arrays are padded to a
  power-of-two ``capacity`` (pad rows are zero; pad *counts* are
  ``PAD_COUNT`` in sorted mode so every Eq. 2 searchsorted window ends
  before the pads) — device pipelines keyed on the array shapes therefore
  survive compactions that don't cross a capacity boundary.
* **delta segment** — append-only, unsorted, in insertion (= global-id)
  order. Inserts are O(batch): no re-sort, no re-fold of the main segment.
  Folded delta rows are maintained eagerly so two-stage engines can scan
  the delta at stage-1 resolution.

**Compaction** is threshold-triggered (``compact_threshold`` delta rows):
the delta is merged into a fresh main segment — rows re-sorted by popcount
(stable, so equal-popcount rows stay in global-id order: exactly the order
a from-scratch :func:`repro.core.bitbound.build_index` would produce) and
re-folded. ``generation`` bumps on compaction, ``delta_version`` on every
write; engines use the two counters to invalidate device-resident copies.

Global ids are assigned monotonically (0..n_total-1) and are stable across
compactions, so engine results are comparable to a from-scratch rebuild on
the concatenated database — the insert-then-search parity contract.
"""
from __future__ import annotations

from dataclasses import dataclass
from pathlib import Path

import numpy as np

from ..core import folding as fl

# Pad sentinel for sorted-mode counts: larger than any reachable Eq.2 upper
# bound (hi_cnt = a / max(cutoff, 1e-6) <= 1024e6 < 2**31 - 1), so windows
# computed by searchsorted always end at or before the last valid row.
PAD_COUNT = np.iinfo(np.int32).max


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (1 for n <= 1)."""
    n = int(n)
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _popcounts(rows: np.ndarray) -> np.ndarray:
    return np.bitwise_count(rows).sum(axis=-1).astype(np.int64)


def validate_rows(fps, words: int | None = None) -> np.ndarray:
    """Validate an insert batch up front: returns a ``(N, W)`` uint32 array
    or raises a clear ``ValueError``.

    Accepted dtypes are ``uint32`` (the packed-word format) and unsigned
    integer types that cast to it losslessly (``uint8`` / ``uint16``).
    Anything else — floats, signed ints (what a bare python list becomes),
    objects — is rejected here instead of surfacing later as a cryptic
    kernel shape/dtype error deep in a compiled pipeline.
    """
    arr = np.asarray(fps)
    if arr.dtype != np.uint32:
        if not (arr.dtype.kind == "u"
                and np.can_cast(arr.dtype, np.uint32, "safe")):
            raise ValueError(
                "fingerprint rows must be packed uint32 words "
                f"(or a losslessly-castable unsigned dtype), got {arr.dtype}")
        arr = arr.astype(np.uint32)
    arr = np.atleast_2d(arr)
    if arr.ndim != 2:
        raise ValueError(
            f"fingerprint rows must be (N, W) packed words, got shape "
            f"{arr.shape}")
    if words is not None and arr.shape[1] != words:
        raise ValueError(
            f"fingerprint width {arr.shape[1]} != store width {words}")
    return arr


@dataclass
class MainSegment:
    """Immutable (between compactions) capacity-padded fingerprint segment."""
    db: np.ndarray               # (capacity, W) uint32; pad rows zero
    counts: np.ndarray           # (capacity,) int64; pads PAD_COUNT (sorted) / 0
    order: np.ndarray            # (capacity,) int64 row -> global id; pads -1
    folded: np.ndarray | None    # (capacity, W/m) uint32 (None when unfolded)
    folded_counts: np.ndarray | None   # (capacity,) int64; pads 0
    n: int                       # valid rows
    capacity: int


class MutableFingerprintStore:
    """Two-segment (main + delta) mutable fingerprint database.

    Parameters
    ----------
    db : (N, W) uint32 packed fingerprints, in global-id order.
    sorted_main : popcount-sort the main segment (BitBound layout). When
        False the main segment keeps global-id order (brute-force layout).
    fold_m / fold_scheme : stage-1 folding level for the main+delta folded
        arrays (``m=1`` stores aliases of the full-resolution arrays).
    compact_threshold : delta row count that triggers compaction on insert.
    """

    #: where a device engine should keep the full-resolution main segment;
    #: :class:`TieredFingerprintStore` overrides this to "tiered" (host RAM,
    #: streamed to HBM per rescore chunk — see core/engine.py residency)
    residency = "device"

    def __init__(self, db: np.ndarray, *, sorted_main: bool = True,
                 fold_m: int = 1, fold_scheme: int = 1,
                 compact_threshold: int = 4096):
        db = np.atleast_2d(np.asarray(db, dtype=np.uint32))
        if db.ndim != 2:
            raise ValueError(f"db must be (N, W) packed words, got {db.shape}")
        self.words = db.shape[1]
        self.sorted_main = bool(sorted_main)
        self.fold_m = int(fold_m)
        self.fold_scheme = int(fold_scheme)
        self.compact_threshold = max(int(compact_threshold), 1)
        self.generation = 0
        self.delta_version = 0
        self.compactions = 0
        self.main = self._build_main(db)
        self._reset_delta()

    # -- segment construction ------------------------------------------------
    def _build_main(self, rows: np.ndarray) -> MainSegment:
        """Build a fresh main segment from rows given in global-id order."""
        n = rows.shape[0]
        capacity = next_pow2(max(n, 1))
        counts = _popcounts(rows)
        if self.sorted_main:
            order = np.argsort(counts, kind="stable").astype(np.int64)
            rows = rows[order]
            counts = counts[order]
        else:
            order = np.arange(n, dtype=np.int64)
        db = np.zeros((capacity, self.words), dtype=np.uint32)
        db[:n] = rows
        cnt = np.full((capacity,), PAD_COUNT if self.sorted_main else 0,
                      dtype=np.int64)
        cnt[:n] = counts
        order_p = np.full((capacity,), -1, dtype=np.int64)
        order_p[:n] = order
        if self.fold_m > 1:
            folded = np.zeros(
                (capacity, fl.folded_words(self.words, self.fold_m)),
                dtype=np.uint32)
            folded[:n] = fl.fold(db[:n], self.fold_m, self.fold_scheme)
        else:
            folded = db
        folded_counts = np.zeros((capacity,), dtype=np.int64)
        folded_counts[:n] = _popcounts(folded[:n])
        return MainSegment(db=db, counts=cnt, order=order_p, folded=folded,
                           folded_counts=folded_counts, n=n, capacity=capacity)

    def _reset_delta(self) -> None:
        wf = (fl.folded_words(self.words, self.fold_m)
              if self.fold_m > 1 else self.words)
        self.delta_db = np.zeros((0, self.words), dtype=np.uint32)
        self.delta_counts = np.zeros((0,), dtype=np.int64)
        self.delta_folded = np.zeros((0, wf), dtype=np.uint32)
        self.delta_folded_counts = np.zeros((0,), dtype=np.int64)

    # -- sizes ---------------------------------------------------------------
    @property
    def n_main(self) -> int:
        return self.main.n

    @property
    def n_delta(self) -> int:
        return self.delta_db.shape[0]

    @property
    def n_total(self) -> int:
        return self.main.n + self.delta_db.shape[0]

    # -- writes --------------------------------------------------------------
    def insert(self, fps: np.ndarray) -> np.ndarray:
        """Append fingerprints to the delta segment; returns their global
        ids. Triggers compaction when the delta reaches the threshold.
        Mis-shaped or mis-dtyped rows raise ``ValueError`` up front
        (:func:`validate_rows`) instead of corrupting the delta."""
        fps = validate_rows(fps, self.words)
        if fps.shape[0] == 0:
            return np.empty((0,), dtype=np.int64)
        gids = np.arange(self.n_total, self.n_total + fps.shape[0],
                         dtype=np.int64)
        self.delta_db = np.concatenate([self.delta_db, fps])
        self.delta_counts = np.concatenate(
            [self.delta_counts, _popcounts(fps)])
        folded = (fl.fold(fps, self.fold_m, self.fold_scheme)
                  if self.fold_m > 1 else fps)
        self.delta_folded = np.concatenate([self.delta_folded, folded])
        self.delta_folded_counts = np.concatenate(
            [self.delta_folded_counts, _popcounts(folded)])
        self.delta_version += 1
        if self.n_delta >= self.compact_threshold:
            self.compact()
        return gids

    # -- compaction ----------------------------------------------------------
    def rows_in_gid_order(self) -> np.ndarray:
        """All valid rows (main + delta) re-assembled in global-id order —
        the database a from-scratch rebuild would be given."""
        n = self.main.n
        rows = np.empty((n, self.words), dtype=np.uint32)
        rows[self.main.order[:n]] = self.main.db[:n]
        if self.n_delta:
            rows = np.concatenate([rows, self.delta_db])
        return rows

    def compact(self) -> None:
        """Merge the delta into a fresh sorted/folded main segment."""
        self.main = self._build_main(self.rows_in_gid_order())
        self._reset_delta()
        self.generation += 1
        self.delta_version += 1
        self.compactions += 1


class TieredFingerprintStore(MutableFingerprintStore):
    """Tiered-residency store: the full-resolution main segment stays on the
    host (ISSUE 7 / ROADMAP "Billion-fingerprint capacity").

    Layout and semantics are byte-identical to
    :class:`MutableFingerprintStore` — same deterministic ``_build_main``,
    same counters, same snapshot format. The differences are residency
    policy, not data:

    * ``residency = "tiered"`` tells the device engines not to upload
      ``main.db`` in ``_sync``; only the folded stage-1 arrays plus the
      (4 B/row) count and order vectors go to HBM, and full-resolution rows
      are gathered on the host and streamed into a double-buffered HBM
      staging window per rescore chunk (``core/engine.py``).
    * ``mmap_dir`` optionally backs the main segment's full-resolution rows
      with a ``np.memmap`` file, so a database much larger than RAM-resident
      working set can be served — the OS pages rescore windows in on demand
      and the sorted copy never has to live in anonymous memory. The folded
      arrays (m× smaller) and the int64 count/order vectors stay in RAM.
      Compactions write a fresh file per generation (``main_<gen>.u32``).

    On a host with pinned-memory support, ``mmap_dir=None`` rows are the
    host-pinned tier; the engine's ``jax.device_put`` chunks are what an
    FPGA/TPU host would DMA from pinned buffers.
    """

    residency = "tiered"

    #: rows per host-side write chunk while building a memmapped segment
    _BUILD_CHUNK = 1 << 16

    def __init__(self, db: np.ndarray, *, mmap_dir: str | None = None,
                 **kwargs):
        self._mmap_dir = mmap_dir
        self._mmap_seq = 0
        super().__init__(db, **kwargs)

    def _build_main(self, rows: np.ndarray) -> MainSegment:
        if self._mmap_dir is None:
            return super()._build_main(rows)
        # memmap-backed build: identical arrays to the parent (pinned by
        # tests/test_tiered.py), written chunk-wise so the full sorted copy
        # never has to be materialised in anonymous memory
        n = rows.shape[0]
        capacity = next_pow2(max(n, 1))
        counts = _popcounts(rows)
        if self.sorted_main:
            order = np.argsort(counts, kind="stable").astype(np.int64)
        else:
            order = np.arange(n, dtype=np.int64)
        base = Path(self._mmap_dir)
        base.mkdir(parents=True, exist_ok=True)
        path = base / f"main_{self._mmap_seq:04d}.u32"
        self._mmap_seq += 1
        db = np.memmap(path, dtype=np.uint32, mode="w+",
                       shape=(capacity, self.words))
        db[n:] = 0
        cnt = np.full((capacity,), PAD_COUNT if self.sorted_main else 0,
                      dtype=np.int64)
        order_p = np.full((capacity,), -1, dtype=np.int64)
        order_p[:n] = order
        wf = fl.folded_words(self.words, self.fold_m)
        folded = (np.zeros((capacity, wf), dtype=np.uint32)
                  if self.fold_m > 1 else db)
        folded_counts = np.zeros((capacity,), dtype=np.int64)
        for lo in range(0, n, self._BUILD_CHUNK):
            hi = min(lo + self._BUILD_CHUNK, n)
            sel = order[lo:hi]
            chunk = rows[sel] if self.sorted_main else rows[lo:hi]
            db[lo:hi] = chunk
            cnt[lo:hi] = counts[sel] if self.sorted_main else counts[lo:hi]
            if self.fold_m > 1:
                fchunk = fl.fold(chunk, self.fold_m, self.fold_scheme)
                folded[lo:hi] = fchunk
                folded_counts[lo:hi] = _popcounts(fchunk)
            else:
                folded_counts[lo:hi] = _popcounts(chunk)
        db.flush()
        return MainSegment(db=db, counts=cnt, order=order_p, folded=folded,
                           folded_counts=folded_counts, n=n,
                           capacity=capacity)
