"""Architecture configuration schema for the assigned model pool."""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25   # capacity dispatch (train); decode is exact


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    moe: MoEConfig | None = None
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # hybrid (jamba): repeating unit of `unit_len` layers, attention at
    # `attn_position`, MoE on every `moe_every`-th layer of the unit
    unit_len: int = 1
    attn_position: int = 0
    moe_every: int = 0             # 0 -> no per-unit MoE pattern (all or none)

    # mamba
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_conv: int = 4

    # xlstm
    xlstm_pattern: str = ""        # e.g. "sm" = alternate sLSTM / mLSTM

    # enc-dec (whisper): n_layers counts each stack
    enc_dec: bool = False
    n_audio_frames: int = 1500     # encoder input length (stub frontend)

    # vlm: number of prepended patch embeddings (stub frontend)
    n_patches: int = 0

    # attention window for long-context (0 = full causal)
    attn_window: int = 0

    # serving
    max_seq: int = 32_768

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to a multiple of 128 (TPU lane alignment + TP
        divisibility — Megatron-style padded vocab). Loss masks the pad."""
        return ((self.vocab + 127) // 128) * 128

    def with_(self, **kw) -> "ArchConfig":
        return replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=max(2, self.unit_len),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            head_dim=16,
            n_audio_frames=8 if self.enc_dec else self.n_audio_frames,
            n_patches=4 if self.n_patches else 0,
            max_seq=64,
            attn_window=min(self.attn_window, 32) if self.attn_window else 0,
        )
        if self.moe:
            # ample capacity: reduced-config smoke/consistency tests are exact
            kw["moe"] = MoEConfig(n_experts=4, top_k=min(2, self.moe.top_k),
                                  capacity_factor=8.0)
        if self.family == "hybrid":
            kw["n_layers"] = self.unit_len  # one full pattern unit
        if self.family == "ssm":
            kw["n_layers"] = 2
        return self.with_(**kw)


@dataclass(frozen=True)
class ShapeSpec:
    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}

# archs allowed to run long_500k (sub-quadratic sequence mixing)
SUBQUADRATIC = {"jamba-v0.1-52b", "xlstm-350m"}


def cell_is_runnable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    if shape.name == "long_500k" and cfg.name not in SUBQUADRATIC:
        return False, "full quadratic attention at 524k context — skipped per harness rules"
    return True, ""
