"""jamba-v0.1-52b [hybrid] — arXiv:2403.19887 (hf tier).

32L, d_model 4096, 32 q heads / 8 kv heads, d_ff 14336, vocab 65536.
Mamba+attention 1:7 interleave (attention at position 4 of each 8-layer
unit, as in the released model), MoE 16 experts top-2 on every other layer.
At 524k context the attention layers run windowed (sliding 8192) — the
documented sub-quadratic path for the long_500k shape (DESIGN.md).
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    moe=MoEConfig(n_experts=16, top_k=2),
    unit_len=8,
    attn_position=4,
    moe_every=2,
    mamba_d_state=16,
    mamba_expand=2,
    mamba_conv=4,
    attn_window=8192,
    max_seq=524_288,
)
