"""olmoe-1b-7b [moe] — arXiv:2409.02060 (hf tier).

16L, d_model 2048, 16 heads (MHA), d_ff 1024 (per expert), vocab 50304.
MoE: 64 experts, top-8.
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    moe=MoEConfig(n_experts=64, top_k=8),
)
