"""xlstm-350m [ssm] — arXiv:2405.04517 (unverified tier).

24L, d_model 1024, 4 heads, d_ff=0 (xLSTM blocks carry their own up/down
projections), vocab 50304. Alternating sLSTM / mLSTM blocks. Recurrent —
runs the long_500k shape.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    xlstm_pattern="sm",
    max_seq=524_288,
)
