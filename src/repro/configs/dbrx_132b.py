"""dbrx-132b [moe] — hf:databricks/dbrx-base (unverified tier).

40L, d_model 6144, 48 q heads / 8 kv heads, d_ff 10752 (per expert),
vocab 100352. MoE: 16 experts, top-4 (fine-grained).
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    moe=MoEConfig(n_experts=16, top_k=4),
)
