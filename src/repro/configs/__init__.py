"""Assigned architecture pool (``--arch <id>``) + the paper's own config."""
from .base import (  # noqa: F401
    ArchConfig, MoEConfig, ShapeSpec, SHAPES, SHAPES_BY_NAME,
    SUBQUADRATIC, cell_is_runnable,
)
from .phi3_medium_14b import CONFIG as phi3_medium_14b
from .mistral_nemo_12b import CONFIG as mistral_nemo_12b
from .granite_3_2b import CONFIG as granite_3_2b
from .qwen1_5_4b import CONFIG as qwen1_5_4b
from .jamba_v0_1_52b import CONFIG as jamba_v0_1_52b
from .whisper_medium import CONFIG as whisper_medium
from .xlstm_350m import CONFIG as xlstm_350m
from .olmoe_1b_7b import CONFIG as olmoe_1b_7b
from .dbrx_132b import CONFIG as dbrx_132b
from .internvl2_26b import CONFIG as internvl2_26b
from .paper import PaperSearchConfig, CHEMBL_LIKE  # noqa: F401

ARCHS = {
    c.name: c for c in (
        phi3_medium_14b, mistral_nemo_12b, granite_3_2b, qwen1_5_4b,
        jamba_v0_1_52b, whisper_medium, xlstm_350m, olmoe_1b_7b,
        dbrx_132b, internvl2_26b,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
