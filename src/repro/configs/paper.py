"""The paper's own workload config: ChEMBL-scale Tanimoto KNN search."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class PaperSearchConfig:
    name: str = "chembl-27.1"
    n_molecules: int = 1_941_405       # ChEMBL 27.1 (paper §III-B)
    fp_len: int = 1024                 # Morgan-1024
    k: int = 20                        # Top-20 search (paper Table I)
    cutoff: float = 0.8                # similarity cutoff for BitBound (Fig. 10)
    folding_m: int = 4
    folding_scheme: int = 1
    hnsw_m: int = 16
    hnsw_ef_construction: int = 100
    hnsw_ef_search: int = 64
    queries_per_batch: int = 1024


CHEMBL_LIKE = PaperSearchConfig()
