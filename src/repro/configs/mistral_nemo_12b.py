"""mistral-nemo-12b [dense] — hf:mistralai/Mistral-Nemo-Base-2407 (hf tier).

40L, d_model 5120, 32 q heads / 8 kv heads, d_ff 14336, vocab 131072.
128k context; head_dim is 128 (not d_model/n_heads=160).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=128,
    rope_theta=1_000_000.0,
    max_seq=131_072,
)
