"""whisper-medium [audio] — arXiv:2212.04356 (unverified tier).

24L encoder + 24L decoder, d_model 1024, 16 heads (MHA), d_ff 4096,
vocab 51865. Enc-dec with cross attention; learned positions (no RoPE);
conv frontend is a STUB — input_specs provides precomputed frame embeddings
(B, 1500, d_model), per the harness rules.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    enc_dec=True,
    n_audio_frames=1500,
    max_seq=32_768,   # sized for the decode_32k cell's learned-position table
)
