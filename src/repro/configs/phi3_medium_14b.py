"""phi3-medium-14b [dense] — arXiv:2404.14219 (unverified tier).

40L, d_model 5120, 40 q heads / 10 kv heads (GQA), d_ff 17920, vocab 100352.
RoPE + SwiGLU + GQA.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab=100352,
)
