"""internvl2-26b [vlm] — arXiv:2404.16821 (hf tier).

Backbone only (InternLM2-20B-class): 48L, d_model 6144, 48 q heads / 8 kv
heads, d_ff 16384, vocab 92553. InternViT frontend is a STUB — input_specs
provides precomputed patch embeddings (B, n_patches, d_model).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    n_patches=256,
)
