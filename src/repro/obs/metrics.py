"""Thread-safe, constant-memory metrics registry (ISSUE 8 tentpole).

Three instrument kinds behind one :class:`MetricsRegistry`:

* **counter** — monotone float/int accumulator (``inc``).
* **gauge** — last-write-wins value (``set``).
* **histogram** — log-bucketed latency/size distribution with constant
  memory per child: values land in geometric buckets spaced
  ``GROWTH = 2**(1/8)`` apart (~9% max relative quantile error from bucket
  midpoints), plus exact ``count``/``sum``/``min``/``max``. Quantiles
  geometric-interpolate inside the crossing bucket and clamp to the
  observed [min, max], so single-valued and narrow distributions report
  exact percentiles.

Every family can carry label dimensions (``labels=("engine", ...)``);
children materialize lazily per label-value tuple and all mutation goes
through one registry lock (the instruments are far off any kernel hot path
— the service touches them a handful of times per *batch*, not per row).

Exposition:

* :meth:`MetricsRegistry.render_prometheus` — Prometheus text format
  (histograms as cumulative ``_bucket{le=...}`` + ``_sum`` / ``_count``).
* :meth:`MetricsRegistry.export_jsonl` — one JSON line per child with the
  quantile summary and sparse bucket map; ``benchmarks/check_obs_schema.py``
  (via :mod:`repro.obs.schema`) validates the shape.

``NULL_METRICS`` is a no-op registry with the same surface — the
``metrics=False`` service path (the serve_load overhead A/B) swaps it in so
call sites never branch.
"""
from __future__ import annotations

import json
import math
import threading
from pathlib import Path

import numpy as np

GROWTH = 2.0 ** 0.125          # 8 buckets per doubling (~9% quantile error)
_LOG_GROWTH = math.log(GROWTH)
HIST_LO = 1e-3                 # smallest resolved value (ms space: 1 us)
HIST_HI = 1e8                  # largest  (ms space: ~28 h)
N_BUCKETS = int(math.ceil(math.log(HIST_HI / HIST_LO) / _LOG_GROWTH)) + 1


def _bucket_index(value: float) -> int:
    if value <= HIST_LO:
        return 0
    i = int(math.log(value / HIST_LO) / _LOG_GROWTH) + 1
    return min(i, N_BUCKETS - 1)


def bucket_upper(i: int) -> float:
    """Upper bound of bucket ``i`` (``le`` edge in the exposition)."""
    if i >= N_BUCKETS - 1:
        return float("inf")
    return HIST_LO * GROWTH ** i


class _Child:
    __slots__ = ("labels",)

    def __init__(self, labels: dict):
        self.labels = labels


class _Counter(_Child):
    __slots__ = ("value",)
    kind = "counter"

    def __init__(self, labels):
        super().__init__(labels)
        self.value = 0.0


class _Gauge(_Child):
    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self, labels):
        super().__init__(labels)
        self.value = 0.0


class _Histogram(_Child):
    __slots__ = ("buckets", "count", "sum", "min", "max")
    kind = "histogram"

    def __init__(self, labels):
        super().__init__(labels)
        self.buckets = np.zeros(N_BUCKETS, dtype=np.int64)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def _observe(self, value: float) -> None:
        self.buckets[_bucket_index(value)] += 1
        self.count += 1
        self.sum += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value


def quantile_from_buckets(buckets: np.ndarray, count: int, q: float,
                          lo: float | None = None,
                          hi: float | None = None) -> float | None:
    """Estimate the ``q``-quantile from log-bucket counts: find the bucket
    the target rank lands in, geometric-interpolate inside it, clamp to the
    observed [lo, hi] when given. ``None`` when empty."""
    if count <= 0:
        return None
    target = max(1, int(math.ceil(q * count)))
    cum = 0
    for i, c in enumerate(buckets):
        if not c:
            continue
        if cum + c >= target:
            frac = (target - cum) / c
            b_lo = HIST_LO * GROWTH ** (i - 1) if i > 0 else 0.0
            b_hi = HIST_LO * GROWTH ** i
            est = b_lo + (b_hi - b_lo) * frac
            if lo is not None:
                est = max(est, lo)
            if hi is not None:
                est = min(est, hi)
            return est
        cum += c
    return hi


class Family:
    """One named metric family; holds the per-label-tuple children."""

    _KINDS = {"counter": _Counter, "gauge": _Gauge, "histogram": _Histogram}

    def __init__(self, registry, name: str, kind: str, help: str,
                 label_names: tuple):
        self._registry = registry
        self.name = name
        self.kind = kind
        self.help = help
        self.label_names = tuple(label_names)
        self._children: dict[tuple, _Child] = {}

    def _child(self, label_values: tuple) -> _Child:
        c = self._children.get(label_values)
        if c is None:
            c = self._KINDS[self.kind](dict(zip(self.label_names,
                                                label_values)))
            self._children[label_values] = c
        return c

    def _resolve(self, labels: dict) -> _Child:
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"{self.name}: labels {sorted(labels)} != declared "
                f"{sorted(self.label_names)}")
        return self._child(tuple(str(labels[n]) for n in self.label_names))

    # -- mutation (each takes the registry lock) ----------------------------
    def touch(self, **labels) -> None:
        """Materialize a labeled child at its zero value without recording
        an event — pre-seeding known label sets so exports (and
        required-family CI floors) see the family before the first hit."""
        with self._registry._lock:
            self._resolve(labels)

    def inc(self, value: float = 1.0, **labels) -> None:
        with self._registry._lock:
            self._resolve(labels).value += value

    def set(self, value: float, **labels) -> None:
        with self._registry._lock:
            self._resolve(labels).value = float(value)

    def observe(self, value: float, **labels) -> None:
        with self._registry._lock:
            self._resolve(labels)._observe(float(value))

    # -- read side ----------------------------------------------------------
    def value(self, **labels) -> float:
        """Current value of one counter/gauge child (0 if never touched)."""
        with self._registry._lock:
            c = self._children.get(
                tuple(str(labels[n]) for n in self.label_names))
            return getattr(c, "value", 0.0) if c is not None else 0.0

    def total(self) -> float:
        """Sum of every child's value (counters/gauges)."""
        with self._registry._lock:
            return sum(c.value for c in self._children.values())

    def count(self) -> int:
        """Total observations across children (histograms)."""
        with self._registry._lock:
            return sum(c.count for c in self._children.values()
                       if isinstance(c, _Histogram))

    def quantile(self, q: float, **labels) -> float | None:
        """Aggregate quantile estimate across children (or one child when
        ``labels`` pin it). ``None`` when no observations."""
        with self._registry._lock:
            if labels:
                key = tuple(str(labels[n]) for n in self.label_names)
                kids = [self._children[key]] if key in self._children else []
            else:
                kids = [c for c in self._children.values()
                        if isinstance(c, _Histogram)]
            if not kids:
                return None
            buckets = np.zeros(N_BUCKETS, dtype=np.int64)
            count, lo, hi = 0, math.inf, -math.inf
            for c in kids:
                buckets += c.buckets
                count += c.count
                lo, hi = min(lo, c.min), max(hi, c.max)
            return quantile_from_buckets(buckets, count, q, lo, hi)

    def mean(self, **labels) -> float | None:
        with self._registry._lock:
            kids = [c for c in self._children.values()
                    if isinstance(c, _Histogram)]
            total = sum(c.sum for c in kids)
            count = sum(c.count for c in kids)
            return (total / count) if count else None

    def _reset(self) -> None:
        self._children.clear()


class MetricsRegistry:
    """Create-or-get metric families; render / export the whole set."""

    def __init__(self):
        self._lock = threading.RLock()
        self._families: dict[str, Family] = {}

    enabled = True

    def _family(self, name: str, kind: str, help: str,
                labels: tuple) -> Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = Family(self, name, kind, help, labels)
                self._families[name] = fam
            elif fam.kind != kind or fam.label_names != tuple(labels):
                raise ValueError(
                    f"metric {name!r} re-registered as {kind}/{labels} "
                    f"(was {fam.kind}/{fam.label_names})")
            return fam

    def counter(self, name, help: str = "", labels: tuple = ()):
        return self._family(name, "counter", help, labels)

    def gauge(self, name, help: str = "", labels: tuple = ()):
        return self._family(name, "gauge", help, labels)

    def histogram(self, name, help: str = "", labels: tuple = ()):
        return self._family(name, "histogram", help, labels)

    def family(self, name: str) -> Family | None:
        return self._families.get(name)

    def reset(self) -> None:
        """Zero every child (family declarations survive)."""
        with self._lock:
            for fam in self._families.values():
                fam._reset()

    # -- exposition ---------------------------------------------------------
    def collect(self) -> list[dict]:
        """Snapshot every child as a plain dict (the JSONL line shape)."""
        out = []
        with self._lock:
            for fam in self._families.values():
                for c in fam._children.values():
                    row = {"name": fam.name, "type": fam.kind,
                           "labels": dict(c.labels)}
                    if isinstance(c, _Histogram):
                        row.update(
                            count=int(c.count), sum=float(c.sum),
                            min=(float(c.min) if c.count else None),
                            max=(float(c.max) if c.count else None),
                            p50=quantile_from_buckets(
                                c.buckets, c.count, 0.5, c.min, c.max),
                            p99=quantile_from_buckets(
                                c.buckets, c.count, 0.99, c.min, c.max),
                            buckets={f"{bucket_upper(i):.6g}": int(n)
                                     for i, n in enumerate(c.buckets) if n})
                    else:
                        row["value"] = float(c.value)
                    out.append(row)
        return out

    def render_prometheus(self) -> str:
        lines = []
        with self._lock:
            for fam in self._families.values():
                if not fam._children:
                    continue
                if fam.help:
                    lines.append(f"# HELP {fam.name} {fam.help}")
                lines.append(f"# TYPE {fam.name} {fam.kind}")
                for c in fam._children.values():
                    lab = ",".join(
                        f'{k}="{v}"' for k, v in c.labels.items())
                    if isinstance(c, _Histogram):
                        cum = 0
                        for i, n in enumerate(c.buckets):
                            if not n:
                                continue
                            cum += int(n)
                            le = bucket_upper(i)
                            le_s = "+Inf" if math.isinf(le) else f"{le:.6g}"
                            blab = (f'{lab},le="{le_s}"' if lab
                                    else f'le="{le_s}"')
                            lines.append(
                                f"{fam.name}_bucket{{{blab}}} {cum}")
                        blab = (f'{lab},le="+Inf"' if lab else 'le="+Inf"')
                        if cum != c.count:    # ensure the +Inf edge exists
                            lines.append(
                                f"{fam.name}_bucket{{{blab}}} {c.count}")
                        sfx = f"{{{lab}}}" if lab else ""
                        lines.append(f"{fam.name}_sum{sfx} {c.sum:.6g}")
                        lines.append(f"{fam.name}_count{sfx} {c.count}")
                    else:
                        sfx = f"{{{lab}}}" if lab else ""
                        lines.append(f"{fam.name}{sfx} {c.value:.6g}")
        return "\n".join(lines) + "\n"

    def export_jsonl(self, path, ts: float | None = None,
                     append: bool = False) -> int:
        """Write one JSON line per child; returns the line count."""
        rows = self.collect()
        if ts is not None:
            for r in rows:
                r["ts"] = ts
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        mode = "a" if append else "w"
        with open(path, mode) as f:
            for r in rows:
                f.write(json.dumps(r) + "\n")
        return len(rows)


class _NullFamily:
    """Accepts every instrument call and does nothing."""
    __slots__ = ()

    def touch(self, **k):
        pass

    def inc(self, *a, **k):
        pass

    def set(self, *a, **k):
        pass

    def observe(self, *a, **k):
        pass

    def value(self, **k):
        return 0.0

    def total(self):
        return 0.0

    def count(self):
        return 0

    def quantile(self, q, **k):
        return None

    def mean(self, **k):
        return None


_NULL_FAMILY = _NullFamily()


class NullMetrics:
    """Registry-shaped no-op (the ``metrics=False`` overhead baseline)."""

    enabled = False

    def counter(self, *a, **k):
        return _NULL_FAMILY

    gauge = histogram = counter

    def family(self, name):
        return None

    def reset(self):
        pass

    def collect(self):
        return []

    def render_prometheus(self):
        return ""

    def export_jsonl(self, path, ts=None, append=False):
        return 0


NULL_METRICS = NullMetrics()
