"""Schema validation for the observability artifacts (ISSUE 8 satellite).

Two validators, both returning a (possibly empty) list of human-readable
error strings — empty means valid:

* :func:`validate_trace` — Chrome trace-event JSON as exported by
  :meth:`repro.obs.trace.Tracer.export_chrome` (and accepted by Perfetto).
* :func:`validate_metrics_jsonl` — the registry's JSONL export, including
  the required-family floor (:data:`REQUIRED_METRIC_FAMILIES`): a serving
  run that silently stopped exporting request latencies must fail CI, not
  produce an empty dashboard.

``benchmarks/check_obs_schema.py`` is the CLI wrapper CI runs.
"""
from __future__ import annotations

import json
from pathlib import Path

#: metric families every SearchService export must contain (the serving
#: dashboards and the SLO harness key on these)
REQUIRED_METRIC_FAMILIES = (
    "service_queries_total",
    "service_request_latency_ms",
    "service_scanned_total",
)

#: families the concurrent front end (ISSUE 9) must additionally export —
#: CI's overload smoke passes these via ``--require-family`` (note that
#: flag *replaces* the service floor, so callers list both sets)
FRONTEND_METRIC_FAMILIES = (
    "frontend_admitted_total",
    "frontend_shed_total",
    "frontend_deadline_expired_total",
    "frontend_queue_depth",
    "frontend_degradation_level",
    "frontend_replica_live",
    "frontend_request_latency_ms",
)

#: Chrome trace-event phases we emit / accept
TRACE_PHASES = {"X", "M", "B", "E", "b", "e", "i", "C"}


def validate_trace(obj, *, require_spans: tuple = ()) -> list[str]:
    """Validate a parsed Chrome trace (dict with ``traceEvents`` or a bare
    event list). ``require_spans`` additionally demands at least one "X"
    event per named span (e.g. ``("tier.device_put",)`` for the tiered
    double-buffer capture)."""
    errors: list[str] = []
    if isinstance(obj, dict):
        events = obj.get("traceEvents")
        if not isinstance(events, list):
            return ["top-level dict has no traceEvents list"]
    elif isinstance(obj, list):
        events = obj
    else:
        return [f"trace must be a dict or list, got {type(obj).__name__}"]
    if not events:
        errors.append("trace has no events")
    seen = set()
    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        for key in ("name", "ph", "pid", "tid", "ts"):
            if key not in ev:
                errors.append(f"{where}: missing {key!r}")
        name, ph = ev.get("name"), ev.get("ph")
        if not isinstance(name, str) or not name:
            errors.append(f"{where}: name must be a non-empty string")
        if ph not in TRACE_PHASES:
            errors.append(f"{where}: unknown phase {ph!r}")
        for key in ("ts", "dur"):
            if key in ev and not isinstance(ev[key], (int, float)):
                errors.append(f"{where}: {key} not numeric")
        if ph == "X":
            if "dur" not in ev:
                errors.append(f"{where}: complete event missing dur")
            elif isinstance(ev["dur"], (int, float)) and ev["dur"] < 0:
                errors.append(f"{where}: negative dur")
            seen.add(name)
            parent = (ev.get("args") or {}).get("parent")
            if parent is not None and not isinstance(parent, str):
                errors.append(f"{where}: args.parent not a string")
    for name in require_spans:
        if name not in seen:
            errors.append(f"required span {name!r} not present in trace")
    return errors


def validate_trace_file(path, *, require_spans: tuple = ()) -> list[str]:
    try:
        obj = json.loads(Path(path).read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable trace JSON ({e})"]
    return validate_trace(obj, require_spans=require_spans)


def _validate_metric_row(row: dict, where: str) -> list[str]:
    errors = []
    for key in ("name", "type", "labels"):
        if key not in row:
            errors.append(f"{where}: missing {key!r}")
    kind = row.get("type")
    if kind not in ("counter", "gauge", "histogram"):
        errors.append(f"{where}: unknown type {kind!r}")
    if not isinstance(row.get("labels", {}), dict):
        errors.append(f"{where}: labels must be an object")
    if kind in ("counter", "gauge"):
        if not isinstance(row.get("value"), (int, float)):
            errors.append(f"{where}: {kind} missing numeric value")
    elif kind == "histogram":
        for key in ("count", "sum"):
            if not isinstance(row.get(key), (int, float)):
                errors.append(f"{where}: histogram missing numeric {key!r}")
        buckets = row.get("buckets")
        if not isinstance(buckets, dict):
            errors.append(f"{where}: histogram missing buckets object")
        else:
            n_in_buckets = 0
            for le, n in buckets.items():
                try:
                    float(le)
                except ValueError:
                    if le not in ("inf", "+Inf"):
                        errors.append(f"{where}: bucket edge {le!r} "
                                      f"not numeric")
                if not isinstance(n, int) or n < 0:
                    errors.append(f"{where}: bucket count {n!r} invalid")
                else:
                    n_in_buckets += n
            if isinstance(row.get("count"), int) \
                    and n_in_buckets != row["count"]:
                errors.append(f"{where}: bucket counts sum to "
                              f"{n_in_buckets} != count {row['count']}")
    return errors


def validate_metrics_jsonl(path, *, require_families: tuple | None = None
                           ) -> list[str]:
    """Validate a registry JSONL export; ``require_families=None`` applies
    :data:`REQUIRED_METRIC_FAMILIES`, ``()`` disables the floor."""
    if require_families is None:
        require_families = REQUIRED_METRIC_FAMILIES
    try:
        text = Path(path).read_text()
    except OSError as e:
        return [f"{path}: unreadable ({e})"]
    errors: list[str] = []
    seen: set[str] = set()
    lines = [ln for ln in text.splitlines() if ln.strip()]
    if not lines:
        errors.append(f"{path}: empty metrics export")
    for i, line in enumerate(lines):
        where = f"line {i + 1}"
        try:
            row = json.loads(line)
        except json.JSONDecodeError as e:
            errors.append(f"{where}: invalid JSON ({e})")
            continue
        if not isinstance(row, dict):
            errors.append(f"{where}: not an object")
            continue
        errors.extend(_validate_metric_row(row, where))
        if isinstance(row.get("name"), str):
            seen.add(row["name"])
    for fam in require_families:
        if fam not in seen:
            errors.append(f"required metric family {fam!r} missing")
    return errors
