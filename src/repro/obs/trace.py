"""Structured trace spans -> Chrome trace-event JSON (ISSUE 8 tentpole).

One process-wide :data:`TRACER` instruments the request path (service queue
wait, batch formation, per-engine flush, stage-1 scans, tiered double-buffer
chunk streams, HNSW traversals, WAL fsyncs, snapshot writes). Spans are
recorded as Chrome trace-event **complete** events (``"ph": "X"``) and the
export opens directly in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``.

Three recording shapes:

* ``with TRACER.span(name, **args):`` — stack span on the calling thread's
  track; nesting renders automatically (ts/dur containment) and the parent
  span's name is linked in ``args.parent``. ``span.set(**kv)`` merges
  result args (e.g. traversal stats) before the span closes.
* ``h = TRACER.begin(name, track=...); ...; h.end(**kv)`` — **flow** span
  with explicit lifetime on a named synthetic track, for work whose end is
  observed later than (and on a different logical timeline from) the code
  that started it — the tiered double-buffer's host->HBM ``device_put``
  transfers land here, so chunk i+1's transfer visibly overlaps chunk i's
  rescore span in Perfetto.
* ``TRACER.emit(name, t0, t1, **args)`` — after-the-fact span from two
  ``time.perf_counter()`` readings (queue-wait attribution).

**Disabled cost is the design constraint**: ``span()`` / ``begin()`` return
the module-level ``NULL_SPAN`` / ``NULL_HANDLE`` singletons when tracing is
off — no span object is allocated, no clock is read, nothing is appended
(pinned by ``tests/test_obs.py::test_disabled_span_fast_path``). Hot loops
may additionally gate arg construction on ``TRACER.enabled``.

The event buffer is bounded (``max_events``, drops counted in
``dropped_events``) so a forgotten-enabled tracer cannot grow without
bound.
"""
from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path


class _NullSpan:
    """Shared do-nothing span: the disabled-tracing fast path."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **kv):
        return self


class _NullHandle:
    __slots__ = ()

    def end(self, **kv):
        pass


NULL_SPAN = _NullSpan()
NULL_HANDLE = _NullHandle()


class _Span:
    __slots__ = ("_tr", "name", "args", "_t0")

    def __init__(self, tr, name, args):
        self._tr = tr
        self.name = name
        self.args = args

    def set(self, **kv):
        self.args.update(kv)
        return self

    def __enter__(self):
        stack = self._tr._stack()
        if stack:
            self.args.setdefault("parent", stack[-1])
        stack.append(self.name)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        stack = self._tr._stack()
        if stack and stack[-1] == self.name:
            stack.pop()
        self._tr._record(self.name, self._t0, t1,
                         threading.get_ident() & 0x7FFFFFFF, self.args)
        return False


class _Handle:
    """Open flow span on a synthetic track; closed by :meth:`end`."""
    __slots__ = ("_tr", "name", "args", "_t0", "_tid")

    def __init__(self, tr, name, tid, args):
        self._tr = tr
        self.name = name
        self.args = args
        self._tid = tid
        self._t0 = time.perf_counter()

    def end(self, **kv):
        if kv:
            self.args.update(kv)
        self._tr._record(self.name, self._t0, time.perf_counter(),
                         self._tid, self.args)


class Tracer:
    """Bounded in-memory Chrome trace-event recorder."""

    def __init__(self, enabled: bool = False, max_events: int = 1_000_000):
        self.enabled = enabled
        self.max_events = max_events
        self.events: list[dict] = []
        self.dropped_events = 0
        self._epoch = time.perf_counter()
        self._local = threading.local()
        self._tracks: dict[str, int] = {}
        self._lock = threading.Lock()

    def configure(self, enabled: bool | None = None,
                  max_events: int | None = None) -> "Tracer":
        if max_events is not None:
            self.max_events = int(max_events)
        if enabled is not None:
            self.enabled = bool(enabled)
        return self

    def clear(self) -> None:
        with self._lock:
            self.events = []
            self.dropped_events = 0
            self._tracks = {}
            self._epoch = time.perf_counter()

    def _stack(self) -> list:
        s = getattr(self._local, "stack", None)
        if s is None:
            s = self._local.stack = []
        return s

    def _record(self, name, t0, t1, tid, args) -> None:
        if len(self.events) >= self.max_events:
            self.dropped_events += 1
            return
        self.events.append({
            "name": name, "ph": "X", "pid": os.getpid(), "tid": tid,
            "ts": (t0 - self._epoch) * 1e6,
            "dur": max((t1 - t0) * 1e6, 0.0),
            "args": args,
        })

    # -- recording API ------------------------------------------------------
    def span(self, name: str, **args):
        """Context-manager span on the calling thread's track (or the
        no-alloc ``NULL_SPAN`` when disabled)."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, args)

    def begin(self, name: str, track: str | None = None, **args):
        """Open a flow span now; the returned handle's ``.end()`` closes it.
        ``track`` names a synthetic timeline (e.g. ``"h2d-stream"``) so
        concurrent host-side and transfer work render as separate rows."""
        if not self.enabled:
            return NULL_HANDLE
        tid = (self.track(track) if track is not None
               else threading.get_ident() & 0x7FFFFFFF)
        return _Handle(self, name, tid, args)

    def emit(self, name: str, t0: float, t1: float,
             track: str | None = None, **args) -> None:
        """Record a span from two ``time.perf_counter()`` readings."""
        if not self.enabled:
            return
        tid = (self.track(track) if track is not None
               else threading.get_ident() & 0x7FFFFFFF)
        self._record(name, t0, t1, tid, args)

    def track(self, name: str) -> int:
        """Synthetic track id for ``name`` (thread_name metadata emitted
        once so Perfetto labels the row)."""
        tid = self._tracks.get(name)
        if tid is None:
            with self._lock:
                tid = self._tracks.get(name)
                if tid is None:
                    tid = 0x40000000 + len(self._tracks)
                    self._tracks[name] = tid
                    self.events.append({
                        "name": "thread_name", "ph": "M", "pid": os.getpid(),
                        "tid": tid, "ts": 0,
                        "args": {"name": name}})
        return tid

    # -- export -------------------------------------------------------------
    def to_chrome(self) -> dict:
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped_events}}

    def export_chrome(self, path) -> int:
        """Write the Chrome trace-event JSON; returns the event count."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_chrome()))
        return len(self.events)


#: process-wide tracer every instrumentation point records into; disabled
#: (and therefore allocation-free) unless a driver turns it on
TRACER = Tracer()
