"""End-to-end observability (ISSUE 8): metrics registry + trace spans.

* :mod:`repro.obs.metrics` — thread-safe, constant-memory counters /
  gauges / log-bucketed histograms with Prometheus text exposition and
  JSONL export (:class:`MetricsRegistry`; ``NULL_METRICS`` no-op twin).
* :mod:`repro.obs.trace` — process-wide span tracer (:data:`TRACER`)
  exporting Chrome trace-event JSON viewable in Perfetto; disabled spans
  are allocation-free singletons.
* :mod:`repro.obs.schema` — validators for both artifact formats
  (CLI: ``benchmarks/check_obs_schema.py``).

This package deliberately imports nothing from the rest of ``repro`` so
every layer (kernels, engines, serving, WAL) can instrument itself without
import cycles. docs/ARCHITECTURE.md §Observability has the span taxonomy
and overhead budget.
"""
from .metrics import MetricsRegistry, NullMetrics, NULL_METRICS  # noqa: F401
from .trace import Tracer, TRACER, NULL_SPAN, NULL_HANDLE  # noqa: F401
from . import schema  # noqa: F401
