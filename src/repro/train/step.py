"""Train step factory: grad accumulation, optimizer, optional error-feedback
gradient compression — built to be lowered with pjit on the production mesh.

Microbatching via ``lax.scan`` serves two purposes: activation memory (only
one microbatch's activations are live) and compute/communication overlap —
XLA's latency-hiding scheduler overlaps microbatch i+1's compute with the
gradient reduce-scatter of microbatch i when grads are accumulated in a
scan carry (the canonical MaxText pattern).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .. import models
from ..distributed.compression import EFState, ef_compress_grads, ef_init
from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update, opt_specs


@dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    n_microbatches: int = 1
    grad_compression: bool = False     # error-feedback int8 on the DP path
    remat: bool = True
    # --- perf-iteration knobs (EXPERIMENTS.md §Perf) ---
    gather_weights_once: bool = False  # hoist the FSDP weight all-gather out
    #   of the microbatch loop: one AG per step instead of one per microbatch
    #   (trades HBM for ICI; only viable when full bf16 weights fit per chip)
    moments_bf16: bool = False         # AdamW m/v in bf16: halves opt-state
    #   HBM and its read/write traffic (stochastic-rounding-free variant;
    #   convergence impact measured in tests)
    grad_accum_bf16: bool = False      # accumulate microbatch grads in bf16
    remat_policy: str | None = None    # None | "save_tp" (§Perf iter 4b)


def _unshard_dp(params, pspecs):
    """Force params to be replicated over the DP axes (keep TP sharding) —
    a single all-gather at the step boundary."""
    from jax.sharding import PartitionSpec as P
    from ..models.sharding import constrain

    def strip(sp):
        return [None if (a in ("pod", "data") or
                         (isinstance(a, (tuple, list)) and
                          set(a) & {"pod", "data"})) else a for a in sp]

    def one(x, sp):
        axes = strip(sp) + [None] * (x.ndim - len(sp))
        return constrain(x, *axes[:x.ndim])

    return jax.tree.map(one, params, pspecs)


def make_train_step(cfg, tcfg: TrainConfig):
    """Returns step(train_state, batch) -> (train_state, metrics).

    train_state = (params, opt_state, ef_state|None)."""
    loss_fn = models.train_loss(cfg, remat_policy=tcfg.remat_policy)
    acc_dtype = jnp.bfloat16 if tcfg.grad_accum_bf16 else jnp.float32

    def compute_grads(params, batch):
        if tcfg.gather_weights_once:
            _, pspecs = models.abstract_params(cfg)
            params = _unshard_dp(params, pspecs)
        if tcfg.n_microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        nm = tcfg.n_microbatches
        b = batch["tokens"].shape[0]
        assert b % nm == 0, (b, nm)

        def micro(c, mb):
            loss_acc, g_acc = c
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            return (loss_acc + l,
                    jax.tree.map(lambda a, x: a + x.astype(acc_dtype), g_acc, g)), None

        mbs = jax.tree.map(lambda x: x.reshape(nm, b // nm, *x.shape[1:]), batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype), params)
        (loss, grads), _ = jax.lax.scan(micro, (jnp.float32(0.0), zero), mbs)
        inv = 1.0 / nm
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def step(state, batch):
        params, opt_state, ef = state
        loss, grads = compute_grads(params, batch)
        if tcfg.grad_compression:
            grads, ef = ef_compress_grads(grads, ef)
        params, opt_state, metrics = adamw_update(tcfg.opt, grads, opt_state, params)
        metrics["loss"] = loss
        return (params, opt_state, ef), metrics

    return step


def init_train_state(cfg, tcfg: TrainConfig, rng):
    params_sp = models.init_params(cfg, rng)
    params, _ = models.split(params_sp)
    opt_state = adamw_init(params, jnp.bfloat16 if tcfg.moments_bf16
                           else jnp.float32)
    ef = ef_init(params) if tcfg.grad_compression else None
    return (params, opt_state, ef)


def train_state_specs(cfg, tcfg: TrainConfig):
    _, pspecs = models.abstract_params(cfg)
    ospecs = opt_specs(pspecs)
    efspecs = EFState(error=pspecs) if tcfg.grad_compression else None
    return (pspecs, ospecs, efspecs)


def abstract_train_state(cfg, tcfg: TrainConfig):
    """ShapeDtypeStruct train state (no allocation) for the dry-run."""
    return jax.eval_shape(lambda: init_train_state(cfg, tcfg, jax.random.key(0)))
