from .optimizer import AdamWConfig, adamw_init, adamw_update, opt_specs  # noqa: F401
from .step import make_train_step, TrainConfig  # noqa: F401
