"""AdamW with decoupled weight decay — implemented from scratch (no optax).

Optimizer moments are f32 and sharded exactly like their parameters; since
params are FSDP-sharded over ``data`` (DESIGN.md §6) this is ZeRO-1 for
free: each data shard owns 1/|data| of the optimizer state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def adamw_init(params, moments_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, moments_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def opt_specs(param_specs) -> AdamWState:
    """Moment shardings mirror the parameter shardings (ZeRO-1)."""
    from jax.sharding import PartitionSpec as P
    return AdamWState(step=P(), m=param_specs, v=param_specs)


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step.astype(jnp.float32) - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = _schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh = m_new / b1c
        vh = v_new / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m_new.astype(m.dtype), v_new.astype(v.dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
