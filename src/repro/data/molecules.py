"""Synthetic ChEMBL-like fingerprint generator (DESIGN.md §4).

ChEMBL 27.1 + RDKit are unavailable offline; the paper itself models the
database popcount distribution as Gaussian (Eq. 3). We generate 1024-bit
prints whose popcount ~ N(mu=62, sigma=22) (clipped), with *scaffold
structure*: molecules are drawn from clusters, each cluster sharing a base
bit pattern with per-molecule mutations. This keeps nearest-neighbour
structure realistic (without clusters, i.i.d. prints make every search
algorithm look artificially good/bad).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.fingerprints import pack_bits


@dataclass(frozen=True)
class SyntheticConfig:
    n: int = 100_000
    length: int = 1024
    mu: float = 62.0          # ChEMBL Morgan-1024 mean popcount (paper Eq. 3 fit)
    sigma: float = 22.0
    n_scaffolds: int = 0      # 0 -> n // 50
    scaffold_keep: float = 0.7  # fraction of bits inherited from the scaffold
    bit_skew: float = 0.0     # optional zipf-ish exponent of the per-bit
    #   frequency distribution (0 = uniform, like hash-based Morgan bits).
    #   NOTE on paper Table I: the paper measures strided folding (scheme 1)
    #   beating adjacent folding (scheme 2) on real ChEMBL prints. That gap
    #   depends on RDKit's actual bit-layout correlations, which no synthetic
    #   layout reproduces faithfully: under uniform bits the two schemes are
    #   statistically identical (verified), and under popularity-sorted
    #   layouts scheme 2 can even win. We reproduce the scheme-independent
    #   claims (accuracy vs m trend, two-stage rescore recovery) and document
    #   this as a data-fidelity gap — see EXPERIMENTS.md §Table I.
    seed: int = 0


def _bit_probs(cfg) -> np.ndarray:
    L = cfg.length
    if cfg.bit_skew <= 0:
        return np.full(L, 1.0 / L)
    p = 1.0 / np.power(np.arange(L) + 8.0, cfg.bit_skew)
    return p / p.sum()


def synthetic_fingerprints(cfg: SyntheticConfig) -> np.ndarray:
    """Returns packed (n, length//32) uint32 fingerprints."""
    rng = np.random.default_rng(cfg.seed)
    n_scaf = cfg.n_scaffolds or max(cfg.n // 50, 1)
    L = cfg.length
    probs = _bit_probs(cfg)

    # scaffold base patterns: popcount drawn from the Gaussian model,
    # bit positions drawn from the skewed frequency law
    scaf_counts = np.clip(rng.normal(cfg.mu, cfg.sigma, n_scaf), 8, L // 4).astype(np.int64)
    scaffolds = np.zeros((n_scaf, L), dtype=np.uint8)
    for i, c in enumerate(scaf_counts):
        scaffolds[i, rng.choice(L, size=c, replace=False, p=probs)] = 1

    assign = rng.integers(0, n_scaf, size=cfg.n)
    base = scaffolds[assign]

    # per-molecule: keep `scaffold_keep` of scaffold bits, add fresh feature bits
    keep_mask = rng.random((cfg.n, L)) < cfg.scaffold_keep
    bits = (base & keep_mask).astype(np.uint8)
    target = np.clip(rng.normal(cfg.mu, cfg.sigma, cfg.n), 8, L // 4).astype(np.int64)
    deficit = np.maximum(target - bits.sum(axis=1, dtype=np.int64), 0).astype(np.int64)
    # add extra bits, frequency-weighted (vectorised: weighted random scores,
    # take the top-deficit new bits per row)
    noise = (rng.random((cfg.n, L)) ** (1.0 / np.maximum(probs * L, 1e-9))) * (1 - bits)
    thresh = -np.sort(-noise, axis=1)[np.arange(cfg.n), np.minimum(deficit, L - 1)]
    bits |= (noise > thresh[:, None]).astype(np.uint8)
    return pack_bits(bits)


def queries_from_db(db: np.ndarray, n_queries: int, seed: int = 1) -> np.ndarray:
    """Paper-style query set: random database members (self-hit included in
    ground truth, as in the ChEMBL benchmarks). Asking for more queries than
    the database holds falls back to sampling with replacement (and warns)
    instead of crashing — small serve/CI configs hit this routinely."""
    rng = np.random.default_rng(seed)
    n = db.shape[0]
    replace = n_queries > n
    if replace:
        import warnings
        warnings.warn(
            f"queries_from_db: {n_queries} queries requested from a database "
            f"of {n}; sampling with replacement", stacklevel=2)
    idx = rng.choice(n, size=n_queries, replace=replace)
    return np.asarray(db)[idx]
