"""Deterministic synthetic LM token pipeline.

Every batch is a pure function of (seed, step) — this is the straggler /
elastic-restart story: any worker can regenerate any step's shard without
coordination, and skip-ahead after a restore is free (DESIGN.md §6).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def batch_for_step(cfg: DataConfig, step: int, arch_cfg=None) -> dict:
    """Host-side batch generation (numpy; cheap, deterministic)."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    # zipf-ish marginal so the loss curve is non-trivial
    z = rng.zipf(1.3, size=(cfg.global_batch, cfg.seq_len + 1))
    toks = np.minimum(z - 1, cfg.vocab - 1).astype(np.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if arch_cfg is not None and arch_cfg.family == "audio":
        batch["audio_embed"] = rng.normal(
            0, 1, (cfg.global_batch, arch_cfg.n_audio_frames, arch_cfg.d_model)
        ).astype(np.float32)
    if arch_cfg is not None and arch_cfg.family == "vlm":
        batch["patch_embed"] = rng.normal(
            0, 1, (cfg.global_batch, arch_cfg.n_patches, arch_cfg.d_model)
        ).astype(np.float32)
    return batch
