from .molecules import synthetic_fingerprints, SyntheticConfig  # noqa: F401
