"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.fingerprints import Metric, TANIMOTO, metric_from_counts, popcount


def tanimoto_scores_ref(queries: jax.Array, db: jax.Array,
                        db_popcount: jax.Array | None = None,
                        metric: Metric = TANIMOTO) -> jax.Array:
    """(Q, W) x (N, W) -> (Q, N) float32 score matrix (Tanimoto default)."""
    if db_popcount is None:
        db_popcount = popcount(db)
    q_cnt = popcount(queries)
    inter = jnp.sum(
        jax.lax.population_count(queries[:, None, :] & db[None, :, :]).astype(jnp.int32),
        axis=-1)
    return metric_from_counts(metric, inter, q_cnt[:, None], db_popcount[None, :])


def tanimoto_topk_ref(queries: jax.Array, db: jax.Array, k: int,
                      db_popcount: jax.Array | None = None,
                      metric: Metric = TANIMOTO):
    """Oracle for the fused on-the-fly engine: exact top-k ids + scores."""
    scores = tanimoto_scores_ref(queries, db, db_popcount, metric=metric)
    vals, ids = jax.lax.top_k(scores, k)
    return ids.astype(jnp.int32), vals


def bitbound_topk_ref(queries: jax.Array, db_sorted: jax.Array,
                      counts_sorted: jax.Array, k: int, cutoff: float,
                      metric: Metric = TANIMOTO):
    """Oracle for the BitBound-pruned kernel: scores outside the metric's
    popcount window (Tanimoto: Eq.2) are treated as -inf (never returned)."""
    scores = tanimoto_scores_ref(queries, db_sorted, counts_sorted,
                                 metric=metric)
    a = popcount(queries).astype(jnp.float32)
    if metric.name == "tanimoto":
        lo = jnp.ceil(a * cutoff)[:, None]
        hi = jnp.floor(a / max(cutoff, 1e-6))[:, None]
    else:
        lo_r, hi_r = metric.bound_ratios(cutoff)
        lo = (jnp.ceil(a * lo_r) if metric.bounded_below
              else jnp.zeros_like(a))[:, None]
        hi = (jnp.floor(a * hi_r) if metric.bounded_above
              else jnp.full_like(a, 2.0**30))[:, None]
    c = counts_sorted[None, :].astype(jnp.float32)
    in_range = jnp.logical_and(c >= lo, c <= hi)
    scores = jnp.where(in_range, scores, -jnp.inf)
    vals, ids = jax.lax.top_k(scores, k)
    ids = jnp.where(jnp.isfinite(vals), ids, -1)
    return ids.astype(jnp.int32), vals


def window_topk_ref(queries: jax.Array, db: jax.Array, db_cnt: jax.Array,
                    lo_row: jax.Array, hi_row: jax.Array, k: int,
                    metric: Metric = TANIMOTO):
    """Oracle for the row-window kernel: rows outside [lo_row, hi_row) are
    -inf (never returned); invalid slots come back as id -1."""
    scores = tanimoto_scores_ref(queries, db, db_cnt, metric=metric)
    idx = jnp.arange(db.shape[0])[None, :]
    in_window = jnp.logical_and(idx >= lo_row[:, None], idx < hi_row[:, None])
    scores = jnp.where(in_window, scores, -jnp.inf)
    vals, ids = jax.lax.top_k(scores, k)
    ids = jnp.where(jnp.isfinite(vals), ids, -1)
    return ids.astype(jnp.int32), vals


def gather_tanimoto_ref(queries: jax.Array, db: jax.Array,
                        ids: jax.Array,
                        metric: Metric = TANIMOTO) -> jax.Array:
    """Oracle for the gather-distance kernel: (Q, W) x (Q, E) ids -> (Q, E)
    sims, with -inf wherever id < 0."""
    safe = jnp.clip(ids, 0, db.shape[0] - 1)
    rows = db[safe]                                     # (Q, E, W)
    q_cnt = popcount(queries)
    inter = jnp.sum(jax.lax.population_count(
        queries[:, None, :] & rows).astype(jnp.int32), axis=-1)
    s = metric_from_counts(metric, inter, q_cnt[:, None], popcount(db)[safe])
    return jnp.where(ids >= 0, s, -jnp.inf)


def expand_sorted_ref(queries: jax.Array, nbr_fps: jax.Array,
                      nbr_cnt: jax.Array, pop_ids: jax.Array,
                      flat_ids: jax.Array, worst: jax.Array, kk: int,
                      metric: Metric = TANIMOTO):
    """Oracle for the fused beam-expansion kernel (``kernels/expand.py``):
    score every neighbour block of the popped beam, mask ``-1`` flat ids and
    scores ``<= worst``, return the top-``kk`` per query sorted descending
    (-inf / -1 in the empty tail)."""
    q_n = queries.shape[0]
    safe = jnp.clip(pop_ids, 0, nbr_fps.shape[0] - 1)
    blk = nbr_fps[safe]                                 # (Q, B, 2M, W)
    q_cnt = popcount(queries)
    inter = jnp.sum(jax.lax.population_count(
        queries[:, None, None, :] & blk).astype(jnp.int32), axis=-1)
    s = metric_from_counts(metric, inter, q_cnt[:, None, None], nbr_cnt[safe])
    s = s.reshape(q_n, -1)
    s = jnp.where(flat_ids >= 0, s, -jnp.inf)
    s = jnp.where(s > worst[:, None], s, -jnp.inf)
    ids = jnp.where(s > -jnp.inf, flat_ids, -1)
    s_srt, pos = jax.lax.top_k(s, kk)
    return s_srt, jnp.take_along_axis(ids, pos, axis=1)


def bitcount_ref(words: jax.Array) -> jax.Array:
    return popcount(words)
