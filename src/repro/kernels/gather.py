"""Fused gather-distance Pallas kernel — the fine-grained distance engine of
the HNSW traversal path (paper §III-C: the distance calculation unit feeding
the graph-walk priority queues).

FPGA -> TPU mapping:

* On the FPGA, beam expansion issues the popped vertices' adjacency lists to
  a fine-grained distance engine: each neighbour id becomes one HBM fetch of
  a single fingerprint, pipelined through BitCnt -> TFC at initiation
  interval 1, and the scores stream straight into the register-array PQs.
* Here the candidate id matrix ``(Q, E)`` is a **scalar-prefetch** operand:
  the grid is ``(Q, E)`` and the database BlockSpec's ``index_map`` reads
  ``ids[q, e]`` to DMA exactly that fingerprint row HBM->VMEM — a true
  data-dependent gather, the Pallas analogue of the FPGA's address generator.
  Per grid step the kernel computes one popcount-Tanimoto (the row's BitCnt
  is recomputed in-register — W words, cheaper than a second gather of the
  precomputed count) and accumulates it into a per-query VMEM row of E
  scores, emitted once on the last step.
* Validity masking: id ``-1`` marks padded / already-visited / masked-out
  neighbours. The index_map clamps them to row 0 (the fetch must still be
  addressable) and the body overwrites their score with ``-inf`` so the PQ
  merge downstream never admits them.

The kernel is jit-compatible and is launched from *inside* the traversal's
``lax.while_loop`` — one launch scores a whole beam expansion (B·2M
neighbours for every query in the batch), which is what amortises traversal
overhead vs. per-candidate dispatch.

Validated with ``interpret=True`` on CPU against ``ref.gather_tanimoto_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.fingerprints import Metric, TANIMOTO, metric_from_counts

NEG = float("-inf")  # python scalar: must not be a captured jnp constant


def _gather_body(ids_ref, q_ref, qcnt_ref, row_ref, out_ref, s_buf,
                 *, n_cand: int, metric: Metric = TANIMOTO):
    qi = pl.program_id(0)
    e = pl.program_id(1)

    @pl.when(e == 0)
    def _init():
        s_buf[...] = jnp.full((1, n_cand), NEG, jnp.float32)

    q = q_ref[0, :]                                     # (W,) uint32
    row = row_ref[0, :]                                 # (W,) gathered print
    inter = jnp.sum(jax.lax.population_count(q & row).astype(jnp.int32))
    cnt = jnp.sum(jax.lax.population_count(row).astype(jnp.int32))
    s = metric_from_counts(metric, inter, qcnt_ref[0], cnt)
    s = jnp.where(ids_ref[qi, e] >= 0, s, NEG)          # validity mask
    lane = jax.lax.iota(jnp.int32, n_cand)
    s_buf[0, :] = jnp.where(lane == e, s, s_buf[0, :])

    @pl.when(e == n_cand - 1)
    def _emit():
        out_ref[0, :] = s_buf[0, :]


def gather_tanimoto_scores(queries: jax.Array, q_cnt: jax.Array,
                           db: jax.Array, ids: jax.Array,
                           interpret: bool = True,
                           metric: Metric = TANIMOTO) -> jax.Array:
    """queries (Q, W) u32, q_cnt (Q,) i32, db (N, W) u32, ids (Q, E) i32.

    Returns sims (Q, E) f32: sim(query_q, db[ids[q, e]]) under ``metric``
    (Tanimoto by default), with ``-inf`` wherever ``ids[q, e] < 0``. The DB
    stays in HBM; only the E gathered rows per query cross into VMEM.
    """
    q_n, w = queries.shape
    e_n = ids.shape[1]
    n = db.shape[0]

    def row_index(q, e, ids_ref):
        # clamp invalid (-1) and out-of-range ids to an addressable row; the
        # body masks their score to -inf, so the fetched data is never used
        return (jnp.clip(ids_ref[q, e], 0, n - 1), 0)

    body = functools.partial(_gather_body, n_cand=e_n, metric=metric)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(q_n, e_n),
        in_specs=[
            pl.BlockSpec((1, w), lambda q, e, ids_ref: (q, 0)),   # query row
            pl.BlockSpec((1,), lambda q, e, ids_ref: (q,)),       # query count
            pl.BlockSpec((1, w), row_index),                      # gathered row
        ],
        out_specs=pl.BlockSpec((1, e_n), lambda q, e, ids_ref: (q, 0)),
        scratch_shapes=[pltpu.VMEM((1, e_n), jnp.float32)],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((q_n, e_n), jnp.float32),
        interpret=interpret,
    )(ids.astype(jnp.int32), queries, q_cnt, db)
