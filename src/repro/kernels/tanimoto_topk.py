"""Fused Tanimoto-scan + streaming top-k Pallas kernel — the "on-the-fly"
query engine of the paper, TPU-native (DESIGN.md §2).

Design (FPGA -> TPU mapping):

* The FPGA cascades BitCnt -> TFC -> top-K-merge-sort FIFOs with pipeline
  interval 1, so a score is consumed by the sorter the cycle it's produced
  and the N-element score stream never exists in off-chip memory.
* Here the database is streamed HBM->VMEM in ``(TILE_N, W)`` BlockSpec tiles;
  each grid step computes the tile's scores in vector registers and merges
  them into a **persistent VMEM top-k scratch** — the scores never get
  written back to HBM. Only the final (k,) result leaves the chip, so HBM
  traffic is exactly one read of the database: the kernel is at the
  streaming-bandwidth roofline by construction (measured in EXPERIMENTS.md).
* The top-k merge uses a sort-based combine (``lax.top_k`` over the
  ``k + TILE_N`` candidate window), the constant-shape analogue of the
  paper's merge-sort unit; resource use scales O(k + TILE_N) like the
  paper's O(log k) comparator tree scales with stream width.
* The BitBound variant adds scalar-prefetched per-query tile windows
  ``(lo_tile, n_tiles)``: the grid is sized for the *worst-case* Eq.2 window
  and the ``index_map`` offsets DB tile fetches by ``lo_tile[q]`` — the TPU
  analogue of the FPGA engine fetching only the popcount-bounded address
  range from HBM. Tiles beyond the query's window are masked via ``pl.when``
  (fetch suppressed by clamping the index map to a single repeated tile).

VMEM budget (v5e ~16 MiB/core): tile (TILE_N=2048, W=32) uint32 = 256 KiB,
plus (k + TILE_N) merge window and (1, k) scratch — comfortably resident
with double-buffering of the DB stream.

Validated with ``interpret=True`` on CPU against ``ref.py``; ``lax.top_k``
and ``population_count`` lower on TPU Mosaic (top_k via sort).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.fingerprints import Metric, TANIMOTO, metric_from_counts

DEFAULT_TILE_N = 2048
NEG = float("-inf")  # python scalar: must not be a captured jnp constant


# ---------------------------------------------------------------------------
# full-scan fused kernel (brute force / folded scan)
# ---------------------------------------------------------------------------

def _fused_body(q_ref, qcnt_ref, db_ref, dbcnt_ref, ids_ref, vals_ref,
                top_s, top_i, *, k: int, tile_n: int, n_tiles: int, n_valid: int,
                metric: Metric = TANIMOTO):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        top_s[...] = jnp.full((1, k), NEG, jnp.float32)
        top_i[...] = jnp.full((1, k), -1, jnp.int32)

    q = q_ref[0, :]                                    # (W,) uint32
    db = db_ref[...]                                   # (tile_n, W) uint32
    # TFC stage: popcount(AND) and precomputed db counts (BitCnt runs on the
    # query only, as in the paper); the metric maps the (a, b, c) triple to a
    # score at trace time — Tanimoto emits the exact historical op sequence.
    inter = jnp.sum(jax.lax.population_count(q[None, :] & db).astype(jnp.int32),
                    axis=-1)                           # (tile_n,)
    s = metric_from_counts(metric, inter, qcnt_ref[0], dbcnt_ref[...])
    idx = t * tile_n + jax.lax.iota(jnp.int32, tile_n)
    s = jnp.where(idx < n_valid, s, NEG)               # mask padded tail rows
    # top-K merge stage: sort-based combine with the persistent scratch
    all_s = jnp.concatenate([top_s[0, :], s])
    all_i = jnp.concatenate([top_i[0, :], idx])
    new_s, pos = jax.lax.top_k(all_s, k)
    top_s[0, :] = new_s
    top_i[0, :] = all_i[pos]

    @pl.when(t == n_tiles - 1)
    def _emit():
        vals_ref[0, :] = top_s[0, :]
        ids_ref[0, :] = top_i[0, :]


def fused_tanimoto_topk(queries: jax.Array, db: jax.Array, db_cnt: jax.Array,
                        k: int, n_valid: int, tile_n: int = DEFAULT_TILE_N,
                        interpret: bool = True, metric: Metric = TANIMOTO):
    """queries (Q, W) u32, db (N_pad, W) u32, db_cnt (N_pad,) i32 (padded to a
    tile multiple; ``db_cnt`` may be any value in the pad — masking is by row
    index vs ``n_valid``). Returns ids (Q, k) i32, vals (Q, k) f32."""
    q_n, w = queries.shape
    n_pad = db.shape[0]
    assert n_pad % tile_n == 0, (n_pad, tile_n)
    n_tiles = n_pad // tile_n
    q_cnt = jnp.sum(jax.lax.population_count(queries).astype(jnp.int32), axis=-1)

    body = functools.partial(_fused_body, k=k, tile_n=tile_n, n_tiles=n_tiles,
                             n_valid=n_valid, metric=metric)
    out = pl.pallas_call(
        body,
        grid=(q_n, n_tiles),
        in_specs=[
            pl.BlockSpec((1, w), lambda q, t: (q, 0)),          # query row
            pl.BlockSpec((1,), lambda q, t: (q,)),              # query popcount
            pl.BlockSpec((tile_n, w), lambda q, t: (t, 0)),     # DB tile stream
            pl.BlockSpec((tile_n,), lambda q, t: (t,)),         # DB popcounts
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda q, t: (q, 0)),
            pl.BlockSpec((1, k), lambda q, t: (q, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q_n, k), jnp.int32),
            jax.ShapeDtypeStruct((q_n, k), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, k), jnp.float32),
            pltpu.VMEM((1, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, q_cnt, db, db_cnt)
    return out[0], out[1]


# ---------------------------------------------------------------------------
# BitBound-windowed fused kernel (scalar-prefetched Eq.2 range)
# ---------------------------------------------------------------------------

def _bitbound_body(lo_ref, nt_ref, q_ref, qcnt_ref, db_ref, dbcnt_ref,
                   ids_ref, vals_ref, top_s, top_i,
                   *, k: int, tile_n: int, max_tiles: int, n_valid: int,
                   cutoff: float, metric: Metric = TANIMOTO):
    qi = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        top_s[...] = jnp.full((1, k), NEG, jnp.float32)
        top_i[...] = jnp.full((1, k), -1, jnp.int32)

    active = t < nt_ref[qi]

    @pl.when(active)
    def _scan():
        q = q_ref[0, :]
        db = db_ref[...]
        inter = jnp.sum(jax.lax.population_count(q[None, :] & db).astype(jnp.int32),
                        axis=-1)
        s = metric_from_counts(metric, inter, qcnt_ref[0], dbcnt_ref[...])
        idx = (lo_ref[qi] + t) * tile_n + jax.lax.iota(jnp.int32, tile_n)
        s = jnp.where(idx < n_valid, s, NEG)
        # strict bound mask: tile-aligned windows over-fetch boundary rows;
        # rows whose popcount is outside the metric's window (Tanimoto:
        # Eq.2 [a*Sc, a/Sc]) are never candidates. ``bound_ratios`` is a
        # trace-time constant, so non-Tanimoto metrics cost the same mask.
        a = qcnt_ref[0].astype(jnp.float32)
        if metric.name == "tanimoto":
            lo_cnt = jnp.ceil(a * cutoff)
            hi_cnt = jnp.floor(a / max(cutoff, 1e-6))
        else:
            lo_r, hi_r = metric.bound_ratios(cutoff)
            lo_cnt = jnp.ceil(a * lo_r) if metric.bounded_below else jnp.float32(0.0)
            hi_cnt = (jnp.floor(a * hi_r) if metric.bounded_above
                      else jnp.float32(2.0**30))
        c = dbcnt_ref[...].astype(jnp.float32)
        s = jnp.where(jnp.logical_and(c >= lo_cnt, c <= hi_cnt), s, NEG)
        all_s = jnp.concatenate([top_s[0, :], s])
        all_i = jnp.concatenate([top_i[0, :], idx])
        new_s, pos = jax.lax.top_k(all_s, k)
        top_s[0, :] = new_s
        top_i[0, :] = all_i[pos]

    @pl.when(t == max_tiles - 1)
    def _emit():
        vals_ref[0, :] = top_s[0, :]
        ids_ref[0, :] = top_i[0, :]


def bitbound_fused_topk(queries: jax.Array, db_sorted: jax.Array,
                        dbcnt_sorted: jax.Array, lo_tile: jax.Array,
                        n_tiles_q: jax.Array, k: int, max_tiles: int,
                        n_valid: int, cutoff: float,
                        tile_n: int = DEFAULT_TILE_N,
                        interpret: bool = True, metric: Metric = TANIMOTO):
    """Scan only each query's Eq.2 tile window of the popcount-sorted DB.

    lo_tile, n_tiles_q: (Q,) int32 scalar-prefetched window per query.
    ``max_tiles`` is the static worst-case window (from the Gaussian model or
    simply the full DB). Returned ids index into the *sorted* DB."""
    q_n, w = queries.shape
    n_pad = db_sorted.shape[0]
    total_tiles = n_pad // tile_n
    q_cnt = jnp.sum(jax.lax.population_count(queries).astype(jnp.int32), axis=-1)

    def db_index(q, t, lo_ref, nt_ref):
        # clamp: inactive tiles re-fetch the window's first tile (cheap, masked)
        blk = jnp.where(t < nt_ref[q], lo_ref[q] + t, lo_ref[q])
        return (jnp.minimum(blk, total_tiles - 1), 0)

    def cnt_index(q, t, lo_ref, nt_ref):
        blk = jnp.where(t < nt_ref[q], lo_ref[q] + t, lo_ref[q])
        return (jnp.minimum(blk, total_tiles - 1),)

    body = functools.partial(_bitbound_body, k=k, tile_n=tile_n,
                             max_tiles=max_tiles, n_valid=n_valid,
                             cutoff=cutoff, metric=metric)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(q_n, max_tiles),
        in_specs=[
            pl.BlockSpec((1, w), lambda q, t, lo, nt: (q, 0)),
            pl.BlockSpec((1,), lambda q, t, lo, nt: (q,)),
            pl.BlockSpec((tile_n, w), db_index),
            pl.BlockSpec((tile_n,), cnt_index),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda q, t, lo, nt: (q, 0)),
            pl.BlockSpec((1, k), lambda q, t, lo, nt: (q, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, k), jnp.float32),
            pltpu.VMEM((1, k), jnp.int32),
        ],
    )
    out = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((q_n, k), jnp.int32),
            jax.ShapeDtypeStruct((q_n, k), jnp.float32),
        ],
        interpret=interpret,
    )(lo_tile.astype(jnp.int32), n_tiles_q.astype(jnp.int32),
      queries, q_cnt, db_sorted, dbcnt_sorted)
    return out[0], out[1]


# ---------------------------------------------------------------------------
# row-window fused kernel (stage 1 of the device-resident two-stage engine)
# ---------------------------------------------------------------------------
#
# Same scalar-prefetched tile streaming as the BitBound kernel above, but the
# valid region is an explicit per-query row interval [lo_row, hi_row) instead
# of a popcount-vs-cutoff predicate. That is what the folded stage-1 scan
# needs: the Eq.2 window is defined on *full-resolution* popcounts (the sort
# key of the database), while the streamed tiles hold the *folded* prints —
# the folded popcounts say nothing about window membership. Because the DB is
# popcount-sorted, the row interval IS the Eq.2 set, exactly.

def _window_body(lo_t_ref, nt_ref, lo_ref, hi_ref, q_ref, qcnt_ref, db_ref,
                 dbcnt_ref, ids_ref, vals_ref, top_s, top_i,
                 *, k: int, tile_n: int, max_tiles: int, n_valid: int,
                 metric: Metric = TANIMOTO):
    qi = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        top_s[...] = jnp.full((1, k), NEG, jnp.float32)
        top_i[...] = jnp.full((1, k), -1, jnp.int32)

    active = t < nt_ref[qi]

    @pl.when(active)
    def _scan():
        q = q_ref[0, :]
        db = db_ref[...]
        inter = jnp.sum(jax.lax.population_count(q[None, :] & db).astype(jnp.int32),
                        axis=-1)
        s = metric_from_counts(metric, inter, qcnt_ref[0], dbcnt_ref[...])
        idx = (lo_t_ref[qi] + t) * tile_n + jax.lax.iota(jnp.int32, tile_n)
        in_window = jnp.logical_and(idx >= lo_ref[qi], idx < hi_ref[qi])
        s = jnp.where(jnp.logical_and(in_window, idx < n_valid), s, NEG)
        all_s = jnp.concatenate([top_s[0, :], s])
        all_i = jnp.concatenate([top_i[0, :], idx])
        new_s, pos = jax.lax.top_k(all_s, k)
        top_s[0, :] = new_s
        top_i[0, :] = all_i[pos]

    @pl.when(t == max_tiles - 1)
    def _emit():
        vals_ref[0, :] = top_s[0, :]
        ids_ref[0, :] = top_i[0, :]


def windowed_fused_topk(queries: jax.Array, db: jax.Array, db_cnt: jax.Array,
                        lo_tile: jax.Array, n_tiles_q: jax.Array,
                        lo_row: jax.Array, hi_row: jax.Array, k: int,
                        max_tiles: int, n_valid: int,
                        tile_n: int = DEFAULT_TILE_N, interpret: bool = True,
                        metric: Metric = TANIMOTO):
    """Scan only rows [lo_row[q], hi_row[q]) of ``db`` for each query.

    lo_tile, n_tiles_q: (Q,) int32 tile window covering the row interval;
    lo_row, hi_row: (Q,) int32 exact row bounds (boundary rows of partially
    covered tiles are masked). ``db`` may be the folded database while the
    bounds come from the full-resolution popcount sort. Returns ids into the
    (sorted) DB and similarity values; empty slots are id -1 / val -inf."""
    q_n, w = queries.shape
    n_pad = db.shape[0]
    total_tiles = n_pad // tile_n
    q_cnt = jnp.sum(jax.lax.population_count(queries).astype(jnp.int32), axis=-1)

    def db_index(q, t, lo_t, nt, lo, hi):
        blk = jnp.where(t < nt[q], lo_t[q] + t, lo_t[q])
        return (jnp.minimum(blk, total_tiles - 1), 0)

    def cnt_index(q, t, lo_t, nt, lo, hi):
        blk = jnp.where(t < nt[q], lo_t[q] + t, lo_t[q])
        return (jnp.minimum(blk, total_tiles - 1),)

    body = functools.partial(_window_body, k=k, tile_n=tile_n,
                             max_tiles=max_tiles, n_valid=n_valid,
                             metric=metric)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(q_n, max_tiles),
        in_specs=[
            pl.BlockSpec((1, w), lambda q, t, lo_t, nt, lo, hi: (q, 0)),
            pl.BlockSpec((1,), lambda q, t, lo_t, nt, lo, hi: (q,)),
            pl.BlockSpec((tile_n, w), db_index),
            pl.BlockSpec((tile_n,), cnt_index),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda q, t, lo_t, nt, lo, hi: (q, 0)),
            pl.BlockSpec((1, k), lambda q, t, lo_t, nt, lo, hi: (q, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, k), jnp.float32),
            pltpu.VMEM((1, k), jnp.int32),
        ],
    )
    out = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((q_n, k), jnp.int32),
            jax.ShapeDtypeStruct((q_n, k), jnp.float32),
        ],
        interpret=interpret,
    )(lo_tile.astype(jnp.int32), n_tiles_q.astype(jnp.int32),
      lo_row.astype(jnp.int32), hi_row.astype(jnp.int32),
      queries, q_cnt, db, db_cnt)
    return out[0], out[1]


# ---------------------------------------------------------------------------
# standalone BitCnt kernel (paper module 1) — mostly pedagogical; the fused
# engine precomputes DB counts and counts queries inline.
# ---------------------------------------------------------------------------

def _bitcount_body(w_ref, o_ref):
    o_ref[...] = jnp.sum(jax.lax.population_count(w_ref[...]).astype(jnp.int32),
                         axis=-1)


def bitcount(words: jax.Array, tile_n: int = 4096, interpret: bool = True):
    """(N, W) uint32 -> (N,) int32 popcounts, tiled through VMEM."""
    n, w = words.shape
    pad = (-n) % tile_n
    wp = jnp.pad(words, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _bitcount_body,
        grid=(wp.shape[0] // tile_n,),
        in_specs=[pl.BlockSpec((tile_n, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((wp.shape[0],), jnp.int32),
        interpret=interpret,
    )(wp)
    return out[:n]


# ---------------------------------------------------------------------------
# query-blocked fused kernel (beyond-paper: amortise the DB stream)
# ---------------------------------------------------------------------------
#
# The paper's engine (and the kernel above) streams the database once PER
# QUERY: bytes/query = N * 128 B, so a bandwidth-bound chip serves
# HBM_bw / (N * 128) QPS. Batching QB queries into one sweep streams the DB
# once per BLOCK: bytes/query /= QB, while the per-tile compute grows only
# by the (cheap) popcount ops — the scan stays memory-bound up to QB ~ 48
# (arithmetic intensity rises ~3 ops/B per query). At QB=32 a v5e chip
# serves ~32x the single-query QPS on the same roofline. The FPGA analogue
# would be replicating the TFC+top-k pipeline behind one HBM channel — the
# paper's multi-engine design folded into one data stream.

def _blocked_body(q_ref, qcnt_ref, db_ref, dbcnt_ref, ids_ref, vals_ref,
                  top_s, top_i, *, k: int, qb: int, tile_n: int,
                  n_tiles: int, n_valid: int, metric: Metric = TANIMOTO):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        top_s[...] = jnp.full((qb, k), NEG, jnp.float32)
        top_i[...] = jnp.full((qb, k), -1, jnp.int32)

    q = q_ref[...]                                     # (qb, W)
    db = db_ref[...]                                   # (tile_n, W)
    inter = jnp.sum(jax.lax.population_count(
        q[:, None, :] & db[None, :, :]).astype(jnp.int32), axis=-1)  # (qb, tile_n)
    s = metric_from_counts(metric, inter, qcnt_ref[...][:, None],
                           dbcnt_ref[...][None, :])
    idx = t * tile_n + jax.lax.iota(jnp.int32, tile_n)
    s = jnp.where((idx < n_valid)[None, :], s, NEG)
    all_s = jnp.concatenate([top_s[...], s], axis=1)   # (qb, k + tile_n)
    all_i = jnp.concatenate([top_i[...], jnp.broadcast_to(idx, (qb, tile_n))],
                            axis=1)
    new_s, pos = jax.lax.top_k(all_s, k)
    top_s[...] = new_s
    top_i[...] = jnp.take_along_axis(all_i, pos, axis=1)

    @pl.when(t == n_tiles - 1)
    def _emit():
        vals_ref[...] = top_s[...]
        ids_ref[...] = top_i[...]


def blocked_tanimoto_topk(queries: jax.Array, db: jax.Array, db_cnt: jax.Array,
                          k: int, n_valid: int, qb: int = 8,
                          tile_n: int = DEFAULT_TILE_N, interpret: bool = True,
                          metric: Metric = TANIMOTO):
    """queries (Q, W) with Q a multiple of qb; one DB sweep per qb queries."""
    q_n, w = queries.shape
    assert q_n % qb == 0, (q_n, qb)
    n_pad = db.shape[0]
    n_tiles = n_pad // tile_n
    q_cnt = jnp.sum(jax.lax.population_count(queries).astype(jnp.int32), axis=-1)
    body = functools.partial(_blocked_body, k=k, qb=qb, tile_n=tile_n,
                             n_tiles=n_tiles, n_valid=n_valid, metric=metric)
    out = pl.pallas_call(
        body,
        grid=(q_n // qb, n_tiles),
        in_specs=[
            pl.BlockSpec((qb, w), lambda q, t: (q, 0)),
            pl.BlockSpec((qb,), lambda q, t: (q,)),
            pl.BlockSpec((tile_n, w), lambda q, t: (t, 0)),
            pl.BlockSpec((tile_n,), lambda q, t: (t,)),
        ],
        out_specs=[
            pl.BlockSpec((qb, k), lambda q, t: (q, 0)),
            pl.BlockSpec((qb, k), lambda q, t: (q, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q_n, k), jnp.int32),
            jax.ShapeDtypeStruct((q_n, k), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((qb, k), jnp.float32),
            pltpu.VMEM((qb, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, q_cnt, db, db_cnt)
    return out[0], out[1]
