"""Fused beam-expansion kernel over the neighbour-blocked fingerprint layout
— the streaming fine-grained distance engine of the HNSW hot path (ISSUE 4).

The DMA-granularity problem (ROADMAP #1): the row-gather kernel
(``kernels/gather.py``) walks a ``(Q, E)`` grid and issues one 128-byte
HBM fetch per *neighbour id* — a beam expansion of ``B`` popped nodes costs
``B * 2M`` scattered row DMAs per query. The paper's FPGA engine instead
streams each popped vertex's whole adjacency list through the distance unit
at initiation interval 1 (§III-C); FPScreen makes the same move explicit:
pack the fingerprints a scan will touch contiguously and the gather-bound
stage becomes a streaming one.

This kernel is that layout change plus the fused compute:

* The device graph keeps a **neighbour-blocked copy of the base layer**:
  ``nbr_fps[v] = db[base_adj[v]]`` of shape ``(N, 2M, W)`` (invalid ``-1``
  slots hold zero rows), with ``nbr_cnt[v]`` the matching popcounts. One
  popped node's entire expansion is one contiguous ``2M * W``-word block.
* The grid is ``(Q, beam)`` — *beam* steps per query, not ``beam * 2M``.
  The popped node ids are a **scalar-prefetch** operand; the BlockSpec
  ``index_map`` reads ``pop_ids[q, b]`` and DMAs that node's whole block
  HBM->VMEM in a single stream, double-buffered across grid steps by the
  Pallas pipeline. DMA streams per query-iteration: ``beam`` (vs
  ``beam * 2M`` row fetches), same total bytes.
* Per step the body computes popcount-Tanimoto for all ``2M`` neighbours
  in-register, masks invalid / visited slots (id ``-1`` in the flattened
  candidate ids) and sub-threshold scores (``<= worst[q]``, the result
  queue's eviction bound), and accumulates into a per-query VMEM score row.
* On the last beam step the row is **sorted in-kernel** (``lax.top_k`` to
  width ``kk``) and emitted with the matching ids — the traversal's
  gather -> score -> sort -> merge chain collapses into one launch per
  iteration; ``pq_insert_batch``/``merge_sorted`` downstream consume a
  single pre-sorted run.

Arithmetic is bit-identical to the row path (integer popcounts, one f32
divide), so ``layout="blocked"`` engines match ``layout="rows"`` exactly.
Validated with ``interpret=True`` on CPU against ``ref.expand_sorted_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.fingerprints import Metric, TANIMOTO, metric_from_counts

NEG = float("-inf")  # python scalar: must not be a captured jnp constant


def _expand_body(pop_ref, q_ref, qcnt_ref, ids_ref, worst_ref, nbr_ref,
                 cnt_ref, s_out, i_out, s_buf, *, beam: int, m2: int,
                 kk: int, n_exp: int, metric: Metric = TANIMOTO):
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        s_buf[...] = jnp.full((1, n_exp), NEG, jnp.float32)

    q = q_ref[0, :]                                    # (W,) uint32
    blk = nbr_ref[0]                                   # (2M, W) streamed block
    inter = jnp.sum(jax.lax.population_count(
        q[None, :] & blk).astype(jnp.int32), axis=-1)  # (2M,)
    s = metric_from_counts(metric, inter, qcnt_ref[0], cnt_ref[0])
    ids_b = ids_ref[0, pl.ds(b * m2, m2)]              # this slot's flat ids
    s = jnp.where(ids_b >= 0, s, NEG)                  # -1 = pad/visited/dup
    s = jnp.where(s > worst_ref[0], s, NEG)            # evict-worst filter
    s_buf[0, pl.ds(b * m2, m2)] = s

    @pl.when(b == beam - 1)
    def _emit():
        all_s = s_buf[0, :]
        all_i = jnp.where(all_s > NEG, ids_ref[0, :], -1)
        new_s, pos = jax.lax.top_k(all_s, kk)          # in-kernel sort stage
        s_out[0, :] = new_s
        i_out[0, :] = jnp.take(all_i, pos)


def expand_sorted_scores(queries: jax.Array, q_cnt: jax.Array,
                         nbr_fps: jax.Array, nbr_cnt: jax.Array,
                         pop_ids: jax.Array, flat_ids: jax.Array,
                         worst: jax.Array, kk: int,
                         interpret: bool = True, metric: Metric = TANIMOTO):
    """queries (Q, W) u32, q_cnt (Q,) i32, nbr_fps (N, 2M, W) u32,
    nbr_cnt (N, 2M) i32, pop_ids (Q, beam) i32 (popped node ids, -1 = empty
    pop), flat_ids (Q, beam*2M) i32 (adjacency of the popped beam, -1 for
    pad / visited / duplicate slots), worst (Q,) f32 (per-query eviction
    threshold; scores must be strictly greater to survive).

    Returns ``(scores (Q, kk) f32 descending, ids (Q, kk) i32)`` — the
    expansion's top-``kk`` survivors, -inf / -1 in the empty tail. One
    contiguous ``nbr_fps`` block DMA per (query, beam slot) grid step.
    """
    q_n, w = queries.shape
    n, m2, _ = nbr_fps.shape
    beam = pop_ids.shape[1]
    n_exp = beam * m2
    assert flat_ids.shape == (q_n, n_exp), (flat_ids.shape, q_n, n_exp)
    assert 0 < kk <= n_exp, (kk, n_exp)

    def nbr_index(q, b, pop_ref):
        # clamp invalid (-1) pops to an addressable block; their flat ids are
        # already -1 so the body masks every score from the fetched block
        return (jnp.clip(pop_ref[q, b], 0, n - 1), 0, 0)

    def cnt_index(q, b, pop_ref):
        return (jnp.clip(pop_ref[q, b], 0, n - 1), 0)

    body = functools.partial(_expand_body, beam=beam, m2=m2, kk=kk,
                             n_exp=n_exp, metric=metric)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(q_n, beam),
        in_specs=[
            pl.BlockSpec((1, w), lambda q, b, pop: (q, 0)),      # query row
            pl.BlockSpec((1,), lambda q, b, pop: (q,)),          # query count
            pl.BlockSpec((1, n_exp), lambda q, b, pop: (q, 0)),  # flat ids
            pl.BlockSpec((1,), lambda q, b, pop: (q,)),          # worst bound
            pl.BlockSpec((1, m2, w), nbr_index),                 # nbr block
            pl.BlockSpec((1, m2), cnt_index),                    # nbr counts
        ],
        out_specs=[
            pl.BlockSpec((1, kk), lambda q, b, pop: (q, 0)),
            pl.BlockSpec((1, kk), lambda q, b, pop: (q, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((1, n_exp), jnp.float32)],
    )
    out = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((q_n, kk), jnp.float32),
            jax.ShapeDtypeStruct((q_n, kk), jnp.int32),
        ],
        interpret=interpret,
    )(pop_ids.astype(jnp.int32), queries, q_cnt, flat_ids.astype(jnp.int32),
      worst.astype(jnp.float32), nbr_fps, nbr_cnt)
    return out[0], out[1]
