"""Jit'd public wrappers around the Pallas kernels (padding, tiling policy).

Kernel-to-engine mapping and the data layouts each kernel streams are
documented in docs/ARCHITECTURE.md. All wrappers are placement-transparent:
they launch on whatever device their operands are committed to, which is
what lets the sharded HNSW fan-out (``HNSWEngine(shards=N)``) run one
kernel-backed traversal per shard device with no per-device code here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.fingerprints import Metric, TANIMOTO, popcount
from . import tanimoto_topk as ktk

# Interpret mode on CPU (this container); on TPU backends the kernels compile
# through Mosaic.
def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pick_tile(n: int, tile_n: int | None) -> int:
    if tile_n is not None:
        return tile_n
    # keep (tile, 32) u32 tile ~<= 256 KiB of VMEM and lane-aligned
    return min(ktk.DEFAULT_TILE_N, max(128, 1 << (max(n - 1, 1)).bit_length() - 1))


@functools.partial(jax.jit, static_argnames=("k", "tile_n", "metric"))
def _tanimoto_topk_impl(queries, db, db_cnt, k: int, tile_n: int,
                        metric: Metric = TANIMOTO):
    n = db.shape[0]
    pad = (-n) % tile_n
    db_p = jnp.pad(db, ((0, pad), (0, 0)))
    cnt_p = jnp.pad(db_cnt, (0, pad))
    return ktk.fused_tanimoto_topk(queries, db_p, cnt_p, k=k, n_valid=n,
                                   tile_n=tile_n, interpret=_interpret(),
                                   metric=metric)


def tanimoto_topk(queries: jax.Array, db: jax.Array, k: int,
                  db_popcount: jax.Array | None = None,
                  tile_n: int | None = None,
                  metric: Metric | None = None):
    """Fused on-the-fly exhaustive KNN: (Q, W) x (N, W) -> ids, vals (Q, k).

    ``metric`` is a trace-time constant: each (metric, shape) pair compiles
    once; the Tanimoto default emits the historical HLO unchanged."""
    queries = jnp.asarray(queries)
    db = jnp.asarray(db)
    if db_popcount is None:
        db_popcount = popcount(db)
    tile = min(_pick_tile(db.shape[0], tile_n), db.shape[0] if db.shape[0] >= 128 else 128)
    ids, vals = _tanimoto_topk_impl(queries, db, db_popcount, k, tile,
                                    metric if metric is not None else TANIMOTO)
    return ids, vals


@functools.partial(jax.jit, static_argnames=("k", "max_tiles", "tile_n", "n_valid", "cutoff", "metric"))
def _bitbound_topk_impl(queries, db_sorted, cnt_sorted, counts_i32,
                        k: int, max_tiles: int, tile_n: int, n_valid: int,
                        cutoff: float, metric: Metric = TANIMOTO):
    # per-metric popcount window per query (Tanimoto: Eq.2), in tile units
    a = jnp.sum(jax.lax.population_count(queries).astype(jnp.int32), -1).astype(jnp.float32)
    if metric.name == "tanimoto":
        lo_cnt = jnp.ceil(a * cutoff).astype(jnp.int32)
        hi_cnt = jnp.floor(a / max(cutoff, 1e-6)).astype(jnp.int32)
    else:
        lo_r, hi_r = metric.bound_ratios(cutoff)
        lo_cnt = (jnp.ceil(a * lo_r) if metric.bounded_below
                  else jnp.zeros_like(a)).astype(jnp.int32)
        hi_cnt = (jnp.minimum(jnp.floor(a * hi_r), 2.0**30)
                  if metric.bounded_above
                  else jnp.full_like(a, 2.0**30)).astype(jnp.int32)
    lo = jnp.searchsorted(counts_i32, lo_cnt, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(counts_i32, hi_cnt, side="right").astype(jnp.int32)
    lo_tile = lo // tile_n
    hi_tile = (hi + tile_n - 1) // tile_n
    n_tiles_q = jnp.clip(hi_tile - lo_tile, 0, max_tiles)
    ids_sorted, vals = ktk.bitbound_fused_topk(
        queries, db_sorted, cnt_sorted, lo_tile, n_tiles_q, k=k,
        max_tiles=max_tiles, n_valid=n_valid, cutoff=cutoff, tile_n=tile_n,
        interpret=_interpret(), metric=metric)
    return ids_sorted, vals


def bitbound_topk(queries: jax.Array, db_sorted: jax.Array,
                  counts_sorted: jax.Array, k: int, cutoff: float,
                  max_tiles: int | None = None, tile_n: int | None = None,
                  metric: Metric | None = None):
    """BitBound-windowed fused KNN over a popcount-sorted DB.

    Returns ids into the *sorted* database (caller maps through the
    BitBoundIndex order), and similarity values. Entries that fall outside
    every window come back as id -1 / val -inf."""
    queries = jnp.asarray(queries)
    db_sorted = jnp.asarray(db_sorted)
    counts_sorted = jnp.asarray(counts_sorted, dtype=jnp.int32)
    n = db_sorted.shape[0]
    tile = _pick_tile(n, tile_n)
    pad = (-n) % tile
    db_p = jnp.pad(db_sorted, ((0, pad), (0, 0)))
    cnt_p = jnp.pad(counts_sorted, (0, pad))
    total_tiles = db_p.shape[0] // tile
    if max_tiles is None:
        max_tiles = total_tiles
    max_tiles = min(max_tiles, total_tiles)
    ids_sorted, vals = _bitbound_topk_impl(
        queries, db_p, cnt_p, counts_sorted, k, max_tiles, tile, n,
        float(cutoff), metric if metric is not None else TANIMOTO)
    ids_sorted = jnp.where(jnp.isfinite(vals), ids_sorted, -1)
    return ids_sorted, vals


@functools.partial(jax.jit, static_argnames=("k", "max_tiles", "tile_n", "n_valid", "metric"))
def _window_topk_impl(queries, db_p, cnt_p, lo_tile, n_tiles, lo_row, hi_row,
                      k: int, max_tiles: int, tile_n: int, n_valid: int,
                      metric: Metric = TANIMOTO):
    return ktk.windowed_fused_topk(queries, db_p, cnt_p, lo_tile, n_tiles,
                                   lo_row, hi_row, k=k, max_tiles=max_tiles,
                                   n_valid=n_valid, tile_n=tile_n,
                                   interpret=_interpret(), metric=metric)


def window_topk(queries: jax.Array, db: jax.Array, db_cnt: jax.Array,
                lo_row: jax.Array, hi_row: jax.Array, k: int,
                max_tiles: int | None = None, tile_n: int | None = None,
                metric: Metric | None = None):
    """Fused KNN over a per-query row window [lo_row, hi_row) of ``db``.

    Stage 1 of the two-stage engine: ``db`` is typically the *folded*
    popcount-sorted database while the row bounds come from the Eq.2 window on
    the full-resolution popcounts. Ids index into ``db``; empty slots are
    id -1 / val -inf. Jit-compatible (callable from inside an enclosing jit)
    when k/max_tiles/tile_n are static.

    HARD PRECONDITION: ``max_tiles`` (the static grid extent) must cover the
    largest window in the batch — a window spanning more tiles is silently
    truncated to its first ``max_tiles`` tiles and rows beyond it are never
    scored (no error, no marker). The engine guarantees this by bucketing
    ``max_tiles`` to a power of two >= the batch's max window; other callers
    must size it the same way (the row bounds are traced values, so this
    cannot be validated here)."""
    queries = jnp.asarray(queries)
    db = jnp.asarray(db)
    n = db.shape[0]
    tile = _pick_tile(n, tile_n)
    pad = (-n) % tile
    db_p = jnp.pad(db, ((0, pad), (0, 0)))
    cnt_p = jnp.pad(jnp.asarray(db_cnt, dtype=jnp.int32), (0, pad))
    total_tiles = db_p.shape[0] // tile
    if max_tiles is None:
        max_tiles = total_tiles
    max_tiles = max(min(max_tiles, total_tiles), 1)
    lo_row = jnp.asarray(lo_row, dtype=jnp.int32)
    hi_row = jnp.asarray(hi_row, dtype=jnp.int32)
    lo_tile = lo_row // tile
    n_tiles = jnp.where(hi_row > lo_row,
                        (hi_row + tile - 1) // tile - lo_tile, 0)
    n_tiles = jnp.clip(n_tiles, 0, max_tiles)
    ids, vals = _window_topk_impl(queries, db_p, cnt_p, lo_tile, n_tiles,
                                  lo_row, hi_row, k=k, max_tiles=max_tiles,
                                  tile_n=tile, n_valid=n,
                                  metric=metric if metric is not None else TANIMOTO)
    ids = jnp.where(jnp.isfinite(vals), ids, -1)
    return ids, vals


def bitcount(words: jax.Array) -> jax.Array:
    return ktk.bitcount(jnp.asarray(words), interpret=_interpret())


def gather_tanimoto(queries: jax.Array, db: jax.Array, ids: jax.Array,
                    q_cnt: jax.Array | None = None,
                    metric: Metric | None = None) -> jax.Array:
    """Fine-grained gather-distance stage: per-query candidate ids -> sims.

    queries (Q, W) u32, db (N, W) u32, ids (Q, E) i32 -> (Q, E) f32.
    Entries with id ``-1`` come back as ``-inf``. Jit-compatible — the HNSW
    traversal calls this from inside its ``lax.while_loop``, scoring one
    whole beam expansion (B·2M neighbour ids) per kernel launch.
    """
    from . import gather as kg
    queries = jnp.asarray(queries)
    db = jnp.asarray(db)
    ids = jnp.asarray(ids, dtype=jnp.int32)
    if q_cnt is None:
        q_cnt = popcount(queries)
    return kg.gather_tanimoto_scores(
        queries, q_cnt, db, ids, interpret=_interpret(),
        metric=metric if metric is not None else TANIMOTO)


def expand_tanimoto_sorted(queries: jax.Array, nbr_fps: jax.Array,
                           nbr_cnt: jax.Array, pop_ids: jax.Array,
                           flat_ids: jax.Array, worst: jax.Array, kk: int,
                           q_cnt: jax.Array | None = None,
                           metric: Metric | None = None):
    """Fused beam-expansion stage over the neighbour-blocked layout.

    queries (Q, W) u32, nbr_fps (N, 2M, W) u32, nbr_cnt (N, 2M) i32,
    pop_ids (Q, beam) i32, flat_ids (Q, beam*2M) i32, worst (Q,) f32 ->
    (scores (Q, kk) desc, ids (Q, kk)). One contiguous block DMA per popped
    node (``beam`` streams per query-iteration vs the row kernel's
    ``beam*2M`` fetches), scores sorted in-kernel so the traversal merges a
    single run. Jit-compatible — the HNSW ``lax.while_loop`` launches it
    once per iteration.
    """
    from . import expand as ke
    queries = jnp.asarray(queries)
    if q_cnt is None:
        q_cnt = popcount(queries)
    return ke.expand_sorted_scores(
        queries, q_cnt, jnp.asarray(nbr_fps), jnp.asarray(nbr_cnt),
        jnp.asarray(pop_ids, dtype=jnp.int32),
        jnp.asarray(flat_ids, dtype=jnp.int32),
        jnp.asarray(worst), kk, interpret=_interpret(),
        metric=metric if metric is not None else TANIMOTO)


@functools.partial(jax.jit, static_argnames=("k", "qb", "tile_n", "metric"))
def _blocked_topk_impl(queries, db, db_cnt, k: int, qb: int, tile_n: int,
                       metric: Metric = TANIMOTO):
    n = db.shape[0]
    pad = (-n) % tile_n
    db_p = jnp.pad(db, ((0, pad), (0, 0)))
    cnt_p = jnp.pad(db_cnt, (0, pad))
    return ktk.blocked_tanimoto_topk(queries, db_p, cnt_p, k=k, n_valid=n,
                                     qb=qb, tile_n=tile_n,
                                     interpret=_interpret(), metric=metric)


def tanimoto_topk_blocked(queries: jax.Array, db: jax.Array, k: int,
                          db_popcount: jax.Array | None = None, qb: int = 8,
                          tile_n: int | None = None,
                          metric: Metric | None = None):
    """Query-blocked fused engine: one DB sweep serves qb queries
    (bytes/query divided by qb — see kernel docstring). Pads Q up to a qb
    multiple."""
    queries = jnp.asarray(queries)
    db = jnp.asarray(db)
    if db_popcount is None:
        db_popcount = popcount(db)
    qn = queries.shape[0]
    qpad = (-qn) % qb
    if qpad:
        queries = jnp.concatenate(
            [queries, jnp.zeros((qpad, queries.shape[1]), queries.dtype)])
    tile = min(_pick_tile(db.shape[0], tile_n),
               db.shape[0] if db.shape[0] >= 128 else 128)
    ids, vals = _blocked_topk_impl(queries, db, db_popcount, k, qb, tile,
                                   metric if metric is not None else TANIMOTO)
    return ids[:qn], vals[:qn]
