"""Injectable filesystem layer for the durability machinery (ISSUE 6).

Every write-side file operation the checkpoint/snapshot writers and the
write-ahead log perform goes through an :class:`Fs` instance instead of the
``os``/``open`` builtins. Production code uses the module-level
:data:`DEFAULT_FS` (thin pass-throughs, plus the fsync discipline real
durability needs); the crash-fault-injection harness swaps in a
:class:`CrashPointFs` that raises :class:`InjectedCrash` after a byte/op
budget — simulating a process death at an arbitrary point inside a WAL
append, a segment rotation, a snapshot leaf write, or the atomic-rename
publish — without monkeypatching globals. ``tests/test_crash_recovery.py``
sweeps those budgets; the subprocess SIGKILL driver covers the real-kill
case the in-process exception cannot (buffers lost mid-syscall).

Only the *write* surface is virtualised (opens for write, writes, fsyncs,
renames, directory create/remove). Reads go through the normal builtins:
a crash cannot corrupt a read, and recovery code paths must work on plain
on-disk state regardless of how it was produced.
"""
from __future__ import annotations

import os
import shutil
from pathlib import Path


class InjectedCrash(RuntimeError):
    """Raised by :class:`CrashPointFs` when the fault budget is exhausted —
    the in-process stand-in for the process dying at this exact point."""


class Fs:
    """Write-side filesystem surface (the ``_Fs`` injection point).

    The default implementation is the real filesystem with the fsync
    discipline durable storage needs: ``fsync`` flushes user-space buffers
    and syncs the file, ``fsync_dir`` syncs a directory's entry table (so a
    rename/create survives power loss), ``replace`` is the atomic publish.
    """

    def open(self, path, mode: str = "wb"):
        return open(path, mode)

    def write(self, f, data: bytes) -> int:
        return f.write(data)

    def fsync(self, f) -> None:
        f.flush()
        os.fsync(f.fileno())

    def fsync_dir(self, path) -> None:
        fd = os.open(path, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    def replace(self, src, dst) -> None:
        os.replace(src, dst)

    def mkdir(self, path, exist_ok: bool = True) -> None:
        Path(path).mkdir(parents=True, exist_ok=exist_ok)

    def remove(self, path) -> None:
        os.remove(path)

    def rmtree(self, path) -> None:
        shutil.rmtree(path, ignore_errors=True)

    def truncate(self, path, size: int) -> None:
        with open(path, "r+b") as f:
            f.truncate(size)
            f.flush()
            os.fsync(f.fileno())


DEFAULT_FS = Fs()


class _BudgetFile:
    """File wrapper that charges writes against a shared budget and tears
    the write that exhausts it (partial bytes hit the disk, then the
    "process" dies) — the shape a real crash leaves behind."""

    def __init__(self, f, fs: "CrashPointFs"):
        self._f = f
        self._fs = fs

    def write(self, data) -> int:
        data = bytes(data)
        keep = self._fs._charge_bytes(len(data))
        if keep < len(data):
            if keep:
                self._f.write(data[:keep])
            self._f.flush()
            raise InjectedCrash(
                f"write torn after {self._fs.bytes_written} bytes")
        return self._f.write(data)

    def __getattr__(self, name):
        return getattr(self._f, name)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self._f.close()
        return False


class CrashPointFs(Fs):
    """Fault-injecting :class:`Fs`: dies after ``byte_budget`` written bytes
    and/or ``op_budget`` metadata operations (fsync / rename / mkdir /
    remove / truncate).

    Byte budgets land crashes *inside* payload writes (torn WAL records,
    truncated ``.npy`` leaves); op budgets land them *between* the metadata
    steps (after temp-write but before rename, after rename but before the
    GC of the superseded generation, ...). Sweeping both floors every crash
    point the durability layer has. Counters keep counting after the first
    crash so a harness can read how far the run got.
    """

    def __init__(self, byte_budget: int | None = None,
                 op_budget: int | None = None):
        self.byte_budget = byte_budget
        self.op_budget = op_budget
        self.bytes_written = 0
        self.ops = 0
        self.crashed = False

    # -- accounting ---------------------------------------------------------
    def _charge_bytes(self, n: int) -> int:
        """Returns how many of ``n`` bytes may still be written."""
        if self.byte_budget is None:
            self.bytes_written += n
            return n
        room = max(self.byte_budget - self.bytes_written, 0)
        keep = min(n, room)
        self.bytes_written += keep
        if keep < n:
            self.crashed = True
        return keep

    def _charge_op(self, what: str) -> None:
        self.ops += 1
        if self.op_budget is not None and self.ops > self.op_budget:
            self.crashed = True
            raise InjectedCrash(f"op budget exhausted at {what} #{self.ops}")

    # -- surface ------------------------------------------------------------
    def open(self, path, mode: str = "wb"):
        f = super().open(path, mode)
        if "w" in mode or "a" in mode or "+" in mode:
            return _BudgetFile(f, self)
        return f

    def write(self, f, data: bytes) -> int:
        return f.write(data)           # f is a _BudgetFile: already budgeted

    def fsync(self, f) -> None:
        self._charge_op("fsync")
        inner = f._f if isinstance(f, _BudgetFile) else f
        super().fsync(inner)

    def fsync_dir(self, path) -> None:
        self._charge_op("fsync_dir")
        super().fsync_dir(path)

    def replace(self, src, dst) -> None:
        self._charge_op("replace")
        super().replace(src, dst)

    def mkdir(self, path, exist_ok: bool = True) -> None:
        self._charge_op("mkdir")
        super().mkdir(path, exist_ok=exist_ok)

    def remove(self, path) -> None:
        self._charge_op("remove")
        super().remove(path)

    def rmtree(self, path) -> None:
        self._charge_op("rmtree")
        super().rmtree(path)

    def truncate(self, path, size: int) -> None:
        self._charge_op("truncate")
        super().truncate(path, size)
