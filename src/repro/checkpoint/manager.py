"""Fault-tolerant checkpointing: atomic, integrity-checked, async,
elastic-restore (DESIGN.md §6).

Layout: <dir>/step_<N>/ with one .npy per leaf + manifest.json holding the
tree structure, shapes, dtypes and per-file sha256. Writes go to a temp dir
and are atomically renamed, so a crash mid-write can never corrupt the
latest checkpoint. ``restore`` device_puts onto *any* mesh/sharding
(elastic: restoring a 512-chip checkpoint onto 256 chips just changes the
target sharding — arrays are resharded on load).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _leaf_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _hash_file(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save_checkpoint(directory: str | os.PathLike, step: int, tree) -> Path:
    base = Path(directory)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = base / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _leaf_paths(tree)
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        dtype_name = str(arr.dtype)
        if arr.dtype.kind == "V":  # ml_dtypes (bfloat16 etc.): store a view
            import ml_dtypes  # noqa: F401 — dtype registry
            dtype_name = arr.dtype.name
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append({
            "file": fname, "shape": list(arr.shape), "dtype": dtype_name,
            "sha256": _hash_file(tmp / fname),
        })
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # atomic publish
    return final


def latest_step(directory: str | os.PathLike) -> int | None:
    base = Path(directory)
    if not base.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in base.glob("step_*"))
    return steps[-1] if steps else None


def restore_checkpoint(directory: str | os.PathLike, step: int, like_tree,
                       shardings=None, verify: bool = True):
    """Restore into the structure of ``like_tree`` (values ignored).
    ``shardings``: optional matching tree of jax.sharding.Sharding for
    elastic placement onto the current mesh."""
    path = Path(directory) / f"step_{step:08d}"
    with open(path / "manifest.json") as f:
        manifest = json.load(f)
    leaves, treedef = _leaf_paths(like_tree)
    assert len(leaves) == len(manifest["leaves"]), "checkpoint/model structure mismatch"
    out = []
    for i, meta in enumerate(manifest["leaves"]):
        fpath = path / meta["file"]
        if verify and _hash_file(fpath) != meta["sha256"]:
            raise IOError(f"integrity check failed for {fpath}")
        arr = np.load(fpath)
        if str(arr.dtype) != meta["dtype"]:   # ml_dtypes round-trip via view
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, meta["dtype"])))
        out.append(arr)
    restored = jax.tree.unflatten(treedef, out)
    if shardings is not None:
        restored = jax.tree.map(jax.device_put, restored, shardings)
    return restored


class CheckpointManager:
    """Double-buffered async checkpointing with retention.

    ``save`` snapshots to host (blocking, cheap relative to a training step)
    and writes to disk on a background thread; ``wait`` joins the in-flight
    write (called before exit / before the next save)."""

    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, blocking: bool = False):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot

        def _write():
            save_checkpoint(self.dir, step, host_tree)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def _gc(self):
        steps = sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def latest(self) -> int | None:
        return latest_step(self.dir)

    def restore_latest(self, like_tree, shardings=None):
        step = self.latest()
        if step is None:
            return None, None
        return step, restore_checkpoint(self.dir, step, like_tree, shardings)
