"""Fault-tolerant checkpointing: atomic, integrity-checked, async,
elastic-restore (DESIGN.md §6).

Layout: <dir>/step_<N>/ with one .npy per leaf + manifest.json holding the
tree structure, shapes, dtypes and per-file sha256. Writes go to a temp dir
and are atomically renamed, so a crash mid-write can never corrupt the
latest checkpoint. ``restore`` device_puts onto *any* mesh/sharding
(elastic: restoring a 512-chip checkpoint onto 256 chips just changes the
target sharding — arrays are resharded on load).

Two manifest flavours share one atomic-write core (``_write_atomic_dir``):

* **tree checkpoints** (``save_checkpoint``/``restore_checkpoint``) — leaves
  of an arbitrary pytree, restored against a ``like_tree`` structure. Used
  by the training loop.
* **named-array snapshots** (``save_array_snapshot``/``load_array_snapshot``)
  — a flat ``{name: ndarray}`` dict plus a JSON ``meta`` blob, restored
  without any template. Used by the serving durability layer
  (``repro.serve.snapshot``), whose restore side runs in a fresh process
  that has no live tree to mirror. ``load_latest_intact`` walks snapshots
  newest-first and skips truncated/corrupt generations, so a crash during
  a snapshot write (or a disk flipping bits in one) falls back to the last
  intact one.

All write-side file operations route through an injectable
:class:`repro.checkpoint.fs.Fs` so the crash-fault harness can kill a write
after N bytes/ops at any point in the sequence. ``durable=True`` adds the
fsync discipline (leaf fsync before rename, directory fsync after) that the
WAL's acked-implies-recovered contract relies on.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np

from .fs import DEFAULT_FS, Fs


def _leaf_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _hash_file(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _write_atomic_dir(base: Path, name: str, writer, *, fs: Fs,
                      durable: bool) -> Path:
    """Write a directory through ``writer(tmp_dir)`` then atomically publish
    it as ``base/name``. A crash anywhere before the final rename leaves at
    most a ``.tmp_*`` orphan; a crash after it leaves a complete dir."""
    base = Path(base)
    fs.mkdir(base)
    final = base / name
    tmp = base / f".tmp_{name}"
    if tmp.exists():
        fs.rmtree(tmp)
    fs.mkdir(tmp)
    writer(tmp)
    if durable:
        fs.fsync_dir(tmp)
    if final.exists():
        fs.rmtree(final)
    fs.replace(tmp, final)
    if durable:
        fs.fsync_dir(base)
    return final


def _write_npy(dirpath: Path, fname: str, arr: np.ndarray, *, fs: Fs,
               durable: bool) -> str:
    """Write one array file through the fs layer; returns its sha256."""
    path = dirpath / fname
    f = fs.open(path, "wb")
    try:
        np.save(f, arr)
        if durable:
            fs.fsync(f)
    finally:
        f.close()
    return _hash_file(path)


def _write_json(dirpath: Path, fname: str, obj, *, fs: Fs,
                durable: bool) -> None:
    path = dirpath / fname
    f = fs.open(path, "wb")
    try:
        f.write(json.dumps(obj).encode("utf-8"))
        if durable:
            fs.fsync(f)
    finally:
        f.close()


def save_checkpoint(directory: str | os.PathLike, step: int, tree, *,
                    fs: Fs = DEFAULT_FS, durable: bool = False) -> Path:
    base = Path(directory)

    def writer(tmp: Path) -> None:
        leaves, treedef = _leaf_paths(tree)
        manifest = {"step": step, "treedef": str(treedef), "leaves": []}
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            dtype_name = str(arr.dtype)
            if arr.dtype.kind == "V":  # ml_dtypes (bfloat16 etc.): view
                import ml_dtypes  # noqa: F401 — dtype registry
                dtype_name = arr.dtype.name
                arr = arr.view(
                    np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
            fname = f"leaf_{i:05d}.npy"
            sha = _write_npy(tmp, fname, arr, fs=fs, durable=durable)
            manifest["leaves"].append({
                "file": fname, "shape": list(arr.shape), "dtype": dtype_name,
                "sha256": sha,
            })
        _write_json(tmp, "manifest.json", manifest, fs=fs, durable=durable)

    return _write_atomic_dir(base, f"step_{step:08d}", writer, fs=fs,
                             durable=durable)


def latest_step(directory: str | os.PathLike) -> int | None:
    base = Path(directory)
    if not base.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in base.glob("step_*"))
    return steps[-1] if steps else None


def restore_checkpoint(directory: str | os.PathLike, step: int, like_tree,
                       shardings=None, verify: bool = True):
    """Restore into the structure of ``like_tree`` (values ignored).
    ``shardings``: optional matching tree of jax.sharding.Sharding for
    elastic placement onto the current mesh."""
    path = Path(directory) / f"step_{step:08d}"
    with open(path / "manifest.json") as f:
        manifest = json.load(f)
    leaves, treedef = _leaf_paths(like_tree)
    assert len(leaves) == len(manifest["leaves"]), "checkpoint/model structure mismatch"
    out = []
    for i, meta in enumerate(manifest["leaves"]):
        fpath = path / meta["file"]
        if verify and _hash_file(fpath) != meta["sha256"]:
            raise IOError(f"integrity check failed for {fpath}")
        arr = np.load(fpath)
        if str(arr.dtype) != meta["dtype"]:   # ml_dtypes round-trip via view
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, meta["dtype"])))
        out.append(arr)
    restored = jax.tree.unflatten(treedef, out)
    if shardings is not None:
        restored = jax.tree.map(jax.device_put, restored, shardings)
    return restored


# -- named-array snapshots (serving durability layer) ------------------------

def save_array_snapshot(directory: str | os.PathLike, step: int,
                        arrays: dict, meta: dict | None = None, *,
                        prefix: str = "snap", fs: Fs = DEFAULT_FS,
                        durable: bool = True) -> Path:
    """Atomically write ``{name: ndarray}`` + a JSON ``meta`` blob as
    ``<directory>/<prefix>_<step>/``. Names may contain ``/`` — files are
    numbered, names live in the manifest."""
    base = Path(directory)

    def writer(tmp: Path) -> None:
        manifest = {"step": step, "meta": meta or {}, "arrays": []}
        for i, name in enumerate(sorted(arrays)):
            arr = np.ascontiguousarray(arrays[name])
            fname = f"arr_{i:05d}.npy"
            sha = _write_npy(tmp, fname, arr, fs=fs, durable=durable)
            manifest["arrays"].append({
                "name": name, "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "sha256": sha,
            })
        _write_json(tmp, "manifest.json", manifest, fs=fs, durable=durable)

    return _write_atomic_dir(base, f"{prefix}_{step:08d}", writer, fs=fs,
                             durable=durable)


def snapshot_steps(directory: str | os.PathLike,
                   prefix: str = "snap") -> list[int]:
    base = Path(directory)
    if not base.exists():
        return []
    return sorted(int(p.name.rsplit("_", 1)[1])
                  for p in base.glob(f"{prefix}_*") if p.is_dir())


def load_array_snapshot(directory: str | os.PathLike, step: int, *,
                        prefix: str = "snap", verify: bool = True):
    """Load one snapshot generation; returns ``(arrays, meta)``. Raises
    ``IOError`` on missing files or sha256 mismatch."""
    path = Path(directory) / f"{prefix}_{step:08d}"
    mpath = path / "manifest.json"
    if not mpath.exists():
        raise IOError(f"no manifest in {path}")
    with open(mpath) as f:
        manifest = json.load(f)
    arrays = {}
    for entry in manifest["arrays"]:
        fpath = path / entry["file"]
        if not fpath.exists():
            raise IOError(f"missing leaf {fpath}")
        if verify and _hash_file(fpath) != entry["sha256"]:
            raise IOError(f"integrity check failed for {fpath}")
        arr = np.load(fpath)
        if (list(arr.shape) != entry["shape"]
                or str(arr.dtype) != entry["dtype"]):
            raise IOError(f"shape/dtype mismatch for {fpath}")
        arrays[entry["name"]] = arr
    return arrays, manifest["meta"]


def read_snapshot_meta(directory: str | os.PathLike, step: int, *,
                       prefix: str = "snap") -> dict:
    """Read just the JSON ``meta`` blob of one snapshot (no array loads)."""
    path = Path(directory) / f"{prefix}_{step:08d}" / "manifest.json"
    with open(path) as f:
        return json.load(f)["meta"]


def load_latest_intact(directory: str | os.PathLike, *,
                       prefix: str = "snap", verify: bool = True):
    """Walk snapshot generations newest-first, skipping truncated or
    corrupt ones; returns ``(step, arrays, meta)`` or ``(None, None, None)``
    when no intact generation exists."""
    for step in reversed(snapshot_steps(directory, prefix)):
        try:
            arrays, meta = load_array_snapshot(directory, step,
                                               prefix=prefix, verify=verify)
            return step, arrays, meta
        except (IOError, ValueError, KeyError, json.JSONDecodeError):
            continue
    return None, None, None


class CheckpointManager:
    """Double-buffered async checkpointing with retention.

    ``save`` snapshots to host (blocking, cheap relative to a training step)
    and writes to disk on a background thread; ``wait`` joins the in-flight
    write (called before exit / before the next save)."""

    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, blocking: bool = False):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot

        def _write():
            save_checkpoint(self.dir, step, host_tree)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def _gc(self):
        steps = sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def latest(self) -> int | None:
        return latest_step(self.dir)

    def restore_latest(self, like_tree, shardings=None):
        step = self.latest()
        if step is None:
            return None, None
        return step, restore_checkpoint(self.dir, step, like_tree, shardings)
