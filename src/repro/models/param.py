"""Parameter trees with co-located sharding specs.

Every ``init_*`` builds a pytree whose leaves are :class:`SP` — (value, spec)
pairs — so the PartitionSpec can never drift from the array it shards.
``split(tree)`` separates values from specs for pjit in_shardings.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class SP(NamedTuple):
    """A parameter leaf: array (or ShapeDtypeStruct) + its PartitionSpec."""
    value: Any
    spec: P


def is_sp(x) -> bool:
    return isinstance(x, SP)


def split(tree):
    """SP tree -> (values tree, specs tree)."""
    values = jax.tree.map(lambda sp: sp.value, tree, is_leaf=is_sp)
    specs = jax.tree.map(lambda sp: sp.spec, tree, is_leaf=is_sp)
    return values, specs


def stack_sp(trees: list):
    """Stack a list of structurally-identical SP trees along a new leading
    axis (layer-scan stacking); leading axis is unsharded."""
    def _stack(*sps):
        vals = [s.value for s in sps]
        spec = sps[0].spec
        return SP(jnp.stack(vals, axis=0), P(None, *spec))
    return jax.tree.map(_stack, *trees, is_leaf=is_sp)


def normal(key, shape, dtype, scale: float):
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


def make_dense(key, in_dim: int, out_dim: int, spec: P, dtype,
               scale: float | None = None, bias: bool = False,
               bias_spec: P | None = None):
    scale = scale if scale is not None else in_dim ** -0.5
    w = SP(normal(key, (in_dim, out_dim), dtype, scale), spec)
    if not bias:
        return {"w": w}
    bspec = bias_spec if bias_spec is not None else P(spec[-1])
    return {"w": w, "b": SP(jnp.zeros((out_dim,), dtype), bspec)}


def apply_dense(p, x):
    y = jnp.einsum("...i,io->...o", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y
