"""Model assembly: every assigned architecture from one composable block zoo.

Layers are grouped into repeating *units* (``cfg.unit_len`` layers — 1 for
homogeneous stacks, 8 for jamba's mamba/attention interleave, 2 for xLSTM's
s/m alternation). Unit parameters are stacked and the stack is traversed
with ``lax.scan`` + ``jax.checkpoint`` — the production activation
checkpointing policy, and what keeps dry-run HLO size independent of depth.

Public entry points:
  init_params(cfg, rng)                  -> SP tree
  train_loss(cfg)(params, batch)         -> scalar loss (CE + MoE aux)
  prefill_step(cfg)(params, batch)       -> (logits_last, caches)
  decode_step(cfg)(params, caches, toks) -> (logits, new caches)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from . import attention as attn
from . import moe as moe_mod
from . import ssm, xlstm
from .layers import (embed, gelu_mlp, init_embedding, init_gelu_mlp,
                     init_layernorm, init_learned_pos, init_rmsnorm,
                     init_swiglu, layernorm, rmsnorm, swiglu, unembed)
from .param import SP, split, stack_sp


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------

def unit_layout(cfg: ArchConfig) -> list[tuple[str, str]]:
    """[(mixer, ffn)] per layer position within one repeating unit."""
    if cfg.family in ("dense", "vlm"):
        return [("attn", "swiglu")]
    if cfg.family == "moe":
        return [("attn", "moe")]
    if cfg.family == "audio":
        return [("attn", "gelu")]
    if cfg.family == "hybrid":
        out = []
        for j in range(cfg.unit_len):
            mixer = "attn" if j == cfg.attn_position else "mamba"
            ffn = "moe" if (cfg.moe_every and j % cfg.moe_every == 1) else "swiglu"
            out.append((mixer, ffn))
        return out
    if cfg.family == "ssm":
        return [({"s": "slstm", "m": "mlstm"}[c], "none") for c in cfg.xlstm_pattern]
    raise ValueError(cfg.family)


def n_units(cfg: ArchConfig) -> int:
    ul = len(unit_layout(cfg))
    assert cfg.n_layers % ul == 0, (cfg.name, cfg.n_layers, ul)
    return cfg.n_layers // ul


def _mask_pad_vocab(cfg, logits):
    """Suppress the padded-vocab tail (cfg.padded_vocab > cfg.vocab)."""
    if cfg.padded_vocab == cfg.vocab:
        return logits
    pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab
    return jnp.where(pad, -1e30, logits)


def _norm_init(cfg, d):
    return init_layernorm(d, jnp.dtype(cfg.dtype)) if cfg.family == "audio" \
        else init_rmsnorm(d, jnp.dtype(cfg.dtype))


def _norm(cfg, p, x):
    return layernorm(p, x, cfg.norm_eps) if cfg.family == "audio" \
        else rmsnorm(p, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

def _init_layer(key, cfg, mixer: str, ffn: str, d: int, cross: bool = False):
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"norm1": _norm_init(cfg, d)}
    if mixer == "attn":
        p["mixer"] = attn.init_attention(ks[0], cfg, d)
    elif mixer == "mamba":
        p["mixer"] = ssm.init_mamba(ks[0], cfg, d)
    elif mixer == "slstm":
        p["mixer"] = xlstm.init_slstm(ks[0], cfg, d)
    elif mixer == "mlstm":
        p["mixer"] = xlstm.init_mlstm(ks[0], cfg, d)
    if cross:
        p["norm_x"] = _norm_init(cfg, d)
        p["cross"] = attn.init_attention(ks[1], cfg, d)
    if ffn != "none":
        p["norm2"] = _norm_init(cfg, d)
    if ffn == "swiglu":
        p["ffn"] = init_swiglu(ks[2], d, cfg.d_ff, jnp.dtype(cfg.dtype))
    elif ffn == "gelu":
        p["ffn"] = init_gelu_mlp(ks[2], d, cfg.d_ff, jnp.dtype(cfg.dtype))
    elif ffn == "moe":
        p["ffn"] = moe_mod.init_moe(ks[2], cfg, d)
    return p


def _apply_ffn(p, cfg, x, ffn: str, exact_moe: bool = False):
    if ffn == "none":
        return x, 0.0
    h = _norm(cfg, p["norm2"], x)
    if ffn == "swiglu":
        return x + swiglu(p["ffn"], h), 0.0
    if ffn == "gelu":
        return x + gelu_mlp(p["ffn"], h), 0.0
    if ffn == "moe":
        if exact_moe:   # decode: tiny T — dense dispatch, no capacity drops
            y, aux = moe_mod.moe_ffn_dense(p["ffn"], cfg, h)
        else:
            y, aux = moe_mod.moe_ffn(p["ffn"], cfg, h)
        return x + y, aux
    raise ValueError(ffn)


def _apply_layer_train(p, cfg, x, positions, mixer, ffn, d, *, causal=True,
                       window=0, enc_out=None, use_rope=True):
    h = _norm(cfg, p["norm1"], x)
    if mixer == "attn":
        x = x + attn.attention_train(p["mixer"], cfg, h, positions,
                                     causal=causal, window=window,
                                     use_rope=use_rope)
    elif mixer == "mamba":
        x = x + ssm.mamba_train(p["mixer"], cfg, h, d)
    elif mixer == "slstm":
        x = x + xlstm.slstm_train(p["mixer"], cfg, h, d)
    elif mixer == "mlstm":
        x = x + xlstm.mlstm_train(p["mixer"], cfg, h, d)
    if enc_out is not None:
        hx = _norm(cfg, p["norm_x"], x)
        x = x + attn.attention_train(p["cross"], cfg, hx, positions,
                                     kv_x=enc_out, use_rope=False)
    return _apply_ffn(p, cfg, x, ffn)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _init_layer_cache(cfg, mixer: str, batch: int, cache_len: int, d: int):
    if mixer == "attn":
        return attn.init_cache(cfg, batch, cache_len, d)
    if mixer == "mamba":
        return ssm.init_mamba_state(cfg, batch, d)
    if mixer == "slstm":
        return xlstm.init_slstm_state(cfg, batch, d)
    if mixer == "mlstm":
        return xlstm.init_mlstm_state(cfg, batch, d)
    raise ValueError(mixer)


def _layer_cache_spec(cfg, mixer: str, dp=("pod", "data")):
    if mixer == "attn":
        return attn.KVCache.spec(dp)
    if mixer == "mamba":
        return ssm.MambaState.spec(dp)
    if mixer == "slstm":
        return xlstm.SLSTMState.spec(dp)
    if mixer == "mlstm":
        return xlstm.MLSTMState.spec(dp)
    raise ValueError(mixer)


def _apply_layer_decode(p, cfg, x, cache, mixer, ffn, d, *, window=0,
                        enc_kv=None, use_rope=True):
    h = _norm(cfg, p["norm1"], x)
    if mixer == "attn":
        y, cache = attn.attention_decode(p["mixer"], cfg, h, cache,
                                         window=window, use_rope=use_rope)
        x = x + y
    elif mixer == "mamba":
        y, cache = ssm.mamba_decode(p["mixer"], cfg, h, cache, d)
        x = x + y
    elif mixer == "slstm":
        y, cache = xlstm.slstm_decode(p["mixer"], cfg, h, cache, d)
        x = x + y
    elif mixer == "mlstm":
        y, cache = xlstm.mlstm_decode(p["mixer"], cfg, h, cache, d)
        x = x + y
    if enc_kv is not None:
        # cross-attend to the (static) encoder output carried in the cache
        hx = _norm(cfg, p["norm_x"], x)
        y = _cross_decode(p["cross"], cfg, hx, enc_kv)
        x = x + y
    x, _ = _apply_ffn(p, cfg, x, ffn, exact_moe=True)
    return x, cache


def _cross_decode(p, cfg, x, enc_kv):
    """Cross-attention with precomputed encoder K/V: enc_kv = (k, v)
    each (B, F, H, hd)."""
    from .param import apply_dense
    hd = cfg.hd
    b = x.shape[0]
    q = apply_dense(p["q"], x).reshape(b, 1, cfg.n_heads, hd)
    k, v = enc_kv
    k = attn._repeat_kv(k, cfg.n_heads, cfg.n_kv_heads)
    v = attn._repeat_kv(v, cfg.n_heads, cfg.n_kv_heads)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", pr, v.astype(jnp.float32)).astype(x.dtype)
    return apply_dense(p["o"], o.reshape(b, 1, cfg.n_heads * hd))


# ---------------------------------------------------------------------------
# whole-model init
# ---------------------------------------------------------------------------

def init_params(cfg: ArchConfig, rng: jax.Array) -> dict:
    d = cfg.d_model
    layout = unit_layout(cfg)
    nu = n_units(cfg)
    keys = jax.random.split(rng, nu + 4)
    params: dict[str, Any] = {
        "embed": init_embedding(keys[0], cfg.padded_vocab, d, jnp.dtype(cfg.dtype)),
        "final_norm": _norm_init(cfg, d),
    }

    def make_unit(k, cross=False):
        uks = jax.random.split(k, len(layout))
        return {str(j): _init_layer(uks[j], cfg, mixer, ffn, d, cross=cross)
                for j, (mixer, ffn) in enumerate(layout)}

    if cfg.enc_dec:
        enc_keys = jax.random.split(keys[1], nu)
        dec_keys = jax.random.split(keys[2], nu)
        params["enc_units"] = stack_sp([make_unit(k) for k in enc_keys])
        params["units"] = stack_sp([make_unit(k, cross=True) for k in dec_keys])
        params["enc_pos"] = init_learned_pos(keys[3], cfg.n_audio_frames, d,
                                             jnp.dtype(cfg.dtype))
        params["dec_pos"] = init_learned_pos(keys[3], cfg.max_seq, d,
                                             jnp.dtype(cfg.dtype))
        params["enc_final_norm"] = _norm_init(cfg, d)
    else:
        params["units"] = stack_sp([make_unit(k) for k in keys[1:1 + nu]])
    return params


def abstract_params(cfg: ArchConfig):
    """ShapeDtypeStruct param tree + specs (no allocation) for the dry-run.

    Specs are static python objects — they are captured by side effect during
    the abstract trace (returning them from eval_shape would fail since
    PartitionSpec is not a JAX type)."""
    box = {}

    def fn():
        values, specs = split(init_params(cfg, jax.random.key(0)))
        box["specs"] = specs
        return values

    values = jax.eval_shape(fn)
    return values, box["specs"]


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _run_stack(units_params, cfg, x, positions, *, causal=True, window=0,
               enc_out=None, use_rope=True, remat=True, remat_policy=None):
    """Scan over stacked units; returns (x, moe_aux_sum).

    remat_policy: None (save nothing) or "save_tp" (keep the row-parallel
    attention/MLP outputs so their all-reduces are not re-run in the bwd
    recompute — trades ~2 activations/unit of HBM for ICI)."""
    layout = unit_layout(cfg)

    def unit_body(carry, unit_p):
        x, aux = carry
        for j, (mixer, ffn) in enumerate(layout):
            x, a = _apply_layer_train(unit_p[str(j)], cfg, x, positions, mixer,
                                      ffn, cfg.d_model, causal=causal,
                                      window=window, enc_out=enc_out,
                                      use_rope=use_rope)
            aux = aux + a
        return (x, aux), None

    if remat:
        policy = None
        if remat_policy == "save_tp":
            policy = jax.checkpoint_policies.save_only_these_names(
                "tp_attn_out", "tp_mlp_out")
        body = jax.checkpoint(unit_body, policy=policy)
    else:
        body = unit_body
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), units_params)
    return x, aux


def _encode(params, cfg, audio_embed):
    """Whisper encoder: bidirectional, learned positions."""
    f = audio_embed.shape[1]
    x = audio_embed + params["enc_pos"]["pos"][:f]
    positions = jnp.broadcast_to(jnp.arange(f), audio_embed.shape[:2])
    x, _ = _run_stack(params["enc_units"], cfg, x, positions, causal=False,
                      use_rope=False)
    return _norm(cfg, params["enc_final_norm"], x)


def train_loss(cfg: ArchConfig, remat_policy: str | None = None):
    """Returns loss_fn(params, batch) -> scalar. Batch fields by family:
    tokens (B, S) + labels (B, S); audio: + audio_embed (B, F, d);
    vlm: + patch_embed (B, P, d) (loss on tokens only)."""

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        labels = batch["labels"]
        b, s = tokens.shape
        x = embed(params["embed"], tokens)
        if cfg.family == "vlm":
            x = jnp.concatenate([batch["patch_embed"].astype(x.dtype), x], axis=1)
            positions = jnp.broadcast_to(jnp.arange(x.shape[1]), (b, x.shape[1]))
        else:
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        enc_out = None
        use_rope = cfg.family != "audio"
        if cfg.enc_dec:
            enc_out = _encode(params, cfg, batch["audio_embed"])
            x = x + params["dec_pos"]["pos"][:s]
        x, aux = _run_stack(params["units"], cfg, x, positions,
                            window=cfg.attn_window, enc_out=enc_out,
                            use_rope=use_rope, remat_policy=remat_policy)
        if cfg.family == "vlm":
            x = x[:, -s:]
        x = _norm(cfg, params["final_norm"], x)
        logits = _mask_pad_vocab(cfg, unembed(params["embed"], x).astype(jnp.float32))
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return ce + 0.01 * aux

    return loss_fn


# -- caches for serving ------------------------------------------------------

def init_caches(cfg: ArchConfig, batch: int, cache_len: int):
    """Stacked (n_units leading dim) cache pytree."""
    layout = unit_layout(cfg)
    nu = n_units(cfg)
    d = cfg.d_model
    if cfg.attn_window:
        attn_len = min(cache_len, cfg.attn_window)
    else:
        attn_len = cache_len

    def unit_cache():
        return {str(j): _init_layer_cache(cfg, mixer, batch,
                                          attn_len if mixer == "attn" else cache_len, d)
                for j, (mixer, _) in enumerate(layout)}

    one = unit_cache()
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (nu, *x.shape)).copy(), one)


def cache_specs(cfg: ArchConfig, dp=("pod", "data")):
    """dp: mesh axes carrying the batch dim (None to leave batch unsharded —
    required when global_batch doesn't divide the DP extent, e.g. long_500k)."""
    layout = unit_layout(cfg)
    unit = {str(j): _layer_cache_spec(cfg, mixer, dp)
            for j, (mixer, _) in enumerate(layout)}
    return jax.tree.map(lambda sp: P(None, *sp), unit,
                        is_leaf=lambda x: isinstance(x, P))


def build_enc_kv(cfg: ArchConfig, params, enc_out):
    """Per-decoder-layer cross-attention K/V from the encoder output —
    computed once per request, reused every decode step (stacked over units,
    scanned alongside the caches)."""
    from .param import apply_dense
    layout = unit_layout(cfg)
    hd = cfg.hd
    b, f, _ = enc_out.shape

    def unit_body(_, unit_p):
        kv = {}
        for j, (mixer, _f) in enumerate(layout):
            pc = unit_p[str(j)]["cross"]
            k = apply_dense(pc["k"], enc_out).reshape(b, f, cfg.n_kv_heads, hd)
            v = apply_dense(pc["v"], enc_out).reshape(b, f, cfg.n_kv_heads, hd)
            kv[str(j)] = (k, v)
        return None, kv

    _, stacked = jax.lax.scan(unit_body, None, params["units"])
    return stacked


def decode_step(cfg: ArchConfig):
    """Returns step(params, caches, tokens (B,1), [enc_kv stacked]) ->
    (logits (B, vocab), new_caches)."""
    layout = unit_layout(cfg)
    use_rope = cfg.family != "audio"

    def step(params, caches, tokens, enc_kv=None):
        x = embed(params["embed"], tokens)
        if cfg.enc_dec:
            # learned decoder position = current self-attn cache length
            length = caches["0"].length[0]
            x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"]["pos"],
                                                 length, 1, axis=0)

        def unit_body(x, scanned):
            if enc_kv is not None:
                unit_p, unit_c, unit_kv = scanned
            else:
                unit_p, unit_c = scanned
                unit_kv = None
            new_c = {}
            for j, (mixer, ffn) in enumerate(layout):
                x, c = _apply_layer_decode(
                    unit_p[str(j)], cfg, x, unit_c[str(j)], mixer, ffn,
                    cfg.d_model, window=cfg.attn_window,
                    enc_kv=unit_kv[str(j)] if unit_kv is not None else None,
                    use_rope=use_rope)
                new_c[str(j)] = c
            return x, new_c

        xs = (params["units"], caches) if enc_kv is None else \
            (params["units"], caches, enc_kv)
        x, new_caches = jax.lax.scan(unit_body, x, xs)
        x = _norm(cfg, params["final_norm"], x)
        logits = _mask_pad_vocab(cfg, unembed(params["embed"], x[:, 0]).astype(jnp.float32))
        return logits, new_caches

    return step


def prefill_step(cfg: ArchConfig):
    """Returns prefill(params, batch) -> (last-token logits, caches).

    Runs the full forward and populates per-layer caches (attention K/V for
    attn layers; recurrent states for SSM layers are produced by a final
    decode-shaped pass in serving — here we return attention caches, which is
    what the decode_32k shape consumes)."""
    layout = unit_layout(cfg)
    use_rope = cfg.family != "audio"

    def prefill(params, batch):
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = embed(params["embed"], tokens)
        if cfg.family == "vlm":
            x = jnp.concatenate([batch["patch_embed"].astype(x.dtype), x], axis=1)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), (b, x.shape[1]))
        enc_out = None
        if cfg.enc_dec:
            enc_out = _encode(params, cfg, batch["audio_embed"])
            x = x + params["dec_pos"]["pos"][:s]

        def unit_body(carry, unit_p):
            x = carry
            caches = {}
            for j, (mixer, ffn) in enumerate(layout):
                p = unit_p[str(j)]
                if mixer == "attn":
                    h = _norm(cfg, p["norm1"], x)
                    y, cache = attn.attention_prefill(p["mixer"], cfg, h, positions,
                                                      window=cfg.attn_window,
                                                      use_rope=use_rope)
                    x = x + y
                    if cfg.enc_dec:
                        hx = _norm(cfg, p["norm_x"], x)
                        x = x + attn.attention_train(p["cross"], cfg, hx, positions,
                                                     kv_x=enc_out, use_rope=False)
                    x, _ = _apply_ffn(p, cfg, x, ffn)
                    caches[str(j)] = cache
                else:
                    # recurrent layers: run the train mixer; final state is
                    # reconstructed by the serving loop (documented in serve/)
                    x, _ = _apply_layer_train(p, cfg, x, positions, mixer, ffn,
                                              cfg.d_model, use_rope=use_rope)
                    caches[str(j)] = _init_layer_cache(cfg, mixer, b, 1, cfg.d_model)
            return x, caches

        x, caches = jax.lax.scan(unit_body, x, params["units"])
        x = _norm(cfg, params["final_norm"], x)
        logits = _mask_pad_vocab(cfg, unembed(params["embed"], x[:, -1]).astype(jnp.float32))
        return logits, caches

    return prefill
