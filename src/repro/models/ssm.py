"""Mamba (S6) block — selective state-space sequence mixing.

Training path: chunked selective scan — ``lax.scan`` over sequence chunks
with an ``associative_scan`` inside each chunk, carrying the (B, d_inner,
d_state) state between chunks. The (B, chunk, d_inner, d_state) intermediate
is the peak live tensor; with d_inner sharded over ``model`` it stays small
(DESIGN.md §6). Decode path: O(1) recurrent state update per token.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .param import SP, make_dense, apply_dense, normal
from .layers import W_IN, W_OUT
from .sharding import DP, constrain


def dt_rank_for(d_model: int) -> int:
    return max(math.ceil(d_model / 16), 1)


def init_mamba(key, cfg, d: int) -> dict:
    dt = jnp.dtype(cfg.dtype)
    di = cfg.mamba_expand * d
    ds = cfg.mamba_d_state
    dr = dt_rank_for(d)
    conv = cfg.mamba_conv
    keys = jax.random.split(key, 6)
    # A initialised to -[1..ds] per channel (S4D-real init)
    a_init = jnp.log(jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds)))
    return {
        "in_proj": make_dense(keys[0], d, 2 * di, W_IN, dt),
        "conv_w": SP(normal(keys[1], (conv, di), dt, conv ** -0.5), P(None, "model")),
        "conv_b": SP(jnp.zeros((di,), dt), P("model")),
        "x_proj": make_dense(keys[2], di, dr + 2 * ds, P("model", None), dt),
        "dt_proj": make_dense(keys[3], dr, di, P(None, "model"), dt, bias=True,
                              bias_spec=P("model")),
        "a_log": SP(a_init, P("model", None)),
        "d_skip": SP(jnp.ones((di,), jnp.float32), P("model")),
        "out_proj": make_dense(keys[4], di, d, W_OUT, dt, scale=di ** -0.5),
    }


class MambaState(NamedTuple):
    h: jax.Array         # (B, d_inner, d_state) f32 — SSM state
    conv: jax.Array      # (B, conv-1, d_inner) — causal conv tail

    @staticmethod
    def spec(dp=("pod", "data")):
        return MambaState(h=P(dp, "model", None),
                          conv=P(dp, None, "model"))


def init_mamba_state(cfg, batch: int, d: int) -> MambaState:
    di = cfg.mamba_expand * d
    return MambaState(
        h=jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.mamba_conv - 1, di), jnp.dtype(cfg.dtype)))


def _causal_conv(p, x, cfg):
    """Depthwise causal conv over seq. x: (B, S, di)."""
    conv = cfg.mamba_conv
    pad = jnp.pad(x, ((0, 0), (conv - 1, 0), (0, 0)))
    # depthwise: sum over the small kernel window (unrolled, conv is 4)
    y = sum(pad[:, i:i + x.shape[1], :] * p["conv_w"][i] for i in range(conv))
    return y + p["conv_b"]


def _ssm_params(p, x, cfg, d: int):
    """x: (..., di) -> delta (..., di), B (..., ds), C (..., ds)."""
    ds = cfg.mamba_d_state
    dr = dt_rank_for(d)
    proj = apply_dense(p["x_proj"], x)
    dt_in, b, c = jnp.split(proj, [dr, dr + ds], axis=-1)
    delta = jax.nn.softplus(apply_dense(p["dt_proj"], dt_in).astype(jnp.float32))
    return delta, b.astype(jnp.float32), c.astype(jnp.float32)


def mamba_train(p, cfg, x, d: int, chunk: int = 256):
    """Full-sequence Mamba mixing. x: (B, S, d) -> (B, S, d)."""
    b_sz, s, _ = x.shape
    di = cfg.mamba_expand * d
    ds = cfg.mamba_d_state
    xz = apply_dense(p["in_proj"], x)
    u, z = jnp.split(xz, 2, axis=-1)
    u = constrain(u, DP, None, "model")
    z = constrain(z, DP, None, "model")
    u = jax.nn.silu(_causal_conv(p, u, cfg))
    delta, bmat, cmat = _ssm_params(p, u, cfg, d)
    A = -jnp.exp(p["a_log"])                                   # (di, ds)

    n_chunks = max(s // chunk, 1)
    ch = s // n_chunks if s % n_chunks == 0 else s
    if s % ch != 0:
        ch, n_chunks = s, 1

    def chunk_body(h, args):
        uc, dc, bc, cc = args                                  # (B, ch, ...)
        decay = jnp.exp(dc[..., None] * A)                     # (B, ch, di, ds)
        xin = (dc * uc.astype(jnp.float32))[..., None] * bc[:, :, None, :]
        # prepend carry as an extra step: h_0 with decay 1
        dec = jnp.concatenate([jnp.ones_like(decay[:, :1]), decay], axis=1)
        xi = jnp.concatenate([h[:, None], xin], axis=1)

        def comb(a, b):
            return (a[0] * b[0], b[0] * a[1] + b[1])

        _, hs = jax.lax.associative_scan(comb, (dec, xi), axis=1)
        y = jnp.einsum("bsdn,bsn->bsd", hs[:, 1:], cc)
        return hs[:, -1], y

    u_c = u.reshape(b_sz, n_chunks, ch, di).transpose(1, 0, 2, 3)
    d_c = delta.reshape(b_sz, n_chunks, ch, di).transpose(1, 0, 2, 3)
    b_c = bmat.reshape(b_sz, n_chunks, ch, ds).transpose(1, 0, 2, 3)
    c_c = cmat.reshape(b_sz, n_chunks, ch, ds).transpose(1, 0, 2, 3)
    h0 = jnp.zeros((b_sz, di, ds), jnp.float32)
    # remat: recompute the (B, ch, di, ds) decay/state tensors in the bwd
    # pass instead of saving them per chunk (16x memory on jamba train_4k)
    _, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, (u_c, d_c, b_c, c_c))
    y = ys.transpose(1, 0, 2, 3).reshape(b_sz, s, di)
    y = y + u.astype(jnp.float32) * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return apply_dense(p["out_proj"], y)


def mamba_decode(p, cfg, x, state: MambaState, d: int):
    """Single-token decode. x: (B, 1, d) -> ((B, 1, d), new_state)."""
    b_sz = x.shape[0]
    di = cfg.mamba_expand * d
    xz = apply_dense(p["in_proj"], x)                          # (B, 1, 2di)
    u, z = jnp.split(xz[:, 0], 2, axis=-1)                     # (B, di)
    window = jnp.concatenate([state.conv, u[:, None, :]], axis=1)  # (B, conv, di)
    uc = jnp.einsum("bcd,cd->bd", window, p["conv_w"]) + p["conv_b"]
    uc = jax.nn.silu(uc)
    delta, bmat, cmat = _ssm_params(p, uc, cfg, d)             # (B, di), (B, ds)
    A = -jnp.exp(p["a_log"])
    decay = jnp.exp(delta[..., None] * A)                      # (B, di, ds)
    h = decay * state.h + (delta * uc.astype(jnp.float32))[..., None] * bmat[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, cmat) + uc.astype(jnp.float32) * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = apply_dense(p["out_proj"], y)[:, None, :]
    return out, MambaState(h=h, conv=window[:, 1:])
