"""Activation sharding constraints.

XLA's sharding propagation loses the TP sharding at reshape boundaries
(e.g. the (B, S, H*hd) -> (B, S, H, hd) head split after a column-parallel
projection) and will happily replicate attention across the model axis —
16x the FLOPs and HBM (caught by the loop-aware roofline; see EXPERIMENTS.md
§Perf iteration 1). ``constrain`` pins activations where propagation is
known to drop the ball, and is a no-op outside a mesh context so single-
device smoke tests run unchanged.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def _current_mesh():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        try:
            from jax.interpreters import pxla
            mesh = pxla.thread_resources.env.physical_mesh
            if not mesh.empty:
                return mesh
        except Exception:
            pass
    return None


def constrain(x, *axes):
    """constrain(x, ('pod','data'), None, 'model', None) — axis entries not
    present on the active mesh are dropped; no active mesh -> identity."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)

    def filt(a, dim):
        if a is None:
            return None
        if not isinstance(a, (tuple, list)):
            a = (a,)
        kept = tuple(x_ for x_ in a if x_ in names)
        if not kept:
            return None
        extent = 1
        for n in kept:
            extent *= mesh.shape[n]
        if dim < extent:          # e.g. batch=1 long-context: don't shard
            return None
        # uneven dims (phi3: 40 heads / 16-way model axis) are allowed — XLA
        # pads. Waste is bounded by (ceil(dim/extent)*extent)/dim and shows
        # up honestly in the roofline FLOPs.
        return kept if len(kept) > 1 else kept[0]

    spec = P(*[filt(a, d) for a, d in zip(axes, x.shape)])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


DP = ("pod", "data")


def row_parallel_dense(w, x, *, batch_axes=DP, tp_axis="model"):
    """Row-parallel (Megatron) matmul with the *textbook* communication
    schedule, bf16 on the wire (EXPERIMENTS.md §Perf iter 4c).

    XLA's default partitioning of this contraction all-reduces the f32 dot
    accumulator forward AND inserts x-sized f32 collectives in the backward
    (HLO audit). The custom VJP encodes what Megatron actually does:
      fwd:  y  = psum_tp(x_local @ w_local)              — ONE bf16 AR
      bwd:  dx = dy @ w_localᵀ                           — NO collective
            dw = psum_dp(x_localᵀ @ dy)                  — tiny (K_loc, N)
    with cotangents cast to the weight dtype. Falls back to a plain einsum
    when no mesh / no tp axis is active or the batch doesn't divide the DP
    extent (single-device tests, long_500k B=1)."""
    mesh = _current_mesh()
    if mesh is None or tp_axis not in mesh.axis_names:
        return jnp.einsum("...i,io->...o", x, w)
    names = set(mesh.axis_names)
    ba = tuple(a for a in batch_axes if a in names)
    extent = 1
    for a in ba:
        extent *= mesh.shape[a]
    if (x.shape[0] % max(extent, 1) != 0 or
            x.shape[-1] % mesh.shape[tp_axis] != 0):
        return jnp.einsum("...i,io->...o", x, w)
    return _row_parallel_custom(w, x, mesh, ba if ba else None, tp_axis,
                                x.ndim)


def _rp_specs(bspec, tp_axis, ndim):
    from jax.sharding import PartitionSpec as P
    x_spec = P(bspec, *([None] * (ndim - 2)), tp_axis)
    w_spec = P(tp_axis, None)
    y_spec = P(bspec, *([None] * (ndim - 1)))
    return x_spec, w_spec, y_spec


def _row_parallel_custom(w, x, mesh, bspec, tp_axis, ndim):
    from jax.experimental.shard_map import shard_map

    x_spec, w_spec, y_spec = _rp_specs(bspec, tp_axis, ndim)

    @jax.custom_vjp
    def rp(w_, x_):
        def fwd_local(x_l, w_l):
            return jax.lax.psum(jnp.einsum("...i,io->...o", x_l, w_l), tp_axis)
        return shard_map(fwd_local, mesh=mesh, in_specs=(x_spec, w_spec),
                         out_specs=y_spec, check_rep=False)(x_, w_)

    def rp_fwd(w_, x_):
        return rp(w_, x_), (w_, x_)

    def rp_bwd(res, dy):
        w_, x_ = res
        dy_c = dy.astype(w_.dtype)                   # bf16 on the wire

        def dx_local(dy_l, w_l):                     # no collective
            return jnp.einsum("...o,io->...i", dy_l, w_l)

        dx = shard_map(dx_local, mesh=mesh, in_specs=(y_spec, w_spec),
                       out_specs=x_spec, check_rep=False)(dy_c, w_)

        dp_axes = bspec

        def dw_local(x_l, dy_l):                     # (K_loc, N) psum over DP
            dw_ = jnp.einsum("...i,...o->io", x_l, dy_l)
            return jax.lax.psum(dw_, dp_axes) if dp_axes else dw_

        dw = shard_map(dw_local, mesh=mesh, in_specs=(x_spec, y_spec),
                       out_specs=w_spec, check_rep=False)(x_, dy_c)
        # cotangent dtypes MUST match the primal dtypes (custom_vjp contract;
        # the whisper encoder runs its residual stream in f32)
        return dw.astype(w_.dtype), dx.astype(x_.dtype)

    rp.defvjp(rp_fwd, rp_bwd)
    return rp(w, x)

