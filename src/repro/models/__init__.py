from .transformer import (  # noqa: F401
    init_params, abstract_params, train_loss, decode_step, prefill_step,
    init_caches, cache_specs, build_enc_kv, unit_layout, n_units,
)
from .param import SP, split, stack_sp  # noqa: F401
