"""Mixture-of-Experts FFN: token-choice top-k routing, expert-parallel.

Two dispatch paths:

* ``moe_ffn`` (default) — capacity-based dispatch: tokens are scattered into
  a dense (E, capacity, d) buffer (capacity = T/E * top_k * cf), experts run
  as one batched einsum with experts sharded over the ``model`` mesh axis
  (EP), results are combined with router weights. Active FLOPs ≈
  6·N_active·D as the MoE roofline expects; tokens beyond capacity are
  dropped (cf=1.25 default, standard practice).
* ``moe_ffn_dense`` — exact dense one-hot dispatch (every expert sees every
  token, masked). No drops; used as the small-config oracle in tests and for
  single-token decode where T is tiny.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .param import SP, normal
from .sharding import DP, constrain


def init_moe(key, cfg, d: int) -> dict:
    moe = cfg.moe
    ff = cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    kr, kg, ku, kd = jax.random.split(key, 4)
    e = moe.n_experts
    return {
        "router": SP(normal(kr, (d, e), dt, d ** -0.5), P(("pod", "data"), None)),
        "gate": SP(normal(kg, (e, d, ff), dt, d ** -0.5), P("model", ("pod", "data"), None)),
        "up": SP(normal(ku, (e, d, ff), dt, d ** -0.5), P("model", ("pod", "data"), None)),
        "down": SP(normal(kd, (e, ff, d), dt, ff ** -0.5), P("model", None, ("pod", "data"))),
    }


def _route(p, moe, xt):
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, moe.top_k)          # (T, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    return probs, top_w, top_e


def _aux_loss(moe, probs, top_e):
    # Switch-style load-balance loss
    me = jnp.mean(probs, axis=0)                            # (E,)
    assigned = jax.nn.one_hot(top_e, moe.n_experts, dtype=jnp.float32).sum(1)
    ce = jnp.mean(assigned, axis=0)
    return moe.n_experts * jnp.sum(me * ce / moe.top_k)


def _expert_compute(p, h_in):
    """h_in: (E, C, d) -> (E, C, d) through each expert's SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", h_in, p["gate"])
    u = jnp.einsum("ecd,edf->ecf", h_in, p["up"])
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["down"])


def _n_groups(t: int) -> int:
    """Dispatch groups (GShard-style): a multiple of the DP extent so each
    data shard dispatches its own token groups locally."""
    for g in (64, 32, 16, 8, 4, 2):
        if t % g == 0 and t // g >= 1:
            return g
    return 1


def moe_ffn(p, cfg, x, capacity_factor: float | None = None,
            n_groups: int | None = None):
    """Grouped capacity-dispatch MoE. x: (B, S, d) -> ((B, S, d), aux_loss).

    Tokens are split into G groups aligned with the DP sharding; routing
    positions and capacity are per group, so the dispatch scatter/gather is
    a *batched* (group-local) operation that SPMD partitions trivially —
    no global scatter, no replicated (C, ff) hidden (the naive global-
    capacity layout put a 2.35 GB f32 tensor on every chip; HLO-dump
    finding, see EXPERIMENTS.md §Perf). Per-group capacity is the standard
    GShard/Switch formulation.
    """
    moe = cfg.moe
    cf = capacity_factor if capacity_factor is not None else moe.capacity_factor
    b, s, d = x.shape
    t = b * s
    g = n_groups or _n_groups(t)
    tg = t // g
    cap = max(int(math.ceil(tg * moe.top_k * cf / moe.n_experts)), moe.top_k)

    xt = x.reshape(g, tg, d)
    xt = constrain(xt, DP, None, None)
    probs, top_w, top_e = _route(p, moe, xt.reshape(t, d))
    probs_g = probs.reshape(g, tg, moe.n_experts)
    w_g = top_w.reshape(g, tg, moe.top_k)
    e_g = top_e.reshape(g, tg, moe.top_k)

    def dispatch_one(xg, wg, eg):
        """One group: (tg, d), (tg, k), (tg, k) -> (E, cap, d) + combine."""
        flat_e = eg.reshape(-1)                             # (tg*k,)
        flat_w = wg.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, moe.n_experts, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) * onehot
        pos_in_e = jnp.sum(pos, axis=-1) - 1
        keep = pos_in_e < cap
        tok_idx = jnp.repeat(jnp.arange(tg), moe.top_k)
        slot_e = jnp.where(keep, flat_e, 0)
        slot_c = jnp.where(keep, pos_in_e, 0)
        buf = jnp.zeros((moe.n_experts, cap, d), xg.dtype)
        buf = buf.at[slot_e, slot_c].add(
            jnp.where(keep[:, None], xg[tok_idx], 0).astype(xg.dtype),
            mode="drop")
        return buf, (slot_e, slot_c, tok_idx, flat_w, keep)

    buf, meta = jax.vmap(dispatch_one)(xt, w_g, e_g)        # (G, E, cap, d)
    buf = constrain(buf, DP, "model", None, None)
    # expert compute, batched over groups: fully local per (dp, model) shard
    gg = jnp.einsum("gecd,edf->gecf", buf, p["gate"])
    uu = jnp.einsum("gecd,edf->gecf", buf, p["up"])
    out_e = jnp.einsum("gecf,efd->gecd", jax.nn.silu(gg) * uu, p["down"])
    out_e = constrain(out_e, DP, "model", None, None)

    def combine_one(oe, m):
        slot_e, slot_c, tok_idx, flat_w, keep = m
        gathered = oe[slot_e, slot_c]                       # (tg*k, d)
        contrib = gathered.astype(jnp.float32) * (flat_w * keep)[:, None]
        return jnp.zeros((tg, d), jnp.float32).at[tok_idx].add(contrib)

    out = jax.vmap(combine_one)(out_e, meta)                # (G, tg, d)
    out = constrain(out, DP, None, None)
    return out.reshape(b, s, d).astype(x.dtype), _aux_loss(moe, probs, top_e)


def moe_ffn_dense(p, cfg, x):
    """Exact dense dispatch (oracle / decode path)."""
    moe = cfg.moe
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    probs, top_w, top_e = _route(p, moe, xt)
    combine = jnp.zeros((xt.shape[0], moe.n_experts), jnp.float32)
    combine = jax.vmap(lambda c, e_i, w: c.at[e_i].add(w))(combine, top_e, top_w)
    out_e = _expert_compute(p, jnp.broadcast_to(xt, (moe.n_experts, *xt.shape)))
    out = jnp.einsum("etd,te->td", out_e.astype(jnp.float32), combine)
    return out.reshape(b, s, d).astype(x.dtype), _aux_loss(moe, probs, top_e)
