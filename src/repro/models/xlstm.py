"""xLSTM blocks (arXiv:2405.04517): sLSTM (scalar memory, recurrent) and
mLSTM (matrix memory, attention-like parallel form for training + O(1)
recurrent decode).

mLSTM training uses the stabilised parallel (quadratic) formulation with
query-chunking (same flash-style discipline as attention.py); decode updates
the per-head (hd, hd) matrix memory C, normaliser n and stabiliser m.
sLSTM is inherently sequential (recurrent gate coupling through h_{t-1});
training scans over time — this is the documented cost of the architecture,
not an implementation shortcut (the original xLSTM trains the same way).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .param import SP, make_dense, apply_dense, normal
from .layers import W_IN, W_OUT

NEG = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

REP = P(None, None)
# xLSTM-350m block weights are REPLICATED over the model axis (TP only for
# the 50k-vocab embedding/unembedding). Rationale (§Perf iter 3, HLO audit):
# with d=1024 and 4 heads, TP-sharding the projections makes every head-dim
# contraction partial — a 537 MB all-reduce per mLSTM chunk (1.6 TB/step)
# and a per-timestep all-reduce in the sLSTM recurrence. A 350M model's
# whole weight set is 0.7 GB bf16 per chip replicated — TP buys nothing.


def init_mlstm(key, cfg, d: int) -> dict:
    dt = jnp.dtype(cfg.dtype)
    h = cfg.n_heads
    hd = d // h
    ks = jax.random.split(key, 6)
    return {
        "q": make_dense(ks[0], d, d, REP, dt),
        "k": make_dense(ks[1], d, d, REP, dt),
        "v": make_dense(ks[2], d, d, REP, dt),
        # head-count gates are tiny (n_heads outputs) — replicated, since
        # n_heads may be far below the model-axis size (xlstm-350m: 4 heads)
        "i_gate": make_dense(ks[3], d, h, REP, dt, bias=True,
                             bias_spec=P(None)),
        "f_gate": make_dense(ks[4], d, h, REP, dt, bias=True,
                             bias_spec=P(None)),
        "o": make_dense(ks[5], d, d, REP, dt),
    }


class MLSTMState(NamedTuple):
    c: jax.Array     # (B, H, hd, hd) f32 matrix memory
    n: jax.Array     # (B, H, hd) f32 normaliser
    m: jax.Array     # (B, H) f32 stabiliser

    @staticmethod
    def spec(dp=("pod", "data")):
        # head dim is small (4) — shard batch only
        return MLSTMState(c=P(dp, None, None, None),
                          n=P(dp, None, None),
                          m=P(dp, None))


def init_mlstm_state(cfg, batch: int, d: int) -> MLSTMState:
    h = cfg.n_heads
    hd = d // h
    return MLSTMState(c=jnp.zeros((batch, h, hd, hd), jnp.float32),
                      n=jnp.zeros((batch, h, hd), jnp.float32),
                      m=jnp.full((batch, h), NEG, jnp.float32))


def _mlstm_qkv(p, cfg, x, d):
    h = cfg.n_heads
    hd = d // h
    q = apply_dense(p["q"], x).reshape(*x.shape[:-1], h, hd)
    k = apply_dense(p["k"], x).reshape(*x.shape[:-1], h, hd)
    v = apply_dense(p["v"], x).reshape(*x.shape[:-1], h, hd)
    i_pre = apply_dense(p["i_gate"], x).astype(jnp.float32)   # (B, S, H)
    f_pre = apply_dense(p["f_gate"], x).astype(jnp.float32)
    return q, k, v, i_pre, f_pre


def mlstm_train(p, cfg, x, d: int, chunk: int = 512):
    """Parallel (quadratic) stabilised mLSTM. x: (B, S, d)."""
    b, s, _ = x.shape
    h = cfg.n_heads
    hd = d // h
    q, k, v, i_pre, f_pre = _mlstm_qkv(p, cfg, x, d)
    logf = jax.nn.log_sigmoid(f_pre)                          # (B, S, H)
    cumf = jnp.cumsum(logf, axis=1)                           # (B, S, H)
    # log decay matrix entry (t, s): cumf_t - cumf_s + i_s  for s <= t
    a = cumf.transpose(0, 2, 1)                               # (B, H, S)
    ilog = (i_pre + 0.0).transpose(0, 2, 1)                   # (B, H, S)
    scale = hd ** -0.5

    n_chunks = max(s // chunk, 1)
    ch = s // n_chunks if s % n_chunks == 0 else s
    if s % ch != 0:
        ch, n_chunks = s, 1

    qh = q.transpose(0, 2, 1, 3)                              # (B, H, S, hd)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    kpos = jnp.arange(s)

    def one_chunk(c0):
        qs = jax.lax.dynamic_slice_in_dim(qh, c0, ch, axis=2)
        ac = jax.lax.dynamic_slice_in_dim(a, c0, ch, axis=2)  # (B, H, ch)
        logd = ac[..., None] - a[:, :, None, :] + ilog[:, :, None, :]  # (B,H,ch,S)
        qpos = c0 + jnp.arange(ch)
        mask = kpos[None, :] <= qpos[:, None]
        logd = jnp.where(mask[None, None], logd, NEG)
        mrow = jnp.max(logd, axis=-1, keepdims=True)          # (B, H, ch, 1)
        dmat = jnp.exp(logd - mrow)
        smat = jnp.einsum("bhqe,bhke->bhqk", qs.astype(jnp.float32),
                          kh.astype(jnp.float32)) * scale * dmat
        norm = jnp.maximum(jnp.abs(smat.sum(-1, keepdims=True)),
                           jnp.exp(-mrow))
        return jnp.einsum("bhqk,bhke->bhqe", smat / norm, vh.astype(jnp.float32))

    if n_chunks == 1:
        out = one_chunk(0)
    else:
        _, outs = jax.lax.scan(jax.checkpoint(lambda _, i: (None, one_chunk(i * ch))),
                               None, jnp.arange(n_chunks))
        out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, s, hd)
    y = out.transpose(0, 2, 1, 3).reshape(b, s, d).astype(x.dtype)
    return apply_dense(p["o"], y)


def mlstm_decode(p, cfg, x, state: MLSTMState, d: int):
    """O(1) recurrent step. x: (B, 1, d)."""
    b = x.shape[0]
    h = cfg.n_heads
    hd = d // h
    q, k, v, i_pre, f_pre = _mlstm_qkv(p, cfg, x, d)
    q, k, v = (t[:, 0].astype(jnp.float32) for t in (q, k, v))  # (B, H, hd)
    i_t = i_pre[:, 0]                                          # (B, H)
    logf = jax.nn.log_sigmoid(f_pre[:, 0])
    m_new = jnp.maximum(logf + state.m, i_t)
    fw = jnp.exp(logf + state.m - m_new)[..., None]            # (B, H, 1)
    iw = jnp.exp(i_t - m_new)[..., None]
    scale = hd ** -0.5
    c_new = fw[..., None] * state.c + iw[..., None] * (k[..., :, None] * v[..., None, :])
    n_new = fw * state.n + iw * k
    num = jnp.einsum("bhij,bhi->bhj", c_new, q * scale)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhi,bhi->bh", n_new, q * scale)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(b, 1, d).astype(x.dtype)
    return apply_dense(p["o"], y), MLSTMState(c_new, n_new, m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg, d: int) -> dict:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 9)
    gates = {}
    for i, g in enumerate(("i", "f", "z", "o")):
        gates[f"w_{g}"] = make_dense(ks[2 * i], d, d, REP, dt,
                                     bias=True, bias_spec=P(None))
        # recurrence weights are REPLICATED: sharding the (d, d) recurrent
        # matvec over `model` costs one all-gather of h per *timestep* per
        # gate (33.9 s of ICI per prefill_32k step at d=1024 — §Perf iter 3).
        # The matvec is tiny; replication removes the per-step collectives.
        gates[f"r_{g}"] = make_dense(ks[2 * i + 1], d, d, P(None, None), dt)
    gates["out"] = make_dense(ks[8], d, d, REP, dt)
    return gates


class SLSTMState(NamedTuple):
    c: jax.Array   # (B, d) f32
    n: jax.Array   # (B, d)
    h: jax.Array   # (B, d)
    m: jax.Array   # (B, d)

    @staticmethod
    def spec(dp=("pod", "data")):
        # replicated over `model`: the recurrence consumes the full h vector
        s = P(dp, None)
        return SLSTMState(c=s, n=s, h=s, m=s)


def init_slstm_state(cfg, batch: int, d: int) -> SLSTMState:
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full((batch, d), NEG, jnp.float32))


def _slstm_step(p, xi, xf, xz, xo, st: SLSTMState):
    """One recurrence step; x* are precomputed input projections (B, d)."""
    h = st.h
    i_pre = xi + jnp.einsum("bd,do->bo", h, p["r_i"]["w"].astype(jnp.float32))
    f_pre = xf + jnp.einsum("bd,do->bo", h, p["r_f"]["w"].astype(jnp.float32))
    z_pre = xz + jnp.einsum("bd,do->bo", h, p["r_z"]["w"].astype(jnp.float32))
    o_pre = xo + jnp.einsum("bd,do->bo", h, p["r_o"]["w"].astype(jnp.float32))
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + st.m, i_pre)
    i_t = jnp.exp(i_pre - m_new)
    f_t = jnp.exp(logf + st.m - m_new)
    c_new = f_t * st.c + i_t * jnp.tanh(z_pre)
    n_new = f_t * st.n + i_t
    h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1e-6)
    return SLSTMState(c=c_new, n=n_new, h=h_new, m=m_new)


def _slstm_inputs(p, x):
    xi = apply_dense(p["w_i"], x).astype(jnp.float32)
    xf = apply_dense(p["w_f"], x).astype(jnp.float32)
    xz = apply_dense(p["w_z"], x).astype(jnp.float32)
    xo = apply_dense(p["w_o"], x).astype(jnp.float32)
    return xi, xf, xz, xo


# --- custom-VJP recurrence core -------------------------------------------
#
# Autodiff of the timestep scan accumulates the recurrent-weight gradient in
# the scan carry; under DP sharding each step's contribution is partial over
# the batch axis, so SPMD inserts an all-reduce of four (d, d) gradients PER
# TIMESTEP (xlstm train_4k: 16 MB x 4096 steps x 12 units x 4 microbatches
# = 3.3 TB of ICI per step; §Perf iter 3c). The custom VJP instead emits the
# per-step pre-activation cotangents as scan outputs and contracts dR =
# h_prev^T @ d_pre ONCE over the whole sequence — a single all-reduce.

def _gate_step(rs, pres, st):
    """(i_pre, f_pre, z_pre, o_pre) + state -> new state. rs unused here;
    pres already include the recurrent contribution."""
    i_pre, f_pre, z_pre, o_pre = pres
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + st.m, i_pre)
    i_t = jnp.exp(i_pre - m_new)
    f_t = jnp.exp(logf + st.m - m_new)
    c_new = f_t * st.c + i_t * jnp.tanh(z_pre)
    n_new = f_t * st.n + i_t
    h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1e-6)
    return SLSTMState(c=c_new, n=n_new, h=h_new, m=m_new)


def _slstm_scan_fwd(rs, xs, st0):
    """rs: (Ri, Rf, Rz, Ro) f32 (d, d); xs: (xi, xf, xz, xo) each (B, S, d)
    f32. Returns hs (B, S, d) f32 + residuals."""
    def body(st, xt):
        pres = tuple(x + st.h @ r for x, r in zip(xt, rs))
        new = _gate_step(rs, pres, st)
        return new, (st, new.h)
    xs_t = tuple(jnp.moveaxis(x, 1, 0) for x in xs)          # (S, B, d)
    _, (prev_states, hs) = jax.lax.scan(body, st0, xs_t)
    return jnp.moveaxis(hs, 0, 1), (rs, xs_t, prev_states)


@jax.custom_vjp
def _slstm_core(rs, xs, st0):
    hs, _ = _slstm_scan_fwd(rs, xs, st0)
    return hs


def _slstm_core_fwd(rs, xs, st0):
    hs, res = _slstm_scan_fwd(rs, xs, st0)
    return hs, res


def _slstm_core_bwd(res, g_hs):
    rs, xs_t, prev_states = res
    g_hs_t = jnp.moveaxis(g_hs, 1, 0)                        # (S, B, d)
    rs_T = tuple(r.T for r in rs)
    zero = jax.tree.map(jnp.zeros_like, jax.tree.map(lambda x: x[0], prev_states))

    def bwd_body(carry, step_res):
        d_state = carry                                       # grads wrt state_t
        st_prev, xt, g_h = step_res

        def fwd_t(h_prev, c_prev, n_prev, m_prev, xt_):
            stp = SLSTMState(c=c_prev, n=n_prev, h=h_prev, m=m_prev)
            pres = tuple(x + h_prev @ r for x, r in zip(xt_, rs))
            new = _gate_step(rs, pres, stp)
            # also return pres so we can capture their cotangents
            return new

        d_state = SLSTMState(c=d_state.c, n=d_state.n,
                             h=d_state.h + g_h, m=d_state.m)
        # vjp wrt (h_prev, c_prev, n_prev, m_prev, xt); R handled via d_pre
        # below: express pres-dependence through xt cotangent (same shape).
        _, vjp_fn = jax.vjp(
            lambda hp, cp, np_, mp, xt_: fwd_t(hp, cp, np_, mp, xt_),
            st_prev.h, st_prev.c, st_prev.n, st_prev.m, xt)
        dh_p, dc_p, dn_p, dm_p, d_pre = vjp_fn(d_state)
        # recurrent path: h_prev also feeds pres via R — that part of dh_p is
        # already included because fwd_t recomputes pres from h_prev.
        new_carry = SLSTMState(c=dc_p, n=dn_p, h=dh_p, m=dm_p)
        return new_carry, d_pre

    _, d_pres_t = jax.lax.scan(bwd_body, zero,
                               (prev_states, xs_t, g_hs_t), reverse=True)
    # d_pres_t: 4 x (S, B, d). Weight grads: ONE contraction over (S, B).
    h_prev_t = prev_states.h                                  # (S, B, d)
    d_rs = tuple(jnp.einsum("sbd,sbe->de", h_prev_t, dp) for dp in d_pres_t)
    d_xs = tuple(jnp.moveaxis(dp, 0, 1) for dp in d_pres_t)   # (B, S, d)
    return d_rs, d_xs, zero


_slstm_core.defvjp(_slstm_core_fwd, _slstm_core_bwd)


def slstm_train(p, cfg, x, d: int):
    """Sequential scan over time. x: (B, S, d)."""
    b, s, _ = x.shape
    xs = _slstm_inputs(p, x)
    rs = tuple(p[f"r_{g}"]["w"].astype(jnp.float32) for g in ("i", "f", "z", "o"))
    hs = _slstm_core(rs, xs, init_slstm_state(cfg, b, d))
    return apply_dense(p["out"], hs.astype(x.dtype))


def slstm_decode(p, cfg, x, state: SLSTMState, d: int):
    xi, xf, xz, xo = _slstm_inputs(p, x[:, 0])
    st = _slstm_step(p, xi, xf, xz, xo, state)
    return apply_dense(p["out"], st.h.astype(x.dtype))[:, None, :], st
