"""GQA attention: training (chunked-flash), prefill (cache build) and decode
(single-token with KV cache), with optional sliding window and cross-attention.

Memory discipline: scores are never materialised as a full (S, S) tensor —
queries are processed in chunks with a running (log-sum-exp) softmax, the
jnp-level equivalent of flash attention (the lax.scan body is what a TPU
flash kernel would fuse; on the dry-run this keeps per-chip activation
memory within HBM for prefill_32k).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name
from jax.sharding import PartitionSpec as P

from .layers import W_IN, W_OUT, apply_rope
from .param import SP, make_dense, apply_dense
from .sharding import DP, constrain, row_parallel_dense

NEG = -1e30


def init_attention(key, cfg, d_model: int | None = None) -> dict:
    d = d_model or cfg.d_model
    hd = cfg.hd
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "q": make_dense(kq, d, cfg.n_heads * hd, W_IN, cfg_dtype(cfg), bias=cfg.qkv_bias),
        "k": make_dense(kk, d, cfg.n_kv_heads * hd, W_IN, cfg_dtype(cfg), bias=cfg.qkv_bias),
        "v": make_dense(kv, d, cfg.n_kv_heads * hd, W_IN, cfg_dtype(cfg), bias=cfg.qkv_bias),
        "o": make_dense(ko, cfg.n_heads * hd, d, W_OUT, cfg_dtype(cfg),
                        scale=(cfg.n_heads * hd) ** -0.5),
    }


def cfg_dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _repeat_kv(k, n_heads, n_kv):
    if n_heads == n_kv:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=2)


def _chunked_attn(q, k, v, *, causal: bool, window: int, q_offset: int,
                  chunk: int = 512):
    """q: (B, Sq, H, hd); k, v: (B, Sk, H, hd) -> (B, Sq, H, hd).

    Scans over query chunks; each chunk computes scores vs all keys with a
    masked softmax in f32. Peak live score tensor: (B, chunk, H, Sk).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = hd ** -0.5
    n_chunks = max(sq // chunk, 1)
    chunk = sq // n_chunks if sq % n_chunks == 0 else sq  # exact tiling or single
    if sq % chunk != 0:
        chunk, n_chunks = sq, 1

    kq_pos = jnp.arange(sk)

    def attend_chunk(qc, c0):
        # qc: (B, chunk, H, hd); c0: scalar start position of the chunk
        s = jnp.einsum("bqhd,bkhd->bhqk", qc.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        qpos = c0 + q_offset + jnp.arange(chunk)
        mask = jnp.ones((chunk, sk), bool)
        if causal:
            mask &= kq_pos[None, :] <= qpos[:, None]
        if window:
            mask &= kq_pos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None], s, NEG)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)

    if n_chunks == 1:
        return attend_chunk(q, 0)

    qs = q.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)

    def body(_, qc_i):
        qc, i = qc_i
        return None, attend_chunk(qc, i * chunk)

    # remat: scores/probs are recomputed in bwd (flash-attention memory law)
    _, out = jax.lax.scan(jax.checkpoint(body), None, (qs, jnp.arange(n_chunks)))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


class KVCache(NamedTuple):
    k: jax.Array        # (B, S_cache, KV, hd)
    v: jax.Array
    length: jax.Array   # () int32 — valid prefix

    @staticmethod
    def spec(dp=("pod", "data")):
        # sequence-sharded cache: works for any kv-head count (DESIGN.md §6)
        return KVCache(k=P(dp, "model", None, None),
                       v=P(dp, "model", None, None),
                       length=P())


def init_cache(cfg, batch: int, max_len: int, d_model: int | None = None) -> KVCache:
    hd = cfg.hd
    dt = cfg_dtype(cfg)
    return KVCache(
        k=jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dt),
        v=jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dt),
        length=jnp.zeros((), jnp.int32))


def attention_train(p, cfg, x, positions, *, causal=True, window=0,
                    kv_x=None, use_rope=True):
    """Full-sequence attention (training / encoder / cross-attn).

    kv_x: source sequence for cross-attention (decoder: x attends kv_x)."""
    hd = cfg.hd
    src = x if kv_x is None else kv_x
    q = constrain(_split_heads(apply_dense(p["q"], x), cfg.n_heads, hd),
                  DP, None, "model", None)
    k = constrain(_split_heads(apply_dense(p["k"], src), cfg.n_kv_heads, hd),
                  DP, None, "model", None)
    v = constrain(_split_heads(apply_dense(p["v"], src), cfg.n_kv_heads, hd),
                  DP, None, "model", None)
    if use_rope and kv_x is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    k = _repeat_kv(k, cfg.n_heads, cfg.n_kv_heads)
    v = _repeat_kv(v, cfg.n_heads, cfg.n_kv_heads)
    k = constrain(k, DP, None, "model", None)
    v = constrain(v, DP, None, "model", None)
    o = _chunked_attn(q, k, v, causal=causal and kv_x is None, window=window,
                      q_offset=0)
    o = constrain(o, DP, None, "model", None)
    out = row_parallel_dense(p["o"]["w"],
                             o.reshape(*x.shape[:-1], cfg.n_heads * hd))
    # named so the `save_tp` remat policy can keep this row-parallel output
    # (its all-reduce is otherwise re-run during remat — §Perf iter 4b)
    return checkpoint_name(out, "tp_attn_out")


def attention_decode(p, cfg, x, cache: KVCache, *, window=0, use_rope=True):
    """Single-token decode: update cache at position cache.length, attend.

    x: (B, 1, d). Returns (out (B, 1, d), new_cache)."""
    hd = cfg.hd
    b = x.shape[0]
    pos = cache.length
    positions = jnp.full((b, 1), pos, jnp.int32)
    q = constrain(_split_heads(apply_dense(p["q"], x), cfg.n_heads, hd),
                  DP, None, None, None)
    k = _split_heads(apply_dense(p["k"], x), cfg.n_kv_heads, hd)
    v = _split_heads(apply_dense(p["v"], x), cfg.n_kv_heads, hd)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    s_cache = cache.k.shape[1]
    # ring-buffer write for windowed attention, linear write otherwise
    slot = jnp.mod(pos, s_cache) if window else jnp.minimum(pos, s_cache - 1)
    ck = jax.lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))
    # GQA-native attention: never materialise the head-repeated cache. A
    # jnp.repeat here forces SPMD to reshard the (sequence-sharded) cache —
    # an all-gather of the whole cache per layer per token (1 GB/unit on
    # granite decode_32k; found via HLO collective audit, §Perf iter 2).
    g = cfg.n_heads // cfg.n_kv_heads
    q5 = q.reshape(b, 1, cfg.n_kv_heads, g, hd)
    scale = hd ** -0.5
    s = jnp.einsum("bqkgd,bskd->bkgqs", q5.astype(jnp.float32),
                   ck.astype(jnp.float32)) * scale      # (B, KV, G, 1, S)
    kpos = jnp.arange(s_cache)
    valid = kpos <= jnp.minimum(pos, s_cache - 1) if not window else (
        jnp.logical_or(kpos <= slot, pos >= s_cache))
    s = jnp.where(valid[None, None, None, None, :], s, NEG)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", pr,
                   cv.astype(jnp.float32)).astype(x.dtype)
    out = apply_dense(p["o"], o.reshape(b, 1, cfg.n_heads * hd))
    return out, KVCache(ck, cv, cache.length + 1)


def attention_prefill(p, cfg, x, positions, *, window=0, use_rope=True):
    """Prefill: full forward + return the populated cache."""
    hd = cfg.hd
    q = constrain(_split_heads(apply_dense(p["q"], x), cfg.n_heads, hd),
                  DP, None, "model", None)
    k = constrain(_split_heads(apply_dense(p["k"], x), cfg.n_kv_heads, hd),
                  DP, None, "model", None)
    v = constrain(_split_heads(apply_dense(p["v"], x), cfg.n_kv_heads, hd),
                  DP, None, "model", None)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    kk = _repeat_kv(k, cfg.n_heads, cfg.n_kv_heads)
    vv = _repeat_kv(v, cfg.n_heads, cfg.n_kv_heads)
    o = _chunked_attn(q, kk, vv, causal=True, window=window, q_offset=0)
    out = apply_dense(p["o"], o.reshape(*x.shape[:-1], cfg.n_heads * hd))
    if window and k.shape[1] > window:
        k, v = k[:, -window:], v[:, -window:]   # decode cache is a window ring
    cache = KVCache(k=k, v=v, length=jnp.asarray(x.shape[1], jnp.int32))
    return out, cache
