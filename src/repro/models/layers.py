"""Common layers: norms, embeddings, rotary, MLPs — pure JAX + SP specs.

Sharding convention (DESIGN.md §6): TP over the mesh axis ``"model"``,
FSDP-style weight sharding over ``"data"``. Activations carry batch on
``("pod", "data")``; TP einsums contract over locally-sharded dims and XLA
SPMD inserts the reduce-scatter/all-gather schedule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name
from jax.sharding import PartitionSpec as P

from .param import SP, make_dense, apply_dense, normal
from .sharding import DP, constrain, row_parallel_dense

# canonical specs
W_IN = P(("pod", "data"), "model")   # (d_model, ff/heads) — column parallel
W_OUT = P("model", ("pod", "data"))  # (ff/heads, d_model) — row parallel
W_REP = P(None, None)
VOCAB_EMB = P("model", ("pod", "data"))  # (vocab, d_model)


def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": SP(jnp.ones((d,), dtype), P(None))}


def rmsnorm(p, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype) -> dict:
    return {"scale": SP(jnp.ones((d,), dtype), P(None)),
            "bias": SP(jnp.zeros((d,), dtype), P(None))}


def layernorm(p, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


def init_embedding(key, vocab: int, d: int, dtype) -> dict:
    return {"table": SP(normal(key, (vocab, d), dtype, d ** -0.5), VOCAB_EMB)}


def embed(p, tokens):
    """Token embedding. The vocab-sharded gather yields a *partial* result
    (each shard contributes rows it owns); without the constraint XLA defers
    the combining all-reduce into the first consumer — which may sit inside
    the layer-scan loop and repeat per layer (xlstm prefill: 4x 7.5 GB AR per
    unit; §Perf iter 3b). Pin the output: one AR here, DP-sharded batch."""
    out = jnp.take(p["table"], tokens, axis=0)
    axes = [DP] + [None] * (out.ndim - 1)
    return constrain(out, *axes)


def unembed(p, x):
    """Tied output projection -> logits sharded on vocab (model axis)."""
    logits = jnp.einsum("...d,vd->...v", x, p["table"])
    axes = [DP] + [None] * (logits.ndim - 2) + ["model"]
    return constrain(logits, *axes)


def init_swiglu(key, d: int, ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": make_dense(k1, d, ff, W_IN, dtype),
        "up": make_dense(k2, d, ff, W_IN, dtype),
        "down": make_dense(k3, ff, d, W_OUT, dtype, scale=ff ** -0.5),
    }


def swiglu(p, x):
    g = apply_dense(p["gate"], x)
    u = apply_dense(p["up"], x)
    out = apply_dense(p["down"], jax.nn.silu(g) * u)
    return checkpoint_name(out, "tp_mlp_out")


def init_gelu_mlp(key, d: int, ff: int, dtype, bias: bool = True) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "up": make_dense(k1, d, ff, W_IN, dtype, bias=bias),
        "down": make_dense(k2, ff, d, W_OUT, dtype, scale=ff ** -0.5, bias=bias,
                           bias_spec=P(None)),
    }


def gelu_mlp(p, x):
    return apply_dense(p["down"], jax.nn.gelu(apply_dense(p["up"], x)))


def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                     # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def init_learned_pos(key, max_len: int, d: int, dtype) -> dict:
    return {"pos": SP(normal(key, (max_len, d), dtype, d ** -0.5), P(None, None))}
