import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract roofline inputs from the compiled artifact.

The two lines above MUST stay the first statements in this file — jax locks
the device count at first backend init (this is why smoke tests and benches
import repro.* normally and see 1 device, while only this entry point sees
512 placeholder devices).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-medium-14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]

Outputs one JSON per cell under experiments/dryrun/ consumed by
benchmarks/roofline.py and EXPERIMENTS.md.
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import models
from ..configs import ARCHS, get_arch, SHAPES_BY_NAME, SHAPES, cell_is_runnable
from ..train.step import TrainConfig, make_train_step, abstract_train_state, train_state_specs
from .mesh import make_production_mesh, batch_spec, data_axes

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — never allocated)
# ---------------------------------------------------------------------------

def dp_for(shape, mesh):
    """DP axes for the batch dim, or None when the batch doesn't divide the
    DP extent (long_500k has global_batch=1 — batch stays unsharded and
    parallelism comes from model/sequence sharding)."""
    dp = data_axes(mesh)
    extent = 1
    for a in dp:
        extent *= mesh.shape[a]
    return dp if shape.global_batch % extent == 0 else None


def input_specs(cfg, shape, mesh):
    """Model inputs for one cell as ShapeDtypeStructs + their PartitionSpecs."""
    dp = dp_for(shape, mesh)
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if shape.kind == "train":
        batch = {"tokens": tok, "labels": tok}
        specs = {"tokens": P(dp), "labels": P(dp)}
    elif shape.kind == "prefill":
        batch = {"tokens": tok}
        specs = {"tokens": P(dp)}
    else:  # decode: one new token, cache of length s
        batch = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        specs = {"tokens": P(dp)}
    if cfg.family == "audio":
        batch["audio_embed"] = jax.ShapeDtypeStruct(
            (b, cfg.n_audio_frames, cfg.d_model), jnp.float32)
        specs["audio_embed"] = P(dp)
    if cfg.family == "vlm" and shape.kind != "decode":
        batch["patch_embed"] = jax.ShapeDtypeStruct(
            (b, cfg.n_patches, cfg.d_model), jnp.float32)
        specs["patch_embed"] = P(dp)
    return batch, specs


def _shardings(mesh, spec_tree):
    from .mesh import filter_spec
    return jax.tree.map(lambda sp: NamedSharding(mesh, filter_spec(sp, mesh)),
                        spec_tree, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# collective-byte extraction from HLO
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)\[([0-9,]*)\]")
_COLL_RE = re.compile(r"=\s*(?:\([^)]*\)|\S+)\s*(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(?:-start)?\(")

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shapes_bytes(segment: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(segment):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op, by kind. HLO lines look like
    ``%x = bf16[16,128]{...} all-reduce(...)`` — we take the result shape(s)
    on the lhs of the op name as the wire-bytes proxy per device."""
    out = {k: 0 for k in _COLL_KINDS}
    counts = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for kind in _COLL_KINDS:
            # result-def lines: "<name> = <shape(s)> <kind>(" or fusion-wrapped
            idx = stripped.find(f" {kind}(")
            if idx < 0:
                idx = stripped.find(f" {kind}-start(")
            if idx < 0:
                continue
            eq = stripped.find("=")
            if eq < 0 or eq > idx:
                continue
            seg = stripped[eq + 1: idx]
            out[kind] += _shapes_bytes(seg)
            counts[kind] += 1
            break
    out["total"] = sum(out[k] for k in _COLL_KINDS)
    out["counts"] = counts
    return out


# ---------------------------------------------------------------------------
# lowering one cell
# ---------------------------------------------------------------------------

def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               tcfg: TrainConfig | None = None, extra_tag: str = ""):
    cfg = get_arch(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    # 4 microbatches: keeps one microbatch's remat residuals live at a time
    # (peak activation memory / 4) and lets XLA overlap the grad reduce-
    # scatter of microbatch i with compute of i+1.
    tcfg = tcfg or TrainConfig(n_microbatches=4)
    dp = dp_for(shape, mesh)

    batch, bspecs = input_specs(cfg, shape, mesh)
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            state = abstract_train_state(cfg, tcfg)
            sspecs = train_state_specs(cfg, tcfg)
            step = make_train_step(cfg, tcfg)
            fn = jax.jit(step,
                         in_shardings=(_shardings(mesh, sspecs),
                                       _shardings(mesh, bspecs)),
                         out_shardings=(_shardings(mesh, sspecs), None),
                         donate_argnums=(0,))
            lowered = fn.lower(state, batch)
        elif shape.kind == "prefill":
            pvals, pspecs = models.abstract_params(cfg)
            pf = models.prefill_step(cfg)
            fn = jax.jit(pf, in_shardings=(_shardings(mesh, pspecs),
                                           _shardings(mesh, bspecs)),
                         out_shardings=(NamedSharding(mesh, P(dp, "model")),
                                        cspecs_sh(mesh, cfg, dp)))
            lowered = fn.lower(pvals, batch)
        else:  # decode
            pvals, pspecs = models.abstract_params(cfg)
            caches = jax.eval_shape(
                lambda: models.init_caches(cfg, shape.global_batch, shape.seq_len))
            dstep = models.decode_step(cfg)
            args = [pvals, caches, batch["tokens"]]
            csh = cspecs_sh(mesh, cfg, dp)
            in_sh = [_shardings(mesh, pspecs), csh, NamedSharding(mesh, P(dp))]
            if cfg.enc_dec:
                enc_kv = jax.eval_shape(
                    lambda: _abstract_enc_kv(cfg, shape.global_batch))
                kv_spec = jax.tree.map(
                    lambda s: NamedSharding(mesh, P(None, dp, None, "model", None)),
                    enc_kv)
                args.append(enc_kv)
                in_sh.append(kv_spec)
            fn = jax.jit(dstep, in_shardings=tuple(in_sh),
                         out_shardings=(NamedSharding(mesh, P(dp)), csh))
            lowered = fn.lower(*args)

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)
    from .hlo_analysis import analyse_hlo
    loop_aware = analyse_hlo(hlo_text)

    result = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "multi_pod": multi_pod, "mesh": list(mesh.devices.shape),
        "n_devices": mesh.devices.size,
        "tag": extra_tag,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
        "collectives": coll,
        "loop_aware": loop_aware,
    }
    return result


def cspecs_sh(mesh, cfg, dp):
    """NamedShardings for the stacked cache tree (leading unit dim)."""
    from .mesh import filter_spec
    unit_specs = models.cache_specs(cfg, dp)
    return jax.tree.map(lambda spec: NamedSharding(mesh, filter_spec(spec, mesh)),
                        unit_specs, is_leaf=lambda x: isinstance(x, P))


def _abstract_enc_kv(cfg, b):
    nu = models.n_units(cfg)
    f = cfg.n_audio_frames
    hd = cfg.hd
    sds = jax.ShapeDtypeStruct((nu, b, f, cfg.n_kv_heads, hd), jnp.dtype(cfg.dtype))
    return {str(j): (sds, sds) for j in range(len(models.unit_layout(cfg)))}


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def run_cell(arch, shape_name, multi_pod, force=False, tcfg=None, tag=""):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    mesh_tag = "pod2" if multi_pod else "pod1"
    suffix = f"_{tag}" if tag else ""
    out = RESULTS_DIR / f"{arch}__{shape_name}__{mesh_tag}{suffix}.json"
    if out.exists() and not force:
        print(f"[skip cached] {out.name}")
        return json.loads(out.read_text())
    print(f"[lowering] {arch} × {shape_name} × {mesh_tag} ...", flush=True)
    try:
        res = lower_cell(arch, shape_name, multi_pod, tcfg=tcfg, extra_tag=tag)
    except Exception as e:
        res = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    out.write_text(json.dumps(res, indent=1))
    if "error" in res:
        print(f"  ERROR: {res['error'][:300]}")
    elif "skipped" in res:
        print(f"  skipped: {res['skipped']}")
    else:
        print(f"  ok: flops={res['flops']:.3e} compile={res['compile_s']}s "
              f"coll={res['collectives']['total']:.3e}B")
    return res


VARIANTS = {
    "": None,
    "gather_once": TrainConfig(n_microbatches=4, gather_weights_once=True),
    "bf16_opt": TrainConfig(n_microbatches=4, moments_bf16=True,
                            grad_accum_bf16=True),
    "micro2": TrainConfig(n_microbatches=2),
    "micro8": TrainConfig(n_microbatches=8),
    "micro8_bf16": TrainConfig(n_microbatches=8, moments_bf16=True,
                               grad_accum_bf16=True),
    "gather_once_bf16": TrainConfig(n_microbatches=4, gather_weights_once=True,
                                    moments_bf16=True, grad_accum_bf16=True),
    "save_tp": TrainConfig(n_microbatches=4, remat_policy="save_tp"),
    "save_tp_micro8": TrainConfig(n_microbatches=8, remat_policy="save_tp"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="", choices=sorted(VARIANTS),
                    help="train-step perf variant (EXPERIMENTS.md §Perf)")
    args = ap.parse_args()

    cells = []
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    failures = 0
    for a, s, mp in cells:
        res = run_cell(a, s, mp, force=args.force,
                       tcfg=VARIANTS[args.variant], tag=args.variant)
        if "error" in res:
            failures += 1
    print(f"\n{len(cells)} cells, {failures} failures")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
