"""Loop-aware HLO analysis for the roofline.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of
trip count (verified empirically — a scan of length 1 and length 10 report
the same flops). Since every model here scans over layer units (and Mamba
scans over sequence chunks inside that), raw cost_analysis under-counts both
FLOPs and collective bytes by ~n_layers. This module parses the compiled
HLO text, builds the computation call graph, extracts while-loop trip counts
from their condition computations, and accumulates:

* dot FLOPs   = 2 * prod(result dims) * prod(lhs contracting dims), weighted
  by the product of enclosing loop trip counts;
* collective bytes (all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute), result-shape bytes weighted the same way;
* per-kind collective op counts.

Elementwise / reduce flops are ignored (≪ dot flops for these models); noted
in EXPERIMENTS.md §Roofline methodology.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
                "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(
    r"(pred|s4|u4|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128|f8e4m3fn|f8e5m2)"
    r"\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{")
_WHILE_RE = re.compile(r"while\(.*?\),\s*condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _first_shape(segment: str):
    m = _SHAPE_RE.search(segment)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


def _all_shapes_bytes(segment: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(segment):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


_PARAM_RE = re.compile(r"([\w.\-]+):\s*(\w+\[[0-9,]*\])")


@dataclass
class Computation:
    name: str
    header: str = ""
    lines: list = field(default_factory=list)
    # populated by analyse
    dot_flops: float = 0.0
    coll_bytes: dict = field(default_factory=lambda: defaultdict(float))
    coll_counts: dict = field(default_factory=lambda: defaultdict(int))
    whiles: list = field(default_factory=list)     # (cond_name, body_name)
    calls: list = field(default_factory=list)      # fusion/call targets
    max_const: int = 0


def parse_computations(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and ("->" in line) and line.strip().endswith("{"):
            cur = Computation(name=hdr.group(1), header=line)
            comps[cur.name] = cur
            if line.strip().startswith("ENTRY"):
                entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            cur.lines.append(line)
    comps["__entry__"] = comps.get(entry) or next(iter(comps.values()))
    return comps


def _analyse_comp(comp: Computation):
    """Single pass: symbol table + dots + collectives + calls."""
    symtab: dict[str, tuple] = {}
    # seed with (array-typed) computation parameters from the header
    for pm in _PARAM_RE.finditer(comp.header.split("->")[0]):
        shp = _first_shape(pm.group(2))
        if shp:
            symtab[pm.group(1)] = shp
    for line in comp.lines:
        s = line.strip()
        m = _DEF_RE.match(s)
        if m:
            name, rhs = m.group(1), m.group(2)
            shp = _first_shape(rhs.split("(")[0] if "(" in rhs else rhs)
            if shp:
                symtab[name] = shp
        for cm in _CONST_RE.finditer(s):
            comp.max_const = max(comp.max_const, int(cm.group(1)))
        wm = _WHILE_RE.search(s)
        if wm:
            comp.whiles.append((wm.group(1), wm.group(2)))
        cm2 = _CALLS_RE.search(s)
        if cm2:
            comp.calls.append(cm2.group(1))
        # dot flops
        if " dot(" in s and m:
            rhs = m.group(2)
            res = _first_shape(rhs)
            contract = _CONTRACT_RE.search(s)
            if res and contract:
                # lhs operand: first arg of dot(...). Newer HLO prints typed
                # operands ("dot(f32[64,64]{1,0} %name, ...)") — take the
                # inline shape; older HLO prints bare names — symtab lookup.
                args = s.split(" dot(", 1)[1].strip()
                mshape = _SHAPE_RE.match(args)
                if mshape:
                    dims = ([int(d) for d in mshape.group(2).split(",")]
                            if mshape.group(2) else [])
                    lhs = (mshape.group(1), dims)
                else:
                    lhs_name = args.split(",")[0].strip().lstrip("%")
                    lhs = symtab.get(lhs_name)
                cdims = [int(d) for d in contract.group(1).split(",")] if contract.group(1) else []
                k = 1
                if lhs:
                    for d in cdims:
                        if d < len(lhs[1]):
                            k *= lhs[1][d]
                n_res = 1
                for d in res[1]:
                    n_res *= d
                comp.dot_flops += 2.0 * n_res * k
        # collectives (result bytes on the lhs of the op name)
        for kind in COLLECTIVE_KINDS:
            idx = s.find(f" {kind}(")
            if idx < 0:
                idx = s.find(f" {kind}-start(")
            if idx < 0:
                continue
            eq = s.find("=")
            if eq < 0 or eq > idx:
                continue
            comp.coll_bytes[kind] += _all_shapes_bytes(s[eq + 1: idx])
            comp.coll_counts[kind] += 1
            break


def analyse_hlo(text: str) -> dict:
    comps = parse_computations(text)
    entry = comps.pop("__entry__")
    for c in comps.values():
        _analyse_comp(c)

    # accumulate multipliers over the call graph
    mult: dict[str, float] = defaultdict(float)

    def visit(name: str, m: float, depth=0):
        if depth > 64 or name not in comps:
            return
        comp = comps[name]
        mult[name] += m
        for cond, body in comp.whiles:
            trip = max(comps[cond].max_const if cond in comps else 1, 1)
            visit(body, m * trip, depth + 1)
            visit(cond, m * trip, depth + 1)
        for callee in comp.calls:
            visit(callee, m, depth + 1)

    visit(entry.name, 1.0)

    flops = 0.0
    coll_bytes = defaultdict(float)
    coll_counts = defaultdict(float)
    for name, m in mult.items():
        c = comps[name]
        flops += c.dot_flops * m
        for k, v in c.coll_bytes.items():
            coll_bytes[k] += v * m
        for k, v in c.coll_counts.items():
            coll_counts[k] += v * m

    return {
        "dot_flops": flops,
        "collective_bytes": dict(coll_bytes),
        "collective_bytes_total": float(sum(coll_bytes.values())),
        "collective_counts": {k: float(v) for k, v in coll_counts.items()},
        "n_computations": len(comps),
    }
