"""Production mesh definitions.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).

Mesh layout (DESIGN.md §6):
  single-pod:  (16, 16)        axes ("data", "model")   — 256 chips (v5e pod)
  multi-pod:   (2, 16, 16)     axes ("pod", "data", "model") — 512 chips

DP runs over ("pod", "data") (hierarchical all-reduce: reduce-scatter inside
a pod over "data", cross-pod all-reduce over "pod" — XLA's collective
scheduler emits exactly this decomposition for the nested axes), TP/EP over
"model".
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions: pass explicit Auto axis_types
    where supported (newer jax defaults shifted), plain call otherwise
    (<= 0.4.x has neither the kwarg nor ``jax.sharding.AxisType``)."""
    try:
        axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=axis_types)
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Whatever this host has — used by smoke tests and CPU examples."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return compat_make_mesh((n // model_axis, model_axis), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_spec(mesh):
    from jax.sharding import PartitionSpec as P
    return P(data_axes(mesh))


def filter_spec(spec, mesh):
    """Drop axis names a mesh doesn't have (e.g. 'pod' on single-pod) from a
    PartitionSpec, so parameter specs can always name the full DP hierarchy."""
    from jax.sharding import PartitionSpec as P
    names = set(mesh.axis_names)

    def filt(a):
        if a is None:
            return None
        if isinstance(a, (tuple, list)):
            kept = tuple(x for x in a if x in names)
            return kept if len(kept) > 1 else (kept[0] if kept else None)
        return a if a in names else None

    return P(*[filt(a) for a in spec])


def shardings_for(mesh, spec_tree):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.tree.map(lambda sp: NamedSharding(mesh, filter_spec(sp, mesh)),
                        spec_tree, is_leaf=lambda x: isinstance(x, P))
