"""Similarity-search serving: the paper's engine as a first-class service.

Two serving shapes:

* ``--engine sharded-brute|bitbound-folding|hnsw`` — frozen-database
  benchmark loops (batched KNN over a mesh-sharded or single-chip engine),
  the paper's offline-deployment measurement.
* ``--engine service`` — the online deployment: a
  :class:`repro.serve.service.SearchService` driven with a mixed
  insert+query workload (``--write-ratio``), dynamic micro-batching into
  power-of-two buckets, LSM-compacting mutable store underneath, and
  per-request latency / QPS / compaction telemetry. This is the paper's
  FPGA host loop (stream queries, append compounds without stalling the
  scan) mapped onto the TPU engines.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import CHEMBL_LIKE
from ..core import BruteForceEngine, BitBoundFoldingEngine, HNSWEngine, recall_at_k
from ..core.distributed import make_sharded_search, shard_database
from ..data.molecules import SyntheticConfig, synthetic_fingerprints, queries_from_db
from .mesh import make_local_mesh


def serve(engine: str = "sharded-brute", n_db: int = 100_000, k: int = 20,
          n_queries: int = 256, batches: int = 4, use_kernel: bool = False,
          backend: str | None = None, hnsw_layout: str = "rows",
          hnsw_shards: int | None = None, residency: str = "device",
          metric: str | None = None, fp_bits: int | None = None,
          log=print):
    """``backend`` selects the engine execution path (shared contract, see
    ``core/engine.py``): "numpy" (host reference), "tpu" (device-resident
    Pallas pipeline, interpret-mode off-TPU) or "jnp" (device path without
    Pallas). Applies to the ``bitbound-folding`` (two-stage scan) and
    ``hnsw`` (batched graph traversal) engines. ``hnsw_layout`` picks the
    traversal's fine-grained distance layout ("rows" row-gather /
    "blocked" neighbour-blocked streaming, bit-exact results);
    ``hnsw_shards`` fans the HNSW engine out over N per-device database
    shards with a rank-merged global top-k (EXPERIMENTS.md §Sharded
    HNSW). ``residency="tiered"`` keeps the full-resolution DB host-side
    and streams rescore candidates through a double-buffered HBM window
    (bitbound-folding engine; EXPERIMENTS.md §Tiered residency).
    ``metric`` / ``fp_bits`` pick the similarity and fingerprint width the
    engines are traced at (EXPERIMENTS.md §Metric sweep)."""
    from ..core.fingerprints import resolve_metric
    met = resolve_metric(metric)
    length = int(fp_bits) if fp_bits else 1024
    db = synthetic_fingerprints(SyntheticConfig(n=n_db, length=length))
    queries = queries_from_db(db, n_queries * batches)

    if engine == "sharded-brute":
        if met.name != "tanimoto":
            raise ValueError(
                "--metric is not supported by the sharded-brute mesh loop; "
                "use --engine bitbound-folding / hnsw / service")
        # only this branch needs the device mesh — the single-chip engines
        # must stay servable even where mesh construction is unsupported
        with make_local_mesh() as mesh:
            db_s, cnt_s, n_valid = shard_database(mesh, db)
            search, _, _ = make_sharded_search(mesh, db_s.shape[0], k,
                                               use_kernel=use_kernel,
                                               n_valid=n_valid)
            # warmup/compile
            q0 = jnp.asarray(queries[:n_queries])
            search(q0, db_s, cnt_s)
            t0 = time.time()
            for b in range(batches):
                q = jnp.asarray(queries[b * n_queries:(b + 1) * n_queries])
                vals, ids = search(q, db_s, cnt_s)
                jax.block_until_ready(vals)
            dt = time.time() - t0
    elif engine == "bitbound-folding":
        eng = BitBoundFoldingEngine(db, cutoff=CHEMBL_LIKE.cutoff,
                                    m=CHEMBL_LIKE.folding_m, backend=backend,
                                    residency=residency, metric=met)
        if eng.backend in ("jnp", "tpu"):
            # warm every batch once: different batches can hit different
            # (window-bucket, k) pipelines, and compiling inside the timed
            # loop would pollute the QPS measurement
            for b in range(batches):
                eng.search(queries[b * n_queries:(b + 1) * n_queries], k)
        t0 = time.time()
        for b in range(batches):
            eng.search(queries[b * n_queries:(b + 1) * n_queries], k)
        dt = time.time() - t0
    elif engine == "hnsw":
        eng = HNSWEngine(db[:min(n_db, 20_000)], m=CHEMBL_LIKE.hnsw_m,
                         ef_construction=CHEMBL_LIKE.hnsw_ef_construction,
                         ef_search=CHEMBL_LIKE.hnsw_ef_search,
                         backend=backend, layout=hnsw_layout,
                         shards=hnsw_shards, metric=met)
        eng.search(queries[:n_queries], k)  # compile
        t0 = time.time()
        for b in range(batches):
            eng.search(queries[b * n_queries:(b + 1) * n_queries], k)
        dt = time.time() - t0
        log(f"[search-serve] hnsw traversal stats: "
            f"{eng.stats.get('iters', 0)} iters, "
            f"{eng.stats.get('neighbour_evals', 0)} neighbour evals, "
            f"{eng.stats.get('max_iters_hit', 0)} budget-terminated "
            f"(last batch, {eng.stats.get('shards') or 1} shard(s))")
    else:
        raise ValueError(engine)

    qps = n_queries * batches / dt
    log(f"[search-serve] engine={engine} backend={backend or 'default'} "
        f"metric={met.spec} fp_bits={length} db={n_db} k={k}: "
        f"{qps:.0f} QPS ({dt:.2f}s for {n_queries * batches} queries)")
    return qps


def make_workload(n_ops: int, write_ratio: float,
                  pool: np.ndarray, queries: np.ndarray, insert_batch: int = 1,
                  seed: int = 2):
    """Deterministic mixed op schedule: ``("query", fp)`` / ``("insert",
    rows)`` tuples with an expected ``write_ratio`` fraction of inserts
    (cycling through the insert pool / query set)."""
    rng = np.random.default_rng(seed)
    is_write = rng.random(n_ops) < write_ratio
    ops = []
    qi = wi = 0
    for w in is_write:
        if w and len(pool):
            rows = pool[wi % len(pool):wi % len(pool) + insert_batch]
            ops.append(("insert", rows))
            wi += len(rows)
        else:
            ops.append(("query", queries[qi % len(queries)]))
            qi += 1
    return ops


def serve_frontend(engines=("brute", "bitbound-folding"), n_db: int = 20_000,
                   k: int = 10, n_ops: int = 256, write_ratio: float = 0.01,
                   backend: str | None = None, compact_threshold: int = 2048,
                   replicas: int = 2, durable_dir: str | None = None,
                   snapshot_every: int = 0, resume: bool = False,
                   metric: str | None = None, fp_bits: int | None = None,
                   metrics_out: str | None = None,
                   trace_out: str | None = None, log=print):
    """Drive the concurrent serving tier (ISSUE 9): the same mixed
    insert+query workload as :func:`serve_service`, but through a
    :class:`repro.serve.frontend.SearchFrontend` — bounded admission,
    deadlines, degradation ladder and ``replicas`` read replicas fed by
    one WAL-ordered insert fan-out. ``durable_dir`` makes the *front end*
    durable (the on-disk layout matches the single service, so either can
    recover the other's directory); ``resume`` warm-restarts every replica
    from the latest snapshot + WAL tail. Returns the frontend summary."""
    from ..obs.trace import TRACER
    from ..serve.frontend import FrontendConfig, SearchFrontend

    if trace_out:
        TRACER.clear()
        TRACER.configure(enabled=True)
    length = int(fp_bits) if fp_bits else 1024
    db = synthetic_fingerprints(SyntheticConfig(n=n_db, length=length))
    pool = synthetic_fingerprints(
        SyntheticConfig(n=max(n_ops, 64), length=length, seed=7))
    queries = queries_from_db(db, min(n_db, 512))
    fcfg = FrontendConfig(replicas=replicas, default_deadline_ms=None,
                          flush_interval_ms=1.0,
                          snapshot_every_inserts=snapshot_every)
    if resume:
        if durable_dir is None:
            raise ValueError("--resume requires --durable-dir")
        fe = SearchFrontend.open(
            durable_dir, frontend=fcfg,
            **({"backend": backend} if backend else {}),
            **({"metric": metric} if metric else {}))
        log(f"[search-serve] frontend resumed from {durable_dir}: "
            f"{fe.n_total} rows x {replicas} replicas")
        if fe.words * 32 != length:
            # the snapshot decides the width on resume — regenerate the
            # driver's insert pool + query set at the restored width
            length = fe.words * 32
            db = synthetic_fingerprints(SyntheticConfig(n=n_db,
                                                        length=length))
            pool = synthetic_fingerprints(
                SyntheticConfig(n=max(n_ops, 64), length=length, seed=7))
            queries = queries_from_db(db, min(n_db, 512))
    else:
        fe = SearchFrontend(db, engines=engines, backend=backend, k=k,
                            cutoff=CHEMBL_LIKE.cutoff,
                            fold_m=CHEMBL_LIKE.folding_m,
                            compact_threshold=compact_threshold,
                            durable_dir=durable_dir, frontend=fcfg,
                            metric=metric or "tanimoto", fp_bits=fp_bits)
    ops = make_workload(n_ops, write_ratio, pool, queries)
    enames = list(fe.engines)
    futs = []
    for i, (op, payload) in enumerate(ops):
        if op == "insert":
            fe.insert(payload)
        else:
            futs.append(fe.submit(payload, k=k,
                                  engine=enames[i % len(enames)]))
    for f in futs:
        f.result(timeout=120.0)
    fe.drain(timeout=120.0)
    s = fe.summary()
    log(f"[search-serve] frontend engines={','.join(fe.engines)} "
        f"backend={fe.config.backend or 'default'} db={n_db} k={k} "
        f"replicas={s['replicas_live']}/{s['replicas']}: "
        f"p50={s.get('p50_ms')}ms p99={s.get('p99_ms')}ms "
        f"{s['n_completed']} completed, shed={s['shed']} "
        f"expired={s['expired']} failovers={s['failovers']} "
        f"degradation<= {s['max_degradation_level']}")
    if durable_dir is not None:
        log(f"[search-serve] durable front end: WAL + snapshots under "
            f"{durable_dir} (resume with --engine service --replicas "
            f"{replicas} --resume --durable-dir {durable_dir})")
    if metrics_out:
        fe.export_metrics(metrics_out, ts=time.time())
        log(f"[search-serve] metrics -> {metrics_out} "
            f"(+ {metrics_out}.prom)")
    fe.close()
    if trace_out:
        TRACER.export_chrome(trace_out)
        log(f"[search-serve] trace -> {trace_out} "
            f"({len(TRACER.events)} events; open in "
            f"https://ui.perfetto.dev)")
        TRACER.configure(enabled=False)
    return s


def serve_service(engines=("brute", "bitbound-folding"), n_db: int = 20_000,
                  k: int = 10, n_ops: int = 256, write_ratio: float = 0.01,
                  backend: str | None = None, compact_threshold: int = 2048,
                  flush_every: int = 8, hnsw_layout: str = "rows",
                  hnsw_shards: int | None = None,
                  durable_dir: str | None = None, snapshot_every: int = 0,
                  resume: bool = False, residency: str = "device",
                  tier_chunk_rows: int | None = None,
                  tier_chunk: int | None = None,
                  metric: str | None = None, fp_bits: int | None = None,
                  metrics_out: str | None = None,
                  trace_out: str | None = None,
                  log=print):
    """Drive a :class:`SearchService` with a mixed insert+query workload and
    report the serving telemetry. Returns the service summary dict.

    ``durable_dir`` turns on the durability layer (WAL + snapshots under
    that directory; every insert is fsync'd before it is acked);
    ``snapshot_every`` writes a full-state snapshot every N inserts;
    ``resume`` warm-restarts from an existing durable directory via
    :meth:`SearchService.open` instead of building the engines from the
    synthetic database (EXPERIMENTS.md §Durability runbook).

    ``metrics_out`` exports the service metrics registry as JSONL (plus a
    Prometheus text twin at ``<path>.prom``); ``trace_out`` enables the
    process-wide span tracer and writes Chrome trace-event JSON — open it in
    Perfetto to see queue wait, batch formation, per-engine search, tiered
    double-buffer chunk streams and WAL fsyncs (EXPERIMENTS.md
    §Observability runbook). ``tier_chunk_rows`` / ``tier_chunk`` shrink the
    tiered streaming chunks to force multi-chunk captures."""
    from ..obs.trace import TRACER
    from ..serve.service import SearchService

    if trace_out:
        TRACER.clear()
        TRACER.configure(enabled=True)
    length = int(fp_bits) if fp_bits else 1024
    db = synthetic_fingerprints(SyntheticConfig(n=n_db, length=length))
    pool = synthetic_fingerprints(
        SyntheticConfig(n=max(n_ops, 64), length=length, seed=7))
    queries = queries_from_db(db, min(n_db, 512))
    if resume:
        if durable_dir is None:
            raise ValueError("--resume requires --durable-dir")
        # only patch the persisted config when a backend was requested —
        # an absent --backend must keep the backend the snapshot was
        # served with, not reset it to the default
        svc = SearchService.open(
            durable_dir, **({"backend": backend} if backend else {}),
            **({"metric": metric} if metric else {}))
        log(f"[search-serve] resumed from {durable_dir}: "
            f"{next(iter(svc.engines.values())).n_total} rows, "
            f"engines={','.join(svc.engines)}")
        if svc.words * 32 != length:
            # the snapshot decides the width on resume — regenerate the
            # driver's insert pool + query set at the restored width
            length = svc.words * 32
            db = synthetic_fingerprints(SyntheticConfig(n=n_db,
                                                        length=length))
            pool = synthetic_fingerprints(
                SyntheticConfig(n=max(n_ops, 64), length=length, seed=7))
            queries = queries_from_db(db, min(n_db, 512))
    else:
        svc = SearchService(db, engines=engines, backend=backend, k=k,
                            cutoff=CHEMBL_LIKE.cutoff,
                            fold_m=CHEMBL_LIKE.folding_m,
                            compact_threshold=compact_threshold,
                            hnsw_layout=hnsw_layout, hnsw_shards=hnsw_shards,
                            durable_dir=durable_dir, residency=residency,
                            tier_chunk_rows=tier_chunk_rows,
                            tier_chunk=tier_chunk,
                            metric=metric or "tanimoto", fp_bits=fp_bits)
    ops = make_workload(n_ops, write_ratio, pool, queries)
    enames = list(svc.engines)
    since_flush = 0
    inserts_since_snap = 0
    for i, (op, payload) in enumerate(ops):
        if op == "insert":
            svc.insert(payload)            # broadcast to every engine
            inserts_since_snap += 1
            if (durable_dir is not None and snapshot_every
                    and inserts_since_snap >= snapshot_every):
                svc.snapshot()
                inserts_since_snap = 0
        else:
            # router: spread query traffic round-robin over the engines
            svc.submit(payload, k=k, engine=enames[i % len(enames)])
            since_flush += 1
            if since_flush >= flush_every:
                svc.flush()
                since_flush = 0
    svc.flush()
    s = svc.summary()
    log(f"[search-serve] service engines={','.join(svc.engines)} "
        f"backend={svc.config.backend or 'default'} db={n_db} k={k} "
        f"write_ratio={write_ratio}: p50={s.get('p50_ms', 0)}ms "
        f"p99={s.get('p99_ms', 0)}ms {s['qps']} QPS, "
        f"{s['n_inserts']} inserts, {s['compactions']} compactions, "
        f"buckets={s['batch_buckets']}")
    if durable_dir is not None:
        log(f"[search-serve] durable: WAL + snapshots under {durable_dir} "
            f"(resume with --engine service --resume --durable-dir "
            f"{durable_dir})")
    svc.close()
    if metrics_out:
        svc.metrics.export_jsonl(metrics_out, ts=time.time())
        with open(str(metrics_out) + ".prom", "w") as f:
            f.write(svc.metrics.render_prometheus())
        log(f"[search-serve] metrics -> {metrics_out} "
            f"(+ {metrics_out}.prom)")
    if trace_out:
        TRACER.export_chrome(trace_out)
        log(f"[search-serve] trace -> {trace_out} "
            f"({len(TRACER.events)} events, {TRACER.dropped_events} dropped;"
            f" open in https://ui.perfetto.dev)")
        TRACER.configure(enabled=False)
    return s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="sharded-brute",
                    choices=["sharded-brute", "bitbound-folding", "hnsw",
                             "service"])
    ap.add_argument("--n-db", type=int, default=100_000)
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--n-queries", type=int, default=256)
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--backend", default=None,
                    choices=["numpy", "jnp", "tpu"],
                    help="engine execution path for bitbound-folding "
                         "(default numpy), hnsw (default jnp) and service")
    ap.add_argument("--hnsw-layout", default="rows",
                    choices=["rows", "blocked"],
                    help="HNSW fine-grained distance layout: per-row gather "
                         "or neighbour-blocked streaming (bit-exact results)")
    ap.add_argument("--shards", type=int, default=None,
                    help="fan the HNSW engine out over N per-device database "
                         "shards (rank-merged global top-k; 1 = bit-identical "
                         "to unsharded)")
    ap.add_argument("--ops", type=int, default=256,
                    help="service mode: number of workload operations")
    ap.add_argument("--write-ratio", type=float, default=0.01,
                    help="service mode: fraction of ops that are inserts")
    ap.add_argument("--compact-threshold", type=int, default=2048,
                    help="service mode: delta rows triggering compaction")
    ap.add_argument("--service-engines", default="brute,bitbound-folding",
                    help="service mode: comma-separated engine list")
    ap.add_argument("--durable-dir", default=None,
                    help="service mode: directory for the WAL + snapshots "
                         "(inserts are fsync'd before they are acked)")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="service mode: full-state snapshot every N inserts "
                         "(0 = only the initial one; requires --durable-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="service mode: warm-restart from --durable-dir "
                         "(latest intact snapshot + WAL replay) instead of "
                         "building fresh engines")
    ap.add_argument("--residency", default="device",
                    choices=["device", "tiered"],
                    help="full-resolution DB placement for the exhaustive "
                         "engines: HBM-resident, or host-resident with "
                         "double-buffered streaming rescore (breaks the "
                         "single-device HBM capacity ceiling)")
    ap.add_argument("--tier-chunk-rows", type=int, default=None,
                    help="service mode, tiered residency: rows per streamed "
                         "chunk for the brute engine (smaller forces more "
                         "chunks through the double buffer)")
    ap.add_argument("--tier-chunk", type=int, default=None,
                    help="service mode, tiered residency: candidate columns "
                         "per streamed rescore chunk (bitbound engine)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="service mode: serve through the concurrent front "
                         "end (SearchFrontend) with N read replicas instead "
                         "of the bare synchronous service")
    ap.add_argument("--metric", default=None,
                    help="similarity metric: tanimoto (default), dice, "
                         "cosine, or tversky(a,b) — engines score, prune "
                         "and build graphs under it; on --resume it must "
                         "match the snapshot's metric")
    ap.add_argument("--fp-bits", type=int, default=None,
                    help="fingerprint width in bits (multiple of 32; "
                         "default 1024) for the synthetic DB and engines")
    ap.add_argument("--metrics-out", default=None,
                    help="service mode: export the metrics registry as JSONL "
                         "here (a Prometheus text twin lands at <path>.prom)")
    ap.add_argument("--trace-out", default=None,
                    help="service mode: enable span tracing and write Chrome "
                         "trace-event JSON here (view in Perfetto)")
    args = ap.parse_args()
    if args.engine == "service" and args.replicas > 1:
        serve_frontend(engines=tuple(args.service_engines.split(",")),
                       n_db=args.n_db, k=args.k, n_ops=args.ops,
                       write_ratio=args.write_ratio, backend=args.backend,
                       compact_threshold=args.compact_threshold,
                       replicas=args.replicas,
                       durable_dir=args.durable_dir,
                       snapshot_every=args.snapshot_every,
                       resume=args.resume,
                       metric=args.metric, fp_bits=args.fp_bits,
                       metrics_out=args.metrics_out,
                       trace_out=args.trace_out)
    elif args.engine == "service":
        serve_service(engines=tuple(args.service_engines.split(",")),
                      n_db=args.n_db, k=args.k, n_ops=args.ops,
                      write_ratio=args.write_ratio, backend=args.backend,
                      compact_threshold=args.compact_threshold,
                      hnsw_layout=args.hnsw_layout, hnsw_shards=args.shards,
                      durable_dir=args.durable_dir,
                      snapshot_every=args.snapshot_every,
                      resume=args.resume, residency=args.residency,
                      tier_chunk_rows=args.tier_chunk_rows,
                      tier_chunk=args.tier_chunk,
                      metric=args.metric, fp_bits=args.fp_bits,
                      metrics_out=args.metrics_out,
                      trace_out=args.trace_out)
    else:
        serve(args.engine, n_db=args.n_db, k=args.k,
              n_queries=args.n_queries, use_kernel=args.use_kernel,
              backend=args.backend, hnsw_layout=args.hnsw_layout,
              hnsw_shards=args.shards, residency=args.residency,
              metric=args.metric, fp_bits=args.fp_bits)


if __name__ == "__main__":
    main()
