"""Similarity-search serving: the paper's engine as a first-class service.

Serves batched Tanimoto KNN queries over a mesh-sharded fingerprint DB —
the paper's multi-engine FPGA deployment mapped onto a TPU pod
(core/distributed.py). Request batching, engine selection and throughput
accounting mirror launch/serve.py for tokens.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import CHEMBL_LIKE
from ..core import BruteForceEngine, BitBoundFoldingEngine, HNSWEngine, recall_at_k
from ..core.distributed import make_sharded_search, shard_database
from ..data.molecules import SyntheticConfig, synthetic_fingerprints, queries_from_db
from .mesh import make_local_mesh


def serve(engine: str = "sharded-brute", n_db: int = 100_000, k: int = 20,
          n_queries: int = 256, batches: int = 4, use_kernel: bool = False,
          backend: str | None = None, log=print):
    """``backend`` selects the engine execution path (shared contract, see
    ``core/engine.py``): "numpy" (host reference), "tpu" (device-resident
    Pallas pipeline, interpret-mode off-TPU) or "jnp" (device path without
    Pallas). Applies to the ``bitbound-folding`` (two-stage scan) and
    ``hnsw`` (batched graph traversal) engines."""
    db = synthetic_fingerprints(SyntheticConfig(n=n_db))
    queries = queries_from_db(db, n_queries * batches)

    if engine == "sharded-brute":
        # only this branch needs the device mesh — the single-chip engines
        # must stay servable even where mesh construction is unsupported
        with make_local_mesh() as mesh:
            db_s, cnt_s, n_valid = shard_database(mesh, db)
            search, _, _ = make_sharded_search(mesh, db_s.shape[0], k,
                                               use_kernel=use_kernel)
            # warmup/compile
            q0 = jnp.asarray(queries[:n_queries])
            search(q0, db_s, cnt_s)
            t0 = time.time()
            for b in range(batches):
                q = jnp.asarray(queries[b * n_queries:(b + 1) * n_queries])
                vals, ids = search(q, db_s, cnt_s)
                jax.block_until_ready(vals)
            dt = time.time() - t0
    elif engine == "bitbound-folding":
        eng = BitBoundFoldingEngine(db, cutoff=CHEMBL_LIKE.cutoff,
                                    m=CHEMBL_LIKE.folding_m, backend=backend)
        if eng.backend in ("jnp", "tpu"):
            # warm every batch once: different batches can hit different
            # (window-bucket, k) pipelines, and compiling inside the timed
            # loop would pollute the QPS measurement
            for b in range(batches):
                eng.search(queries[b * n_queries:(b + 1) * n_queries], k)
        t0 = time.time()
        for b in range(batches):
            eng.search(queries[b * n_queries:(b + 1) * n_queries], k)
        dt = time.time() - t0
    elif engine == "hnsw":
        eng = HNSWEngine(db[:min(n_db, 20_000)], m=CHEMBL_LIKE.hnsw_m,
                         ef_construction=CHEMBL_LIKE.hnsw_ef_construction,
                         ef_search=CHEMBL_LIKE.hnsw_ef_search,
                         backend=backend)
        eng.search(queries[:n_queries], k)  # compile
        t0 = time.time()
        for b in range(batches):
            eng.search(queries[b * n_queries:(b + 1) * n_queries], k)
        dt = time.time() - t0
        log(f"[search-serve] hnsw traversal stats: "
            f"{eng.stats.get('iters', 0)} iters, "
            f"{eng.stats.get('neighbour_evals', 0)} neighbour evals, "
            f"{eng.stats.get('max_iters_hit', 0)} budget-terminated "
            f"(last batch)")
    else:
        raise ValueError(engine)

    qps = n_queries * batches / dt
    log(f"[search-serve] engine={engine} backend={backend or 'default'} "
        f"db={n_db} k={k}: "
        f"{qps:.0f} QPS ({dt:.2f}s for {n_queries * batches} queries)")
    return qps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="sharded-brute",
                    choices=["sharded-brute", "bitbound-folding", "hnsw"])
    ap.add_argument("--n-db", type=int, default=100_000)
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--n-queries", type=int, default=256)
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--backend", default=None,
                    choices=["numpy", "jnp", "tpu"],
                    help="engine execution path for bitbound-folding "
                         "(default numpy) and hnsw (default jnp)")
    args = ap.parse_args()
    serve(args.engine, n_db=args.n_db, k=args.k, n_queries=args.n_queries,
          use_kernel=args.use_kernel, backend=args.backend)


if __name__ == "__main__":
    main()
