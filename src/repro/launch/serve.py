"""Token-serving driver: batched prefill + decode with per-layer caches."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import models
from ..configs import get_arch
from .mesh import make_local_mesh


def generate(arch: str, prompt_len: int = 16, gen_len: int = 16,
             batch: int = 4, reduced: bool = True, seed: int = 0, log=print):
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = make_local_mesh()
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)

    params, _ = models.split(models.init_params(cfg, jax.random.key(0)))
    decode = jax.jit(models.decode_step(cfg))
    cache_len = prompt_len + gen_len

    with mesh:
        enc_kv = None
        extra = {}
        if cfg.family == "audio":
            extra["audio_embed"] = jnp.zeros(
                (batch, cfg.n_audio_frames, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.family == "vlm":
            extra["patch_embed"] = jnp.zeros(
                (batch, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.enc_dec:
            from ..models.transformer import _encode, build_enc_kv
            enc_out = _encode(params, cfg, extra["audio_embed"])
            enc_kv = build_enc_kv(cfg, params, enc_out)

        # prefill by teacher-forced decode (exact for every family, incl.
        # recurrent states, at 1 token/step — the batched prefill_step path
        # is the attention-family fast path used by the dry-run)
        caches = models.init_caches(cfg, batch, cache_len)
        t0 = time.time()
        for t in range(prompt_len):
            logits, caches = decode(params, caches, tokens[:, t:t + 1], enc_kv) \
                if enc_kv is not None else decode(params, caches, tokens[:, t:t + 1])
        out = []
        cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for t in range(gen_len):
            out.append(cur)
            logits, caches = decode(params, caches, cur, enc_kv) \
                if enc_kv is not None else decode(params, caches, cur)
            cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        dt = time.time() - t0
        gen = jnp.concatenate(out, axis=1)
        total_tok = batch * (prompt_len + gen_len)
        log(f"[serve] {arch}: {total_tok} tokens in {dt:.2f}s "
            f"({total_tok / dt:.1f} tok/s incl. jit)")
    return np.asarray(gen)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()
    gen = generate(args.arch, args.prompt_len, args.gen_len, args.batch)
    print("generated token ids (first row):", gen[0].tolist())


if __name__ == "__main__":
    main()
