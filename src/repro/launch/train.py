"""Training driver with fault-tolerance supervisor (DESIGN.md §6).

Runs on whatever mesh the host offers (CPU smoke: 1 device; production:
pass --production for the 16x16 pod). Features exercised by tests/examples:

* checkpoint/restart: periodic async checkpoints; on any step failure the
  supervisor restores the last checkpoint and replays (deterministic data =>
  exact recovery). ``--fail-at`` injects a fault to prove it.
* straggler mitigation / elasticity: data is a pure function of (seed, step)
  so a restarted/rescaled job skips ahead with no coordination; restore
  reshards onto the current mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import models
from ..checkpoint import CheckpointManager
from ..configs import get_arch
from ..data.tokens import DataConfig, batch_for_step
from ..train.step import (TrainConfig, init_train_state, make_train_step,
                          train_state_specs)
from .mesh import make_local_mesh, make_production_mesh, batch_spec


class FaultInjector:
    def __init__(self, fail_at: int | None):
        self.fail_at = fail_at
        self.fired = False

    def maybe_fail(self, step: int):
        if self.fail_at is not None and step == self.fail_at and not self.fired:
            self.fired = True
            raise RuntimeError(f"injected node failure at step {step}")


def train(arch: str, steps: int = 20, global_batch: int = 8, seq_len: int = 128,
          ckpt_dir: str = "/tmp/repro_ckpt", ckpt_every: int = 5,
          fail_at: int | None = None, production: bool = False,
          n_microbatches: int = 1, grad_compression: bool = False,
          reduced: bool = True, log=print):
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = make_production_mesh() if production else make_local_mesh()
    tcfg = TrainConfig(n_microbatches=n_microbatches,
                       grad_compression=grad_compression)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=seq_len, global_batch=global_batch)

    step_fn = make_train_step(cfg, tcfg)
    sspecs = train_state_specs(cfg, tcfg)
    from .mesh import filter_spec
    state_sh = jax.tree.map(lambda sp: NamedSharding(mesh, filter_spec(sp, mesh)),
                            sspecs, is_leaf=lambda x: isinstance(x, P))
    bspec = NamedSharding(mesh, batch_spec(mesh))

    with mesh:
        jit_step = jax.jit(step_fn, in_shardings=(state_sh, bspec),
                           out_shardings=(state_sh, None), donate_argnums=(0,))

        mgr = CheckpointManager(ckpt_dir)
        state = init_train_state(cfg, tcfg, jax.random.key(0))
        state = jax.device_put(state, state_sh)
        start = 0
        restored = mgr.restore_latest(state, state_sh)
        if restored[0] is not None:
            start, state = restored[0] + 1, restored[1]
            log(f"[restore] resuming from step {restored[0]}")

        injector = FaultInjector(fail_at)
        losses = []
        step = start
        while step < steps:
            try:
                injector.maybe_fail(step)
                batch = {k: jax.device_put(jnp.asarray(v), bspec)
                         for k, v in batch_for_step(dcfg, step, cfg).items()}
                t0 = time.time()
                state, metrics = jit_step(state, batch)
                loss = float(metrics["loss"])
                losses.append(loss)
                log(f"step {step:4d} loss {loss:.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} "
                    f"({(time.time() - t0) * 1e3:.0f} ms)")
                if ckpt_every and step % ckpt_every == 0:
                    mgr.save(step, state)
                step += 1
            except RuntimeError as e:
                log(f"[fault] {e} — restoring last checkpoint")
                mgr.wait()
                restored = mgr.restore_latest(state, state_sh)
                if restored[0] is None:
                    log("[fault] no checkpoint; restarting from scratch")
                    state = jax.device_put(
                        init_train_state(cfg, tcfg, jax.random.key(0)), state_sh)
                    step = 0
                else:
                    step = restored[0] + 1
                    state = restored[1]
        mgr.wait()
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full architecture (production only)")
    ap.add_argument("--production", action="store_true")
    args = ap.parse_args()
    train(args.arch, steps=args.steps, global_batch=args.global_batch,
          seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
          ckpt_every=args.ckpt_every, fail_at=args.fail_at,
          production=args.production, n_microbatches=args.microbatches,
          grad_compression=args.grad_compression, reduced=not args.full_config)


if __name__ == "__main__":
    main()
