"""HNSW over Tanimoto similarity — paper §III-C / §IV-B.

* Graph **construction** is host-side numpy (as in the paper: hnswlib builds
  on CPU; the FPGA/TPU accelerates *search*). Heuristic neighbour selection
  (Malkov & Yashunin Alg. 4) with the long-range-link property the paper
  credits for HNSW's recall.
* Graph **search** is the accelerated path: a batched, device-resident
  traversal engine mirroring the paper's FPGA graph engine —
  SEARCH-LAYER-TOP greedy descent (Alg. 1) followed by a lock-step batched
  SEARCH-LAYER-BASE beam search (Alg. 2):

  - two fixed-shape **register-array priority queues** per query (candidates
    C, results M) from ``core/topk.py`` — compare-and-shift / rank-merge
    semantics, the paper's Fig. 9 structure;
  - a **fine-grained gather-distance stage** scoring one whole beam
    expansion (``beam * 2M`` neighbour ids per query) per launch, on one of
    two memory layouts (bit-exact results): ``rows`` — per-neighbour row
    gather (Pallas scalar-prefetch kernel ``kernels.ops.gather_tanimoto`` or
    its jnp twin :func:`score_ids_jnp`) — or ``blocked`` — a neighbour-packed
    copy of the base layer (``nbr_fps (N, 2M, W)``) streamed one contiguous
    block per popped node through the fused gather/score/sort kernel
    ``kernels.ops.expand_tanimoto_sorted`` (jnp twin
    :func:`expand_scores_jnp`), ``beam`` DMA streams per query-iteration
    instead of ``beam*2M`` scattered row fetches;
  - per-query **termination** (Alg. 2 bound) with a global ``max_iters``
    budget; per-query telemetry (iterations, expansions, stop reason) comes
    back as :class:`TraversalStats`.

Distances: we work directly in *similarity* space (maximise Tanimoto), so the
candidate queue pops the most-similar element and the result queue evicts the
least-similar — sign-flipped but otherwise identical to Alg. 1/2.

Scaling past one device: the §"sharded fan-out" helpers below partition the
database round-robin into independent per-shard graphs, fan queries out to
one traversal per shard device and rank-merge the per-shard runs
(``core/distributed.merge_shard_topk``). Schemas and control flow for all of
this are documented in docs/ARCHITECTURE.md.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .fingerprints import (Metric, TANIMOTO, metric_from_counts,
                           metric_from_counts_np)
from .topk import NEG_INF, PQ, merge_sorted, pq_pop_many, pq_worst


# ---------------------------------------------------------------------------
# host-side helpers (numpy popcount similarity)
# ---------------------------------------------------------------------------

def _np_popcount(words: np.ndarray) -> np.ndarray:
    return np.bitwise_count(words).sum(axis=-1).astype(np.int32)


def _np_tanimoto(q: np.ndarray, db: np.ndarray, db_cnt: np.ndarray) -> np.ndarray:
    inter = np.bitwise_count(q[None, :] & db).sum(axis=-1).astype(np.int32)
    union = _np_popcount(q[None, :]) + db_cnt - inter
    return np.where(union > 0, inter / np.maximum(union, 1), 0.0).astype(np.float32)


def _np_metric(metric: Metric, q: np.ndarray, db: np.ndarray,
               db_cnt: np.ndarray) -> np.ndarray:
    """Metric-generic host scorer; the Tanimoto branch is the historical
    f64-divide path verbatim (the graph-determinism anchor)."""
    if metric.name == "tanimoto":
        return _np_tanimoto(q, db, db_cnt)
    inter = np.bitwise_count(q[None, :] & db).sum(axis=-1).astype(np.int64)
    return metric_from_counts_np(metric, inter,
                                 _np_popcount(q[None, :]).astype(np.int64),
                                 db_cnt.astype(np.int64))


# ---------------------------------------------------------------------------
# index structure
# ---------------------------------------------------------------------------

@dataclass
class HNSWIndex:
    db: np.ndarray                 # (N, W) uint32 packed fingerprints
    db_popcount: np.ndarray        # (N,) int32
    m: int                         # max degree upper layers; base layer 2M
    ef_construction: int
    entry_point: int
    max_level: int
    base_adj: np.ndarray           # (N, 2M) int32, -1 padded
    # upper layers: per level 1..max_level
    level_nodes: list = field(default_factory=list)   # [int32 array of global ids]
    level_adj: list = field(default_factory=list)     # [(n_l, M) int32 global ids]
    level_of: np.ndarray | None = None                # (N,) int8 max level per node
    seed: int = 0                  # level-draw stream; insert_hnsw continues it
    max_level_cap: int = 4
    # similarity the graph was built under; searches must use the same one
    metric: Metric = TANIMOTO
    # construction-time upper layers (level -> {gid: int32 neighbour array});
    # kept so insert_hnsw can continue building without re-deriving state
    upper_dicts: list | None = field(default=None, repr=False)
    # persisted level-draw Generator: continuing it yields exactly the values
    # a from-scratch _draw_levels(seed, n_total) stream would, without the
    # O(n_total) re-draw per insert batch (rebuilt from ``seed`` when absent)
    rng: np.random.Generator | None = field(default=None, repr=False)
    # log of nodes whose base_adj row changed (inserted nodes +
    # bidirectional-link updates); engines consume suffixes of it to update
    # device copies (incl. the neighbour-blocked layout) incrementally.
    # Bounded: when it outgrows ~2n entries it is cleared and ``dirty_epoch``
    # bumps, forcing stale consumers to full-rebuild instead of leaking host
    # memory under sustained insert streams.
    dirty_log: list | None = field(default=None, repr=False)
    dirty_epoch: int = 0
    # bumped whenever an insert batch can have touched the upper layers
    # (some inserted node drew level > 0); lets engines skip the O(cap)
    # upper-layer densify entirely on level-0-only batches (the ~(m-1)/m
    # common case)
    upper_version: int = 0
    # amortized-doubling backing arrays; db/db_popcount/base_adj/level_of are
    # views of their prefixes once insert_hnsw has run (O(1) amortized growth
    # instead of an O(n_total) concatenate per batch)
    _db_cap: np.ndarray | None = field(default=None, repr=False)
    _cnt_cap: np.ndarray | None = field(default=None, repr=False)
    _adj_cap: np.ndarray | None = field(default=None, repr=False)
    _lvl_cap: np.ndarray | None = field(default=None, repr=False)

    @property
    def n(self) -> int:
        return self.db.shape[0]


def _select_heuristic(cand_ids: np.ndarray, cand_sims: np.ndarray, m: int,
                      db: np.ndarray, db_cnt: np.ndarray,
                      metric: Metric = TANIMOTO) -> np.ndarray:
    """Alg. 4 neighbour selection: keep candidate e only if it is closer to the
    query than to every already-selected neighbour (keeps long-range links).

    The candidate-to-candidate similarity matrix is computed in one vectorised
    pass (candidate sets are small: <= ef_construction rows); the selection
    loop itself is pure index bookkeeping. This is the construction hot path —
    the per-pair scoring it replaces dominated build time.
    """
    order = np.argsort(-cand_sims, kind="stable")
    cand = np.asarray(cand_ids, dtype=np.int64)[order]
    sims = np.asarray(cand_sims, dtype=np.float32)[order]
    fps = db[cand]
    cnts = db_cnt[cand].astype(np.int64)
    inter = np.bitwise_count(fps[:, None, :] & fps[None, :, :]).sum(-1)
    if metric.name == "tanimoto":
        union = cnts[:, None] + cnts[None, :] - inter
        pair = np.where(union > 0, inter / np.maximum(union, 1),
                        0.0).astype(np.float32)
    else:
        pair = metric_from_counts_np(metric, inter.astype(np.int64),
                                     cnts[:, None], cnts[None, :])

    selected: list[int] = []
    for j in range(len(cand)):
        if len(selected) >= m:
            break
        # e closer to an existing neighbour than to q -> rejected
        if all(pair[j, s] <= sims[j] for s in selected):
            selected.append(j)
    # backfill with best remaining if heuristic selected < m (paper keeps M links)
    if len(selected) < m:
        chosen = set(selected)
        for j in range(len(cand)):
            if j not in chosen:
                selected.append(j)
                chosen.add(j)
                if len(selected) >= m:
                    break
    return cand[np.asarray(selected[:m], dtype=np.int64)].astype(np.int32)


def _search_layer_np(index_db, db_cnt, adj, q, entry_points, ef,
                     counters: dict | None = None, scorer=None,
                     metric: Metric = TANIMOTO):
    """Host-side SEARCH-LAYER-BASE used during construction and by the
    ``numpy`` engine backend. adj: dict-like callable gid -> int32 array of
    neighbour gids. ``counters`` (optional) accumulates ``evals`` (scored
    neighbours) and ``iters`` (queue pops) for the telemetry contract.
    ``scorer(q, ids) -> sims`` replaces the default numpy popcount-Tanimoto
    for the frontier batches (e.g. the device gather kernel during online
    inserts); it must be value-identical to keep graphs deterministic."""
    if scorer is None:
        def scorer(qq, ids):
            return _np_metric(metric, qq, index_db[ids], db_cnt[ids])
    visited = set(int(e) for e in entry_points)
    ep = np.asarray(list(visited), dtype=np.int32)
    sims = scorer(q, ep)
    # candidates: max-first by similarity; results: bounded by ef
    cand = list(zip((-sims).tolist(), ep.tolist()))
    import heapq
    heapq.heapify(cand)
    results = list(zip(sims.tolist(), ep.tolist()))
    heapq.heapify(results)  # min-heap over similarity = worst first
    while cand:
        neg_s, c = heapq.heappop(cand)
        if -neg_s < results[0][0] and len(results) >= ef:
            break
        if counters is not None:
            counters["iters"] = counters.get("iters", 0) + 1
        neigh = adj(c)
        neigh = [int(e) for e in neigh if e >= 0 and int(e) not in visited]
        if not neigh:
            continue
        visited.update(neigh)
        na = np.asarray(neigh, dtype=np.int32)
        ns = scorer(q, na)
        if counters is not None:
            counters["evals"] = counters.get("evals", 0) + len(neigh)
        for e, s in zip(neigh, ns.tolist()):
            if len(results) < ef or s > results[0][0]:
                heapq.heappush(cand, (-s, e))
                heapq.heappush(results, (s, e))
                if len(results) > ef:
                    heapq.heappop(results)
    rs = sorted(results, reverse=True)
    return (np.asarray([e for _, e in rs], dtype=np.int32),
            np.asarray([s for s, _ in rs], dtype=np.float32))


def _draw_levels(rng: np.random.Generator, n: int, m: int,
                 max_level_cap: int) -> np.ndarray:
    """Draw the next ``n`` node levels from the persisted rng stream.

    ``Generator.random(n)`` consumes the PCG64 stream sequentially, so
    drawing ``n_old`` values at build time and ``n_new`` more per insert
    batch yields exactly the levels one ``random(n_old + n_new)`` call of a
    from-scratch build would — the property the insert-then-rebuild parity
    contract rests on, now without the O(n_total) re-draw per batch.
    """
    ml = 1.0 / math.log(m)
    u = rng.random(n)
    return np.minimum(
        np.floor(-np.log(np.maximum(u, 1e-12)) * ml).astype(np.int32),
        max_level_cap)


def _level_rng(index: HNSWIndex) -> np.random.Generator:
    """The index's persisted level-draw Generator; indexes that predate the
    field (deserialized) rebuild it by fast-forwarding ``seed``'s stream
    past the ``n`` draws the existing nodes consumed."""
    if index.rng is None:
        index.rng = np.random.default_rng(index.seed)
        index.rng.random(index.n)
    return index.rng


def _insert_node(db, db_cnt, base_adj, upper, levels, i, m, ef_construction,
                 entry_point, ep_level, scorer=None, dirty=None,
                 metric: Metric = TANIMOTO):
    """Insert node ``i`` into the half-built graph (Alg. 1 descent + Alg. 2
    layer searches + Alg. 4 selection, with bidirectional link shrink).

    One shared implementation drives both offline :func:`build_hnsw` and
    online :func:`insert_hnsw` — graph determinism across the two paths is
    what makes online engines bit-identical to a rebuild. ``upper`` is the
    level -> {gid: neighbours} dict list; ``entry_point < 0`` means the graph
    is still empty. Returns the updated ``(entry_point, ep_level)``.

    ``dirty`` (optional list) collects every node whose *base-layer* row is
    written — the inserted node plus its bidirectionally-linked neighbours —
    so engines can refresh device adjacency copies incrementally.
    """
    m0 = base_adj.shape[1]
    l_new = int(levels[i])
    if entry_point < 0:                       # first node ever
        for l in range(1, l_new + 1):
            upper[l][i] = np.empty((0,), np.int32)
        return i, l_new

    def adj_at(level):
        if level == 0:
            return lambda gid: base_adj[gid]
        return lambda gid: upper[level].get(gid, np.empty((0,), np.int32))

    q = db[i]
    ep = np.asarray([entry_point], dtype=np.int32)
    # greedy descent through layers above l_new (Alg. 1)
    for level in range(ep_level, l_new, -1):
        ids, _ = _search_layer_np(db, db_cnt, adj_at(level), q, ep, 1,
                                  scorer=scorer, metric=metric)
        ep = ids[:1]
    # insert at layers min(ep_level, l_new) .. 0 (Alg. 2 + Alg. 4)
    for level in range(min(ep_level, l_new), -1, -1):
        ids, sims = _search_layer_np(db, db_cnt, adj_at(level), q, ep,
                                     ef_construction, scorer=scorer,
                                     metric=metric)
        mmax = m0 if level == 0 else m
        sel = _select_heuristic(ids, sims, min(m, len(ids)), db, db_cnt,
                                metric=metric)
        if level == 0:
            base_adj[i, :len(sel)] = sel
            if dirty is not None:
                dirty.append(i)
        else:
            upper[level][i] = sel.copy()
        # bidirectional links + shrink
        for e in sel:
            e = int(e)
            if level == 0:
                if dirty is not None:
                    dirty.append(e)
                row = base_adj[e]
                free = np.where(row < 0)[0]
                if len(free):
                    row[free[0]] = i
                else:
                    cand = np.concatenate([row, [i]]).astype(np.int32)
                    cs = _np_metric(metric, db[e], db[cand], db_cnt[cand])
                    base_adj[e] = _select_heuristic(cand, cs, mmax, db, db_cnt,
                                                    metric=metric)
            else:
                row = upper[level].get(e, np.empty((0,), np.int32))
                row = np.concatenate([row, [i]]).astype(np.int32)
                if len(row) > m:
                    cs = _np_metric(metric, db[e], db[row], db_cnt[row])
                    row = _select_heuristic(row, cs, m, db, db_cnt,
                                            metric=metric)
                upper[level][e] = row
        ep = ids
    if l_new > ep_level:
        entry_point, ep_level = i, l_new
        for l in range(1, l_new + 1):
            upper[l].setdefault(i, np.empty((0,), np.int32))
    return entry_point, ep_level


def _densify(upper: list, max_level: int, m: int):
    """Upper-layer dicts -> per-level (node ids, dense adjacency) arrays."""
    level_nodes, level_adj = [], []
    for l in range(1, max_level + 1):
        gids = np.asarray(sorted(upper[l].keys()), dtype=np.int32)
        adjm = np.full((len(gids), m), -1, dtype=np.int32)
        for r, g in enumerate(gids):
            row = upper[l][g][:m]
            adjm[r, :len(row)] = row
        level_nodes.append(gids)
        level_adj.append(adjm)
    return level_nodes, level_adj


def _upper_dicts_from_dense(index: HNSWIndex) -> list:
    """Rebuild the construction-time dict view from the dense per-level
    arrays (for indexes that predate ``upper_dicts``, e.g. deserialized)."""
    upper = [dict() for _ in range(index.max_level_cap + 1)]
    for l in range(1, index.max_level + 1):
        for g, row in zip(index.level_nodes[l - 1], index.level_adj[l - 1]):
            upper[l][int(g)] = row[row >= 0].astype(np.int32).copy()
    return upper


def build_hnsw(db: np.ndarray, m: int = 16, ef_construction: int = 100,
               seed: int = 0, max_level_cap: int = 4,
               metric: Metric = TANIMOTO) -> HNSWIndex:
    """Sequential insert construction (paper builds offline; search is the
    accelerated path). The per-node insertion is :func:`_insert_node` — the
    same code online :func:`insert_hnsw` runs, so incremental growth and
    from-scratch builds produce identical graphs."""
    db = np.asarray(db, dtype=np.uint32)
    n, _ = db.shape
    db_cnt = _np_popcount(db)
    rng = np.random.default_rng(seed)
    levels = _draw_levels(rng, n, m, max_level_cap)
    base_adj = np.full((n, 2 * m), -1, dtype=np.int32)
    upper = [dict() for _ in range(max_level_cap + 1)]  # gid -> int32 array

    entry_point, ep_level = -1, 0
    for i in range(n):
        entry_point, ep_level = _insert_node(
            db, db_cnt, base_adj, upper, levels, i, m, ef_construction,
            entry_point, ep_level, metric=metric)

    max_level = int(levels.max(initial=0))
    level_nodes, level_adj = _densify(upper, max_level, m)
    return HNSWIndex(db=db, db_popcount=db_cnt, m=m,
                     ef_construction=ef_construction, entry_point=entry_point,
                     max_level=max_level, base_adj=base_adj,
                     level_nodes=level_nodes, level_adj=level_adj,
                     level_of=levels.astype(np.int8), seed=seed,
                     max_level_cap=max_level_cap, metric=metric,
                     upper_dicts=upper, rng=rng)


def _ensure_capacity(index: HNSWIndex, n_total: int) -> None:
    """Amortized-doubling growth: make db / db_popcount / base_adj / level_of
    views into power-of-two backing arrays with room for ``n_total`` rows.

    One copy per doubling instead of an O(n_total) ``np.concatenate`` per
    insert batch (the ROADMAP known cost). Values are untouched, so parity
    with a from-scratch rebuild is preserved bit-for-bit. The check against
    ``.base`` re-seeds the backing arrays whenever the caller swapped the
    public arrays out from under us (fresh build, deserialized index).
    """
    cap_arr = index._db_cap
    if (cap_arr is not None and cap_arr.shape[0] >= n_total
            and index.db.base is cap_arr):
        return
    cap = 1 << max(int(n_total - 1).bit_length(), 4)
    n, w = index.db.shape
    m2 = index.base_adj.shape[1]
    index._db_cap = np.zeros((cap, w), dtype=np.uint32)
    index._db_cap[:n] = index.db
    index._cnt_cap = np.zeros((cap,), dtype=np.int32)
    index._cnt_cap[:n] = index.db_popcount
    index._adj_cap = np.full((cap, m2), -1, dtype=np.int32)
    index._adj_cap[:n] = index.base_adj
    index._lvl_cap = np.zeros((cap,), dtype=np.int8)
    index._lvl_cap[:n] = index.level_of


def insert_hnsw(index: HNSWIndex, new_fps: np.ndarray,
                scorer_factory=None) -> np.ndarray:
    """Batched incremental inserts: grow ``index`` in place by ``new_fps``.

    Levels continue the seed's rng stream (the persisted Generator,
    :func:`_draw_levels`) and every node runs the same :func:`_insert_node`
    the offline build uses, so after any number of insert batches the index
    is **identical** to ``build_hnsw(concatenated_db)`` — the engine parity
    contract. Array growth is amortized-doubling (:func:`_ensure_capacity`):
    appending a batch costs O(batch), not O(n_total).

    ``scorer_factory(db, db_cnt) -> scorer(q, ids) -> sims`` swaps the
    frontier distance stage; engines pass the Pallas ``gather_tanimoto``
    wrapper to score insert frontiers on device (first cut of the ROADMAP
    device-side-construction item — the kernel's f32 arithmetic is
    value-identical to the host scorer for <=2048-bit prints, keeping the
    graph deterministic). Base-layer rows touched by the batch are appended
    to ``index.dirty_log`` for incremental device-copy refresh (engines
    track their own consumed offset). Returns the new nodes' global ids.
    """
    new_fps = np.atleast_2d(np.asarray(new_fps, dtype=np.uint32))
    n_new = new_fps.shape[0]
    n_old = index.n
    if n_new == 0:
        return np.empty((0,), dtype=np.int64)
    n_total = n_old + n_new
    _ensure_capacity(index, n_total)
    index._db_cap[n_old:n_total] = new_fps
    index._cnt_cap[n_old:n_total] = _np_popcount(new_fps)
    index._adj_cap[n_old:n_total] = -1
    levels_new = _draw_levels(_level_rng(index), n_new, index.m,
                              index.max_level_cap)
    index._lvl_cap[n_old:n_total] = levels_new
    index.db = index._db_cap[:n_total]
    index.db_popcount = index._cnt_cap[:n_total]
    index.base_adj = index._adj_cap[:n_total]
    index.level_of = index._lvl_cap[:n_total]
    if index.upper_dicts is None:
        index.upper_dicts = _upper_dicts_from_dense(index)
    if index.dirty_log is None:
        index.dirty_log = []
    upper = index.upper_dicts
    scorer = (scorer_factory(index.db, index.db_popcount)
              if scorer_factory is not None else None)
    metric = getattr(index, "metric", TANIMOTO)
    ep, epl = int(index.entry_point), int(index.max_level)
    for i in range(n_old, n_total):
        ep, epl = _insert_node(index.db, index.db_popcount, index.base_adj,
                               upper, index.level_of, i, index.m,
                               index.ef_construction, ep, epl, scorer=scorer,
                               dirty=index.dirty_log, metric=metric)
    index.entry_point, index.max_level = int(ep), int(epl)
    index.level_nodes, index.level_adj = _densify(upper, index.max_level,
                                                  index.m)
    if bool((levels_new > 0).any()):
        # only a level>0 node can write upper-layer rows (_insert_node's
        # upper mutations all sit under ``level >= 1`` of the new node)
        index.upper_version += 1
    if len(index.dirty_log) > max(1024, 2 * n_total):
        index.dirty_log = []
        index.dirty_epoch += 1
    return np.arange(n_old, n_total, dtype=np.int64)


def auto_beam(ef_search: int) -> int:
    """Beam width from ``ef_search`` (ROADMAP telemetry note: B=4 cuts
    lock-step iterations ~3.7x at equal recall for ef=64). Scales B with ef
    so small-ef searches don't waste expansions, clamped to [1, 8]."""
    return max(1, min(8, int(ef_search) // 16))


# ---------------------------------------------------------------------------
# accelerated batched search (JAX) — the paper's graph traversal engine
# ---------------------------------------------------------------------------

class HNSWDeviceGraph(NamedTuple):
    """Device-resident, constant-shape view of the index for the JAX engine.

    ``layout="blocked"`` additionally carries the **neighbour-blocked copy of
    the base layer** (ISSUE 4): ``nbr_fps[v] = db[base_adj[v]]`` with zero
    rows for ``-1`` slots, plus the matching popcounts — one popped node's
    whole expansion is a single contiguous ``2M*W``-word HBM stream for the
    fused expand kernel, at the HBM cost of one extra ``2M*W``-word copy of
    the base layer per node.
    """
    db: jax.Array             # (N, W) uint32
    db_popcount: jax.Array    # (N,) int32
    base_adj: jax.Array       # (N, 2M) int32
    upper_adj: jax.Array      # (L, N, M) int32 — dense per-level adjacency (-1 pad)
    entry_point: jax.Array    # () int32
    max_level: int
    nbr_fps: jax.Array | None = None   # (N, 2M, W) uint32 — blocked layout only
    nbr_cnt: jax.Array | None = None   # (N, 2M) int32


LAYOUTS = ("rows", "blocked")


def _dense_upper(index: HNSWIndex, cap: int) -> np.ndarray:
    """Dense (L, cap, M) upper-layer adjacency (-1 padded)."""
    L = max(index.max_level, 0)
    upper = np.full((max(L, 1), cap, index.m), -1, dtype=np.int32)
    for l in range(1, L + 1):
        gids = index.level_nodes[l - 1]
        upper[l - 1, gids] = index.level_adj[l - 1]
    return upper


def _blocked_rows(db: np.ndarray, db_cnt: np.ndarray, adj: np.ndarray):
    """Neighbour-blocked rows for ``adj`` (any (R, 2M) id slice): gathers the
    referenced fingerprints/popcounts, zeroing ``-1`` slots."""
    safe = np.maximum(adj, 0)
    fps = db[safe].copy()                              # (R, 2M, W)
    fps[adj < 0] = 0
    cnt = db_cnt[safe].astype(np.int32)
    cnt[adj < 0] = 0
    return fps, cnt


def to_device_graph(index: HNSWIndex, capacity: int | None = None,
                    layout: str = "rows") -> HNSWDeviceGraph:
    """Densify the index for the device engine. ``capacity`` (>= n) pads the
    node dimension — pad rows are zero fingerprints with no edges, so they
    are unreachable and the traversal is unaffected. Engines pad to a power
    of two so online inserts below the capacity reuse compiled traversals.
    ``layout="blocked"`` also builds the neighbour-blocked base-layer copy
    (:class:`HNSWDeviceGraph` docstring)."""
    if layout not in LAYOUTS:
        raise ValueError(f"layout must be one of {LAYOUTS}, got {layout!r}")
    L = max(index.max_level, 0)
    n = index.n
    cap = n if capacity is None else max(int(capacity), n)
    upper = _dense_upper(index, cap)
    db = np.zeros((cap, index.db.shape[1]), dtype=np.uint32)
    db[:n] = index.db
    cnt = np.zeros((cap,), dtype=np.int32)
    cnt[:n] = index.db_popcount
    base = np.full((cap, index.base_adj.shape[1]), -1, dtype=np.int32)
    base[:n] = index.base_adj
    nbr_fps = nbr_cnt = None
    if layout == "blocked":
        nbr_fps_np, nbr_cnt_np = _blocked_rows(db, cnt, base)
        nbr_fps = jnp.asarray(nbr_fps_np)
        nbr_cnt = jnp.asarray(nbr_cnt_np)
    return HNSWDeviceGraph(
        db=jnp.asarray(db), db_popcount=jnp.asarray(cnt),
        base_adj=jnp.asarray(base), upper_adj=jnp.asarray(upper),
        entry_point=jnp.int32(index.entry_point), max_level=L,
        nbr_fps=nbr_fps, nbr_cnt=nbr_cnt)


def _sims(q: jax.Array, q_cnt: jax.Array, g: HNSWDeviceGraph, ids: jax.Array,
          metric: Metric = TANIMOTO) -> jax.Array:
    """Single-query view of :func:`score_ids_jnp` (greedy-descent stage)."""
    return score_ids_jnp(q[None], q_cnt[None], g, ids[None], metric=metric)[0]


def score_ids_jnp(queries: jax.Array, q_cnt: jax.Array, g: HNSWDeviceGraph,
                  ids: jax.Array, metric: Metric = TANIMOTO) -> jax.Array:
    """Batched gather-distance fallback: (Q, W) x (Q, E) ids -> (Q, E) sims.

    Plain-jnp twin of the Pallas ``kernels.ops.gather_tanimoto`` kernel —
    identical arithmetic (popcount similarity via ``metric_from_counts``,
    -inf for id -1), used when Pallas is unavailable or the engine backend
    is ``"jnp"``.
    """
    safe = jnp.maximum(ids, 0)
    fps = g.db[safe]                                    # (Q, E, W)
    inter = jnp.sum(jax.lax.population_count(
        queries[:, None, :] & fps).astype(jnp.int32), axis=-1)
    s = metric_from_counts(metric, inter, q_cnt[:, None], g.db_popcount[safe])
    return jnp.where(ids >= 0, s, NEG_INF)


def expand_scores_jnp(queries: jax.Array, q_cnt: jax.Array,
                      nbr_fps: jax.Array, nbr_cnt: jax.Array,
                      pop_ids: jax.Array, flat_ids: jax.Array,
                      worst: jax.Array, kk: int, metric: Metric = TANIMOTO):
    """Plain-jnp twin of the fused expand kernel (``kernels/expand.py``):
    gather ``beam`` contiguous neighbour blocks per query from the blocked
    layout, score, mask ``-1``/sub-threshold slots, return the top-``kk``
    sorted run. Identical arithmetic to the kernel and to the row path's
    gather -> score -> filter -> sort chain — the bit-exactness contract
    between ``layout="blocked"`` and ``layout="rows"``.
    """
    q_n = queries.shape[0]
    safe = jnp.maximum(pop_ids, 0)
    blk = nbr_fps[safe]                                 # (Q, B, 2M, W)
    inter = jnp.sum(jax.lax.population_count(
        queries[:, None, None, :] & blk).astype(jnp.int32), axis=-1)
    s = metric_from_counts(metric, inter, q_cnt[:, None, None], nbr_cnt[safe])
    s = s.reshape(q_n, -1)
    s = jnp.where(flat_ids >= 0, s, NEG_INF)
    s = jnp.where(s > worst[:, None], s, NEG_INF)
    ids = jnp.where(s > NEG_INF, flat_ids, -1)
    s_srt, pos = jax.lax.top_k(s, kk)
    return s_srt, jnp.take_along_axis(ids, pos, axis=1)


def _greedy_descent(q, q_cnt, g: HNSWDeviceGraph, level: int,
                    start: jax.Array, metric: Metric = TANIMOTO) -> jax.Array:
    """SEARCH-LAYER-TOP (Alg. 1) at one (static) upper level from ``start``."""
    adj = g.upper_adj[level - 1]

    def cond(state):
        cur, cur_sim, moved = state
        return moved

    def body(state):
        cur, cur_sim, _ = state
        neigh = adj[cur]                                   # (M,)
        s = _sims(q, q_cnt, g, neigh, metric=metric)
        j = jnp.argmax(s)
        better = s[j] > cur_sim
        return (jnp.where(better, neigh[j], cur),
                jnp.where(better, s[j], cur_sim), better)

    s0 = _sims(q, q_cnt, g, start[None], metric=metric)[0]
    cur, _, _ = jax.lax.while_loop(cond, body, (start, s0, jnp.bool_(True)))
    return cur


# Early-termination reasons (TraversalStats.reason values).
REASON_CONVERGED = 0   # best remaining candidate worse than worst result
REASON_MAX_ITERS = 1   # iteration budget exhausted before convergence


class TraversalStats(NamedTuple):
    """Per-query telemetry from one batched traversal."""
    iters: jax.Array        # (Q,) int32 — beam-expansion iterations executed
    expansions: jax.Array   # (Q,) int32 — candidates actually expanded
    reason: jax.Array       # (Q,) int32 — REASON_CONVERGED / REASON_MAX_ITERS


def stats_summary(iters, expansions, reason, m2: int) -> dict:
    """Fold (already stacked/summed-ready) per-query traversal telemetry
    into the scalar totals dict shared by :attr:`HNSWEngine.stats` and the
    ``hnsw.search`` trace-span args (ISSUE 8): iteration/expansion totals,
    neighbour evaluations (``expansions * 2M``) and termination-reason
    counts. Accepts device arrays or numpy; always returns plain ints."""
    iters = np.asarray(iters)
    expansions = np.asarray(expansions)
    reason = np.asarray(reason)
    return {
        "iters": int(iters.sum()),
        "expansions": int(expansions.sum()),
        "neighbour_evals": int(expansions.sum()) * int(m2),
        "converged": int((reason == REASON_CONVERGED).sum()),
        "max_iters_hit": int((reason == REASON_MAX_ITERS).sum()),
    }


def search_hnsw(g: HNSWDeviceGraph, queries: jax.Array, k: int, ef: int,
                max_iters: int | None = None, beam: int = 1, score_fn=None,
                expand_fn=None, metric: Metric = TANIMOTO):
    """Batched device-resident KNN search over the base layer.

    The whole query batch traverses in lock-step inside one
    ``lax.while_loop``: per iteration each still-active query pops its best
    ``beam`` candidates from the candidate queue C, gathers their base-layer
    adjacency (``beam * 2M`` neighbour ids), scores all of them in ONE
    gather-distance launch (``score_fn``), and rank-merges the scored batch
    into both fixed-shape register-array queues (C and the result set M,
    ``core/topk.py``). A per-query visited bitset gives exactly-once scoring;
    queries terminate individually (Alg. 2 bound: best candidate worse than
    the worst retained result) and finished queries ride along masked until
    the last one converges or ``max_iters`` is hit.

    queries: (Q, W) uint32. Returns ``(ids (Q, k), sims (Q, k), stats)``
    with ids descending by similarity (-1 pads) and :class:`TraversalStats`
    device arrays.

    ``score_fn(queries, q_cnt, ids) -> sims`` is the fine-grained distance
    stage for the entry-point scoring and the ``rows``-layout expansion;
    default is the jnp gather (:func:`score_ids_jnp`), engines pass the
    Pallas ``gather_tanimoto`` kernel for the ``tpu`` backend.

    ``expand_fn(queries, q_cnt, pop_ids, flat_ids, worst, kk) ->
    (scores (Q, kk) desc, ids (Q, kk))`` replaces the whole
    gather -> score -> evict-filter -> sort stage of one beam expansion
    (``pop_ids (Q, beam)`` are the popped node ids, ``flat_ids (Q, beam*2M)``
    their adjacency with -1 for pad/visited slots, ``worst (Q,)`` the result
    queues' eviction bounds). Engines pass the fused blocked-layout kernel
    (``kernels.ops.expand_tanimoto_sorted``) or its jnp twin
    (:func:`expand_scores_jnp`) for ``layout="blocked"``; the default is the
    row-gather chain over ``score_fn``. Either way the emitted run is sorted,
    so the queues merge it directly (one launch per iteration).
    """
    ef = max(ef, k)
    beam = max(1, min(beam, ef))
    if max_iters is None:
        max_iters = 4 * ef + 16
    if score_fn is None:
        def score_fn(qs, qc, ids):
            return score_ids_jnp(qs, qc, g, ids, metric=metric)

    q_n = queries.shape[0]
    n = g.db.shape[0]
    m2 = g.base_adj.shape[1]
    n_exp = beam * m2                                   # neighbours per launch
    kk = min(n_exp, ef)                                 # sorted-run width
    if expand_fn is None:
        def expand_fn(qs, qc, pop_ids, flat, worst, kk):
            # rows layout: scattered row gather + score, then evict-filter
            # and one sort (the run feeds BOTH queues — pq_insert_batch
            # would sort twice)
            s = score_fn(qs, qc, flat)
            keep = s > worst[:, None]                    # evict-worst filter
            s = jnp.where(keep, s, NEG_INF)
            fl = jnp.where(keep, flat, -1)
            s_srt, pos = jax.lax.top_k(s, kk)
            return s_srt, jnp.take_along_axis(fl, pos, axis=1)

    vwords = (n + 31) // 32
    q_cnt = jnp.sum(jax.lax.population_count(queries).astype(jnp.int32), -1)

    # greedy descent through the upper layers (Alg. 1), vmapped per query
    def descend(q, qc):
        ep = g.entry_point
        for level in range(g.max_level, 0, -1):          # static unroll
            ep = _greedy_descent(q, qc, g, level, ep, metric=metric)
        return ep

    ep = jax.vmap(descend)(queries, q_cnt)               # (Q,)
    ep_sim = score_fn(queries, q_cnt, ep[:, None])[:, 0]

    # C (candidates, pop-best) and M (results, evict-worst): batched
    # register-array queues (core/topk.py PQ invariants), one row per query —
    # every queue op below is the vmapped scalar PQ primitive.
    cand = PQ(jnp.full((q_n, ef), NEG_INF).at[:, 0].set(ep_sim),
              jnp.full((q_n, ef), -1, jnp.int32).at[:, 0].set(ep))
    res = cand
    rows = jnp.arange(q_n)
    visited = jnp.zeros((q_n, vwords), jnp.uint32)
    visited = visited.at[rows, ep // 32].set(
        jnp.uint32(1) << (ep % 32).astype(jnp.uint32))

    def where_rows(mask, new, old):
        """Per-query select between two batched PQ pytrees."""
        return jax.tree.map(
            lambda a, b: jnp.where(mask[:, None], a, b), new, old)

    state = (cand, res, visited,
             jnp.ones((q_n,), bool),                     # active
             jnp.zeros((q_n,), jnp.int32),               # iters
             jnp.zeros((q_n,), jnp.int32),               # expansions
             jnp.int32(0))                               # lock-step counter

    def cond(st):
        active, it = st[3], st[6]
        return jnp.logical_and(jnp.any(active), it < max_iters)

    def body(st):
        cand, res, visited, active, iters, expans, it = st
        worst = jax.vmap(pq_worst)(res)                  # eviction threshold
        # Alg. 2 termination, per query: stop when the best candidate cannot
        # beat the worst retained result (monotone -> inactive stays inactive)
        go = jnp.logical_and(active, jnp.logical_and(
            cand.scores[:, 0] > NEG_INF, cand.scores[:, 0] >= worst))

        # pop the beam: best B candidates, queue shifts up by B
        pop_s, pop_i, popped = jax.vmap(
            lambda pq: pq_pop_many(pq, beam))(cand)
        valid_pop = (pop_s > NEG_INF) & (pop_s >= worst[:, None]) & go[:, None]
        cand = where_rows(go, popped, cand)

        # beam expansion: adjacency gather, (Q, beam, 2M)
        nb = g.base_adj[jnp.maximum(pop_i, 0)]
        nb = jnp.where(valid_pop[:, :, None], nb, -1)

        # visited check + mark, one beam slot at a time (static unroll): ids
        # within a slot are unique (one node's adjacency), so the scatter-ADD
        # below equals scatter-OR; marking between slots dedups neighbours
        # shared by two popped candidates in the same iteration.
        fresh_slots = []
        for b in range(beam):
            ids_b = nb[:, b, :]
            safe = jnp.maximum(ids_b, 0)
            word = jnp.take_along_axis(visited, safe // 32, axis=1)
            bit = (word >> (safe % 32).astype(jnp.uint32)) & 1
            fresh = jnp.logical_and(ids_b >= 0, bit == 0)
            upd = jnp.where(
                fresh, jnp.uint32(1) << (safe % 32).astype(jnp.uint32),
                jnp.uint32(0))
            visited = visited.at[rows[:, None], safe // 32].add(upd)
            fresh_slots.append(fresh)
        fresh = jnp.stack(fresh_slots, axis=1).reshape(q_n, n_exp)
        flat = jnp.where(fresh, nb.reshape(q_n, n_exp), -1)

        # fused expansion stage: gather + score + evict-filter + sort for
        # all B*2M neighbours per query in one launch; the sorted run
        # rank-merges into both queues (Fig. 9)
        s_srt, i_srt = expand_fn(queries, q_cnt, pop_i, flat, worst, kk)
        vmerge = jax.vmap(
            lambda pq, ms, mi: PQ(*merge_sorted(pq.scores, pq.payload,
                                                ms, mi)))
        res = where_rows(go, vmerge(res, s_srt, i_srt), res)
        cand = where_rows(go, vmerge(cand, s_srt, i_srt), cand)

        iters = iters + go.astype(jnp.int32)
        expans = expans + jnp.sum(valid_pop, axis=1).astype(jnp.int32)
        return cand, res, visited, go, iters, expans, it + 1

    _, res, _, active, iters, expans, _ = jax.lax.while_loop(cond, body, state)
    reason = jnp.where(active, REASON_MAX_ITERS, REASON_CONVERGED)
    ids = res.payload[:, :k]
    sims = jnp.where(ids >= 0, res.scores[:, :k], 0.0)
    return ids, sims, TraversalStats(iters=iters, expansions=expans,
                                     reason=reason.astype(jnp.int32))


# ---------------------------------------------------------------------------
# sharded fan-out (ISSUE 5) — partition-then-merge across the device mesh
# ---------------------------------------------------------------------------
#
# The paper scales its HNSW engine by replicating traversal/distance engines
# and splitting the database across parallel pipelines (§IV, Fig. 8); the
# same recipe here is FPScreen-style partition-then-merge: the database rows
# are **round-robin partitioned** into S shards (global row g lives in shard
# ``g % S`` at local row ``g // S``), each shard builds its own independent
# HNSW graph, queries fan out to one lock-step traversal per shard (each
# with its own entry point, visited bitset and PQ queues, placed on its own
# device), and the per-shard result runs rank-merge into one global top-k
# (``core/topk.merge_sorted_many`` via ``core/distributed.merge_shard_topk``).
# Round-robin keeps shards balanced under online inserts and makes the
# local<->global id map a closed form — no translation table on device.


def sharded_global_ids(local_ids: np.ndarray, shard: int,
                       n_shards: int) -> np.ndarray:
    """Map one shard's local result ids to global ids (``-1`` pads kept) —
    host-side twin of :func:`globalize_shard_ids` for the numpy backend."""
    return np.where(local_ids >= 0, local_ids * n_shards + shard, -1)


@jax.jit
def globalize_shard_ids(local_ids: jax.Array) -> jax.Array:
    """(S, ..., k) stacked per-shard local ids -> global ids under the
    round-robin partition (``gid = local * S + shard``; ``-1`` pads kept).
    The single device-side implementation of the id map — the engine's
    fan-out and :func:`search_hnsw_sharded` both go through it."""
    n_shards = local_ids.shape[0]
    shard = jnp.arange(n_shards, dtype=local_ids.dtype).reshape(
        (n_shards,) + (1,) * (local_ids.ndim - 1))
    return jnp.where(local_ids >= 0, local_ids * n_shards + shard, -1)


def build_hnsw_sharded(db: np.ndarray, n_shards: int, m: int = 16,
                       ef_construction: int = 100, seed: int = 0,
                       max_level_cap: int = 4,
                       metric: Metric = TANIMOTO) -> list:
    """Build S independent per-shard indexes over the round-robin partition.

    Shard ``s`` is ``build_hnsw(db[s::S], seed=seed + s)`` — with
    ``n_shards == 1`` this is exactly the unsharded build (same rows, same
    seed), the base of the 1-shard bit-parity contract. Each shard draws its
    levels from its own seed stream so per-shard graphs stay deterministic
    under :func:`insert_hnsw_sharded` growth.
    """
    db = np.asarray(db, dtype=np.uint32)
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if db.shape[0] < n_shards:
        raise ValueError(f"cannot split {db.shape[0]} rows into "
                         f"{n_shards} shards")
    return [build_hnsw(db[s::n_shards], m=m, ef_construction=ef_construction,
                       seed=seed + s, max_level_cap=max_level_cap,
                       metric=metric)
            for s in range(n_shards)]


def insert_hnsw_sharded(indexes: list, new_fps: np.ndarray,
                        scorer_factory=None):
    """Route an insert batch to its shards (``gid % S``) in global-id order.

    New rows get the next global ids ``n_total..``; because the ids are
    contiguous, the sub-batch landing on shard ``s`` appends at exactly the
    local rows ``gid // S`` — the round-robin invariant is self-maintaining
    and an engine grown online stays graph-identical to
    :func:`build_hnsw_sharded` on the concatenated database (the per-shard
    :func:`insert_hnsw` parity contract). Returns ``(gids, touched)`` where
    ``touched`` lists the shards whose device copies need refreshing.

    ``scorer_factory`` is per-shard (called with each shard's db); callers
    that cache device state per database must key it per shard.
    """
    new_fps = np.atleast_2d(np.asarray(new_fps, dtype=np.uint32))
    n_shards = len(indexes)
    n_total = sum(ix.n for ix in indexes)
    for s, ix in enumerate(indexes):            # round-robin invariant
        expect = len(range(s, n_total, n_shards))
        if ix.n != expect:
            raise ValueError(f"shard {s} holds {ix.n} rows, round-robin of "
                             f"{n_total} total expects {expect}")
    gids = np.arange(n_total, n_total + new_fps.shape[0], dtype=np.int64)
    touched = []
    for s in range(n_shards):
        rows = new_fps[(gids % n_shards) == s]
        if rows.shape[0]:
            insert_hnsw(indexes[s], rows, scorer_factory=scorer_factory)
            touched.append(s)
    return gids, touched


def place_graph(g: HNSWDeviceGraph, device) -> HNSWDeviceGraph:
    """Commit a device graph's arrays to ``device`` (static fields kept)."""
    return HNSWDeviceGraph(**{
        f: (jax.device_put(v, device) if isinstance(v, jax.Array) else v)
        for f, v in g._asdict().items()})


def to_device_graph_sharded(indexes: list, layout: str = "rows",
                            capacities: list | None = None,
                            devices: list | None = None) -> list:
    """Per-shard device graphs for the fan-out traversal.

    Each shard's graph is an ordinary :func:`to_device_graph` (padded to its
    own power-of-two capacity unless ``capacities`` overrides it — per-shard
    ``nbr_fps`` blocks included on ``layout="blocked"``), committed to its
    own device (``devices``, default
    :func:`repro.core.distributed.shard_devices`) so the S traversals run
    in parallel across the mesh.
    """
    from .distributed import shard_devices
    from ..serve.store import next_pow2
    if devices is None:
        devices = shard_devices(len(indexes))
    if capacities is None:
        capacities = [next_pow2(ix.n) for ix in indexes]
    return [place_graph(to_device_graph(ix, capacity=cap, layout=layout), dev)
            for ix, cap, dev in zip(indexes, capacities, devices)]


def search_hnsw_sharded(graphs: list, queries, k: int, ef: int,
                        max_iters: int | None = None, beam: int = 1,
                        score_fn_for=None, expand_fn_for=None,
                        metric: Metric = TANIMOTO):
    """Fan-out KNN over per-shard device graphs + rank-merge.

    Runs one :func:`search_hnsw` lock-step traversal per shard (queries are
    committed to each shard's device, so launches overlap across the mesh
    under JAX's async dispatch), maps local ids to global ids
    (:func:`globalize_shard_ids`) and rank-merges the per-shard runs with
    ``core/distributed.merge_shard_topk``. ``score_fn_for(g)`` /
    ``expand_fn_for(g)`` build optional per-shard kernel stages; ``None``
    uses the jnp defaults. Returns ``(gids (Q, k), sims (Q, k),
    stats_list)``.

    This is the uncached module-level form of the fan-out — one eager
    traversal per call. ``HNSWEngine(shards=N)`` runs the same loop with
    per-shape jit-compiled traversals (``engine.py::_search_sharded``);
    ``tests/test_sharded_hnsw.py`` pins the two paths equal. With one
    shard the merge is the identity, so results are bit-identical to the
    unsharded traversal — ``HNSWEngine(shards=1)``'s contract.
    """
    from .distributed import merge_shard_topk
    dev0 = next(iter(graphs[0].db.devices()))
    runs_s, runs_i, stats = [], [], []
    for g in graphs:
        q = jax.device_put(jnp.asarray(queries), next(iter(g.db.devices())))
        ids, sims, st = search_hnsw(
            g, q, k, ef, max_iters=max_iters, beam=beam,
            score_fn=score_fn_for(g) if score_fn_for else None,
            expand_fn=expand_fn_for(g) if expand_fn_for else None,
            metric=metric)
        runs_s.append(jax.device_put(sims, dev0))
        runs_i.append(jax.device_put(ids, dev0))
        stats.append(st)
    gids = globalize_shard_ids(jnp.stack(runs_i))
    gids, sims = merge_shard_topk(jnp.stack(runs_s), gids, k)
    return gids, sims, stats


def search_hnsw_numpy(index: HNSWIndex, queries: np.ndarray, k: int, ef: int):
    """Host-side reference traversal (the ``numpy`` engine backend).

    True variable-length queues (heapq), one python loop per query — the
    semantics oracle for the fixed-shape device path. Returns
    ``(ids (Q, k) int64, sims (Q, k) float32, counters)`` where counters
    accumulates ``evals`` / ``iters`` over the batch.
    """
    ef = max(ef, k)
    db, db_cnt = index.db, index.db_popcount
    metric = getattr(index, "metric", TANIMOTO)

    def adj_at(level):
        if level == 0:
            return lambda gid: index.base_adj[gid]
        gids = index.level_nodes[level - 1]
        adjm = index.level_adj[level - 1]

        def f(gid):
            r = np.searchsorted(gids, gid)
            if r < len(gids) and gids[r] == gid:
                return adjm[r]
            return np.empty((0,), np.int32)
        return f

    queries = np.asarray(queries)
    ids_out = np.full((len(queries), k), -1, dtype=np.int64)
    sims_out = np.zeros((len(queries), k), dtype=np.float32)
    counters: dict = {"evals": 0, "iters": 0}
    for qi, q in enumerate(queries):
        ep = np.asarray([index.entry_point], dtype=np.int32)
        for level in range(index.max_level, 0, -1):
            ids, _ = _search_layer_np(db, db_cnt, adj_at(level), q, ep, 1,
                                      metric=metric)
            ep = ids[:1]
        ids, sims = _search_layer_np(db, db_cnt, adj_at(0), q, ep, ef,
                                     counters=counters, metric=metric)
        kk = min(k, len(ids))
        ids_out[qi, :kk] = ids[:kk]
        sims_out[qi, :kk] = sims[:kk]
    return ids_out, sims_out, counters
