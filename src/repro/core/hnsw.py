"""HNSW over Tanimoto similarity — paper §III-C / §IV-B.

* Graph **construction** is host-side numpy (as in the paper: hnswlib builds
  on CPU; the FPGA/TPU accelerates *search*). Heuristic neighbour selection
  (Malkov & Yashunin Alg. 4) with the long-range-link property the paper
  credits for HNSW's recall.
* Graph **search** is the accelerated path: a batched JAX engine mirroring the
  paper's graph-traversal engine — SEARCH-LAYER-TOP greedy descent
  (Alg. 1) and SEARCH-LAYER-BASE beam search (Alg. 2) with two fixed-shape
  register-array priority queues (candidates C, results M) and a vectorised
  TFC distance stage over the (2M-padded) adjacency gather.

Distances: we work directly in *similarity* space (maximise Tanimoto), so the
candidate queue pops the most-similar element and the result queue evicts the
least-similar — sign-flipped but otherwise identical to Alg. 1/2.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .topk import NEG_INF


# ---------------------------------------------------------------------------
# host-side helpers (numpy popcount Tanimoto)
# ---------------------------------------------------------------------------

def _np_popcount(words: np.ndarray) -> np.ndarray:
    return np.bitwise_count(words).sum(axis=-1).astype(np.int32)


def _np_tanimoto(q: np.ndarray, db: np.ndarray, db_cnt: np.ndarray) -> np.ndarray:
    inter = np.bitwise_count(q[None, :] & db).sum(axis=-1).astype(np.int32)
    union = _np_popcount(q[None, :]) + db_cnt - inter
    return np.where(union > 0, inter / np.maximum(union, 1), 0.0).astype(np.float32)


# ---------------------------------------------------------------------------
# index structure
# ---------------------------------------------------------------------------

@dataclass
class HNSWIndex:
    db: np.ndarray                 # (N, W) uint32 packed fingerprints
    db_popcount: np.ndarray        # (N,) int32
    m: int                         # max degree upper layers; base layer 2M
    ef_construction: int
    entry_point: int
    max_level: int
    base_adj: np.ndarray           # (N, 2M) int32, -1 padded
    # upper layers: per level 1..max_level
    level_nodes: list = field(default_factory=list)   # [int32 array of global ids]
    level_adj: list = field(default_factory=list)     # [(n_l, M) int32 global ids]
    level_of: np.ndarray | None = None                # (N,) int8 max level per node

    @property
    def n(self) -> int:
        return self.db.shape[0]


def _select_heuristic(cand_ids: np.ndarray, cand_sims: np.ndarray, m: int,
                      db: np.ndarray, db_cnt: np.ndarray) -> np.ndarray:
    """Alg. 4 neighbour selection: keep candidate e only if it is closer to the
    query than to every already-selected neighbour (keeps long-range links)."""
    order = np.argsort(-cand_sims, kind="stable")
    selected: list[int] = []
    for j in order:
        if len(selected) >= m:
            break
        e = int(cand_ids[j])
        e_fp = db[e]
        ok = True
        for s in selected:
            s_to_e = _np_tanimoto(e_fp, db[s:s + 1], db_cnt[s:s + 1])[0]
            if s_to_e > cand_sims[j]:   # e closer to an existing neighbour than to q
                ok = False
                break
        if ok:
            selected.append(e)
    # backfill with best remaining if heuristic selected < m (paper keeps M links)
    if len(selected) < m:
        for j in order:
            e = int(cand_ids[j])
            if e not in selected:
                selected.append(e)
                if len(selected) >= m:
                    break
    return np.asarray(selected[:m], dtype=np.int32)


def _search_layer_np(index_db, db_cnt, adj, q, entry_points, ef):
    """Host-side SEARCH-LAYER-BASE used during construction. adj: dict-like
    callable gid -> int32 array of neighbour gids."""
    visited = set(int(e) for e in entry_points)
    ep = np.asarray(list(visited), dtype=np.int32)
    sims = _np_tanimoto(q, index_db[ep], db_cnt[ep])
    # candidates: max-first by similarity; results: bounded by ef
    cand = list(zip((-sims).tolist(), ep.tolist()))
    import heapq
    heapq.heapify(cand)
    results = list(zip(sims.tolist(), ep.tolist()))
    heapq.heapify(results)  # min-heap over similarity = worst first
    while cand:
        neg_s, c = heapq.heappop(cand)
        if -neg_s < results[0][0] and len(results) >= ef:
            break
        neigh = adj(c)
        neigh = [int(e) for e in neigh if e >= 0 and int(e) not in visited]
        if not neigh:
            continue
        visited.update(neigh)
        na = np.asarray(neigh, dtype=np.int32)
        ns = _np_tanimoto(q, index_db[na], db_cnt[na])
        for e, s in zip(neigh, ns.tolist()):
            if len(results) < ef or s > results[0][0]:
                heapq.heappush(cand, (-s, e))
                heapq.heappush(results, (s, e))
                if len(results) > ef:
                    heapq.heappop(results)
    rs = sorted(results, reverse=True)
    return (np.asarray([e for _, e in rs], dtype=np.int32),
            np.asarray([s for s, _ in rs], dtype=np.float32))


def build_hnsw(db: np.ndarray, m: int = 16, ef_construction: int = 100,
               seed: int = 0, max_level_cap: int = 4) -> HNSWIndex:
    """Sequential insert construction (paper builds offline; search is the
    accelerated path)."""
    db = np.asarray(db, dtype=np.uint32)
    n, _ = db.shape
    db_cnt = _np_popcount(db)
    rng = np.random.default_rng(seed)
    ml = 1.0 / math.log(m)
    levels = np.minimum(
        np.floor(-np.log(np.maximum(rng.random(n), 1e-12)) * ml).astype(np.int32),
        max_level_cap)
    max_level = int(levels.max(initial=0))
    m0 = 2 * m
    base_adj = np.full((n, m0), -1, dtype=np.int32)
    upper_adj = [dict() for _ in range(max_level + 1)]  # gid -> np.int32 array

    entry_point = 0
    ep_level = int(levels[0])

    def adj_at(level):
        if level == 0:
            return lambda gid: base_adj[gid]
        return lambda gid: upper_adj[level].get(gid, np.empty((0,), np.int32))

    for i in range(n):
        if i == 0:
            for l in range(1, int(levels[0]) + 1):
                upper_adj[l][0] = np.empty((0,), np.int32)
            continue
        q = db[i]
        l_new = int(levels[i])
        ep = np.asarray([entry_point], dtype=np.int32)
        # greedy descent through layers above l_new (Alg. 1)
        for level in range(ep_level, l_new, -1):
            ids, _ = _search_layer_np(db, db_cnt, adj_at(level), q, ep, 1)
            ep = ids[:1]
        # insert at layers min(ep_level, l_new) .. 0 (Alg. 2 + Alg. 4)
        for level in range(min(ep_level, l_new), -1, -1):
            ids, sims = _search_layer_np(db, db_cnt, adj_at(level), q, ep, ef_construction)
            mmax = m0 if level == 0 else m
            sel = _select_heuristic(ids, sims, min(m, len(ids)), db, db_cnt)
            if level == 0:
                base_adj[i, :len(sel)] = sel
            else:
                upper_adj[level][i] = sel.copy()
            # bidirectional links + shrink
            for e in sel:
                e = int(e)
                if level == 0:
                    row = base_adj[e]
                    free = np.where(row < 0)[0]
                    if len(free):
                        row[free[0]] = i
                    else:
                        cand = np.concatenate([row, [i]]).astype(np.int32)
                        cs = _np_tanimoto(db[e], db[cand], db_cnt[cand])
                        base_adj[e] = _select_heuristic(cand, cs, mmax, db, db_cnt)
                else:
                    row = upper_adj[level].get(e, np.empty((0,), np.int32))
                    row = np.concatenate([row, [i]]).astype(np.int32)
                    if len(row) > m:
                        cs = _np_tanimoto(db[e], db[row], db_cnt[row])
                        row = _select_heuristic(row, cs, m, db, db_cnt)
                    upper_adj[level][e] = row
            ep = ids
        if l_new > ep_level:
            entry_point, ep_level = i, l_new
            for l in range(1, l_new + 1):
                upper_adj[l].setdefault(i, np.empty((0,), np.int32))

    # densify upper layers into arrays
    level_nodes, level_adj = [], []
    for l in range(1, max_level + 1):
        gids = np.asarray(sorted(upper_adj[l].keys()), dtype=np.int32)
        adjm = np.full((len(gids), m), -1, dtype=np.int32)
        for r, g in enumerate(gids):
            row = upper_adj[l][g][:m]
            adjm[r, :len(row)] = row
        level_nodes.append(gids)
        level_adj.append(adjm)

    return HNSWIndex(db=db, db_popcount=db_cnt, m=m,
                     ef_construction=ef_construction, entry_point=entry_point,
                     max_level=max_level, base_adj=base_adj,
                     level_nodes=level_nodes, level_adj=level_adj,
                     level_of=levels.astype(np.int8))


# ---------------------------------------------------------------------------
# accelerated batched search (JAX) — the paper's graph traversal engine
# ---------------------------------------------------------------------------

class HNSWDeviceGraph(NamedTuple):
    """Device-resident, constant-shape view of the index for the JAX engine."""
    db: jax.Array             # (N, W) uint32
    db_popcount: jax.Array    # (N,) int32
    base_adj: jax.Array       # (N, 2M) int32
    upper_adj: jax.Array      # (L, N, M) int32 — dense per-level adjacency (-1 pad)
    entry_point: jax.Array    # () int32
    max_level: int


def to_device_graph(index: HNSWIndex) -> HNSWDeviceGraph:
    L = max(index.max_level, 0)
    n, m = index.n, index.m
    upper = np.full((max(L, 1), n, m), -1, dtype=np.int32)
    for l in range(1, L + 1):
        gids = index.level_nodes[l - 1]
        upper[l - 1, gids] = index.level_adj[l - 1]
    return HNSWDeviceGraph(
        db=jnp.asarray(index.db), db_popcount=jnp.asarray(index.db_popcount),
        base_adj=jnp.asarray(index.base_adj), upper_adj=jnp.asarray(upper),
        entry_point=jnp.int32(index.entry_point), max_level=L)


def _sims(q: jax.Array, q_cnt: jax.Array, g: HNSWDeviceGraph, ids: jax.Array) -> jax.Array:
    """Vectorised TFC stage: Tanimoto of query vs gathered fingerprints.
    Invalid ids (-1) -> -inf."""
    safe = jnp.maximum(ids, 0)
    fps = g.db[safe]                       # (E, W)
    inter = jnp.sum(jax.lax.population_count(q[None, :] & fps).astype(jnp.int32), -1)
    union = q_cnt + g.db_popcount[safe] - inter
    s = jnp.where(union > 0, inter.astype(jnp.float32) / union.astype(jnp.float32), 0.0)
    return jnp.where(ids >= 0, s, NEG_INF)


def _greedy_descent(q, q_cnt, g: HNSWDeviceGraph, level: int) -> jax.Array:
    """SEARCH-LAYER-TOP (Alg. 1) at one (static) upper level."""
    adj = g.upper_adj[level - 1]

    def cond(state):
        cur, cur_sim, moved = state
        return moved

    def body(state):
        cur, cur_sim, _ = state
        neigh = adj[cur]                                   # (M,)
        s = _sims(q, q_cnt, g, neigh)
        j = jnp.argmax(s)
        better = s[j] > cur_sim
        return (jnp.where(better, neigh[j], cur),
                jnp.where(better, s[j], cur_sim), better)

    ep = g.entry_point
    s0 = _sims(q, q_cnt, g, ep[None])[0]
    cur, _, _ = jax.lax.while_loop(cond, body, (ep, s0, jnp.bool_(True)))
    return cur


def _search_base(q, q_cnt, g: HNSWDeviceGraph, ep: jax.Array, ef: int,
                 max_iters: int):
    """SEARCH-LAYER-BASE (Alg. 2), fixed-shape. Returns (ids, sims) desc, (ef,)."""
    n = g.db.shape[0]
    vwords = (n + 31) // 32
    ep_sim = _sims(q, q_cnt, g, ep[None])[0]

    # C (candidates, pop best) and M (results, evict worst): sorted desc arrays.
    cand_s = jnp.full((ef,), NEG_INF).at[0].set(ep_sim)
    cand_i = jnp.full((ef,), -1, jnp.int32).at[0].set(ep)
    res_s, res_i = cand_s, cand_i
    visited = jnp.zeros((vwords,), jnp.uint32)
    visited = visited.at[ep // 32].set(jnp.uint32(1) << (ep % 32).astype(jnp.uint32))

    def cond(st):
        cand_s, cand_i, res_s, res_i, visited, it = st
        has_cand = cand_s[0] > NEG_INF
        # stop when best candidate is worse than the worst retained result
        worst = res_s[ef - 1]
        return jnp.logical_and(it < max_iters,
                               jnp.logical_and(has_cand, cand_s[0] >= worst))

    def body(st):
        cand_s, cand_i, res_s, res_i, visited, it = st
        top_i = cand_i[0]
        # pop best candidate
        cand_s = jnp.concatenate([cand_s[1:], jnp.array([NEG_INF])])
        cand_i = jnp.concatenate([cand_i[1:], jnp.array([-1], jnp.int32)])
        neigh = g.base_adj[jnp.maximum(top_i, 0)]           # (2M,)
        word = visited[jnp.maximum(neigh, 0) // 32]
        bit = (word >> (jnp.maximum(neigh, 0) % 32).astype(jnp.uint32)) & 1
        fresh = jnp.logical_and(neigh >= 0, bit == 0)
        # mark visited. Scatter-OR via scatter-ADD: fresh neighbour ids are
        # unique, so their single-bit masks never collide within a word and
        # addition equals bitwise OR (a .set here would drop bits whenever
        # two neighbours share a word).
        upd = jnp.where(fresh, jnp.uint32(1) << (jnp.maximum(neigh, 0) % 32).astype(jnp.uint32),
                        jnp.uint32(0))
        visited = visited.at[jnp.maximum(neigh, 0) // 32].add(upd)
        s = _sims(q, q_cnt, g, neigh)
        s = jnp.where(fresh, s, NEG_INF)
        worst = res_s[ef - 1]
        keep = s > worst                                     # or M not full: worst=-inf then
        s = jnp.where(keep, s, NEG_INF)
        ni = jnp.where(keep, neigh, -1)
        # merge into result and candidate queues (register-array PQ analogue:
        # one sorted merge per expansion, constant shape)
        def merge(qs, qi):
            all_s = jnp.concatenate([qs, s])
            all_i = jnp.concatenate([qi, ni])
            top, pos = jax.lax.top_k(all_s, ef)
            return top, all_i[pos]
        res_s, res_i = merge(res_s, res_i)
        cand_s, cand_i = merge(cand_s, cand_i)
        return cand_s, cand_i, res_s, res_i, visited, it + 1

    st = (cand_s, cand_i, res_s, res_i, visited, jnp.int32(0))
    _, _, res_s, res_i, _, iters = jax.lax.while_loop(cond, body, st)
    return res_i, res_s, iters


def search_hnsw(g: HNSWDeviceGraph, queries: jax.Array, k: int, ef: int,
                max_iters: int | None = None):
    """Batched KNN search. queries: (Q, W) uint32 -> (ids (Q,k), sims (Q,k))."""
    ef = max(ef, k)
    if max_iters is None:
        max_iters = 4 * ef + 16

    def one(q):
        q_cnt = jnp.sum(jax.lax.population_count(q).astype(jnp.int32))
        ep = g.entry_point
        for level in range(g.max_level, 0, -1):   # static unroll over levels
            ep = _greedy_descent(q, q_cnt, g, level)
        ids, sims, iters = _search_base(q, q_cnt, g, ep, ef, max_iters)
        return ids[:k], sims[:k], iters

    return jax.vmap(one)(queries)
