"""Core library: the paper's contribution (Tanimoto KNN engines) in JAX."""
from .fingerprints import (  # noqa: F401
    pack_bits, unpack_bits, popcount, tanimoto, tanimoto_scores,
    batched_tanimoto_scores, n_words, DEFAULT_LEN,
    Metric, TANIMOTO, resolve_metric, METRIC_NAMES,
    metric_scores, batched_metric_scores,
)
from .engine import (  # noqa: F401
    SearchEngine, BruteForceEngine, BitBoundFoldingEngine, HNSWEngine,
    recall_at_k,
)
from . import bitbound, folding, hnsw, topk  # noqa: F401
