"""Top-k primitives: streaming tile merge and sorted-array priority queue.

Two structures from the paper (DESIGN.md §2):

* ``streaming_topk`` — the top-K *merge sort* unit of the exhaustive engine:
  the score stream is consumed tile by tile; each tile's local top-k is merged
  into a running top-k so the full score array never exists in memory. This is
  the pure-JAX model of the fused Pallas kernel in ``kernels/tanimoto_topk``.

* ``PriorityQueue`` — fixed-shape sorted-array priority queue, the TPU
  analogue of the paper's register-array PQ (even/odd compare-and-swap,
  initiation interval 1). Insert is a vectorised compare-and-shift across
  lanes: O(1) sequential depth, constant shapes (no data-dependent sizes).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-jnp.inf)


def merge_topk(scores_a, idx_a, scores_b, idx_b, k: int):
    """Merge two (descending) top-k candidate sets into one of size k."""
    s = jnp.concatenate([scores_a, scores_b])
    i = jnp.concatenate([idx_a, idx_b])
    top_s, pos = jax.lax.top_k(s, k)
    return top_s, i[pos]


def streaming_topk(scores: jax.Array, k: int, tile: int = 2048):
    """Running top-k over a score stream of shape (N,), tiled like the engine.

    Returns (values desc, indices). Pads N up to a tile multiple with -inf.
    """
    n = scores.shape[0]
    n_pad = (-n) % tile
    scores_p = jnp.pad(scores, (0, n_pad), constant_values=-jnp.inf)
    n_tiles = scores_p.shape[0] // tile
    init = (jnp.full((k,), NEG_INF), jnp.full((k,), -1, dtype=jnp.int32))

    def body(carry, t):
        run_s, run_i = carry
        tile_s = jax.lax.dynamic_slice(scores_p, (t * tile,), (tile,))
        tile_i = t * tile + jnp.arange(tile, dtype=jnp.int32)
        run_s, run_i = merge_topk(run_s, run_i, tile_s, tile_i, k)
        return (run_s, run_i), None

    (vals, idxs), _ = jax.lax.scan(body, init, jnp.arange(n_tiles))
    return vals, idxs


class PQ(NamedTuple):
    """Fixed-capacity priority queue state. ``scores`` sorted; invalid = sentinel."""
    scores: jax.Array   # (cap,) f32
    payload: jax.Array  # (cap,) int32
    size: jax.Array     # () int32


def pq_make(cap: int, max_heap: bool) -> PQ:
    """max_heap=True keeps the *largest* entries sorted descending (results set M);
    max_heap=False keeps the *smallest* sorted ascending (not used for similarity,
    provided for distance metrics)."""
    fill = NEG_INF if max_heap else jnp.float32(jnp.inf)
    return PQ(jnp.full((cap,), fill), jnp.full((cap,), -1, dtype=jnp.int32),
              jnp.int32(0))


def pq_insert_max(pq: PQ, score: jax.Array, payload: jax.Array) -> PQ:
    """Insert into a descending-sorted max queue (register-array style).

    Vectorised compare-and-shift: find insertion position, shift the tail by
    one lane, write. When full, the smallest entry falls off the end — which
    is exactly the paper's bounded result set M behaviour.
    """
    cap = pq.scores.shape[0]
    pos = jnp.sum((pq.scores >= score).astype(jnp.int32))  # first index with smaller score
    lane = jnp.arange(cap)
    shifted_s = jnp.where(lane > pos, jnp.roll(pq.scores, 1), pq.scores)
    shifted_p = jnp.where(lane > pos, jnp.roll(pq.payload, 1), pq.payload)
    new_s = jnp.where(lane == pos, score, shifted_s)
    new_p = jnp.where(lane == pos, payload, shifted_p)
    dropped = pos >= cap  # score worse than everything in a full queue
    new_s = jnp.where(dropped, pq.scores, new_s)
    new_p = jnp.where(dropped, pq.payload, new_p)
    size = jnp.where(dropped, pq.size, jnp.minimum(pq.size + 1, cap))
    return PQ(new_s, new_p, size)


def pq_pop_max(pq: PQ):
    """Pop the best (largest score) entry; returns (score, payload, new_pq)."""
    s0, p0 = pq.scores[0], pq.payload[0]
    new_s = jnp.concatenate([pq.scores[1:], jnp.array([NEG_INF])])
    new_p = jnp.concatenate([pq.payload[1:], jnp.array([-1], dtype=jnp.int32)])
    return s0, p0, PQ(new_s, new_p, jnp.maximum(pq.size - 1, 0))


def pq_worst_max(pq: PQ) -> jax.Array:
    """Score of the worst *valid* entry (or -inf when not full)."""
    cap = pq.scores.shape[0]
    return jnp.where(pq.size >= cap, pq.scores[cap - 1], NEG_INF)
