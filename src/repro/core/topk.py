"""Top-k primitives: the shared register-array priority queue + tile merge.

One module models the paper's two sorting structures (DESIGN.md §2, Fig. 9):

* :class:`PQ` — a fixed-capacity *register-array priority queue*: a
  descending-sorted pair of (scores, payload) lanes with ``NEG_INF`` / ``-1``
  sentinels in the empty slots. This is the TPU analogue of the paper's
  register-array PQ (even/odd compare-and-swap network, initiation interval
  1): every operation is a constant-shape vector op across the ``cap`` lanes,
  never a data-dependent resize.

  - :func:`pq_insert` — compare-and-shift insert: find the insertion lane,
    shift the tail by one, write. O(cap) lane work, O(1) sequential depth.
    When full, the worst entry falls off the end (the paper's bounded result
    set M behaviour — "evict worst").
  - :func:`pq_insert_batch` — merge a batch of E unsorted candidates: one
    sort of the batch (``lax.top_k``) followed by a rank-computation merge of
    the two sorted runs (:func:`merge_sorted`), i.e. a *merge* network, not a
    re-sort of the whole queue.
  - :func:`pq_pop` / :func:`pq_pop_many` — pop the best 1 / B entries and
    shift the array up (the candidate set C of HNSW's SEARCH-LAYER).
  - :func:`pq_worst` — the current eviction threshold (``NEG_INF`` while the
    queue still has free lanes, so inserts always succeed until full).

* :func:`streaming_topk` — the top-K merge-sort unit of the exhaustive
  engine: a score stream is consumed tile by tile and each tile is folded
  into a running :class:`PQ` via :func:`pq_insert_batch`, so the full score
  array never exists in memory. Pure-JAX model of the fused Pallas kernel in
  ``kernels/tanimoto_topk``.

Both the HNSW traversal queues (``core/hnsw.py``) and the streaming scan are
built on the same PQ primitives — there is exactly one top-k merge
implementation in the codebase; :func:`merge_sorted_many` extends it across
database shards (the fan-out combiner of ``HNSWEngine(shards=N)``, see
docs/ARCHITECTURE.md).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-jnp.inf)


class PQ(NamedTuple):
    """Fixed-capacity register-array priority queue.

    Invariant: ``scores`` is sorted descending; empty lanes hold ``NEG_INF``
    scores and ``-1`` payloads and always form a suffix. The queue is "full"
    exactly when ``scores[-1] > NEG_INF``.
    """
    scores: jax.Array   # (cap,) float32, descending
    payload: jax.Array  # (cap,) int32

    @property
    def cap(self) -> int:
        return self.scores.shape[0]


def pq_make(cap: int) -> PQ:
    return PQ(jnp.full((cap,), NEG_INF),
              jnp.full((cap,), -1, dtype=jnp.int32))


def pq_insert(pq: PQ, score: jax.Array, payload: jax.Array) -> PQ:
    """Compare-and-shift insert (register-array style).

    Vectorised across lanes: compute the insertion position, shift the tail
    one lane down, write. When the queue is full and ``score`` is worse than
    every entry, the insert is dropped; otherwise the worst entry is evicted.
    Ties keep existing entries ahead of the new one.
    """
    cap = pq.cap
    lane = jnp.arange(cap)
    pos = jnp.sum((pq.scores >= score).astype(jnp.int32))
    shifted_s = jnp.where(lane > pos, jnp.roll(pq.scores, 1), pq.scores)
    shifted_p = jnp.where(lane > pos, jnp.roll(pq.payload, 1), pq.payload)
    new_s = jnp.where(lane == pos, score, shifted_s)
    new_p = jnp.where(lane == pos, payload, shifted_p)
    dropped = pos >= cap
    return PQ(jnp.where(dropped, pq.scores, new_s),
              jnp.where(dropped, pq.payload, new_p))


def pq_pop(pq: PQ):
    """Pop the best entry; returns ``(score, payload, rest)``."""
    s, p, rest = pq_pop_many(pq, 1)
    return s[0], p[0], rest


def pq_pop_many(pq: PQ, b: int):
    """Pop the best ``b`` entries (the beam): returns ``(scores (b,),
    payloads (b,), rest)``. Popping past the valid suffix yields sentinels."""
    b = min(b, pq.cap)
    rest = PQ(
        jnp.concatenate([pq.scores[b:], jnp.full((b,), NEG_INF)]),
        jnp.concatenate([pq.payload[b:], jnp.full((b,), -1, jnp.int32)]))
    return pq.scores[:b], pq.payload[:b], rest


def pq_worst(pq: PQ) -> jax.Array:
    """Eviction threshold: the worst retained score, ``NEG_INF`` until full."""
    return pq.scores[-1]


def merge_sorted(s_a: jax.Array, i_a: jax.Array,
                 s_b: jax.Array, i_b: jax.Array):
    """Merge two descending-sorted runs, keeping the best ``len(s_a)``.

    Rank-computation merge (the constant-shape analogue of a merge network):
    each element's output position is its own index plus the count of
    elements from the other run strictly ahead of it — two vectorised
    ``searchsorted`` calls and one scatter, no re-sort. Ties place run-A
    elements first, so re-merging is stable w.r.t. the existing queue.
    """
    a, b = s_a.shape[0], s_b.shape[0]
    na, nb = -s_a, -s_b                       # ascending views
    pos_a = jnp.arange(a) + jnp.searchsorted(nb, na, side="left")
    pos_b = jnp.arange(b) + jnp.searchsorted(na, nb, side="right")
    out_s = jnp.zeros((a + b,), s_a.dtype).at[pos_a].set(s_a).at[pos_b].set(s_b)
    out_i = jnp.zeros((a + b,), i_a.dtype).at[pos_a].set(i_a).at[pos_b].set(i_b)
    return out_s[:a], out_i[:a]


def merge_sorted_many(scores: jax.Array, ids: jax.Array):
    """Rank-merge ``S`` descending-sorted runs into the best ``cap``.

    ``scores (S, cap)`` / ``ids (S, cap)`` are stacked per-shard result runs
    (the sharded-HNSW fan-out); the reduction is a **left-leaning pairwise
    merge tree** of :func:`merge_sorted` calls — ``ceil(log2 S)`` levels,
    each level one vmapped rank-merge. Ties keep the lower run index first
    at every level (``merge_sorted`` places run A ahead), so equal scores
    come back ordered by shard index — the deterministic cross-shard order
    the sharded engines and their parity tests rely on. Sentinel slots
    (``NEG_INF`` / ``-1`` pads, e.g. a shard that returned fewer than
    ``cap`` valid rows) lose to every real entry and can only surface when
    fewer than ``cap`` valid entries exist in total.

    Returns ``(scores (cap,), ids (cap,))``. ``S == 1`` is the identity —
    the 1-shard bit-parity contract of the sharded traversal.
    """
    s, i = scores, ids
    while s.shape[0] > 1:
        even_s, even_i = s[0::2], i[0::2]
        odd_s, odd_i = s[1::2], i[1::2]
        if odd_s.shape[0] < even_s.shape[0]:      # odd run count: carry last
            pad_s = jnp.full_like(even_s[:1], NEG_INF)
            pad_i = jnp.full_like(even_i[:1], -1)
            odd_s = jnp.concatenate([odd_s, pad_s])
            odd_i = jnp.concatenate([odd_i, pad_i])
        s, i = jax.vmap(merge_sorted)(even_s, even_i, odd_s, odd_i)
    return s[0], i[0]


def pq_insert_batch(pq: PQ, scores: jax.Array, payloads: jax.Array) -> PQ:
    """Merge a batch of E unsorted candidates into the queue.

    Sorts the batch once (only its best ``cap`` can matter), then rank-merges
    the two sorted runs. ``NEG_INF`` scores never displace valid entries.
    """
    kk = min(scores.shape[0], pq.cap)
    s_sorted, pos = jax.lax.top_k(scores, kk)
    p_sorted = jnp.take(payloads, pos)
    return PQ(*merge_sorted(pq.scores, pq.payload, s_sorted, p_sorted))


def streaming_topk(scores: jax.Array, k: int, tile: int = 2048):
    """Running top-k over a score stream of shape (N,), tiled like the engine.

    Returns (values desc, indices). Pads N up to a tile multiple with -inf.
    """
    n = scores.shape[0]
    n_pad = (-n) % tile
    scores_p = jnp.pad(scores, (0, n_pad), constant_values=-jnp.inf)
    n_tiles = scores_p.shape[0] // tile

    def body(pq, t):
        tile_s = jax.lax.dynamic_slice(scores_p, (t * tile,), (tile,))
        tile_i = t * tile + jnp.arange(tile, dtype=jnp.int32)
        return pq_insert_batch(pq, tile_s, tile_i), None

    pq, _ = jax.lax.scan(body, pq_make(k), jnp.arange(n_tiles))
    return pq.scores, pq.payload
