"""Distributed similarity search: DB sharded over the mesh, hierarchical
top-k merge — the paper's multi-engine scaling mapped onto collectives
(DESIGN.md §2, last row; data layouts in docs/ARCHITECTURE.md).

Two scaling recipes share the merge primitives in ``core/topk.py``:

* **Exhaustive** (:func:`make_sharded_search`): each device scans its DB
  shard with the fused on-the-fly engine (Pallas kernel or the
  streaming-jnp equivalent), producing a local (Q, k) top-k. Local results
  are then merged: ``all_gather`` over ``data`` (intra-pod ring on ICI),
  merge-sort; for multi-pod meshes a second all_gather over ``pod``
  (cross-pod DCN) merges pod winners. This is a log-depth distributed
  version of the paper's top-k merge unit.
* **HNSW fan-out** (:func:`merge_shard_topk` + :func:`shard_devices`): the
  sharded graph engine (``core/hnsw.py`` / ``HNSWEngine(shards=N)``) runs
  one independent lock-step traversal per database shard — each with its
  own entry point, visited bitset and PQ queues, placed on its own device —
  and this module's rank-merge tree (``core/topk.merge_sorted_many``)
  combines the per-shard result runs into one global top-k.

Either way the wire bytes per query are shards·k·8 — independent of DB
size, which is what makes both designs scale to thousands of nodes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .fingerprints import popcount, tanimoto_scores
from .topk import NEG_INF, merge_sorted_many, streaming_topk


def _local_topk(queries, db_shard, cnt_shard, k: int, use_kernel: bool):
    if use_kernel:
        from ..kernels import ops as kops
        ids, vals = kops.tanimoto_topk(queries, db_shard, k=k,
                                       db_popcount=cnt_shard)
        return vals, ids

    def one(q):
        s = tanimoto_scores(q, db_shard, cnt_shard)
        return streaming_topk(s, k)

    vals, ids = jax.vmap(one)(queries)
    return vals, ids


def make_sharded_search(mesh, n_total: int, k: int, use_kernel: bool = False,
                        n_valid: int | None = None):
    """Build a pjit-able sharded search fn.

    DB layout: fingerprints sharded over all DP axes (('pod','data') if
    present); queries replicated; result (Q, k) replicated.

    ``n_valid`` is the unpadded database size (``shard_database`` returns
    it): ids of the zero pad rows the sharder appends are masked to ``-1``
    (sim 0) instead of leaking into the merged top-k — without the mask a
    pad row's 0-score entry can displace a truncated real row whenever ``k``
    approaches the shard size. Defaults to ``n_total`` (no masking) for
    callers that pad externally.
    """
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    db_spec = P(dp_axes, None)
    cnt_spec = P(dp_axes)
    n_shards = 1
    for a in dp_axes:
        n_shards *= mesh.shape[a]
    shard_n = n_total // n_shards
    if n_valid is None:
        n_valid = n_total

    def local_fn(queries, db_shard, cnt_shard):
        vals, ids = _local_topk(queries, db_shard, cnt_shard, k, use_kernel)
        # global ids: offset by this shard's position along the DP axes
        idx = jax.lax.axis_index(dp_axes)
        ids = jnp.where(ids >= 0, ids + idx * shard_n, ids)
        # pad rows out of every queue: id -1, score -inf (never beats a real
        # row; restored to 0 after the merge)
        pad = ids >= n_valid
        ids = jnp.where(pad, -1, ids)
        vals = jnp.where(pad, -jnp.inf, vals)
        # hierarchical merge: gather per-shard top-k along 'data' then 'pod'
        for ax in reversed(dp_axes):            # innermost (ICI) first
            av = jax.lax.all_gather(vals, ax)   # (D, Q, k)
            ai = jax.lax.all_gather(ids, ax)
            d = av.shape[0]
            av = jnp.moveaxis(av, 0, 1).reshape(av.shape[1], d * k)
            ai = jnp.moveaxis(ai, 0, 1).reshape(ai.shape[1], d * k)
            vals, sel = jax.lax.top_k(av, k)
            ids = jnp.take_along_axis(ai, sel, axis=1)
        vals = jnp.where(ids >= 0, vals, 0.0)
        return vals, ids

    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(P(), db_spec, cnt_spec),
                   out_specs=(P(), P()),
                   check_rep=False)
    return jax.jit(fn), db_spec, cnt_spec


def shard_devices(n_shards: int) -> list:
    """Device placement for a shard fan-out: shard ``s`` lives on local
    device ``s % n_devices``. With fewer devices than shards the assignment
    wraps (several logical shards per device — same results, serialized);
    on a single-device host every shard is local and the fan-out degrades
    to a loop. The forced-host recipe in EXPERIMENTS.md §Sharded HNSW gives
    a laptop 8 devices to place on."""
    devs = jax.devices()
    return [devs[s % len(devs)] for s in range(n_shards)]


@functools.partial(jax.jit, static_argnames=("k",))
def merge_shard_topk(sims: jax.Array, gids: jax.Array, k: int):
    """Combine per-shard top-k runs into the global top-k.

    ``sims (S, Q, kk)`` / ``gids (S, Q, kk)`` are the fan-out's per-shard
    result runs (descending scores, global ids, ``-1`` pads). Pad rows are
    masked to ``NEG_INF`` so a real 0-similarity entry always beats them,
    the rank-merge tree (``core/topk.merge_sorted_many``) reduces the S
    runs per query, and pad similarities are restored to 0 after — the same
    conventions as the single-shard traversal's output. Ties across shards
    come back ordered by shard index (the tree is left-leaning).

    Returns ``(ids (Q, k), sims (Q, k))``. With ``S == 1`` the merge is the
    identity, which is what makes a 1-shard engine bit-identical to the
    unsharded path.
    """
    s = jnp.where(gids >= 0, sims, NEG_INF)
    s_q = jnp.moveaxis(s, 0, 1)                    # (Q, S, kk)
    i_q = jnp.moveaxis(gids, 0, 1)
    ms, mi = jax.vmap(merge_sorted_many)(s_q, i_q)
    ms, mi = ms[:, :k], mi[:, :k]
    return mi, jnp.where(mi >= 0, ms, 0.0)


def shard_database(mesh, db, counts=None):
    """Place a packed fingerprint DB (padded to the shard multiple) onto the
    mesh. Returns (db_sharded, counts_sharded, n_valid)."""
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    n_shards = 1
    for a in dp_axes:
        n_shards *= mesh.shape[a]
    n = db.shape[0]
    pad = (-n) % n_shards
    db = jnp.asarray(db)
    if pad:
        db = jnp.concatenate([db, jnp.zeros((pad, db.shape[1]), db.dtype)])
    if counts is None:
        counts = popcount(db)
        # force padded rows out of every top-k (score 0 beats -inf only at k>N)
    db_s = jax.device_put(db, NamedSharding(mesh, P(dp_axes, None)))
    cnt_s = jax.device_put(counts, NamedSharding(mesh, P(dp_axes)))
    return db_s, cnt_s, n
