"""Unified similarity-search engines (paper §IV).

Three engines, one per paper design point, on one shared base:

* :class:`BruteForceEngine` — exhaustive linear scan with the fused
  scan+top-k path (on-the-fly engine; Pallas kernel when enabled, streaming
  jnp fallback otherwise).
* :class:`BitBoundFoldingEngine` — exhaustive with Eq.2 popcount pruning and
  2-stage modulo-OR folding; host-side numpy reference plus a fully
  device-resident ``search_tpu`` path.
* :class:`HNSWEngine` — approximate graph search over the device-resident
  batched traversal engine (``core/hnsw.py``).

The ``backend=`` contract (shared, :class:`SearchEngine`)
---------------------------------------------------------
Every engine exposes ``search(queries, k) -> (ids, sims)`` numpy arrays and a
``backend`` selector naming the execution path:

* ``"numpy"`` — host-side reference loop with true variable-length data
  structures. Exact semantics; the parity oracle for the device paths.
  (Engines whose reference *is* the device path don't offer it.)
* ``"jnp"``   — fully device-resident fixed-shape path built from plain jnp
  ops (works on any JAX backend, no Pallas required).
* ``"tpu"``   — same device-resident path with its hot stage swapped for the
  Pallas kernel (Mosaic on TPU, interpret mode elsewhere). Engines fall back
  to the ``jnp`` stage automatically when Pallas cannot be imported.

Invalid names raise ``ValueError`` listing the engine's supported backends.
The legacy ``use_kernel=True`` flag maps onto ``backend="tpu"`` when
``backend`` is unset.

Online inserts (the serving write path)
---------------------------------------
``insert(fps) -> global ids`` appends fingerprints while the engine keeps
serving. Brute/BitBound engines are backed by a
:class:`repro.serve.store.MutableFingerprintStore` (immutable popcount-sorted
main segment + append-only delta, LSM-style compaction): searches scan
main + delta and rank-merge the two result runs with
``core/topk.merge_sorted``; the delta is padded to power-of-two buckets so
compiled pipelines are reused as it grows. :class:`HNSWEngine` routes inserts
through :func:`repro.core.hnsw.insert_hnsw` (batched incremental graph
construction). The contract — pinned by ``tests/test_insert_parity.py`` — is
that after any interleaving of inserts and searches (including across a
compaction) results are bit-identical to a from-scratch engine built on the
concatenated database.

Work accounting: ``scanned(n_queries)`` is the number of candidate
fingerprints the engine scores for ``n_queries`` queries, extrapolated from
the *most recent* ``search`` batch: ``last_batch_total * n_queries /
last_batch_n_queries``. Before any search it is 0 for data-dependent
engines; engines whose per-query work is input-independent compute it in
closed form. Per-batch traversal telemetry beyond that single number lives
in the engine's ``stats`` dict (see :attr:`HNSWEngine.stats`).

The engine x backend x layout matrix is summarised in README.md; data
layouts and the request path are documented in docs/ARCHITECTURE.md.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import bitbound as bb
from . import folding as fl
from . import hnsw as hn
from ..obs.trace import TRACER as _TR
from .distributed import merge_shard_topk, shard_devices
from .fingerprints import (Metric, TANIMOTO, batched_metric_scores,
                           batched_tanimoto_scores, metric_from_counts,
                           metric_from_counts_np, metric_scores, popcount,
                           resolve_metric, tanimoto_scores)
from .topk import merge_sorted, streaming_topk


def _kernels_available() -> bool:
    try:
        from ..kernels import ops  # noqa: F401
        return True
    except Exception:  # Pallas/Mosaic not importable on this install
        return False


def _store_mod():
    # Lazy: core must stay importable without triggering the serve package
    # at module-import time (serve imports core back).
    from ..serve import store
    return store


@functools.partial(jax.jit, static_argnames=("metric",))
def _gather_score_frontier(q, dev_db, ids, metric: Metric = TANIMOTO):
    """Jitted single-query gather-distance launch for the insert-frontier
    scorer. Module-level so the compile cache is keyed purely on shapes
    (plus the static metric): with the capacity-stable cached device db,
    repeated frontier widths across insert batches (and engines) replay
    compiled launches instead of re-tracing the Pallas call per frontier."""
    from ..kernels import ops as kops
    return kops.gather_tanimoto(q[None], dev_db, ids[None], metric=metric)[0]


@jax.jit
def _merge_main_delta(s_a, i_a, s_b, i_b, n_main):
    """Rank-merge the main-segment and delta (scores, ids) runs, keeping the
    best ``k = s_a.shape[1]`` per row. Ties keep run A (the main segment)
    ahead — the same order a single stable scan over main⊕delta produces.

    Main-run entries pointing at capacity-pad rows (``id >= n_main``) are
    masked out first: their sim-0 entries would otherwise win cross-run ties
    against real sim-0 delta rows (within the main run they always lose
    index ties, but ``merge_sorted`` puts run A first on ties)."""
    pad = i_a >= n_main
    s_a = jnp.where(pad, -jnp.inf, s_a)
    i_a = jnp.where(pad, -1, i_a)
    return jax.vmap(merge_sorted)(s_a, i_a, s_b, i_b)


class SearchEngine:
    """Shared engine plumbing: backend selection, compiled-function caching
    and the ``scanned`` work-counter contract (module docstring).

    Subclasses declare ``BACKENDS`` / ``DEFAULT_BACKEND`` and call
    :meth:`_init_engine` from ``__post_init__``; per-batch work is recorded
    with :meth:`_record_batch` and jitted pipelines are memoised per static
    key with :meth:`_cached`. Online writes go through :meth:`insert`; each
    engine implements :meth:`_apply_insert`.
    """

    BACKENDS: tuple = ("jnp", "tpu")
    DEFAULT_BACKEND: str = "jnp"
    #: memory layouts the engine's device path can run on; engines with a
    #: ``layout`` field (HNSW: "rows" row-gather / "blocked"
    #: neighbour-blocked streaming) extend this
    LAYOUTS: tuple = ("rows",)
    #: residency modes the engine supports; the exhaustive engines extend
    #: this with "tiered" (full-resolution rows stay in host memory and are
    #: streamed into a double-buffered HBM staging window — ISSUE 7)
    RESIDENCIES: tuple = ("device",)

    def _init_engine(self) -> None:
        if self.backend is None:
            self.backend = ("tpu" if getattr(self, "use_kernel", False)
                            else self.DEFAULT_BACKEND)
        if self.backend not in self.BACKENDS:
            raise ValueError(
                f"{type(self).__name__} backend must be one of "
                f"{'/'.join(repr(b) for b in self.BACKENDS)}, "
                f"got {self.backend!r}")
        layout = getattr(self, "layout", None)
        if layout is not None and layout not in self.LAYOUTS:
            raise ValueError(
                f"{type(self).__name__} layout must be one of "
                f"{'/'.join(repr(x) for x in self.LAYOUTS)}, "
                f"got {layout!r}")
        self._last_scanned = 0
        self._last_n_queries = 0
        self._jit_cache: dict = {}
        self.stats: dict = {}

    def _resolve_metric_width(self, words: int) -> None:
        """Resolve the ``metric`` spec (None / name / Metric) and pin the
        engine's fingerprint width. ``fp_bits=None`` infers the width from
        the data; an explicit value must match the packed word count —
        metric and width are per-engine trace-time constants, so every
        compiled pipeline downstream is keyed by construction."""
        self.metric = resolve_metric(self.metric)
        words = int(words)
        if self.fp_bits is None:
            self.fp_bits = words * 32
        elif int(self.fp_bits) != words * 32:
            raise ValueError(
                f"fp_bits={self.fp_bits} does not match the database width "
                f"({words} words = {words * 32} bits)")

    def _resolve_residency(self) -> None:
        """Resolve the ``residency`` field after the store exists: ``None``
        inherits the store's policy (a :class:`TieredFingerprintStore`
        defaults the engine to "tiered"), then validate."""
        if getattr(self, "residency", None) is None:
            self.residency = getattr(self.store, "residency", "device")
        if self.residency not in self.RESIDENCIES:
            raise ValueError(
                f"{type(self).__name__} residency must be one of "
                f"{'/'.join(repr(r) for r in self.RESIDENCIES)}, "
                f"got {self.residency!r}")

    def _cached(self, key, builder):
        fn = self._jit_cache.get(key)
        if fn is None:
            fn = builder()
            self._jit_cache[key] = fn
        return fn

    def _record_batch(self, scanned, n_queries) -> None:
        self._last_scanned = int(scanned)
        self._last_n_queries = int(n_queries)

    def scanned(self, n_queries: int) -> int:
        """Candidates scored for ``n_queries`` queries, extrapolated from the
        most recent search batch (0 before any search)."""
        if self._last_n_queries == 0:
            return 0
        return round(self._last_scanned * n_queries / self._last_n_queries)

    @property
    def n_total(self) -> int:
        """Fingerprints currently searchable (base + online inserts)."""
        raise NotImplementedError

    def search(self, queries, k: int):
        raise NotImplementedError

    def insert(self, fps) -> np.ndarray:
        """Append fingerprints online; returns their global ids (monotone,
        stable across compactions). Results after an insert are identical to
        a from-scratch engine on the concatenated database. Mis-dtyped rows
        (floats, signed ints) raise ``ValueError`` up front instead of being
        silently reinterpreted as uint32."""
        fps = _store_mod().validate_rows(fps)
        if fps.shape[0] == 0:
            return np.empty((0,), dtype=np.int64)
        return self._apply_insert(fps)

    def _apply_insert(self, fps: np.ndarray) -> np.ndarray:
        raise NotImplementedError(
            f"{type(self).__name__} does not support online insert()")


def _brute_topk(queries: jax.Array, db: jax.Array, db_cnt: jax.Array, k: int,
                use_kernel: bool, tile: int = 2048,
                metric: Metric = TANIMOTO):
    if use_kernel:
        from ..kernels import ops as kops
        return kops.tanimoto_topk(queries, db, k=k, db_popcount=db_cnt,
                                  metric=metric)

    def one(q):
        # the tanimoto branch keeps the historical scorer verbatim (HLO
        # bit-identity for the default path)
        if metric.name == "tanimoto":
            s = tanimoto_scores(q, db, db_cnt)
        else:
            s = metric_scores(q, db, metric, db_cnt)
        return streaming_topk(s, k, tile=tile)

    vals, idxs = jax.vmap(one)(queries)
    return idxs, vals


@dataclass
class BruteForceEngine(SearchEngine):
    """Exhaustive scan. ``backend``: ``"tpu"`` = fused Pallas kernel
    (interpret-mode off-TPU), ``"jnp"`` = streaming jnp path.

    Online inserts append to the store's delta segment; a search scans the
    (capacity-padded, global-id-ordered) main segment with the compiled
    pipeline, scans the power-of-two-padded delta with a bucketed jnp
    pipeline, and rank-merges the two top-k runs (main capacity-pad entries
    masked to -1 first — see :func:`_merge_main_delta`), so results match a
    from-scratch scan exactly for ``k <= n_total``.

    ``residency`` (ISSUE 7): ``"device"`` keeps the whole main segment in
    HBM (the default); ``"tiered"`` keeps it in host memory and streams
    ``tier_chunk_rows``-row chunks through a double-buffered HBM staging
    window — each chunk is scanned with the same fused top-k primitive and
    rank-merged into the running result via :func:`core.topk.merge_sorted`
    (ties keep the earlier chunk, reproducing the full scan's
    ascending-index tie order bit-for-bit). ``None`` inherits the store's
    policy. Double-buffer telemetry (chunks, bytes streamed, stall seconds /
    fraction) lands in :attr:`stats` after each search.
    """
    db: jax.Array
    use_kernel: bool = False
    backend: str | None = None
    compact_threshold: int = 4096
    #: prebuilt store (durability warm restart) — skips the store build;
    #: ``db`` is ignored when set
    store: object = None
    residency: str | None = None
    #: rows per streamed chunk in tiered mode (rounded to a power of two so
    #: chunks tile the power-of-two capacity exactly)
    tier_chunk_rows: int = 65536
    #: similarity metric (None / name / spec string / Metric descriptor);
    #: trace-time constant — each (metric, shape) pair compiles once
    metric: Metric | str | None = None
    #: fingerprint width in bits; None infers from the data, an explicit
    #: value is validated against the packed word count
    fp_bits: int | None = None

    BACKENDS = ("jnp", "tpu")
    DEFAULT_BACKEND = "jnp"
    RESIDENCIES = ("device", "tiered")

    def __post_init__(self):
        self._init_engine()
        self.use_kernel = self.backend == "tpu" and _kernels_available()
        if self.store is None:
            self.store = _store_mod().MutableFingerprintStore(
                np.asarray(self.db), sorted_main=False, fold_m=1,
                compact_threshold=self.compact_threshold)
        else:
            if self.store.sorted_main or self.store.fold_m != 1:
                raise ValueError("restored store layout does not match "
                                 "a brute-force engine")
            self.compact_threshold = self.store.compact_threshold
        self._resolve_metric_width(self.store.words)
        self._resolve_residency()
        self._sync_gen = None
        self._sync_delta = None
        self._delta_dev = None
        self._sync()

    @property
    def n_total(self) -> int:
        return self.store.n_total

    def _sync(self) -> None:
        st = self.store
        if self._sync_gen != st.generation:
            self._sync_gen = st.generation
            if self.residency == "tiered":
                # full-resolution rows stay on the host; searches stream
                # them chunk-wise through _tiered_main_topk
                self.db = None
                self.db_cnt = None
                self._db_np = st.main.db
            else:
                self.db = jnp.asarray(st.main.db)      # (capacity, W)
                self.db_cnt = popcount(self.db)        # pad rows -> 0
        if self._sync_delta != st.delta_version:
            self._sync_delta = st.delta_version
            if st.n_delta == 0:
                self._delta_dev = None
            else:
                bucket = _store_mod().next_pow2(st.n_delta)
                d = np.zeros((bucket, st.words), dtype=np.uint32)
                d[:st.n_delta] = st.delta_db
                d = jnp.asarray(d)
                self._delta_dev = (d, popcount(d), bucket)

    def _main_builder(self, k: int):
        use_kernel = self.use_kernel
        metric = self.metric

        def build():
            return jax.jit(
                lambda q, db, db_cnt: _brute_topk(q, db, db_cnt, k,
                                                  use_kernel, metric=metric))
        return build

    def _delta_builder(self, k: int, bucket: int):
        metric = self.metric

        def build():
            dk = min(k, bucket)

            def run(q, ddb, dcnt, n_delta):
                s = batched_metric_scores(q, ddb, metric, dcnt)
                slot = jnp.arange(bucket)[None, :]
                s = jnp.where(slot < n_delta, s, -jnp.inf)
                vals, slots = jax.lax.top_k(s, dk)
                ids = jnp.where(jnp.isfinite(vals), slots, -1)
                if dk < k:
                    pad = ((0, 0), (0, k - dk))
                    vals = jnp.pad(vals, pad, constant_values=-jnp.inf)
                    ids = jnp.pad(ids, pad, constant_values=-1)
                return ids, vals
            return jax.jit(run)
        return build

    def _tier_scan_builder(self, k: int, rows_n: int):
        """Per-chunk fused scan: popcount + top-k over one streamed chunk.
        Same primitive as the device-resident path, so per-row scores are
        bit-identical."""
        use_kernel = self.use_kernel
        metric = self.metric

        def build():
            dk = min(k, rows_n)

            def run(q, rows):
                return _brute_topk(q, rows, popcount(rows), dk, use_kernel,
                                   metric=metric)
            return jax.jit(run)
        return build

    def _tier_merge_builder(self, k: int, rows_n: int):
        """Fold one chunk's (ids, vals) into the running top-k. The running
        run always holds earlier (lower-id) chunks, and ``merge_sorted``
        keeps run A ahead on ties — together with the in-chunk top-k's
        lowest-index tie rule this reproduces the full scan's global
        ascending-index tie order exactly."""
        def build():
            dk = min(k, rows_n)

            def run(run_vals, run_ids, vals_c, ids_c, base):
                gids = jnp.where(ids_c >= 0, ids_c.astype(jnp.int32) + base,
                                 -1)
                if dk < k:
                    pad = ((0, 0), (0, k - dk))
                    vals_c = jnp.pad(vals_c, pad, constant_values=-jnp.inf)
                    gids = jnp.pad(gids, pad, constant_values=-1)
                return jax.vmap(merge_sorted)(run_vals, run_ids, vals_c, gids)
            return jax.jit(run)
        return build

    def _tiered_main_topk(self, q, k: int):
        """Stream the host-resident main segment through a double-buffered
        HBM staging window: ``jax.device_put`` of chunk i+1 is dispatched
        before the scan of chunk i, so under JAX async dispatch the host→HBM
        transfer overlaps the previous chunk's compute. The stall time —
        waiting on a transfer that compute overtook — is measured per chunk
        and reported in :attr:`stats`."""
        sm = _store_mod()
        cap = self.store.main.capacity
        r = min(sm.next_pow2(max(self.tier_chunk_rows, 1)), cap)
        n_chunks = cap // r
        db_np = self._db_np
        sfn = self._cached(("tier_scan", int(k), r),
                           self._tier_scan_builder(k, r))
        mfn = self._cached(("tier_merge", int(k), r),
                           self._tier_merge_builder(k, r))
        nq = q.shape[0]
        run_vals = jnp.full((nq, k), -jnp.inf, jnp.float32)
        run_ids = jnp.full((nq, k), -1, jnp.int32)
        # tracing (ISSUE 8): each host->HBM transfer is a flow span on the
        # "h2d-stream" track from dispatch to observed-ready; with tracing
        # on, each chunk's scan additionally syncs so its span carries real
        # device time — chunk i's scan span then visibly overlaps chunk
        # i+1's device_put span in Perfetto (dispatched before the scan).
        traced = _TR.enabled
        t0 = time.perf_counter()
        stall = 0.0
        put_h = _TR.begin("tier.device_put", track="h2d-stream", chunk=0)
        staged = jax.device_put(db_np[:r])
        for c in range(n_chunks):
            cur, cur_h = staged, put_h
            if c + 1 < n_chunks:
                put_h = _TR.begin("tier.device_put", track="h2d-stream",
                                  chunk=c + 1)
                staged = jax.device_put(db_np[(c + 1) * r:(c + 2) * r])
            ts = time.perf_counter()
            jax.block_until_ready(cur)
            te = time.perf_counter()
            stall += te - ts
            cur_h.end()
            if traced:
                _TR.emit("tier.stall", ts, te, chunk=c)
                with _TR.span("tier.scan_chunk", chunk=c, rows=r):
                    ids_c, vals_c = sfn(q, cur)
                    run_vals, run_ids = mfn(run_vals, run_ids, vals_c, ids_c,
                                            jnp.int32(c * r))
                    jax.block_until_ready(run_vals)  # tracing-only sync
            else:
                ids_c, vals_c = sfn(q, cur)
                run_vals, run_ids = mfn(run_vals, run_ids, vals_c, ids_c,
                                        jnp.int32(c * r))
        jax.block_until_ready(run_vals)
        total = time.perf_counter() - t0
        self.stats.update(
            residency="tiered", tiered_chunks=n_chunks, tiered_chunk_rows=r,
            tiered_streamed_bytes=int(n_chunks) * r * db_np.shape[1] * 4,
            tiered_stall_s=stall, tiered_scan_s=total,
            tiered_stall_fraction=(stall / total) if total > 0 else 0.0)
        return run_ids, run_vals

    def search(self, queries, k: int):
        self._sync()
        q = jnp.asarray(queries)
        if self.residency == "tiered":
            ids, vals = self._tiered_main_topk(q, k)
        else:
            fn = self._cached(("main", int(k), self.db.shape[0]),
                              self._main_builder(k))
            ids, vals = fn(q, self.db, self.db_cnt)
        if self._delta_dev is not None:
            ddb, dcnt, bucket = self._delta_dev
            dfn = self._cached(("delta", int(k), bucket),
                               self._delta_builder(k, bucket))
            dids, dvals = dfn(q, ddb, dcnt, jnp.int32(self.store.n_delta))
            gids = jnp.where(dids >= 0,
                             dids + jnp.int32(self.store.n_main), -1)
            vals, ids = _merge_main_delta(vals, ids.astype(jnp.int32),
                                          dvals, gids.astype(jnp.int32),
                                          jnp.int32(self.store.n_main))
        return np.asarray(ids), np.asarray(vals)

    def _apply_insert(self, fps):
        return self.store.insert(fps)   # compaction handled by the store

    def scanned(self, n_queries: int) -> int:
        # per-query work is the whole DB regardless of the query batch
        return n_queries * self.store.n_total


@dataclass
class BitBoundFoldingEngine(SearchEngine):
    """BitBound (Eq. 2) + 2-stage folding (paper §III-B, §IV-A).

    Stage 1 scans only the popcount-bounded range of the *folded* DB and keeps
    ``k_r1 = k*m*log2(2m)`` candidates; stage 2 rescores them at full
    resolution. ``cutoff`` is the similarity cutoff Sc; ``m=1`` disables
    folding (pure BitBound).

    The fingerprints live in a :class:`~repro.serve.store.MutableFingerprintStore`
    (popcount-sorted capacity-padded main segment + append-only delta).
    Searches scan the main segment through the Eq.2 window machinery and the
    delta through a popcount mask computed with the *same* float64 bounds;
    candidates from both segments are merged **in the global popcount-sorted
    order a from-scratch rebuild would produce** (stable ties: ascending
    (popcount, global id)), so results are bit-identical to a rebuilt engine
    at every interleaving of inserts and searches.

    Two execution paths share the store:

    * ``search_numpy`` — host-side reference with true variable-length Eq.2
      ranges (one python loop per query). Exact semantics, used as the parity
      oracle and for algorithmic speedup measurements.
    * ``search_tpu`` — device-resident fixed-shape path: stage 1 runs the
      scalar-prefetched row-window Pallas kernel over each query's Eq.2 tile
      window of the folded main segment (``kernels.ops.window_topk``) plus a
      masked jnp scan of the folded delta; the merged stage-1 candidate set
      (rank = virtual position in the merged sorted array, via two
      searchsorteds) is rescored at full resolution with a fused top-k — one
      jitted function per ``(window bucket, k, delta bucket, capacity)``, no
      host round-trips, returning ``(ids, sims, scanned)`` device arrays.
      When Pallas is unavailable (or ``backend="jnp"``) stage 1 falls back to
      a masked jnp scan with identical results.

    ``backend`` selects what :meth:`search` runs: ``"numpy"`` (default,
    reference), ``"tpu"`` (Pallas device path) or ``"jnp"`` (device path
    without Pallas).

    ``residency`` (ISSUE 7) selects where the *full-resolution* main segment
    lives for the device paths:

    * ``"device"`` — everything in HBM (the default).
    * ``"tiered"`` — only the folded stage-1 arrays plus the 4 B/row count
      and order vectors stay in HBM (``(4*W/m + 8)`` bytes/row instead of
      ``4*W*(1 + 1/m) + 8``); the full-resolution rows stay on the host
      (optionally memmapped — :class:`~repro.serve.store.TieredFingerprintStore`).
      Stage 1 and the rebuilt-order candidate merge run on device exactly as
      before, but instead of gathering rescore rows from an HBM-resident
      array, the candidate metadata returns to the host, which gathers the
      BitBound-bounded candidate rows and streams them in
      ``tier_chunk``-candidate chunks through a double-buffered HBM staging
      window: ``jax.device_put`` of chunk i+1 overlaps the fused
      rescore+top-k of chunk i, and partial top-k runs are rank-merged with
      :func:`core.topk.merge_sorted`. Chunks ascend in stage-1 candidate
      rank and ``merge_sorted`` keeps the earlier run on ties, so results
      are **bit-identical** to ``residency="device"``
      (``tests/test_tiered.py``). Double-buffer telemetry (chunks, bytes
      streamed, stall fraction) lands in :attr:`stats`. ``None`` inherits
      the store's policy. The numpy backend is host-resident by definition
      and ignores the knob.
    """
    db: np.ndarray
    cutoff: float = 0.8
    m: int = 4
    scheme: int = 1
    use_kernel: bool = False
    backend: str | None = None
    compact_threshold: int = 4096
    #: prebuilt store (durability warm restart) — skips the store build;
    #: ``db`` is ignored when set
    store: object = None
    residency: str | None = None
    #: stage-2 candidate columns per streamed chunk in tiered mode
    tier_chunk: int = 256
    #: similarity metric (None / name / spec string / Metric descriptor).
    #: Metrics with a popcount bound get a per-metric Eq.2-style window
    #: (``Metric.bound_ratios``); unbounded ones (tversky alpha/beta = 0)
    #: fall back to a full scan and ``scanned`` reflects it.
    metric: Metric | str | None = None
    fp_bits: int | None = None

    BACKENDS = ("numpy", "jnp", "tpu")
    DEFAULT_BACKEND = "numpy"
    RESIDENCIES = ("device", "tiered")

    def __post_init__(self):
        self._init_engine()
        if self.store is None:
            self.store = _store_mod().MutableFingerprintStore(
                np.asarray(self.db), sorted_main=True, fold_m=self.m,
                fold_scheme=self.scheme,
                compact_threshold=self.compact_threshold)
        else:
            if (not self.store.sorted_main or self.store.fold_m != self.m
                    or self.store.fold_scheme != self.scheme):
                raise ValueError("restored store layout does not match "
                                 "engine fold config")
            self.compact_threshold = self.store.compact_threshold
        self._resolve_metric_width(self.store.words)
        self._resolve_residency()
        self._stage1_cache = self._jit_cache
        self._sync_gen = None
        self._sync_delta = None
        self._delta_dev = None
        self._device_state: dict | None = None
        self._sync()

    @property
    def n_total(self) -> int:
        return self.store.n_total

    def _sync(self) -> None:
        st = self.store
        if self._sync_gen != st.generation:
            self._sync_gen = st.generation
            if self.residency == "tiered":
                # full-resolution rows stay host-side; counts/order (4 and
                # 8 B/row) still ship — the rebuilt-order merge needs them
                self.full = None
                self._full_np = st.main.db
            else:
                self.full = jnp.asarray(st.main.db)
                self._full_np = None
            self.full_cnt = jnp.asarray(st.main.counts.astype(np.int32))
            self.folded = jnp.asarray(st.main.folded)
            self.folded_cnt = jnp.asarray(
                st.main.folded_counts.astype(np.int32))
            self.order = jnp.asarray(st.main.order.astype(np.int32))
            self._counts_np = st.main.counts           # pads = PAD_COUNT
        if self._sync_delta != st.delta_version:
            self._sync_delta = st.delta_version
            nd = st.n_delta
            if nd == 0:
                self._delta_dev = None
            else:
                sm = _store_mod()
                bucket = sm.next_pow2(nd)
                pad = bucket - nd
                wf = st.delta_folded.shape[1]
                d_full = np.concatenate(
                    [st.delta_db, np.zeros((pad, st.words), np.uint32)])
                d_folded = np.concatenate(
                    [st.delta_folded, np.zeros((pad, wf), np.uint32)])
                d_cnt = np.concatenate(
                    [st.delta_counts,
                     np.full((pad,), sm.PAD_COUNT, np.int64)])
                d_fcnt = np.concatenate(
                    [st.delta_folded_counts, np.zeros((pad,), np.int64)])
                self._delta_dev = {
                    "bucket": bucket,
                    "full": jnp.asarray(d_full),
                    "folded": jnp.asarray(d_folded),
                    "cnt": jnp.asarray(d_cnt.astype(np.int32)),
                    "folded_cnt": jnp.asarray(d_fcnt.astype(np.int32)),
                }

    # -- dispatch -----------------------------------------------------------
    def search(self, queries, k: int):
        """Top-k per query via the configured backend -> (ids, sims) numpy."""
        if self.backend in ("jnp", "tpu"):
            ids, sims, _ = self.search_tpu(queries, k)
            return np.asarray(ids), np.asarray(sims)
        return self.search_numpy(queries, k)

    def _apply_insert(self, fps):
        return self.store.insert(fps)   # compaction handled by the store

    # -- host-side (variable-shape) reference path --------------------------
    def _np_scores(self, q: np.ndarray, db: np.ndarray, db_cnt: np.ndarray):
        # tanimoto keeps the historical f64 scorer verbatim (its orderings
        # are the pre-metric baseline); other metrics score in f32 via the
        # shared oracle so host orderings match the device's f32 sort
        inter = np.bitwise_count(q[None, :] & db).sum(-1).astype(np.int64)
        if self.metric.name == "tanimoto":
            union = (int(np.bitwise_count(q).sum())
                     + db_cnt.astype(np.int64) - inter)
            return np.where(union > 0, inter / np.maximum(union, 1), 0.0)
        return metric_from_counts_np(self.metric, inter,
                                     int(np.bitwise_count(q).sum()),
                                     db_cnt.astype(np.int64))

    def search_numpy(self, queries, k: int):
        """Reference engine (numpy): true variable-range pruning, used for
        wall-clock algorithmic speedup measurements and as the parity oracle
        for the fixed-shape device path (`search_tpu`).

        The per-query candidate window is the main segment's Eq.2 range plus
        the delta rows whose popcount falls inside the same bounds, stably
        re-sorted by popcount (main first on ties) — exactly the window a
        from-scratch rebuild on the concatenated database would scan.
        """
        self._sync()
        st = self.store
        queries = np.asarray(queries)
        n_main_v = st.n_main
        full = st.main.db
        full_cnt = st.main.counts
        folded = st.main.folded
        folded_cnt = st.main.folded_counts
        order = st.main.order
        kr1 = fl.kr1_for(k, self.m)
        ids_out = np.full((len(queries), k), -1, dtype=np.int64)
        sims_out = np.zeros((len(queries), k), dtype=np.float32)
        # one shared Eq.2 implementation with the device path — the m=1
        # bit-for-bit parity contract depends on identical windows
        a_all = np.bitwise_count(queries).sum(-1)
        los, his = bb.bound_range_np(self._counts_np, a_all, self.cutoff,
                                     metric=self.metric)
        # delta mask from the SAME float64 bounds as the main window
        lo_cnt, hi_cnt = bb.bound_counts_np(a_all, self.cutoff,
                                            metric=self.metric)
        d_cnt = st.delta_counts
        scanned = 0
        for qi, q in enumerate(queries):
            lo, hi = los[qi], his[qi]
            d_idx = np.where((d_cnt >= lo_cnt[qi]) & (d_cnt <= hi_cnt[qi]))[0]
            n_win = (hi - lo) + len(d_idx)
            if n_win <= 0:
                continue
            scanned += n_win
            # merged window in the rebuilt sorted order: stable popcount
            # sort with the (already sorted) main run first, so equal
            # popcounts stay in ascending global-id order
            cnt_w = np.concatenate([full_cnt[lo:hi], d_cnt[d_idx]])
            mo = np.argsort(cnt_w, kind="stable")
            fold_w = np.concatenate(
                [folded[lo:hi], st.delta_folded[d_idx]])[mo]
            fcnt_w = np.concatenate(
                [folded_cnt[lo:hi], st.delta_folded_counts[d_idx]])[mo]
            full_w = np.concatenate([full[lo:hi], st.delta_db[d_idx]])[mo]
            cnt_w = cnt_w[mo]
            gids_w = np.concatenate([order[lo:hi], n_main_v + d_idx])[mo]
            qf = fl.fold(q[None], self.m, self.scheme)[0]
            s1 = self._np_scores(qf, fold_w, fcnt_w)
            kr1_eff = min(kr1, n_win)
            # stable sort, ties by ascending merged-window index — the same
            # deterministic order the device path's top_k produces
            cand = np.argsort(-s1, kind="stable")[:kr1_eff]
            s2 = self._np_scores(q, full_w[cand], cnt_w[cand])
            k_eff = min(k, len(cand))
            best = np.argsort(-s2, kind="stable")[:k_eff]
            ids_out[qi, :k_eff] = gids_w[cand[best]]
            sims_out[qi, :k_eff] = s2[best]
        self._record_batch(scanned, len(queries))
        return ids_out, sims_out

    # -- device-resident fixed-shape path -----------------------------------
    def _device_meta(self) -> dict:
        cap = self.store.main.capacity
        if self._device_state is not None and \
                self._device_state["capacity"] == cap:
            return self._device_state
        kops = None
        if self.backend != "jnp":
            try:
                from ..kernels import ops as kops_mod
                kops = kops_mod
            except Exception:  # Pallas unavailable: fall back to jnp stage 1
                kops = None
        if kops is not None:
            tile = kops._pick_tile(cap, None)
        else:
            tile = min(2048, max(128, 1 << (max(cap - 1, 1).bit_length() - 1)))
        self._device_state = {"kops": kops, "tile": tile,
                              "total_tiles": (cap + tile - 1) // tile,
                              "capacity": cap}
        return self._device_state

    def _make_stage1(self, bucket: int, k1m: int):
        """Shared stage-1 closure (windowed folded scan -> per-query top-k1m
        candidate window rows). Used by both `_build_device_search` and
        `_build_tiered_candidates` — one implementation, so the two
        residencies stay bit-identical by construction."""
        state = self._device_meta()
        kops, tile, capacity = state["kops"], state["tile"], state["capacity"]

        metric = self.metric

        def stage1_main(qf, folded, folded_cnt, lo_row, hi_row):
            if kops is not None:
                cand, s1 = kops.window_topk(qf, folded, folded_cnt, lo_row,
                                            hi_row, k=k1m, max_tiles=bucket,
                                            tile_n=tile, metric=metric)
            else:
                s = batched_metric_scores(qf, folded, metric, folded_cnt)
                idx = jnp.arange(capacity)[None, :]
                in_window = jnp.logical_and(idx >= lo_row[:, None],
                                            idx < hi_row[:, None])
                s = jnp.where(in_window, s, -jnp.inf)
                s1, cand = jax.lax.top_k(s, k1m)
                cand = jnp.where(jnp.isfinite(s1), cand, -1)
            return cand, s1

        return stage1_main

    def _make_delta_select(self, k1m: int, k1c: int, delta_bucket: int):
        """Shared main+delta candidate merge: stage-1 scores for both
        segments, merged in the *rebuilt* global popcount-sorted order and
        truncated to ``k1c`` candidates. Returns per-candidate metadata
        (scores, validity, delta routing, window rows, global ids) — the
        device pipeline gathers rescore rows from HBM right after this;
        the tiered pipeline returns it to the host instead."""
        capacity = self._device_meta()["capacity"]
        metric = self.metric
        BIG = jnp.int32(2**30)

        def select(qf, cand, s1, full_cnt, order, d_folded, d_cnt,
                   d_folded_cnt, d_ok, n_main):
            # delta stage-1: masked folded scan (same arithmetic as the
            # kernel: int popcounts routed through metric_from_counts)
            qf_cnt = popcount(qf)
            d_inter = jnp.sum(jax.lax.population_count(
                qf[:, None, :] & d_folded).astype(jnp.int32), axis=-1)
            s1d = metric_from_counts(metric, d_inter, qf_cnt[:, None],
                                     d_folded_cnt[None, :])
            s1d = jnp.where(d_ok, s1d, -jnp.inf)
            # virtual position of every candidate in the merged popcount-
            # sorted array (= the rebuilt sorted row): main row r keeps rank
            # r + |delta with cnt < cnt[r]|; delta row d gets its stable
            # (cnt, insertion-order) rank + |main with cnt <= cnt[d]|.
            # Delta global-ids always exceed main ids, which makes these two
            # searchsorted sides reproduce the rebuilt stable sort exactly.
            d_sorted = jnp.sort(d_cnt)                   # pads: PAD_COUNT
            d_rank = jnp.argsort(jnp.argsort(d_cnt, stable=True))
            pos_d = (d_rank + jnp.searchsorted(full_cnt, d_cnt, side="right")
                     ).astype(jnp.int32)
            safe_c = jnp.clip(cand, 0, capacity - 1)
            pos_m = cand + jnp.searchsorted(
                d_sorted, full_cnt[safe_c], side="left").astype(jnp.int32)
            pos_m = jnp.where(cand >= 0, pos_m, BIG)
            s_all = jnp.concatenate([s1, s1d], axis=1)   # (Q, k1m + D)
            pos_all = jnp.concatenate(
                [pos_m, jnp.broadcast_to(pos_d[None, :], s1d.shape)], axis=1)
            # stage-1 truncation in rebuilt order: score desc, position asc
            sel = jnp.lexsort((pos_all, -s_all), axis=-1)[:, :k1c]
            sel_s = jnp.take_along_axis(s_all, sel, axis=1)
            valid = jnp.isfinite(sel_s)
            is_d = sel >= k1m
            cand_sel = jnp.take_along_axis(cand, jnp.clip(sel, 0, k1m - 1),
                                           axis=1)
            d_slot = jnp.clip(sel - k1m, 0, delta_bucket - 1)
            safe_m = jnp.clip(cand_sel, 0, capacity - 1)
            gids = jnp.where(is_d, n_main + d_slot, order[safe_m])
            gids = jnp.where(valid, gids, -1)
            return sel_s, valid, is_d, d_slot, safe_m, gids

        return select

    def _build_device_search(self, bucket: int, k: int, delta_bucket: int):
        """One jitted two-stage pipeline for <= ``bucket``-tile main windows
        and a ``delta_bucket``-row delta segment (0 = no delta). All segment
        arrays are runtime arguments, so the compiled pipeline survives
        compactions that keep the capacity (and so the shapes) unchanged."""
        state = self._device_meta()
        capacity = state["capacity"]
        m, scheme = self.m, self.scheme
        kr1 = max(fl.kr1_for(k, m), k)
        k1m = min(kr1, capacity)
        stage1_main = self._make_stage1(bucket, k1m)

        metric = self.metric

        def rescore(queries, rows, cnts, valid):
            q_cnt = popcount(queries)
            inter = jnp.sum(jax.lax.population_count(
                queries[:, None, :] & rows).astype(jnp.int32), axis=-1)
            s2 = metric_from_counts(metric, inter, q_cnt[:, None], cnts)
            return jnp.where(valid, s2, -jnp.inf)

        def finish(vals, gids, ok, lo_row, hi_row, extra_scanned):
            k_out = vals.shape[1]
            ids = jnp.where(ok, gids, -1)
            sims = jnp.where(ok, vals, 0.0).astype(jnp.float32)
            if k_out < k:                               # k > N degenerate pad
                pad = ((0, 0), (0, k - k_out))
                ids = jnp.pad(ids, pad, constant_values=-1)
                sims = jnp.pad(sims, pad)
            scanned = jnp.sum(jnp.maximum(hi_row - lo_row, 0)) + extra_scanned
            return ids, sims, scanned

        if delta_bucket == 0:
            k_out = min(k, k1m)

            def run(queries, lo_row, hi_row, folded, folded_cnt, full,
                    full_cnt, order):
                qf = fl.fold_jax(queries, m, scheme)
                cand, s1 = stage1_main(qf, folded, folded_cnt, lo_row, hi_row)
                valid = cand >= 0
                safe = jnp.clip(cand, 0, capacity - 1)
                if m == 1:
                    # folded == full: stage-1 scores are already exact
                    vals, ok = s1[:, :k_out], valid[:, :k_out]
                    gids = order[safe[:, :k_out]]
                else:
                    s2 = rescore(queries, full[safe], full_cnt[safe], valid)
                    vals, pos = jax.lax.top_k(s2, k_out)  # fused top-k
                    top = jnp.take_along_axis(safe, pos, axis=1)
                    ok = jnp.isfinite(vals)
                    gids = order[top]
                return finish(vals, gids, ok, lo_row, hi_row, jnp.int32(0))

            return jax.jit(run)

        # -- main + delta: merge stage-1 candidates in the *rebuilt* global
        # popcount-sorted order before the kr1 truncation ------------------
        k1c = min(kr1, k1m + delta_bucket)
        k_out = min(k, k1c)
        select = self._make_delta_select(k1m, k1c, delta_bucket)

        def run(queries, lo_row, hi_row, folded, folded_cnt, full, full_cnt,
                order, d_full, d_folded, d_cnt, d_folded_cnt, d_ok, n_main):
            qf = fl.fold_jax(queries, m, scheme)
            cand, s1 = stage1_main(qf, folded, folded_cnt, lo_row, hi_row)
            sel_s, valid, is_d, d_slot, safe_m, gids = select(
                qf, cand, s1, full_cnt, order, d_folded, d_cnt,
                d_folded_cnt, d_ok, n_main)
            if m == 1:
                vals, ok = sel_s[:, :k_out], valid[:, :k_out]
                top_g = gids[:, :k_out]
            else:
                rows = jnp.where(is_d[..., None], d_full[d_slot],
                                 full[safe_m])
                cnts = jnp.where(is_d, d_cnt[d_slot], full_cnt[safe_m])
                s2 = rescore(queries, rows, cnts, valid)
                vals, p = jax.lax.top_k(s2, k_out)
                top_g = jnp.take_along_axis(gids, p, axis=1)
                ok = jnp.isfinite(vals)
            extra = jnp.sum(d_ok.astype(jnp.int32))
            return finish(vals, top_g, ok, lo_row, hi_row, extra)

        return jax.jit(run)

    # -- tiered residency: host-resident full rows, streamed rescore --------
    def _build_tiered_candidates(self, bucket: int, k: int,
                                 delta_bucket: int):
        """Candidate half of the pipeline for ``residency="tiered"``
        (m > 1): the same jitted stage-1 folded scan (+ rebuilt-order delta
        merge) as `_build_device_search`, stopped at the point where the
        device pipeline would gather full-resolution rows from HBM. The
        candidate metadata returns to the host, which gathers the rows from
        the host-resident main segment and streams them through
        `_tiered_rescore`."""
        state = self._device_meta()
        capacity = state["capacity"]
        m, scheme = self.m, self.scheme
        kr1 = max(fl.kr1_for(k, m), k)
        k1m = min(kr1, capacity)
        stage1_main = self._make_stage1(bucket, k1m)

        if delta_bucket == 0:
            def run(queries, lo_row, hi_row, folded, folded_cnt, order):
                qf = fl.fold_jax(queries, m, scheme)
                cand, s1 = stage1_main(qf, folded, folded_cnt, lo_row,
                                       hi_row)
                valid = cand >= 0
                safe = jnp.clip(cand, 0, capacity - 1)
                gids = jnp.where(valid, order[safe], -1)
                return safe, gids, valid

            return jax.jit(run)

        k1c = min(kr1, k1m + delta_bucket)
        select = self._make_delta_select(k1m, k1c, delta_bucket)

        def run(queries, lo_row, hi_row, folded, folded_cnt, full_cnt,
                order, d_folded, d_cnt, d_folded_cnt, d_ok, n_main):
            qf = fl.fold_jax(queries, m, scheme)
            cand, s1 = stage1_main(qf, folded, folded_cnt, lo_row, hi_row)
            sel_s, valid, is_d, d_slot, safe_m, gids = select(
                qf, cand, s1, full_cnt, order, d_folded, d_cnt,
                d_folded_cnt, d_ok, n_main)
            return safe_m, gids, valid, is_d, d_slot

        return jax.jit(run)

    def _tier_rescore_builder(self, k: int, chunk: int):
        """Fused rescore + top-k + rank-merge over one streamed candidate
        chunk. Candidate popcounts are recomputed on device from the
        streamed rows (identical integers to the stored counts for every
        valid candidate; invalid ones are masked to -inf on both
        residencies). Per-chunk `lax.top_k` breaks score ties by the lowest
        in-chunk index and `merge_sorted` keeps the running (earlier-chunk)
        run ahead on ties; chunks ascend in stage-1 candidate rank, so the
        merged run reproduces the device path's single global top-k bit for
        bit."""
        dk = min(k, chunk)
        metric = self.metric

        def run(queries, rows, valid_c, gids_c, run_vals, run_ids):
            cnts = jnp.sum(jax.lax.population_count(rows).astype(jnp.int32),
                           axis=-1)
            q_cnt = popcount(queries)
            inter = jnp.sum(jax.lax.population_count(
                queries[:, None, :] & rows).astype(jnp.int32), axis=-1)
            s2 = metric_from_counts(metric, inter, q_cnt[:, None], cnts)
            s2 = jnp.where(valid_c, s2, -jnp.inf)
            vals, pos = jax.lax.top_k(s2, dk)
            g = jnp.take_along_axis(gids_c, pos, axis=1)
            g = jnp.where(jnp.isfinite(vals), g, -1)
            if dk < k:
                pad = ((0, 0), (0, k - dk))
                vals = jnp.pad(vals, pad, constant_values=-jnp.inf)
                g = jnp.pad(g, pad, constant_values=-1)
            return jax.vmap(merge_sorted)(run_vals, run_ids, vals, g)

        return jax.jit(run)

    def _tiered_rescore(self, queries, k: int, safe_m, gids, valid,
                        is_d, d_slot):
        """Stream the BitBound-selected candidate rows host -> HBM in
        double-buffered chunks and rank-merge the per-chunk top-k runs.
        ``jax.device_put`` of chunk i+1 is dispatched before the fused
        rescore kernel on chunk i, so the host link overlaps compute; the
        wait on the staged buffer is timed as the stall telemetry."""
        sm = _store_mod()
        nq = queries.shape[0]
        safe_np = np.asarray(safe_m)
        gids_np = np.asarray(gids)
        valid_np = np.asarray(valid)
        n_cand = safe_np.shape[1]
        C = max(1, min(sm.next_pow2(max(self.tier_chunk, 1)),
                       sm.next_pow2(n_cand)))
        n_chunks = (n_cand + C - 1) // C
        rfn = self._cached(("tierrescore", int(k), C),
                           lambda: self._tier_rescore_builder(k, C))
        if is_d is not None:
            is_d_np = np.asarray(is_d)
            d_slot_np = np.asarray(d_slot)
            d_db = self.store.delta_db
            nd = max(d_db.shape[0], 1)

        def host_chunk(c):
            lo_c, hi_c = c * C, min((c + 1) * C, n_cand)
            rows = self._full_np[safe_np[:, lo_c:hi_c]]
            v = valid_np[:, lo_c:hi_c]
            g = gids_np[:, lo_c:hi_c]
            if is_d is not None:
                dm = is_d_np[:, lo_c:hi_c]
                if dm.any():
                    ds = np.minimum(d_slot_np[:, lo_c:hi_c], nd - 1)
                    rows = np.where(dm[:, :, None], d_db[ds], rows)
            if hi_c - lo_c < C:     # last chunk: pad to the compiled shape
                pad = C - (hi_c - lo_c)
                rows = np.concatenate(
                    [rows, np.zeros((nq, pad, rows.shape[2]), np.uint32)],
                    axis=1)
                v = np.concatenate([v, np.zeros((nq, pad), bool)], axis=1)
                g = np.concatenate(
                    [g, np.full((nq, pad), -1, g.dtype)], axis=1)
            return np.ascontiguousarray(rows), v, np.ascontiguousarray(g)

        run_vals = jnp.full((nq, k), -jnp.inf, jnp.float32)
        run_ids = jnp.full((nq, k), -1, jnp.int32)
        # tracing (ISSUE 8): same span scheme as the brute tiered scan —
        # "tier.device_put" flow spans on the h2d-stream track (dispatch ->
        # observed-ready), per-chunk "tier.host_gather" / "tier.rescore"
        # stack spans, with a tracing-only sync so rescore spans carry real
        # device time and chunk i+1's transfer visibly overlaps chunk i.
        traced = _TR.enabled
        stall = 0.0
        t_all = time.perf_counter()
        put_h = _TR.begin("tier.device_put", track="h2d-stream", chunk=0)
        with _TR.span("tier.host_gather", chunk=0):
            first = host_chunk(0)
        staged = jax.device_put(first)
        for c in range(n_chunks):
            cur, cur_h = staged, put_h
            if c + 1 < n_chunks:
                put_h = _TR.begin("tier.device_put", track="h2d-stream",
                                  chunk=c + 1)
                with _TR.span("tier.host_gather", chunk=c + 1):
                    nxt = host_chunk(c + 1)
                staged = jax.device_put(nxt)
            ts = time.perf_counter()
            jax.block_until_ready(cur)
            te = time.perf_counter()
            stall += te - ts
            cur_h.end()
            rows_c, v_c, g_c = cur
            if traced:
                _TR.emit("tier.stall", ts, te, chunk=c)
                with _TR.span("tier.rescore", chunk=c, cols=C):
                    run_vals, run_ids = rfn(queries, rows_c, v_c, g_c,
                                            run_vals, run_ids)
                    jax.block_until_ready(run_vals)  # tracing-only sync
            else:
                run_vals, run_ids = rfn(queries, rows_c, v_c, g_c,
                                        run_vals, run_ids)
        jax.block_until_ready(run_vals)
        total = time.perf_counter() - t_all
        words = self._full_np.shape[1]
        self.stats.update(
            residency="tiered", tiered_chunks=n_chunks, tiered_chunk_cols=C,
            tiered_streamed_bytes=int(n_chunks) * int(C) * int(nq)
            * (4 * words + 5),
            tiered_stall_s=stall, tiered_scan_s=total,
            tiered_stall_fraction=(stall / total) if total > 0 else 0.0)
        vals_np = np.asarray(run_vals)
        ids_np = np.asarray(run_ids)
        ok = np.isfinite(vals_np)
        ids = np.where(ok, ids_np, -1)
        sims = np.where(ok, vals_np, 0.0).astype(np.float32)
        return ids, sims

    def search_tpu(self, queries, k: int):
        """Fixed-shape device path -> ``(ids, sims, scanned)`` jax arrays.

        Host work is only window metadata (two searchsorteds + the delta
        popcount mask per batch and the power-of-two grid bucket); the folded
        scans, merge, gather, rescore and top-k all run inside one jitted
        function per ``(bucket, k, delta bucket, capacity)``.
        """
        self._sync()
        state = self._device_meta()
        tile, total_tiles = state["tile"], state["total_tiles"]
        queries = jnp.asarray(queries)
        q_np = np.asarray(queries)
        a = np.bitwise_count(q_np).sum(-1)
        lo, hi = bb.bound_range_np(self._counts_np, a, self.cutoff,
                                   metric=self.metric)
        n_tiles = np.where(hi > lo,
                           (hi + tile - 1) // tile - lo // tile, 0)
        bucket = bb.bucket_tiles(int(n_tiles.max(initial=0)), total_tiles)
        if state["kops"] is None:
            bucket = total_tiles  # jnp fallback scans full rows, one variant
        dd = self._delta_dev
        delta_bucket = dd["bucket"] if dd is not None else 0
        lo_j = jnp.asarray(lo, jnp.int32)
        hi_j = jnp.asarray(hi, jnp.int32)
        ok_np = None
        if dd is not None:
            lo_cnt, hi_cnt = bb.bound_counts_np(a, self.cutoff,
                                                metric=self.metric)
            d_cnt_np = self.store.delta_counts
            ok_np = np.zeros((q_np.shape[0], delta_bucket), dtype=bool)
            ok_np[:, :d_cnt_np.shape[0]] = (
                (d_cnt_np[None, :] >= lo_cnt[:, None]) &
                (d_cnt_np[None, :] <= hi_cnt[:, None]))
        # m == 1 never gathers full-resolution rows (folded == full, stage-1
        # scores are already exact), so the device pipeline serves tiered
        # mode as-is with `self.full is None` — the traced function simply
        # never touches that argument.
        if self.residency == "tiered" and self.m > 1:
            cfn = self._cached(
                ("tiercand", bucket, int(k), delta_bucket,
                 state["capacity"]),
                lambda: self._build_tiered_candidates(bucket, k,
                                                      delta_bucket))
            with _TR.span("bitbound.stage1", bucket=int(bucket),
                          tiered=True):
                if dd is None:
                    safe_m, gids, valid = cfn(queries, lo_j, hi_j,
                                              self.folded, self.folded_cnt,
                                              self.order)
                    is_d = d_slot = None
                    extra = 0
                else:
                    safe_m, gids, valid, is_d, d_slot = cfn(
                        queries, lo_j, hi_j, self.folded, self.folded_cnt,
                        self.full_cnt, self.order, dd["folded"], dd["cnt"],
                        dd["folded_cnt"], jnp.asarray(ok_np),
                        jnp.int32(self.store.n_main))
                    extra = int(ok_np.sum())
            ids, sims = self._tiered_rescore(queries, k, safe_m, gids,
                                             valid, is_d, d_slot)
            scanned = int(np.maximum(hi - lo, 0).sum()) + extra
            self._record_batch(scanned, q_np.shape[0])
            return ids, sims, scanned
        fn = self._cached(
            (bucket, int(k), delta_bucket, state["capacity"]),
            lambda: self._build_device_search(bucket, k, delta_bucket))
        with _TR.span("bitbound.pipeline", bucket=int(bucket),
                      delta_bucket=int(delta_bucket)):
            if dd is None:
                ids, sims, scanned = fn(queries, lo_j, hi_j, self.folded,
                                        self.folded_cnt, self.full,
                                        self.full_cnt, self.order)
            else:
                ids, sims, scanned = fn(queries, lo_j, hi_j, self.folded,
                                        self.folded_cnt, self.full,
                                        self.full_cnt, self.order,
                                        dd["full"], dd["folded"],
                                        dd["cnt"], dd["folded_cnt"],
                                        jnp.asarray(ok_np),
                                        jnp.int32(self.store.n_main))
        self._record_batch(scanned, queries.shape[0])
        return ids, sims, scanned


@dataclass
class HNSWEngine(SearchEngine):
    """Approximate graph search (paper §III-C / §IV-B).

    ``backend`` (module-docstring contract):

    * ``"numpy"`` — host reference traversal, true variable-length queues
      (:func:`repro.core.hnsw.search_hnsw_numpy`).
    * ``"jnp"``   — batched device-resident traversal with the jnp
      gather-distance stage.
    * ``"tpu"``   — same traversal with the Pallas ``gather_tanimoto``
      kernel as the fine-grained distance stage (jnp fallback when Pallas
      is unavailable).

    ``layout`` selects the fine-grained distance stage's memory layout
    (bit-exact results either way):

    * ``"rows"``    — scattered row gather per neighbour id (the ``(Q, E)``
      kernel / jnp twin). One 128-byte fetch per neighbour.
    * ``"blocked"`` — neighbour-blocked base layer (``nbr_fps (N, 2M, W)``):
      one contiguous stream per popped node through the fused
      gather/score/sort expand kernel (``kernels/expand.py``) or its jnp
      twin — ``beam`` DMA streams per query-iteration instead of
      ``beam*2M`` row fetches, at the HBM cost of one extra ``2M*W``-word
      copy of the base layer. Upper layers and entry-point scoring keep the
      row kernel (tiny, adjacency is M-wide).

    ``beam`` is the number of candidates expanded per traversal iteration
    (``beam * 2M`` neighbours scored per kernel launch); ``beam=None`` (the
    default) auto-tunes it from ``ef_search`` (:func:`repro.core.hnsw.auto_beam`,
    the ROADMAP telemetry rule — equal recall, ~B× fewer lock-step
    iterations). ``max_iters`` caps the lock-step loop (default
    ``4*ef + 16``).

    ``shards`` (ISSUE 5) splits the database round-robin into N independent
    per-shard graphs, one per mesh device (FPScreen-style partition-then-
    merge — the paper's replicated traversal pipelines): a search fans out
    one lock-step traversal per shard — per-shard entry points, visited
    bitsets and PQ queues, each on its own device so launches overlap under
    async dispatch — and rank-merges the per-shard result runs into one
    global top-k (``core/distributed.merge_shard_topk`` over
    ``core/topk.merge_sorted_many``). ``shards=1`` is bit-identical to the
    unsharded path (same build seed, identity merge); ``shards=None`` (the
    default) skips the fan-out machinery entirely. Inserts route rows to
    their shard (``gid % N``) and only touched shards refresh their device
    graphs (a full per-shard rebuild at the padded capacity — compiled
    traversals are reused; the unsharded path's finer dirty-row scatter is
    not yet wired through the fan-out). The blocked layout shards
    naturally: each shard packs only its own nodes' ``nbr_fps``, so the
    extra ``2M*W``-word HBM copy is split N ways (the roofline budget in
    ``benchmarks/roofline.py --gather``).

    Online inserts go through :func:`repro.core.hnsw.insert_hnsw` (batched
    incremental construction, rng-continuation levels), so an engine that
    inserted online is graph-identical to one rebuilt from scratch on the
    concatenated database. The device graph is padded to a power-of-two node
    capacity: inserts below the capacity reuse every compiled traversal, and
    the post-insert refresh is **incremental** — only new fingerprint rows
    and the base-adjacency / neighbour-block rows the batch touched
    (``index.dirty_log``) are scattered into the device arrays; a full
    re-upload happens only when the capacity doubles. The ``tpu`` backend's
    insert-frontier scorer likewise keeps a capacity-padded device copy of
    the database and appends new rows in place instead of re-uploading the
    full database every batch.

    After each ``search``, :attr:`stats` holds the batch's traversal
    telemetry: ``iters`` / ``expansions`` / ``neighbour_evals`` totals and,
    on device backends, per-query arrays plus termination-reason counts
    (``converged`` vs ``max_iters_hit``).
    """
    db: np.ndarray
    m: int = 16
    ef_construction: int = 100
    ef_search: int = 64
    seed: int = 0
    index: hn.HNSWIndex = None
    _graph: hn.HNSWDeviceGraph = None
    backend: str | None = None
    layout: str = "rows"
    beam: int | None = None
    max_iters: int | None = None
    shards: int | None = None
    #: prebuilt per-shard indexes (durability warm restart) — skips the
    #: sharded build; requires ``shards`` and ignores ``db``
    shard_indexes: list | None = None
    #: similarity metric (None / name / spec string / Metric descriptor);
    #: the graph is built under it and searches must match — a restored
    #: index built under a different metric raises up front
    metric: Metric | str | None = None
    fp_bits: int | None = None

    BACKENDS = ("numpy", "jnp", "tpu")
    DEFAULT_BACKEND = "jnp"
    LAYOUTS = hn.LAYOUTS

    def _check_index_metric(self, index) -> None:
        built = getattr(index, "metric", TANIMOTO) or TANIMOTO
        if built != self.metric:
            raise ValueError(
                f"restored HNSW index was built under metric "
                f"{built.spec!r}; engine requests {self.metric.spec!r} — "
                f"graph neighbourhoods are metric-specific, rebuild instead")

    def __post_init__(self):
        self._init_engine()
        if self.metric is None and self.index is not None:
            self.metric = getattr(self.index, "metric", TANIMOTO)
        if self.metric is None and self.shard_indexes:
            self.metric = getattr(self.shard_indexes[0], "metric", TANIMOTO)
        self.metric = resolve_metric(self.metric)
        if self.beam is None:
            self.beam = hn.auto_beam(self.ef_search)
        if self.shard_indexes is not None and self.shards is None:
            raise ValueError("shard_indexes= requires shards=")
        if self.shards is not None:
            if self.index is not None:
                raise ValueError("pass either index= or shards=, not both")
            self.shards = int(self.shards)
            if self.shard_indexes is not None:
                if len(self.shard_indexes) != self.shards:
                    raise ValueError(
                        f"{len(self.shard_indexes)} restored shard indexes "
                        f"for shards={self.shards}")
                for ix in self.shard_indexes:
                    self._check_index_metric(ix)
                self._shard_indexes = list(self.shard_indexes)
            else:
                self._shard_indexes = hn.build_hnsw_sharded(
                    np.asarray(self.db), self.shards, m=self.m,
                    ef_construction=self.ef_construction, seed=self.seed,
                    metric=self.metric)
            self._resolve_metric_width(self._shard_indexes[0].db.shape[1])
            # the numpy backend never touches a device — don't init jax
            self._shard_devices = (None if self.backend == "numpy"
                                   else shard_devices(self.shards))
            self._shard_graphs = [None] * self.shards
            self._shard_dirty = [True] * self.shards
            self._graph = None
            self._refresh_shard_graphs()
            return
        if self.index is None:
            self.index = hn.build_hnsw(np.asarray(self.db), m=self.m,
                                       ef_construction=self.ef_construction,
                                       seed=self.seed, metric=self.metric)
        else:
            self._check_index_metric(self.index)
        self._resolve_metric_width(self.index.db.shape[1])
        self._graph_dirty = False
        self._graph_n = 0          # index.n the device graph was built for
        self._dirty_pos = 0        # consumed prefix of index.dirty_log
        self._dirty_epoch = 0      # dirty_log epoch the prefix belongs to
        self._upper_version = 0    # index.upper_version the graph carries
        self._insert_db_cache = None  # (device db (cap, W), rows filled)
        self._refresh_graph()

    @property
    def n_total(self) -> int:
        if self.shards is not None:
            return sum(ix.n for ix in self._shard_indexes)
        return self.index.n

    def _refresh_shard_graphs(self) -> None:
        """(Re)build the device graphs of shards whose index grew or whose
        padded capacity doubled; untouched shards keep their device copy."""
        if self.backend == "numpy":
            self._shard_dirty = [False] * self.shards
            return
        np2 = _store_mod().next_pow2
        for s, idx in enumerate(self._shard_indexes):
            g = self._shard_graphs[s]
            cap = np2(idx.n)
            if g is not None and not self._shard_dirty[s] \
                    and g.db.shape[0] == cap:
                continue
            self._shard_graphs[s] = hn.place_graph(
                hn.to_device_graph(idx, capacity=cap, layout=self.layout),
                self._shard_devices[s])
            self._shard_dirty[s] = False

    def _refresh_graph(self) -> None:
        # the numpy backend never touches the device — don't ship the graph
        if self.backend == "numpy":
            self._graph = None
            return
        idx = self.index
        cap = _store_mod().next_pow2(idx.n)
        g = self._graph
        log = idx.dirty_log
        if (g is None or g.db.shape[0] != cap or log is None
                or self._graph_n > idx.n
                or self._dirty_epoch != idx.dirty_epoch):
            self._graph = hn.to_device_graph(idx, capacity=cap,
                                             layout=self.layout)
        else:
            # incremental refresh: scatter only the rows the insert batches
            # touched (new fingerprints + dirty base-adjacency rows and, on
            # the blocked layout, their neighbour blocks) into the device
            # arrays — the full re-upload only ever happens on a capacity
            # doubling. tests/test_hnsw_backends.py pins this equal to a
            # fresh to_device_graph.
            rows = sorted(set(log[self._dirty_pos:])
                          | set(range(self._graph_n, idx.n)))
            db_dev, cnt_dev = g.db, g.db_popcount
            base_dev, nbr_fps, nbr_cnt = g.base_adj, g.nbr_fps, g.nbr_cnt
            if rows:
                rows = np.asarray(rows, dtype=np.int64)
                adj_rows = idx.base_adj[rows]
                db_dev = db_dev.at[rows].set(jnp.asarray(idx.db[rows]))
                cnt_dev = cnt_dev.at[rows].set(
                    jnp.asarray(idx.db_popcount[rows].astype(np.int32)))
                base_dev = base_dev.at[rows].set(jnp.asarray(adj_rows))
                if self.layout == "blocked":
                    fps_np, cnt_np = hn._blocked_rows(
                        idx.db, idx.db_popcount, adj_rows)
                    nbr_fps = nbr_fps.at[rows].set(jnp.asarray(fps_np))
                    nbr_cnt = nbr_cnt.at[rows].set(jnp.asarray(cnt_np))
            # upper layers only change when a batch inserted a level>0 node
            # (index.upper_version) — level-0-only batches skip the O(cap)
            # densify+upload entirely
            upper_dev = g.upper_adj
            if self._upper_version != idx.upper_version:
                upper_dev = jnp.asarray(hn._dense_upper(idx, cap))
            self._graph = hn.HNSWDeviceGraph(
                db=db_dev, db_popcount=cnt_dev, base_adj=base_dev,
                upper_adj=upper_dev, entry_point=jnp.int32(idx.entry_point),
                max_level=max(idx.max_level, 0),
                nbr_fps=nbr_fps, nbr_cnt=nbr_cnt)
        self._graph_n = idx.n
        self._dirty_pos = len(idx.dirty_log) if idx.dirty_log is not None \
            else 0
        self._dirty_epoch = idx.dirty_epoch
        self._upper_version = idx.upper_version
        self._graph_dirty = False

    def _insert_scorer_factory(self, db: np.ndarray, db_cnt: np.ndarray):
        """Insert-frontier scorer routing neighbour batches through the
        Pallas ``gather_tanimoto`` kernel (ROADMAP "device-side
        construction": the graph walk stays host-side, the distance stage
        runs on device). The device database is cached capacity-padded
        across insert batches — new rows are appended in place (one scatter
        per batch) and a full upload only happens when the capacity doubles,
        instead of the old full-db re-upload per batch. Existing rows are
        immutable, so the cache key is just (capacity, rows filled).
        ``db_cnt`` is part of the scorer-factory protocol but unused here —
        the kernel recomputes row popcounts in-register."""
        del db_cnt
        n, w = db.shape
        cached = self._insert_db_cache
        if (cached is not None and cached[0].shape[0] >= n
                and cached[0].shape[1] == w):
            dev, filled = cached
            if n > filled:
                dev = dev.at[filled:n].set(jnp.asarray(db[filled:n]))
        else:
            cap = _store_mod().next_pow2(n)
            dev = jnp.zeros((cap, w), jnp.uint32).at[:n].set(jnp.asarray(db))
        self._insert_db_cache = (dev, n)

        metric = self.metric

        def scorer(q: np.ndarray, ids: np.ndarray) -> np.ndarray:
            s = _gather_score_frontier(jnp.asarray(q), dev,
                                       jnp.asarray(ids, dtype=jnp.int32),
                                       metric=metric)
            return np.asarray(s)
        return scorer

    def _apply_insert(self, fps):
        if self.shards is not None:
            # per-shard incremental construction in global-id order; only
            # the shards the batch landed on refresh at the next search.
            # (The device frontier scorer caches one db — per-shard dbs
            # differ, so sharded inserts keep the value-identical host
            # scorer.)
            gids, touched = hn.insert_hnsw_sharded(self._shard_indexes, fps)
            for s in touched:
                self._shard_dirty[s] = True
            return gids
        factory = None
        if self.backend == "tpu" and _kernels_available():
            factory = self._insert_scorer_factory
        gids = hn.insert_hnsw(self.index, fps, scorer_factory=factory)
        # lazy device refresh: N consecutive insert batches cost one
        # (incremental) graph update at the next search, not N
        self._graph_dirty = True
        return gids

    def _device_search(self, k: int, ef: int, beam: int, max_level: int):
        use_kernel = self.backend == "tpu" and _kernels_available()
        layout = self.layout
        max_iters = self.max_iters
        metric = self.metric
        key = (k, ef, beam, max_level, use_kernel, layout, metric)

        def build():
            def run(q, db, db_cnt, base_adj, upper_adj, ep, nbr_fps, nbr_cnt):
                g = hn.HNSWDeviceGraph(db=db, db_popcount=db_cnt,
                                       base_adj=base_adj, upper_adj=upper_adj,
                                       entry_point=ep, max_level=max_level,
                                       nbr_fps=nbr_fps, nbr_cnt=nbr_cnt)
                score_fn = None
                expand_fn = None
                if use_kernel:
                    from ..kernels import ops as kops

                    def score_fn(qs, qc, ids):
                        return kops.gather_tanimoto(qs, db, ids, q_cnt=qc,
                                                    metric=metric)
                if layout == "blocked":
                    if use_kernel:
                        def expand_fn(qs, qc, pop, flat, worst, kk):
                            return kops.expand_tanimoto_sorted(
                                qs, nbr_fps, nbr_cnt, pop, flat, worst, kk,
                                q_cnt=qc, metric=metric)
                    else:
                        def expand_fn(qs, qc, pop, flat, worst, kk):
                            return hn.expand_scores_jnp(
                                qs, qc, nbr_fps, nbr_cnt, pop, flat, worst,
                                kk, metric=metric)
                return hn.search_hnsw(g, q, k, ef, max_iters=max_iters,
                                      beam=beam, score_fn=score_fn,
                                      expand_fn=expand_fn, metric=metric)
            return jax.jit(run)
        return self._cached(key, build)

    def _search_sharded(self, queries, k: int, ef: int, beam: int):
        """Fan-out: one traversal per shard, rank-merge into global top-k."""
        n_shards = self.shards
        queries = np.asarray(queries)
        q_n = queries.shape[0]
        m2 = self._shard_indexes[0].base_adj.shape[1]
        if self.backend == "numpy":
            runs_i = np.full((n_shards, q_n, k), -1, dtype=np.int64)
            runs_s = np.zeros((n_shards, q_n, k), dtype=np.float32)
            evals = iters = 0
            for s, idx in enumerate(self._shard_indexes):
                ids, sims, ctr = hn.search_hnsw_numpy(idx, queries, k, ef)
                runs_i[s] = hn.sharded_global_ids(ids, s, n_shards)
                runs_s[s] = sims
                evals += ctr["evals"]
                iters += ctr["iters"]
            # host merge, same semantics as the device tree: pads lose to
            # every real entry, ties keep shard order (stable sort over the
            # shard-major concatenation)
            alli = runs_i.transpose(1, 0, 2).reshape(q_n, n_shards * k)
            alls = runs_s.transpose(1, 0, 2).reshape(q_n, n_shards * k)
            alls = np.where(alli >= 0, alls, -np.inf)
            order = np.argsort(-alls, axis=1, kind="stable")[:, :k]
            ids = np.take_along_axis(alli, order, axis=1)
            sims = np.take_along_axis(alls, order, axis=1)
            sims = np.where(ids >= 0, sims, 0.0).astype(np.float32)
            self._record_batch(evals, q_n)
            self.stats = {"backend": "numpy", "shards": n_shards,
                          "iters": iters, "expansions": iters,
                          "neighbour_evals": evals}
            return ids, sims
        if any(self._shard_dirty):
            self._refresh_shard_graphs()
        runs_s, runs_i, shard_stats = [], [], []
        dev0 = self._shard_devices[0]
        q_dev = jnp.asarray(queries)
        for s, g in enumerate(self._shard_graphs):
            fn = self._device_search(k, ef, beam, g.max_level)
            q_s = jax.device_put(q_dev, self._shard_devices[s])
            ids, sims, tstats = fn(q_s, g.db, g.db_popcount, g.base_adj,
                                   g.upper_adj, g.entry_point, g.nbr_fps,
                                   g.nbr_cnt)
            runs_i.append(jax.device_put(ids, dev0))
            runs_s.append(jax.device_put(sims, dev0))
            shard_stats.append(tstats)
        gids = hn.globalize_shard_ids(jnp.stack(runs_i))
        ids, sims = merge_shard_topk(jnp.stack(runs_s), gids, k)
        iters = np.stack([np.asarray(st.iters) for st in shard_stats])
        expans = np.stack([np.asarray(st.expansions) for st in shard_stats])
        reason = np.stack([np.asarray(st.reason) for st in shard_stats])
        self._record_batch(int(expans.sum()) * m2, q_n)
        self.stats = {
            "backend": self.backend,
            "layout": self.layout,
            "shards": n_shards,
            **hn.stats_summary(iters, expans, reason, m2),
            "iters_per_query": iters.sum(axis=0),
            "expansions_per_query": expans.sum(axis=0),
            "per_shard": [{"iters": int(i.sum()), "expansions": int(e.sum())}
                          for i, e in zip(iters, expans)],
        }
        return np.asarray(ids), np.asarray(sims)

    def search(self, queries, k: int, ef: int | None = None,
               beam: int | None = None):
        ef = ef or self.ef_search
        beam = beam or self.beam
        # the span folds the traversal's TraversalStats totals into its args
        # via span.set() before closing (no-op when tracing is disabled)
        with _TR.span("hnsw.search", backend=self.backend, ef=int(ef),
                      shards=self.shards or 1) as sp:
            if self.shards is not None:
                ids, sims = self._search_sharded(queries, k, ef, beam)
                sp.set(**{kk: self.stats[kk]
                          for kk in ("iters", "expansions", "neighbour_evals")
                          if kk in self.stats})
                return ids, sims
            m2 = self.index.base_adj.shape[1]
            if self.backend == "numpy":
                ids, sims, ctr = hn.search_hnsw_numpy(
                    self.index, np.asarray(queries), k, ef)
                self._record_batch(ctr["evals"], len(queries))
                self.stats = {"backend": "numpy", "iters": ctr["iters"],
                              "expansions": ctr["iters"],
                              "neighbour_evals": ctr["evals"]}
                sp.set(iters=ctr["iters"], neighbour_evals=ctr["evals"])
                return ids, sims
            if self._graph_dirty:
                self._refresh_graph()
            fn = self._device_search(k, ef, beam, self._graph.max_level)
            g = self._graph
            ids, sims, tstats = fn(jnp.asarray(queries), g.db, g.db_popcount,
                                   g.base_adj, g.upper_adj, g.entry_point,
                                   g.nbr_fps, g.nbr_cnt)
            iters = np.asarray(tstats.iters)
            expans = np.asarray(tstats.expansions)
            # each expanded candidate gathers and scores <= 2M neighbour
            # slots
            self._record_batch(int(expans.sum()) * m2, iters.shape[0])
            self.stats = {
                "backend": self.backend,
                "layout": self.layout,
                **hn.stats_summary(iters, expans, tstats.reason, m2),
                "iters_per_query": iters,
                "expansions_per_query": expans,
            }
            sp.set(iters=self.stats["iters"],
                   expansions=self.stats["expansions"],
                   neighbour_evals=self.stats["neighbour_evals"],
                   converged=self.stats["converged"],
                   max_iters_hit=self.stats["max_iters_hit"])
            return ids, sims


def recall_at_k(pred_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Top-K matching rate vs brute force (paper's accuracy definition)."""
    hits = 0
    for p, t in zip(pred_ids, true_ids):
        hits += len(set(int(x) for x in p if x >= 0) & set(int(x) for x in t))
    return hits / true_ids.size
