"""Unified similarity-search engines (paper §IV).

Three engines, one per paper design point:

* :class:`BruteForceEngine` — exhaustive linear scan with the fused
  scan+top-k path (on-the-fly engine; Pallas kernel when enabled, streaming
  jnp fallback otherwise).
* :class:`BitBoundFoldingEngine` — exhaustive with Eq.2 popcount pruning and
  2-stage modulo-OR folding.
* :class:`HNSWEngine` — approximate graph search.

All engines share ``search(queries, k) -> (ids, sims)`` and report per-query
work counters used by the benchmarks (candidates scanned, etc.).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import bitbound as bb
from . import folding as fl
from . import hnsw as hn
from .fingerprints import popcount, tanimoto_scores
from .topk import streaming_topk


def _brute_topk(queries: jax.Array, db: jax.Array, db_cnt: jax.Array, k: int,
                use_kernel: bool, tile: int = 2048):
    if use_kernel:
        from ..kernels import ops as kops
        return kops.tanimoto_topk(queries, db, k=k, db_popcount=db_cnt)

    def one(q):
        s = tanimoto_scores(q, db, db_cnt)
        return streaming_topk(s, k, tile=tile)

    vals, idxs = jax.vmap(one)(queries)
    return idxs, vals


@dataclass
class BruteForceEngine:
    db: jax.Array
    use_kernel: bool = False

    def __post_init__(self):
        self.db = jnp.asarray(self.db)
        self.db_cnt = popcount(self.db)
        self._search = jax.jit(
            lambda q, k: _brute_topk(q, self.db, self.db_cnt, k, self.use_kernel),
            static_argnames="k")

    def search(self, queries, k: int):
        ids, sims = self._search(jnp.asarray(queries), k)
        return np.asarray(ids), np.asarray(sims)

    def scanned(self, n_queries: int) -> int:
        return n_queries * self.db.shape[0]


@dataclass
class BitBoundFoldingEngine:
    """BitBound (Eq. 2) + 2-stage folding (paper §III-B, §IV-A).

    Stage 1 scans only the popcount-bounded range of the *folded* DB and keeps
    k_r1 = k*m*log2(2m) candidates; stage 2 rescores them at full resolution.
    ``cutoff`` is the similarity cutoff Sc. m=1 disables folding (pure
    BitBound).
    """
    db: np.ndarray
    cutoff: float = 0.8
    m: int = 4
    scheme: int = 1
    use_kernel: bool = False

    def __post_init__(self):
        self.index = bb.build_index(jnp.asarray(self.db))
        folded_np = fl.fold(np.asarray(self.index.db), self.m, self.scheme)
        self.folded = jnp.asarray(folded_np)
        self.folded_cnt = popcount(self.folded)
        self.full = self.index.db
        self.full_cnt = self.index.counts
        self._last_scanned = 0
        if self.use_kernel:
            from ..kernels import ops as kops
            self._kernel = kops
        # jitted per-(range-bucket) stage-1 scan: bucket sizes are powers of 2
        self._stage1_cache: dict[int, callable] = {}

    # -- host-side (variable-shape) reference path --------------------------
    def _np_scores(self, q: np.ndarray, db: np.ndarray, db_cnt: np.ndarray):
        inter = np.bitwise_count(q[None, :] & db).sum(-1).astype(np.int64)
        union = int(np.bitwise_count(q).sum()) + db_cnt.astype(np.int64) - inter
        return np.where(union > 0, inter / np.maximum(union, 1), 0.0)

    def search(self, queries, k: int):
        """Reference engine (numpy): true variable-range pruning, used for
        wall-clock algorithmic speedup measurements. The fixed-shape TPU path
        is `search_tpu`."""
        queries = np.asarray(queries)
        full = np.asarray(self.full)
        full_cnt = np.asarray(self.full_cnt)
        folded = np.asarray(self.folded)
        folded_cnt = np.asarray(self.folded_cnt)
        order = np.asarray(self.index.order)
        kr1 = fl.kr1_for(k, self.m)
        ids_out = np.full((len(queries), k), -1, dtype=np.int64)
        sims_out = np.zeros((len(queries), k), dtype=np.float32)
        scanned = 0
        for qi, q in enumerate(queries):
            a = int(np.bitwise_count(q).sum())
            lo_cnt = int(np.ceil(a * self.cutoff))
            hi_cnt = int(np.floor(a / max(self.cutoff, 1e-6)))
            lo = np.searchsorted(full_cnt, lo_cnt, side="left")
            hi = np.searchsorted(full_cnt, hi_cnt, side="right")
            if hi <= lo:
                continue
            scanned += hi - lo
            qf = fl.fold(q[None], self.m, self.scheme)[0]
            s1 = self._np_scores(qf, folded[lo:hi], folded_cnt[lo:hi])
            kr1_eff = min(kr1, hi - lo)
            cand = np.argpartition(-s1, kr1_eff - 1)[:kr1_eff] + lo
            s2 = self._np_scores(q, full[cand], full_cnt[cand])
            k_eff = min(k, len(cand))
            best = np.argsort(-s2, kind="stable")[:k_eff]
            ids_out[qi, :k_eff] = order[cand[best]]
            sims_out[qi, :k_eff] = s2[best]
        self._last_scanned = scanned
        return ids_out, sims_out

    def scanned(self, n_queries: int) -> int:
        return self._last_scanned


@dataclass
class HNSWEngine:
    db: np.ndarray
    m: int = 16
    ef_construction: int = 100
    ef_search: int = 64
    seed: int = 0
    index: hn.HNSWIndex = None
    _graph: hn.HNSWDeviceGraph = None

    def __post_init__(self):
        if self.index is None:
            self.index = hn.build_hnsw(np.asarray(self.db), m=self.m,
                                       ef_construction=self.ef_construction,
                                       seed=self.seed)
        self._graph = hn.to_device_graph(self.index)
        self._jit_search = jax.jit(
            lambda q, k, ef: hn.search_hnsw(self._graph, q, k, ef),
            static_argnames=("k", "ef"))
        self._last_iters = 0

    def search(self, queries, k: int, ef: int | None = None):
        ef = ef or self.ef_search
        ids, sims, iters = self._jit_search(jnp.asarray(queries), k, ef)
        self._last_iters = int(np.asarray(iters).sum())
        return np.asarray(ids), np.asarray(sims)

    def scanned(self, n_queries: int) -> int:
        # each traversal iteration evaluates <= 2M neighbours
        return self._last_iters * 2 * self.index.m


def recall_at_k(pred_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Top-K matching rate vs brute force (paper's accuracy definition)."""
    hits = 0
    for p, t in zip(pred_ids, true_ids):
        hits += len(set(int(x) for x in p if x >= 0) & set(int(x) for x in t))
    return hits / true_ids.size
