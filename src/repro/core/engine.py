"""Unified similarity-search engines (paper §IV).

Three engines, one per paper design point, on one shared base:

* :class:`BruteForceEngine` — exhaustive linear scan with the fused
  scan+top-k path (on-the-fly engine; Pallas kernel when enabled, streaming
  jnp fallback otherwise).
* :class:`BitBoundFoldingEngine` — exhaustive with Eq.2 popcount pruning and
  2-stage modulo-OR folding; host-side numpy reference plus a fully
  device-resident ``search_tpu`` path.
* :class:`HNSWEngine` — approximate graph search over the device-resident
  batched traversal engine (``core/hnsw.py``).

The ``backend=`` contract (shared, :class:`SearchEngine`)
---------------------------------------------------------
Every engine exposes ``search(queries, k) -> (ids, sims)`` numpy arrays and a
``backend`` selector naming the execution path:

* ``"numpy"`` — host-side reference loop with true variable-length data
  structures. Exact semantics; the parity oracle for the device paths.
  (Engines whose reference *is* the device path don't offer it.)
* ``"jnp"``   — fully device-resident fixed-shape path built from plain jnp
  ops (works on any JAX backend, no Pallas required).
* ``"tpu"``   — same device-resident path with its hot stage swapped for the
  Pallas kernel (Mosaic on TPU, interpret mode elsewhere). Engines fall back
  to the ``jnp`` stage automatically when Pallas cannot be imported.

Invalid names raise ``ValueError`` listing the engine's supported backends.
The legacy ``use_kernel=True`` flag maps onto ``backend="tpu"`` when
``backend`` is unset.

Work accounting: ``scanned(n_queries)`` is the number of candidate
fingerprints the engine scores for ``n_queries`` queries, extrapolated from
the *most recent* ``search`` batch: ``last_batch_total * n_queries /
last_batch_n_queries``. Before any search it is 0 for data-dependent
engines; engines whose per-query work is input-independent compute it in
closed form. Per-batch traversal telemetry beyond that single number lives
in the engine's ``stats`` dict (see :attr:`HNSWEngine.stats`).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import bitbound as bb
from . import folding as fl
from . import hnsw as hn
from .fingerprints import popcount, tanimoto_scores, batched_tanimoto_scores
from .topk import streaming_topk


def _kernels_available() -> bool:
    try:
        from ..kernels import ops  # noqa: F401
        return True
    except Exception:  # Pallas/Mosaic not importable on this install
        return False


class SearchEngine:
    """Shared engine plumbing: backend selection, compiled-function caching
    and the ``scanned`` work-counter contract (module docstring).

    Subclasses declare ``BACKENDS`` / ``DEFAULT_BACKEND`` and call
    :meth:`_init_engine` from ``__post_init__``; per-batch work is recorded
    with :meth:`_record_batch` and jitted pipelines are memoised per static
    key with :meth:`_cached`.
    """

    BACKENDS: tuple = ("jnp", "tpu")
    DEFAULT_BACKEND: str = "jnp"

    def _init_engine(self) -> None:
        if self.backend is None:
            self.backend = ("tpu" if getattr(self, "use_kernel", False)
                            else self.DEFAULT_BACKEND)
        if self.backend not in self.BACKENDS:
            raise ValueError(
                f"{type(self).__name__} backend must be one of "
                f"{'/'.join(repr(b) for b in self.BACKENDS)}, "
                f"got {self.backend!r}")
        self._last_scanned = 0
        self._last_n_queries = 0
        self._jit_cache: dict = {}
        self.stats: dict = {}

    def _cached(self, key, builder):
        fn = self._jit_cache.get(key)
        if fn is None:
            fn = builder()
            self._jit_cache[key] = fn
        return fn

    def _record_batch(self, scanned, n_queries) -> None:
        self._last_scanned = int(scanned)
        self._last_n_queries = int(n_queries)

    def scanned(self, n_queries: int) -> int:
        """Candidates scored for ``n_queries`` queries, extrapolated from the
        most recent search batch (0 before any search)."""
        if self._last_n_queries == 0:
            return 0
        return round(self._last_scanned * n_queries / self._last_n_queries)

    def search(self, queries, k: int):
        raise NotImplementedError


def _brute_topk(queries: jax.Array, db: jax.Array, db_cnt: jax.Array, k: int,
                use_kernel: bool, tile: int = 2048):
    if use_kernel:
        from ..kernels import ops as kops
        return kops.tanimoto_topk(queries, db, k=k, db_popcount=db_cnt)

    def one(q):
        s = tanimoto_scores(q, db, db_cnt)
        return streaming_topk(s, k, tile=tile)

    vals, idxs = jax.vmap(one)(queries)
    return idxs, vals


@dataclass
class BruteForceEngine(SearchEngine):
    """Exhaustive scan. ``backend``: ``"tpu"`` = fused Pallas kernel
    (interpret-mode off-TPU), ``"jnp"`` = streaming jnp path."""
    db: jax.Array
    use_kernel: bool = False
    backend: str | None = None

    BACKENDS = ("jnp", "tpu")
    DEFAULT_BACKEND = "jnp"

    def __post_init__(self):
        self._init_engine()
        self.use_kernel = self.backend == "tpu" and _kernels_available()
        self.db = jnp.asarray(self.db)
        self.db_cnt = popcount(self.db)
        self._search = jax.jit(
            lambda q, k: _brute_topk(q, self.db, self.db_cnt, k, self.use_kernel),
            static_argnames="k")

    def search(self, queries, k: int):
        ids, sims = self._search(jnp.asarray(queries), k)
        return np.asarray(ids), np.asarray(sims)

    def scanned(self, n_queries: int) -> int:
        # per-query work is the whole DB regardless of the query batch
        return n_queries * self.db.shape[0]


@dataclass
class BitBoundFoldingEngine(SearchEngine):
    """BitBound (Eq. 2) + 2-stage folding (paper §III-B, §IV-A).

    Stage 1 scans only the popcount-bounded range of the *folded* DB and keeps
    ``k_r1 = k*m*log2(2m)`` candidates; stage 2 rescores them at full
    resolution. ``cutoff`` is the similarity cutoff Sc; ``m=1`` disables
    folding (pure BitBound).

    Two execution paths share the index:

    * ``search_numpy`` — host-side reference with true variable-length Eq.2
      ranges (one python loop per query). Exact semantics, used as the parity
      oracle and for algorithmic speedup measurements.
    * ``search_tpu`` — device-resident fixed-shape path: stage 1 runs the
      scalar-prefetched row-window Pallas kernel over each query's Eq.2 tile
      window of the folded DB (``kernels.ops.window_topk``), stage 2 gathers
      the ``k_r1`` survivors and rescores at full resolution with a fused
      top-k — one jitted function, no host round-trips, returning
      ``(ids, sims, scanned)`` as device arrays. Window sizes are bucketed to
      powers of two (``bitbound.bucket_tiles``) and one compiled function is
      cached per ``(bucket, k)``, so recompilation is O(log n_tiles). When
      Pallas is unavailable (or ``backend="jnp"``) stage 1 falls back to a
      masked jnp scan with identical results.

    ``backend`` selects what :meth:`search` runs: ``"numpy"`` (default,
    reference), ``"tpu"`` (Pallas device path) or ``"jnp"`` (device path
    without Pallas).
    """
    db: np.ndarray
    cutoff: float = 0.8
    m: int = 4
    scheme: int = 1
    use_kernel: bool = False
    backend: str | None = None

    BACKENDS = ("numpy", "jnp", "tpu")
    DEFAULT_BACKEND = "numpy"

    def __post_init__(self):
        self._init_engine()
        self.index = bb.build_index(jnp.asarray(self.db))
        folded_np = fl.fold(np.asarray(self.index.db), self.m, self.scheme)
        self.folded = jnp.asarray(folded_np)
        self.folded_cnt = popcount(self.folded)
        self.full = self.index.db
        self.full_cnt = self.index.counts
        self._counts_np = np.asarray(self.index.counts)
        # device path: jitted two-stage search per (window-bucket, k)
        self._stage1_cache = self._jit_cache
        self._device_state: dict | None = None

    # -- dispatch -----------------------------------------------------------
    def search(self, queries, k: int):
        """Top-k per query via the configured backend -> (ids, sims) numpy."""
        if self.backend in ("jnp", "tpu"):
            ids, sims, _ = self.search_tpu(queries, k)
            return np.asarray(ids), np.asarray(sims)
        return self.search_numpy(queries, k)

    # -- host-side (variable-shape) reference path --------------------------
    def _np_scores(self, q: np.ndarray, db: np.ndarray, db_cnt: np.ndarray):
        inter = np.bitwise_count(q[None, :] & db).sum(-1).astype(np.int64)
        union = int(np.bitwise_count(q).sum()) + db_cnt.astype(np.int64) - inter
        return np.where(union > 0, inter / np.maximum(union, 1), 0.0)

    def search_numpy(self, queries, k: int):
        """Reference engine (numpy): true variable-range pruning, used for
        wall-clock algorithmic speedup measurements and as the parity oracle
        for the fixed-shape device path (`search_tpu`)."""
        queries = np.asarray(queries)
        full = np.asarray(self.full)
        full_cnt = np.asarray(self.full_cnt)
        folded = np.asarray(self.folded)
        folded_cnt = np.asarray(self.folded_cnt)
        order = np.asarray(self.index.order)
        kr1 = fl.kr1_for(k, self.m)
        ids_out = np.full((len(queries), k), -1, dtype=np.int64)
        sims_out = np.zeros((len(queries), k), dtype=np.float32)
        # one shared Eq.2 implementation with the device path — the m=1
        # bit-for-bit parity contract depends on identical windows
        a_all = np.bitwise_count(queries).sum(-1)
        los, his = bb.bound_range_np(full_cnt, a_all, self.cutoff)
        scanned = 0
        for qi, q in enumerate(queries):
            lo, hi = los[qi], his[qi]
            if hi <= lo:
                continue
            scanned += hi - lo
            qf = fl.fold(q[None], self.m, self.scheme)[0]
            s1 = self._np_scores(qf, folded[lo:hi], folded_cnt[lo:hi])
            kr1_eff = min(kr1, hi - lo)
            # stable sort, ties by ascending sorted-row index — the same
            # deterministic order the device path's top_k produces
            cand = np.argsort(-s1, kind="stable")[:kr1_eff] + lo
            s2 = self._np_scores(q, full[cand], full_cnt[cand])
            k_eff = min(k, len(cand))
            best = np.argsort(-s2, kind="stable")[:k_eff]
            ids_out[qi, :k_eff] = order[cand[best]]
            sims_out[qi, :k_eff] = s2[best]
        self._record_batch(scanned, len(queries))
        return ids_out, sims_out

    # -- device-resident fixed-shape path -----------------------------------
    def _ensure_device(self) -> dict:
        if self._device_state is not None:
            return self._device_state
        kops = None
        if self.backend != "jnp":
            try:
                from ..kernels import ops as kops_mod
                kops = kops_mod
            except Exception:  # Pallas unavailable: fall back to jnp stage 1
                kops = None
        n = self.full.shape[0]
        if kops is not None:
            tile = kops._pick_tile(n, None)
        else:
            tile = min(2048, max(128, 1 << (max(n - 1, 1).bit_length() - 1)))
        total_tiles = (n + tile - 1) // tile
        self._device_state = {"kops": kops, "tile": tile,
                              "total_tiles": total_tiles}
        return self._device_state

    def _build_device_search(self, bucket: int, k: int):
        """One jitted two-stage pipeline for windows of <= ``bucket`` tiles."""
        state = self._ensure_device()
        kops, tile = state["kops"], state["tile"]
        n = self.full.shape[0]
        m, scheme = self.m, self.scheme
        k_stage1 = min(max(fl.kr1_for(k, m), k), n)
        k_out = min(k, k_stage1)
        folded, folded_cnt = self.folded, self.folded_cnt
        full, full_cnt, order = self.full, self.full_cnt, self.index.order

        def run(queries, lo_row, hi_row):
            qf = fl.fold_jax(queries, m, scheme)
            if kops is not None:
                cand, s1 = kops.window_topk(qf, folded, folded_cnt, lo_row,
                                            hi_row, k=k_stage1,
                                            max_tiles=bucket, tile_n=tile)
            else:
                s = batched_tanimoto_scores(qf, folded, folded_cnt)
                idx = jnp.arange(n)[None, :]
                in_window = jnp.logical_and(idx >= lo_row[:, None],
                                            idx < hi_row[:, None])
                s = jnp.where(in_window, s, -jnp.inf)
                s1, cand = jax.lax.top_k(s, k_stage1)
                cand = jnp.where(jnp.isfinite(s1), cand, -1)
            valid = cand >= 0
            safe = jnp.clip(cand, 0, n - 1)
            if m == 1:
                # folded == full: stage-1 scores are already exact
                vals, top = s1[:, :k_out], safe[:, :k_out]
                ok = valid[:, :k_out]
            else:
                rows = full[safe]                       # (Q, k_r1, W) gather
                q_cnt = popcount(queries)
                inter = jnp.sum(jax.lax.population_count(
                    queries[:, None, :] & rows).astype(jnp.int32), axis=-1)
                union = q_cnt[:, None] + full_cnt[safe] - inter
                s2 = jnp.where(union > 0,
                               inter.astype(jnp.float32) /
                               union.astype(jnp.float32), 0.0)
                s2 = jnp.where(valid, s2, -jnp.inf)
                vals, pos = jax.lax.top_k(s2, k_out)    # fused full-res top-k
                top = jnp.take_along_axis(safe, pos, axis=1)
                ok = jnp.isfinite(vals)
            ids = jnp.where(ok, order[top], -1)
            sims = jnp.where(ok, vals, 0.0).astype(jnp.float32)
            if k_out < k:                               # k > N degenerate pad
                pad = ((0, 0), (0, k - k_out))
                ids = jnp.pad(ids, pad, constant_values=-1)
                sims = jnp.pad(sims, pad)
            scanned = jnp.sum(jnp.maximum(hi_row - lo_row, 0))
            return ids, sims, scanned

        return jax.jit(run)

    def search_tpu(self, queries, k: int):
        """Fixed-shape device path -> ``(ids, sims, scanned)`` jax arrays.

        Host work is only window metadata (two searchsorteds per batch and
        the power-of-two grid bucket); the folded scan, gather, rescore and
        top-k all run inside one jitted function per ``(bucket, k)``.
        """
        state = self._ensure_device()
        tile, total_tiles = state["tile"], state["total_tiles"]
        queries = jnp.asarray(queries)
        q_np = np.asarray(queries)
        a = np.bitwise_count(q_np).sum(-1)
        lo, hi = bb.bound_range_np(self._counts_np, a, self.cutoff)
        n_tiles = np.where(hi > lo,
                           (hi + tile - 1) // tile - lo // tile, 0)
        bucket = bb.bucket_tiles(int(n_tiles.max(initial=0)), total_tiles)
        if state["kops"] is None:
            bucket = total_tiles  # jnp fallback scans full rows, one variant
        fn = self._cached((bucket, int(k)),
                          lambda: self._build_device_search(bucket, k))
        ids, sims, scanned = fn(queries, jnp.asarray(lo, jnp.int32),
                                jnp.asarray(hi, jnp.int32))
        self._record_batch(scanned, queries.shape[0])
        return ids, sims, scanned


@dataclass
class HNSWEngine(SearchEngine):
    """Approximate graph search (paper §III-C / §IV-B).

    ``backend`` (module-docstring contract):

    * ``"numpy"`` — host reference traversal, true variable-length queues
      (:func:`repro.core.hnsw.search_hnsw_numpy`).
    * ``"jnp"``   — batched device-resident traversal with the jnp
      gather-distance stage.
    * ``"tpu"``   — same traversal with the Pallas ``gather_tanimoto``
      kernel as the fine-grained distance stage (jnp fallback when Pallas
      is unavailable).

    ``beam`` is the number of candidates expanded per traversal iteration
    (``beam * 2M`` neighbours scored per kernel launch); ``max_iters`` caps
    the lock-step loop (default ``4*ef + 16``).

    After each ``search``, :attr:`stats` holds the batch's traversal
    telemetry: ``iters`` / ``expansions`` / ``neighbour_evals`` totals and,
    on device backends, per-query arrays plus termination-reason counts
    (``converged`` vs ``max_iters_hit``).
    """
    db: np.ndarray
    m: int = 16
    ef_construction: int = 100
    ef_search: int = 64
    seed: int = 0
    index: hn.HNSWIndex = None
    _graph: hn.HNSWDeviceGraph = None
    backend: str | None = None
    beam: int = 1
    max_iters: int | None = None

    BACKENDS = ("numpy", "jnp", "tpu")
    DEFAULT_BACKEND = "jnp"

    def __post_init__(self):
        self._init_engine()
        if self.index is None:
            self.index = hn.build_hnsw(np.asarray(self.db), m=self.m,
                                       ef_construction=self.ef_construction,
                                       seed=self.seed)
        # the numpy backend never touches the device — don't ship the graph
        self._graph = (None if self.backend == "numpy"
                       else hn.to_device_graph(self.index))
        self._score_fn = None   # None -> jnp gather inside search_hnsw
        if self.backend == "tpu" and _kernels_available():
            from ..kernels import ops as kops
            graph = self._graph

            def score_fn(qs, qc, ids):
                return kops.gather_tanimoto(qs, graph.db, ids, q_cnt=qc)
            self._score_fn = score_fn

    def _device_search(self, k: int, ef: int, beam: int):
        def build():
            return jax.jit(lambda q: hn.search_hnsw(
                self._graph, q, k, ef, max_iters=self.max_iters, beam=beam,
                score_fn=self._score_fn))
        return self._cached((k, ef, beam), build)

    def search(self, queries, k: int, ef: int | None = None,
               beam: int | None = None):
        ef = ef or self.ef_search
        beam = beam or self.beam
        m2 = self.index.base_adj.shape[1]
        if self.backend == "numpy":
            ids, sims, ctr = hn.search_hnsw_numpy(self.index,
                                                  np.asarray(queries), k, ef)
            self._record_batch(ctr["evals"], len(queries))
            self.stats = {"backend": "numpy", "iters": ctr["iters"],
                          "expansions": ctr["iters"],
                          "neighbour_evals": ctr["evals"]}
            return ids, sims
        fn = self._device_search(k, ef, beam)
        ids, sims, tstats = fn(jnp.asarray(queries))
        iters = np.asarray(tstats.iters)
        expans = np.asarray(tstats.expansions)
        reason = np.asarray(tstats.reason)
        # each expanded candidate gathers and scores <= 2M neighbour slots
        self._record_batch(int(expans.sum()) * m2, iters.shape[0])
        self.stats = {
            "backend": self.backend,
            "iters": int(iters.sum()),
            "expansions": int(expans.sum()),
            "neighbour_evals": int(expans.sum()) * m2,
            "converged": int((reason == hn.REASON_CONVERGED).sum()),
            "max_iters_hit": int((reason == hn.REASON_MAX_ITERS).sum()),
            "iters_per_query": iters,
            "expansions_per_query": expans,
        }
        return np.asarray(ids), np.asarray(sims)


def recall_at_k(pred_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Top-K matching rate vs brute force (paper's accuracy definition)."""
    hits = 0
    for p, t in zip(pred_ids, true_ids):
        hits += len(set(int(x) for x in p if x >= 0) & set(int(x) for x in t))
    return hits / true_ids.size
