"""Modulo-OR compression ("folding") — paper §III-B, Fig. 3, Table I.

For an L-bit fingerprint and folding level m (power of two):

* **Scheme 1** (strided / "modulo"): split into m sections of L/m bits and OR
  the sections together — bit j of the folded print is OR of bits
  j, j + L/m, j + 2L/m, ...  (This is the classic modulo-OR fold; the paper's
  Fig. 3 draws it as OR between L/m-strided sections.)
* **Scheme 2** (adjacent): OR every m neighbouring bits —
  folded bit j = OR of bits j*m .. j*m + m-1.

Scheme 1 preserves accuracy much better (Table I: 99.3% vs 91.5% at m=2)
because Morgan bits are locally correlated: hashing-adjacent bits collide
under scheme 2 far more often than L/m-strided bits.

Two-stage search (GPUsimilarity-style, paper §III-B): stage 1 scans the
m-folded DB (L/m bits/print -> m× less memory traffic) and returns
``k_r1 = k * m * log2(2m)`` candidates; stage 2 rescores only those on the
full-resolution DB and returns the top k.

Because folding only ORs bits, folded Tanimoto is a *biased estimate*; the
two-stage rescore restores exactness on everything stage 1 retains, so the
only error source is stage-1 recall — measured in Table I terms by
``benchmarks/folding_accuracy.py``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .fingerprints import WORD_BITS, pack_bits, unpack_bits


def kr1_for(k: int, m: int) -> int:
    """Paper: k_r1 = k * m * log2(2m)."""
    if m <= 1:
        return k
    return int(k * m * math.log2(2 * m))


def folded_words(W: int, m: int) -> int:
    """Folded word count for scheme 1: ceil(W / m) — widths off the m grid
    zero-pad the word axis (OR with zero words is the identity), so every
    ``fp_bits`` that packs to whole words folds at every level."""
    return -(-int(W) // int(m))


def fold_scheme1(words: np.ndarray, m: int, length: int = None) -> np.ndarray:
    """Strided modulo-OR fold of packed prints: (..., W) -> (..., ceil(W/m)).

    With L a multiple of 32*m, sections are whole words: word-level OR of
    m word-sections. Pure word ops — no unpacking needed (and this is how the
    TPU kernel folds on the fly). Word counts not divisible by m are
    zero-padded up to ``folded_words(W, m) * m`` first — identical scores,
    one extra partial section."""
    words = np.asarray(words)
    W = words.shape[-1]
    sec = folded_words(W, m)
    if sec * m != W:
        pad = np.zeros((*words.shape[:-1], sec * m - W), dtype=words.dtype)
        words = np.concatenate([words, pad], axis=-1)
    out = words.reshape(*words.shape[:-1], m, sec)
    result = out[..., 0, :]
    for i in range(1, m):
        result = result | out[..., i, :]
    return result


def fold_scheme1_jax(words: jax.Array, m: int) -> jax.Array:
    """Jit-traceable word-level scheme-1 fold (used on the query path).
    Must stay value-identical to :func:`fold_scheme1`, including the
    odd-width zero padding."""
    W = words.shape[-1]
    sec = folded_words(W, m)
    if sec * m != W:
        pad = jnp.zeros((*words.shape[:-1], sec * m - W), dtype=words.dtype)
        words = jnp.concatenate([words, pad], axis=-1)
    sections = words.reshape(*words.shape[:-1], m, sec)
    out = sections[..., 0, :]
    for i in range(1, m):
        out = out | sections[..., i, :]
    return out


def fold_scheme2_jax(words: jax.Array, m: int) -> jax.Array:
    """Jit-traceable adjacent-OR fold (query path of the device engine).

    Bit-level: unpack each uint32 word to its 32 bits, OR every m neighbouring
    bits, repack. Matches :func:`fold_scheme2` exactly, including the
    zero-bit padding for lengths off the m*32 grid."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)    # (..., W, 32)
    L = words.shape[-1] * WORD_BITS
    bits = bits.reshape(*words.shape[:-1], L)
    Lp = -(-L // (m * WORD_BITS)) * (m * WORD_BITS)
    if Lp != L:
        bits = jnp.concatenate(
            [bits, jnp.zeros((*bits.shape[:-1], Lp - L), dtype=bits.dtype)],
            axis=-1)
    folded = bits.reshape(*words.shape[:-1], Lp // m, m).max(axis=-1)
    out_words = folded.reshape(*words.shape[:-1], Lp // m // WORD_BITS,
                               WORD_BITS)
    weights = jnp.uint32(1) << shifts
    return jnp.sum(out_words.astype(jnp.uint32) * weights, axis=-1,
                   dtype=jnp.uint32)


def fold_jax(words: jax.Array, m: int, scheme: int = 1) -> jax.Array:
    """Jit-traceable :func:`fold` (both schemes), for on-device query folding."""
    if m == 1:
        return words
    if scheme == 1:
        return fold_scheme1_jax(words, m)
    if scheme == 2:
        return fold_scheme2_jax(words, m)
    raise ValueError(f"unknown folding scheme {scheme}")


def fold_scheme2(words: np.ndarray, m: int) -> np.ndarray:
    """Adjacent-OR fold: unpack, OR every m neighbouring bits, repack.
    Lengths off the m*32 grid zero-pad the bit axis first (identical
    scores; the folded print still packs to whole words)."""
    bits = unpack_bits(words)
    L = bits.shape[-1]
    Lp = -(-L // (m * WORD_BITS)) * (m * WORD_BITS)
    if Lp != L:
        bits = np.concatenate(
            [bits, np.zeros((*bits.shape[:-1], Lp - L), dtype=bits.dtype)],
            axis=-1)
    grouped = bits.reshape(*bits.shape[:-1], Lp // m, m)
    folded = grouped.max(axis=-1)
    return pack_bits(folded)


def fold(words: np.ndarray, m: int, scheme: int = 1) -> np.ndarray:
    if m == 1:
        return np.asarray(words)
    if scheme == 1:
        return fold_scheme1(words, m)
    if scheme == 2:
        return fold_scheme2(words, m)
    raise ValueError(f"unknown folding scheme {scheme}")


@dataclass
class FoldedDB:
    """Compressed + full-resolution database pair for 2-stage search."""
    full: jax.Array      # (N, W) uint32
    folded: jax.Array    # (N, W/m) uint32
    m: int
    scheme: int


def build_folded(db: np.ndarray, m: int, scheme: int = 1) -> FoldedDB:
    return FoldedDB(full=jnp.asarray(db), folded=jnp.asarray(fold(db, m, scheme)),
                    m=m, scheme=scheme)
